"""Pure-jnp oracles for the L1 Pallas kernels.

Every kernel in this package must agree with these references bit-for-bit
on indices (leftmost-min tie-break) and up to float equality on values.
``jnp.argmin`` returns the *first* occurrence of the minimum, which is
exactly the paper's leftmost-position convention (§2).
"""

import jax.numpy as jnp


def rmq_ref(xs, ls, rs):
    """Batched RMQ: for each query q, argmin of xs[ls[q] .. rs[q]].

    Args:
      xs: f32[n] values.
      ls, rs: i32[q] inclusive range endpoints, 0 <= l <= r < n.

    Returns:
      (mins f32[q], args i32[q]) with leftmost tie-break.
    """
    n = xs.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    mask = (idx[None, :] >= ls[:, None]) & (idx[None, :] <= rs[:, None])
    vals = jnp.where(mask, xs[None, :], jnp.inf)
    args = jnp.argmin(vals, axis=1).astype(jnp.int32)
    mins = jnp.min(vals, axis=1)
    return mins, args


def block_min_ref(xs, bs):
    """Per-block minimum and global argmin (paper §5.3's A' array).

    Requires n % bs == 0 (the AOT pipeline pads inputs to this shape).
    Returns (mins f32[n//bs], args i32[n//bs]).
    """
    n = xs.shape[0]
    assert n % bs == 0, "pad the array before calling"
    tiles = xs.reshape(n // bs, bs)
    local = jnp.argmin(tiles, axis=1).astype(jnp.int32)
    args = (jnp.arange(n // bs, dtype=jnp.int32) * bs + local).astype(jnp.int32)
    mins = jnp.min(tiles, axis=1)
    return mins, args


def masked_argmin_ref(vals, lo, hi):
    """Per-row masked argmin over column range [lo, hi] (empty => +inf, 0).

    Args:
      vals: f32[q, w].
      lo, hi: i32[q] inclusive column bounds; hi < lo marks an empty range.

    Returns:
      (mins f32[q], args i32[q]) — args are column indices.
    """
    w = vals.shape[1]
    col = jnp.arange(w, dtype=jnp.int32)
    mask = (col[None, :] >= lo[:, None]) & (col[None, :] <= hi[:, None])
    masked = jnp.where(mask, vals, jnp.inf)
    args = jnp.argmin(masked, axis=1).astype(jnp.int32)
    mins = jnp.min(masked, axis=1)
    return mins, args

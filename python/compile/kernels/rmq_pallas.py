"""L1 Pallas kernels — the compute hot-spot of the three-layer stack.

Hardware adaptation (DESIGN.md §1): the paper's hot spot is "one ray vs
many triangles, keep closest hit". An RMQ ray-cast is a *masked min/argmin
whose mask is a geometric range predicate*, so on TPU we tile that
reduction for the VPU instead of walking a BVH:

- ``rmq_kernel``: grid (query-tiles × array-blocks). Each step holds one
  array block and one query tile in VMEM (BlockSpec = the HBM→VMEM
  schedule the paper expressed with per-block geometry), computes the
  in-range mask against a global-index iota and folds (min, leftmost
  argmin) into the output accumulator. The paper's block-matrix
  decomposition maps exactly onto this grid.
- ``block_min_kernel``: builds the block-minimums array A' (§5.3).
- ``masked_argmin_kernel``: per-row bounded argmin over gathered tiles —
  the partial-block stage of Algorithm 6 in the L2 graph.

All kernels run ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU performance is *estimated* from the VMEM
footprint of these BlockSpecs (EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. (8, 128) is the f32 VPU lane layout; tiles are kept
# 2D-aligned so the same BlockSpecs lower to Mosaic unchanged on real TPU.
DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_N = 2048


def _rmq_step(l_ref, r_ref, x_ref, min_ref, arg_ref, *, block_n: int):
    """One grid step: fold array block j into the query tile's accumulator."""
    j = pl.program_id(1)
    base = j * block_n
    x = x_ref[...]  # f32[block_n]
    l = l_ref[...]  # i32[block_q]
    r = r_ref[...]
    # Global indices of this block's elements.
    idx = base + jax.lax.iota(jnp.int32, block_n)
    mask = (idx[None, :] >= l[:, None]) & (idx[None, :] <= r[:, None])
    vals = jnp.where(mask, x[None, :], jnp.inf)
    local_arg = jnp.argmin(vals, axis=1).astype(jnp.int32)  # leftmost
    local_min = jnp.min(vals, axis=1)
    global_arg = base + local_arg

    @pl.when(j == 0)
    def _init():
        min_ref[...] = jnp.full(min_ref.shape, jnp.inf, dtype=min_ref.dtype)
        arg_ref[...] = jnp.zeros(arg_ref.shape, dtype=arg_ref.dtype)

    cur_min = min_ref[...]
    cur_arg = arg_ref[...]
    # Strict '<': blocks are visited left-to-right, so ties keep the
    # earlier (leftmost) index.
    better = local_min < cur_min
    min_ref[...] = jnp.where(better, local_min, cur_min)
    arg_ref[...] = jnp.where(better, global_arg, cur_arg)


def rmq_kernel(xs, ls, rs, *, block_q: int = DEFAULT_BLOCK_Q, block_n: int = DEFAULT_BLOCK_N):
    """Batched exhaustive RMQ (the paper's EXHAUSTIVE baseline on the GPU
    side, §6.1) as a tiled Pallas reduction.

    Shapes: xs f32[n], ls/rs i32[q] with n % block_n == 0, q % block_q == 0.
    Returns (mins f32[q], args i32[q]).
    """
    n, q = xs.shape[0], ls.shape[0]
    assert n % block_n == 0 and q % block_q == 0, (n, q, block_n, block_q)
    grid = (q // block_q, n // block_n)
    kernel = functools.partial(_rmq_step, block_n=block_n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q,), lambda i, j: (i,)),  # ls
            pl.BlockSpec((block_q,), lambda i, j: (i,)),  # rs
            pl.BlockSpec((block_n,), lambda i, j: (j,)),  # xs
        ],
        out_specs=[
            pl.BlockSpec((block_q,), lambda i, j: (i,)),
            pl.BlockSpec((block_q,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q,), jnp.float32),
            jax.ShapeDtypeStruct((q,), jnp.int32),
        ],
        interpret=True,
    )(ls, rs, xs)


def _block_min_step(x_ref, min_ref, arg_ref, *, bs: int):
    b = pl.program_id(0)
    x = x_ref[...]
    local = jnp.argmin(x).astype(jnp.int32)
    min_ref[...] = jnp.min(x)[None]
    arg_ref[...] = (b * bs + local)[None]


def block_min_kernel(xs, bs):
    """Block minimums + global argmins (A' of §5.3). n % bs == 0."""
    n = xs.shape[0]
    assert n % bs == 0
    nb = n // bs
    kernel = functools.partial(_block_min_step, bs=bs)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((bs,), lambda b: (b,))],
        out_specs=[pl.BlockSpec((1,), lambda b: (b,)), pl.BlockSpec((1,), lambda b: (b,))],
        out_shape=[
            jax.ShapeDtypeStruct((nb,), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.int32),
        ],
        interpret=True,
    )(xs)


def _masked_argmin_step(lo_ref, hi_ref, vals_ref, min_ref, arg_ref):
    vals = vals_ref[...]  # f32[block_q, w]
    lo = lo_ref[...]
    hi = hi_ref[...]
    w = vals.shape[1]
    col = jax.lax.iota(jnp.int32, w)
    mask = (col[None, :] >= lo[:, None]) & (col[None, :] <= hi[:, None])
    masked = jnp.where(mask, vals, jnp.inf)
    arg_ref[...] = jnp.argmin(masked, axis=1).astype(jnp.int32)
    min_ref[...] = jnp.min(masked, axis=1)


def masked_argmin_kernel(vals, lo, hi, *, block_q: int = DEFAULT_BLOCK_Q):
    """Per-row masked argmin over [lo, hi] columns (empty: (inf, 0)).

    vals f32[q, w], lo/hi i32[q], q % block_q == 0.
    """
    q, w = vals.shape
    assert q % block_q == 0, (q, block_q)
    return pl.pallas_call(
        _masked_argmin_step,
        grid=(q // block_q,),
        in_specs=[
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec((block_q, w), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q,), jnp.float32),
            jax.ShapeDtypeStruct((q,), jnp.int32),
        ],
        interpret=True,
    )(lo, hi, vals)


def vmem_footprint_bytes(block_q: int, block_n: int) -> int:
    """Estimated VMEM bytes held live by one ``rmq_kernel`` grid step:
    query tile (l, r: 2×i32), array block (f32), accumulators (f32+i32),
    and the (block_q × block_n) mask/vals intermediate. Used by the §Perf
    pass to keep the working set under the ~16 MiB/core VMEM budget."""
    tile = block_q * 4 * 4  # l, r, min, arg
    block = block_n * 4
    intermediate = block_q * block_n * (4 + 1)  # f32 vals + bool mask
    return tile + block + intermediate

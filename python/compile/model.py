"""L2 — the JAX compute graphs AOT-compiled for the Rust coordinator.

Two graphs, both calling the L1 Pallas kernels:

- ``exhaustive_rmq``: the paper's EXHAUSTIVE GPU baseline (§6.1) — a
  single tiled masked-argmin sweep over the whole array.
- ``block_rmq``: the paper's Algorithm 6 as a dense compute graph: a
  query decomposes into left-partial-block + right-partial-block
  (``masked_argmin_kernel`` over gathered tiles) + fully-covered interior
  (``masked_argmin_kernel`` over the block-minimums array built by
  ``block_min_kernel``), combined with a leftmost-preferring min.

Shapes are static (XLA): the AOT pipeline emits one artifact per (n, q,
bs) variant, and the Rust runtime pads query batches to `q`.
"""

import jax.numpy as jnp

from .kernels import rmq_pallas as k


def exhaustive_rmq(xs, ls, rs, *, block_q=None, block_n=None):
    """Batched brute-force RMQ. Returns (mins f32[q], args i32[q])."""
    kwargs = {}
    if block_q is not None:
        kwargs["block_q"] = block_q
    if block_n is not None:
        kwargs["block_n"] = block_n
    mins, args = k.rmq_kernel(xs, ls, rs, **kwargs)
    return mins, args


def _gather_tiles(xs, block_idx, bs):
    """Gather per-query block tiles: (q,) block indices -> f32[q, bs]."""
    base = block_idx[:, None] * bs
    cols = jnp.arange(bs, dtype=jnp.int32)[None, :]
    return xs[(base + cols).astype(jnp.int32)]


def block_rmq(xs, ls, rs, bs, *, block_q=None):
    """Algorithm 6 as an L2 graph. Requires n % bs == 0.

    Returns (mins f32[q], args i32[q]) — global indices, leftmost ties.
    """
    n = xs.shape[0]
    assert n % bs == 0
    kwargs = {"block_q": block_q} if block_q is not None else {}

    # Preprocessing stage (paper: "performed once for the input"): the
    # block minimums A'. XLA CSEs this across the jit; the AOT variant
    # takes xs as an argument so the artifact recomputes it per call —
    # the Rust engine amortises by caching answers per array epoch.
    bmins, bargs = k.block_min_kernel(xs, bs)
    nb = n // bs

    bl = (ls // bs).astype(jnp.int32)
    br = (rs // bs).astype(jnp.int32)
    same = bl == br
    lloc = (ls % bs).astype(jnp.int32)
    rloc = (rs % bs).astype(jnp.int32)

    # Left partial block: local range [l%bs, bs-1], clipped to r%bs when
    # the query lives in a single block (case #1 collapses into this).
    left_tiles = _gather_tiles(xs, bl, bs)
    left_hi = jnp.where(same, rloc, jnp.int32(bs - 1))
    lmin, larg = k.masked_argmin_kernel(left_tiles, lloc, left_hi, **kwargs)
    lglob = bl * bs + larg

    # Right partial block: [0, r%bs]; empty when the query is one block.
    right_tiles = _gather_tiles(xs, br, bs)
    rlo = jnp.where(same, jnp.int32(1), jnp.int32(0))
    rhi = jnp.where(same, jnp.int32(0), rloc)  # hi < lo => empty
    rmin, rarg = k.masked_argmin_kernel(right_tiles, rlo, rhi, **kwargs)
    rglob = br * bs + rarg

    # Interior: block-minimum range [bl+1, br-1]; empty when br-bl < 2.
    q = ls.shape[0]
    interior = jnp.broadcast_to(bmins[None, :], (q, nb))
    imin, iarg_b = k.masked_argmin_kernel(interior, bl + 1, br - 1, **kwargs)
    iglob = bargs[iarg_b]

    # Leftmost-preferring combine: candidates are in index order
    # (left block < interior blocks < right block), so strict '<' when
    # replacing keeps the leftmost global minimum.
    best_min, best_arg = lmin, lglob
    take_i = imin < best_min
    best_min = jnp.where(take_i, imin, best_min)
    best_arg = jnp.where(take_i, iglob, best_arg)
    take_r = rmin < best_min
    best_min = jnp.where(take_r, rmin, best_min)
    best_arg = jnp.where(take_r, rglob, best_arg)
    return best_min, best_arg.astype(jnp.int32)


def block_minimums(xs, bs):
    """Expose the preprocessing stage as its own artifact (the Rust
    coordinator calls it once per array epoch)."""
    return k.block_min_kernel(xs, bs)

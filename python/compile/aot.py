"""AOT pipeline: lower the L2 graphs to HLO **text** artifacts + manifest.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids, which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:
    python -m compile.aot --out-dir ../artifacts [--quick]

Emits one `<name>.hlo.txt` per variant plus `manifest.json` describing
shapes, so the Rust runtime (`rust/src/runtime`) can pick a variant and
pad batches without re-deriving anything.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (name, n, q, bs, block_q, block_n). bs == 0 => exhaustive variant.
# Tile sizes are the §Perf-tuned defaults; `--quick` keeps only the
# smallest of each kind for CI.
VARIANTS = [
    # Exhaustive (the paper's GPU baseline; small n only — brute force).
    {"name": "exhaustive_n4096_q256", "kind": "exhaustive", "n": 4096, "q": 256,
     "block_q": 256, "block_n": 1024},
    {"name": "exhaustive_n16384_q256", "kind": "exhaustive", "n": 16384, "q": 256,
     "block_q": 256, "block_n": 2048},
    # Block-matrix graph (Algorithm 6).
    {"name": "block_n4096_q256_bs64", "kind": "block", "n": 4096, "q": 256, "bs": 64,
     "block_q": 256},
    {"name": "block_n65536_q256_bs256", "kind": "block", "n": 65536, "q": 256, "bs": 256,
     "block_q": 256},
    # Preprocessing-only artifact.
    {"name": "blockmin_n65536_bs256", "kind": "blockmin", "n": 65536, "bs": 256},
]

QUICK_NAMES = {"exhaustive_n4096_q256", "block_n4096_q256_bs64"}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(v):
    n, q = v["n"], v.get("q", 0)
    xs = jax.ShapeDtypeStruct((n,), jnp.float32)
    ls = jax.ShapeDtypeStruct((q,), jnp.int32)
    rs = jax.ShapeDtypeStruct((q,), jnp.int32)
    if v["kind"] == "exhaustive":
        fn = lambda a, b, c: model.exhaustive_rmq(
            a, b, c, block_q=v["block_q"], block_n=v["block_n"])
        return jax.jit(fn).lower(xs, ls, rs)
    if v["kind"] == "block":
        fn = lambda a, b, c: model.block_rmq(a, b, c, v["bs"], block_q=v["block_q"])
        return jax.jit(fn).lower(xs, ls, rs)
    if v["kind"] == "blockmin":
        fn = lambda a: model.block_minimums(a, v["bs"])
        return jax.jit(fn).lower(xs)
    raise ValueError(v["kind"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only the smallest variant of each kind")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "variants": []}
    for v in VARIANTS:
        if args.quick and v["name"] not in QUICK_NAMES:
            continue
        lowered = lower_variant(v)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, v["name"] + ".hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entry = dict(v)
        entry["file"] = v["name"] + ".hlo.txt"
        # Outputs are a tuple (return_tuple=True): (mins f32, args i32).
        manifest["variants"].append(entry)
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()

"""L2 correctness: the block-decomposition graph vs the flat oracle, and
the AOT lowering path (HLO text round-trip sanity)."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import aot, model
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


def make_queries(rng, n, q):
    ls = rng.integers(0, n, size=q).astype(np.int32)
    span = rng.integers(0, n, size=q)
    rs = np.minimum(ls + span, n - 1).astype(np.int32)
    ls = np.minimum(ls, rs)
    return ls, rs


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    nb=st.sampled_from([2, 4, 16]),
    bs=st.sampled_from([32, 64]),
    dup=st.booleans(),
)
def test_block_rmq_matches_flat_ref(seed, nb, bs, dup):
    rng = np.random.default_rng(seed)
    n = nb * bs
    q = 64
    xs = (rng.integers(0, 4, size=n) if dup else rng.random(n)).astype(np.float32)
    ls, rs = make_queries(rng, n, q)
    mins, args = model.block_rmq(jnp.array(xs), jnp.array(ls), jnp.array(rs), bs, block_q=32)
    rmins, rargs = ref.rmq_ref(jnp.array(xs), jnp.array(ls), jnp.array(rs))
    np.testing.assert_array_equal(np.asarray(args), np.asarray(rargs))
    np.testing.assert_allclose(np.asarray(mins), np.asarray(rmins), rtol=0)


def test_block_rmq_case1_single_block():
    # Query fully inside one block (Algorithm 6 case #1).
    xs = jnp.array([4, 3, 2, 1, 8, 7, 6, 5], dtype=jnp.float32)
    ls = jnp.array([0, 4, 5, 6], dtype=jnp.int32)
    rs = jnp.array([2, 7, 6, 6], dtype=jnp.int32)
    mins, args = model.block_rmq(xs, ls, rs, bs=4, block_q=4)
    np.testing.assert_array_equal(np.asarray(args), [2, 7, 6, 6])
    np.testing.assert_allclose(np.asarray(mins), [2, 5, 6, 6])


def test_block_rmq_adjacent_blocks_no_interior():
    # br - bl == 1: no fully-covered interior blocks.
    xs = jnp.arange(16, 0, -1).astype(jnp.float32)  # decreasing
    ls = jnp.array([2, 6], dtype=jnp.int32)
    rs = jnp.array([9, 9], dtype=jnp.int32)
    _, args = model.block_rmq(xs, ls, rs, bs=8, block_q=2)
    np.testing.assert_array_equal(np.asarray(args), [9, 9])


def test_exhaustive_rmq_matches_ref():
    rng = np.random.default_rng(7)
    xs = rng.random(2048, dtype=np.float32)
    ls, rs = make_queries(rng, 2048, 128)
    mins, args = model.exhaustive_rmq(
        jnp.array(xs), jnp.array(ls), jnp.array(rs), block_q=128, block_n=512)
    rmins, rargs = ref.rmq_ref(jnp.array(xs), jnp.array(ls), jnp.array(rs))
    np.testing.assert_array_equal(np.asarray(args), np.asarray(rargs))
    np.testing.assert_allclose(np.asarray(mins), np.asarray(rmins))


def test_block_minimums_artifact_fn():
    xs = jnp.array([3, 1, 2, 0], dtype=jnp.float32)
    mins, args = model.block_minimums(xs, 2)
    np.testing.assert_allclose(np.asarray(mins), [1, 0])
    np.testing.assert_array_equal(np.asarray(args), [1, 3])


# ------------------------------------------------------------- AOT path

def test_lower_variant_produces_hlo_text():
    v = {"name": "t", "kind": "exhaustive", "n": 512, "q": 64,
         "block_q": 64, "block_n": 256}
    lowered = aot.lower_variant(v)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    # A tuple root with two outputs (mins, args).
    assert "f32[64]" in text and "s32[64]" in text


def test_lowered_block_variant_executes_correctly():
    # Execute the exact lowered computation (the artifact the Rust side
    # runs) and compare against the oracle — cross-checks the AOT path
    # end to end on the Python side.
    v = {"name": "t2", "kind": "block", "n": 1024, "q": 64, "bs": 64, "block_q": 64}
    fn = jax.jit(lambda a, b, c: model.block_rmq(a, b, c, v["bs"], block_q=v["block_q"]))
    rng = np.random.default_rng(11)
    xs = rng.random(v["n"], dtype=np.float32)
    ls, rs = make_queries(rng, v["n"], v["q"])
    mins, args = fn(jnp.array(xs), jnp.array(ls), jnp.array(rs))
    _, rargs = ref.rmq_ref(jnp.array(xs), jnp.array(ls), jnp.array(rs))
    np.testing.assert_array_equal(np.asarray(args), np.asarray(rargs))
    assert np.all(np.isfinite(np.asarray(mins)))

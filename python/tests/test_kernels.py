"""L1 correctness: Pallas kernels vs the pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and data (including duplicate-heavy arrays that
exercise the leftmost tie-break); fixed seeds keep CI deterministic.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import rmq_pallas as k

SETTINGS = dict(max_examples=25, deadline=None)


def make_queries(rng, n, q):
    ls = rng.integers(0, n, size=q).astype(np.int32)
    span = rng.integers(0, n, size=q)
    rs = np.minimum(ls + span, n - 1).astype(np.int32)
    ls = np.minimum(ls, rs)
    return ls, rs


# ------------------------------------------------------------- rmq_kernel

@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_blocks=st.integers(1, 4),
    block_n=st.sampled_from([128, 256]),
    q_tiles=st.integers(1, 2),
    block_q=st.sampled_from([32, 64]),
    dup=st.booleans(),
)
def test_rmq_kernel_matches_ref(seed, n_blocks, block_n, q_tiles, block_q, dup):
    rng = np.random.default_rng(seed)
    n, q = n_blocks * block_n, q_tiles * block_q
    if dup:
        xs = rng.integers(0, 4, size=n).astype(np.float32)
    else:
        xs = rng.random(n, dtype=np.float32)
    ls, rs = make_queries(rng, n, q)
    mins, args = k.rmq_kernel(jnp.array(xs), jnp.array(ls), jnp.array(rs),
                              block_q=block_q, block_n=block_n)
    rmins, rargs = ref.rmq_ref(jnp.array(xs), jnp.array(ls), jnp.array(rs))
    np.testing.assert_array_equal(np.asarray(args), np.asarray(rargs))
    np.testing.assert_allclose(np.asarray(mins), np.asarray(rmins), rtol=0)


def test_rmq_kernel_paper_example():
    # §2: X = [9,2,7,8,4,1,3] (padded to 8), RMQ(2,6) = 5.
    xs = jnp.array([9, 2, 7, 8, 4, 1, 3, np.inf], dtype=jnp.float32)
    ls = jnp.array([2, 0, 0, 3], dtype=jnp.int32)
    rs = jnp.array([6, 6, 3, 3], dtype=jnp.int32)
    mins, args = k.rmq_kernel(xs, ls, rs, block_q=4, block_n=8)
    np.testing.assert_array_equal(np.asarray(args), [5, 5, 1, 3])
    np.testing.assert_allclose(np.asarray(mins), [1, 1, 2, 8])


def test_rmq_kernel_leftmost_across_block_boundary():
    # Equal minima in different array blocks: the left one must win.
    xs = jnp.array([5, 1, 7, 9, 1, 8, 2, 3], dtype=jnp.float32)
    ls = jnp.array([0, 2], dtype=jnp.int32)
    rs = jnp.array([7, 7], dtype=jnp.int32)
    _, args = k.rmq_kernel(xs, ls, rs, block_q=2, block_n=4)  # 2 blocks
    np.testing.assert_array_equal(np.asarray(args), [1, 4])


# -------------------------------------------------------- block_min_kernel

@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    nb=st.integers(1, 16),
    bs=st.sampled_from([8, 32, 128]),
    dup=st.booleans(),
)
def test_block_min_matches_ref(seed, nb, bs, dup):
    rng = np.random.default_rng(seed)
    n = nb * bs
    xs = (rng.integers(0, 3, size=n) if dup else rng.random(n)).astype(np.float32)
    mins, args = k.block_min_kernel(jnp.array(xs), bs)
    rmins, rargs = ref.block_min_ref(jnp.array(xs), bs)
    np.testing.assert_array_equal(np.asarray(args), np.asarray(rargs))
    np.testing.assert_allclose(np.asarray(mins), np.asarray(rmins), rtol=0)


# ----------------------------------------------------- masked_argmin_kernel

@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    q_tiles=st.integers(1, 3),
    block_q=st.sampled_from([16, 64]),
    w=st.sampled_from([8, 64, 200]),
    dup=st.booleans(),
)
def test_masked_argmin_matches_ref(seed, q_tiles, block_q, w, dup):
    rng = np.random.default_rng(seed)
    q = q_tiles * block_q
    vals = (rng.integers(0, 3, size=(q, w)) if dup else rng.random((q, w))).astype(np.float32)
    lo = rng.integers(0, w, size=q).astype(np.int32)
    hi = rng.integers(-1, w, size=q).astype(np.int32)  # allows empty ranges
    mins, args = k.masked_argmin_kernel(jnp.array(vals), jnp.array(lo), jnp.array(hi),
                                        block_q=block_q)
    rmins, rargs = ref.masked_argmin_ref(jnp.array(vals), jnp.array(lo), jnp.array(hi))
    np.testing.assert_array_equal(np.asarray(args), np.asarray(rargs))
    np.testing.assert_array_equal(np.asarray(mins), np.asarray(rmins))


def test_masked_argmin_empty_rows_are_inf():
    vals = jnp.ones((4, 8), dtype=jnp.float32)
    lo = jnp.array([5, 0, 7, 3], dtype=jnp.int32)
    hi = jnp.array([4, 7, 6, 3], dtype=jnp.int32)  # rows 0 and 2 empty
    mins, args = k.masked_argmin_kernel(vals, lo, hi, block_q=4)
    m = np.asarray(mins)
    assert np.isinf(m[0]) and np.isinf(m[2])
    assert m[1] == 1.0 and m[3] == 1.0
    assert np.asarray(args)[3] == 3


def test_vmem_footprint_budget():
    # The shipped default tiles must sit well inside a 16 MiB VMEM core.
    assert k.vmem_footprint_bytes(k.DEFAULT_BLOCK_Q, k.DEFAULT_BLOCK_N) < 8 * 2**20


@pytest.mark.parametrize("bad", [(100, 64), (256, 100)])
def test_rmq_kernel_rejects_unaligned(bad):
    q, n = 256, 2048
    xs = jnp.zeros((n,), jnp.float32)
    ls = jnp.zeros((q,), jnp.int32)
    with pytest.raises(AssertionError):
        k.rmq_kernel(xs, ls, ls, block_q=bad[0], block_n=bad[1])

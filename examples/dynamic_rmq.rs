//! Dynamic RMQ — the paper's future-work item (iii): "solve batches of
//! RMQs for input arrays that change their values over time; useful for
//! scientific applications such as simulations", using the RT cores'
//! "fast update/rebuild functions".
//!
//! Scenario: a running simulation tracks the minimum energy in sliding
//! windows of a particle field while the field evolves. Each tick
//! updates a small fraction of values; RTXRMQ re-shapes only the touched
//! triangles and *refits* the BVH (no rebuild), then serves a query
//! batch. A rebuild-every-tick strategy is measured alongside for the
//! update/rebuild balance the paper anticipates.
//!
//! Run: `cargo run --release --example dynamic_rmq [--n 2^14] [--ticks 40]`

use rtxrmq::rmq::rtx::{RtxMode, RtxOptions, RtxRmq};
use rtxrmq::rmq::sparse_table::SparseTable;
use rtxrmq::rmq::RmqSolver;
use rtxrmq::util::cli::Args;
use rtxrmq::util::rng::Rng;
use rtxrmq::workload::{gen_queries, RangeDist};

fn main() {
    let args = Args::from_env();
    let n: usize = args.get_or("n", 1usize << 14).unwrap();
    let ticks: usize = args.get_or("ticks", 40usize).unwrap();
    let updates_per_tick: usize = args.get_or("updates", 32usize).unwrap();
    let queries_per_tick: usize = args.get_or("queries", 256usize).unwrap();
    let bs = (n as f64).sqrt() as usize;

    let mut rng = Rng::new(0xD41A);
    let mut xs = Rng::new(1).uniform_f32_vec(n);
    let opts = RtxOptions { mode: RtxMode::Blocks { block_size: bs }, ..Default::default() };
    let mut refit_solver = RtxRmq::with_options(&xs, opts);

    let (mut t_refit, mut t_rebuild, mut t_query) =
        (std::time::Duration::ZERO, std::time::Duration::ZERO, std::time::Duration::ZERO);
    let mut answered = 0usize;

    for tick in 0..ticks {
        // Simulation step: a few particles change energy.
        let updates: Vec<(usize, f32)> =
            (0..updates_per_tick).map(|_| (rng.range(0, n - 1), rng.f32())).collect();

        // Strategy A (paper's future work): incremental updates, one
        // refit per tick.
        let t0 = std::time::Instant::now();
        for &(i, v) in &updates {
            xs[i] = v;
        }
        refit_solver.update_values(&updates);
        t_refit += t0.elapsed();

        // Strategy B: rebuild from scratch every tick.
        let t1 = std::time::Instant::now();
        let rebuilt = RtxRmq::with_options(&xs, opts);
        t_rebuild += t1.elapsed();

        // Query batch against the fresh state; verify both strategies
        // against the oracle.
        let qs = gen_queries(n, queries_per_tick, RangeDist::Small, &mut rng);
        let t2 = std::time::Instant::now();
        let got = refit_solver.batch(&qs, 1);
        t_query += t2.elapsed();
        let st = SparseTable::new(&xs);
        for (k, &(l, r)) in qs.iter().enumerate() {
            assert_eq!(got[k], st.rmq(l, r), "tick {tick} query ({l},{r})");
        }
        assert_eq!(got, rebuilt.batch(&qs, 1), "refit and rebuild must agree");
        answered += qs.len();
    }

    let per_tick_updates = updates_per_tick as f64;
    println!("dynamic RMQ over {ticks} ticks (n = {n}, {updates_per_tick} updates + {queries_per_tick} queries/tick):");
    println!(
        "  refit path   : {:>9.2?} total  ({:.1} µs per tick, {:.2} µs per update)",
        t_refit,
        t_refit.as_micros() as f64 / ticks as f64,
        t_refit.as_micros() as f64 / (ticks as f64 * per_tick_updates)
    );
    println!(
        "  rebuild path : {:>9.2?} total  ({:.1}x the refit cost)",
        t_rebuild,
        t_rebuild.as_secs_f64() / t_refit.as_secs_f64()
    );
    println!("  queries      : {answered} answered & verified in {t_query:.2?}");
    println!("  -> refit keeps answers exact while avoiding full rebuilds (paper §7.iii)");
}

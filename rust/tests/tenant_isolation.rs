//! Cross-tenant isolation, differentially tested: the multi-tenant
//! coordinator must behave — per tenant — exactly like a dedicated
//! single-array coordinator, no matter how the executor interleaves
//! other tenants' work.
//!
//! Three contracts:
//! - **Answer isolation**: every accepted response is bit-identical to
//!   a sequential re-solve of that tenant's own op stream (leftmost
//!   ties included), even with concurrent clients hammering the other
//!   tenants.
//! - **Fault isolation**: an injected executor-batch kill in one tenant
//!   fails that tenant's request *atomically* (none of its updates
//!   apply) and leaves every other tenant's accepted answers and fault
//!   counters untouched.
//! - **Epoch isolation**: per-tenant epoch versions are monotonic in
//!   submission order, and a forced static rebuild in one tenant does
//!   not move any other tenant's epoch.

use rtxrmq::coordinator::batcher::ServeError;
use rtxrmq::coordinator::engine::{BuildJob, EngineCfg, LifecycleCfg};
use rtxrmq::coordinator::tenants::{MultiCfg, MultiCoordinator, TenantCfg};
use rtxrmq::rmq::naive_rmq;
use rtxrmq::util::faults::{self, FaultPlan};
use rtxrmq::util::rng::Rng;
use rtxrmq::workload::{gen_array, gen_mixed, Op, RangeDist};

/// The chaos test arms the **process-global** fault registry; the clean
/// tests assert exact per-tenant counters. Same serialization idiom as
/// `mixed_stream.rs`.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

/// Sequential semantics of one tenant's op stream: apply to a plain
/// array, answer queries by rescan.
fn oracle_run(xs: &mut [f32], ops: &[Op]) -> Vec<u32> {
    let mut out = Vec::new();
    for op in ops {
        match *op {
            Op::Query((l, r)) => out.push(naive_rmq(xs, l as usize, r as usize) as u32),
            Op::Update { i, v } => xs[i as usize] = v,
            Op::RangeAdd { l, r, v } => {
                for x in &mut xs[l as usize..=r as usize] {
                    *x += v;
                }
            }
            Op::RangeAssign { l, r, v } => {
                for x in &mut xs[l as usize..=r as usize] {
                    *x = v;
                }
            }
        }
    }
    out
}

fn start_tenants(specs: &[(&str, usize)]) -> MultiCoordinator {
    let arrays = specs
        .iter()
        .enumerate()
        .map(|(i, (name, n))| {
            let mut tc = TenantCfg::named(name);
            tc.engines = EngineCfg::default();
            tc.lifecycle = LifecycleCfg::default();
            (tc, gen_array(*n, 7 + i as u64))
        })
        .collect();
    MultiCoordinator::start(arrays, None, MultiCfg::default())
}

#[test]
fn interleaved_tenants_answer_their_own_oracles() {
    let _g = serial();
    let specs: &[(&str, usize, RangeDist, f64)] = &[
        ("alpha", 512, RangeDist::Small, 0.3),
        ("beta", 1024, RangeDist::Large, 0.1),
        ("gamma", 768, RangeDist::Medium, 0.5),
    ];
    let mc = start_tenants(&specs.iter().map(|(n, sz, _, _)| (*n, *sz)).collect::<Vec<_>>());
    std::thread::scope(|s| {
        for (i, &(name, n, dist, uf)) in specs.iter().enumerate() {
            let mc = &mc;
            s.spawn(move || {
                let mut rng = Rng::new(100 + i as u64);
                let mut oracle = gen_array(n, 7 + i as u64);
                for round in 0..24 {
                    let ops = gen_mixed(n, 32, uf, dist, &mut rng);
                    let want = oracle_run(&mut oracle, &ops);
                    let resp = mc
                        .submit(name, ops, None)
                        .unwrap_or_else(|e| panic!("{name} round {round}: {e}"));
                    assert_eq!(
                        resp.answers, want,
                        "{name} round {round}: answers diverged from the single-array oracle"
                    );
                }
            });
        }
    });
    mc.shutdown();
}

#[test]
fn fault_in_one_tenant_leaves_other_answers_untouched() {
    let _g = serial();
    let n = 512;
    let mc = start_tenants(&[("victim", n), ("bystander", n)]);
    let mut victim_oracle = gen_array(n, 7);
    let mut bystander_oracle = gen_array(n, 8);

    // First two executor batches die wholesale; blocking submits make
    // the victim's two requests exactly those batches.
    faults::arm(FaultPlan::parse("tenant.exec:panic:1.0:2", 99).unwrap());
    let mut rng = Rng::new(5);
    for _ in 0..2 {
        // Updates included on purpose: a failed batch must apply none.
        let ops = gen_mixed(n, 16, 0.5, RangeDist::Small, &mut rng);
        let err = mc.submit("victim", ops, None).expect_err("armed batch must fail");
        assert!(
            matches!(err.downcast_ref::<ServeError>(), Some(ServeError::Failed)),
            "expected ServeError::Failed, got {err}"
        );
    }
    faults::disarm();

    // The failed requests applied nothing: the victim's array still
    // matches the oracle that never saw those ops.
    for _ in 0..8 {
        let ops = gen_mixed(n, 24, 0.3, RangeDist::Small, &mut rng);
        let want = oracle_run(&mut victim_oracle, &ops);
        let resp = mc.submit("victim", ops, None).expect("post-fault victim submit");
        assert_eq!(resp.answers, want, "victim state drifted after its failed batches");
    }
    // The bystander never saw a fault: answers exact, no degraded
    // events, nothing shed or expired.
    for _ in 0..8 {
        let ops = gen_mixed(n, 24, 0.3, RangeDist::Medium, &mut rng);
        let want = oracle_run(&mut bystander_oracle, &ops);
        let resp = mc.submit("bystander", ops, None).expect("bystander submit");
        assert_eq!(resp.answers, want, "bystander answers diverged");
    }
    let bm = mc.metrics("bystander").unwrap();
    let bm = bm.lock();
    assert_eq!(bm.degraded_fallbacks, 0, "fault leaked into the bystander's counters");
    assert_eq!(bm.shed + bm.deadline_expired, 0);
    drop(bm);
    let vm = mc.metrics("victim").unwrap();
    assert!(vm.lock().degraded_fallbacks >= 2, "victim must record its killed batches");
    mc.shutdown();
}

#[test]
fn epochs_are_monotonic_and_rebuilds_are_isolated_per_tenant() {
    let _g = serial();
    let n = 512;
    let mc = start_tenants(&[("a", n), ("b", n)]);
    let mut rng = Rng::new(13);
    let mut oracle_a = gen_array(n, 7);
    let mut oracle_b = gen_array(n, 8);

    // Epochs observed by a's responses never go backwards.
    let mut last_epoch = 0u64;
    for _ in 0..12 {
        let ops = gen_mixed(n, 24, 0.4, RangeDist::Small, &mut rng);
        let want = oracle_run(&mut oracle_a, &ops);
        let resp = mc.submit("a", ops, None).expect("a submit");
        assert_eq!(resp.answers, want);
        assert!(resp.epoch >= last_epoch, "epoch went backwards: {} < {last_epoch}", resp.epoch);
        last_epoch = resp.epoch;
    }

    // Force a static rebuild in `a` only (the shared builder pool's
    // job, run synchronously here for determinism).
    let a_before = mc.lifecycle("a").unwrap().epoch_version();
    let b_before = mc.lifecycle("b").unwrap().epoch_version();
    let am = mc.metrics("a").unwrap();
    mc.lifecycle("a").unwrap().run_job(BuildJob::Statics, &am);
    assert!(mc.lifecycle("a").unwrap().epoch_version() > a_before, "rebuild must bump a's epoch");
    assert_eq!(
        mc.lifecycle("b").unwrap().epoch_version(),
        b_before,
        "a's rebuild moved b's epoch"
    );

    // Both tenants still answer exactly after the publish.
    for _ in 0..4 {
        let ops = gen_mixed(n, 24, 0.2, RangeDist::Medium, &mut rng);
        let want = oracle_run(&mut oracle_a, &ops);
        assert_eq!(mc.submit("a", ops, None).expect("a submit").answers, want);
        let ops = gen_mixed(n, 24, 0.2, RangeDist::Medium, &mut rng);
        let want = oracle_run(&mut oracle_b, &ops);
        assert_eq!(mc.submit("b", ops, None).expect("b submit").answers, want);
    }
    mc.shutdown();
}

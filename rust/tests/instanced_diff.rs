//! Differential suite for the instanced block geometry (ISSUE 7's
//! acceptance gate): the instanced sharded engine must answer
//! **hit-for-hit identically** to the non-instanced (per-block BVH)
//! sharded engine and the naive oracle — across every `RangeDist`
//! regime, on adversarial arrays, under point updates through the
//! instance refit path, and at quantization-bucket boundaries where the
//! compressed `u16` leaf records cannot distinguish values on their own.

use rtxrmq::rmq::naive_rmq;
use rtxrmq::rmq::sharded::{ShardBackend, ShardedOptions, ShardedRmq};
use rtxrmq::rmq::RmqSolver;
use rtxrmq::util::proptest::{check, gen};
use rtxrmq::util::rng::Rng;
use rtxrmq::workload::{gen_queries, gen_updates, RangeDist};

fn instanced(bs: usize) -> ShardedOptions {
    ShardedOptions { block_size: bs, backend: ShardBackend::Instanced, ..Default::default() }
}

fn rtx_oracle(bs: usize) -> ShardedOptions {
    ShardedOptions { block_size: bs, backend: ShardBackend::Rtx, ..Default::default() }
}

/// Instanced vs the non-instanced sharded engine, batch-for-batch, over
/// all three range regimes.
#[test]
fn instanced_matches_rtx_backend_across_regimes() {
    check("instanced vs rtx sharded, all regimes", 12, |rng| {
        let xs = gen::f32_array(rng, 2..=1500);
        let n = xs.len();
        let bs = 1usize << rng.range(0, 8);
        let inst = ShardedRmq::with_options(&xs, instanced(bs));
        let oracle = ShardedRmq::with_options(&xs, rtx_oracle(bs));
        for dist in RangeDist::all() {
            let queries = gen_queries(n, 64, dist, rng);
            let (a, b) = (inst.batch(&queries, 2), oracle.batch(&queries, 2));
            if a != b {
                let bad = a.iter().zip(&b).position(|(x, y)| x != y).unwrap();
                return Err(format!(
                    "{dist:?} n={n} bs={bs}: query {:?} instanced {} rtx {}",
                    queries[bad], a[bad], b[bad]
                ));
            }
        }
        Ok(())
    });
}

/// Adversarial shapes: all-equal (scale collapses to 0), heavy
/// duplicates (every quantization bucket shared), and n not a multiple
/// of B (tail block gets its own shared shape). Exhaustive sweeps on
/// the small ones.
#[test]
fn instanced_handles_adversarial_arrays() {
    let shapes: Vec<(&str, Vec<f32>)> = vec![
        ("n1", vec![0.5]),
        ("n2-tie", vec![0.4, 0.4]),
        ("all-equal", vec![1.0; 200]),
        ("heavy-dup", (0..300).map(|i| (i % 3) as f32).collect()),
        ("sawtooth", (0..256).map(|i| (i % 16) as f32).collect()),
        // 131 % {1,2,16,64} != 0 for the non-1 sizes: tail shape paths.
        ("prime-len", (0..131).map(|i| ((i * 7919) % 131) as f32).collect()),
    ];
    let mut rng = Rng::new(0x1257);
    for (label, xs) in &shapes {
        let n = xs.len();
        for bs in [1usize, 2, 16, 64] {
            let inst = ShardedRmq::with_options(xs, instanced(bs));
            let queries: Vec<(u32, u32)> = if n <= 24 {
                (0..n as u32).flat_map(|l| (l..n as u32).map(move |r| (l, r))).collect()
            } else {
                let mut qs: Vec<(u32, u32)> = (0..128)
                    .map(|_| {
                        let l = rng.range(0, n - 1);
                        (l as u32, rng.range(l, n - 1) as u32)
                    })
                    .collect();
                qs.push((0, n as u32 - 1));
                qs.push((0, 0));
                qs.push((n as u32 - 1, n as u32 - 1));
                qs
            };
            for &(l, r) in &queries {
                let want = naive_rmq(xs, l as usize, r as usize) as u32;
                let got = inst.rmq(l, r);
                assert_eq!(got, want, "{label} bs={bs} ({l},{r})");
            }
            inst.validate().unwrap_or_else(|e| panic!("{label} bs={bs}: {e}"));
        }
    }
}

/// Point updates through the instance refit path (leaf-table write +
/// lane-min walk, no tree rebuild) vs a fresh from-scratch build and
/// the in-place non-instanced engine, after every batch.
#[test]
fn instanced_updates_match_refit_and_rebuild() {
    check("instanced updates vs rtx + rebuild", 10, |rng| {
        let mut xs = gen::f32_array(rng, 16..=700);
        let n = xs.len();
        let bs = 1usize << rng.range(1, 6);
        let mut inst = ShardedRmq::with_options(&xs, instanced(bs));
        let mut oracle = ShardedRmq::with_options(&xs, rtx_oracle(bs));
        for round in 0..4 {
            // Alternate single-point batches (instance refit_point path)
            // and multi-point batches (rebuild_values path).
            let count = if round % 2 == 0 { 1 } else { rng.range(2, 12) };
            let updates = gen_updates(n, count, rng);
            for &(i, v) in &updates {
                xs[i] = v;
            }
            inst.update_batch(&updates);
            oracle.update_batch(&updates);
            let rebuilt = ShardedRmq::with_options(&xs, instanced(bs));
            for dist in RangeDist::all() {
                let queries = gen_queries(n, 32, dist, rng);
                let a = inst.batch(&queries, 2);
                if a != oracle.batch(&queries, 2) {
                    return Err(format!("bs={bs} round={round} {dist:?}: vs rtx mismatch"));
                }
                if a != rebuilt.batch(&queries, 2) {
                    return Err(format!("bs={bs} round={round} {dist:?}: vs rebuild mismatch"));
                }
            }
        }
        inst.validate()
    });
}

/// Values separated by less than one quantization bucket: the
/// compressed records collide, so only the exact resolve-on-hit keeps
/// leftmost semantics. Constructed so block minima also collide across
/// blocks (summary-level buckets shared too).
#[test]
fn compressed_leaf_ties_are_exact_at_bucket_boundaries() {
    let n = 256usize;
    let bs = 16usize;
    // Spread [0, 655.35] over the block so scale is exactly 0.01, then
    // plant sub-bucket-width differences (0.001) around the minimum.
    let mut xs = vec![655.35f32; n];
    for b in 0..n / bs {
        let start = b * bs;
        // The true block min (+9) sits RIGHT of two near-ties that share
        // its quantization bucket — the bucket screen alone would pick
        // the earlier position, so exactness here pins resolve-on-hit.
        xs[start + 2] = 0.002;
        xs[start + 5] = 0.001;
        xs[start + 9] = 0.0;
    }
    let inst = ShardedRmq::with_options(&xs, instanced(bs));
    let oracle = ShardedRmq::with_options(&xs, rtx_oracle(bs));
    inst.validate().unwrap();
    for l in 0..n as u32 {
        for r in l..n as u32 {
            let want = naive_rmq(&xs, l as usize, r as usize) as u32;
            assert_eq!(inst.rmq(l, r), want, "instanced ({l},{r})");
            assert_eq!(oracle.rmq(l, r), want, "rtx ({l},{r})");
        }
    }
    // Exact equal values across blocks: leftmost block must win at the
    // summary level despite every block-min record sharing a bucket.
    let flat = vec![3.25f32; n];
    let inst = ShardedRmq::with_options(&flat, instanced(bs));
    for l in (0..n as u32).step_by(5) {
        for r in (l..n as u32).step_by(7) {
            assert_eq!(inst.rmq(l, r), l, "all-equal leftmost ({l},{r})");
        }
    }
}

/// The staged (pipelined) write path builds instanced replacement
/// blocks against the shared shape cache with no lock held; committing
/// must be bit-identical to the direct path.
#[test]
fn instanced_staged_commit_matches_direct() {
    let mut rng = Rng::new(0xABC7);
    let xs: Vec<f32> = (0..500).map(|_| rng.f32()).collect();
    let mut staged = ShardedRmq::with_options(&xs, instanced(32));
    let mut direct = ShardedRmq::with_options(&xs, instanced(32));
    for _ in 0..6 {
        let updates: Vec<(usize, f32)> =
            (0..rng.range(1, 16)).map(|_| (rng.range(0, 499), rng.f32())).collect();
        let prep = staged.prepare_update_batch(&updates, 3);
        staged.commit_prepared(prep).unwrap_or_else(|_| panic!("commit refused"));
        direct.update_batch(&updates);
        assert_eq!(staged.values(), direct.values());
        for _ in 0..40 {
            let l = rng.range(0, 499) as u32;
            let r = rng.range(l as usize, 499) as u32;
            assert_eq!(staged.rmq(l, r), direct.rmq(l, r), "({l},{r})");
        }
    }
    staged.validate().unwrap();
}

//! Differential suite for packetized traversal (ISSUE 9's acceptance
//! gate): at every packet width the packet path must answer
//! **hit-for-hit identically** to scalar traversal — across all three
//! `RangeDist` regimes, on duplicate-heavy arrays where the leftmost-tie
//! convention is load-bearing, through blocks-mode carried hits, after
//! point-update refits, and at instanced quantization-bucket boundaries.
//! The divergence fallback is exercised explicitly from the batch
//! driver, both as a correctness case and via its counter signature
//! (`node_fetches == nodes_visited`).

use rtxrmq::bvh::AccelLayout;
use rtxrmq::rmq::naive_rmq;
use rtxrmq::rmq::rtx::{RtxMode, RtxOptions, RtxRmq};
use rtxrmq::rmq::sharded::{ShardBackend, ShardedOptions, ShardedRmq};
use rtxrmq::util::proptest::{check, gen};
use rtxrmq::util::rng::Rng;
use rtxrmq::workload::{gen_array, gen_queries, gen_updates, RangeDist};

/// The acceptance sweep: degenerate single-ray packets, the tuner's
/// defaults, the widest sensible packet, and a non-power-of-two width
/// (remainder packets on every chunk).
const WIDTHS: [usize; 5] = [1, 4, 8, 16, 7];

fn wide(packet_width: usize) -> RtxOptions {
    RtxOptions { layout: AccelLayout::Wide, packet_width, ..Default::default() }
}

fn instanced(block_size: usize, packet_width: usize) -> ShardedOptions {
    ShardedOptions {
        block_size,
        backend: ShardBackend::Instanced,
        packet_width,
        ..Default::default()
    }
}

/// Compare one packet solver against the scalar answers, reporting the
/// first mismatching query.
fn expect_identical(
    tag: &str,
    queries: &[(u32, u32)],
    scalar: &[u32],
    packet: &[u32],
) -> Result<(), String> {
    if scalar != packet {
        let bad = scalar.iter().zip(packet).position(|(a, b)| a != b).unwrap();
        return Err(format!(
            "{tag}: query {:?} scalar {} packet {}",
            queries[bad], scalar[bad], packet[bad]
        ));
    }
    Ok(())
}

/// Flat wide BVH: every width, every range regime, random arrays.
#[test]
fn packet_matches_scalar_across_widths_and_regimes() {
    check("flat wide packet vs scalar, all regimes", 10, |rng| {
        let xs = gen::f32_array(rng, 2..=2000);
        let n = xs.len();
        let scalar = RtxRmq::with_options(&xs, wide(0));
        let packets: Vec<(usize, RtxRmq)> =
            WIDTHS.iter().map(|&w| (w, RtxRmq::with_options(&xs, wide(w)))).collect();
        for dist in RangeDist::all() {
            let queries = gen_queries(n, 96, dist, rng);
            let base = scalar.batch_counted(&queries, 2).0;
            for (w, solver) in &packets {
                let got = solver.batch_counted(&queries, 2).0;
                expect_identical(&format!("{dist:?} n={n} p={w}"), &queries, &base, &got)?;
            }
        }
        Ok(())
    });
}

/// Duplicate-heavy arrays force ties in nearly every range; the packet
/// path must keep the leftmost-minimum convention bit-for-bit (checked
/// against the naive oracle, not just the scalar solver).
#[test]
fn packet_preserves_leftmost_ties() {
    check("leftmost ties under packets", 10, |rng| {
        let distinct = rng.range(1, 3);
        let xs = gen::dup_array(rng, 2..=800, distinct);
        let n = xs.len();
        let queries = gen_queries(n, 128, RangeDist::Small, rng);
        let oracle: Vec<u32> = queries
            .iter()
            .map(|&(l, r)| naive_rmq(&xs, l as usize, r as usize) as u32)
            .collect();
        for &w in &WIDTHS {
            let got = RtxRmq::with_options(&xs, wide(w)).batch_counted(&queries, 2).0;
            expect_identical(&format!("dup={distinct} n={n} p={w}"), &queries, &oracle, &got)?;
        }
        Ok(())
    });
}

/// Blocks mode answers a query in up to three phases that *carry* the
/// best hit between geometries; a carried hit must win ties at its own
/// t inside the packet path exactly as it does in the scalar path.
#[test]
fn blocks_mode_carried_hits_match_across_widths() {
    check("blocks-mode carried hits under packets", 8, |rng| {
        let xs = gen::dup_array(rng, 64..=1200, rng.range(2, 5));
        let n = xs.len();
        let bs = 1usize << rng.range(3, 6);
        let blocks = |p: usize| RtxOptions {
            mode: RtxMode::Blocks { block_size: bs },
            packet_width: p,
            ..wide(0)
        };
        let scalar = RtxRmq::with_options(&xs, blocks(0));
        for dist in RangeDist::all() {
            let queries = gen_queries(n, 64, dist, rng);
            let base = scalar.batch_counted(&queries, 2).0;
            for &(l, r) in queries.iter().take(4) {
                assert_eq!(
                    base[queries.iter().position(|q| *q == (l, r)).unwrap()],
                    naive_rmq(&xs, l as usize, r as usize) as u32,
                    "scalar blocks-mode disagrees with the oracle"
                );
            }
            for &w in &WIDTHS {
                let got = RtxRmq::with_options(&xs, blocks(w)).batch_counted(&queries, 2).0;
                expect_identical(&format!("{dist:?} bs={bs} p={w}"), &queries, &base, &got)?;
            }
        }
        Ok(())
    });
}

/// Point updates refit the wide BVH in place; the packet path reads the
/// same refitted lanes, so answers must stay identical after every
/// update batch (checked against a rolling naive oracle).
#[test]
fn packet_matches_scalar_after_point_update_refits() {
    let n = 1500;
    let mut xs = gen_array(n, 21);
    let mut rng = Rng::new(22);
    let mut scalar = RtxRmq::with_options(&xs, wide(0));
    let mut packets: Vec<(usize, RtxRmq)> =
        WIDTHS.iter().map(|&w| (w, RtxRmq::with_options(&xs, wide(w)))).collect();
    for round in 0..4 {
        let ups = gen_updates(n, 40, &mut rng);
        scalar.update_values(&ups);
        for (_, s) in &mut packets {
            s.update_values(&ups);
        }
        for (i, v) in &ups {
            xs[*i] = *v;
        }
        let queries = gen_queries(n, 96, RangeDist::Medium, &mut rng);
        let base = scalar.batch_counted(&queries, 2).0;
        for (k, &(l, r)) in queries.iter().enumerate().take(8) {
            assert_eq!(
                base[k],
                naive_rmq(&xs, l as usize, r as usize) as u32,
                "round {round}: scalar disagrees with the rolling oracle at {:?}",
                (l, r)
            );
        }
        for (w, s) in &packets {
            let got = s.batch_counted(&queries, 2).0;
            expect_identical(&format!("round={round} p={w}"), &queries, &base, &got).unwrap();
        }
    }
}

/// Instanced sharded engine: quantized `u16` lane minima screen the
/// packet, exact values resolve each range. Duplicate-heavy arrays put
/// many blocks in shared quantization buckets, where the screen alone
/// cannot order candidates — the exact strict-`<` resolve must.
#[test]
fn instanced_packets_match_at_quantization_boundaries() {
    check("instanced sharded packets on shared buckets", 10, |rng| {
        let xs = gen::dup_array(rng, 2..=1500, rng.range(1, 4));
        let n = xs.len();
        let bs = 1usize << rng.range(0, 8);
        let scalar = ShardedRmq::with_options(&xs, instanced(bs, 0));
        for dist in RangeDist::all() {
            let queries = gen_queries(n, 64, dist, rng);
            let base = scalar.batch_counted(&queries, 2).0;
            for &(l, r) in queries.iter().take(4) {
                assert_eq!(
                    base[queries.iter().position(|q| *q == (l, r)).unwrap()],
                    naive_rmq(&xs, l as usize, r as usize) as u32,
                    "scalar instanced disagrees with the oracle"
                );
            }
            for &w in &WIDTHS {
                let got =
                    ShardedRmq::with_options(&xs, instanced(bs, w)).batch_counted(&queries, 2).0;
                expect_identical(&format!("{dist:?} n={n} bs={bs} p={w}"), &queries, &base, &got)?;
            }
        }
        Ok(())
    });
}

/// The divergence fallback, exercised explicitly from the batch driver:
/// a packet of origins spread across the whole array exceeds
/// [`rtxrmq::bvh::wide::PACKET_DIVERGENCE_FRAC`] of the root envelope
/// and drops to per-ray traversal — identical answers, and the
/// fallback's counter signature (`node_fetches == nodes_visited`). A
/// coherent batch on the same solver keeps the shared descent
/// (`node_fetches < nodes_visited`).
#[test]
fn divergence_fallback_is_exercised_and_identical() {
    let n = 4096;
    let xs = gen_array(n, 31);
    let scalar = RtxRmq::with_options(&xs, wide(0));
    let packet = RtxRmq::with_options(&xs, wide(8));

    // Eight queries spanning the array: one packet, guaranteed past the
    // divergence threshold, so the whole batch runs per-ray.
    let divergent: Vec<(u32, u32)> =
        (0..8u32).map(|i| (i * 500, i * 500 + 20)).collect();
    let (base, _) = scalar.batch_counted(&divergent, 1);
    let (got, c) = packet.batch_counted(&divergent, 1);
    assert_eq!(base, got, "fallback answers must stay bit-identical");
    assert_eq!(
        c.node_fetches, c.nodes_visited,
        "a fully divergent packet carries the scalar counter signature"
    );

    // Thirty-two near-identical ranges: four packets of eight, all
    // within the envelope threshold — descents are shared, so fetches
    // amortize below the per-ray visit charge.
    let coherent: Vec<(u32, u32)> = (0..32u32).map(|i| (i * 4, i * 4 + 64)).collect();
    let (base, _) = scalar.batch_counted(&coherent, 1);
    let (got, c) = packet.batch_counted(&coherent, 1);
    assert_eq!(base, got, "shared-descent answers must stay bit-identical");
    assert!(
        c.node_fetches < c.nodes_visited,
        "coherent packets share descents: fetches {} !< visits {}",
        c.node_fetches,
        c.nodes_visited
    );
}

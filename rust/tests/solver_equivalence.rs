//! Differential property suite (ISSUE 2's acceptance gate): **every**
//! `RmqSolver` in the repo answers hit-for-hit identically — leftmost
//! tie-break included — across all three `RangeDist` regimes, on
//! adversarial arrays (sorted, reverse-sorted, all-equal, heavy
//! duplicates, n = 1, n = 2), and on block-boundary-straddling queries.
//! The sharded engine is additionally checked after randomized update
//! sequences against a freshly built sparse table, and its refitted
//! block BVHs against a from-scratch rebuild.

use rtxrmq::bvh::AccelLayout;
use rtxrmq::rmq::exhaustive::Exhaustive;
use rtxrmq::rmq::hrmq::Hrmq;
use rtxrmq::rmq::lca::LcaRmq;
use rtxrmq::rmq::naive_rmq;
use rtxrmq::rmq::rtx::{RtxMode, RtxOptions, RtxRmq};
use rtxrmq::rmq::sharded::{ShardBackend, ShardedOptions, ShardedRmq};
use rtxrmq::rmq::sparse_table::SparseTable;
use rtxrmq::rmq::{Query, RmqSolver};
use rtxrmq::util::proptest::{check, gen};
use rtxrmq::util::rng::Rng;
use rtxrmq::workload::{gen_queries, gen_updates, RangeDist};

/// Every solver in the repo, built over `xs`. `shard_bs` sizes the
/// sharded/blocked variants (clamped internally where configs require).
fn all_solvers(xs: &[f32], shard_bs: usize) -> Vec<(String, Box<dyn RmqSolver>)> {
    let n = xs.len();
    let mut out: Vec<(String, Box<dyn RmqSolver>)> = vec![
        ("SPARSE".into(), Box::new(SparseTable::new(xs))),
        ("EXHAUSTIVE".into(), Box::new(Exhaustive::new(xs))),
        ("HRMQ".into(), Box::new(Hrmq::new(xs))),
        ("LCA".into(), Box::new(LcaRmq::new(xs))),
        (
            "RTX/flat/binary".into(),
            Box::new(RtxRmq::with_options(
                xs,
                RtxOptions { layout: AccelLayout::Binary, ..Default::default() },
            )),
        ),
        ("RTX/flat/wide".into(), Box::new(RtxRmq::with_options(xs, RtxOptions::default()))),
    ];
    if n >= 2 {
        // The paper's block-matrix geometry (distinct from the sharded
        // engine: one scene, block-min triangles inside it).
        let bs = shard_bs.clamp(1, n);
        out.push((
            format!("RTX/blocks{bs}/wide"),
            Box::new(RtxRmq::with_options(
                xs,
                RtxOptions { mode: RtxMode::Blocks { block_size: bs }, ..Default::default() },
            )),
        ));
    }
    for (layout, backend) in [
        (AccelLayout::Wide, ShardBackend::Instanced),
        (AccelLayout::Wide, ShardBackend::Rtx),
        (AccelLayout::Binary, ShardBackend::Rtx),
        (AccelLayout::Wide, ShardBackend::Sparse),
    ] {
        out.push((
            format!("SHARDED/{}/{}", backend.name(), layout.name()),
            Box::new(ShardedRmq::with_options(
                xs,
                ShardedOptions { block_size: shard_bs, layout, backend, ..Default::default() },
            )),
        ));
    }
    out
}

/// Assert every solver matches the naive scan on the given queries.
fn assert_all_agree(xs: &[f32], queries: &[Query], shard_bs: usize, ctx: &str) {
    let want: Vec<u32> =
        queries.iter().map(|&(l, r)| naive_rmq(xs, l as usize, r as usize) as u32).collect();
    for (name, solver) in all_solvers(xs, shard_bs) {
        let got = solver.batch(queries, 2);
        assert_eq!(got, want, "{name} disagrees ({ctx}, n={}, bs={shard_bs})", xs.len());
    }
}

#[test]
fn all_solvers_agree_across_range_regimes() {
    check("solver equivalence across regimes", 12, |rng| {
        let xs = gen::f32_array(rng, 1..=1200);
        let n = xs.len();
        let shard_bs = 1usize << rng.range(0, 7);
        for dist in RangeDist::all() {
            let queries = gen_queries(n, 48, dist, rng);
            let want: Vec<u32> = queries
                .iter()
                .map(|&(l, r)| naive_rmq(&xs, l as usize, r as usize) as u32)
                .collect();
            for (name, solver) in all_solvers(&xs, shard_bs) {
                let got = solver.batch(&queries, 2);
                if got != want {
                    let bad = got.iter().zip(&want).position(|(g, w)| g != w).unwrap();
                    return Err(format!(
                        "{name} {dist:?} n={n} bs={shard_bs}: query {:?} got {} want {}",
                        queries[bad], got[bad], want[bad]
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn all_solvers_agree_on_adversarial_arrays() {
    // Deterministic shapes; exhaustive (l, r) sweep on the small ones.
    let shapes: Vec<(&str, Vec<f32>)> = vec![
        ("n1", vec![0.5]),
        ("n2", vec![0.7, 0.3]),
        ("n2-tie", vec![0.4, 0.4]),
        ("sorted", (0..257).map(|i| i as f32).collect()),
        ("reverse", (0..257).rev().map(|i| i as f32).collect()),
        ("all-equal", vec![1.0; 200]),
        ("heavy-dup", (0..300).map(|i| (i % 3) as f32).collect()),
        ("sawtooth", (0..256).map(|i| (i % 16) as f32).collect()),
    ];
    let mut rng = Rng::new(0x5EED);
    for (label, xs) in &shapes {
        let n = xs.len();
        for shard_bs in [1usize, 2, 16, 64] {
            let queries: Vec<Query> = if n <= 24 {
                (0..n as u32).flat_map(|l| (l..n as u32).map(move |r| (l, r))).collect()
            } else {
                let mut qs: Vec<Query> = (0..96)
                    .map(|_| {
                        let l = rng.range(0, n - 1);
                        (l as u32, rng.range(l, n - 1) as u32)
                    })
                    .collect();
                // Always include the extremes.
                qs.push((0, n as u32 - 1));
                qs.push((0, 0));
                qs.push((n as u32 - 1, n as u32 - 1));
                qs
            };
            assert_all_agree(xs, &queries, shard_bs, label);
        }
    }
}

#[test]
fn block_boundary_straddling_queries_agree() {
    // Queries placed exactly on / across the sharded block seams, where
    // the ≤3-probe decomposition switches shape: inside one block, two
    // adjacent blocks (no summary), and 3+ blocks (summary probe).
    let mut rng = Rng::new(0xB10C);
    let xs: Vec<f32> = (0..256).map(|_| (rng.below(4)) as f32).collect();
    let n = xs.len() as u32;
    for bs in [7usize, 16, 32] {
        let b = bs as u32;
        let mut queries: Vec<Query> = Vec::new();
        for k in 1..(n / b) {
            let seam = k * b;
            queries.push((seam - 1, seam)); // straddles exactly one seam
            queries.push((seam, seam)); // first slot of a block
            queries.push((seam - 1, seam - 1)); // last slot of a block
            queries.push((seam.saturating_sub(b), seam)); // one full block + 1
            if seam + b < n {
                queries.push((seam - 1, seam + b)); // covers a full block
            }
        }
        queries.push((0, n - 1));
        assert_all_agree(&xs, &queries, bs, "seams");
    }
}

#[test]
fn sharded_updates_match_fresh_sparse_table() {
    // The mutable-array gate: after each randomized update batch, the
    // refitted sharded engine must match a sparse table built from
    // scratch on the current values — across all three regimes.
    check("sharded updates vs fresh oracle", 10, |rng| {
        let mut xs = gen::f32_array(rng, 16..=600);
        let n = xs.len();
        let bs = 1usize << rng.range(1, 6);
        for backend in [ShardBackend::Instanced, ShardBackend::Rtx, ShardBackend::Sparse] {
            let mut sharded = ShardedRmq::with_options(
                &xs,
                ShardedOptions { block_size: bs, backend, ..Default::default() },
            );
            for round in 0..4 {
                let updates = gen_updates(n, rng.range(1, 12), rng);
                for &(i, v) in &updates {
                    xs[i] = v;
                }
                sharded.update_batch(&updates);
                let oracle = SparseTable::new(&xs);
                for dist in RangeDist::all() {
                    let queries = gen_queries(n, 32, dist, rng);
                    let got = sharded.batch(&queries, 2);
                    let want = oracle.batch(&queries, 1);
                    if got != want {
                        return Err(format!(
                            "{backend:?} bs={bs} round={round} {dist:?}: mismatch"
                        ));
                    }
                }
            }
            sharded.validate()?;
        }
        Ok(())
    });
}

#[test]
fn refitted_shards_match_from_scratch_rebuild() {
    // Refit vs rebuild: after an update sequence, the incrementally
    // refitted engine and a from-scratch build over the final values
    // must agree on an exhaustive query sweep, and the refitted BVHs
    // must still satisfy the structural invariants.
    check("refit == rebuild", 10, |rng| {
        let mut xs = gen::dup_array(rng, 8..=160, 3);
        let n = xs.len();
        let bs = 1usize << rng.range(1, 5);
        let opts = ShardedOptions { block_size: bs, ..Default::default() };
        let mut refitted = ShardedRmq::with_options(&xs, opts);
        for _ in 0..3 {
            let updates = gen_updates(n, rng.range(1, 8), rng);
            for &(i, v) in &updates {
                xs[i] = v;
            }
            refitted.update_batch(&updates);
        }
        refitted.validate()?;
        let rebuilt = ShardedRmq::with_options(&xs, opts);
        for l in 0..n as u32 {
            for r in l..n as u32 {
                let (a, b) = (refitted.rmq(l, r), rebuilt.rmq(l, r));
                if a != b {
                    return Err(format!("bs={bs} ({l},{r}): refit {a} != rebuild {b}"));
                }
                if a as usize != naive_rmq(&xs, l as usize, r as usize) {
                    return Err(format!("bs={bs} ({l},{r}): both wrong vs naive"));
                }
            }
        }
        Ok(())
    });
}

//! Full-stack integration: coordinator + router + batcher + all engines
//! (including the PJRT/XLA engine over real artifacts) against the
//! sparse-table oracle, under mixed concurrent load.

use rtxrmq::coordinator::batcher::BatcherCfg;
use rtxrmq::coordinator::router::Policy;
use rtxrmq::coordinator::server::{Coordinator, CoordinatorCfg};
use rtxrmq::rmq::sparse_table::SparseTable;
use rtxrmq::rmq::RmqSolver;
use rtxrmq::runtime::Runtime;
use rtxrmq::util::rng::Rng;
use rtxrmq::workload::{gen_array, gen_queries, RangeDist};
use std::path::PathBuf;
use std::sync::Arc;

/// The PJRT runtime over the AOT artifacts, or None when the backend /
/// artifacts are unavailable (tests needing it then skip; the native
/// engines are exercised by `batching_under_concurrency_is_lossless`
/// either way).
fn artifacts() -> Option<Arc<Runtime>> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Runtime::load(&dir) {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            if std::env::var_os("RTXRMQ_REQUIRE_PJRT").is_some() {
                panic!("RTXRMQ_REQUIRE_PJRT set but runtime failed to load: {e}");
            }
            eprintln!("skipping XLA-engine test: {e}");
            None
        }
    }
}

#[test]
fn coordinator_with_xla_engine_serves_all_distributions() {
    let Some(rt) = artifacts() else { return };
    let n = 3500; // deliberately not a power of two, below artifact n
    let xs = gen_array(n, 11);
    let st = SparseTable::new(&xs);
    let c = Coordinator::start(
        &xs,
        Some(rt),
        CoordinatorCfg { policy: Policy::ModeledCost, ..Default::default() },
    );
    let mut rng = Rng::new(12);
    for dist in RangeDist::all() {
        let qs = gen_queries(n, 100, dist, &mut rng);
        let resp = c.query(qs.clone()).unwrap();
        for (i, &(l, r)) in qs.iter().enumerate() {
            assert_eq!(resp.answers[i], st.rmq(l, r), "{dist:?} ({l},{r}) via {}", resp.engine);
        }
    }
    c.shutdown();
}

#[test]
fn fixed_xla_policy_exercises_pjrt_path() {
    let Some(rt) = artifacts() else { return };
    let n = 4096;
    let xs = gen_array(n, 13);
    let st = SparseTable::new(&xs);
    let c = Coordinator::start(
        &xs,
        Some(rt),
        CoordinatorCfg {
            policy: Policy::Fixed(rtxrmq::coordinator::engine::EngineKind::Xla),
            ..Default::default()
        },
    );
    let mut rng = Rng::new(14);
    let qs = gen_queries(n, 300, RangeDist::Medium, &mut rng); // > one artifact chunk
    let resp = c.query(qs.clone()).unwrap();
    assert_eq!(resp.engine, "XLA");
    for (i, &(l, r)) in qs.iter().enumerate() {
        assert_eq!(resp.answers[i], st.rmq(l, r), "({l},{r})");
    }
    c.shutdown();
}

#[test]
fn batching_under_concurrency_is_lossless() {
    let n = 1 << 12;
    let xs = gen_array(n, 15);
    let st = SparseTable::new(&xs);
    let c = Arc::new(Coordinator::start(
        &xs,
        None,
        CoordinatorCfg {
            policy: Policy::ModeledCost,
            batcher: BatcherCfg {
                max_batch_queries: 512,
                max_wait: std::time::Duration::from_millis(3),
                queue_cap: 64,
                ..Default::default()
            },
            engine_workers: 2,
            ..Default::default()
        },
    ));
    let st = Arc::new(st);
    let mut handles = Vec::new();
    for t in 0..6u64 {
        let c = c.clone();
        let st = st.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(50 + t);
            for _ in 0..20 {
                let count = rng.range(1, 40);
                let qs = gen_queries(n, count, RangeDist::Small, &mut rng);
                let resp = c.query(qs.clone()).unwrap();
                assert_eq!(resp.answers.len(), qs.len());
                for (i, &(l, r)) in qs.iter().enumerate() {
                    assert_eq!(resp.answers[i], st.rmq(l, r));
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = c.metrics.lock();
    assert_eq!(m.requests, 120);
    // Batching must have fused at least some requests.
    let batches: u64 = rtxrmq::coordinator::engine::EngineKind::all()
        .iter()
        .filter_map(|&k| m.engine(k))
        .map(|e| e.batches)
        .sum();
    assert!(batches <= 120, "fused batches ({batches}) must not exceed requests");
}

#[test]
fn dynamic_rmq_stays_consistent_under_serving() {
    // Future-work (iii) at the solver level: updates interleaved with
    // queries on the RTX engine directly (the coordinator-level mixed
    // op-stream path is covered by `tests/mixed_stream.rs`).
    let mut xs = gen_array(2048, 16);
    let mut rtx = rtxrmq::rmq::rtx::RtxRmq::with_options(
        &xs,
        rtxrmq::rmq::rtx::RtxOptions {
            mode: rtxrmq::rmq::rtx::RtxMode::Blocks { block_size: 64 },
            ..Default::default()
        },
    );
    let mut rng = Rng::new(17);
    for round in 0..50 {
        let i = rng.range(0, 2047);
        let v = rng.f32();
        xs[i] = v;
        rtx.update_value(i, v);
        let st = SparseTable::new(&xs);
        let l = rng.range(0, 2047);
        let r = rng.range(l, 2047);
        assert_eq!(rtx.rmq(l as u32, r as u32), st.rmq(l as u32, r as u32), "round {round}");
    }
}

//! Differential suite for lazy-tag range updates (the PR's acceptance
//! gate): `add v` / `assign v` over `[l, r]` must be **bit-identical**
//! to both a naive elementwise re-solve and to the same stream with
//! every range op decomposed into point writes — across all three
//! shard backends, at block seams, through snapshot/re-shard/staged
//! commit round-trips, under tie-heavy arrays, and end to end through
//! the pipelined and serial coordinators with faults injected into the
//! staging lane. The Instanced fast path is additionally pinned
//! *structurally*: `tag_hits` must count exactly the fully-covered
//! blocks, which is the O(1)-per-covered-block claim made checkable.

use rtxrmq::coordinator::engine::{CommitOutcome, EngineCfg, ShardBlock, ShardedEngine};
use rtxrmq::coordinator::router::Policy;
use rtxrmq::coordinator::server::{Coordinator, CoordinatorCfg};
use rtxrmq::rmq::naive_rmq;
use rtxrmq::rmq::sharded::{ShardBackend, ShardedOptions, ShardedRmq};
use rtxrmq::rmq::RmqSolver;
use rtxrmq::util::faults::{self, FaultPlan};
use rtxrmq::util::rng::Rng;
use rtxrmq::workload::{gen_array, gen_mixed_ranged, Op, RangeDist, UpdateOp};

/// The chaos test arms the **process-global** fault registry and the
/// clean coordinator tests assert exact pipeline counters; same
/// serialization idiom as `mixed_stream.rs`.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

fn opts(backend: ShardBackend, bs: usize) -> ShardedOptions {
    ShardedOptions { block_size: bs, backend, ..Default::default() }
}

/// Sequential semantics of a mixed op stream (the coordinator tests).
fn oracle_run(xs: &mut [f32], ops: &[Op]) -> Vec<u32> {
    let mut out = Vec::new();
    for op in ops {
        match *op {
            Op::Query((l, r)) => out.push(naive_rmq(xs, l as usize, r as usize) as u32),
            Op::Update { i, v } => xs[i as usize] = v,
            Op::RangeAdd { l, r, v } => {
                for x in &mut xs[l as usize..=r as usize] {
                    *x += v;
                }
            }
            Op::RangeAssign { l, r, v } => {
                for x in &mut xs[l as usize..=r as usize] {
                    *x = v;
                }
            }
        }
    }
    out
}

/// Decompose an update stream into pure point writes against a rolling
/// value oracle — the reference semantics every range op must match.
/// The oracle advances with the same elementwise f32 ops
/// (`apply_naive`), so the produced values are bit-identical by
/// construction; what the decomposition checks is the *structures*.
fn decompose_to_points(ops: &[UpdateOp], oracle: &mut [f32]) -> Vec<UpdateOp> {
    let mut out = Vec::new();
    for op in ops {
        match *op {
            UpdateOp::Point { .. } => out.push(*op),
            UpdateOp::RangeAdd { l, r, .. } | UpdateOp::RangeAssign { l, r, .. } => {
                op.apply_naive(oracle);
                for i in l..=r {
                    out.push(UpdateOp::Point { i, v: oracle[i] });
                }
                continue;
            }
        }
        op.apply_naive(oracle);
    }
    out
}

fn random_update_stream(n: usize, count: usize, rng: &mut Rng) -> Vec<UpdateOp> {
    (0..count)
        .map(|_| {
            let x = rng.f64();
            if x < 0.25 {
                let l = rng.range(0, n - 1);
                UpdateOp::RangeAdd { l, r: rng.range(l, n - 1), v: rng.f32() - 0.5 }
            } else if x < 0.5 {
                let l = rng.range(0, n - 1);
                UpdateOp::RangeAssign { l, r: rng.range(l, n - 1), v: rng.f32() }
            } else {
                UpdateOp::Point { i: rng.range(0, n - 1), v: rng.f32() }
            }
        })
        .collect()
}

fn assert_matches_naive(solver: &ShardedRmq, xs: &[f32], rng: &mut Rng, ctx: &str) {
    let n = xs.len();
    let mut queries: Vec<(u32, u32)> = (0..96)
        .map(|_| {
            let l = rng.range(0, n - 1);
            (l as u32, rng.range(l, n - 1) as u32)
        })
        .collect();
    queries.push((0, n as u32 - 1));
    let got = solver.batch(&queries, 2);
    for (k, &(l, r)) in queries.iter().enumerate() {
        assert_eq!(
            got[k] as usize,
            naive_rmq(xs, l as usize, r as usize),
            "{ctx}: ({l},{r})"
        );
    }
}

#[test]
fn range_ops_match_point_decomposition_across_backends() {
    let _g = serial();
    for backend in [ShardBackend::Instanced, ShardBackend::Rtx, ShardBackend::Sparse] {
        let mut rng = Rng::new(0x2201);
        for &(n, bs) in &[(700usize, 32usize), (1024, 64), (129, 16)] {
            let xs = gen_array(n, 71);
            let mut oracle = xs.clone();
            let mut ranged = ShardedRmq::with_options(&xs, opts(backend, bs));
            let mut pointwise = ShardedRmq::with_options(&xs, opts(backend, bs));
            for round in 0..6 {
                let ops = random_update_stream(n, 12, &mut rng);
                let points = decompose_to_points(&ops, &mut oracle);
                ranged.apply_update_ops(&ops, 2);
                pointwise.apply_update_ops(&points, 2);
                assert_eq!(
                    ranged.values(),
                    &oracle[..],
                    "{backend:?} n={n} bs={bs} round {round}: values drifted"
                );
                assert_eq!(ranged.values(), pointwise.values());
                let ctx = format!("{backend:?} n={n} bs={bs} round {round} ranged");
                assert_matches_naive(&ranged, &oracle, &mut rng, &ctx);
                let ctx = format!("{backend:?} n={n} bs={bs} round {round} pointwise");
                assert_matches_naive(&pointwise, &oracle, &mut rng, &ctx);
            }
            ranged.validate().unwrap_or_else(|e| panic!("{backend:?} n={n} bs={bs}: {e}"));
        }
    }
}

#[test]
fn boundary_seams_and_partial_blocks_stay_exact() {
    let _g = serial();
    let (n, bs) = (1024usize, 64usize);
    let mut rng = Rng::new(0x2202);
    for backend in [ShardBackend::Instanced, ShardBackend::Sparse] {
        let xs = gen_array(n, 72);
        let mut oracle = xs.clone();
        let mut solver = ShardedRmq::with_options(&xs, opts(backend, bs));
        // Every decomposition case: exact block spans (pure covered),
        // seam-straddling two-partial spans, a strict-interior
        // single-block span, single elements at both seam sides, the
        // full array, and a span whose partials sandwich covered blocks.
        let spans: Vec<(usize, usize)> = vec![
            (bs, 3 * bs - 1),          // aligned: blocks 1,2 fully covered
            (bs - 1, bs),              // seam straddle: two partial blocks
            (2 * bs + 5, 3 * bs - 7),  // interior of block 2 only
            (4 * bs - 1, 4 * bs - 1),  // single element, right edge
            (4 * bs, 4 * bs),          // single element, left edge
            (0, n - 1),                // full array
            (bs / 2, n - bs / 2 - 1),  // partial + covered run + partial
        ];
        for (k, &(l, r)) in spans.iter().enumerate() {
            let v = rng.f32() - 0.5;
            if k % 2 == 0 {
                solver.range_add(l, r, v);
                for x in &mut oracle[l..=r] {
                    *x += v;
                }
            } else {
                solver.range_assign(l, r, v);
                for x in &mut oracle[l..=r] {
                    *x = v;
                }
            }
            // Sweep every query window crossing the mutated seams.
            for seam in [l, r + 1] {
                let lo = seam.saturating_sub(3);
                for ql in lo..(seam + 3).min(n) {
                    for qr in ql..(seam + 3).min(n) {
                        assert_eq!(
                            solver.rmq(ql as u32, qr as u32) as usize,
                            naive_rmq(&oracle, ql, qr),
                            "{backend:?} span {k} ({l},{r}) query ({ql},{qr})"
                        );
                    }
                }
            }
            assert_matches_naive(&solver, &oracle, &mut rng, &format!("{backend:?} span {k}"));
        }
        solver.validate().unwrap();
    }
}

#[test]
fn assign_then_add_composition_on_covered_blocks() {
    let _g = serial();
    let (n, bs) = (512usize, 32usize);
    let xs = gen_array(n, 73);
    let mut oracle = xs.clone();
    let mut solver = ShardedRmq::with_options(&xs, opts(ShardBackend::Instanced, bs));
    // assign collapses covered blocks to the constant-block fast path
    // (scale = 0); the add after it must shift that constant exactly,
    // and the point write after *that* must reopen the block correctly.
    let ops = vec![
        UpdateOp::RangeAssign { l: 0, r: n - 1, v: 0.75 },
        UpdateOp::RangeAdd { l: bs, r: 5 * bs - 1, v: -0.25 },
        UpdateOp::RangeAdd { l: 2 * bs, r: 3 * bs - 1, v: -0.25 },
        UpdateOp::Point { i: 2 * bs + 7, v: -2.0 },
        UpdateOp::RangeAdd { l: 0, r: n - 1, v: 0.125 },
        UpdateOp::RangeAssign { l: 3 * bs, r: 7 * bs - 1, v: -1.5 },
        UpdateOp::RangeAdd { l: 3 * bs + 1, r: 4 * bs, v: 3.0 },
    ];
    let mut rng = Rng::new(0x2203);
    for (k, op) in ops.iter().enumerate() {
        solver.apply_update_ops(std::slice::from_ref(op), 1);
        op.apply_naive(&mut oracle);
        assert_eq!(solver.values(), &oracle[..], "op {k}: values drifted");
        assert_matches_naive(&solver, &oracle, &mut rng, &format!("after op {k}"));
    }
    solver.validate().unwrap();
    // Ops 0, 1, 2, 4 and 5 hit covered instanced blocks; the counter
    // proves the tag path (not a requantize) absorbed them.
    let stats = solver.range_stats();
    assert_eq!(stats.range_updates, 6, "six range ops applied");
    assert!(stats.tag_hits > 0, "covered blocks must take the tag path");
}

#[test]
fn covered_add_is_o1_per_block_via_tag_hits() {
    let _g = serial();
    let (n, bs) = (4096usize, 64usize);
    let nb = n / bs;
    let xs = gen_array(n, 74);
    let mut oracle = xs.clone();
    let mut inst = ShardedRmq::with_options(&xs, opts(ShardBackend::Instanced, bs));
    // Full-array add: every block fully covered, every block a tag hit —
    // the counter equality IS the O(1)-per-covered-block assertion (a
    // requantize or node rebuild never increments it).
    inst.range_add(0, n - 1, 0.5);
    for x in &mut oracle[..] {
        *x += 0.5;
    }
    let s = inst.range_stats();
    assert_eq!(s.range_updates, 1);
    assert_eq!(s.tag_hits, nb as u64, "all {nb} covered blocks must be absorbed as tags");
    // Unaligned span: the two boundary blocks rebuild, the interior
    // blocks tag — the counter grows by exactly covered = span - 2.
    let (l, r) = (bs / 2, n - bs / 2 - 1);
    inst.range_add(l, r, -0.25);
    for x in &mut oracle[l..=r] {
        *x -= 0.25;
    }
    let s = inst.range_stats();
    assert_eq!(s.range_updates, 2);
    assert_eq!(s.tag_hits, (nb + nb - 2) as u64, "interior blocks tag, boundaries rebuild");
    let mut rng = Rng::new(0x2204);
    assert_matches_naive(&inst, &oracle, &mut rng, "after counted adds");
    inst.validate().unwrap();
    // The non-instanced backends have no tag path: same ops, zero hits,
    // same answers.
    let mut sparse = ShardedRmq::with_options(&xs, opts(ShardBackend::Sparse, bs));
    sparse.range_add(0, n - 1, 0.5);
    sparse.range_add(l, r, -0.25);
    assert_eq!(sparse.range_stats().range_updates, 2);
    assert_eq!(sparse.range_stats().tag_hits, 0, "sparse blocks never tag");
    assert_eq!(sparse.values(), inst.values());
}

#[test]
fn tie_heavy_streams_keep_leftmost_ties_through_v_lo_shifts() {
    let _g = serial();
    // Values and deltas are exact multiples of 0.25 (exactly
    // representable), so every add preserves exact equality between
    // tied positions — any argmin drift through the shifted `v_lo`
    // transform or the collapsed constant blocks is a leftmost-tie bug,
    // not rounding.
    let (n, bs) = (512usize, 32usize);
    let xs: Vec<f32> = gen_array(n, 75).iter().map(|v| (v * 4.0).floor() / 4.0).collect();
    let mut oracle = xs.clone();
    let mut inst = ShardedRmq::with_options(&xs, opts(ShardBackend::Instanced, bs));
    let mut rng = Rng::new(0x2205);
    for round in 0..10 {
        let op = match round % 3 {
            0 => {
                let b = rng.range(0, n / bs - 2);
                UpdateOp::RangeAdd {
                    l: b * bs,
                    r: (b + 2) * bs - 1,
                    v: (rng.range(0, 4) as f32 - 2.0) * 0.25,
                }
            }
            1 => {
                let l = rng.range(0, n - 1);
                UpdateOp::RangeAssign {
                    l,
                    r: rng.range(l, n - 1),
                    v: rng.range(0, 3) as f32 * 0.25,
                }
            }
            _ => UpdateOp::Point { i: rng.range(0, n - 1), v: rng.range(0, 3) as f32 * 0.25 },
        };
        inst.apply_update_ops(std::slice::from_ref(&op), 1);
        op.apply_naive(&mut oracle);
        // Exhaustive-ish sweep: strided windows catch any tie that
        // resolves to a non-leftmost position.
        for l in (0..n).step_by(3) {
            for r in (l..n).step_by(5) {
                assert_eq!(
                    inst.rmq(l as u32, r as u32) as usize,
                    naive_rmq(&oracle, l, r),
                    "round {round} ({l},{r})"
                );
            }
        }
    }
    assert!(inst.range_stats().tag_hits > 0, "covered quantized adds must tag");
    inst.validate().unwrap();
}

#[test]
fn tags_survive_snapshot_reshard_and_staged_commits() {
    let _g = serial();
    let n = 768usize;
    let xs = gen_array(n, 76);
    let mut oracle = xs.clone();
    let engine = ShardedEngine::new(ShardedRmq::with_options(
        &xs,
        opts(ShardBackend::Instanced, 32),
    ));
    let mut rng = Rng::new(0x2206);
    let solve = |queries: &[(u32, u32)], oracle: &[f32], ctx: &str| {
        let got = rtxrmq::coordinator::engine::Engine::solve(&engine, queries, 2).unwrap();
        for (k, &(l, r)) in queries.iter().enumerate() {
            assert_eq!(got[k] as usize, naive_rmq(oracle, l as usize, r as usize), "{ctx} ({l},{r})");
        }
    };
    let queries: Vec<(u32, u32)> = (0..120)
        .map(|_| {
            let l = rng.range(0, n - 1);
            (l as u32, rng.range(l, n - 1) as u32)
        })
        .collect();

    // Direct ops, then a snapshot: values() is eager truth, so the
    // snapshot must already contain every tag's effect.
    let ops = random_update_stream(n, 10, &mut rng);
    engine.update_ops(&ops, 2).unwrap();
    for op in &ops {
        op.apply_naive(&mut oracle);
    }
    let (snap, seq) = engine.snapshot();
    assert_eq!(snap, oracle, "snapshot must carry the tags' values");
    assert_eq!(seq, 1);
    solve(&queries, &oracle, "post-direct");

    // A range-carrying segment stages as a pointer-sized tag spec and
    // commits clean at the fence.
    let ops = vec![
        UpdateOp::Point { i: 5, v: -0.5 },
        UpdateOp::RangeAdd { l: 64, r: 447, v: 0.25 },
        UpdateOp::RangeAssign { l: 200, r: 263, v: -1.0 },
    ];
    // Solver-level shape check: the staged spec carries no prebuilt
    // blocks (that is what "pointer-sized" means operationally).
    {
        let probe = ShardedRmq::with_options(&oracle, opts(ShardBackend::Instanced, 32));
        let spec = probe.prepare_update_ops(&ops, 2);
        assert!(spec.is_tag_only(), "range-carrying segments stage tag-only");
        assert_eq!(spec.touched_blocks(), 0, "no per-block value copies staged");
    }
    let before = engine.range_stats();
    let prep = engine.prepare_update_ops(&ops, 2);
    assert_eq!(engine.commit_prepared(prep, 2), CommitOutcome::Installed);
    for op in &ops {
        op.apply_naive(&mut oracle);
    }
    solve(&queries, &oracle, "post-staged-commit");
    let after = engine.range_stats();
    assert_eq!(after.range_updates, before.range_updates + 2);
    assert!(after.tag_hits > before.tag_hits, "covered blocks tagged at the fence");

    // Conflicted commit: a direct write between stage and commit voids
    // the prepared tag spec; the fallback applies the same ops in
    // commit order, bit-identically.
    let staged_ops = vec![UpdateOp::RangeAdd { l: 0, r: n - 1, v: -0.125 }];
    let prep = engine.prepare_update_ops(&staged_ops, 2);
    let conflict = vec![UpdateOp::Point { i: 100, v: 9.0 }];
    engine.update_ops(&conflict, 2).unwrap();
    assert_eq!(engine.commit_prepared(prep, 2), CommitOutcome::FellBack);
    for op in conflict.iter().chain(&staged_ops) {
        op.apply_naive(&mut oracle);
    }
    solve(&queries, &oracle, "post-conflicted-commit");

    // Re-shard: the replacement must adopt the lifetime counters
    // (monotone metrics) and keep answering exactly; fresh range ops on
    // the new decomposition keep counting from there.
    let stats_before = engine.range_stats();
    assert!(engine.reshard(64), "quiet re-shard installs");
    assert_eq!(engine.block_size(), 64);
    assert_eq!(engine.range_stats(), stats_before, "re-shard adopts the counters");
    solve(&queries, &oracle, "post-reshard");
    engine.update_ops(&[UpdateOp::RangeAdd { l: 0, r: n - 1, v: 0.5 }], 2).unwrap();
    for x in &mut oracle[..] {
        *x += 0.5;
    }
    solve(&queries, &oracle, "post-reshard range op");
    let stats = engine.range_stats();
    assert_eq!(stats.range_updates, stats_before.range_updates + 1);
    assert!(stats.tag_hits >= stats_before.tag_hits + (n as u64 / 64), "new blocks tag too");
}

#[test]
fn pipelined_and_serial_coordinators_agree_on_ranged_streams() {
    let _g = serial();
    let n = 1 << 12;
    let xs = gen_array(n, 77);
    let mk = |pipeline: bool| {
        Coordinator::start(
            &xs,
            None,
            CoordinatorCfg {
                policy: Policy::ModeledCost,
                engines: EngineCfg { shard_block: ShardBlock::Fixed(64) },
                pipeline,
                ..Default::default()
            },
        )
    };
    let pipelined = mk(true);
    let serial_c = mk(false);
    let mut oracle = xs.clone();
    let mut rng = Rng::new(0x2207);
    for round in 0..10 {
        let ops = gen_mixed_ranged(n, 96, 0.2, 0.15, RangeDist::Small, &mut rng);
        let want = oracle_run(&mut oracle, &ops);
        let a = pipelined.submit_mixed(ops.clone()).unwrap();
        let b = serial_c.submit_mixed(ops).unwrap();
        assert_eq!(a.answers, want, "pipelined, round {round}");
        assert_eq!(b.answers, want, "serial, round {round}");
        assert_eq!(a.updates_applied, b.updates_applied);
    }
    let mp = pipelined.metrics.lock();
    assert!(mp.range_updates > 0, "the stream must contain range ops: {mp}");
    assert!(mp.tag_hits > 0, "instanced default backend must absorb covered blocks");
    assert!(mp.staged_batches > 0, "ranged segments ride the overlap lane");
    drop(mp);
    let ms = serial_c.metrics.lock();
    assert_eq!(ms.staged_batches, 0);
    assert_eq!(ms.range_updates, pipelined.metrics.lock().range_updates);
    drop(ms);
    pipelined.shutdown();
    serial_c.shutdown();
}

#[test]
fn chaos_staging_faults_keep_ranged_answers_exact() {
    let _g = serial();
    // The schedule aims at exactly the lane the tag-only specs ride:
    // the staged-prepare worker dies twice, commits are forced into the
    // conflict-fallback path, and pool workers panic sporadically. The
    // guarantee: every *accepted* answer stays bit-identical to the
    // sequential oracle — range adds are not idempotent, so this also
    // exercises the union-span recovery snapshot in the direct path.
    let arm = faults::arm_guard(
        FaultPlan::parse(
            "stage.prepare:panic:1.0:2,stage.commit:err:0.5:3,pool.worker:panic:0.1:4",
            0x2208,
        )
        .unwrap(),
    );
    let n = 1 << 12;
    let xs = gen_array(n, 78);
    let mut oracle = xs.clone();
    let c = Coordinator::start(
        &xs,
        None,
        CoordinatorCfg {
            policy: Policy::ModeledCost,
            engines: EngineCfg { shard_block: ShardBlock::Fixed(64) },
            ..Default::default()
        },
    );
    let mut rng = Rng::new(0x2209);
    for round in 0..12 {
        let ops = gen_mixed_ranged(n, 64, 0.2, 0.2, RangeDist::Small, &mut rng);
        let want = oracle_run(&mut oracle, &ops);
        let resp = c.submit_mixed(ops).unwrap();
        assert_eq!(resp.answers, want, "chaos round {round}");
    }
    c.sync_faults();
    let m = c.metrics.lock();
    assert!(m.injected_faults >= 4, "the schedule must actually fire: {m}");
    assert!(m.caught_panics >= 1, "injected panics were caught, not propagated");
    assert!(m.range_updates > 0, "range ops flowed under faults");
    assert!(m.tag_hits > 0);
    drop(m);
    drop(arm);
    c.shutdown();
}

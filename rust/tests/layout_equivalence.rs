//! Cross-layout equivalence properties (the wide-SoA acceptance gate):
//! wide-BVH hits — prim id AND t, including leftmost tie-breaks and the
//! Algorithm-6 carried-hit sub-rays — must be identical to the binary
//! BVH and to `naive_rmq`, across Flat/Blocks geometry, both builders,
//! and after `update_value` refits.

use rtxrmq::bvh::build::{build, collapse_to_wide};
use rtxrmq::bvh::traverse::{closest_hit, closest_hit_from, Counters, TraversalStack};
use rtxrmq::bvh::wide::{closest_hit_wide, closest_hit_wide_from, WideStack};
use rtxrmq::bvh::{AccelLayout, Builder};
use rtxrmq::geometry::flat::{build_scene, ray_for_query, ray_origin_x};
use rtxrmq::rmq::naive_rmq;
use rtxrmq::rmq::rtx::{RtxMode, RtxOptions, RtxRmq};
use rtxrmq::rmq::sharded::{ShardBackend, ShardedOptions, ShardedRmq};
use rtxrmq::rmq::{Query, RmqSolver};
use rtxrmq::util::proptest::{check, gen};

/// Raw traversal equivalence: the same rays through both layouts must
/// produce the same `Hit` (t and prim), for fresh and carried casts,
/// for both builders, on duplicate-heavy inputs (tie-break stress).
#[test]
fn raw_hits_identical_across_layouts() {
    check("hit-for-hit wide == binary", 60, |rng| {
        let xs = gen::dup_array(rng, 2..=600, 3);
        let n = xs.len();
        let tris = build_scene(&xs);
        let theta = ray_origin_x(&xs);
        for builder in [Builder::BinnedSah, Builder::Lbvh] {
            let bvh = build(&tris, builder, 4);
            let wb = collapse_to_wide(&bvh, &tris);
            wb.validate(&tris)?;
            let mut bs = TraversalStack::new();
            let mut ws = WideStack::new();
            let (mut cb, mut cw) = (Counters::default(), Counters::default());
            for _ in 0..12 {
                let (l1, r1) = gen::query(rng, n);
                let ray = ray_for_query(l1 as u32, r1 as u32, n, theta);
                let bh = closest_hit(&bvh, &tris, &ray, &mut bs, &mut cb);
                let wh = closest_hit_wide(&wb, &ray, &mut ws, &mut cw);
                if bh != wh {
                    return Err(format!("{builder:?} ({l1},{r1}): {bh:?} != {wh:?}"));
                }
                let want = naive_rmq(&xs, l1, r1);
                if wh.map(|h| h.prim as usize) != Some(want) {
                    return Err(format!("({l1},{r1}): wide {wh:?} want {want}"));
                }
                // Carried-hit sub-ray (Algorithm 6's payload-min): seed
                // the next cast with this hit on both sides.
                let (l2, r2) = gen::query(rng, n);
                let ray2 = ray_for_query(l2 as u32, r2 as u32, n, theta);
                let bh2 = closest_hit_from(&bvh, &tris, &ray2, &mut bs, &mut cb, bh);
                let wh2 = closest_hit_wide_from(&wb, &ray2, &mut ws, &mut cw, wh);
                if bh2 != wh2 {
                    return Err(format!(
                        "{builder:?} carried ({l1},{r1})->({l2},{r2}): {bh2:?} != {wh2:?}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Solver-level equivalence over the full matrix: builders × modes ×
/// layouts, against the naive oracle, before and after refits.
#[test]
fn solver_matrix_agrees_including_refits() {
    check("solver matrix wide == binary == naive", 25, |rng| {
        let mut xs = gen::dup_array(rng, 8..=512, 4);
        let n = xs.len();
        let bs = 1usize << rng.range(1, 5);
        let queries: Vec<Query> = (0..32)
            .map(|_| {
                let (l, r) = gen::query(rng, n);
                (l as u32, r as u32)
            })
            .collect();
        for builder in [Builder::BinnedSah, Builder::Lbvh] {
            for mode in [RtxMode::Flat, RtxMode::Blocks { block_size: bs }] {
                let mut solvers: Vec<RtxRmq> = AccelLayout::all()
                    .into_iter()
                    .map(|layout| {
                        RtxRmq::with_options(
                            &xs,
                            RtxOptions { mode, builder, layout, ..Default::default() },
                        )
                    })
                    .collect();
                let want: Vec<u32> = queries
                    .iter()
                    .map(|&(l, r)| naive_rmq(&xs, l as usize, r as usize) as u32)
                    .collect();
                for s in &solvers {
                    let got = s.batch(&queries, 2);
                    if got != want {
                        return Err(format!("{builder:?}/{mode:?}: pre-refit mismatch"));
                    }
                }
                // Dynamic updates: batch of point updates, one refit.
                let updates: Vec<(usize, f32)> =
                    (0..4).map(|_| (rng.range(0, n - 1), rng.f32())).collect();
                for &(i, v) in &updates {
                    xs[i] = v;
                }
                for s in solvers.iter_mut() {
                    s.update_values(&updates);
                }
                let want: Vec<u32> = queries
                    .iter()
                    .map(|&(l, r)| naive_rmq(&xs, l as usize, r as usize) as u32)
                    .collect();
                for s in &solvers {
                    let got = s.batch(&queries, 2);
                    if got != want {
                        return Err(format!("{builder:?}/{mode:?}: post-refit mismatch"));
                    }
                }
            }
        }
        Ok(())
    });
}

/// The same matrix discipline for the two-level sharded engine: every
/// (layout × backend) shard configuration must agree with the naive
/// oracle before and after batched-update refits, and the refitted
/// per-block BVHs must keep their structural invariants.
#[test]
fn sharded_matrix_agrees_including_refits() {
    check("sharded matrix agrees incl. refits", 20, |rng| {
        let mut xs = gen::dup_array(rng, 8..=512, 4);
        let n = xs.len();
        let bs = 1usize << rng.range(1, 5);
        let queries: Vec<Query> = (0..32)
            .map(|_| {
                let (l, r) = gen::query(rng, n);
                (l as u32, r as u32)
            })
            .collect();
        let configs = [
            (AccelLayout::Wide, ShardBackend::Instanced),
            (AccelLayout::Wide, ShardBackend::Rtx),
            (AccelLayout::Binary, ShardBackend::Rtx),
            (AccelLayout::Wide, ShardBackend::Sparse),
        ];
        let mut solvers: Vec<ShardedRmq> = configs
            .iter()
            .map(|&(layout, backend)| {
                ShardedRmq::with_options(
                    &xs,
                    ShardedOptions { block_size: bs, layout, backend, ..Default::default() },
                )
            })
            .collect();
        let want: Vec<u32> = queries
            .iter()
            .map(|&(l, r)| naive_rmq(&xs, l as usize, r as usize) as u32)
            .collect();
        for (s, cfg) in solvers.iter().zip(&configs) {
            if s.batch(&queries, 2) != want {
                return Err(format!("{cfg:?} bs={bs}: pre-refit mismatch"));
            }
        }
        let updates: Vec<(usize, f32)> =
            (0..5).map(|_| (rng.range(0, n - 1), rng.f32())).collect();
        for &(i, v) in &updates {
            xs[i] = v;
        }
        let want: Vec<u32> = queries
            .iter()
            .map(|&(l, r)| naive_rmq(&xs, l as usize, r as usize) as u32)
            .collect();
        for (s, cfg) in solvers.iter_mut().zip(&configs) {
            s.update_batch(&updates);
            if s.batch(&queries, 2) != want {
                return Err(format!("{cfg:?} bs={bs}: post-refit mismatch"));
            }
            s.validate().map_err(|e| format!("{cfg:?} bs={bs}: {e}"))?;
        }
        Ok(())
    });
}

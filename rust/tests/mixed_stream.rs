//! Mixed query+update streams through the full coordinator stack,
//! differentially tested against a naive array + rescan oracle.
//!
//! The consistency contract under test (the fence): updates between two
//! query chunks must be visible to the later chunk and invisible to the
//! earlier one — exactly the answers a sequential re-solve of the op
//! stream produces, leftmost ties included.

use rtxrmq::coordinator::engine::{
    CommitOutcome, EngineCfg, LifecycleCfg, ShardBlock, ShardedEngine,
};
use rtxrmq::coordinator::router::Policy;
use rtxrmq::coordinator::server::{Coordinator, CoordinatorCfg};
use rtxrmq::rmq::naive_rmq;
use rtxrmq::rmq::sharded::{ShardedOptions, ShardedRmq};
use rtxrmq::util::faults::{self, FaultPlan};
use rtxrmq::util::rng::Rng;
use rtxrmq::workload::{gen_array, gen_mixed, gen_queries, Op, RangeDist};

/// Every test in this binary serializes on one mutex: the chaos tests
/// arm the **process-global** fault registry, and the clean tests
/// assert exact pipeline counters (e.g. `staged_fallbacks == 0`) that a
/// concurrently-armed schedule would perturb. Cargo runs the tests of
/// one binary on concurrent threads, so the isolation must be explicit.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    // A panicked test poisons the mutex; later tests still run.
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

/// The oracle: apply the op stream to a plain array, answering queries
/// by rescan — the sequential semantics the coordinator must reproduce.
fn oracle_run(xs: &mut [f32], ops: &[Op]) -> Vec<u32> {
    let mut out = Vec::new();
    for op in ops {
        match *op {
            Op::Query((l, r)) => out.push(naive_rmq(xs, l as usize, r as usize) as u32),
            Op::Update { i, v } => xs[i as usize] = v,
            Op::RangeAdd { l, r, v } => {
                for x in &mut xs[l as usize..=r as usize] {
                    *x += v;
                }
            }
            Op::RangeAssign { l, r, v } => {
                for x in &mut xs[l as usize..=r as usize] {
                    *x = v;
                }
            }
        }
    }
    out
}

fn coordinator(xs: &[f32], shard_block: ShardBlock) -> Coordinator {
    Coordinator::start(
        xs,
        None,
        CoordinatorCfg {
            policy: Policy::ModeledCost,
            engines: EngineCfg { shard_block },
            ..Default::default()
        },
    )
}

#[test]
fn gen_mixed_streams_match_oracle_hit_for_hit() {
    let _guard = serial();
    let n = 1 << 12;
    let xs = gen_array(n, 21);
    let mut oracle = xs.clone();
    let c = coordinator(&xs, ShardBlock::Fixed(64));
    let mut rng = Rng::new(22);
    for round in 0..10 {
        let ops = gen_mixed(n, 96, 0.3, RangeDist::Small, &mut rng);
        let want = oracle_run(&mut oracle, &ops);
        let resp = c.submit_mixed(ops.clone()).unwrap();
        assert_eq!(resp.answers, want, "round {round}");
        assert_eq!(resp.updates_applied, ops.iter().filter(|o| o.is_update()).count());
    }
    c.shutdown();
}

#[test]
fn duplicate_heavy_streams_keep_leftmost_ties() {
    // Quantised values force constant ties between the left partial,
    // summary and right partial probes — and between pre- and
    // post-update values.
    let _guard = serial();
    let n = 1 << 11;
    let xs: Vec<f32> = gen_array(n, 23).iter().map(|v| (v * 4.0).floor() / 4.0).collect();
    let mut oracle = xs.clone();
    let c = coordinator(&xs, ShardBlock::Fixed(32));
    let mut rng = Rng::new(24);
    for round in 0..8 {
        // Updates drawn from the same quantised palette keep ties alive.
        let ops: Vec<Op> = gen_mixed(n, 80, 0.4, RangeDist::Medium, &mut rng)
            .into_iter()
            .map(|op| match op {
                Op::Update { i, v } => Op::Update { i, v: (v * 4.0).floor() / 4.0 },
                q => q,
            })
            .collect();
        let want = oracle_run(&mut oracle, &ops);
        let resp = c.submit_mixed(ops).unwrap();
        assert_eq!(resp.answers, want, "round {round}");
    }
    c.shutdown();
}

#[test]
fn update_bursts_straddling_block_seams() {
    // Bursts land exactly on the block seams (last index of block b,
    // first of b+1), fenced between query chunks whose ranges straddle
    // the same seams — the decomposition's worst case.
    let _guard = serial();
    let n = 1024usize;
    let bs = 64usize;
    let xs = gen_array(n, 25);
    let mut oracle = xs.clone();
    let c = coordinator(&xs, ShardBlock::Fixed(bs));
    let mut rng = Rng::new(26);
    for round in 0..6 {
        let mut ops = Vec::new();
        for b in 1..(n / bs) {
            let seam = b * bs;
            ops.push(Op::Query(((seam - 5) as u32, (seam + 5) as u32)));
            ops.push(Op::Update { i: (seam - 1) as u32, v: rng.f32() });
            ops.push(Op::Update { i: seam as u32, v: rng.f32() });
            ops.push(Op::Query(((seam - 5) as u32, (seam + 5) as u32)));
            ops.push(Op::Query((0, (n - 1) as u32)));
        }
        let want = oracle_run(&mut oracle, &ops);
        let resp = c.submit_mixed(ops).unwrap();
        assert_eq!(resp.answers, want, "round {round}");
    }
    c.shutdown();
}

#[test]
fn back_to_back_batches_touching_the_same_block() {
    // Consecutive requests hammer one block (refit-after-refit on the
    // same BVH) with full-range reads fencing each burst.
    let _guard = serial();
    let n = 512usize;
    let xs = gen_array(n, 27);
    let mut oracle = xs.clone();
    let c = coordinator(&xs, ShardBlock::Fixed(64));
    let mut rng = Rng::new(28);
    for round in 0..12 {
        let block = 3usize; // always the same block
        let mut ops = Vec::new();
        for _ in 0..6 {
            let i = block * 64 + rng.range(0, 63);
            ops.push(Op::Update { i: i as u32, v: rng.f32() });
        }
        ops.push(Op::Query((0, (n - 1) as u32)));
        ops.push(Op::Query(((block * 64) as u32, (block * 64 + 63) as u32)));
        let want = oracle_run(&mut oracle, &ops);
        let resp = c.submit_mixed(ops).unwrap();
        assert_eq!(resp.answers, want, "round {round}");
    }
    c.shutdown();
}

#[test]
fn auto_tuned_shard_block_serves_mixed_streams() {
    // `--shard-block auto` end to end: the tuner picks the block size,
    // the stream still matches the oracle hit for hit.
    let _guard = serial();
    let n = 1 << 12;
    let xs = gen_array(n, 29);
    let mut oracle = xs.clone();
    let c = coordinator(&xs, ShardBlock::Auto { dist: RangeDist::Small, update_frac: 0.25 });
    let mut rng = Rng::new(30);
    for round in 0..6 {
        let ops = gen_mixed(n, 128, 0.25, RangeDist::Small, &mut rng);
        let want = oracle_run(&mut oracle, &ops);
        let resp = c.submit_mixed(ops).unwrap();
        assert_eq!(resp.answers, want, "round {round}");
    }
    c.shutdown();
}

#[test]
fn quiet_period_rebuild_reroutes_large_ranges_to_lca() {
    // The lifecycle's headline differential: a mixed stream makes the
    // static engines stale (large-range batches degrade to the shards);
    // after a quiet period the background builder rebuilds them from a
    // snapshot, the router's freshness check clears, and a large-range
    // batch lands on the rebuilt LCA engine — with every answer,
    // including those served while the epoch swap was in flight,
    // matching the sequential oracle.
    let _guard = serial();
    let n = 1usize << 15;
    let xs = gen_array(n, 41);
    let mut oracle = xs.clone();
    let c = Coordinator::start(
        &xs,
        None,
        CoordinatorCfg {
            policy: Policy::Heuristic,
            engines: EngineCfg { shard_block: ShardBlock::Sqrt },
            lifecycle: LifecycleCfg { observer_half_life: 4.0, ..Default::default() },
            ..Default::default()
        },
    );
    let mut rng = Rng::new(42);
    // Busy mixed phase: updates keep the epoch stale and the observed
    // update rate above the rebuild threshold.
    for round in 0..6 {
        let ops = gen_mixed(n, 64, 0.3, RangeDist::Small, &mut rng);
        let want = oracle_run(&mut oracle, &ops);
        let resp = c.submit_mixed(ops).unwrap();
        assert_eq!(resp.answers, want, "mixed round {round}");
    }
    assert_eq!(c.lifecycle.epoch_version(), 0, "busy traffic must not rebuild");
    // Stale epoch: even a large-range batch is pinned to the shards.
    let large = gen_queries(n, 64, RangeDist::Large, &mut rng);
    let resp = c.submit_mixed(large.iter().copied().map(Op::Query).collect()).unwrap();
    assert_eq!(resp.engine, "SHARDED", "stale epoch pins large ranges to the shards");
    for (k, &(l, r)) in large.iter().enumerate() {
        assert_eq!(resp.answers[k], naive_rmq(&oracle, l as usize, r as usize) as u32);
    }
    // Quiet period: pure queries decay the observed update rate until
    // the cost model schedules a background rebuild.
    let mut fired = false;
    for round in 0..600 {
        let qs = gen_queries(n, 64, RangeDist::Small, &mut rng);
        let resp = c.query(qs.clone()).unwrap();
        for (k, &(l, r)) in qs.iter().enumerate() {
            assert_eq!(
                resp.answers[k],
                naive_rmq(&oracle, l as usize, r as usize) as u32,
                "quiet round {round} ({l},{r}) via {}",
                resp.engine
            );
        }
        if c.lifecycle.rebuilds() >= 1 {
            fired = true;
            break;
        }
    }
    assert!(fired, "quiet period must trigger a background rebuild");
    assert!(c.metrics.lock().rebuilds >= 1);
    // Fresh epoch: the crossover routing is back — large ranges go to
    // the rebuilt LCA (not the shards), hit-for-hit with the oracle.
    let large = gen_queries(n, 128, RangeDist::Large, &mut rng);
    let resp = c.query(large.clone()).unwrap();
    assert_eq!(resp.engine, "LCA", "rebuilt statics serve large ranges again");
    assert!(resp.epoch >= 1, "served by a rebuilt epoch");
    for (k, &(l, r)) in large.iter().enumerate() {
        assert_eq!(
            resp.answers[k],
            naive_rmq(&oracle, l as usize, r as usize) as u32,
            "post-rebuild ({l},{r})"
        );
    }
    c.shutdown();
}

#[test]
fn rebuild_mid_stream_pins_segments_to_their_epochs() {
    // Background rebuilds complete at arbitrary points while four
    // clients stream ops. The contract for any swap timing: in-flight
    // segments finish on the epoch they pinned, later segments use the
    // new one (response epochs are monotone per client), and every
    // answer is bit-identical to each client's sequential oracle.
    let _guard = serial();
    let n = 1usize << 14;
    let region = n / 4;
    let xs = gen_array(n, 43);
    let c = std::sync::Arc::new(Coordinator::start(
        &xs,
        None,
        CoordinatorCfg {
            policy: Policy::ModeledCost,
            engines: EngineCfg { shard_block: ShardBlock::Fixed(64) },
            lifecycle: LifecycleCfg { observer_half_life: 2.0, ..Default::default() },
            ..Default::default()
        },
    ));
    let xs = std::sync::Arc::new(xs);
    let mut handles = Vec::new();
    for t in 0..4usize {
        let c = c.clone();
        let xs = xs.clone();
        handles.push(std::thread::spawn(move || {
            let lo = t * region;
            let mut oracle: Vec<f32> = xs.as_ref().clone();
            let mut rng = Rng::new(300 + t as u64);
            let mut last_epoch = 0u64;
            for round in 0..28 {
                // First rounds mutate; the rest are a quiet query phase
                // during which rebuilds fire mid-stream.
                let update_frac = if round < 3 { 0.3 } else { 0.0 };
                let mut ops = Vec::new();
                for _ in 0..32 {
                    if rng.f64() < update_frac {
                        let i = lo + rng.range(0, region - 1);
                        ops.push(Op::Update { i: i as u32, v: rng.f32() });
                    } else {
                        let l = lo + rng.range(0, region - 1);
                        let r = rng.range(l, lo + region - 1);
                        ops.push(Op::Query((l as u32, r as u32)));
                    }
                }
                let want = oracle_run(&mut oracle, &ops);
                let resp = c.submit_mixed(ops).unwrap();
                assert_eq!(resp.answers, want, "client {t} round {round}");
                assert!(
                    resp.epoch >= last_epoch,
                    "client {t}: epoch went backwards ({} < {last_epoch})",
                    resp.epoch
                );
                last_epoch = resp.epoch;
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // Drive the quiet period on from the main thread until at least one
    // rebuild has certainly landed (it usually fires mid-stream above).
    let mut rng = Rng::new(310);
    let mut fired = c.lifecycle.rebuilds() >= 1;
    for _ in 0..600 {
        if fired {
            break;
        }
        let qs = gen_queries(n, 32, RangeDist::Small, &mut rng);
        c.query(qs).unwrap();
        fired = c.lifecycle.rebuilds() >= 1;
    }
    assert!(fired, "no rebuild for any swap timing");
    // Later segments use the new epoch.
    let resp = c.query(vec![(0, (n - 1) as u32)]).unwrap();
    assert!(resp.epoch >= 1, "post-rebuild responses carry the new epoch");
    assert!(c.metrics.lock().updates > 0);
}

#[test]
fn reshard_trigger_fires_when_the_offered_distribution_shifts() {
    // `--shard-block auto` under serving must tune from *observed*
    // traffic: the CLI prior says small ranges with updates, the
    // offered load is pure large ranges — the workload-fed tuner drifts
    // >= 2x from the live block size, the lifecycle re-shards in the
    // background, and answers stay exact throughout.
    let _guard = serial();
    let n = 1usize << 15;
    let xs = gen_array(n, 44);
    let c = Coordinator::start(
        &xs,
        None,
        CoordinatorCfg {
            policy: Policy::Heuristic,
            engines: EngineCfg {
                shard_block: ShardBlock::Auto { dist: RangeDist::Small, update_frac: 0.2 },
            },
            lifecycle: LifecycleCfg { observer_half_life: 4.0, ..Default::default() },
            ..Default::default()
        },
    );
    let initial = c.lifecycle.shard_block_live();
    assert!(initial >= 4);
    let mut rng = Rng::new(45);
    let mut fired = false;
    for _ in 0..200 {
        let qs = gen_queries(n, 64, RangeDist::Large, &mut rng);
        let resp = c.query(qs.clone()).unwrap();
        // Spot-check (the array never mutates in this test).
        for (k, &(l, r)) in qs.iter().take(2).enumerate() {
            assert_eq!(resp.answers[k], naive_rmq(&xs, l as usize, r as usize) as u32);
        }
        if c.lifecycle.reshards() >= 1 {
            fired = true;
            break;
        }
    }
    assert!(fired, "shifted distribution must trigger a background re-shard");
    let live = c.lifecycle.shard_block_live();
    let drift = (live as f64 / initial as f64).max(initial as f64 / live as f64);
    assert!(drift >= 2.0, "initial {initial} live {live}");
    assert_eq!(c.metrics.lock().reshards, c.lifecycle.reshards());
    // The re-sharded engine still answers exactly — full check on a
    // small-range batch routed to the shards.
    let qs = gen_queries(n, 64, RangeDist::Small, &mut rng);
    let resp = c.query(qs.clone()).unwrap();
    assert_eq!(resp.engine, "SHARDED");
    for (k, &(l, r)) in qs.iter().enumerate() {
        assert_eq!(resp.answers[k], naive_rmq(&xs, l as usize, r as usize) as u32);
    }
    c.shutdown();
}

/// Fence-heavy op stream generator: high alternation rate between
/// queries and updates (many short segments — the shape the two-lane
/// pipeline is built for), with an optional block to confine indices to.
fn fence_heavy_ops(
    n: usize,
    count: usize,
    block: Option<(usize, usize)>,
    rng: &mut Rng,
) -> Vec<Op> {
    let (lo, len) = block.unwrap_or((0, n));
    let mut ops = Vec::with_capacity(count);
    for k in 0..count {
        // Alternate in short runs: q,u,q,u with occasional doubles.
        if k % 2 == 0 || rng.f64() < 0.2 {
            let l = lo + rng.range(0, len - 1);
            let r = lo + rng.range(l - lo, len - 1);
            ops.push(Op::Query((l as u32, r as u32)));
        } else {
            let i = lo + rng.range(0, len - 1);
            ops.push(Op::Update { i: i as u32, v: rng.f32() });
        }
    }
    ops
}

#[test]
fn pipelined_and_serial_executors_agree_hit_for_hit() {
    // The tentpole invariant: the two-lane pipelined executor must be
    // bit-identical to the serial executor (and both to the sequential
    // oracle) on fence-heavy streams.
    let _guard = serial();
    let n = 1 << 12;
    let xs = gen_array(n, 50);
    let pipelined = Coordinator::start(
        &xs,
        None,
        CoordinatorCfg {
            engines: EngineCfg { shard_block: ShardBlock::Fixed(64) },
            ..Default::default()
        },
    );
    let serial = Coordinator::start(
        &xs,
        None,
        CoordinatorCfg {
            engines: EngineCfg { shard_block: ShardBlock::Fixed(64) },
            pipeline: false,
            ..Default::default()
        },
    );
    let mut oracle = xs.clone();
    let mut rng = Rng::new(51);
    for round in 0..12 {
        let ops = fence_heavy_ops(n, 64, None, &mut rng);
        let want = oracle_run(&mut oracle, &ops);
        let a = pipelined.submit_mixed(ops.clone()).unwrap();
        let b = serial.submit_mixed(ops).unwrap();
        assert_eq!(a.answers, want, "pipelined, round {round}");
        assert_eq!(b.answers, want, "serial, round {round}");
        assert_eq!(a.updates_applied, b.updates_applied);
    }
    let mp = pipelined.metrics.lock();
    assert!(mp.staged_batches > 0, "fence-heavy streams must exercise the overlap lane");
    assert_eq!(mp.staged_fallbacks, 0, "single-writer streams never conflict");
    assert!(mp.overlap_ns_hidden_total > 0);
    drop(mp);
    assert_eq!(serial.metrics.lock().staged_batches, 0);
    pipelined.shutdown();
    serial.shutdown();
}

#[test]
fn pipelined_update_then_query_on_the_same_block() {
    // The sharpest fence case for the overlap: the staged preparation
    // rebuilds exactly the block the preceding query segment is
    // probing, and the query after the fence re-reads it. Everything is
    // confined to one block so any leak is unmissable.
    let _guard = serial();
    let n = 1024usize;
    let bs = 64usize;
    let xs = gen_array(n, 52);
    let mut oracle = xs.clone();
    let c = coordinator(&xs, ShardBlock::Fixed(bs));
    let mut rng = Rng::new(53);
    for round in 0..10 {
        let block = rng.range(0, n / bs - 1);
        let mut ops = fence_heavy_ops(n, 40, Some((block * bs, bs)), &mut rng);
        // End on update-then-query-the-whole-block, the classic pair.
        let i = block * bs + rng.range(0, bs - 1);
        ops.push(Op::Update { i: i as u32, v: -1.0 - round as f32 });
        ops.push(Op::Query(((block * bs) as u32, (block * bs + bs - 1) as u32)));
        ops.push(Op::Query((0, (n - 1) as u32)));
        let want = oracle_run(&mut oracle, &ops);
        let resp = c.submit_mixed(ops).unwrap();
        assert_eq!(resp.answers, want, "round {round}");
    }
    assert!(c.metrics.lock().staged_batches > 0);
    c.shutdown();
}

#[test]
fn back_to_back_update_segments_mix_staged_and_direct_paths() {
    // Leading update segments have no query to hide behind (direct
    // path); interior ones ride the overlap lane. Streams shaped
    // [u..][q..][u..] and [q..][u..][u-leading next request] pin both
    // paths and their interleaving across consecutive fused batches.
    let _guard = serial();
    let n = 1 << 11;
    let xs = gen_array(n, 54);
    let mut oracle = xs.clone();
    let c = coordinator(&xs, ShardBlock::Fixed(32));
    let mut rng = Rng::new(55);
    for round in 0..8 {
        let shapes: [&[bool]; 3] = [
            &[false, false, true],                     // u,u,q — leading updates
            &[true, false, true, false],               // q,u,q,u — trailing update
            &[false, true, false, false, true, false], // u,q,u,u,q,u
        ];
        for (si, shape) in shapes.iter().enumerate() {
            let mut ops = Vec::new();
            for &is_query in shape.iter() {
                for _ in 0..rng.range(1, 4) {
                    if is_query {
                        let l = rng.range(0, n - 1);
                        ops.push(Op::Query((l as u32, rng.range(l, n - 1) as u32)));
                    } else {
                        ops.push(Op::Update {
                            i: rng.range(0, n - 1) as u32,
                            v: rng.f32(),
                        });
                    }
                }
            }
            let want = oracle_run(&mut oracle, &ops);
            let resp = c.submit_mixed(ops).unwrap();
            assert_eq!(resp.answers, want, "round {round} shape {si}");
        }
    }
    let m = c.metrics.lock();
    assert!(m.staged_batches > 0, "interior update segments staged");
    assert!(
        m.staged_batches < m.update_batches,
        "leading update segments took the direct path: staged {} of {}",
        m.staged_batches,
        m.update_batches
    );
    drop(m);
    c.shutdown();
}

#[test]
fn commit_conflict_fallback_is_exact_through_the_public_api() {
    // The prepared work races a conflicting writer (another update
    // batch, then separately a re-shard): the commit must detect it,
    // fall back to the direct path, and end bit-identical to applying
    // the batches in commit order.
    let _guard = serial();
    let mut rng = Rng::new(56);
    let xs: Vec<f32> = (0..512).map(|_| rng.f32()).collect();
    let engine = ShardedEngine::new(ShardedRmq::with_options(
        &xs,
        ShardedOptions { block_size: 32, ..Default::default() },
    ));
    let mut oracle = xs.clone();
    // Conflicting update batch between stage and commit.
    let staged_batch = vec![(40usize, -1.0f32), (41, 0.75), (300, -0.5)];
    let prep = engine.prepare_update_batch(&staged_batch, 2);
    let conflict = vec![(41usize, -2.0f32), (100, -3.0)];
    rtxrmq::coordinator::engine::Engine::update_batch(&engine, &conflict, 2).unwrap();
    assert_eq!(engine.commit_prepared(prep, 2), CommitOutcome::FellBack);
    for &(i, v) in conflict.iter().chain(&staged_batch) {
        oracle[i] = v;
    }
    assert_eq!(engine.seq(), 2, "both batches bumped the seq once each");
    let queries: Vec<(u32, u32)> = (0..200)
        .map(|_| {
            let l = rng.range(0, 511);
            (l as u32, rng.range(l, 511) as u32)
        })
        .collect();
    let got = rtxrmq::coordinator::engine::Engine::solve(&engine, &queries, 2).unwrap();
    for (k, &(l, r)) in queries.iter().enumerate() {
        assert_eq!(got[k] as usize, naive_rmq(&oracle, l as usize, r as usize), "({l},{r})");
    }
    // Re-shard between stage and commit: values unchanged, shape moved.
    let prep = engine.prepare_update_batch(&[(7, -9.0)], 2);
    assert!(engine.reshard(8), "quiet re-shard installs");
    assert_eq!(engine.commit_prepared(prep, 2), CommitOutcome::FellBack);
    oracle[7] = -9.0;
    assert_eq!(
        rtxrmq::coordinator::engine::Engine::solve(&engine, &[(0, 511)], 1).unwrap(),
        vec![7],
        "post-reshard fallback applied the batch"
    );
}

#[test]
fn epoch_swap_during_overlapped_prepare_stays_exact() {
    // Background rebuilds and re-shards publish at arbitrary points
    // while the pipelined executor has prepares in flight: busy mixed
    // phase (stale epoch, staged fences), then a quiet phase with
    // sporadic updates so rebuilds/re-shards land *between* staged
    // commits. Every answer must match the sequential oracle and at
    // least one background publish must actually have happened.
    let _guard = serial();
    let n = 1usize << 14;
    let xs = gen_array(n, 57);
    let mut oracle = xs.clone();
    let c = Coordinator::start(
        &xs,
        None,
        CoordinatorCfg {
            policy: Policy::Heuristic,
            engines: EngineCfg {
                shard_block: ShardBlock::Auto { dist: RangeDist::Small, update_frac: 0.3 },
            },
            lifecycle: LifecycleCfg { observer_half_life: 2.0, ..Default::default() },
            ..Default::default()
        },
    );
    let mut rng = Rng::new(58);
    // Busy phase: fence-heavy mixed streams keep prepares in flight.
    for round in 0..6 {
        let ops = fence_heavy_ops(n, 64, None, &mut rng);
        let want = oracle_run(&mut oracle, &ops);
        let resp = c.submit_mixed(ops).unwrap();
        assert_eq!(resp.answers, want, "busy round {round}");
    }
    // Shifted, mostly-quiet phase: large-range queries drive the tuner
    // (re-shard pressure) and decay the update rate (rebuild pressure),
    // while an occasional staged update keeps the overlap lane hot.
    let mut publishes = 0u64;
    for round in 0..400 {
        let mut ops: Vec<Op> =
            gen_queries(n, 24, RangeDist::Large, &mut rng).into_iter().map(Op::Query).collect();
        if round % 5 == 0 {
            let i = rng.range(0, n - 1);
            ops.push(Op::Update { i: i as u32, v: rng.f32() });
            ops.push(Op::Query((0, (n - 1) as u32)));
        }
        let want = oracle_run(&mut oracle, &ops);
        let resp = c.submit_mixed(ops).unwrap();
        assert_eq!(resp.answers, want, "quiet round {round} via {}", resp.engine);
        publishes = c.lifecycle.rebuilds() + c.lifecycle.reshards();
        if publishes >= 2 {
            break;
        }
    }
    assert!(publishes >= 1, "no background publish landed during the pipelined stream");
    let m = c.metrics.lock();
    assert!(m.staged_batches > 0);
    // Conflicted commits (a re-shard racing a staged prepare) are legal
    // — the fallback path absorbs them — but every answer above was
    // still exact.
    assert_eq!(m.staged_installed + m.staged_fallbacks, m.staged_batches);
    drop(m);
    c.shutdown();
}

#[test]
fn concurrent_mixed_clients_in_disjoint_regions() {
    // Four clients each own a disjoint quarter of the array and confine
    // both their queries and updates to it. Each client's stream is then
    // sequentially consistent in isolation (other clients never touch
    // its region), so its answers must match its private oracle even
    // though the coordinator interleaves and fuses across clients.
    let _guard = serial();
    let n = 1 << 12;
    let region = n / 4;
    let xs = gen_array(n, 31);
    let c = std::sync::Arc::new(coordinator(&xs, ShardBlock::Fixed(64)));
    let xs = std::sync::Arc::new(xs);
    let mut handles = Vec::new();
    for t in 0..4usize {
        let c = c.clone();
        let xs = xs.clone();
        handles.push(std::thread::spawn(move || {
            let lo = t * region;
            let mut oracle: Vec<f32> = xs.as_ref().clone();
            let mut rng = Rng::new(200 + t as u64);
            for round in 0..10 {
                let mut ops = Vec::new();
                for _ in 0..40 {
                    if rng.f64() < 0.3 {
                        let i = lo + rng.range(0, region - 1);
                        ops.push(Op::Update { i: i as u32, v: rng.f32() });
                    } else {
                        let l = lo + rng.range(0, region - 1);
                        let r = rng.range(l, lo + region - 1);
                        ops.push(Op::Query((l as u32, r as u32)));
                    }
                }
                let want = oracle_run(&mut oracle, &ops);
                let resp = c.submit_mixed(ops).unwrap();
                assert_eq!(resp.answers, want, "client {t} round {round}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = c.metrics.lock();
    assert_eq!(m.requests, 40);
    assert!(m.updates > 0, "streams contained updates");
}

// ---------------------------------------------------------------------
// Chaos differentials: the same oracle contract, but with a seeded fault
// schedule armed. The guarantee under test is absorb-at-source — every
// injected panic/delay/forced-error is caught below the serving loop, so
// every *accepted* request's answers stay bit-identical to the
// sequential oracle, and the fault metrics record the recovery.
// ---------------------------------------------------------------------

#[test]
fn chaos_staging_lane_faults_keep_accepted_answers_exact() {
    let _guard = serial();
    // The schedule kills the staged-prepare worker twice, delays it
    // twice, kills one per-block spec build, forces commit conflicts
    // (the err form — panic at commit is rejected by the parser), and
    // sprinkles pool-worker panics. All deterministic from the seed.
    let arm = faults::arm_guard(
        FaultPlan::parse(
            "stage.prepare:panic:1.0:2,stage.prepare:delay2:1.0:2,\
             stage.build:panic:1.0:1,stage.commit:err:0.5:3,pool.worker:panic:0.2:4",
            4242,
        )
        .unwrap(),
    );
    let n = 1 << 12;
    let xs = gen_array(n, 60);
    let mut oracle = xs.clone();
    let c = coordinator(&xs, ShardBlock::Fixed(64));
    let mut rng = Rng::new(61);
    for round in 0..12 {
        let ops = fence_heavy_ops(n, 64, None, &mut rng);
        let want = oracle_run(&mut oracle, &ops);
        let resp = c.submit_mixed(ops).unwrap();
        assert_eq!(resp.answers, want, "chaos round {round}");
    }
    c.sync_faults();
    let m = c.metrics.lock();
    assert!(m.injected_faults >= 5, "the schedule must actually fire: {m}");
    assert!(m.caught_panics >= 1, "injected panics were caught, not propagated");
    assert!(m.degraded_fallbacks >= 1, "a dead staged prepare fell back to the direct path");
    assert!(m.to_string().contains("injected="), "faults line surfaces in the report: {m}");
    drop(m);
    drop(arm); // disarm before shutdown so teardown runs clean
    c.shutdown();
}

#[test]
fn chaos_builder_panic_respawns_and_the_rebuild_still_lands() {
    let _guard = serial();
    // The first background rebuild job panics at `build.statics`; the
    // builder thread must respawn, the lifecycle must reschedule, and
    // the retry must publish a fresh epoch — self-healing end to end.
    let arm = faults::arm_guard(FaultPlan::parse("build.statics:panic:1.0:1", 7).unwrap());
    let n = 1usize << 15;
    let xs = gen_array(n, 62);
    let mut oracle = xs.clone();
    let c = Coordinator::start(
        &xs,
        None,
        CoordinatorCfg {
            policy: Policy::Heuristic,
            engines: EngineCfg { shard_block: ShardBlock::Sqrt },
            lifecycle: LifecycleCfg { observer_half_life: 4.0, ..Default::default() },
            ..Default::default()
        },
    );
    let mut rng = Rng::new(63);
    // Busy mixed phase: make the static engines stale.
    for round in 0..6 {
        let ops = gen_mixed(n, 64, 0.3, RangeDist::Small, &mut rng);
        let want = oracle_run(&mut oracle, &ops);
        let resp = c.submit_mixed(ops).unwrap();
        assert_eq!(resp.answers, want, "busy round {round}");
    }
    // Quiet phase: the first scheduled rebuild dies to the injected
    // panic; keep serving until the respawned builder's retry lands.
    let mut fired = false;
    for round in 0..900 {
        let qs = gen_queries(n, 64, RangeDist::Small, &mut rng);
        let resp = c.query(qs.clone()).unwrap();
        for (k, &(l, r)) in qs.iter().take(2).enumerate() {
            assert_eq!(
                resp.answers[k],
                naive_rmq(&oracle, l as usize, r as usize) as u32,
                "quiet round {round} ({l},{r}) via {}",
                resp.engine
            );
        }
        if c.lifecycle.rebuilds() >= 1 {
            fired = true;
            break;
        }
    }
    assert!(fired, "the rebuild must land after the injected builder panic");
    c.sync_faults();
    let m = c.metrics.lock();
    assert_eq!(m.builder_respawns, 1, "the injected panic killed exactly one job: {m}");
    assert!(m.caught_panics >= 1);
    assert!(m.injected_faults >= 1);
    drop(m);
    drop(arm);
    c.shutdown();
}

#[test]
fn chaos_handoff_fault_rejects_the_group_whole_and_serving_continues() {
    let _guard = serial();
    // A panic at the batcher hand-off drops the pulled group before any
    // segment executes: its submitters see a rejection (closed reply
    // channel), never a partial effect — so the oracle simply skips the
    // rejected stream, and later requests serve normally.
    let arm = faults::arm_guard(FaultPlan::parse("batcher.handoff:panic:1.0:1", 9).unwrap());
    let n = 1 << 10;
    let xs = gen_array(n, 64);
    let mut oracle = xs.clone();
    let c = coordinator(&xs, ShardBlock::Fixed(32));
    let mut rng = Rng::new(65);
    let (mut served, mut rejected) = (0u32, 0u32);
    for round in 0..6 {
        let ops = fence_heavy_ops(n, 32, None, &mut rng);
        match c.submit_mixed(ops.clone()) {
            Ok(resp) => {
                // Accepted: must be exact, and the oracle advances.
                let want = oracle_run(&mut oracle, &ops);
                assert_eq!(resp.answers, want, "round {round}");
                served += 1;
            }
            Err(_) => rejected += 1, // rejected whole: oracle untouched
        }
    }
    assert_eq!(rejected, 1, "exactly the first pulled group died to the injected fault");
    assert_eq!(served, 5, "serving continued after the caught panic");
    c.sync_faults();
    let m = c.metrics.lock();
    assert!(m.caught_panics >= 1);
    assert!(m.degraded_fallbacks >= 1, "the lost group is counted as a degraded event");
    drop(m);
    drop(arm);
    c.shutdown();
}

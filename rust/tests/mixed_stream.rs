//! Mixed query+update streams through the full coordinator stack,
//! differentially tested against a naive array + rescan oracle.
//!
//! The consistency contract under test (the fence): updates between two
//! query chunks must be visible to the later chunk and invisible to the
//! earlier one — exactly the answers a sequential re-solve of the op
//! stream produces, leftmost ties included.

use rtxrmq::coordinator::engine::{EngineCfg, ShardBlock};
use rtxrmq::coordinator::router::Policy;
use rtxrmq::coordinator::server::{Coordinator, CoordinatorCfg};
use rtxrmq::rmq::naive_rmq;
use rtxrmq::util::rng::Rng;
use rtxrmq::workload::{gen_array, gen_mixed, Op, RangeDist};

/// The oracle: apply the op stream to a plain array, answering queries
/// by rescan — the sequential semantics the coordinator must reproduce.
fn oracle_run(xs: &mut [f32], ops: &[Op]) -> Vec<u32> {
    let mut out = Vec::new();
    for op in ops {
        match *op {
            Op::Query((l, r)) => out.push(naive_rmq(xs, l as usize, r as usize) as u32),
            Op::Update { i, v } => xs[i as usize] = v,
        }
    }
    out
}

fn coordinator(xs: &[f32], shard_block: ShardBlock) -> Coordinator {
    Coordinator::start(
        xs,
        None,
        CoordinatorCfg {
            policy: Policy::ModeledCost,
            engines: EngineCfg { shard_block },
            ..Default::default()
        },
    )
}

#[test]
fn gen_mixed_streams_match_oracle_hit_for_hit() {
    let n = 1 << 12;
    let xs = gen_array(n, 21);
    let mut oracle = xs.clone();
    let c = coordinator(&xs, ShardBlock::Fixed(64));
    let mut rng = Rng::new(22);
    for round in 0..10 {
        let ops = gen_mixed(n, 96, 0.3, RangeDist::Small, &mut rng);
        let want = oracle_run(&mut oracle, &ops);
        let resp = c.submit_mixed(ops.clone()).unwrap();
        assert_eq!(resp.answers, want, "round {round}");
        assert_eq!(resp.updates_applied, ops.iter().filter(|o| o.is_update()).count());
    }
    c.shutdown();
}

#[test]
fn duplicate_heavy_streams_keep_leftmost_ties() {
    // Quantised values force constant ties between the left partial,
    // summary and right partial probes — and between pre- and
    // post-update values.
    let n = 1 << 11;
    let xs: Vec<f32> = gen_array(n, 23).iter().map(|v| (v * 4.0).floor() / 4.0).collect();
    let mut oracle = xs.clone();
    let c = coordinator(&xs, ShardBlock::Fixed(32));
    let mut rng = Rng::new(24);
    for round in 0..8 {
        // Updates drawn from the same quantised palette keep ties alive.
        let ops: Vec<Op> = gen_mixed(n, 80, 0.4, RangeDist::Medium, &mut rng)
            .into_iter()
            .map(|op| match op {
                Op::Update { i, v } => Op::Update { i, v: (v * 4.0).floor() / 4.0 },
                q => q,
            })
            .collect();
        let want = oracle_run(&mut oracle, &ops);
        let resp = c.submit_mixed(ops).unwrap();
        assert_eq!(resp.answers, want, "round {round}");
    }
    c.shutdown();
}

#[test]
fn update_bursts_straddling_block_seams() {
    // Bursts land exactly on the block seams (last index of block b,
    // first of b+1), fenced between query chunks whose ranges straddle
    // the same seams — the decomposition's worst case.
    let n = 1024usize;
    let bs = 64usize;
    let xs = gen_array(n, 25);
    let mut oracle = xs.clone();
    let c = coordinator(&xs, ShardBlock::Fixed(bs));
    let mut rng = Rng::new(26);
    for round in 0..6 {
        let mut ops = Vec::new();
        for b in 1..(n / bs) {
            let seam = b * bs;
            ops.push(Op::Query(((seam - 5) as u32, (seam + 5) as u32)));
            ops.push(Op::Update { i: (seam - 1) as u32, v: rng.f32() });
            ops.push(Op::Update { i: seam as u32, v: rng.f32() });
            ops.push(Op::Query(((seam - 5) as u32, (seam + 5) as u32)));
            ops.push(Op::Query((0, (n - 1) as u32)));
        }
        let want = oracle_run(&mut oracle, &ops);
        let resp = c.submit_mixed(ops).unwrap();
        assert_eq!(resp.answers, want, "round {round}");
    }
    c.shutdown();
}

#[test]
fn back_to_back_batches_touching_the_same_block() {
    // Consecutive requests hammer one block (refit-after-refit on the
    // same BVH) with full-range reads fencing each burst.
    let n = 512usize;
    let xs = gen_array(n, 27);
    let mut oracle = xs.clone();
    let c = coordinator(&xs, ShardBlock::Fixed(64));
    let mut rng = Rng::new(28);
    for round in 0..12 {
        let block = 3usize; // always the same block
        let mut ops = Vec::new();
        for _ in 0..6 {
            let i = block * 64 + rng.range(0, 63);
            ops.push(Op::Update { i: i as u32, v: rng.f32() });
        }
        ops.push(Op::Query((0, (n - 1) as u32)));
        ops.push(Op::Query(((block * 64) as u32, (block * 64 + 63) as u32)));
        let want = oracle_run(&mut oracle, &ops);
        let resp = c.submit_mixed(ops).unwrap();
        assert_eq!(resp.answers, want, "round {round}");
    }
    c.shutdown();
}

#[test]
fn auto_tuned_shard_block_serves_mixed_streams() {
    // `--shard-block auto` end to end: the tuner picks the block size,
    // the stream still matches the oracle hit for hit.
    let n = 1 << 12;
    let xs = gen_array(n, 29);
    let mut oracle = xs.clone();
    let c = coordinator(&xs, ShardBlock::Auto { dist: RangeDist::Small, update_frac: 0.25 });
    let mut rng = Rng::new(30);
    for round in 0..6 {
        let ops = gen_mixed(n, 128, 0.25, RangeDist::Small, &mut rng);
        let want = oracle_run(&mut oracle, &ops);
        let resp = c.submit_mixed(ops).unwrap();
        assert_eq!(resp.answers, want, "round {round}");
    }
    c.shutdown();
}

#[test]
fn concurrent_mixed_clients_in_disjoint_regions() {
    // Four clients each own a disjoint quarter of the array and confine
    // both their queries and updates to it. Each client's stream is then
    // sequentially consistent in isolation (other clients never touch
    // its region), so its answers must match its private oracle even
    // though the coordinator interleaves and fuses across clients.
    let n = 1 << 12;
    let region = n / 4;
    let xs = gen_array(n, 31);
    let c = std::sync::Arc::new(coordinator(&xs, ShardBlock::Fixed(64)));
    let xs = std::sync::Arc::new(xs);
    let mut handles = Vec::new();
    for t in 0..4usize {
        let c = c.clone();
        let xs = xs.clone();
        handles.push(std::thread::spawn(move || {
            let lo = t * region;
            let mut oracle: Vec<f32> = xs.as_ref().clone();
            let mut rng = Rng::new(200 + t as u64);
            for round in 0..10 {
                let mut ops = Vec::new();
                for _ in 0..40 {
                    if rng.f64() < 0.3 {
                        let i = lo + rng.range(0, region - 1);
                        ops.push(Op::Update { i: i as u32, v: rng.f32() });
                    } else {
                        let l = lo + rng.range(0, region - 1);
                        let r = rng.range(l, lo + region - 1);
                        ops.push(Op::Query((l as u32, r as u32)));
                    }
                }
                let want = oracle_run(&mut oracle, &ops);
                let resp = c.submit_mixed(ops).unwrap();
                assert_eq!(resp.answers, want, "client {t} round {round}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = c.metrics.lock().unwrap();
    assert_eq!(m.requests, 40);
    assert!(m.updates > 0, "streams contained updates");
}

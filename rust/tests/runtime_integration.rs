//! Cross-language integration: execute the AOT artifacts (lowered from
//! the L2 JAX graphs calling L1 Pallas kernels) through the PJRT runtime
//! and check the answers against the Rust sparse-table oracle.
//!
//! Requires `make artifacts` AND a real `xla` bindings crate (see
//! `rust/vendor/xla`). When either is missing, `Runtime::load` fails and
//! every test here skips — the pure-Rust engines are covered by the rest
//! of the suite regardless.

use rtxrmq::rmq::sparse_table::SparseTable;
use rtxrmq::rmq::RmqSolver;
use rtxrmq::runtime::{Runtime, VariantKind};
use rtxrmq::util::rng::Rng;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Load the runtime, or None when the PJRT backend / artifacts are
/// unavailable (in which case the calling test skips). Set
/// `RTXRMQ_REQUIRE_PJRT=1` on hosts that have the real backend to turn
/// a silent skip into a hard failure (guards against these suites
/// going permanently vacuously green).
fn runtime() -> Option<Runtime> {
    match Runtime::load(&artifacts_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            if std::env::var_os("RTXRMQ_REQUIRE_PJRT").is_some() {
                panic!("RTXRMQ_REQUIRE_PJRT set but runtime failed to load: {e}");
            }
            eprintln!("skipping PJRT integration test: {e}");
            None
        }
    }
}

fn queries(rng: &mut Rng, n: usize, count: usize) -> Vec<(u32, u32)> {
    (0..count)
        .map(|_| {
            let l = rng.range(0, n - 1);
            let r = rng.range(l, n - 1);
            (l as u32, r as u32)
        })
        .collect()
}

#[test]
fn manifest_lists_expected_kinds() {
    let Some(rt) = runtime() else { return };
    let kinds: Vec<VariantKind> = rt.variants().map(|v| v.kind).collect();
    assert!(kinds.contains(&VariantKind::Exhaustive));
    assert!(kinds.contains(&VariantKind::Block));
}

#[test]
fn exhaustive_artifact_matches_oracle() {
    let Some(rt) = runtime() else { return };
    let v = rt
        .variants()
        .find(|v| v.kind == VariantKind::Exhaustive)
        .expect("exhaustive variant")
        .clone();
    let mut rng = Rng::new(0xA11CE);
    let n = v.n; // exact fit
    let xs = rng.uniform_f32_vec(n);
    let qs = queries(&mut rng, n, v.q);
    let out = rt.exec_rmq(&v.name, &xs, &qs).unwrap();
    let st = SparseTable::new(&xs);
    for (i, &(l, r)) in qs.iter().enumerate() {
        let want = st.rmq(l, r);
        assert_eq!(out.args[i] as u32, want, "query {i} = ({l},{r})");
        assert_eq!(out.mins[i], xs[want as usize]);
    }
}

#[test]
fn block_artifact_matches_oracle_with_padding() {
    let Some(rt) = runtime() else { return };
    let v = rt
        .variants()
        .find(|v| v.kind == VariantKind::Block)
        .expect("block variant")
        .clone();
    let mut rng = Rng::new(0xB0B);
    // Deliberately smaller than the variant's static n: exercises +inf
    // padding of both the array and the query batch.
    let n = v.n - v.bs / 2 - 3;
    let xs = rng.uniform_f32_vec(n);
    let qs = queries(&mut rng, n, v.q / 2 + 1);
    let out = rt.exec_rmq(&v.name, &xs, &qs).unwrap();
    assert_eq!(out.args.len(), qs.len());
    let st = SparseTable::new(&xs);
    for (i, &(l, r)) in qs.iter().enumerate() {
        let want = st.rmq(l, r);
        assert_eq!(out.args[i] as u32, want, "query {i} = ({l},{r}) n={n}");
    }
}

#[test]
fn block_artifact_handles_duplicates_leftmost() {
    let Some(rt) = runtime() else { return };
    let v = rt.variants().find(|v| v.kind == VariantKind::Block).unwrap().clone();
    let mut rng = Rng::new(0xD0D);
    let n = v.n;
    // Few distinct values -> heavy ties; kernel must stay leftmost.
    let xs: Vec<f32> = (0..n).map(|_| rng.below(3) as f32).collect();
    let qs = queries(&mut rng, n, v.q);
    let out = rt.exec_rmq(&v.name, &xs, &qs).unwrap();
    let st = SparseTable::new(&xs);
    for (i, &(l, r)) in qs.iter().enumerate() {
        assert_eq!(out.args[i] as u32, st.rmq(l, r), "query {i} = ({l},{r})");
    }
}

#[test]
fn blockmin_artifact_matches_scan() {
    let Some(rt) = runtime() else { return };
    let Some(v) = rt.variants().find(|v| v.kind == VariantKind::BlockMin).cloned() else {
        // quick artifact sets may omit it
        return;
    };
    let mut rng = Rng::new(0xE0E);
    let xs = rng.uniform_f32_vec(v.n);
    let out = rt.exec_blockmin(&v.name, &xs).unwrap();
    let nb = v.n / v.bs;
    assert_eq!(out.mins.len(), nb);
    for b in 0..nb {
        let block = &xs[b * v.bs..(b + 1) * v.bs];
        let mut arg = 0usize;
        for (k, &x) in block.iter().enumerate() {
            if x < block[arg] {
                arg = k;
            }
        }
        assert_eq!(out.mins[b], block[arg], "block {b}");
        assert_eq!(out.args[b] as usize, b * v.bs + arg, "block {b}");
    }
}

#[test]
fn oversize_inputs_are_rejected() {
    let Some(rt) = runtime() else { return };
    let v = rt.variants().find(|v| v.kind == VariantKind::Exhaustive).unwrap().clone();
    let xs = vec![0.0f32; v.n + 1];
    assert!(rt.exec_rmq(&v.name, &xs, &[(0, 0)]).is_err());
    let xs = vec![0.0f32; 8];
    let too_many = vec![(0u32, 1u32); v.q + 1];
    assert!(rt.exec_rmq(&v.name, &xs, &too_many).is_err());
}

#[test]
fn select_variant_prefers_smallest_fit() {
    let Some(rt) = runtime() else { return };
    let v = rt.select_rmq_variant(100).expect("some variant fits");
    assert!(v.n >= 100);
    let all_fit: Vec<usize> =
        rt.variants().filter(|x| x.q > 0 && x.n >= 100).map(|x| x.n).collect();
    assert_eq!(v.n, *all_fit.iter().min().unwrap());
}

//! Fig. 14 — performance scaling of RTXRMQ and LCA across GPU
//! generations (Turing → Ampere → Lovelace) plus the projected next
//! generation, for Large/Medium/Small ranges. The paper's finding:
//! RTXRMQ scales near-exponentially with the RT-core generation factor,
//! LCA only with CUDA throughput, so the projection narrows (L), flips
//! (M) and widens RTXRMQ's lead (S). Emits `results/fig14_arch.csv`.

use rtxrmq::bench_harness::{print_table, BenchCfg};
use rtxrmq::bench_harness::runner::Suite;
use rtxrmq::rtcore::arch::generations;
use rtxrmq::util::csv::{fnum, CsvWriter};
use rtxrmq::util::rng::Rng;
use rtxrmq::workload::{gen_queries, RangeDist};

fn main() {
    let cfg = BenchCfg::from_env();
    let mut rng = Rng::new(cfg.seed);
    let n = cfg.max_n;
    let suite = Suite::build(n, cfg.seed);
    let mut csv = CsvWriter::create(
        cfg.out_dir.join("fig14_arch.csv"),
        &["arch", "dist", "rtx_ns", "lca_ns"],
    )
    .unwrap();

    let mut rows = Vec::new();
    // ratios[dist] = (rtx series, lca series) across generations.
    let mut series: Vec<(Vec<f64>, Vec<f64>)> = vec![(vec![], vec![]); 3];
    for (di, dist) in RangeDist::all().into_iter().enumerate() {
        let qs = gen_queries(n, cfg.sample_queries, dist, &mut rng);
        for gpu in generations() {
            let p = suite.measure_point_on(&qs, cfg.model_batch, &gpu, cfg.workers);
            csv.row(&[gpu.name.to_string(), dist.name().to_string(), fnum(p.rtx_ns), fnum(p.lca_ns)])
                .unwrap();
            rows.push(vec![
                gpu.name.to_string(),
                dist.name().to_string(),
                fnum(p.rtx_ns),
                fnum(p.lca_ns),
                format!("{:.2}x", p.lca_ns / p.rtx_ns),
            ]);
            series[di].0.push(p.rtx_ns);
            series[di].1.push(p.lca_ns);
        }
    }
    csv.flush().unwrap();
    print_table(
        "Fig 14: RTXRMQ vs LCA across GPU generations (last = projected)",
        &["architecture", "dist", "RTX ns", "LCA ns", "RTX advantage"],
        &rows,
    );

    // Scaling-rate check: RTXRMQ's generational improvement factor must
    // exceed LCA's (the paper's core scaling claim).
    for (di, dist) in RangeDist::all().into_iter().enumerate() {
        let (rtx, lca) = &series[di];
        let rtx_rate = rtx.first().unwrap() / rtx.last().unwrap();
        let lca_rate = lca.first().unwrap() / lca.last().unwrap();
        println!(
            "  [{}] Turing->projected speedup: RTXRMQ {:.1}x vs LCA {:.1}x -> RT scales faster: {}",
            dist.name(),
            rtx_rate,
            lca_rate,
            rtx_rate > lca_rate
        );
    }
    // Projection outcome for the medium range: RTXRMQ should overtake
    // LCA on the projected part (paper §6.5).
    let (rtx_m, lca_m) = &series[1];
    println!(
        "  medium-range projected winner: {} (paper projects RTXRMQ)",
        if rtx_m.last() < lca_m.last() { "RTXRMQ" } else { "LCA" }
    );
}

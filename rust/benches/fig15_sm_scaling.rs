//! Fig. 15 — scaling within one architecture (Lovelace) across SM
//! counts: RTX 4070 Ti (60 SMs) → 4080 (76) → 4090 (128) → 6000 Ada
//! (142). Paper finding: RTXRMQ scales ~linearly with SMs; LCA scales up
//! to the 4090 but *drops* on the 142-SM part (its 96 MB L2 is shared by
//! more SMs per byte of bandwidth — we model the plateau via saturation
//! + cache pressure). Emits `results/fig15_sm.csv`.

use rtxrmq::bench_harness::{print_table, BenchCfg};
use rtxrmq::bench_harness::runner::Suite;
use rtxrmq::rtcore::arch::lovelace_skus;
use rtxrmq::util::csv::{fnum, CsvWriter};
use rtxrmq::util::rng::Rng;
use rtxrmq::workload::{gen_queries, RangeDist};

fn main() {
    let cfg = BenchCfg::from_env();
    let mut rng = Rng::new(cfg.seed);
    let n = cfg.max_n;
    let suite = Suite::build(n, cfg.seed);
    let mut csv = CsvWriter::create(
        cfg.out_dir.join("fig15_sm.csv"),
        &["sku", "sms", "dist", "rtx_ns", "lca_ns", "rtx_throughput_rel"],
    )
    .unwrap();

    let skus = lovelace_skus();
    let mut rows = Vec::new();
    for dist in RangeDist::all() {
        let qs = gen_queries(n, cfg.sample_queries, dist, &mut rng);
        let mut base_rtx = None;
        for gpu in skus {
            let p = suite.measure_point_on(&qs, cfg.model_batch, &gpu, cfg.workers);
            let base = *base_rtx.get_or_insert(p.rtx_ns * gpu.sm_count as f64);
            // Relative RTX throughput per SM-normalized baseline: ~1.0
            // everywhere iff scaling is linear in SMs.
            let rel = base / (p.rtx_ns * gpu.sm_count as f64);
            csv.row(&[
                gpu.name.to_string(),
                gpu.sm_count.to_string(),
                dist.name().to_string(),
                fnum(p.rtx_ns),
                fnum(p.lca_ns),
                fnum(rel),
            ])
            .unwrap();
            rows.push(vec![
                gpu.name.to_string(),
                gpu.sm_count.to_string(),
                dist.name().to_string(),
                fnum(p.rtx_ns),
                fnum(p.lca_ns),
                format!("{rel:.3}"),
            ]);
        }
    }
    csv.flush().unwrap();
    print_table(
        "Fig 15: Lovelace SM scaling (rtx_throughput_rel ~ 1.0 == linear in SMs)",
        &["SKU", "SMs", "dist", "RTX ns", "LCA ns", "RTX linear-scaling ratio"],
        &rows,
    );
    println!("\nfig15: CSV written to {}", cfg.out_dir.join("fig15_sm.csv").display());
}

//! Fig. 12 — ns/RMQ for all approaches and speedup over HRMQ, under the
//! Large/Medium/Small (l,r) distributions (paper §6.4), sweeping n.
//! The headline numbers at n = 1e8: RTXRMQ ≈ 2.5×/4×/5× over HRMQ for
//! L/M/S; LCA ≈ 12.5×/8×/2.2×; RTXRMQ beats LCA only in the small
//! regime (~2.3×).
//!
//! The small-regime crossover requires paper-scale n (LCA's structures
//! leave the 96 MB L2 only past n ≈ 2^22), which exceeds the default CI
//! sweep — so after the measured sweep this driver prints a **paper-
//! scale extrapolation** row: measured per-query work extended to
//! n = 1e8 by its observed growth law (RTX traversal work ~ log n; LCA
//! structure bytes = 20n; HRMQ wall-clock × the CPU cache-regime
//! factor). Run with `--paper-scale` to push the measured sweep itself
//! to 2^24. Emits `results/fig12_<dist>.csv`.

use rtxrmq::bench_harness::{print_table, BenchCfg};
use rtxrmq::bench_harness::runner::Suite;
use rtxrmq::model::rtcost::saturation;
use rtxrmq::rtcore::arch::LOVELACE_RTX6000ADA;
use rtxrmq::util::csv::{fnum, CsvWriter};
use rtxrmq::util::rng::Rng;
use rtxrmq::workload::{gen_queries, RangeDist};

fn main() {
    let cfg = BenchCfg::from_env();
    let mut rng = Rng::new(cfg.seed);
    let gpu = LOVELACE_RTX6000ADA;
    let paper = [("large", 2.5, 12.5), ("medium", 4.0, 8.0), ("small", 5.0, 2.17)];
    let n_sweep = cfg.n_sweep();

    // Build each suite once, reuse across the three distributions.
    let suites: Vec<Suite> =
        n_sweep.iter().map(|&n| Suite::build(n, cfg.seed ^ n as u64)).collect();

    for (di, dist) in RangeDist::all().into_iter().enumerate() {
        let mut csv = CsvWriter::create(
            cfg.out_dir.join(format!("fig12_{}.csv", dist.name())),
            &["n", "rtx_ns", "lca_ns", "hrmq_ns", "exhaustive_ns", "rtx_speedup", "lca_speedup"],
        )
        .unwrap();
        let mut rows = Vec::new();
        let mut top: Option<(usize, f64, f64)> = None; // (n, rtx_work, hrmq_single_ns)
        for (si, &n) in n_sweep.iter().enumerate() {
            let suite = &suites[si];
            let qs = gen_queries(n, cfg.sample_queries, dist, &mut rng);
            suite.verify(&qs[..qs.len().min(64)], cfg.workers);
            let p = suite.measure_point(&qs, cfg.model_batch, cfg.workers);
            let (rtx_speedup, lca_speedup) = (p.hrmq_ns / p.rtx_ns, p.hrmq_ns / p.lca_ns);
            csv.row(&[
                n.to_string(),
                fnum(p.rtx_ns),
                fnum(p.lca_ns),
                fnum(p.hrmq_ns),
                fnum(p.exhaustive_ns),
                fnum(rtx_speedup),
                fnum(lca_speedup),
            ])
            .unwrap();
            rows.push(vec![
                format!("2^{}", n.trailing_zeros()),
                fnum(p.rtx_ns),
                fnum(p.lca_ns),
                fnum(p.hrmq_ns),
                fnum(p.exhaustive_ns),
                format!("{rtx_speedup:.2}x"),
                format!("{lca_speedup:.2}x"),
            ]);
            let hrmq_single = p.hrmq_ns * 192.0 * 0.75; // undo the host model
            top = Some((n, p.rtx_work, hrmq_single));
        }
        csv.flush().unwrap();
        print_table(
            &format!("Fig 12 [{} ranges]: ns/RMQ and speedup over HRMQ (measured sweep)", dist.name()),
            &["n", "RTXRMQ", "LCA", "HRMQ", "EXH", "RTX/HRMQ", "LCA/HRMQ"],
            &rows,
        );

        // ---- paper-scale extrapolation to n = 1e8 ----
        if let Some((n_top, rtx_work, hrmq_single)) = top {
            let n_paper = 1e8f64;
            let suite = suites.last().unwrap();
            // RTX: traversal work scales ~ log2(n) for the block scheme.
            let work = rtx_work * n_paper.log2() / (n_top as f64).log2();
            let util = saturation(cfg.model_batch, suite.rt_model.half_sat);
            let rtx_ns = work * suite.rt_model.ns_per_unit_ref / util;
            // LCA: structure bytes 20n; range factor at the paper
            // distribution's mean length at 1e8 (§6.4's growth laws:
            // small ~ n^0.3, medium ~ n^0.6, large ~ n/2).
            let mean_paper = dist.mean_len(n_paper as usize);
            let lca_ns = suite
                .lca_model
                .ns_per_query((n_paper * 20.0) as u64, cfg.model_batch, &gpu)
                * suite.lca_model.range_factor(mean_paper, n_paper as usize);
            // HRMQ: single-thread wall clock grows with the RAM-regime
            // factor (structure ~0.4 B/elem + 4 B/elem input leaves all
            // caches at 1e8).
            let cpu_factor = 3.0; // L2-resident -> RAM-resident dependent reads
            let hrmq_ns =
                suite.hrmq_model.ns_per_query(hrmq_single * cpu_factor, cfg.model_batch);
            let (_, p_rtx, p_lca) = paper[di];
            println!(
                "  extrapolated @n=1e8: RTX {:.1} ns ({:.1}x), LCA {:.1} ns ({:.1}x), HRMQ {:.1} ns | \
                 paper: RTX {p_rtx}x, LCA {p_lca}x | small-regime winner (RTX vs LCA): {}",
                rtx_ns,
                hrmq_ns / rtx_ns,
                lca_ns,
                hrmq_ns / lca_ns,
                hrmq_ns,
                if rtx_ns < lca_ns { "RTXRMQ" } else { "LCA" },
            );
        }
    }
    println!("\nfig12: CSVs written to {}", cfg.out_dir.display());
}

//! Fig. 16 — power time series for all approaches under the three range
//! distributions. The paper measures stable draw: RTXRMQ/EXHAUSTIVE at
//! the 300 W TDP, LCA at 200–240 W, HRMQ ≈ 600 W on the dual-EPYC host.
//! We model the run duration from measured work (q = model batch) and
//! synthesize the series. Emits `results/fig16_<dist>.csv`.

use rtxrmq::bench_harness::{print_table, BenchCfg};
use rtxrmq::bench_harness::runner::Suite;
use rtxrmq::model::EnergyModel;
use rtxrmq::rtcore::arch::{EPYC_9654_X2, LOVELACE_RTX6000ADA};
use rtxrmq::util::csv::{fnum, CsvWriter};
use rtxrmq::util::rng::Rng;
use rtxrmq::workload::{gen_queries, RangeDist};

fn main() {
    let cfg = BenchCfg::from_env();
    let mut rng = Rng::new(cfg.seed);
    let n = cfg.max_n;
    let suite = Suite::build(n, cfg.seed);
    let energy = EnergyModel::default();
    let gpu = LOVELACE_RTX6000ADA;

    let mut rows = Vec::new();
    for dist in RangeDist::all() {
        let qs = gen_queries(n, cfg.sample_queries, dist, &mut rng);
        let p = suite.measure_point(&qs, cfg.model_batch, cfg.workers);
        let q = cfg.model_batch as f64;
        let watts = [
            ("RTXRMQ", energy.gpu_watts(energy.util_rtx, &gpu), p.rtx_ns * q),
            ("LCA", energy.gpu_watts(energy.util_lca, &gpu), p.lca_ns * q),
            ("HRMQ", energy.cpu_watts(&EPYC_9654_X2), p.hrmq_ns * q),
            ("EXHAUSTIVE", energy.gpu_watts(energy.util_exhaustive, &gpu), p.exhaustive_ns * q),
        ];
        let mut csv = CsvWriter::create(
            cfg.out_dir.join(format!("fig16_{}.csv", dist.name())),
            &["approach", "t_s", "watts"],
        )
        .unwrap();
        for (name, w, total_ns) in watts {
            let duration_s = (total_ns * 1e-9).max(0.05);
            let series = energy.series(w, duration_s, 10.0, cfg.seed ^ w as u64);
            for (t, watt) in series.t_s.iter().zip(&series.watts) {
                csv.row(&[name.to_string(), fnum(*t), fnum(*watt)]).unwrap();
            }
            rows.push(vec![
                dist.name().to_string(),
                name.to_string(),
                format!("{w:.0} W"),
                format!("{:.2} s", duration_s),
                format!("{:.0} J", series.energy_j),
            ]);
        }
        csv.flush().unwrap();
    }
    print_table(
        "Fig 16: modeled steady power, duration and energy per full batch",
        &["dist", "approach", "draw", "duration", "energy"],
        &rows,
    );
    println!(
        "\nfig16: paper reference draws — RTXRMQ/EXH 300 W (TDP), LCA 200–240 W, HRMQ ~600 W; \
         series CSVs at {}",
        cfg.out_dir.display()
    );
}

//! Table 2 — memory usage of each approach's data structures, including
//! RTXRMQ's default vs compacted BVH. Paper reference (MB):
//!
//! | n     | input  | RTX default | RTX compacted | LCA    | HRMQ  |
//! | 2^10  | 0.004  | 0.07        | 0.06 (85%)    | 0.334  | 0.003 |
//! | 2^15  | 0.131  | 2.24        | 1.77 (79%)    | 0.55   | 0.01  |
//! | 2^20  | 4.19   | 71.63       | 56.28 (78%)   | 6.93   | 0.30  |
//! | 2^26  | 268.43 | 4512.15     | 3601.46 (79%) | 170.52 | 20.12 |
//!
//! Emits `results/table2_memory.csv` and prints measured-vs-paper rows.
//!
//! The sharded columns extend the paper's table with the two-level
//! engine's resident footprint: `sharded_rtx` keeps one wide BVH per
//! block, `sharded_inst` (the default backend) shares one shape tree
//! per block length and stores ~6 bytes of compressed leaf records per
//! element — ISSUE 7's acceptance gate (`inst × 4 ≤ rtx` at every n,
//! bit-identical answers) is asserted inline, so a soak run of this
//! bench at `--paper-scale` (n = 2^26) is the memory acceptance check.

use rtxrmq::bench_harness::{print_table, BenchCfg};
use rtxrmq::rmq::hrmq::Hrmq;
use rtxrmq::rmq::lca::LcaRmq;
use rtxrmq::rmq::rtx::RtxRmq;
use rtxrmq::rmq::sharded::{ShardBackend, ShardedOptions, ShardedRmq};
use rtxrmq::rmq::RmqSolver;
use rtxrmq::util::csv::CsvWriter;
use rtxrmq::util::rng::Rng;
use rtxrmq::workload::gen_array;

fn mb(bytes: usize) -> f64 {
    bytes as f64 / (1u64 << 20) as f64
}

fn main() {
    let cfg = BenchCfg::from_env();
    let paper: &[(usize, f64, f64, f64, f64)] = &[
        (1 << 10, 0.07, 0.06, 0.334, 0.003),
        (1 << 15, 2.24, 1.77, 0.55, 0.01),
        (1 << 20, 71.63, 56.28, 6.93, 0.30),
        (1 << 26, 4512.15, 3601.46, 170.52, 20.12),
    ];
    let mut csv = CsvWriter::create(
        cfg.out_dir.join("table2_memory.csv"),
        &[
            "n",
            "input_mb",
            "rtx_default_mb",
            "rtx_compacted_mb",
            "compaction_pct",
            "lca_mb",
            "hrmq_mb",
            "sharded_rtx_mb",
            "sharded_inst_mb",
            "inst_ratio",
        ],
    )
    .unwrap();
    let mut rows = Vec::new();
    for &(n, p_rtx, p_rtxc, p_lca, p_hrmq) in paper {
        if n > cfg.max_n && !cfg.paper_scale {
            println!("  (skipping n = 2^{} — pass --paper-scale)", n.trailing_zeros());
            continue;
        }
        let xs = gen_array(n, cfg.seed);
        let rtx = RtxRmq::new_auto(&xs);
        let (default_b, compact_b) = rtx.scene().bvh.optix_size_estimate(rtx.prim_count());
        let lca = LcaRmq::new(&xs);
        let hrmq = Hrmq::new(&xs);
        // Two-level sharded engine, per-block BVHs vs instanced blocks
        // (shared shape trees + compressed leaf records), at the auto
        // (√n) block size both would serve with.
        let sharded_rtx = ShardedRmq::with_options(
            &xs,
            ShardedOptions { backend: ShardBackend::Rtx, ..Default::default() },
        );
        let sharded_inst = ShardedRmq::with_options(
            &xs,
            ShardedOptions { backend: ShardBackend::Instanced, ..Default::default() },
        );
        let (rtx_b, inst_b) = (sharded_rtx.memory_bytes(), sharded_inst.memory_bytes());
        let pct = 100.0 * compact_b as f64 / default_b as f64;
        csv.row(&[
            n.to_string(),
            format!("{:.3}", mb(n * 4)),
            format!("{:.2}", mb(default_b)),
            format!("{:.2}", mb(compact_b)),
            format!("{pct:.0}"),
            format!("{:.3}", mb(lca.memory_bytes())),
            format!("{:.4}", mb(hrmq.memory_bytes())),
            format!("{:.3}", mb(rtx_b)),
            format!("{:.3}", mb(inst_b)),
            format!("{:.1}", rtx_b as f64 / inst_b as f64),
        ])
        .unwrap();
        rows.push(vec![
            format!("2^{}", n.trailing_zeros()),
            format!("{:.3}", mb(n * 4)),
            format!("{:.2} (paper {p_rtx})", mb(default_b)),
            format!("{:.2} ({pct:.0}%) (paper {p_rtxc})", mb(compact_b)),
            format!("{:.3} (paper {p_lca})", mb(lca.memory_bytes())),
            format!("{:.4} (paper {p_hrmq})", mb(hrmq.memory_bytes())),
            format!("{:.3}", mb(rtx_b)),
            format!("{:.3} ({:.1}x smaller)", mb(inst_b), rtx_b as f64 / inst_b as f64),
        ]);
        // Structural check (the paper's ordering must hold):
        assert!(hrmq.memory_bytes() < lca.memory_bytes());
        assert!(lca.memory_bytes() < default_b);
        assert!(compact_b < default_b);
        // ISSUE 7's memory acceptance: instanced blocks resident at
        // least 4x below per-block BVHs — at equal answers.
        assert!(
            inst_b * 4 <= rtx_b,
            "n={n}: instanced {inst_b} B not 4x below sharded-rtx {rtx_b} B"
        );
        let mut rng = Rng::new(cfg.seed ^ 0x7AB1E2);
        for _ in 0..64 {
            let l = rng.range(0, n - 1);
            let r = rng.range(l, n - 1);
            assert_eq!(
                sharded_inst.rmq(l as u32, r as u32),
                sharded_rtx.rmq(l as u32, r as u32),
                "n={n} ({l},{r}): instanced answer diverged"
            );
        }
    }
    csv.flush().unwrap();
    print_table(
        "Table 2: data-structure memory (MB), measured vs paper",
        &[
            "n",
            "input",
            "RTXRMQ default",
            "RTXRMQ compacted",
            "LCA",
            "HRMQ",
            "sharded rtx",
            "sharded inst",
        ],
        &rows,
    );
    println!(
        "\nNote: LCA paper numbers are Polak et al.'s Euler-tour structures; ours is the \
         Schieber–Vishkin form (~20 B/elem) — ordering and growth match, constants differ \
         (documented in DESIGN.md)."
    );
}

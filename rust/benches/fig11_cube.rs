//! Fig. 11 — RTXRMQ's 3D heat map over (n × |(l,r)| × #blocks), with
//! the Eq. 2 / OptiX-limit-invalid configurations filtered out exactly
//! as the paper filters its cube. Emits `results/fig11_cube.csv` and
//! prints, per (n, range), the optimal block count — the projection used
//! by Fig. 10's RTXRMQ map.

use rtxrmq::bench_harness::{print_table, BenchCfg};
use rtxrmq::bench_harness::runner::Suite;
use rtxrmq::geometry::precision::{valid_pow2_block_sizes, OptixLimits};
use rtxrmq::util::csv::{fnum, CsvWriter};
use rtxrmq::util::rng::Rng;

fn main() {
    let cfg = BenchCfg::from_env();
    let mut rng = Rng::new(cfg.seed);
    let mut csv = CsvWriter::create(
        cfg.out_dir.join("fig11_cube.csv"),
        &["n", "range_len", "block_size", "nb", "valid", "ns_per_rmq", "work_per_query"],
    )
    .unwrap();

    let limits = OptixLimits::default();
    let n_sweep: Vec<usize> =
        cfg.n_sweep().into_iter().filter(|&n| n <= (1 << 16).min(cfg.max_n)).collect();
    let mut best_rows: Vec<Vec<String>> = Vec::new();
    let mut total_cells = 0usize;
    let mut filtered_cells = 0usize;

    for &n in &n_sweep {
        // Block-size axis: every power of two up to n (invalid ones are
        // recorded as filtered, like the cube's cut-away region).
        let valid = valid_pow2_block_sizes(n, &limits);
        for y in [-1i32, -6, -12] {
            let len = ((n as f64) * (y as f64).exp2()).round().max(1.0) as usize;
            let queries: Vec<(u32, u32)> = (0..cfg.sample_queries.min(1024))
                .map(|_| {
                    let l = rng.range(0, n - len) as u32;
                    (l, (l as usize + len - 1) as u32)
                })
                .collect();
            let mut best: Option<(usize, f64)> = None;
            let mut bs = 2usize;
            while bs <= n {
                total_cells += 1;
                let nb = n.div_ceil(bs);
                if !valid.contains(&bs) {
                    filtered_cells += 1;
                    csv.row(&[
                        n.to_string(),
                        len.to_string(),
                        bs.to_string(),
                        nb.to_string(),
                        "0".into(),
                        String::new(),
                        String::new(),
                    ])
                    .unwrap();
                    bs <<= 2;
                    continue;
                }
                let suite = Suite::build_with_block_size(n, cfg.seed ^ n as u64, bs)
                    .expect("validated config");
                let (ns, work) =
                    suite.rtx_modeled_ns(&queries, cfg.model_batch, &rtxrmq::rtcore::arch::LOVELACE_RTX6000ADA, cfg.workers);
                csv.row(&[
                    n.to_string(),
                    len.to_string(),
                    bs.to_string(),
                    nb.to_string(),
                    "1".into(),
                    fnum(ns),
                    fnum(work),
                ])
                .unwrap();
                if best.map_or(true, |(_, b)| ns < b) {
                    best = Some((bs, ns));
                }
                bs <<= 2;
            }
            if let Some((bs, ns)) = best {
                best_rows.push(vec![
                    n.to_string(),
                    format!("n*2^{y}"),
                    bs.to_string(),
                    n.div_ceil(bs).to_string(),
                    fnum(ns),
                ]);
            }
        }
    }
    csv.flush().unwrap();

    print_table(
        "Fig 11: optimal block configuration per (n, range) cell",
        &["n", "range", "best_bs", "nb", "ns_per_rmq"],
        &best_rows,
    );
    println!(
        "\nfig11: {total_cells} cells, {filtered_cells} filtered by Eq.2/limits \
         (the paper's cut-away cube region); CSV at {}",
        cfg.out_dir.join("fig11_cube.csv").display()
    );
}

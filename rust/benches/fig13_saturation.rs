//! Fig. 13 — parallel saturation: ns/RMQ as the batch size grows from 1
//! to 2^26. Paper shape: LCA/HRMQ/EXHAUSTIVE saturate near 2^18 (LCA
//! with an L2-capacity dip near 2^17); RTXRMQ keeps improving through
//! 2^26. Per-query work is measured once per distribution; the batch
//! axis is the models' saturation term. Emits `results/fig13_<dist>.csv`.

use rtxrmq::bench_harness::{print_table, BenchCfg};
use rtxrmq::bench_harness::runner::Suite;
use rtxrmq::util::csv::{fnum, CsvWriter};
use rtxrmq::util::rng::Rng;
use rtxrmq::workload::{gen_queries, RangeDist};

fn main() {
    let cfg = BenchCfg::from_env();
    let mut rng = Rng::new(cfg.seed);
    let n = cfg.max_n;
    let suite = Suite::build(n, cfg.seed);
    let batches: Vec<u64> = (0..=26).step_by(2).map(|e| 1u64 << e).collect();

    for dist in RangeDist::all() {
        let qs = gen_queries(n, cfg.sample_queries, dist, &mut rng);
        suite.verify(&qs[..qs.len().min(64)], cfg.workers);
        let mut csv = CsvWriter::create(
            cfg.out_dir.join(format!("fig13_{}.csv", dist.name())),
            &["batch", "rtx_ns", "lca_ns", "hrmq_ns", "exhaustive_ns"],
        )
        .unwrap();
        let mut rows = Vec::new();
        let mut series: Vec<(u64, f64, f64)> = Vec::new();
        for &b in &batches {
            let p = suite.measure_point(&qs, b, cfg.workers);
            csv.row(&[
                b.to_string(),
                fnum(p.rtx_ns),
                fnum(p.lca_ns),
                fnum(p.hrmq_ns),
                fnum(p.exhaustive_ns),
            ])
            .unwrap();
            rows.push(vec![
                format!("2^{}", b.trailing_zeros()),
                fnum(p.rtx_ns),
                fnum(p.lca_ns),
                fnum(p.hrmq_ns),
                fnum(p.exhaustive_ns),
            ]);
            series.push((b, p.rtx_ns, p.lca_ns));
        }
        csv.flush().unwrap();
        print_table(
            &format!("Fig 13 [{} ranges]: ns/RMQ vs batch size (n = {n})", dist.name()),
            &["batch", "RTXRMQ", "LCA", "HRMQ", "EXH"],
            &rows,
        );
        // Saturation check: LCA gain from 2^18 -> 2^26 must be marginal,
        // RTXRMQ must still be improving (the paper's key observation).
        let at = |target: u64| series.iter().find(|&&(b, _, _)| b == target).copied();
        if let (Some((_, r18, l18)), Some((_, r26, l26))) = (at(1 << 18), at(1 << 26)) {
            let lca_gain = (l18 - l26) / l18;
            let rtx_gain = (r18 - r26) / r18;
            println!(
                "  saturation 2^18->2^26: LCA gain {:.1}% (paper: ~0), RTXRMQ gain {:.1}% \
                 (paper: still scaling) -> matches paper: {}",
                lca_gain * 100.0,
                rtx_gain * 100.0,
                rtx_gain > lca_gain
            );
        }
    }
    println!("\nfig13: CSVs written to {}", cfg.out_dir.display());
}

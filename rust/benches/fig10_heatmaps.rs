//! Fig. 10 — performance heat maps in (n × |(l,r)|) space for all four
//! approaches. Emits `results/fig10_<approach>.csv` with one row per
//! cell (blue = fast, yellow = slow in the paper's rendering) and prints
//! a compact ASCII map per approach.

use rtxrmq::bench_harness::{print_table, BenchCfg};
use rtxrmq::bench_harness::runner::Suite;
use rtxrmq::util::csv::{fnum, CsvWriter};
use rtxrmq::util::rng::Rng;

fn main() {
    let cfg = BenchCfg::from_env();
    let mut rng = Rng::new(cfg.seed);
    let approaches = ["RTXRMQ", "LCA", "HRMQ", "EXHAUSTIVE"];
    let mut writers: Vec<CsvWriter> = approaches
        .iter()
        .map(|a| {
            CsvWriter::create(
                cfg.out_dir.join(format!("fig10_{}.csv", a.to_lowercase())),
                &["n", "range_len", "y_exp", "ns_per_rmq"],
            )
            .unwrap()
        })
        .collect();

    // Per-approach grids for the ASCII rendering: grid[a][(ni, yi)] = ns.
    let n_sweep = cfg.n_sweep();
    let y_exps: Vec<i32> = (0..8).map(|k| -2 * k - 1).collect(); // 2^-1 .. 2^-15
    let mut grids = vec![vec![vec![f64::NAN; y_exps.len()]; n_sweep.len()]; 4];

    for (ni, &n) in n_sweep.iter().enumerate() {
        let suite = Suite::build(n, cfg.seed ^ n as u64);
        for (yi, &y) in y_exps.iter().enumerate() {
            let len = ((n as f64) * (y as f64).exp2()).round().max(1.0) as usize;
            let queries: Vec<(u32, u32)> = (0..cfg.sample_queries)
                .map(|_| {
                    let l = rng.range(0, n - len) as u32;
                    (l, (l as usize + len - 1) as u32)
                })
                .collect();
            suite.verify(&queries[..queries.len().min(64)], cfg.workers);
            let p = suite.measure_point(&queries, cfg.model_batch, cfg.workers);
            let ns = [p.rtx_ns, p.lca_ns, p.hrmq_ns, p.exhaustive_ns];
            for (a, &v) in ns.iter().enumerate() {
                grids[a][ni][yi] = v;
                writers[a]
                    .row(&[n.to_string(), len.to_string(), y.to_string(), fnum(v)])
                    .unwrap();
            }
        }
    }
    for w in &mut writers {
        w.flush().unwrap();
    }

    // ASCII heat maps (log-scaled shade per approach, like the paper's
    // per-plot color scales).
    for (a, name) in approaches.iter().enumerate() {
        println!("\n-- Fig 10 heat map: {name} (rows = |(l,r)| = n*2^y, cols = n; '.'=fast '#'=slow) --");
        let flat: Vec<f64> =
            grids[a].iter().flatten().copied().filter(|v| v.is_finite()).collect();
        let (lo, hi) = flat
            .iter()
            .fold((f64::INFINITY, 0.0f64), |(l, h), &v| (l.min(v.ln()), h.max(v.ln())));
        let shades = [b'.', b':', b'-', b'=', b'+', b'*', b'%', b'#'];
        for (yi, &y) in y_exps.iter().enumerate() {
            let mut line = String::new();
            for ni in 0..n_sweep.len() {
                let v = grids[a][ni][yi];
                let t = if hi > lo { (v.ln() - lo) / (hi - lo) } else { 0.0 };
                let idx = ((t * (shades.len() - 1) as f64).round() as usize).min(shades.len() - 1);
                line.push(shades[idx] as char);
            }
            println!("  y={y:>3}  {line}");
        }
    }

    // Headline check from the paper: for RTXRMQ at the largest n,
    // small/medium ranges must be faster than large ones; for LCA the
    // opposite holds.
    let ni = n_sweep.len() - 1;
    let rows = vec![
        vec![
            "RTXRMQ".into(),
            fnum(grids[0][ni][y_exps.len() - 1]),
            fnum(grids[0][ni][0]),
            (grids[0][ni][y_exps.len() - 1] < grids[0][ni][0]).to_string(),
        ],
        vec![
            "LCA".into(),
            fnum(grids[1][ni][y_exps.len() - 1]),
            fnum(grids[1][ni][0]),
            (grids[1][ni][y_exps.len() - 1] > grids[1][ni][0]).to_string(),
        ],
    ];
    print_table(
        "Fig 10 check at largest n (paper: RTX favors small ranges, LCA favors large)",
        &["approach", "ns@small", "ns@large", "matches_paper"],
        &rows,
    );
    println!("\nfig10: CSVs written to {}", cfg.out_dir.display());
}

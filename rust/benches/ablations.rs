//! Design-choice ablations (DESIGN.md §7):
//!
//! 1. **SAH vs LBVH builders** — traversal work on identical workloads
//!    (GPU builders are LBVH-family; how much work does that cost?).
//! 2. **Block minimums: acceleration structure vs lookup table** — the
//!    paper reports the AS was faster (§5.3); we replay both, with the
//!    LUT's O(nb²) memory cost made explicit.
//! 3. **Flat vs block-matrix geometry** — the §5.2→§5.3 motivation: the
//!    flat layout's traversal work grows superlinearly for rays that hit
//!    far triangles.
//!
//! Emits `results/ablations.csv`.

use rtxrmq::bench_harness::{print_table, BenchCfg};
use rtxrmq::bvh::Builder;
use rtxrmq::model::RtCostModel;
use rtxrmq::rmq::rtx::{RtxMode, RtxOptions, RtxRmq};
use rtxrmq::rmq::sparse_table::SparseTable;
use rtxrmq::rmq::RmqSolver;
use rtxrmq::util::csv::{fnum, CsvWriter};
use rtxrmq::util::rng::Rng;
use rtxrmq::workload::{gen_array, gen_queries, RangeDist};

fn main() {
    let cfg = BenchCfg::from_env();
    let mut rng = Rng::new(cfg.seed);
    let n = cfg.max_n.min(1 << 16);
    let xs = gen_array(n, cfg.seed);
    let bs = (n as f64).sqrt() as usize;
    let queries = gen_queries(n, cfg.sample_queries, RangeDist::Medium, &mut rng);
    let model = RtCostModel::default();
    let mut csv = CsvWriter::create(
        cfg.out_dir.join("ablations.csv"),
        &["ablation", "variant", "work_per_query", "extra_mem_mb"],
    )
    .unwrap();
    let mut rows = Vec::new();

    // 1. SAH vs LBVH.
    for builder in [Builder::BinnedSah, Builder::Lbvh] {
        let rtx = RtxRmq::with_options(
            &xs,
            RtxOptions {
                mode: RtxMode::Blocks { block_size: bs },
                builder,
                leaf_size: 4,
                ..Default::default()
            },
        );
        let (_, c) = rtx.batch_counted(&queries, cfg.workers);
        let work = model.work_per_query(&c, queries.len() as u64);
        let name = format!("{builder:?}");
        csv.row(&["builder".into(), name.clone(), fnum(work), String::new()]).unwrap();
        rows.push(vec!["builder".into(), name, fnum(work), "-".into()]);
    }

    // 2. Block minimums: second AS (measured above as part of blocks
    //    mode) vs lookup table. The LUT replaces the interior ray with an
    //    O(1) read: work drops by the interior ray's share, memory grows
    //    by nb^2 entries.
    {
        let rtx = RtxRmq::with_options(
            &xs,
            RtxOptions { mode: RtxMode::Blocks { block_size: bs }, ..Default::default() },
        );
        let (_, c_as) = rtx.batch_counted(&queries, cfg.workers);
        let work_as = model.work_per_query(&c_as, queries.len() as u64);
        // LUT variant: interior sub-query answered by a table read.
        // Replay Algorithm 6 counting only the partial-block rays.
        let nb = n.div_ceil(bs);
        let st = SparseTable::new(&xs); // stand-in for correct interior answers
        let mut c_lut = rtxrmq::bvh::traverse::Counters::default();
        let mut ts = rtxrmq::rmq::rtx::RtxScratch::new();
        for &(l, r) in &queries {
            let (bl, br) = (l as usize / bs, r as usize / bs);
            if bl == br {
                rtx.rmq_counted(l, r, &mut ts, &mut c_lut);
            } else {
                // two partial rays only; interior via LUT (no ray)
                let left_end = ((bl + 1) * bs - 1).min(n - 1) as u32;
                rtx.rmq_counted(l, left_end, &mut ts, &mut c_lut);
                rtx.rmq_counted((br * bs) as u32, r, &mut ts, &mut c_lut);
                std::hint::black_box(st.rmq(l, r));
            }
        }
        let work_lut = model.work_per_query(&c_lut, queries.len() as u64);
        let lut_mb = (nb * nb * 4) as f64 / (1u64 << 20) as f64;
        csv.row(&["blockmin".into(), "accel-structure".into(), fnum(work_as), "0".into()])
            .unwrap();
        csv.row(&["blockmin".into(), "lookup-table".into(), fnum(work_lut), fnum(lut_mb)])
            .unwrap();
        rows.push(vec!["blockmin".into(), "accel-structure".into(), fnum(work_as), "0".into()]);
        rows.push(vec![
            "blockmin".into(),
            "lookup-table".into(),
            fnum(work_lut),
            format!("{lut_mb:.2}"),
        ]);
    }

    // 3. Flat vs blocks.
    {
        let flat = RtxRmq::with_options(&xs, RtxOptions::default());
        let (_, cf) = flat.batch_counted(&queries, cfg.workers);
        let blocks = RtxRmq::with_options(
            &xs,
            RtxOptions { mode: RtxMode::Blocks { block_size: bs }, ..Default::default() },
        );
        let (_, cb) = blocks.batch_counted(&queries, cfg.workers);
        let wf = model.work_per_query(&cf, queries.len() as u64);
        let wb = model.work_per_query(&cb, queries.len() as u64);
        csv.row(&["layout".into(), "flat".into(), fnum(wf), String::new()]).unwrap();
        csv.row(&["layout".into(), "block-matrix".into(), fnum(wb), String::new()]).unwrap();
        rows.push(vec!["layout".into(), "flat".into(), fnum(wf), "-".into()]);
        rows.push(vec!["layout".into(), "block-matrix".into(), fnum(wb), "-".into()]);
        println!(
            "flat/block work ratio at n={n}: {:.2} (paper §5.3: blocks cut traversal work)",
            wf / wb
        );
    }

    csv.flush().unwrap();
    print_table(
        "Ablations (traversal work units per query; lower is better)",
        &["ablation", "variant", "work/query", "extra mem (MB)"],
        &rows,
    );
}

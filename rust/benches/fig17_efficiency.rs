//! Fig. 17 — energy efficiency (RMQs per joule) for all approaches under
//! the three range distributions. Paper findings: LCA most efficient for
//! large/medium ranges, RTXRMQ most efficient for small ranges; HRMQ
//! follows despite its 600 W draw; EXHAUSTIVE is hopeless at large
//! ranges but improves by orders of magnitude as ranges shrink.
//! Emits `results/fig17_efficiency.csv`.

use rtxrmq::bench_harness::{print_table, BenchCfg};
use rtxrmq::bench_harness::runner::Suite;
use rtxrmq::model::EnergyModel;
use rtxrmq::rtcore::arch::{EPYC_9654_X2, LOVELACE_RTX6000ADA};
use rtxrmq::util::csv::{fnum, CsvWriter};
use rtxrmq::util::rng::Rng;
use rtxrmq::workload::{gen_queries, RangeDist};

fn main() {
    let cfg = BenchCfg::from_env();
    let mut rng = Rng::new(cfg.seed);
    let n = cfg.max_n;
    let suite = Suite::build(n, cfg.seed);
    let energy = EnergyModel::default();
    let gpu = LOVELACE_RTX6000ADA;
    let q = cfg.model_batch;

    let mut csv = CsvWriter::create(
        cfg.out_dir.join("fig17_efficiency.csv"),
        &["dist", "approach", "rmq_per_joule"],
    )
    .unwrap();
    let mut rows = Vec::new();
    let mut winners = Vec::new();
    for dist in RangeDist::all() {
        let qs = gen_queries(n, cfg.sample_queries, dist, &mut rng);
        let p = suite.measure_point(&qs, q, cfg.workers);
        let entries = [
            ("RTXRMQ", p.rtx_ns, energy.gpu_watts(energy.util_rtx, &gpu)),
            ("LCA", p.lca_ns, energy.gpu_watts(energy.util_lca, &gpu)),
            ("HRMQ", p.hrmq_ns, energy.cpu_watts(&EPYC_9654_X2)),
            ("EXHAUSTIVE", p.exhaustive_ns, energy.gpu_watts(energy.util_exhaustive, &gpu)),
        ];
        let mut best = ("", 0.0f64);
        for (name, ns, w) in entries {
            let rpj = energy.rmq_per_joule(q, ns * q as f64, w);
            csv.row(&[dist.name().to_string(), name.to_string(), fnum(rpj)]).unwrap();
            rows.push(vec![dist.name().to_string(), name.to_string(), format!("{rpj:.3e}")]);
            if rpj > best.1 {
                best = (name, rpj);
            }
        }
        winners.push((dist.name(), best.0));
    }
    csv.flush().unwrap();
    print_table("Fig 17: RMQs per joule", &["dist", "approach", "RMQ/J"], &rows);
    for (dist, w) in winners {
        let paper = match dist {
            "large" | "medium" => "LCA",
            _ => "RTXRMQ",
        };
        println!("  [{dist}] most efficient: {w} (paper: {paper}) -> match: {}", w == paper);
    }
    println!(
        "  note: below paper scale LCA is cache-resident and over-performs; the small-range\n\
         \x20 RTXRMQ efficiency win appears at n >= ~2^22 (see fig12's @1e8 extrapolation)."
    );
}

//! Compile-only stub of the `xla` PJRT bindings.
//!
//! The rtxrmq runtime layer (`rtxrmq::runtime`) executes AOT-lowered HLO
//! artifacts through the XLA CPU client when the real `xla` crate (and
//! the `xla_extension` shared library) is installed. This offline build
//! environment has neither, so this stub keeps the runtime layer
//! source-compatible: every entry point type-checks, and the very first
//! call a loader makes — [`PjRtClient::cpu`] — returns an error, which
//! callers already treat as "PJRT backend unavailable" (the CLI falls
//! back to the native engines and the integration tests skip).
//!
//! To run against real XLA, point the `xla` dependency of `rtxrmq` at the
//! actual bindings crate; no source changes are needed.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type mirroring the shape of the real bindings' error.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error("PJRT/XLA backend not available in this build (compile-only stub; see rust/vendor/xla)".to_string())
}

/// Element types storable in a [`Literal`].
pub trait Element: Copy + 'static {}
impl Element for f32 {}
impl Element for f64 {}
impl Element for i32 {}
impl Element for i64 {}
impl Element for u32 {}
impl Element for u8 {}

/// Host-side literal (stub: retains only the element count).
pub struct Literal {
    len: usize,
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Element>(data: &[T]) -> Literal {
        Literal { len: data.len() }
    }

    /// Number of elements (diagnostic only in the stub).
    pub fn element_count(&self) -> usize {
        self.len
    }

    /// Copy out as a typed vector. Unreachable in the stub (no
    /// executable can produce a result literal), kept for API parity.
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }

    /// Destructure a tuple literal. Unreachable in the stub.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }
}

/// Parsed HLO module (stub: empty).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    /// Parse an HLO text file. The stub reports the backend missing
    /// without touching the file.
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Device buffer returned by an execution (stub: uninhabitable in
/// practice since [`PjRtClient::cpu`] always errors first).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals.
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Create the CPU client. Always errors in the stub — this is the
    /// single gate every runtime user passes through first.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must error");
        assert!(format!("{err:?}").contains("stub"));
    }

    #[test]
    fn literal_roundtrip_shape_only() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert_eq!(l.element_count(), 3);
        assert!(l.to_vec::<f32>().is_err());
    }
}

//! Minimal, dependency-free stand-in for the `anyhow` crate, covering
//! exactly the API subset rtxrmq uses: [`Error`], [`Result`], the
//! [`anyhow!`] / [`bail!`] macros, and the [`Context`] extension trait.
//!
//! The offline build environment has no crates.io access, so this path
//! crate keeps the call sites source-compatible with the real `anyhow`;
//! swapping back to the upstream crate is a one-line Cargo.toml change.

use std::fmt;

/// A boxed-free error: a message chain rendered eagerly into a string.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend context, mirroring `anyhow::Error::context`.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` legal.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{c}: {e}") })
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"))
    }

    #[test]
    fn macros_and_display() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let n = 3;
        let e = anyhow!("count = {}", n);
        assert_eq!(e.to_string(), "count = 3");
        let msg = String::from("owned");
        let e = anyhow!(msg);
        assert_eq!(e.to_string(), "owned");
    }

    #[test]
    fn bail_returns() {
        fn f() -> Result<()> {
            bail!("stop {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "stop 1");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            io_err()?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("boom"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<()> = io_err().context("reading x");
        let s = r.unwrap_err().to_string();
        assert!(s.starts_with("reading x: "), "{s}");
        let o: Option<u32> = None;
        assert_eq!(o.context("missing field").unwrap_err().to_string(), "missing field");
        let o: Option<u32> = Some(7);
        assert_eq!(o.with_context(|| "unused").unwrap(), 7);
    }
}

//! Dynamic RMQ — the paper's future-work item (iii): "solve batches of
//! RMQs for input arrays that change their values over time; useful for
//! scientific applications such as simulations" — served end to end
//! through the coordinator's **mixed op-stream path**.
//!
//! Scenario: a running simulation tracks the minimum energy in sliding
//! windows of a particle field while the field evolves. Each tick
//! submits one fenced op stream (`workload::gen_mixed` shape): point
//! updates interleaved with query chunks. The coordinator routes the
//! update batches to the sharded engine (per-block refits in parallel,
//! no global rebuild), pins post-update queries to the same engine, and
//! guarantees the fence: a query sees exactly the updates that precede
//! it in the stream. Every answer is verified against a naive re-solve
//! oracle.
//!
//! Run: `cargo run --release --example dynamic_rmq [--n 2^14]
//!       [--ticks 40] [--update-frac 0.2] [--shard-block auto]`

use rtxrmq::coordinator::engine::{EngineCfg, ShardBlock};
use rtxrmq::coordinator::server::{Coordinator, CoordinatorCfg};
use rtxrmq::rmq::naive_rmq;
use rtxrmq::util::cli::Args;
use rtxrmq::util::rng::Rng;
use rtxrmq::workload::{gen_mixed, Op, RangeDist};

fn main() {
    let args = Args::from_env();
    let n: usize = args.get_or("n", 1usize << 14).unwrap();
    let ticks: usize = args.get_or("ticks", 40usize).unwrap();
    let ops_per_tick: usize = args.get_or("ops", 288usize).unwrap();
    let update_frac: f64 = args.get_or("update-frac", 0.2f64).unwrap();
    let dist = RangeDist::parse(&args.str_or("dist", "small")).unwrap_or(RangeDist::Small);
    let shard_block = match args.opt("shard-block") {
        None => ShardBlock::Sqrt,
        Some(s) => ShardBlock::parse(s, dist, update_frac).expect("valid --shard-block"),
    };

    let mut rng = Rng::new(0xD41A);
    let xs = Rng::new(1).uniform_f32_vec(n);
    let mut oracle = xs.clone();

    let t_build = std::time::Instant::now();
    let coordinator = Coordinator::start(
        &xs,
        None,
        CoordinatorCfg { engines: EngineCfg { shard_block }, ..Default::default() },
    );
    println!(
        "coordinator up in {:.2?} (n = {n}, shard block rule {shard_block:?})",
        t_build.elapsed()
    );

    let t0 = std::time::Instant::now();
    let (mut answered, mut updated) = (0usize, 0usize);
    for tick in 0..ticks {
        // One simulation tick = one fenced op stream.
        let ops = gen_mixed(n, ops_per_tick, update_frac, dist, &mut rng);
        let resp = coordinator.submit_mixed(ops.clone()).expect("serve tick");
        updated += resp.updates_applied;

        // Verify every answer against the sequential re-solve oracle.
        let mut k = 0usize;
        for op in &ops {
            match *op {
                Op::Query((l, r)) => {
                    let want = naive_rmq(&oracle, l as usize, r as usize) as u32;
                    assert_eq!(
                        resp.answers[k], want,
                        "tick {tick} query ({l},{r}) via {}",
                        resp.engine
                    );
                    k += 1;
                }
                Op::Update { i, v } => oracle[i as usize] = v,
            }
        }
        answered += k;
    }
    let wall = t0.elapsed();

    println!(
        "dynamic RMQ over {ticks} ticks ({ops_per_tick} ops/tick, {:.0}% updates):",
        update_frac * 100.0
    );
    println!("  {answered} queries + {updated} updates served & verified in {wall:.2?}");
    println!(
        "  {:.0} ops/s end to end (fenced: each query sees exactly the prior updates)",
        (answered + updated) as f64 / wall.as_secs_f64()
    );
    println!("\n{}", coordinator.metrics.lock());
    coordinator.shutdown();
    println!("-> the refit write path keeps answers exact with no global rebuild (paper §7.iii)");
}

//! Computational-biology example (one of the paper's motivating
//! applications, §1/§2): longest-common-extension (LCE) queries over a
//! DNA sequence via RMQ on the LCP array.
//!
//! Pipeline: synthetic DNA → suffix array (prefix-doubling) → LCP array
//! (Kasai) → RMQ structure → `LCE(i, j) = LCP[RMQ(rank_i+1, rank_j)]`.
//! RTXRMQ serves the queries; answers are verified by direct character
//! comparison.
//!
//! Run: `cargo run --release --example genome_lcp [--n 2^14] [--queries 500]`

use rtxrmq::rmq::rtx::RtxRmq;
use rtxrmq::rmq::RmqSolver;
use rtxrmq::util::cli::Args;
use rtxrmq::util::rng::Rng;

/// Suffix array by prefix doubling (O(n log² n), dependency-free).
fn suffix_array(s: &[u8]) -> Vec<u32> {
    let n = s.len();
    let mut sa: Vec<u32> = (0..n as u32).collect();
    let mut rank: Vec<i64> = s.iter().map(|&c| c as i64).collect();
    let mut tmp = vec![0i64; n];
    let mut k = 1usize;
    while k < n {
        let key = |i: u32| {
            let i = i as usize;
            (rank[i], if i + k < n { rank[i + k] } else { -1 })
        };
        sa.sort_unstable_by_key(|&i| key(i));
        tmp[sa[0] as usize] = 0;
        for w in 1..n {
            tmp[sa[w] as usize] =
                tmp[sa[w - 1] as usize] + i64::from(key(sa[w]) != key(sa[w - 1]));
        }
        rank.copy_from_slice(&tmp);
        if rank[sa[n - 1] as usize] as usize == n - 1 {
            break;
        }
        k <<= 1;
    }
    sa
}

/// Kasai's LCP construction: lcp[j] = LCP(suffix sa[j-1], suffix sa[j]).
fn lcp_array(s: &[u8], sa: &[u32]) -> Vec<u32> {
    let n = s.len();
    let mut rank = vec![0u32; n];
    for (j, &i) in sa.iter().enumerate() {
        rank[i as usize] = j as u32;
    }
    let mut lcp = vec![0u32; n];
    let mut h = 0usize;
    for i in 0..n {
        let r = rank[i] as usize;
        if r > 0 {
            let j = sa[r - 1] as usize;
            while i + h < n && j + h < n && s[i + h] == s[j + h] {
                h += 1;
            }
            lcp[r] = h as u32;
            h = h.saturating_sub(1);
        } else {
            h = 0;
        }
    }
    lcp
}

fn naive_lce(s: &[u8], i: usize, j: usize) -> usize {
    let mut h = 0;
    while i + h < s.len() && j + h < s.len() && s[i + h] == s[j + h] {
        h += 1;
    }
    h
}

fn main() {
    let args = Args::from_env();
    let n: usize = args.get_or("n", 1usize << 14).unwrap();
    let queries: usize = args.get_or("queries", 500usize).unwrap();
    let mut rng = Rng::new(0xD9A);

    // Synthetic DNA with repeated motifs (so LCEs are non-trivial).
    let motif: Vec<u8> = (0..64).map(|_| b"ACGT"[rng.below(4) as usize]).collect();
    let dna: Vec<u8> = (0..n)
        .map(|i| {
            if rng.f64() < 0.7 {
                motif[i % motif.len()]
            } else {
                b"ACGT"[rng.below(4) as usize]
            }
        })
        .collect();

    let t0 = std::time::Instant::now();
    let sa = suffix_array(&dna);
    let lcp = lcp_array(&dna, &sa);
    let mut rank = vec![0u32; n];
    for (j, &i) in sa.iter().enumerate() {
        rank[i as usize] = j as u32;
    }
    println!("suffix + LCP arrays built for {n} bp in {:.2?}", t0.elapsed());

    // RMQ over the LCP values with RTXRMQ (values as f32: LCP < 2^24).
    let lcp_f: Vec<f32> = lcp.iter().map(|&v| v as f32).collect();
    let solver = RtxRmq::new_auto(&lcp_f);
    println!("RTXRMQ geometry: {} triangles, mode {:?}", solver.prim_count(), solver.mode());

    let t1 = std::time::Instant::now();
    let mut checked = 0;
    for _ in 0..queries {
        let i = rng.range(0, n - 1);
        let j = rng.range(0, n - 1);
        let lce = if i == j {
            n - i
        } else {
            let (a, b) = (rank[i].min(rank[j]), rank[i].max(rank[j]));
            lcp[solver.rmq(a + 1, b) as usize] as usize
        };
        assert_eq!(lce, naive_lce(&dna, i, j), "LCE({i},{j})");
        checked += 1;
    }
    println!(
        "{checked} LCE queries answered via RMQ and verified by direct comparison in {:.2?}",
        t1.elapsed()
    );
    println!(
        "example LCE: positions 0 vs {}: {} bp common prefix",
        motif.len(),
        naive_lce(&dna, 0, motif.len())
    );
}

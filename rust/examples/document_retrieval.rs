//! Document-retrieval example (Muthukrishnan's classic RMQ application,
//! cited in the paper's §1/§2): list the *distinct* documents whose text
//! appears in a position range of a concatenated corpus, in output-
//! sensitive time via recursive range minima over the "previous
//! occurrence" array.
//!
//! C[i] = last position before i holding the same document id (or −1).
//! A document occurs in [l, r] with *first* occurrence at k iff C[k] < l,
//! and those k are found by repeatedly taking range minima — each report
//! costs O(1) RMQs, independent of how often the document repeats.
//!
//! Run: `cargo run --release --example document_retrieval`

use rtxrmq::rmq::rtx::RtxRmq;
use rtxrmq::rmq::RmqSolver;
use rtxrmq::util::cli::Args;
use rtxrmq::util::rng::Rng;
use std::collections::BTreeSet;

/// Recursive distinct-listing via RMQ on C (Muthukrishnan 2002).
fn list_documents(
    solver: &RtxRmq,
    c: &[i64],
    docs: &[u32],
    l: usize,
    r: usize,
    l0: usize,
    out: &mut Vec<u32>,
) {
    if l > r {
        return;
    }
    let k = solver.rmq(l as u32, r as u32) as usize;
    if c[k] >= l0 as i64 {
        return; // every doc in [l, r] already reported
    }
    out.push(docs[k]);
    if k > l {
        list_documents(solver, c, docs, l, k - 1, l0, out);
    }
    list_documents(solver, c, docs, k + 1, r, l0, out);
}

fn main() {
    let args = Args::from_env();
    let n: usize = args.get_or("n", 1usize << 15).unwrap();
    let ndocs: usize = args.get_or("docs", 200usize).unwrap();
    let queries: usize = args.get_or("queries", 300usize).unwrap();
    let mut rng = Rng::new(0x0D0C);

    // Synthetic corpus: position i belongs to a document; bursty runs so
    // ranges contain few distinct documents (the realistic case).
    let mut docs = Vec::with_capacity(n);
    let mut cur = 0u32;
    for _ in 0..n {
        if rng.f64() < 0.02 {
            cur = rng.below(ndocs as u64) as u32;
        }
        docs.push(cur);
    }

    // Previous-occurrence array C.
    let mut last = vec![-1i64; ndocs];
    let mut c = Vec::with_capacity(n);
    for (i, &d) in docs.iter().enumerate() {
        c.push(last[d as usize]);
        last[d as usize] = i as i64;
    }

    // RMQ over C (i64 values fit f32 exactly for n < 2^24).
    let c_f: Vec<f32> = c.iter().map(|&v| v as f32).collect();
    let solver = RtxRmq::new_auto(&c_f);
    println!(
        "corpus: {n} positions, {ndocs} documents; RTXRMQ geometry {} triangles",
        solver.prim_count()
    );

    let t0 = std::time::Instant::now();
    let mut reported = 0usize;
    for _ in 0..queries {
        let l = rng.range(0, n - 1);
        let r = rng.range(l, n - 1);
        let mut out = Vec::new();
        list_documents(&solver, &c, &docs, l, r, l, &mut out);
        // Verify against a direct scan.
        let expect: BTreeSet<u32> = docs[l..=r].iter().copied().collect();
        let got: BTreeSet<u32> = out.iter().copied().collect();
        assert_eq!(got, expect, "range ({l},{r})");
        assert_eq!(out.len(), expect.len(), "each document reported exactly once");
        reported += out.len();
    }
    println!(
        "{queries} ranges listed ({reported} documents reported, all verified) in {:.2?}",
        t0.elapsed()
    );
    println!("output-sensitive: ~{:.1} RMQs per reported document", 2.0);
}

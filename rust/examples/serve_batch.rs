//! End-to-end serving driver (DESIGN.md §4's E2E row): start the L3
//! coordinator with all engines **including the PJRT-backed XLA engine**
//! (L1 Pallas kernels lowered through the L2 JAX graph — Python never
//! runs here), fire a mixed workload of request batches at it from
//! concurrent clients, and report routing decisions, latency percentiles
//! and throughput. Results are recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example serve_batch`
//! Flags: --n 2^16  --requests 512  --batch 2^10  --no-xla

use rtxrmq::coordinator::batcher::BatcherCfg;
use rtxrmq::coordinator::router::Policy;
use rtxrmq::coordinator::server::{Coordinator, CoordinatorCfg};
use rtxrmq::rmq::sparse_table::SparseTable;
use rtxrmq::rmq::RmqSolver;
use rtxrmq::runtime::Runtime;
use rtxrmq::util::cli::Args;
use rtxrmq::util::rng::Rng;
use rtxrmq::util::stats::{fmt_ns, percentile};
use rtxrmq::workload::{gen_array, gen_queries, RangeDist};
use std::path::Path;
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let n: usize = args.get_or("n", 1usize << 16).unwrap();
    let requests: usize = args.get_or("requests", 384usize).unwrap();
    let per_request: usize = args.get_or("batch", 1usize << 10).unwrap();
    let clients: usize = args.get_or("clients", 4usize).unwrap();

    let xs = gen_array(n, 7);
    let runtime = if args.flag("no-xla") {
        None
    } else {
        match Runtime::load(Path::new("artifacts")) {
            Ok(rt) => {
                println!("loaded {} AOT artifact variants via PJRT", rt.variants().count());
                Some(Arc::new(rt))
            }
            Err(e) => {
                eprintln!("warning: XLA engine disabled ({e}); run `make artifacts`");
                None
            }
        }
    };

    let t_build = std::time::Instant::now();
    let coordinator = Arc::new(Coordinator::start(
        &xs,
        runtime,
        CoordinatorCfg {
            policy: Policy::ModeledCost,
            batcher: BatcherCfg {
                max_batch_queries: 1 << 15,
                max_wait: std::time::Duration::from_millis(1),
                queue_cap: 128,
                ..Default::default()
            },
            engine_workers: rtxrmq::util::pool::default_workers(),
            ..Default::default()
        },
    ));
    println!("engines built in {:.2?} (n = {n})", t_build.elapsed());

    // Concurrent clients with a mixed distribution profile.
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    let latencies = Arc::new(std::sync::Mutex::new(Vec::<f64>::new()));
    let per_engine = Arc::new(std::sync::Mutex::new(std::collections::HashMap::<String, u64>::new()));
    for c in 0..clients {
        let coordinator = coordinator.clone();
        let latencies = latencies.clone();
        let per_engine = per_engine.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(1000 + c as u64);
            let my_requests = requests / clients.max(1);
            for i in 0..my_requests {
                let dist = match i % 3 {
                    0 => RangeDist::Small,
                    1 => RangeDist::Medium,
                    _ => RangeDist::Large,
                };
                let qs = gen_queries(n, per_request, dist, &mut rng);
                let t = std::time::Instant::now();
                let resp = coordinator.query(qs).expect("serve");
                latencies.lock().unwrap().push(t.elapsed().as_nanos() as f64);
                *per_engine.lock().unwrap().entry(resp.engine.to_string()).or_default() += 1;
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed();

    // Spot-check correctness against the oracle.
    let st = SparseTable::new(&xs);
    let mut rng = Rng::new(5);
    let check = gen_queries(n, 256, RangeDist::Medium, &mut rng);
    let resp = coordinator.query(check.clone()).unwrap();
    for (i, &(l, r)) in check.iter().enumerate() {
        assert_eq!(resp.answers[i], st.rmq(l, r), "query ({l},{r})");
    }
    println!("correctness spot-check vs sparse-table oracle: OK (256 queries)");

    let lat = latencies.lock().unwrap();
    let served: u64 = requests as u64 * per_request as u64 / clients.max(1) as u64 * clients as u64;
    println!("\n== serve_batch E2E report ==");
    println!("requests served : {} ({} queries each, {} clients)", lat.len(), per_request, clients);
    println!("total queries   : {}", served);
    println!("wall time       : {wall:.2?}");
    println!("throughput      : {:.0} queries/s", served as f64 / wall.as_secs_f64());
    println!(
        "request latency : p50 {}  p95 {}  p99 {}",
        fmt_ns(percentile(&lat, 50.0)),
        fmt_ns(percentile(&lat, 95.0)),
        fmt_ns(percentile(&lat, 99.0))
    );
    println!("routing         : {:?}", per_engine.lock().unwrap());
    println!("\n{}", coordinator.metrics.lock());
}

//! Quickstart: build every solver over one array, answer a few queries,
//! and show the paper's worked example (§2).
//!
//! Run: `cargo run --release --example quickstart`

use rtxrmq::rmq::exhaustive::Exhaustive;
use rtxrmq::rmq::hrmq::Hrmq;
use rtxrmq::rmq::lca::LcaRmq;
use rtxrmq::rmq::rtx::RtxRmq;
use rtxrmq::rmq::RmqSolver;
use rtxrmq::util::rng::Rng;
use rtxrmq::workload::{gen_queries, RangeDist};

fn main() {
    // --- the paper's §2 example ---
    let xs = [9.0f32, 2.0, 7.0, 8.0, 4.0, 1.0, 3.0];
    let rtx = RtxRmq::new_auto(&xs);
    println!("X = {xs:?}");
    println!("RMQ(2, 6) = {} (paper: 5, value {})", rtx.rmq(2, 6), rtx.value_of(rtx.rmq(2, 6)));

    // --- all four approaches on a real batch ---
    let n = 1 << 16;
    let values = Rng::new(1).uniform_f32_vec(n);
    let mut rng = Rng::new(2);
    let queries = gen_queries(n, 1024, RangeDist::Small, &mut rng);

    let solvers: Vec<Box<dyn RmqSolver>> = vec![
        Box::new(RtxRmq::new_auto(&values)),
        Box::new(LcaRmq::new(&values)),
        Box::new(Hrmq::new(&values)),
        Box::new(Exhaustive::new(&values)),
    ];
    let reference = solvers[0].batch(&queries, 1);
    for s in &solvers {
        let t0 = std::time::Instant::now();
        let answers = s.batch(&queries, 1);
        let dt = t0.elapsed();
        assert_eq!(answers, reference, "solvers must agree");
        println!(
            "{:<11} answered {} queries in {:>9.2?}  ({:.0} B aux memory)",
            s.name(),
            queries.len(),
            dt,
            s.memory_bytes() as f64
        );
    }
    println!("all solvers agree on {} small-range queries over n = {n}", queries.len());
}

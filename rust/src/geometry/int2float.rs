//! Algorithm 4 — exact monotone int→float transform for indices beyond
//! the 2^24 exact-integer range of f32.
//!
//! A plain `i as f32` cast is exact only for `i < 2^24` (23+1 mantissa
//! bits); past that, distinct indices collide and RMQ answers become
//! wrong (paper §5.2). Algorithm 4 instead maps
//!
//! ```text
//! E = ⌊x / 2^23⌋,  M = x mod 2^23,  q = (M + 2^23) / 2^24 ∈ [0.5, 1),
//! f(x) = q · 2^E
//! ```
//!
//! q is a dyadic rational with 24 significant bits — exactly
//! representable — and multiplication by 2^E is exponent arithmetic, so
//! `f` is exact and strictly increasing over the whole index range the
//! paper targets.

const TWO23: u64 = 1 << 23;
const TWO24: u64 = 1 << 24;

/// Exact monotone transform (Algorithm 4).
#[inline]
pub fn int_to_float_monotone(x: u64) -> f32 {
    let e = (x / TWO23) as i32;
    let m = x % TWO23;
    let q = (m + TWO23) as f32 / TWO24 as f32;
    // q * 2^E via exponent arithmetic (exact; f32 exponent range is
    // ±126, far beyond the paper's 2^30-primitive ceiling at E ≤ 128).
    q * (e as f32).exp2()
}

/// Whether a plain cast is still exact for the given index.
#[inline]
pub fn plain_cast_is_exact(x: u64) -> bool {
    x <= TWO24
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn matches_cast_in_exact_range() {
        // In [0, 2^23) the transform equals q*1 with q in [0.5,1) — NOT
        // the identity; what matters is monotonicity and injectivity.
        // But the *cast* is exact there, so verify injectivity against it.
        for x in [0u64, 1, 2, 1000, TWO23 - 1, TWO23, TWO23 + 1] {
            let f = int_to_float_monotone(x);
            assert!(f.is_finite());
        }
    }

    #[test]
    fn strictly_monotone_across_boundaries() {
        // Check strict monotonicity around every 2^23 boundary and far
        // past 2^24 where plain casts collapse.
        let interesting = [
            0u64,
            1,
            TWO23 - 1,
            TWO23,
            TWO23 + 1,
            TWO24 - 1,
            TWO24,
            TWO24 + 1,
            (1 << 26) - 1,
            1 << 26,
            (1 << 30) - 1,
        ];
        for w in interesting.windows(2) {
            let (a, b) = (int_to_float_monotone(w[0]), int_to_float_monotone(w[1]));
            assert!(a < b, "f({}) = {a} !< f({}) = {b}", w[0], w[1]);
        }
    }

    #[test]
    fn injective_where_cast_is_not() {
        // 2^24 + 1 is the first index a plain cast cannot represent.
        let x = TWO24 + 1;
        assert_eq!(x as f32, (x - 1) as f32, "plain cast collides");
        assert_ne!(
            int_to_float_monotone(x),
            int_to_float_monotone(x - 1),
            "algorithm 4 must not collide"
        );
    }

    #[test]
    fn property_adjacent_values_distinct() {
        check("alg4 adjacent distinct + monotone", 200, |rng| {
            let x = rng.below(1 << 30);
            let (a, b) = (int_to_float_monotone(x), int_to_float_monotone(x + 1));
            if !(a < b) {
                return Err(format!("f({x}) = {a} !< f({}) = {b}", x + 1));
            }
            Ok(())
        });
    }

    #[test]
    fn exactness_of_q() {
        // q must be a 24-bit dyadic rational: multiplying back by 2^24
        // must give an integer.
        for x in [5u64, TWO23 + 12345, (1 << 28) + 7] {
            let e = (x / TWO23) as i32;
            let m = x % TWO23;
            let q = (m + TWO23) as f32 / TWO24 as f32;
            let back = q * TWO24 as f32;
            assert_eq!(back.fract(), 0.0);
            assert_eq!(back as u64, m + TWO23);
            let _ = e;
        }
    }
}

//! FP32 precision validity (paper Eq. 2) and the OptiX resource limits
//! the paper filters block configurations with (§5.3, Figs. 10/11).
//!
//! "the needed precision is 1/BS and the obtained precision is calculated
//! from the furthest point from the origin in square coordinates", giving
//!
//! ```text
//! 2^⌊log2(2·⌈√(n/BS)⌉)⌋ · 2^−23  ≤  1/BS        (Eq. 2)
//! ```
//!
//! plus the hard OptiX limits: BS ≤ 2^18, #blocks ≤ 2^24, ≤ 2^29
//! primitives per GAS, ≤ 2^30 rays per launch.

/// OptiX resource limits (paper §5.3).
#[derive(Clone, Copy, Debug)]
pub struct OptixLimits {
    pub max_block_size: usize,
    pub max_blocks: usize,
    pub max_prims: usize,
    pub max_rays_per_launch: usize,
}

impl Default for OptixLimits {
    fn default() -> Self {
        OptixLimits {
            max_block_size: 1 << 18,
            max_blocks: 1 << 24,
            max_prims: 1 << 29,
            max_rays_per_launch: 1 << 30,
        }
    }
}

/// Why a configuration is invalid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConfigError {
    /// Eq. 2 fails: the ULP at the furthest cell exceeds 1/BS.
    PrecisionEq2,
    /// BS > 2^18.
    BlockTooLarge,
    /// #blocks > 2^24.
    TooManyBlocks,
    /// n > 2^29 triangles in one geometry acceleration structure.
    TooManyPrims,
}

/// Eq. 2 check, verbatim from the paper.
pub fn eq2_valid(n: usize, bs: usize) -> bool {
    debug_assert!(n > 0 && bs > 0);
    let blocks = n.div_ceil(bs);
    let sqrt_ceil = (blocks as f64).sqrt().ceil() as u64;
    let arg = 2 * sqrt_ceil.max(1);
    let floor_log2 = 63 - arg.leading_zeros() as i64; // ⌊log2(arg)⌋
    // 2^floor_log2 * 2^-23 <= 1/bs  <=>  bs * 2^floor_log2 <= 2^23
    (bs as u64) << floor_log2 <= 1u64 << 23
}

/// Full validity check for a (n, BS) configuration.
pub fn config_valid(n: usize, bs: usize, limits: &OptixLimits) -> Result<(), ConfigError> {
    if bs > limits.max_block_size {
        return Err(ConfigError::BlockTooLarge);
    }
    let blocks = n.div_ceil(bs);
    if blocks > limits.max_blocks {
        return Err(ConfigError::TooManyBlocks);
    }
    if n > limits.max_prims {
        return Err(ConfigError::TooManyPrims);
    }
    if !eq2_valid(n, bs) {
        return Err(ConfigError::PrecisionEq2);
    }
    Ok(())
}

/// All power-of-two block sizes valid for a given n (used by the Fig. 11
/// cube sweep and by the coordinator's auto-tuner).
pub fn valid_pow2_block_sizes(n: usize, limits: &OptixLimits) -> Vec<usize> {
    let mut out = Vec::new();
    let mut bs = 1usize;
    while bs <= n.max(1) {
        if config_valid(n, bs, limits).is_ok() {
            out.push(bs);
        }
        bs <<= 1;
    }
    out
}

/// Largest valid power-of-two block size (fewest blocks ⇒ fastest
/// block-level stage), or None if nothing is valid.
pub fn best_block_size(n: usize, limits: &OptixLimits) -> Option<usize> {
    // Heuristic from the Fig. 11 discussion: high-performance path runs
    // near balanced √n blocks; choose the valid pow2 closest to √n.
    let sizes = valid_pow2_block_sizes(n, limits);
    if sizes.is_empty() {
        return None;
    }
    let target = (n as f64).sqrt();
    sizes
        .into_iter()
        .min_by(|&a, &b| {
            let da = (a as f64).log2() - target.log2();
            let db = (b as f64).log2() - target.log2();
            da.abs().partial_cmp(&db.abs()).unwrap()
        })
        .map(Some)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq2_small_n_always_valid_for_small_bs() {
        // n/BS small => sqrt small => lhs tiny.
        assert!(eq2_valid(1 << 10, 1 << 5));
        assert!(eq2_valid(1 << 20, 1 << 10));
    }

    #[test]
    fn eq2_rejects_large_bs_with_many_blocks() {
        // bs = 2^18 with 2^8 blocks: lhs = 2^18 * 2^floor(log2(2*16)) =
        // 2^18 * 32 = 2^23 <= 2^23 -> valid (boundary).
        assert!(eq2_valid((1 << 18) * (1 << 8), 1 << 18));
        // One more doubling of blocks pushes it over.
        assert!(!eq2_valid((1 << 18) * (1 << 11), 1 << 18));
    }

    #[test]
    fn limits_enforced() {
        let lim = OptixLimits::default();
        assert_eq!(config_valid(1 << 20, 1 << 19, &lim), Err(ConfigError::BlockTooLarge));
        assert_eq!(config_valid(1 << 30, 1 << 10, &lim), Err(ConfigError::TooManyPrims));
        // blocks > 2^24 needs n/bs > 2^24 with n <= 2^29: bs < 2^5.
        assert_eq!(config_valid(1 << 29, 8, &lim), Err(ConfigError::TooManyBlocks));
    }

    #[test]
    fn paper_scale_configs() {
        let lim = OptixLimits::default();
        // The paper's largest benchmark n = 2^26 must admit some valid
        // block size (they ran it).
        let sizes = valid_pow2_block_sizes(1 << 26, &lim);
        assert!(!sizes.is_empty());
        // And the chosen best size is among them, near sqrt(n) = 2^13.
        let best = best_block_size(1 << 26, &lim).unwrap();
        assert!(sizes.contains(&best));
        assert!((10..=16).contains(&best.trailing_zeros()), "best = 2^{}", best.trailing_zeros());
    }

    #[test]
    fn monotone_in_bs() {
        // For fixed n, if bs is valid then any smaller pow2 bs with more
        // blocks may or may not be valid — but the list must be
        // contiguous at the small end? Not necessarily; just check the
        // checker is deterministic and list is sorted.
        let lim = OptixLimits::default();
        let sizes = valid_pow2_block_sizes(1 << 24, &lim);
        let mut sorted = sizes.clone();
        sorted.sort_unstable();
        assert_eq!(sizes, sorted);
    }
}

//! Geometric reformulation of RMQ (paper §5): array elements become
//! triangles whose X position is the element's *value* and whose (Y, Z)
//! footprint encodes the element's *index*; a query `RMQ(l, r)` becomes a
//! +X ray launched from `(−∞, l/n, r/n)` whose closest hit is the range
//! minimum.
//!
//! - [`flat`] — Algorithm 1 (single normalized space, n ≤ 2^24).
//! - [`blocks`] — Algorithms 5/6 (block-matrix layout for large inputs).
//! - [`int2float`] — Algorithm 4 (exact monotone int→f32 transform).
//! - [`precision`] — Eq. 2 validity + the OptiX limits used to filter
//!   configurations in Figs. 10/11.

pub mod blocks;
pub mod flat;
pub mod int2float;
pub mod precision;

/// 3D point, FP32 like OptiX device geometry (the paper's precision
/// constraints come precisely from this being f32).
pub type Vec3 = [f32; 3];

/// One triangle of the scene; `prim` is the primitive id OptiX would
/// report on hit (here: the array index / block-min id it encodes).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Triangle {
    pub v0: Vec3,
    pub v1: Vec3,
    pub v2: Vec3,
    pub prim: u32,
}

impl Triangle {
    /// Axis-aligned bounds (used by the BVH builders).
    pub fn bounds(&self) -> ([f32; 3], [f32; 3]) {
        let mut lo = self.v0;
        let mut hi = self.v0;
        for v in [self.v1, self.v2] {
            for a in 0..3 {
                lo[a] = lo[a].min(v[a]);
                hi[a] = hi[a].max(v[a]);
            }
        }
        (lo, hi)
    }

    /// All three vertices share the X coordinate by construction (the
    /// element's value plane).
    pub fn x_plane(&self) -> f32 {
        self.v0[0]
    }
}

/// A query ray: origin + implicit direction (1, 0, 0). The paper launches
/// every ray along +X (§5.2, Algorithm 2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Ray {
    pub origin: Vec3,
}

impl Ray {
    pub fn new(origin: Vec3) -> Ray {
        Ray { origin }
    }
}

/// Geometric hit test in the (Y, Z) plane replicating the OptiX border
/// semantics the paper engineers around (§5.2): rays through the *bottom
/// and right* borders do not count as hits, so triangles must cover
/// `[0, i+1)` horizontally and `(i−1, n−1]` vertically. Our test is
/// therefore **strict** on the y = l_i and z = r_i edges and closed on
/// the hypotenuse side.
#[inline]
pub fn point_in_footprint(y: f32, z: f32, tri: &Triangle) -> bool {
    // Vertices: v0 = (x, l, r) right-angle corner, v1 = (x, l, zmax),
    // v2 = (x, ymin, r).
    let (l, r) = (tri.v0[1], tri.v0[2]);
    if !(y < l && z > r) {
        return false;
    }
    // Hypotenuse half-plane from v1 (l, zmax) to v2 (ymin, r): inside is
    // the side containing v0. cross = (v2-v1) × (p-v1) in 2D.
    let (e_y, e_z) = (tri.v2[1] - tri.v1[1], tri.v2[2] - tri.v1[2]);
    let (p_y, p_z) = (y - tri.v1[1], z - tri.v1[2]);
    let cross_p = e_y * p_z - e_z * p_y;
    let (q_y, q_z) = (tri.v0[1] - tri.v1[1], tri.v0[2] - tri.v1[2]);
    let cross_v0 = e_y * q_z - e_z * q_y;
    cross_p * cross_v0 >= 0.0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tri(l: f32, r: f32) -> Triangle {
        Triangle { v0: [0.5, l, r], v1: [0.5, l, 2.0], v2: [0.5, -1.0, r], prim: 0 }
    }

    #[test]
    fn bounds_cover_vertices() {
        let t = tri(0.5, 0.25);
        let (lo, hi) = t.bounds();
        assert_eq!(lo, [0.5, -1.0, 0.25]);
        assert_eq!(hi, [0.5, 0.5, 2.0]);
    }

    #[test]
    fn footprint_interior_and_borders() {
        let t = tri(0.5, 0.25);
        // strictly inside the covered rectangle
        assert!(point_in_footprint(0.4, 0.5, &t));
        // on the y = l border: excluded (right border rule)
        assert!(!point_in_footprint(0.5, 0.5, &t));
        // on the z = r border: excluded (bottom border rule)
        assert!(!point_in_footprint(0.4, 0.25, &t));
        // outside on either side
        assert!(!point_in_footprint(0.6, 0.5, &t));
        assert!(!point_in_footprint(0.4, 0.2, &t));
    }

    #[test]
    fn hypotenuse_is_inclusive_and_outside_rejected() {
        let t = tri(0.5, 0.25);
        // Hypotenuse runs from (0.5, 2.0) to (-1.0, 0.25). A point well
        // beyond it (large z, small y) must be out.
        assert!(!point_in_footprint(-0.9, 1.99, &t));
        // The query space [0,1]x[0,1] corner (0, 1): y<l? 0<0.5 ok,
        // z>r ok, and inside the hypotenuse for this shape.
        assert!(point_in_footprint(0.0, 1.0, &t));
    }
}

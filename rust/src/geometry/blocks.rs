//! Block-matrix geometry for large inputs — paper §5.3, Algorithms 5/6.
//!
//! The array is split into `nb = ⌈n/BS⌉` blocks. Each block gets its own
//! normalized triangle set placed at a distinct *cell* of a √nb × √nb
//! grid in the (Y, Z) plane ("a matrix-like layout of blocks ... keeping
//! the sets closer to the origin where there is a more favorable floating
//! point density", §5.3). Cell slot 0 is reserved for the geometry of the
//! *block minimums* array A′ (the paper found a second acceleration
//! structure faster than a lookup table; both are implemented — the
//! lookup-table ablation lives in `bench_harness`).
//!
//! Layout note: the paper's Algorithm 5 spaces cells 2 units apart and
//! clips triangle tops to the cell; we use a 3-unit pitch with *unclipped*
//! triangles — each triangle spans [−1, 2] around its cell origin, so a
//! 3-unit pitch makes cells exactly disjoint. This preserves the covering
//! property and the precision analysis shape (coordinates grow like
//! Θ(√nb)); Eq. 2 from `precision` is still used as the validity filter,
//! as in the paper.

use super::{Ray, Triangle};

/// Distance between adjacent cell origins. Triangles span [−1, 2] in
/// each axis around their cell origin, so 3 makes cells disjoint.
pub const CELL_PITCH: f32 = 3.0;

/// Geometry layout for the block-matrix scheme.
#[derive(Clone, Copy, Debug)]
pub struct BlockLayout {
    /// Array length.
    pub n: usize,
    /// Block size (BS).
    pub bs: usize,
    /// Number of blocks ⌈n/BS⌉.
    pub nb: usize,
    /// Grid side G = ⌈√(nb+1)⌉ (slot 0 is the block-minimums set).
    pub grid: usize,
}

impl BlockLayout {
    pub fn new(n: usize, bs: usize) -> BlockLayout {
        assert!(n > 0 && bs > 0);
        let nb = n.div_ceil(bs);
        let grid = ((nb + 1) as f64).sqrt().ceil() as usize;
        BlockLayout { n, bs, nb, grid }
    }

    /// Number of elements in block `b` (the last block may be partial).
    #[inline]
    pub fn block_len(&self, b: usize) -> usize {
        debug_assert!(b < self.nb);
        if b + 1 == self.nb { self.n - b * self.bs } else { self.bs }
    }

    /// Grid cell (cx, cy) of a slot (slot 0 = block minimums, slot b+1 =
    /// block b).
    #[inline]
    pub fn cell_of(&self, slot: usize) -> (usize, usize) {
        debug_assert!(slot <= self.nb);
        (slot % self.grid, slot / self.grid)
    }

    /// (Y, Z) origin of a slot's cell.
    #[inline]
    pub fn cell_origin(&self, slot: usize) -> (f32, f32) {
        let (cx, cy) = self.cell_of(slot);
        (cx as f32 * CELL_PITCH, cy as f32 * CELL_PITCH)
    }

    /// Triangle for array element `i` with value `x` (Algorithm 5):
    /// placed in its block's cell, with the local index normalized by BS.
    #[inline]
    pub fn triangle_for_element(&self, x: f32, i: usize) -> Triangle {
        debug_assert!(i < self.n);
        let b = i / self.bs;
        let j = i % self.bs;
        let (y0, z0) = self.cell_origin(b + 1);
        let bsf = self.bs as f32;
        let l = y0 + (j as f32 + 1.0) / bsf;
        let r = z0 + (j as f32 - 1.0) / bsf;
        Triangle { v0: [x, l, r], v1: [x, l, z0 + 2.0], v2: [x, y0 - 1.0, r], prim: i as u32 }
    }

    /// Triangle for block-minimum `b` with value `x`, in cell slot 0,
    /// normalized by nb. `prim` encodes the *block index*.
    #[inline]
    pub fn triangle_for_blockmin(&self, x: f32, b: usize) -> Triangle {
        debug_assert!(b < self.nb);
        let (y0, z0) = self.cell_origin(0); // (0, 0), kept symbolic
        let nbf = self.nb as f32;
        let l = y0 + (b as f32 + 1.0) / nbf;
        let r = z0 + (b as f32 - 1.0) / nbf;
        Triangle { v0: [x, l, r], v1: [x, l, z0 + 2.0], v2: [x, y0 - 1.0, r], prim: b as u32 }
    }

    /// Ray origin (Y, Z) for a sub-query covering local indices
    /// `[jl, jr]` of block `b` (Algorithm 6's per-block RT core RMQ).
    #[inline]
    pub fn ray_for_block_query(&self, b: usize, jl: usize, jr: usize, theta: f32) -> Ray {
        debug_assert!(jl <= jr && jr < self.block_len(b));
        let (y0, z0) = self.cell_origin(b + 1);
        let bsf = self.bs as f32;
        Ray::new([theta, y0 + jl as f32 / bsf, z0 + jr as f32 / bsf])
    }

    /// Ray origin for a query over the block-minimums set covering blocks
    /// `[bl, br]`.
    #[inline]
    pub fn ray_for_blockmin_query(&self, bl: usize, br: usize, theta: f32) -> Ray {
        debug_assert!(bl <= br && br < self.nb);
        let (y0, z0) = self.cell_origin(0);
        let nbf = self.nb as f32;
        Ray::new([theta, y0 + bl as f32 / nbf, z0 + br as f32 / nbf])
    }

    /// Build the full scene: one triangle per element plus one per block
    /// minimum. Returns (triangles, block_min_values, block_argmin).
    /// Block-min prims are tagged by adding `n` to the prim id so hits
    /// can be mapped back ("prim >= n ⇒ block-min of block prim − n").
    pub fn build_scene(&self, xs: &[f32]) -> (Vec<Triangle>, Vec<f32>, Vec<u32>) {
        assert_eq!(xs.len(), self.n);
        let mut tris = Vec::with_capacity(self.n + self.nb);
        for (i, &x) in xs.iter().enumerate() {
            tris.push(self.triangle_for_element(x, i));
        }
        let mut mins = Vec::with_capacity(self.nb);
        let mut argmins = Vec::with_capacity(self.nb);
        for b in 0..self.nb {
            let start = b * self.bs;
            let end = start + self.block_len(b);
            let mut arg = start;
            for k in start + 1..end {
                if xs[k] < xs[arg] {
                    arg = k;
                }
            }
            mins.push(xs[arg]);
            argmins.push(arg as u32);
            let mut t = self.triangle_for_blockmin(xs[arg], b);
            t.prim = (self.n + b) as u32;
            tris.push(t);
        }
        (tris, mins, argmins)
    }

    /// Total primitive count (elements + block minimums).
    pub fn prim_count(&self) -> usize {
        self.n + self.nb
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::point_in_footprint;
    use crate::util::proptest::{check, gen};

    #[test]
    fn layout_shapes() {
        let l = BlockLayout::new(100, 16);
        assert_eq!(l.nb, 7);
        assert_eq!(l.grid, 3); // ceil(sqrt(8)) = 3
        assert_eq!(l.block_len(6), 100 - 96);
        assert_eq!(l.prim_count(), 107);
    }

    #[test]
    fn cells_are_disjoint() {
        // Triangles of one cell must never be hit by rays of another.
        let l = BlockLayout::new(64, 8);
        let xs: Vec<f32> = (0..64).map(|i| (i as f32) / 64.0).collect();
        let (tris, _, _) = l.build_scene(&xs);
        // For every block b and full-block ray, the hits must be exactly
        // that block's elements.
        for b in 0..l.nb {
            let ray = l.ray_for_block_query(b, 0, l.block_len(b) - 1, -1.0);
            for t in &tris {
                let hit = point_in_footprint(ray.origin[1], ray.origin[2], t);
                let prim = t.prim as usize;
                let expect = prim < 64 && prim / l.bs == b; // element of b
                assert_eq!(hit, expect, "block {b} prim {prim}");
            }
        }
    }

    #[test]
    fn local_covering_property() {
        check("block-local triangles cover [jl,jr]", 60, |rng| {
            let n = gen::len_in(rng, 2..=512);
            let bs = 1 << rng.range(0, 6);
            let layout = BlockLayout::new(n, bs);
            let xs: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let (tris, _, _) = layout.build_scene(&xs);
            let b = rng.range(0, layout.nb - 1);
            let blen = layout.block_len(b);
            let jl = rng.range(0, blen - 1);
            let jr = rng.range(jl, blen - 1);
            let ray = layout.ray_for_block_query(b, jl, jr, -1.0);
            for t in &tris {
                let prim = t.prim as usize;
                if prim >= n {
                    // block-min triangles live in cell 0; a block ray
                    // must never touch them
                    if point_in_footprint(ray.origin[1], ray.origin[2], t) && b + 1 != 0 {
                        return Err(format!("block ray hit block-min prim {}", prim - n));
                    }
                    continue;
                }
                let hit = point_in_footprint(ray.origin[1], ray.origin[2], t);
                let expect = prim / bs == b && (jl..=jr).contains(&(prim % bs));
                if hit != expect {
                    return Err(format!(
                        "n={n} bs={bs} block={b} range=({jl},{jr}) prim={prim}: {hit}!={expect}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn blockmin_covering_property() {
        check("block-min triangles cover [bl,br]", 60, |rng| {
            let n = gen::len_in(rng, 4..=512);
            let bs = 1 << rng.range(0, 5);
            let layout = BlockLayout::new(n, bs);
            if layout.nb < 2 {
                return Ok(());
            }
            let xs: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let (tris, _, _) = layout.build_scene(&xs);
            let bl = rng.range(0, layout.nb - 1);
            let br = rng.range(bl, layout.nb - 1);
            let ray = layout.ray_for_blockmin_query(bl, br, -1.0);
            for t in &tris {
                let prim = t.prim as usize;
                let hit = point_in_footprint(ray.origin[1], ray.origin[2], t);
                let expect = prim >= n && (bl..=br).contains(&(prim - n));
                if hit != expect {
                    return Err(format!(
                        "n={n} bs={bs} blocks=({bl},{br}) prim={prim}: {hit}!={expect}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn block_argmins_are_leftmost() {
        let l = BlockLayout::new(8, 4);
        let xs = [5.0, 1.0, 1.0, 3.0, 2.0, 2.0, 9.0, 0.5];
        let (_, mins, argmins) = l.build_scene(&xs);
        assert_eq!(mins, vec![1.0, 0.5]);
        assert_eq!(argmins, vec![1, 7]);
    }
}

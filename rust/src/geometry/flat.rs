//! Flat (single-space) geometry — paper §5.2, Algorithms 1 and 2.
//!
//! Element i of an array of n values becomes a right triangle in the
//! plane x = value(i):
//!
//! ```text
//! v0 = (x, (i+1)/n, (i-1)/n)      right-angle corner (l_i, r_i)
//! v1 = (x, (i+1)/n, 2)            top
//! v2 = (x, -1,      (i-1)/n)      left
//! ```
//!
//! so a ray from `(Θ, l/n, r/n)` along +X (Θ below every value) pierces
//! exactly the triangles of elements with `l ≤ i ≤ r`, and its *closest*
//! hit is the range minimum. The one-normalized-unit border the paper
//! adds on the bottom/right edges is the `(i±1)/n` in place of `i/n`.

use super::{Ray, Triangle};

/// Normalized triangle for element `i` with value `x` (Algorithm 1).
#[inline]
pub fn triangle_for(x: f32, i: usize, n: usize) -> Triangle {
    let nf = n as f32;
    let l = (i as f32 + 1.0) / nf;
    let r = (i as f32 - 1.0) / nf;
    Triangle { v0: [x, l, r], v1: [x, l, 2.0], v2: [x, -1.0, r], prim: i as u32 }
}

/// Build the whole scene for an array (values are used as X positions
/// directly; the paper normalizes inputs to [0,1], which our workloads
/// already are — arbitrary values also work as long as `ray_origin_x`
/// is below all of them).
pub fn build_scene(xs: &[f32]) -> Vec<Triangle> {
    let n = xs.len();
    xs.iter().enumerate().map(|(i, &x)| triangle_for(x, i, n)).collect()
}

/// X coordinate rays start from: strictly before every triangle plane
/// (Algorithm 2's Θ).
pub fn ray_origin_x(xs: &[f32]) -> f32 {
    let min = xs.iter().copied().fold(f32::INFINITY, f32::min);
    // One unit below the minimum keeps t-values positive and well away
    // from the first plane.
    min - 1.0
}

/// Ray for `RMQ(l, r)` (Algorithm 2): origin `(Θ, l/n, r/n)`, dir +X.
#[inline]
pub fn ray_for_query(l: u32, r: u32, n: usize, theta: f32) -> Ray {
    let nf = n as f32;
    Ray::new([theta, l as f32 / nf, r as f32 / nf])
}

/// Reference hit check: does the query ray for (l, r) pierce element i's
/// triangle? Used by tests to validate the covering property without a
/// BVH.
pub fn query_hits_element(l: u32, r: u32, i: usize, xs: &[f32]) -> bool {
    let n = xs.len();
    let tri = triangle_for(xs[i], i, n);
    let ray = ray_for_query(l, r, n, ray_origin_x(xs));
    super::point_in_footprint(ray.origin[1], ray.origin[2], &tri)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, gen};

    #[test]
    fn covering_property_paper_example() {
        // Figure 5's array: [5,3,1,9,6,2]; query (3,5) must cover exactly
        // elements 3, 4, 5.
        let xs = [5.0, 3.0, 1.0, 9.0, 6.0, 2.0];
        for i in 0..6 {
            let expect = (3..=5).contains(&i);
            assert_eq!(query_hits_element(3, 5, i, &xs), expect, "elem {i}");
        }
    }

    #[test]
    fn covering_property_randomized() {
        // The geometric predicate must equal the arithmetic predicate
        // l <= i <= r for every element and query — this is the heart of
        // the paper's construction.
        check("triangle covers exactly [l,r]", 100, |rng| {
            let xs = gen::f32_array(rng, 1..=512);
            let n = xs.len();
            for _ in 0..8 {
                let (l, r) = gen::query(rng, n);
                for i in 0..n {
                    let hit = query_hits_element(l as u32, r as u32, i, &xs);
                    let expect = l <= i && i <= r;
                    if hit != expect {
                        return Err(format!(
                            "n={n} query=({l},{r}) elem={i}: geometric={hit} arithmetic={expect}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn scene_has_one_triangle_per_element() {
        let xs = [0.3, 0.1, 0.9];
        let scene = build_scene(&xs);
        assert_eq!(scene.len(), 3);
        for (i, t) in scene.iter().enumerate() {
            assert_eq!(t.prim, i as u32);
            assert_eq!(t.x_plane(), xs[i]);
        }
    }

    #[test]
    fn ray_origin_before_all_planes() {
        let xs = [0.5, 0.2, 0.8];
        let theta = ray_origin_x(&xs);
        assert!(xs.iter().all(|&x| theta < x));
    }

    #[test]
    fn closest_hit_is_range_min_geometrically() {
        // Without a BVH: brute-force the closest pierced triangle and
        // compare to the arithmetic RMQ.
        check("closest pierced plane == rmq", 80, |rng| {
            let xs = gen::f32_array(rng, 1..=256);
            let n = xs.len();
            let theta = ray_origin_x(&xs);
            for _ in 0..8 {
                let (l, r) = gen::query(rng, n);
                let ray = ray_for_query(l as u32, r as u32, n, theta);
                let mut best: Option<(f32, usize)> = None;
                for i in 0..n {
                    let tri = triangle_for(xs[i], i, n);
                    if crate::geometry::point_in_footprint(ray.origin[1], ray.origin[2], &tri) {
                        let t = tri.x_plane() - theta;
                        let better = match best {
                            None => true,
                            Some((bt, bi)) => t < bt || (t == bt && i < bi),
                        };
                        if better {
                            best = Some((t, i));
                        }
                    }
                }
                let got = best.expect("ray must hit in-range triangles").1;
                let want = crate::rmq::naive_rmq(&xs, l, r);
                if got != want {
                    return Err(format!("({l},{r}): geometric {got}, rmq {want}"));
                }
            }
            Ok(())
        });
    }
}

//! L3 coordinator — the serving system around the solvers, in the
//! vLLM-router mold (DESIGN.md §3):
//!
//! - [`engine`]: uniform [`engine::Engine`] wrappers over RTXRMQ / LCA /
//!   HRMQ / EXHAUSTIVE and the PJRT-backed XLA engine — organised into
//!   versioned **epochs** with a background rebuild/re-shard lifecycle
//!   ([`engine::EpochState`]) so static engines recover from mutation.
//! - [`router`]: picks an engine per request from the batch's range-length
//!   statistics using the cost models (the Fig. 10 regimes as a policy),
//!   within the current epoch's freshness ([`router::Router::route_epoch`]).
//! - [`batcher`]: dynamic batching with bounded queues (backpressure).
//! - [`server`]: the request loop (std threads + channels; the offline
//!   environment has no tokio — documented substitution, DESIGN.md §0).
//! - [`metrics`]: per-engine latency histograms, throughput counters,
//!   lifecycle counters and the decayed traffic observation.
//! - [`tenants`]: the multi-tenant front-end — a registry of named
//!   arrays (each with its own epoch lifecycle) behind a work-stealing
//!   executor with two-class QoS and layered admission control
//!   ([`tenants::MultiCoordinator`]).

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod server;
pub mod tenants;

//! L3 coordinator — the serving system around the solvers, in the
//! vLLM-router mold (DESIGN.md §3):
//!
//! - [`engine`]: uniform [`engine::Engine`] wrappers over RTXRMQ / LCA /
//!   HRMQ / EXHAUSTIVE and the PJRT-backed XLA engine.
//! - [`router`]: picks an engine per request from the batch's range-length
//!   statistics using the cost models (the Fig. 10 regimes as a policy).
//! - [`batcher`]: dynamic batching with bounded queues (backpressure).
//! - [`server`]: the request loop (std threads + channels; the offline
//!   environment has no tokio — documented substitution, DESIGN.md §0).
//! - [`metrics`]: per-engine latency histograms and throughput counters.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod server;

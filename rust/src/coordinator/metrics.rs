//! Serving metrics: per-engine latency histograms, query/batch counts,
//! epoch-lifecycle counters, and a human-readable snapshot for the CLI
//! and the E2E example. Empty sections (no updates applied, no
//! lifecycle events, no observed traffic) are suppressed from the
//! snapshot so pure-query runs print no dead histogram lines.

use super::engine::EngineKind;
use crate::rmq::sharded::RangeStats;
use crate::util::faults::FaultStats;
use crate::util::stats::{fmt_ns, LatencyHistogram};
use crate::workload::observer::ObservedWorkload;
use std::collections::HashMap;
use std::fmt;

#[derive(Clone, Default)]
pub struct EngineMetrics {
    pub batches: u64,
    pub queries: u64,
    pub batch_latency: LatencyHistogram,
}

#[derive(Clone, Default)]
pub struct Metrics {
    per_engine: HashMap<EngineKind, EngineMetrics>,
    pub requests: u64,
    pub rejected: u64,
    /// Write path: fenced update batches applied by the mutable engine.
    pub update_batches: u64,
    /// Write path: total point updates applied.
    pub updates: u64,
    pub update_latency: LatencyHistogram,
    /// Write path: lazy range updates (`add`/`assign` over `[l,r]`)
    /// applied by the sharded engine.
    pub range_updates: u64,
    /// Write path: fully-covered blocks that took the O(1) lazy-tag
    /// path (instanced `v_lo` shift or constant-block collapse) instead
    /// of a value rebuild.
    pub tag_hits: u64,
    /// Pipeline: update segments whose refit work was staged on the
    /// overlap lane while the preceding query segment executed.
    pub staged_batches: u64,
    /// Pipeline: staged commits that installed the prepared work as-is.
    pub staged_installed: u64,
    /// Pipeline: staged commits voided by a conflicting write or
    /// re-shard, re-applied through the direct path at the fence.
    pub staged_fallbacks: u64,
    /// Pipeline: total ns of update preparation hidden behind query
    /// execution (per segment: min(prepare wall-clock, dispatch→fence
    /// gap) — the latency the two-lane executor removed vs a serial
    /// refit-at-the-fence).
    pub overlap_ns_hidden_total: u64,
    /// Pipeline: per-segment distribution of the hidden preparation ns.
    pub overlap_hidden: LatencyHistogram,
    /// Lifecycle: latest published epoch version.
    pub epoch_version: u64,
    /// Lifecycle: background static rebuilds completed.
    pub rebuilds: u64,
    /// Lifecycle: online re-shards completed.
    pub reshards: u64,
    /// Lifecycle: wall-clock of completed static rebuilds.
    pub rebuild_latency: LatencyHistogram,
    /// Live sharded block size (0 until the serving loop records one).
    pub shard_block: usize,
    /// Decayed traffic observation (`workload::observer`), refreshed by
    /// the serving loop after every fused batch.
    pub observed: Option<ObservedWorkload>,
    /// Faults: injected events fired by the `util::faults` registry
    /// (0 on a production run with no `--inject` schedule).
    pub injected_faults: u64,
    /// Faults: panics caught at an isolation boundary (pool join, stager,
    /// builder loop, serving-loop backstop) — injected *or* genuine.
    pub caught_panics: u64,
    /// Faults: poisoned locks transparently recovered by
    /// `util::sync`.
    pub lock_recoveries: u64,
    /// Faults: background builder job-loop respawns after a caught panic.
    pub builder_respawns: u64,
    /// Faults: degraded-path events — a dead staged preparation falling
    /// back to the direct apply, or a batch lost to the serving-loop
    /// backstop.
    pub degraded_fallbacks: u64,
    /// QoS: fused batches served while the drained queue head was
    /// interactive-class (multi-tenant executor only; the single-array
    /// coordinator never tags a class, so both stay 0 there).
    pub interactive_batches: u64,
    /// QoS: per-batch service latency of interactive-class drains.
    pub interactive_latency: LatencyHistogram,
    /// QoS: fused batches served while the drained head was bulk-class.
    pub bulk_batches: u64,
    /// QoS: per-batch service latency of bulk-class drains.
    pub bulk_latency: LatencyHistogram,
    /// Shedding: requests rejected at admission (queue at watermark).
    pub shed: u64,
    /// Shedding: requests dropped because their deadline expired (at
    /// admission or at batch build time).
    pub deadline_expired: u64,
    pub started: Option<std::time::Instant>,
    /// Correlation labels, prefixed to the snapshot header when set:
    /// the manifest's run token (`run=<id>`) and, under the
    /// multi-tenant front-end, the owning tenant (`tenant=<name>`).
    pub run_id: Option<String>,
    pub tenant: Option<String>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics { started: Some(std::time::Instant::now()), ..Default::default() }
    }

    /// Attach correlation labels (manifest run token, tenant name) to
    /// every later snapshot render.
    pub fn set_labels(&mut self, run_id: Option<String>, tenant: Option<String>) {
        self.run_id = run_id;
        self.tenant = tenant;
    }

    pub fn record_batch(&mut self, kind: EngineKind, queries: u64, latency_ns: u64) {
        let e = self.per_engine.entry(kind).or_default();
        e.batches += 1;
        e.queries += queries;
        e.batch_latency.record(latency_ns);
    }

    pub fn record_request(&mut self) {
        self.requests += 1;
    }

    pub fn record_rejected(&mut self) {
        self.rejected += 1;
    }

    pub fn record_update_batch(&mut self, updates: u64, latency_ns: u64) {
        self.update_batches += 1;
        self.updates += updates;
        self.update_latency.record(latency_ns);
    }

    /// A staged (pipelined) update segment committed at its fence.
    /// `installed` is whether the prepared work survived the conflict
    /// checks; `hidden_ns` is the preparation time that overlapped the
    /// preceding query segment.
    pub fn record_staged_commit(&mut self, installed: bool, hidden_ns: u64) {
        self.staged_batches += 1;
        if installed {
            self.staged_installed += 1;
        } else {
            self.staged_fallbacks += 1;
        }
        self.overlap_ns_hidden_total += hidden_ns;
        self.overlap_hidden.record(hidden_ns);
    }

    /// A background static rebuild published epoch `version`.
    pub fn record_rebuild(&mut self, version: u64, latency_ns: u64) {
        self.rebuilds += 1;
        self.epoch_version = self.epoch_version.max(version);
        self.rebuild_latency.record(latency_ns);
    }

    /// A background re-shard published epoch `version` at `block`.
    pub fn record_reshard(&mut self, version: u64, block: usize) {
        self.reshards += 1;
        self.epoch_version = self.epoch_version.max(version);
        self.shard_block = block;
    }

    /// The serving loop's per-batch refresh of the decayed traffic view
    /// and live lifecycle observables.
    pub fn record_observed(&mut self, obs: ObservedWorkload, epoch_version: u64, block: usize) {
        self.observed = Some(obs);
        self.epoch_version = self.epoch_version.max(epoch_version);
        self.shard_block = block;
    }

    /// Mirror the fault registry's live counters (cumulative since the
    /// registry was last armed; monotone, so overwrite is exact). The
    /// serving loop refreshes this after every batch.
    pub fn record_faults(&mut self, s: FaultStats) {
        self.injected_faults = self.injected_faults.max(s.injected());
        self.caught_panics = self.caught_panics.max(s.caught);
        self.lock_recoveries = self.lock_recoveries.max(s.lock_recovered);
    }

    /// Mirror the engine's cumulative range-update counters (monotone
    /// within one engine's lifetime and adopted across installs and
    /// re-shards, so overwrite-by-max is exact — same contract as
    /// [`record_faults`](Self::record_faults)).
    pub fn record_range_stats(&mut self, s: RangeStats) {
        self.range_updates = self.range_updates.max(s.range_updates);
        self.tag_hits = self.tag_hits.max(s.tag_hits);
    }

    /// The background builder respawned its job loop after a panic.
    pub fn record_builder_respawn(&mut self) {
        self.builder_respawns += 1;
    }

    /// A degraded-path event: staged-prepare death fell back to the
    /// direct apply, or a batch was lost to the serving-loop backstop.
    pub fn record_degraded(&mut self) {
        self.degraded_fallbacks += 1;
    }

    /// One fused batch served by the multi-tenant executor, tagged with
    /// the QoS class of the queue head that was drained. Interactive
    /// heads are picked strictly before bulk heads, so the split
    /// histograms are the direct evidence the pick order holds under
    /// load (an interactive p99 tracking the bulk p99 means it doesn't).
    pub fn record_class_batch(&mut self, interactive: bool, latency_ns: u64) {
        if interactive {
            self.interactive_batches += 1;
            self.interactive_latency.record(latency_ns);
        } else {
            self.bulk_batches += 1;
            self.bulk_latency.record(latency_ns);
        }
    }

    /// A request was shed at admission (queue depth at the watermark).
    pub fn record_shed(&mut self) {
        self.shed += 1;
    }

    /// A request was dropped because its deadline expired.
    pub fn record_expired(&mut self) {
        self.deadline_expired += 1;
    }

    fn any_faults(&self) -> bool {
        self.injected_faults > 0
            || self.caught_panics > 0
            || self.lock_recoveries > 0
            || self.builder_respawns > 0
            || self.degraded_fallbacks > 0
            || self.shed > 0
            || self.deadline_expired > 0
    }

    pub fn engine(&self, kind: EngineKind) -> Option<&EngineMetrics> {
        self.per_engine.get(&kind)
    }

    pub fn total_queries(&self) -> u64 {
        self.per_engine.values().map(|e| e.queries).sum()
    }

    /// Overall throughput in queries/second since start.
    pub fn throughput_qps(&self) -> f64 {
        match self.started {
            Some(t0) => {
                let s = t0.elapsed().as_secs_f64();
                if s > 0.0 {
                    self.total_queries() as f64 / s
                } else {
                    0.0
                }
            }
            None => 0.0,
        }
    }

    /// Manifest-shaped snapshot: the counters a soak's claims rest on,
    /// as a JSON object (`util::manifest` embeds one per run, one per
    /// tenant under the multi-tenant front-end).
    pub fn summary_json(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        let mut pairs: Vec<(&str, Json)> = vec![
            ("requests", Json::Num(self.requests as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("total_queries", Json::Num(self.total_queries() as f64)),
            ("updates", Json::Num(self.updates as f64)),
            ("update_batches", Json::Num(self.update_batches as f64)),
            ("range_updates", Json::Num(self.range_updates as f64)),
            ("tag_hits", Json::Num(self.tag_hits as f64)),
            ("staged_batches", Json::Num(self.staged_batches as f64)),
            ("staged_installed", Json::Num(self.staged_installed as f64)),
            ("epoch_version", Json::Num(self.epoch_version as f64)),
            ("rebuilds", Json::Num(self.rebuilds as f64)),
            ("reshards", Json::Num(self.reshards as f64)),
            ("shard_block", Json::Num(self.shard_block as f64)),
            ("injected_faults", Json::Num(self.injected_faults as f64)),
            ("caught_panics", Json::Num(self.caught_panics as f64)),
            ("builder_respawns", Json::Num(self.builder_respawns as f64)),
            ("degraded_fallbacks", Json::Num(self.degraded_fallbacks as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("deadline_expired", Json::Num(self.deadline_expired as f64)),
        ];
        // Per-class service latency, present only when the class was
        // actually drained (keeps single-array summaries unchanged).
        if self.interactive_batches > 0 {
            pairs.push(("interactive_batches", Json::Num(self.interactive_batches as f64)));
            pairs.push((
                "interactive_p50_ns",
                Json::Num(self.interactive_latency.quantile_ns(0.5) as f64),
            ));
            pairs.push((
                "interactive_p99_ns",
                Json::Num(self.interactive_latency.quantile_ns(0.99) as f64),
            ));
        }
        if self.bulk_batches > 0 {
            pairs.push(("bulk_batches", Json::Num(self.bulk_batches as f64)));
            pairs.push(("bulk_p50_ns", Json::Num(self.bulk_latency.quantile_ns(0.5) as f64)));
            pairs.push(("bulk_p99_ns", Json::Num(self.bulk_latency.quantile_ns(0.99) as f64)));
        }
        if let Some(t) = &self.tenant {
            pairs.push(("tenant", Json::Str(t.clone())));
        }
        obj(pairs)
    }
}

impl fmt::Display for Metrics {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Label prefixes come first so the header stays grep-stable:
        // every existing consumer matches from `requests=` onward.
        if let Some(rid) = &self.run_id {
            write!(f, "run={rid} ")?;
        }
        if let Some(t) = &self.tenant {
            write!(f, "tenant={t} ")?;
        }
        writeln!(
            f,
            "requests={} rejected={} total_queries={} throughput={:.0} q/s",
            self.requests,
            self.rejected,
            self.total_queries(),
            self.throughput_qps()
        )?;
        let mut kinds: Vec<_> = self.per_engine.keys().copied().collect();
        kinds.sort_by_key(|k| k.name());
        for k in kinds {
            let e = &self.per_engine[&k];
            writeln!(
                f,
                "  {:<10} batches={:<6} queries={:<9} batch p50={} p99={} mean={}",
                k.name(),
                e.batches,
                e.queries,
                fmt_ns(e.batch_latency.quantile_ns(0.5) as f64),
                fmt_ns(e.batch_latency.quantile_ns(0.99) as f64),
                fmt_ns(e.batch_latency.mean_ns()),
            )?;
        }
        // Per-class service lines only under the multi-tenant executor
        // (the single-array path never tags a class, so nothing prints).
        for (label, batches, hist) in [
            ("interactive", self.interactive_batches, &self.interactive_latency),
            ("bulk", self.bulk_batches, &self.bulk_latency),
        ] {
            if batches > 0 {
                writeln!(
                    f,
                    "  {:<10} batches={:<6} batch p50={} p99={} mean={}",
                    label,
                    batches,
                    fmt_ns(hist.quantile_ns(0.5) as f64),
                    fmt_ns(hist.quantile_ns(0.99) as f64),
                    fmt_ns(hist.mean_ns()),
                )?;
            }
        }
        // Pure-query runs print no empty update histogram line.
        if self.update_batches > 0 && self.updates > 0 {
            writeln!(
                f,
                "  {:<10} batches={:<6} points={:<9} batch p50={} p99={} mean={}",
                "updates",
                self.update_batches,
                self.updates,
                fmt_ns(self.update_latency.quantile_ns(0.5) as f64),
                fmt_ns(self.update_latency.quantile_ns(0.99) as f64),
                fmt_ns(self.update_latency.mean_ns()),
            )?;
        }
        // Range-tag line only when a range update landed.
        if self.range_updates > 0 {
            writeln!(
                f,
                "  {:<10} range_updates={} tag_hits={}",
                "ranges", self.range_updates, self.tag_hits,
            )?;
        }
        // Pipeline line only when the two-lane executor staged work.
        if self.staged_batches > 0 {
            writeln!(
                f,
                "  {:<10} staged={} installed={} fallbacks={} overlap_ns_hidden={} hidden p50={}",
                "pipeline",
                self.staged_batches,
                self.staged_installed,
                self.staged_fallbacks,
                self.overlap_ns_hidden_total,
                fmt_ns(self.overlap_hidden.quantile_ns(0.5) as f64),
            )?;
        }
        // Lifecycle line only once something happened.
        if self.epoch_version > 0 || self.rebuilds > 0 || self.reshards > 0 {
            write!(
                f,
                "  {:<10} epoch={} rebuilds={} reshards={} shard_block={}",
                "lifecycle", self.epoch_version, self.rebuilds, self.reshards, self.shard_block,
            )?;
            if self.rebuilds > 0 {
                write!(f, " rebuild p50={}", fmt_ns(self.rebuild_latency.quantile_ns(0.5) as f64))?;
            }
            writeln!(f)?;
        }
        // Fault/shed accounting, suppressed on a clean run (the common
        // case: no injection, no panics, no overload).
        if self.any_faults() {
            writeln!(
                f,
                "  {:<10} injected={} caught={} lock_recovered={} respawns={} fallbacks={} \
                 shed={} expired={}",
                "faults",
                self.injected_faults,
                self.caught_panics,
                self.lock_recoveries,
                self.builder_respawns,
                self.degraded_fallbacks,
                self.shed,
                self.deadline_expired,
            )?;
        }
        // Decayed traffic view, suppressed until traffic was observed.
        if let Some(o) = &self.observed {
            if o.ops > 0 {
                writeln!(
                    f,
                    "  {:<10} ops={} mean_range={:.1} mean_batch={:.1} update_frac={:.4}",
                    "observed", o.ops, o.mean_range, o.mean_batch, o.update_frac,
                )?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_aggregates() {
        let mut m = Metrics::new();
        m.record_request();
        m.record_batch(EngineKind::Rtx, 100, 1_000);
        m.record_batch(EngineKind::Rtx, 50, 2_000);
        m.record_batch(EngineKind::Lca, 10, 500);
        assert_eq!(m.total_queries(), 160);
        assert_eq!(m.engine(EngineKind::Rtx).unwrap().batches, 2);
        assert!(m.engine(EngineKind::Xla).is_none());
        let text = m.to_string();
        assert!(text.contains("RTXRMQ") && text.contains("LCA"));
    }

    #[test]
    fn records_update_batches_separately() {
        let mut m = Metrics::new();
        m.record_update_batch(16, 2_000);
        m.record_update_batch(4, 1_000);
        assert_eq!(m.update_batches, 2);
        assert_eq!(m.updates, 20);
        // The write path never inflates query throughput.
        assert_eq!(m.total_queries(), 0);
        assert!(m.to_string().contains("updates"));
    }

    #[test]
    fn pure_query_snapshot_has_no_update_or_lifecycle_lines() {
        let mut m = Metrics::new();
        m.record_request();
        m.record_batch(EngineKind::Lca, 64, 1_000);
        let text = m.to_string();
        assert!(!text.contains("updates"), "{text}");
        assert!(!text.contains("lifecycle"), "{text}");
        assert!(!text.contains("observed"), "{text}");
        assert!(!text.contains("pipeline"), "{text}");
        assert!(!text.contains("ranges"), "{text}");
    }

    #[test]
    fn range_stats_line_appears_and_merges_by_max() {
        let mut m = Metrics::new();
        assert!(!m.to_string().contains("ranges"), "{m}");
        m.record_range_stats(RangeStats { range_updates: 3, tag_hits: 17 });
        // Cumulative engine counters: a later, larger snapshot
        // overwrites; a stale smaller one never regresses the line.
        m.record_range_stats(RangeStats { range_updates: 2, tag_hits: 5 });
        assert_eq!(m.range_updates, 3);
        assert_eq!(m.tag_hits, 17);
        let text = m.to_string();
        assert!(text.contains("ranges"), "{text}");
        assert!(text.contains("range_updates=3 tag_hits=17"), "{text}");
        let j = m.summary_json();
        assert_eq!(j.get("range_updates").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("tag_hits").unwrap().as_u64(), Some(17));
    }

    #[test]
    fn staged_commits_roll_up_into_the_pipeline_line() {
        let mut m = Metrics::new();
        m.record_staged_commit(true, 40_000);
        m.record_staged_commit(true, 10_000);
        m.record_staged_commit(false, 0);
        assert_eq!(m.staged_batches, 3);
        assert_eq!(m.staged_installed, 2);
        assert_eq!(m.staged_fallbacks, 1);
        assert_eq!(m.overlap_ns_hidden_total, 50_000);
        let text = m.to_string();
        assert!(text.contains("pipeline"), "{text}");
        assert!(text.contains("staged=3 installed=2 fallbacks=1"), "{text}");
        assert!(text.contains("overlap_ns_hidden=50000"), "{text}");
    }

    #[test]
    fn lifecycle_and_observed_lines_appear_when_recorded() {
        let mut m = Metrics::new();
        m.record_rebuild(1, 5_000_000);
        m.record_reshard(2, 256);
        let obs = ObservedWorkload {
            mean_range: 42.5,
            mean_batch: 128.0,
            update_frac: 0.125,
            ops: 1000,
            ..Default::default()
        };
        m.record_observed(obs, 2, 256);
        assert_eq!(m.epoch_version, 2);
        assert_eq!(m.rebuilds, 1);
        assert_eq!(m.reshards, 1);
        assert_eq!(m.shard_block, 256);
        let text = m.to_string();
        assert!(text.contains("lifecycle"), "{text}");
        assert!(text.contains("epoch=2 rebuilds=1 reshards=1 shard_block=256"), "{text}");
        assert!(text.contains("observed"), "{text}");
        assert!(text.contains("update_frac=0.1250"), "{text}");
        // An empty observation stays suppressed.
        let mut quiet = Metrics::new();
        quiet.record_observed(ObservedWorkload::default(), 0, 64);
        assert!(!quiet.to_string().contains("observed"));
    }

    #[test]
    fn faults_line_appears_only_when_something_went_wrong() {
        let mut m = Metrics::new();
        m.record_batch(EngineKind::Lca, 64, 1_000);
        assert!(!m.to_string().contains("faults"), "{m}");
        // A clean registry snapshot keeps the line suppressed.
        m.record_faults(FaultStats::default());
        assert!(!m.to_string().contains("faults"), "{m}");
        m.record_faults(FaultStats {
            injected_panics: 2,
            injected_delays: 1,
            injected_errors: 0,
            caught: 2,
            lock_recovered: 1,
        });
        m.record_builder_respawn();
        m.record_degraded();
        m.record_shed();
        m.record_expired();
        let text = m.to_string();
        assert!(
            text.contains(
                "injected=3 caught=2 lock_recovered=1 respawns=1 fallbacks=1 shed=1 expired=1"
            ),
            "{text}"
        );
        // Registry counters are cumulative: a later, larger snapshot
        // overwrites; a stale smaller one never regresses the line.
        m.record_faults(FaultStats { injected_panics: 5, caught: 4, ..Default::default() });
        m.record_faults(FaultStats::default());
        assert_eq!(m.injected_faults, 5);
        assert_eq!(m.caught_panics, 4);
        assert_eq!(m.lock_recoveries, 1);
    }

    #[test]
    fn labels_prefix_the_header_without_moving_it() {
        let mut m = Metrics::new();
        m.record_request();
        assert!(m.to_string().starts_with("requests="), "{m}");
        m.set_labels(Some("cafe0123deadbeef".into()), Some("bulk".into()));
        let text = m.to_string();
        assert!(text.starts_with("run=cafe0123deadbeef tenant=bulk requests="), "{text}");
        // Existing consumers still match from `requests=` onward.
        assert!(text.contains("requests=1 rejected=0"), "{text}");
    }

    #[test]
    fn summary_json_carries_the_soak_counters() {
        let mut m = Metrics::new();
        m.record_request();
        m.record_batch(EngineKind::Sharded, 64, 1_000);
        m.record_shed();
        m.record_rebuild(3, 1_000);
        m.set_labels(None, Some("interactive".into()));
        let j = m.summary_json();
        assert_eq!(j.get("requests").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("total_queries").unwrap().as_u64(), Some(64));
        assert_eq!(j.get("shed").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("rebuilds").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("tenant").unwrap().as_str(), Some("interactive"));
    }

    #[test]
    fn class_latency_lines_split_by_class_and_stay_suppressed_when_untagged() {
        let mut m = Metrics::new();
        m.record_batch(EngineKind::Lca, 64, 1_000);
        // Single-array path: no class tags, no class lines.
        let text = m.to_string();
        assert!(!text.contains("interactive"), "{text}");
        assert!(!text.contains("bulk"), "{text}");
        m.record_class_batch(true, 2_000);
        m.record_class_batch(true, 4_000);
        let text = m.to_string();
        assert!(text.contains("interactive"), "{text}");
        assert!(!text.contains("bulk"), "one drained class prints one line: {text}");
        m.record_class_batch(false, 8_000);
        let text = m.to_string();
        assert!(text.contains("interactive") && text.contains("bulk"), "{text}");
        assert_eq!(m.interactive_batches, 2);
        assert_eq!(m.bulk_batches, 1);
        let j = m.summary_json();
        assert_eq!(j.get("interactive_batches").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("bulk_batches").unwrap().as_u64(), Some(1));
        assert!(j.get("interactive_p99_ns").is_some() && j.get("bulk_p50_ns").is_some());
        // An untagged snapshot exports none of the class keys.
        let quiet = Metrics::new();
        let j = quiet.summary_json();
        assert!(j.get("interactive_batches").is_none() && j.get("bulk_batches").is_none());
    }

    #[test]
    fn throughput_positive_after_work() {
        let mut m = Metrics::new();
        m.record_batch(EngineKind::Hrmq, 1000, 10);
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(m.throughput_qps() > 0.0);
    }
}

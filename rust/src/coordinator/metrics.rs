//! Serving metrics: per-engine latency histograms, query/batch counts,
//! and a human-readable snapshot for the CLI and the E2E example.

use super::engine::EngineKind;
use crate::util::stats::{fmt_ns, LatencyHistogram};
use std::collections::HashMap;
use std::fmt;

#[derive(Clone, Default)]
pub struct EngineMetrics {
    pub batches: u64,
    pub queries: u64,
    pub batch_latency: LatencyHistogram,
}

#[derive(Clone, Default)]
pub struct Metrics {
    per_engine: HashMap<EngineKind, EngineMetrics>,
    pub requests: u64,
    pub rejected: u64,
    /// Write path: fenced update batches applied by the mutable engine.
    pub update_batches: u64,
    /// Write path: total point updates applied.
    pub updates: u64,
    pub update_latency: LatencyHistogram,
    pub started: Option<std::time::Instant>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics { started: Some(std::time::Instant::now()), ..Default::default() }
    }

    pub fn record_batch(&mut self, kind: EngineKind, queries: u64, latency_ns: u64) {
        let e = self.per_engine.entry(kind).or_default();
        e.batches += 1;
        e.queries += queries;
        e.batch_latency.record(latency_ns);
    }

    pub fn record_request(&mut self) {
        self.requests += 1;
    }

    pub fn record_rejected(&mut self) {
        self.rejected += 1;
    }

    pub fn record_update_batch(&mut self, updates: u64, latency_ns: u64) {
        self.update_batches += 1;
        self.updates += updates;
        self.update_latency.record(latency_ns);
    }

    pub fn engine(&self, kind: EngineKind) -> Option<&EngineMetrics> {
        self.per_engine.get(&kind)
    }

    pub fn total_queries(&self) -> u64 {
        self.per_engine.values().map(|e| e.queries).sum()
    }

    /// Overall throughput in queries/second since start.
    pub fn throughput_qps(&self) -> f64 {
        match self.started {
            Some(t0) => {
                let s = t0.elapsed().as_secs_f64();
                if s > 0.0 {
                    self.total_queries() as f64 / s
                } else {
                    0.0
                }
            }
            None => 0.0,
        }
    }
}

impl fmt::Display for Metrics {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "requests={} rejected={} total_queries={} throughput={:.0} q/s",
            self.requests,
            self.rejected,
            self.total_queries(),
            self.throughput_qps()
        )?;
        let mut kinds: Vec<_> = self.per_engine.keys().copied().collect();
        kinds.sort_by_key(|k| k.name());
        for k in kinds {
            let e = &self.per_engine[&k];
            writeln!(
                f,
                "  {:<10} batches={:<6} queries={:<9} batch p50={} p99={} mean={}",
                k.name(),
                e.batches,
                e.queries,
                fmt_ns(e.batch_latency.quantile_ns(0.5) as f64),
                fmt_ns(e.batch_latency.quantile_ns(0.99) as f64),
                fmt_ns(e.batch_latency.mean_ns()),
            )?;
        }
        if self.update_batches > 0 {
            writeln!(
                f,
                "  {:<10} batches={:<6} points={:<9} batch p50={} p99={} mean={}",
                "updates",
                self.update_batches,
                self.updates,
                fmt_ns(self.update_latency.quantile_ns(0.5) as f64),
                fmt_ns(self.update_latency.quantile_ns(0.99) as f64),
                fmt_ns(self.update_latency.mean_ns()),
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_aggregates() {
        let mut m = Metrics::new();
        m.record_request();
        m.record_batch(EngineKind::Rtx, 100, 1_000);
        m.record_batch(EngineKind::Rtx, 50, 2_000);
        m.record_batch(EngineKind::Lca, 10, 500);
        assert_eq!(m.total_queries(), 160);
        assert_eq!(m.engine(EngineKind::Rtx).unwrap().batches, 2);
        assert!(m.engine(EngineKind::Xla).is_none());
        let text = m.to_string();
        assert!(text.contains("RTXRMQ") && text.contains("LCA"));
    }

    #[test]
    fn records_update_batches_separately() {
        let mut m = Metrics::new();
        m.record_update_batch(16, 2_000);
        m.record_update_batch(4, 1_000);
        assert_eq!(m.update_batches, 2);
        assert_eq!(m.updates, 20);
        // The write path never inflates query throughput.
        assert_eq!(m.total_queries(), 0);
        assert!(m.to_string().contains("updates"));
    }

    #[test]
    fn throughput_positive_after_work() {
        let mut m = Metrics::new();
        m.record_batch(EngineKind::Hrmq, 1000, 10);
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(m.throughput_qps() > 0.0);
    }
}

//! Request router: picks the engine for a batch from its range-length
//! statistics — operationalising the paper's Fig. 10/12 findings (RTXRMQ
//! wins small ranges; LCA wins medium/large; EXHAUSTIVE is only ever
//! competitive for tiny ranges on small arrays).
//!
//! Two policies:
//! - [`Policy::Heuristic`] — the regime thresholds read directly off the
//!   paper's results.
//! - [`Policy::ModeledCost`] — asks the cost models (`crate::model`) for
//!   a per-engine estimate and picks the cheapest available. This is the
//!   default: the router literally runs the paper's performance model at
//!   admission time.

use super::engine::EngineKind;
use crate::model::{CudaCostModel, LcaCostModel, RtCostModel};
use crate::rmq::Query;
use crate::rtcore::arch::{ArchProfile, LOVELACE_RTX6000ADA};
use crate::workload::mean_range_len;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Policy {
    ModeledCost,
    Heuristic,
    Fixed(EngineKind),
}

pub struct Router {
    pub policy: Policy,
    pub gpu: ArchProfile,
    rt_model: RtCostModel,
    lca_model: LcaCostModel,
    cuda_model: CudaCostModel,
}

impl Router {
    pub fn new(policy: Policy) -> Router {
        Router {
            policy,
            gpu: LOVELACE_RTX6000ADA,
            rt_model: RtCostModel::default(),
            lca_model: LcaCostModel::default(),
            cuda_model: CudaCostModel::default(),
        }
    }

    /// Serving-loop entry point: route within one engine epoch.
    /// `fresh` is the epoch's freshness (`built_from_seq` equals the
    /// published applied-update sequence — `EpochState::is_fresh`).
    ///
    /// On a stale epoch only engines that track updates in place still
    /// match the served values, so availability collapses to the
    /// sharded engine — a uniform *availability* rule, not a policy
    /// override. This replaced the old sticky `mutated` flag and its
    /// explicit `Policy::Fixed` special case: a pin chooses among fresh
    /// engines like every other policy, and the moment the background
    /// rebuild publishes a fresh epoch the pin (and the Fig. 12
    /// crossover routing) is honored again instead of being lost for
    /// the rest of the process lifetime.
    pub fn route_epoch(
        &self,
        n: usize,
        queries: &[Query],
        available: &[EngineKind],
        fresh: bool,
    ) -> EngineKind {
        if !fresh && available.contains(&EngineKind::Sharded) {
            return EngineKind::Sharded;
        }
        self.route(n, queries, available)
    }

    /// Choose an engine for a batch against an array of length `n`.
    /// `available` lists the engines actually built (XLA may be absent).
    pub fn route(&self, n: usize, queries: &[Query], available: &[EngineKind]) -> EngineKind {
        // A fixed pin never inspects the batch — skip the O(batch) scan.
        let mean = if matches!(self.policy, Policy::Fixed(_)) {
            0.0
        } else {
            mean_range_len(queries)
        };
        let mut choice = match self.policy {
            Policy::Fixed(k) => k,
            Policy::Heuristic => self.heuristic(n, mean),
            Policy::ModeledCost => self.modeled(n, queries.len() as u64, mean),
        };
        // The paper's EXHAUSTIVE is a GPU kernel; our GPU form of it is
        // the AOT-compiled Pallas kernel behind the XLA engine — prefer
        // it whenever an artifact variant fits this array.
        if choice == EngineKind::Exhaustive && available.contains(&EngineKind::Xla) {
            choice = EngineKind::Xla;
        }
        // The blocked decomposition converts any small/medium range into
        // ≤2 partial-block probes plus one summary probe — all in the
        // regime RTXRMQ wins by construction (Fig. 10) — so those batches
        // go to the shards when they are built. Large ranges stay on the
        // monolithic engines (Fig. 12's crossover: LCA owns that regime),
        // tiny arrays keep their winner (Fig. 12: EXHAUSTIVE), and a
        // `Policy::Fixed` pin is honored verbatim — never upgraded.
        if !matches!(self.policy, Policy::Fixed(_))
            && available.contains(&EngineKind::Sharded)
            && matches!(choice, EngineKind::Rtx | EngineKind::Lca)
            && n > (1 << 14)
        {
            // Small ≈ n^0.3 and Medium ≈ n^0.6 both fall under this
            // cutoff; Large ≈ n/2 exceeds it for any serving-scale n.
            if mean <= (n as f64).powf(0.65) {
                choice = EngineKind::Sharded;
            }
        }
        if available.contains(&choice) {
            choice
        } else {
            // Deterministic fallback order.
            [EngineKind::Lca, EngineKind::Rtx, EngineKind::Hrmq, EngineKind::Exhaustive]
                .into_iter()
                .find(|k| available.contains(k))
                .unwrap_or(EngineKind::Exhaustive)
        }
    }

    /// Paper-regime thresholds: the Small distribution has mean ≈ n^0.3,
    /// Medium ≈ n^0.6 (§6.4). RTXRMQ wins the small regime once n is
    /// large (Fig. 12 right column); LCA wins the rest. `mean` is the
    /// batch's mean range length (computed once by `route`).
    fn heuristic(&self, n: usize, mean: f64) -> EngineKind {
        let nf = n as f64;
        if mean <= nf.powf(0.45).max(32.0) {
            if n < (1 << 14) {
                // Fig. 12: EXHAUSTIVE is surprisingly the fastest for
                // small ranges on small problem sizes (~2^15).
                EngineKind::Exhaustive
            } else {
                EngineKind::Rtx
            }
        } else {
            EngineKind::Lca
        }
    }

    /// Cost-model policy: pre-execution *forecasts* per engine (the
    /// post-hoc models in `crate::model` convert measured work; routing
    /// needs an estimate before executing anything). Forecast anchors are
    /// the paper's Fig. 12 saturated endpoints on the reference GPU
    /// (ns/RMQ at n = 1e8: RTX 1/2/5 for S/M/L, LCA 2.3/1.6/1.0), with
    /// batch-saturation from Fig. 13 applied on top.
    fn modeled(&self, n: usize, q: u64, mean: f64) -> EngineKind {
        let mean = mean.max(1.0);
        let nf = n as f64;
        let bs = nf.sqrt().max(2.0);

        // RTXRMQ: traversal work grows with how many block-min boxes the
        // interior ray crosses — interpolate between the small-range and
        // large-range anchors on that axis.
        let span = (1.0 + mean / bs).log2() / (1.0 + nf / (2.0 * bs)).log2().max(1e-9);
        let rtx_sat = 1.0 + 4.0 * span.clamp(0.0, 1.0);
        let rtx_util = crate::model::rtcost::saturation(q, self.rt_model.half_sat);
        let rtx_ns =
            rtx_sat / rtx_util + self.rt_model.launch_overhead_ns / q.max(1) as f64;

        // LCA: O(1) work; the n-dependence is the cache staircase and the
        // small-range penalty the paper observes in Fig. 10 (small/medium
        // ranges run *slower* than long ones at large n).
        let range_factor = self.lca_model.range_factor(mean, n);
        let lca_base = self.lca_model.ns_per_query((n as u64) * 20, q, &self.gpu);
        let lca_ns = lca_base * range_factor;

        // EXHAUSTIVE: scans `mean` elements per query.
        let ex_ns = self.cuda_model.ns_per_query(mean, (n as u64) * 4, q, &self.gpu);

        let mut best = (EngineKind::Rtx, rtx_ns);
        for (k, v) in [(EngineKind::Lca, lca_ns), (EngineKind::Exhaustive, ex_ns)] {
            if v < best.1 {
                best = (k, v);
            }
        }
        best.0
    }
}

/// Mean-range ceiling of the multi-tenant **interactive** QoS class:
/// √n. The paper's Small distribution (mean ≈ n^0.3 — the regime
/// RTXRMQ/the shards win by construction) sits well under it at any
/// serving-scale n, Medium (≈ n^0.6) and Large (≈ n/2) sit above, so
/// the class boundary matches the routing regime the interactive
/// guarantee is about: a query-only batch of shard-sized ranges is
/// cheap enough to always cut ahead of bulk work.
pub fn interactive_range_ceiling(n: usize) -> f64 {
    (n.max(1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workload::{gen_queries, RangeDist};

    fn all_kinds() -> Vec<EngineKind> {
        vec![EngineKind::Rtx, EngineKind::Lca, EngineKind::Hrmq, EngineKind::Exhaustive]
    }

    #[test]
    fn interactive_ceiling_separates_the_distributions() {
        let n = 1 << 16;
        let ceil = interactive_range_ceiling(n);
        assert_eq!(ceil, 256.0);
        // Small's mean (≈ n^0.3 ≈ 28) is interactive; Medium/Large not.
        assert!(RangeDist::Small.mean_len(n) < ceil);
        assert!(RangeDist::Medium.mean_len(n) > ceil);
        assert!(RangeDist::Large.mean_len(n) > ceil);
    }

    #[test]
    fn deterministic_routing() {
        let router = Router::new(Policy::ModeledCost);
        let mut rng = Rng::new(70);
        let n = 1 << 20;
        let qs = gen_queries(n, 512, RangeDist::Medium, &mut rng);
        let a = router.route(n, &qs, &all_kinds());
        let b = router.route(n, &qs, &all_kinds());
        assert_eq!(a, b);
    }

    #[test]
    fn heuristic_matches_paper_regimes() {
        let router = Router::new(Policy::Heuristic);
        let mut rng = Rng::new(71);
        let n = 1 << 22;
        let small = gen_queries(n, 256, RangeDist::Small, &mut rng);
        let large = gen_queries(n, 256, RangeDist::Large, &mut rng);
        assert_eq!(router.route(n, &small, &all_kinds()), EngineKind::Rtx);
        assert_eq!(router.route(n, &large, &all_kinds()), EngineKind::Lca);
    }

    #[test]
    fn heuristic_prefers_exhaustive_on_tiny_small() {
        let router = Router::new(Policy::Heuristic);
        let mut rng = Rng::new(72);
        let n = 1 << 12;
        let small = gen_queries(n, 256, RangeDist::Small, &mut rng);
        assert_eq!(router.route(n, &small, &all_kinds()), EngineKind::Exhaustive);
    }

    #[test]
    fn modeled_cost_follows_fig12_shape() {
        // At large n with a saturated batch (the paper uses q = 2^26):
        // small ranges -> RTX, large ranges -> LCA — the headline
        // crossover must be reproduced by the policy.
        let router = Router::new(Policy::ModeledCost);
        let mut rng = Rng::new(73);
        let n = 1 << 26;
        let blow_up = |qs: Vec<(u32, u32)>| -> Vec<(u32, u32)> {
            qs.iter().cycle().take(1 << 23).copied().collect()
        };
        let small = blow_up(gen_queries(n, 1024, RangeDist::Small, &mut rng));
        let large = blow_up(gen_queries(n, 1024, RangeDist::Large, &mut rng));
        assert_eq!(router.route(n, &small, &all_kinds()), EngineKind::Rtx);
        assert_eq!(router.route(n, &large, &all_kinds()), EngineKind::Lca);
    }

    #[test]
    fn modeled_cost_prefers_lca_when_rtx_unsaturated() {
        // Fig. 13: with small batches RTXRMQ cannot saturate its RT
        // cores; the router must notice and route small batches to LCA
        // even in the small-range regime.
        let router = Router::new(Policy::ModeledCost);
        let mut rng = Rng::new(74);
        let n = 1 << 26;
        let small = gen_queries(n, 256, RangeDist::Small, &mut rng);
        let got = router.route(n, &small, &all_kinds());
        assert_ne!(got, EngineKind::Rtx, "unsaturated batch must not go to RT cores");
    }

    #[test]
    fn sharded_takes_small_and_medium_when_available() {
        let mut with_sharded = all_kinds();
        with_sharded.push(EngineKind::Sharded);
        let mut rng = Rng::new(75);
        let n = 1 << 22;
        for policy in [Policy::Heuristic, Policy::ModeledCost] {
            let router = Router::new(policy);
            for dist in [RangeDist::Small, RangeDist::Medium] {
                let qs: Vec<(u32, u32)> = gen_queries(n, 1024, dist, &mut rng)
                    .iter()
                    .cycle()
                    .take(1 << 20)
                    .copied()
                    .collect();
                assert_eq!(
                    router.route(n, &qs, &with_sharded),
                    EngineKind::Sharded,
                    "{policy:?} {dist:?}"
                );
            }
            // Large ranges stay off the shards.
            let large = gen_queries(n, 1024, RangeDist::Large, &mut rng);
            assert_ne!(router.route(n, &large, &with_sharded), EngineKind::Sharded, "{policy:?}");
            // Without the sharded engine built, routing is unchanged.
            let small = gen_queries(n, 1024, RangeDist::Small, &mut rng);
            assert_ne!(router.route(n, &small, &all_kinds()), EngineKind::Sharded);
        }
    }

    #[test]
    fn fixed_policy_is_never_upgraded_to_sharded() {
        // An explicit pin must be honored verbatim even in the regime
        // the sharded upgrade targets.
        let mut with_sharded = all_kinds();
        with_sharded.push(EngineKind::Sharded);
        let mut rng = Rng::new(77);
        let n = 1 << 22;
        let small = gen_queries(n, 256, RangeDist::Small, &mut rng);
        for pinned in [EngineKind::Rtx, EngineKind::Lca] {
            let router = Router::new(Policy::Fixed(pinned));
            assert_eq!(router.route(n, &small, &with_sharded), pinned);
        }
    }

    #[test]
    fn tiny_arrays_keep_their_winner() {
        let mut with_sharded = all_kinds();
        with_sharded.push(EngineKind::Sharded);
        let router = Router::new(Policy::Heuristic);
        let mut rng = Rng::new(76);
        let n = 1 << 12;
        let small = gen_queries(n, 256, RangeDist::Small, &mut rng);
        assert_eq!(router.route(n, &small, &with_sharded), EngineKind::Exhaustive);
    }

    #[test]
    fn stale_epochs_pin_every_policy_to_sharded() {
        // On a stale epoch only the in-place-updated engine matches the
        // served values: whatever the policy or distribution, query
        // segments must go to the shards. On a fresh epoch, routing is
        // exactly `route` — including for `Policy::Fixed`, which needs
        // no special staleness override any more.
        let mut with_sharded = all_kinds();
        with_sharded.push(EngineKind::Sharded);
        let mut rng = Rng::new(78);
        let n = 1 << 20;
        for policy in [
            Policy::Heuristic,
            Policy::ModeledCost,
            Policy::Fixed(EngineKind::Lca),
            Policy::Fixed(EngineKind::Rtx),
        ] {
            let router = Router::new(policy);
            for dist in RangeDist::all() {
                let qs = gen_queries(n, 128, dist, &mut rng);
                assert_eq!(
                    router.route_epoch(n, &qs, &with_sharded, false),
                    EngineKind::Sharded,
                    "{policy:?} {dist:?}"
                );
                // A fresh epoch routes exactly like `route` — the
                // rebuilt statics are usable again.
                assert_eq!(
                    router.route_epoch(n, &qs, &with_sharded, true),
                    router.route(n, &qs, &with_sharded),
                    "{policy:?} {dist:?}"
                );
            }
        }
        // A fresh epoch re-enables a Fixed pin verbatim.
        let router = Router::new(Policy::Fixed(EngineKind::Lca));
        let qs = gen_queries(n, 64, RangeDist::Small, &mut rng);
        assert_eq!(router.route_epoch(n, &qs, &with_sharded, true), EngineKind::Lca);
        // Without a sharded engine there is nothing fresh to pin to;
        // fall through to the normal policy (callers always build it).
        let router = Router::new(Policy::Heuristic);
        let qs = gen_queries(n, 64, RangeDist::Large, &mut rng);
        assert_eq!(router.route_epoch(n, &qs, &all_kinds(), false), EngineKind::Lca);
    }

    #[test]
    fn fixed_policy_and_fallback() {
        let router = Router::new(Policy::Fixed(EngineKind::Xla));
        let qs = vec![(0u32, 1u32)];
        // XLA requested but unavailable: deterministic fallback.
        let got = router.route(100, &qs, &all_kinds());
        assert_eq!(got, EngineKind::Lca);
        // Available: honored.
        let with_xla: Vec<EngineKind> = EngineKind::all().to_vec();
        assert_eq!(router.route(100, &qs, &with_xla), EngineKind::Xla);
    }
}

//! Multi-tenant serving front-end: a registry of named arrays, each
//! owning its own epoch lifecycle (engines, observer, fault counters —
//! everything [`EpochState`] already scopes per array), behind a small
//! work-stealing executor that schedules fused batches **across**
//! tenants.
//!
//! Scheduling contract (the QoS design note in `rmq/mod.rs` has the
//! full rationale):
//!
//! - **One FIFO queue per tenant.** A tenant's op streams execute in
//!   submission order no matter how the executor interleaves tenants —
//!   the same arrival-order consistency the single-array coordinator
//!   gives, so per-tenant rolling oracles stay valid. Requests are
//!   classified once at admission ([`is_interactive`]); the queue
//!   *head*'s class is the tenant's current class.
//! - **Two-class pick order.** Workers scan interactive-headed tenants
//!   first and bulk-headed tenants only when no interactive head
//!   exists, so a small-range interactive segment is never queued
//!   behind another tenant's bulk update/rebuild work. Within a class,
//!   tenants are picked by **weighted deficit**: every scan adds the
//!   tenant's weight to its deficit, the largest deficit wins and
//!   resets — starvation-free weighted fairness without timestamps.
//! - **At most one worker per tenant** ([`Claim`]): the fence semantics
//!   of a fused batch require serial execution per array; claims make
//!   cross-tenant parallelism safe without reordering any one tenant.
//! - **Admission control is layered.** A global watermark (aggregate
//!   queued requests) sheds first, then the per-tenant watermark, then
//!   the per-tenant default deadline applies to requests that carry
//!   none. Rejections are typed ([`ServeError`]) exactly like the
//!   single-array path.
//! - **One shared builder pool.** Rebuild/re-shard jobs from every
//!   tenant funnel through [`spawn_shared_builder`] with per-tenant
//!   panic backoff, so N tenants' lifecycles cannot monopolise N cores.
//! - **Faults stay inside the batch.** Execution is backstopped per
//!   batch (including the injectable `tenant.exec` site): a panic
//!   rejects exactly that tenant's batch with [`ServeError::Failed`]
//!   and touches no other tenant's queue, metrics, or epoch.

use super::batcher::{is_interactive, FusedBatch, Reply, Request, Response, Segment, ServeError};
use super::engine::{spawn_shared_builder, BuildJob, EngineCfg, EpochState, LifecycleCfg};
use super::metrics::Metrics;
use super::router::{interactive_range_ceiling, Policy, Router};
use super::server::execute_query_segment;
use crate::runtime::Runtime;
use crate::util::faults;
use crate::util::pool::Claim;
use crate::util::sync::Mutex;
use crate::workload::{validate_ops, Op, RangeDist, TenantLoad};
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex as StdMutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Per-tenant serving configuration (the single-array
/// `CoordinatorCfg`, minus the batcher thread, plus QoS knobs).
#[derive(Clone, Debug)]
pub struct TenantCfg {
    pub name: String,
    pub policy: Policy,
    pub engines: EngineCfg,
    pub lifecycle: LifecycleCfg,
    /// Weighted-deficit share relative to other tenants (≥ 1).
    pub weight: u32,
    /// Shed this tenant's submissions past this queue depth.
    pub shed_watermark: usize,
    /// Default deadline applied to requests that carry none.
    pub deadline: Option<Duration>,
    /// Close a drained batch at this many ops.
    pub max_batch_ops: usize,
    /// Interactive-class mean-range-length ceiling; `None` = √n
    /// ([`interactive_range_ceiling`]).
    pub interactive_ceiling: Option<f64>,
}

impl TenantCfg {
    pub fn named(name: &str) -> TenantCfg {
        TenantCfg {
            name: name.to_string(),
            policy: Policy::ModeledCost,
            engines: EngineCfg::default(),
            lifecycle: LifecycleCfg::default(),
            weight: 1,
            shed_watermark: 256,
            deadline: None,
            max_batch_ops: 1 << 16,
            interactive_ceiling: None,
        }
    }
}

/// Executor-level configuration.
#[derive(Clone, Copy, Debug)]
pub struct MultiCfg {
    /// Executor worker threads (cross-tenant parallelism; per-tenant
    /// execution stays serial via claims).
    pub exec_workers: usize,
    /// Worker threads used by the engines inside one fused batch.
    pub engine_workers: usize,
    /// Aggregate queued-request cap across every tenant; sheds before
    /// any per-tenant watermark is consulted.
    pub global_watermark: usize,
}

impl Default for MultiCfg {
    fn default() -> Self {
        MultiCfg {
            exec_workers: 2,
            engine_workers: crate::util::pool::default_workers(),
            global_watermark: 1024,
        }
    }
}

/// A queued request with its QoS class (classified once, at admission).
struct QueuedReq {
    req: Request,
    interactive: bool,
}

/// One registered array and everything scoped to it.
pub struct Tenant {
    pub name: String,
    n: usize,
    state: Arc<EpochState>,
    router: Router,
    pub metrics: Arc<Mutex<Metrics>>,
    queue: Mutex<VecDeque<QueuedReq>>,
    /// Live queue depth (this tenant only).
    queued: AtomicUsize,
    /// Exclusive-execution claim: at most one worker drains this tenant
    /// at a time, preserving the per-array fence.
    claim: Claim,
    /// Weighted-deficit accumulator (reset on pick).
    deficit: AtomicU64,
    weight: u32,
    shed_watermark: usize,
    deadline: Option<Duration>,
    max_batch_ops: usize,
    ceiling: f64,
    next_id: AtomicU64,
}

impl Tenant {
    fn head_class(&self) -> Option<bool> {
        self.queue.lock().front().map(|q| q.interactive)
    }
}

/// State shared by the executor workers.
struct Shared {
    tenants: Vec<Arc<Tenant>>,
    global_queued: AtomicUsize,
    stop: AtomicBool,
    /// Wakeup signal: submitters notify after a push, workers wait when
    /// every queue is empty or claimed.
    signal: (StdMutex<()>, Condvar),
    engine_workers: usize,
}

/// Scan one QoS class: every ready (non-empty, unclaimed, head-class
/// matching) tenant earns its weight of deficit; the largest deficit is
/// picked and reset. Returns the picked tenant index.
fn pick_class(tenants: &[Arc<Tenant>], interactive: bool) -> Option<usize> {
    let mut best: Option<(usize, u64)> = None;
    for (i, t) in tenants.iter().enumerate() {
        if t.claim.is_claimed() || t.head_class() != Some(interactive) {
            continue;
        }
        let d = t.deficit.fetch_add(u64::from(t.weight), Ordering::AcqRel) + u64::from(t.weight);
        if best.map(|(_, bd)| d > bd).unwrap_or(true) {
            best = Some((i, d));
        }
    }
    best.map(|(i, _)| {
        tenants[i].deficit.store(0, Ordering::Release);
        i
    })
}

/// Two-pass pick: interactive-headed tenants strictly before
/// bulk-headed ones.
fn pick_next(tenants: &[Arc<Tenant>]) -> Option<usize> {
    pick_class(tenants, true).or_else(|| pick_class(tenants, false))
}

/// Drain one batch from a claimed tenant and execute it. Only
/// **consecutive same-class** requests fuse (a class flip at the head
/// re-enters the scheduler, so a bulk run queued behind an interactive
/// head cannot ride its pick), capped at `max_batch_ops` ops.
fn serve_one(shared: &Shared, idx: usize, job_tx: &SyncSender<(usize, BuildJob)>) {
    let t = &shared.tenants[idx];
    // The head's class survives the drain: the whole group shares it
    // (the flip check below), and the per-class latency histogram is
    // tagged with it after the batch completes.
    let (group, head_class) = {
        let mut q = t.queue.lock();
        let Some(head_class) = q.front().map(|r| r.interactive) else {
            return;
        };
        let mut group: Vec<Request> = Vec::new();
        let mut ops = 0usize;
        while let Some(front) = q.front() {
            if front.interactive != head_class || (!group.is_empty() && ops >= t.max_batch_ops) {
                break;
            }
            let qr = q.pop_front().expect("front checked");
            ops += qr.req.ops.len();
            t.queued.fetch_sub(1, Ordering::AcqRel);
            shared.global_queued.fetch_sub(1, Ordering::AcqRel);
            group.push(qr.req);
        }
        (group, head_class)
    };
    if group.is_empty() {
        return;
    }
    let fused = FusedBatch::from_requests(group, Instant::now());
    for req in &fused.expired {
        t.metrics.lock().record_expired();
        let _ = req.reply.try_send(Err(ServeError::DeadlineExceeded));
    }
    if fused.requests.is_empty() {
        return;
    }
    let st = &t.state;
    let m = &t.metrics;
    let workers = shared.engine_workers;
    let t0 = Instant::now();
    // Batch backstop, same contract as the single-array loop: a panic
    // (a genuine executor bug, or the injectable `tenant.exec` site)
    // costs exactly this tenant's batch — Failed replies — and leaves
    // every other tenant untouched.
    let exec = catch_unwind(AssertUnwindSafe(|| {
        faults::fire("tenant.exec");
        let mut answers: Vec<u32> = Vec::with_capacity(fused.total_queries());
        let mut query_engine: Option<&'static str> = None;
        let mut update_engine: Option<&'static str> = None;
        let mut updates_ok = true;
        let mut epoch_seen = st.current().version;
        for seg in &fused.segments {
            match seg {
                Segment::Queries(qs) => {
                    let (got, epoch_version, kind) =
                        execute_query_segment(st, &t.router, m, qs, workers, t.n);
                    epoch_seen = epoch_version;
                    query_engine = Some(kind.name());
                    answers.extend_from_slice(&got);
                }
                Segment::Updates(ups) => {
                    let ts = Instant::now();
                    match st.update_ops(ups, workers) {
                        Ok(kind) => {
                            update_engine.get_or_insert(kind.name());
                            m.lock().record_update_batch(
                                ups.len() as u64,
                                ts.elapsed().as_nanos() as u64,
                            );
                        }
                        Err(e) => {
                            eprintln!("tenant {}: update batch dropped: {e}", t.name);
                            updates_ok = false;
                        }
                    }
                    st.observer.lock().observe_updates(ups.len());
                }
            }
        }
        (answers, query_engine, update_engine, updates_ok, epoch_seen)
    }));
    let latency = t0.elapsed().as_nanos() as u64;
    match exec {
        Ok((answers, query_engine, update_engine, updates_ok, epoch_seen)) => {
            {
                let obs = st.observer.lock().snapshot();
                let mut g = m.lock();
                g.record_class_batch(head_class, latency);
                g.record_observed(obs, st.epoch_version(), st.shard_block_live());
                g.record_faults(faults::stats());
                g.record_range_stats(st.range_stats());
            }
            // Lifecycle work goes to the shared pool, tagged with the
            // tenant index so backoff and accounting stay per tenant.
            if let Some(job) = st.plan() {
                if job_tx.try_send((idx, job)).is_err() {
                    st.clear_pending();
                }
            }
            let per_request = fused.split_answers(&answers);
            let engine_name = query_engine.or(update_engine).unwrap_or("NONE");
            for ((req, ans), &ups) in
                fused.requests.iter().zip(per_request).zip(&fused.update_splits)
            {
                let _ = req.reply.try_send(Ok(Response {
                    id: req.id,
                    answers: ans,
                    updates_applied: if updates_ok { ups } else { 0 },
                    engine: engine_name,
                    epoch: epoch_seen,
                    batch_latency_ns: latency,
                }));
            }
        }
        Err(_) => {
            faults::note_caught();
            {
                let mut g = m.lock();
                g.record_degraded();
                g.record_faults(faults::stats());
            }
            for req in &fused.requests {
                let _ = req.reply.try_send(Err(ServeError::Failed));
            }
        }
    }
}

fn worker_loop(shared: Arc<Shared>, job_tx: SyncSender<(usize, BuildJob)>) {
    loop {
        match pick_next(&shared.tenants) {
            Some(idx) => {
                // The pick can lose the claim race to another worker —
                // fine, re-scan; the loser finds other work or waits.
                if let Some(_guard) = shared.tenants[idx].claim.try_claim() {
                    serve_one(&shared, idx, &job_tx);
                }
            }
            None => {
                if shared.stop.load(Ordering::Acquire)
                    && shared.global_queued.load(Ordering::Acquire) == 0
                {
                    break;
                }
                let g = shared.signal.0.lock().unwrap_or_else(|p| p.into_inner());
                // Short timeout: a claimed tenant releasing, or stop,
                // must be observed without a dedicated notification.
                let _ = shared
                    .signal
                    .1
                    .wait_timeout(g, Duration::from_millis(2))
                    .map(|x| x.0)
                    .unwrap_or_else(|p| p.into_inner().0);
            }
        }
    }
}

/// Handle to the running multi-tenant front-end.
pub struct MultiCoordinator {
    shared: Arc<Shared>,
    by_name: BTreeMap<String, usize>,
    global_watermark: usize,
    workers: Vec<JoinHandle<()>>,
    job_tx: Option<SyncSender<(usize, BuildJob)>>,
    builder: Option<JoinHandle<()>>,
}

impl MultiCoordinator {
    /// Bootstrap every tenant's initial epoch, start the shared builder
    /// pool and the executor workers.
    pub fn start(
        arrays: Vec<(TenantCfg, Vec<f32>)>,
        runtime: Option<Arc<Runtime>>,
        cfg: MultiCfg,
    ) -> MultiCoordinator {
        let mut tenants = Vec::with_capacity(arrays.len());
        let mut by_name = BTreeMap::new();
        for (i, (tc, xs)) in arrays.into_iter().enumerate() {
            let state = EpochState::bootstrap(&xs, runtime.clone(), tc.engines, tc.lifecycle);
            let metrics = Arc::new(Mutex::new(Metrics::new()));
            metrics.lock().set_labels(None, Some(tc.name.clone()));
            let ceiling =
                tc.interactive_ceiling.unwrap_or_else(|| interactive_range_ceiling(xs.len()));
            by_name.insert(tc.name.clone(), i);
            tenants.push(Arc::new(Tenant {
                name: tc.name,
                n: xs.len(),
                state,
                router: Router::new(tc.policy),
                metrics,
                queue: Mutex::new(VecDeque::new()),
                queued: AtomicUsize::new(0),
                claim: Claim::new(),
                deficit: AtomicU64::new(0),
                weight: tc.weight.max(1),
                shed_watermark: tc.shed_watermark,
                deadline: tc.deadline,
                max_batch_ops: tc.max_batch_ops,
                ceiling,
                next_id: AtomicU64::new(0),
            }));
        }
        let (job_tx, builder) = spawn_shared_builder(
            tenants.iter().map(|t| (t.state.clone(), t.metrics.clone())).collect(),
        );
        let shared = Arc::new(Shared {
            tenants,
            global_queued: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
            signal: (StdMutex::new(()), Condvar::new()),
            engine_workers: cfg.engine_workers.max(1),
        });
        let workers = (0..cfg.exec_workers.max(1))
            .map(|_| {
                let s = shared.clone();
                let jt = job_tx.clone();
                std::thread::spawn(move || worker_loop(s, jt))
            })
            .collect();
        MultiCoordinator {
            shared,
            by_name,
            global_watermark: cfg.global_watermark,
            workers,
            job_tx: Some(job_tx),
            builder: Some(builder),
        }
    }

    fn tenant(&self, name: &str) -> Result<&Arc<Tenant>> {
        self.by_name
            .get(name)
            .map(|&i| &self.shared.tenants[i])
            .ok_or_else(|| anyhow!("unknown tenant {name:?}"))
    }

    /// Admit a request for `tenant` and return the reply channel
    /// without blocking on the answer (pipelined clients keep `depth`
    /// of these in flight). Admission order: validation → global
    /// watermark → per-tenant watermark → effective deadline.
    pub fn submit_async(
        &self,
        tenant: &str,
        ops: Vec<Op>,
        deadline: Option<Duration>,
    ) -> Result<Receiver<Reply>> {
        let t = self.tenant(tenant)?;
        validate_ops(t.n, &ops).map_err(|e| {
            t.metrics.lock().record_rejected();
            anyhow!(e)
        })?;
        if self.shared.global_queued.load(Ordering::Acquire) >= self.global_watermark
            || t.queued.load(Ordering::Acquire) >= t.shed_watermark
        {
            t.metrics.lock().record_shed();
            return Err(anyhow::Error::new(ServeError::Overloaded));
        }
        let deadline = match deadline.or(t.deadline) {
            Some(d) if d.is_zero() => {
                t.metrics.lock().record_expired();
                return Err(anyhow::Error::new(ServeError::DeadlineExceeded));
            }
            d => d.map(|d| Instant::now() + d),
        };
        t.metrics.lock().record_request();
        let interactive = is_interactive(&ops, t.ceiling);
        let (reply_tx, reply_rx) = sync_channel(1);
        let id = t.next_id.fetch_add(1, Ordering::Relaxed);
        // Gauges go up *before* the push: workers decrement after the
        // pop, and neither gauge may underflow.
        t.queued.fetch_add(1, Ordering::AcqRel);
        self.shared.global_queued.fetch_add(1, Ordering::AcqRel);
        t.queue
            .lock()
            .push_back(QueuedReq { req: Request { id, ops, deadline, reply: reply_tx }, interactive });
        self.shared.signal.1.notify_all();
        Ok(reply_rx)
    }

    /// Blocking submit: admit, wait, unwrap the typed reply.
    pub fn submit(
        &self,
        tenant: &str,
        ops: Vec<Op>,
        deadline: Option<Duration>,
    ) -> Result<Response> {
        let rx = self.submit_async(tenant, ops, deadline)?;
        match rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(e)) => Err(anyhow::Error::new(e)),
            Err(_) => Err(anyhow!("executor dropped reply")),
        }
    }

    pub fn tenant_names(&self) -> Vec<&str> {
        self.shared.tenants.iter().map(|t| t.name.as_str()).collect()
    }

    pub fn metrics(&self, tenant: &str) -> Result<Arc<Mutex<Metrics>>> {
        Ok(self.tenant(tenant)?.metrics.clone())
    }

    pub fn lifecycle(&self, tenant: &str) -> Result<Arc<EpochState>> {
        Ok(self.tenant(tenant)?.state.clone())
    }

    /// Fold the fault registry's live counters into every tenant's
    /// metrics (see `Coordinator::sync_faults`).
    pub fn sync_faults(&self) {
        for t in &self.shared.tenants {
            t.metrics.lock().record_faults(faults::stats());
        }
    }

    /// Graceful shutdown: workers drain every queue, then the shared
    /// builder drains its lifecycle jobs.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.shared.stop.store(true, Ordering::Release);
        self.shared.signal.1.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        drop(self.job_tx.take());
        if let Some(b) = self.builder.take() {
            let _ = b.join();
        }
        self.sync_faults();
    }
}

impl Drop for MultiCoordinator {
    fn drop(&mut self) {
        if !self.workers.is_empty() || self.builder.is_some() {
            self.stop();
        }
    }
}

/// One tenant's CLI/driver spec: the workload shape
/// ([`TenantLoad`]) plus serving and driver knobs. Grammar (one spec;
/// `serve --tenant-specs` joins several with `;`):
///
/// ```text
/// name[,k=v]*    keys: n, dist, uf, rf, weight, watermark, deadline-ms,
///                      depth, tail, shift, requests, batch
/// ```
#[derive(Clone, Debug)]
pub struct TenantSpec {
    pub load: TenantLoad,
    pub weight: u32,
    pub watermark: Option<usize>,
    pub deadline_ms: Option<u64>,
    /// Async submissions the driver keeps in flight (1 = blocking).
    pub depth: usize,
    /// Quiet pure-query requests appended after the main stream (gives
    /// the lifecycle a window to rebuild/re-shard).
    pub tail: usize,
    /// Driver request count override (else the serve-level default).
    pub requests: Option<usize>,
    /// Driver ops-per-request override.
    pub batch: Option<usize>,
}

impl TenantSpec {
    /// A default tenant (`t0`, `t1`, … via `serve --tenants N`).
    pub fn default_named(name: &str) -> TenantSpec {
        TenantSpec {
            load: TenantLoad {
                name: name.to_string(),
                n: 1 << 16,
                dist: RangeDist::Medium,
                update_frac: 0.1,
                range_frac: 0.0,
                shift: None,
            },
            weight: 1,
            watermark: None,
            deadline_ms: None,
            depth: 1,
            tail: 0,
            requests: None,
            batch: None,
        }
    }

    /// Parse one `name,k=v,...` spec.
    pub fn parse(s: &str) -> std::result::Result<TenantSpec, String> {
        let mut parts = s.split(',').map(str::trim);
        let name = parts.next().filter(|p| !p.is_empty()).ok_or("empty tenant spec")?;
        if name.contains('=') {
            return Err(format!("tenant spec must start with a name, got {name:?}"));
        }
        let mut spec = TenantSpec::default_named(name);
        for kv in parts {
            if kv.is_empty() {
                continue;
            }
            let (k, v) = kv.split_once('=').ok_or_else(|| format!("expected k=v, got {kv:?}"))?;
            match k {
                "n" => {
                    spec.load.n = crate::util::cli::parse_scaled(v)
                        .filter(|&n| n >= 2)
                        .ok_or_else(|| format!("bad n={v}"))? as usize;
                }
                "dist" => {
                    spec.load.dist = RangeDist::parse(v).ok_or_else(|| format!("bad dist={v}"))?;
                }
                "uf" => {
                    spec.load.update_frac = v
                        .parse::<f64>()
                        .ok()
                        .filter(|u| (0.0..=1.0).contains(u))
                        .ok_or_else(|| format!("bad uf={v}"))?;
                }
                "rf" => {
                    spec.load.range_frac = v
                        .parse::<f64>()
                        .ok()
                        .filter(|u| (0.0..=1.0).contains(u))
                        .ok_or_else(|| format!("bad rf={v}"))?;
                }
                "shift" => {
                    spec.load.shift =
                        Some(RangeDist::parse(v).ok_or_else(|| format!("bad shift={v}"))?);
                }
                "weight" => {
                    spec.weight = v
                        .parse::<u32>()
                        .ok()
                        .filter(|&w| w >= 1)
                        .ok_or_else(|| format!("bad weight={v}"))?;
                }
                "watermark" => {
                    spec.watermark =
                        Some(v.parse::<usize>().map_err(|_| format!("bad watermark={v}"))?);
                }
                "deadline-ms" => {
                    spec.deadline_ms =
                        Some(v.parse::<u64>().map_err(|_| format!("bad deadline-ms={v}"))?);
                }
                "depth" => {
                    spec.depth = v
                        .parse::<usize>()
                        .ok()
                        .filter(|&d| d >= 1)
                        .ok_or_else(|| format!("bad depth={v}"))?;
                }
                "tail" => {
                    spec.tail = v.parse::<usize>().map_err(|_| format!("bad tail={v}"))?;
                }
                "requests" => {
                    spec.requests = Some(
                        crate::util::cli::parse_scaled(v)
                            .filter(|&r| r >= 1)
                            .ok_or_else(|| format!("bad requests={v}"))?
                            as usize,
                    );
                }
                "batch" => {
                    spec.batch = Some(
                        v.parse::<usize>()
                            .ok()
                            .filter(|&b| b >= 1)
                            .ok_or_else(|| format!("bad batch={v}"))?,
                    );
                }
                other => return Err(format!("unknown tenant key {other:?}")),
            }
        }
        Ok(spec)
    }

    /// Parse a `;`-joined list, rejecting duplicate names.
    pub fn parse_list(s: &str) -> std::result::Result<Vec<TenantSpec>, String> {
        let mut specs = Vec::new();
        let mut names = std::collections::BTreeSet::new();
        for part in s.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            let spec = TenantSpec::parse(part)?;
            if !names.insert(spec.load.name.clone()) {
                return Err(format!("duplicate tenant name {:?}", spec.load.name));
            }
            specs.push(spec);
        }
        if specs.is_empty() {
            return Err("no tenant specs".to_string());
        }
        Ok(specs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rmq::naive_rmq;
    use crate::util::rng::Rng;
    use crate::workload::{gen_array, gen_mixed_ranged};

    fn mk_multi(names: &[&str], n: usize, cfg: MultiCfg) -> MultiCoordinator {
        let arrays = names
            .iter()
            .enumerate()
            .map(|(i, name)| (TenantCfg::named(name), gen_array(n, 100 + i as u64)))
            .collect();
        MultiCoordinator::start(arrays, None, cfg)
    }

    fn push_raw(mc: &MultiCoordinator, tenant: &str, interactive: bool) -> Receiver<Reply> {
        let t = mc.tenant(tenant).unwrap();
        let (tx, rx) = sync_channel(1);
        t.queued.fetch_add(1, Ordering::AcqRel);
        mc.shared.global_queued.fetch_add(1, Ordering::AcqRel);
        t.queue.lock().push_back(QueuedReq {
            req: Request { id: 0, ops: vec![Op::Query((0, 1))], deadline: None, reply: tx },
            interactive,
        });
        rx
    }

    fn drain_manual(mc: &MultiCoordinator, idx: usize, job_tx: &SyncSender<(usize, BuildJob)>) {
        let _guard = mc.shared.tenants[idx].claim.try_claim().expect("unclaimed in test");
        serve_one(&mc.shared, idx, job_tx);
    }

    /// A coordinator with no live workers, so tests can drive the
    /// scheduler by hand without racing the real executor.
    fn mk_manual_arrays(
        arrays: Vec<(TenantCfg, Vec<f32>)>,
    ) -> (MultiCoordinator, SyncSender<(usize, BuildJob)>) {
        let mut mc = MultiCoordinator::start(
            arrays,
            None,
            MultiCfg { exec_workers: 1, engine_workers: 2, global_watermark: 1024 },
        );
        mc.shared.stop.store(true, Ordering::Release);
        mc.shared.signal.1.notify_all();
        for w in mc.workers.drain(..) {
            let _ = w.join();
        }
        mc.shared.stop.store(false, Ordering::Release);
        let jt = mc.job_tx.clone().expect("running");
        (mc, jt)
    }

    fn mk_manual(names: &[&str], n: usize) -> (MultiCoordinator, SyncSender<(usize, BuildJob)>) {
        let arrays = names
            .iter()
            .enumerate()
            .map(|(i, name)| (TenantCfg::named(name), gen_array(n, 100 + i as u64)))
            .collect();
        mk_manual_arrays(arrays)
    }

    #[test]
    fn interactive_heads_pick_before_bulk_heads() {
        let (mc, _jt) = mk_manual(&["a", "b", "c"], 64);
        // Bulk heads on a and b (with accumulated deficit), interactive
        // head on c: c must still win the pick.
        let _ra = push_raw(&mc, "a", false);
        let _rb = push_raw(&mc, "b", false);
        mc.shared.tenants[0].deficit.store(1000, Ordering::Release);
        mc.shared.tenants[1].deficit.store(1000, Ordering::Release);
        let _rc = push_raw(&mc, "c", true);
        assert_eq!(pick_next(&mc.shared.tenants), Some(2), "interactive preempts bulk");
        // With c drained, the bulk pass resumes on the deficit leaders.
        mc.shared.tenants[2].queue.lock().clear();
        let got = pick_next(&mc.shared.tenants);
        assert!(got == Some(0) || got == Some(1), "bulk pass picks a bulk head, got {got:?}");
    }

    #[test]
    fn weighted_deficit_shares_picks_by_weight() {
        let mut a = TenantCfg::named("w3");
        a.weight = 3;
        let b = TenantCfg::named("w1");
        let (mc, _jt) =
            mk_manual_arrays(vec![(a, gen_array(64, 1)), (b, gen_array(64, 2))]);
        let mut picks = [0usize; 2];
        for _ in 0..40 {
            // Keep both queues non-empty with bulk heads.
            for name in ["w3", "w1"] {
                let t = mc.tenant(name).unwrap();
                if t.queue.lock().is_empty() {
                    let _rx = push_raw(&mc, name, false);
                }
            }
            let i = pick_next(&mc.shared.tenants).expect("both ready");
            picks[i] += 1;
            mc.shared.tenants[i].queue.lock().clear();
            while mc.shared.tenants[i].queued.swap(0, Ordering::AcqRel) > 0 {
                mc.shared.global_queued.fetch_sub(1, Ordering::AcqRel);
            }
        }
        // 3:1 weights → w3 gets ~30 of 40 picks; allow slack for the
        // alternating warm-up.
        assert!(
            picks[0] >= 2 * picks[1],
            "weight-3 tenant out-picks weight-1 ({} vs {})",
            picks[0],
            picks[1]
        );
    }

    #[test]
    fn answers_match_per_tenant_oracles_under_interleaving() {
        let n = 512;
        let mc = mk_multi(
            &["t0", "t1"],
            n,
            MultiCfg { exec_workers: 3, engine_workers: 2, global_watermark: 1024 },
        );
        let mut oracles: Vec<Vec<f32>> =
            vec![gen_array(n, 100), gen_array(n, 101)];
        let mut rng = Rng::new(7);
        for round in 0..30 {
            for (ti, name) in ["t0", "t1"].iter().enumerate() {
                // Mixed stream with range tags riding along: per-tenant
                // fencing must hold for every mutation kind.
                let ops = gen_mixed_ranged(n, 16, 0.2, 0.1, RangeDist::Small, &mut rng);
                let resp = mc.submit(name, ops.clone(), None).expect("accepted");
                let mut ai = 0;
                for op in &ops {
                    match *op {
                        Op::Update { i, v } => oracles[ti][i as usize] = v,
                        Op::RangeAdd { l, r, v } => {
                            for x in oracles[ti][l as usize..=r as usize].iter_mut() {
                                *x += v;
                            }
                        }
                        Op::RangeAssign { l, r, v } => {
                            for x in oracles[ti][l as usize..=r as usize].iter_mut() {
                                *x = v;
                            }
                        }
                        Op::Query((l, r)) => {
                            let want = naive_rmq(&oracles[ti], l as usize, r as usize) as u32;
                            assert_eq!(
                                resp.answers[ai], want,
                                "tenant {name} round {round} query {ai}"
                            );
                            ai += 1;
                        }
                    }
                }
                assert_eq!(ai, resp.answers.len());
            }
        }
        mc.shutdown();
    }

    #[test]
    fn per_tenant_watermark_sheds_only_that_tenant() {
        let mut full = TenantCfg::named("full");
        full.shed_watermark = 0;
        let open = TenantCfg::named("open");
        let mc = MultiCoordinator::start(
            vec![(full, gen_array(64, 1)), (open, gen_array(64, 2))],
            None,
            MultiCfg::default(),
        );
        let err = mc.submit("full", vec![Op::Query((0, 1))], None).unwrap_err();
        assert_eq!(err.downcast_ref::<ServeError>(), Some(&ServeError::Overloaded));
        assert_eq!(mc.metrics("full").unwrap().lock().shed, 1);
        let ok = mc.submit("open", vec![Op::Query((0, 1))], None).unwrap();
        assert_eq!(ok.answers.len(), 1);
        assert_eq!(mc.metrics("open").unwrap().lock().shed, 0);
        mc.shutdown();
    }

    #[test]
    fn global_watermark_sheds_before_tenant_watermarks() {
        let mc = mk_multi(
            &["a", "b"],
            64,
            MultiCfg { exec_workers: 1, engine_workers: 1, global_watermark: 0 },
        );
        for name in ["a", "b"] {
            let err = mc.submit(name, vec![Op::Query((0, 1))], None).unwrap_err();
            assert_eq!(err.downcast_ref::<ServeError>(), Some(&ServeError::Overloaded));
        }
        mc.shutdown();
    }

    #[test]
    fn default_deadline_applies_and_zero_expires_at_admission() {
        let mut t = TenantCfg::named("strict");
        t.deadline = Some(Duration::ZERO);
        let mc =
            MultiCoordinator::start(vec![(t, gen_array(64, 1))], None, MultiCfg::default());
        // No per-request deadline: the tenant default (zero) applies.
        let err = mc.submit("strict", vec![Op::Query((0, 1))], None).unwrap_err();
        assert_eq!(err.downcast_ref::<ServeError>(), Some(&ServeError::DeadlineExceeded));
        assert_eq!(mc.metrics("strict").unwrap().lock().deadline_expired, 1);
        // An explicit per-request deadline overrides the default.
        let ok = mc
            .submit("strict", vec![Op::Query((0, 1))], Some(Duration::from_secs(60)))
            .unwrap();
        assert_eq!(ok.answers.len(), 1);
        mc.shutdown();
    }

    #[test]
    fn unknown_tenant_and_invalid_ops_reject() {
        let mc = mk_multi(&["only"], 64, MultiCfg::default());
        assert!(mc.submit("nope", vec![Op::Query((0, 1))], None).is_err());
        let err = mc.submit("only", vec![Op::Query((0, 64))], None).unwrap_err();
        assert!(err.downcast_ref::<ServeError>().is_none(), "validation is not a ServeError");
        assert_eq!(mc.metrics("only").unwrap().lock().rejected, 1);
        mc.shutdown();
    }

    #[test]
    fn shutdown_drains_pending_requests() {
        let mc = mk_multi(
            &["d"],
            256,
            MultiCfg { exec_workers: 2, engine_workers: 2, global_watermark: 1024 },
        );
        let rxs: Vec<_> = (0..16)
            .map(|i| {
                mc.submit_async("d", vec![Op::Query((0, i as u32))], None).expect("admitted")
            })
            .collect();
        mc.shutdown();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().expect("reply delivered").expect("served, not dropped");
            assert_eq!(resp.answers.len(), 1, "request {i}");
        }
    }

    #[test]
    fn class_flip_splits_the_drained_batch() {
        let (mc, jt) = mk_manual(&["x"], 64);
        // interactive, interactive, bulk: one drain takes exactly the
        // two interactive requests; the bulk request waits its turn.
        let r1 = push_raw(&mc, "x", true);
        let r2 = push_raw(&mc, "x", true);
        let r3 = push_raw(&mc, "x", false);
        drain_manual(&mc, 0, &jt);
        assert!(r1.try_recv().is_ok() && r2.try_recv().is_ok());
        assert!(r3.try_recv().is_err(), "bulk run does not ride the interactive drain");
        assert_eq!(mc.shared.tenants[0].head_class(), Some(false));
        drain_manual(&mc, 0, &jt);
        assert!(r3.try_recv().is_ok());
        assert_eq!(mc.shared.global_queued.load(Ordering::Acquire), 0);
    }

    #[test]
    fn class_latency_is_tagged_with_the_drained_head_class() {
        let (mc, jt) = mk_manual(&["x"], 64);
        // interactive, interactive, bulk: the first drain serves the two
        // interactive requests as one batch, the second serves the bulk
        // request — one histogram sample per class-tagged drain.
        let r1 = push_raw(&mc, "x", true);
        let r2 = push_raw(&mc, "x", true);
        let r3 = push_raw(&mc, "x", false);
        drain_manual(&mc, 0, &jt);
        drain_manual(&mc, 0, &jt);
        assert!(r1.try_recv().is_ok() && r2.try_recv().is_ok() && r3.try_recv().is_ok());
        let m = mc.metrics("x").unwrap();
        let g = m.lock();
        assert_eq!(g.interactive_batches, 1, "two fused interactive requests, one drain");
        assert_eq!(g.bulk_batches, 1);
        let text = format!("{}", *g);
        assert!(text.contains("interactive") && text.contains("bulk"), "{text}");
    }

    #[test]
    fn tenant_spec_parses_grammar_and_rejects_junk() {
        let spec = TenantSpec::parse(
            "bulk,n=64k,dist=large,uf=0.5,rf=0.1,weight=2,watermark=4,deadline-ms=250,depth=8,tail=3,shift=small,requests=1k,batch=32",
        )
        .unwrap();
        assert_eq!(spec.load.name, "bulk");
        assert_eq!(spec.load.n, 64 * 1024);
        assert_eq!(spec.load.dist, RangeDist::Large);
        assert_eq!(spec.load.update_frac, 0.5);
        assert_eq!(spec.load.range_frac, 0.1);
        assert_eq!(spec.load.shift, Some(RangeDist::Small));
        assert_eq!(spec.weight, 2);
        assert_eq!(spec.watermark, Some(4));
        assert_eq!(spec.deadline_ms, Some(250));
        assert_eq!(spec.depth, 8);
        assert_eq!(spec.tail, 3);
        assert_eq!(spec.requests, Some(1024));
        assert_eq!(spec.batch, Some(32));
        // Defaults.
        let d = TenantSpec::parse("plain").unwrap();
        assert_eq!(d.load.n, 1 << 16);
        assert_eq!(d.weight, 1);
        assert_eq!(d.depth, 1);
        // Rejections.
        assert!(TenantSpec::parse("").is_err());
        assert!(TenantSpec::parse("k=v").is_err(), "name must come first");
        assert!(TenantSpec::parse("t,uf=1.5").is_err());
        assert!(TenantSpec::parse("t,rf=-0.1").is_err());
        assert!(TenantSpec::parse("t,weight=0").is_err());
        assert!(TenantSpec::parse("t,nope=1").is_err());
        assert!(TenantSpec::parse_list("a;b;a").is_err(), "duplicate names");
        assert_eq!(TenantSpec::parse_list("a; b ;c").unwrap().len(), 3);
        assert!(TenantSpec::parse_list("").is_err());
    }
}

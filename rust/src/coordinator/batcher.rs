//! Dynamic batcher: coalesces small requests into engine-sized batches
//! under a latency bound, with bounded queues for backpressure (the
//! vLLM-router pattern adapted to RMQ batches).
//!
//! Semantics: requests are grouped FIFO; a group closes when it reaches
//! `max_batch_queries` or `max_wait` elapses after its first request.
//! Queries keep request order inside the fused batch, so answers can be
//! split back losslessly.

use crate::rmq::Query;
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender};
use std::time::{Duration, Instant};

/// One client request.
pub struct Request {
    pub id: u64,
    pub queries: Vec<Query>,
    /// Where to deliver the response.
    pub reply: SyncSender<Response>,
}

/// Answer for one request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub answers: Vec<u32>,
    /// Engine that served the fused batch.
    pub engine: &'static str,
    /// End-to-end latency of the fused batch (ns).
    pub batch_latency_ns: u64,
}

/// Batching configuration.
#[derive(Clone, Copy, Debug)]
pub struct BatcherCfg {
    /// Close a group at this many queries.
    pub max_batch_queries: usize,
    /// ... or when this much time passed since the group opened.
    pub max_wait: Duration,
    /// Bounded request queue length (senders block when full —
    /// backpressure).
    pub queue_cap: usize,
}

impl Default for BatcherCfg {
    fn default() -> Self {
        BatcherCfg {
            max_batch_queries: 1 << 16,
            max_wait: Duration::from_millis(2),
            queue_cap: 256,
        }
    }
}

/// A closed group of requests to run as one engine batch.
pub struct FusedBatch {
    pub requests: Vec<Request>,
    pub queries: Vec<Query>,
    /// Per-request query counts, for splitting answers back.
    pub splits: Vec<usize>,
}

impl FusedBatch {
    fn from_requests(requests: Vec<Request>) -> FusedBatch {
        let mut queries = Vec::new();
        let mut splits = Vec::with_capacity(requests.len());
        for r in &requests {
            splits.push(r.queries.len());
            queries.extend_from_slice(&r.queries);
        }
        FusedBatch { requests, queries, splits }
    }

    /// Split a flat answer vector back per request (answer slices align
    /// with `splits`).
    pub fn split_answers(&self, answers: &[u32]) -> Vec<Vec<u32>> {
        debug_assert_eq!(answers.len(), self.queries.len());
        let mut out = Vec::with_capacity(self.splits.len());
        let mut off = 0;
        for &len in &self.splits {
            out.push(answers[off..off + len].to_vec());
            off += len;
        }
        out
    }
}

/// Pull the next fused batch from the queue. Returns None when all
/// senders disconnected and the queue drained (shutdown).
pub fn next_batch(rx: &Receiver<Request>, cfg: &BatcherCfg) -> Option<FusedBatch> {
    // Block for the first request of the group.
    let first = rx.recv().ok()?;
    let mut total = first.queries.len();
    let mut group = vec![first];
    let opened = Instant::now();
    while total < cfg.max_batch_queries {
        let left = cfg.max_wait.checked_sub(opened.elapsed()).unwrap_or_default();
        if left.is_zero() {
            break;
        }
        match rx.recv_timeout(left) {
            Ok(req) => {
                total += req.queries.len();
                group.push(req);
            }
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(FusedBatch::from_requests(group))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: u64, queries: Vec<Query>) -> (Request, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::sync_channel(1);
        (Request { id, queries, reply: tx }, rx)
    }

    #[test]
    fn fuses_in_fifo_order_and_splits_back() {
        let (r1, _k1) = req(1, vec![(0, 1), (2, 3)]);
        let (r2, _k2) = req(2, vec![(4, 5)]);
        let fused = FusedBatch::from_requests(vec![r1, r2]);
        assert_eq!(fused.queries, vec![(0, 1), (2, 3), (4, 5)]);
        let split = fused.split_answers(&[10, 20, 30]);
        assert_eq!(split, vec![vec![10, 20], vec![30]]);
    }

    #[test]
    fn next_batch_closes_on_size() {
        let (tx, rx) = mpsc::sync_channel::<Request>(16);
        let cfg = BatcherCfg { max_batch_queries: 3, max_wait: Duration::from_secs(5), queue_cap: 16 };
        for id in 0..4 {
            let (r, _keep) = req(id, vec![(0, 0), (1, 1)]);
            std::mem::forget(_keep); // keep reply channel alive
            tx.send(r).unwrap();
        }
        let b = next_batch(&rx, &cfg).unwrap();
        // First request has 2 >= ... group closes at >= 3 queries: two
        // requests (4 queries) since the check happens before pulling.
        assert_eq!(b.requests.len(), 2);
        assert_eq!(b.queries.len(), 4);
        // Remaining two requests form the next group.
        let b2 = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b2.requests.len(), 2);
    }

    #[test]
    fn next_batch_closes_on_timeout() {
        let (tx, rx) = mpsc::sync_channel::<Request>(16);
        let cfg = BatcherCfg {
            max_batch_queries: 1000,
            max_wait: Duration::from_millis(5),
            queue_cap: 16,
        };
        let (r, _keep) = req(7, vec![(0, 0)]);
        tx.send(r).unwrap();
        let t0 = Instant::now();
        let b = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b.requests.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn next_batch_none_on_shutdown() {
        let (tx, rx) = mpsc::sync_channel::<Request>(1);
        drop(tx);
        assert!(next_batch(&rx, &BatcherCfg::default()).is_none());
    }

    #[test]
    fn property_split_preserves_every_query() {
        crate::util::proptest::check("batcher split lossless", 50, |rng| {
            let mut requests = Vec::new();
            let mut expected: Vec<Vec<u32>> = Vec::new();
            let mut counter = 0u32;
            for id in 0..rng.range(1, 8) {
                let qn = rng.range(0, 10);
                let qs: Vec<Query> = (0..qn).map(|k| (k as u32, k as u32 + 1)).collect();
                let (r, _keep) = req(id as u64, qs);
                std::mem::forget(_keep);
                let answers: Vec<u32> = (0..qn).map(|_| {
                    counter += 1;
                    counter
                }).collect();
                expected.push(answers);
                requests.push(r);
            }
            let fused = FusedBatch::from_requests(requests);
            let flat: Vec<u32> = expected.iter().flatten().copied().collect();
            if fused.split_answers(&flat) != expected {
                return Err("split mismatch".into());
            }
            Ok(())
        });
    }
}

//! Dynamic batcher: coalesces small requests into engine-sized batches
//! under a latency bound, with bounded queues for backpressure (the
//! vLLM-router pattern adapted to RMQ batches).
//!
//! Semantics: requests are grouped FIFO; a group closes when it reaches
//! `max_batch_queries` ops or `max_wait` elapses after its first
//! request. A request carries an ordered *op stream* (queries, point
//! updates and range `add`/`assign` tags — every mutation kind fences
//! identically); the fused batch flattens the streams in arrival order into
//! [`Segment`]s — maximal same-kind runs. Query segments keep request
//! order, so answers can be split back losslessly; an update segment is
//! a **fence**: the server applies it between the neighbouring query
//! segments, so queries before it never see its values and queries
//! after it always do.
//!
//! Overload behavior: a request may carry a deadline; one that expires
//! while queued is dropped whole at segment-build time — none of its
//! ops execute (updates included, so the op stream stays all-or-
//! nothing) and it is rejected with [`ServeError::DeadlineExceeded`].
//! Admission control on top sheds with [`ServeError::Overloaded`] when
//! the queue depth crosses [`BatcherCfg::shed_watermark`] (the
//! coordinator's `submit` path), so under sustained overload the queue
//! rejects fast instead of timing every caller out.

use crate::rmq::Query;
use crate::util::faults;
use crate::workload::{Op, UpdateOp};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender};
use std::time::{Duration, Instant};

/// What a submitter gets back: the response, or a typed rejection.
pub type Reply = Result<Response, ServeError>;

/// Two-class QoS classification for the multi-tenant front-end: a
/// request is **interactive** iff it is query-only and its mean range
/// length sits at or under `ceiling`
/// ([`router::interactive_range_ceiling`](crate::coordinator::router::interactive_range_ceiling)
/// = √n). Anything that mutates — or scans past the shard regime — is
/// **bulk**. Classified once at admission; the executor's pick order
/// guarantees an interactive-headed tenant is never queued behind
/// another tenant's bulk work.
pub fn is_interactive(ops: &[Op], ceiling: f64) -> bool {
    let mut total = 0u64;
    let mut count = 0u64;
    for op in ops {
        match op {
            // Anything that mutates — point writes and range tags alike —
            // demotes the request to bulk.
            Op::Update { .. } | Op::RangeAdd { .. } | Op::RangeAssign { .. } => return false,
            Op::Query((l, r)) => {
                total += u64::from(*r) - u64::from(*l) + 1;
                count += 1;
            }
        }
    }
    count > 0 && total as f64 / count as f64 <= ceiling
}

/// Typed rejection for a request that was not served. The differential
/// contract only covers *accepted* requests — a rejected request
/// executes none of its ops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Shed at admission: queue depth crossed the watermark.
    Overloaded,
    /// The deadline passed before the request reached an engine.
    DeadlineExceeded,
    /// The serving loop could not complete the request (its batch was
    /// lost to a caught panic).
    Failed,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded => write!(f, "request shed: queue at watermark"),
            ServeError::DeadlineExceeded => write!(f, "request deadline exceeded"),
            ServeError::Failed => write!(f, "request failed in the serving loop"),
        }
    }
}

impl std::error::Error for ServeError {}

/// One client request: an ordered stream of queries and updates.
pub struct Request {
    pub id: u64,
    pub ops: Vec<Op>,
    /// Drop-dead time: if the request is still queued past this
    /// instant, it is dropped whole and rejected with
    /// [`ServeError::DeadlineExceeded`]. `None` = wait forever.
    pub deadline: Option<Instant>,
    /// Where to deliver the response (or the typed rejection).
    pub reply: SyncSender<Reply>,
}

impl Request {
    /// A read-only request (the common case).
    pub fn queries(id: u64, queries: Vec<Query>, reply: SyncSender<Reply>) -> Request {
        Request { id, ops: queries.into_iter().map(Op::Query).collect(), deadline: None, reply }
    }
}

/// Answer for one request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// One answer per *query* op, in op order.
    pub answers: Vec<u32>,
    /// Point updates applied on behalf of this request.
    pub updates_applied: usize,
    /// Engine that served the fused batch's *last* query segment (the
    /// mutable engine's name for update-only batches). Batch-level: a
    /// mixed fused batch can span engines across a fence — the
    /// per-segment truth lives in the coordinator metrics.
    pub engine: &'static str,
    /// Version of the engine epoch that served the last query segment
    /// (query segments pin their epoch, so a background rebuild
    /// completing mid-batch shows up here exactly from the first
    /// segment that routed against it).
    pub epoch: u64,
    /// End-to-end latency of the fused batch (ns).
    pub batch_latency_ns: u64,
}

/// Batching configuration.
#[derive(Clone, Copy, Debug)]
pub struct BatcherCfg {
    /// Close a group at this many ops.
    pub max_batch_queries: usize,
    /// ... or when this much time passed since the group opened.
    pub max_wait: Duration,
    /// Bounded request queue length (senders block when full —
    /// backpressure).
    pub queue_cap: usize,
    /// Shed new submissions with [`ServeError::Overloaded`] once this
    /// many requests are queued. Defaults to `queue_cap`: shedding
    /// replaces blocking exactly where backpressure would have begun.
    pub shed_watermark: usize,
}

impl Default for BatcherCfg {
    fn default() -> Self {
        BatcherCfg {
            max_batch_queries: 1 << 16,
            max_wait: Duration::from_millis(2),
            queue_cap: 256,
            shed_watermark: 256,
        }
    }
}

/// A maximal run of same-kind ops inside a fused batch. Query segments
/// are solved as one engine batch; update segments are applied between
/// them (the fence).
#[derive(Clone, Debug)]
pub enum Segment {
    Queries(Vec<Query>),
    /// A fenced run of mutations in stream order: point writes and
    /// range `add`/`assign` tags alike — the fence semantics are
    /// identical, only the engine-side application differs.
    Updates(Vec<UpdateOp>),
}

/// A closed group of requests to run as one fused batch.
pub struct FusedBatch {
    pub requests: Vec<Request>,
    /// Requests whose deadline had already passed when the batch was
    /// built — excluded from every segment (no op of theirs executes);
    /// the server rejects each with [`ServeError::DeadlineExceeded`].
    pub expired: Vec<Request>,
    /// The flattened op streams as alternating query/update segments.
    pub segments: Vec<Segment>,
    /// Fence-dependency annotation, parallel to `segments`: for an
    /// update segment, the index of the query segment its *preparation*
    /// may overlap with — always the directly preceding one, because
    /// the fence only constrains queries *after* the update segment;
    /// queries before it read values the staging lane never mutates.
    /// `None` for every query segment and for an update segment with no
    /// preceding query segment (nothing to hide the refit work behind).
    pub overlap_with: Vec<Option<usize>>,
    /// Per-request query-op counts, for splitting answers back
    /// (parallel to `requests` — expired requests have no slot).
    pub query_splits: Vec<usize>,
    /// Per-request update-op counts (reported in each response).
    pub update_splits: Vec<usize>,
}

impl FusedBatch {
    /// Build the segment view of a closed group, dropping requests
    /// whose deadline passed before `now` (deadline-based shedding's
    /// second stage — the queue-time check).
    pub fn from_requests(requests: Vec<Request>, now: Instant) -> FusedBatch {
        let (requests, expired): (Vec<_>, Vec<_>) =
            requests.into_iter().partition(|r| r.deadline.map_or(true, |d| d > now));
        let mut segments: Vec<Segment> = Vec::new();
        let mut query_splits = Vec::with_capacity(requests.len());
        let mut update_splits = Vec::with_capacity(requests.len());
        for r in &requests {
            let (mut nq, mut nu) = (0usize, 0usize);
            for op in &r.ops {
                let up = match *op {
                    Op::Query(q) => {
                        nq += 1;
                        match segments.last_mut() {
                            Some(Segment::Queries(qs)) => qs.push(q),
                            _ => segments.push(Segment::Queries(vec![q])),
                        }
                        continue;
                    }
                    Op::Update { i, v } => UpdateOp::Point { i: i as usize, v },
                    Op::RangeAdd { l, r, v } => {
                        UpdateOp::RangeAdd { l: l as usize, r: r as usize, v }
                    }
                    Op::RangeAssign { l, r, v } => {
                        UpdateOp::RangeAssign { l: l as usize, r: r as usize, v }
                    }
                };
                nu += 1;
                match segments.last_mut() {
                    Some(Segment::Updates(us)) => us.push(up),
                    _ => segments.push(Segment::Updates(vec![up])),
                }
            }
            query_splits.push(nq);
            update_splits.push(nu);
        }
        // Segments strictly alternate kinds, so a non-leading update
        // segment is always directly preceded by a query segment.
        let overlap_with = segments
            .iter()
            .enumerate()
            .map(|(i, s)| match s {
                Segment::Updates(_) if i > 0 => Some(i - 1),
                _ => None,
            })
            .collect();
        FusedBatch { requests, expired, segments, overlap_with, query_splits, update_splits }
    }

    /// Total query ops across the fused batch.
    pub fn total_queries(&self) -> usize {
        self.query_splits.iter().sum()
    }

    /// Split a flat answer vector (one entry per query op, in stream
    /// order) back per request.
    pub fn split_answers(&self, answers: &[u32]) -> Vec<Vec<u32>> {
        debug_assert_eq!(answers.len(), self.total_queries());
        let mut out = Vec::with_capacity(self.query_splits.len());
        let mut off = 0;
        for &len in &self.query_splits {
            out.push(answers[off..off + len].to_vec());
            off += len;
        }
        out
    }
}

/// What one batcher pull produced.
pub enum BatchPull {
    /// A fused batch; more may follow.
    Batch(FusedBatch),
    /// The request channel disconnected with these requests already
    /// pulled: serve them, then shut down. (Treating Disconnected like
    /// Timeout here used to strand a pending partial group — the next
    /// `recv` would report shutdown and the group's ops were lost.)
    Final(FusedBatch),
    /// All senders disconnected and the queue drained.
    Shutdown,
}

/// Pull the next fused batch from the queue, keeping `queued` (the
/// admission-control depth gauge) in sync as requests leave it.
pub fn next_batch(rx: &Receiver<Request>, cfg: &BatcherCfg, queued: &AtomicUsize) -> BatchPull {
    // Block for the first request of the group.
    let first = match rx.recv() {
        Ok(r) => r,
        Err(_) => return BatchPull::Shutdown,
    };
    queued.fetch_sub(1, Ordering::AcqRel);
    let mut total = first.ops.len();
    let mut group = vec![first];
    let opened = Instant::now();
    let mut disconnected = false;
    while total < cfg.max_batch_queries {
        let left = cfg.max_wait.checked_sub(opened.elapsed()).unwrap_or_default();
        if left.is_zero() {
            break;
        }
        match rx.recv_timeout(left) {
            Ok(req) => {
                queued.fetch_sub(1, Ordering::AcqRel);
                total += req.ops.len();
                group.push(req);
            }
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => {
                disconnected = true;
                break;
            }
        }
    }
    // Injected hand-off failure: unwinds before any segment executes,
    // so the pulled group is dropped whole — its submitters see a
    // closed reply channel (a rejection), never a partial effect.
    faults::fire("batcher.handoff");
    let fused = FusedBatch::from_requests(group, Instant::now());
    if disconnected {
        BatchPull::Final(fused)
    } else {
        BatchPull::Batch(fused)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: u64, queries: Vec<Query>) -> (Request, mpsc::Receiver<Reply>) {
        let (tx, rx) = mpsc::sync_channel(1);
        (Request::queries(id, queries, tx), rx)
    }

    fn mixed(id: u64, ops: Vec<Op>) -> (Request, mpsc::Receiver<Reply>) {
        let (tx, rx) = mpsc::sync_channel(1);
        (Request { id, ops, deadline: None, reply: tx }, rx)
    }

    #[test]
    fn interactive_class_rejects_updates_and_wide_ranges() {
        // Pure small-range queries under the ceiling: interactive.
        let qs = vec![Op::Query((0, 3)), Op::Query((10, 12))];
        assert!(is_interactive(&qs, 16.0));
        // Mean range length above the ceiling: bulk.
        let wide = vec![Op::Query((0, 100))];
        assert!(!is_interactive(&wide, 16.0));
        // A single update anywhere demotes the whole request.
        let upd = vec![Op::Query((0, 1)), Op::Update { i: 2, v: 0.5 }];
        assert!(!is_interactive(&upd, 16.0));
        // Range mutations demote just like point writes.
        let radd = vec![Op::Query((0, 1)), Op::RangeAdd { l: 0, r: 3, v: 0.5 }];
        assert!(!is_interactive(&radd, 16.0));
        let rasn = vec![Op::RangeAssign { l: 0, r: 3, v: 0.5 }];
        assert!(!is_interactive(&rasn, 16.0));
        // Empty requests carry no latency claim.
        assert!(!is_interactive(&[], 16.0));
        // Mean is what matters, not the max: one wide query amortized
        // over many points can still be interactive.
        let mixed_widths =
            vec![Op::Query((0, 0)), Op::Query((1, 1)), Op::Query((2, 2)), Op::Query((0, 30))];
        assert!(is_interactive(&mixed_widths, 16.0));
    }

    #[test]
    fn fuses_in_fifo_order_and_splits_back() {
        let (r1, _k1) = req(1, vec![(0, 1), (2, 3)]);
        let (r2, _k2) = req(2, vec![(4, 5)]);
        let fused = FusedBatch::from_requests(vec![r1, r2], Instant::now());
        // Query-only requests fuse into one segment.
        assert_eq!(fused.segments.len(), 1);
        match &fused.segments[0] {
            Segment::Queries(qs) => assert_eq!(qs, &vec![(0, 1), (2, 3), (4, 5)]),
            s => panic!("expected query segment, got {s:?}"),
        }
        let split = fused.split_answers(&[10, 20, 30]);
        assert_eq!(split, vec![vec![10, 20], vec![30]]);
        assert_eq!(fused.update_splits, vec![0, 0]);
        assert!(fused.expired.is_empty());
    }

    #[test]
    fn updates_fence_query_runs_into_segments() {
        let (r1, _k1) = mixed(
            1,
            vec![
                Op::Query((0, 1)),
                Op::Update { i: 3, v: 0.5 },
                Op::RangeAdd { l: 2, r: 6, v: 0.25 },
                Op::Query((2, 3)),
            ],
        );
        let (r2, _k2) = mixed(2, vec![Op::Query((4, 5)), Op::RangeAssign { l: 0, r: 2, v: 0.1 }]);
        let fused = FusedBatch::from_requests(vec![r1, r2], Instant::now());
        // q | uu | q q | u — the trailing query run merges across the
        // request boundary (r2 arrived later, so seeing r1's updates is
        // exactly arrival-order consistency). Range ops join the same
        // fenced runs as point writes, in stream order.
        assert_eq!(fused.segments.len(), 4);
        match (&fused.segments[0], &fused.segments[1], &fused.segments[2], &fused.segments[3]) {
            (
                Segment::Queries(a),
                Segment::Updates(u1),
                Segment::Queries(b),
                Segment::Updates(u2),
            ) => {
                assert_eq!(a, &vec![(0, 1)]);
                assert_eq!(
                    u1,
                    &vec![
                        UpdateOp::Point { i: 3, v: 0.5 },
                        UpdateOp::RangeAdd { l: 2, r: 6, v: 0.25 },
                    ]
                );
                assert_eq!(b, &vec![(2, 3), (4, 5)]);
                assert_eq!(u2, &vec![UpdateOp::RangeAssign { l: 0, r: 2, v: 0.1 }]);
            }
            s => panic!("unexpected segment shape {s:?}"),
        }
        assert_eq!(fused.query_splits, vec![2, 1]);
        assert_eq!(fused.update_splits, vec![2, 1]);
        assert_eq!(fused.total_queries(), 3);
        let split = fused.split_answers(&[7, 8, 9]);
        assert_eq!(split, vec![vec![7, 8], vec![9]]);
        // Fence-dependency annotation: each update segment may overlap
        // the query segment directly before it.
        assert_eq!(fused.overlap_with, vec![None, Some(0), None, Some(2)]);
    }

    #[test]
    fn leading_update_segment_has_no_overlap_target() {
        let (r, _k) = mixed(
            1,
            vec![Op::Update { i: 0, v: 0.5 }, Op::Update { i: 1, v: 0.25 }, Op::Query((0, 1))],
        );
        let fused = FusedBatch::from_requests(vec![r], Instant::now());
        assert_eq!(fused.segments.len(), 2);
        assert_eq!(fused.overlap_with, vec![None, None]);
    }

    #[test]
    fn expired_requests_are_dropped_whole_at_build_time() {
        let now = Instant::now();
        let (mut r1, _k1) =
            mixed(1, vec![Op::Query((0, 1)), Op::Update { i: 3, v: 0.5 }, Op::Query((2, 3))]);
        r1.deadline = Some(now - Duration::from_millis(1));
        let (r2, _k2) = req(2, vec![(4, 5)]);
        let (mut r3, _k3) = req(3, vec![(6, 7)]);
        r3.deadline = Some(now + Duration::from_secs(60));
        let fused = FusedBatch::from_requests(vec![r1, r2, r3], now);
        // r1 is gone whole: no query slot, no update fence, nothing.
        assert_eq!(fused.expired.len(), 1);
        assert_eq!(fused.expired[0].id, 1);
        assert_eq!(fused.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(fused.segments.len(), 1, "the expired update fence must not execute");
        assert_eq!(fused.query_splits, vec![1, 1]);
        assert_eq!(fused.update_splits, vec![0, 0]);
        assert_eq!(fused.split_answers(&[10, 20]), vec![vec![10], vec![20]]);
    }

    #[test]
    fn next_batch_closes_on_size() {
        let (tx, rx) = mpsc::sync_channel::<Request>(16);
        let cfg = BatcherCfg {
            max_batch_queries: 3,
            max_wait: Duration::from_secs(5),
            queue_cap: 16,
            shed_watermark: 16,
        };
        let queued = AtomicUsize::new(0);
        for id in 0..4 {
            let (r, _keep) = req(id, vec![(0, 0), (1, 1)]);
            std::mem::forget(_keep); // keep reply channel alive
            tx.send(r).unwrap();
            queued.fetch_add(1, Ordering::AcqRel);
        }
        let b = match next_batch(&rx, &cfg, &queued) {
            BatchPull::Batch(b) => b,
            _ => panic!("live channel yields a regular batch"),
        };
        // First request has 2 >= ... group closes at >= 3 ops: two
        // requests (4 ops) since the check happens before pulling.
        assert_eq!(b.requests.len(), 2);
        assert_eq!(b.total_queries(), 4);
        assert_eq!(queued.load(Ordering::Acquire), 2, "pulls decrement the depth gauge");
        // Remaining two requests form the next group.
        let b2 = match next_batch(&rx, &cfg, &queued) {
            BatchPull::Batch(b) => b,
            _ => panic!("live channel yields a regular batch"),
        };
        assert_eq!(b2.requests.len(), 2);
        assert_eq!(queued.load(Ordering::Acquire), 0);
    }

    #[test]
    fn next_batch_closes_on_timeout() {
        let (tx, rx) = mpsc::sync_channel::<Request>(16);
        let cfg = BatcherCfg {
            max_batch_queries: 1000,
            max_wait: Duration::from_millis(5),
            queue_cap: 16,
            shed_watermark: 16,
        };
        let (r, _keep) = req(7, vec![(0, 0)]);
        tx.send(r).unwrap();
        let queued = AtomicUsize::new(1);
        let t0 = Instant::now();
        let b = match next_batch(&rx, &cfg, &queued) {
            BatchPull::Batch(b) => b,
            _ => panic!("timeout closes a regular batch"),
        };
        assert_eq!(b.requests.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn next_batch_shutdown_on_disconnect() {
        let (tx, rx) = mpsc::sync_channel::<Request>(1);
        drop(tx);
        let queued = AtomicUsize::new(0);
        assert!(matches!(
            next_batch(&rx, &BatcherCfg::default(), &queued),
            BatchPull::Shutdown
        ));
    }

    #[test]
    fn disconnect_flushes_the_pending_partial_group() {
        // A group is open (first request pulled) when every sender
        // disconnects: the partial group must come back as Final, not
        // be stranded behind a Timeout-equal arm.
        let (tx, rx) = mpsc::sync_channel::<Request>(16);
        let cfg = BatcherCfg {
            max_batch_queries: 1000,
            max_wait: Duration::from_secs(5),
            queue_cap: 16,
            shed_watermark: 16,
        };
        let (r1, _k1) = req(1, vec![(0, 0)]);
        let (r2, _k2) = req(2, vec![(1, 1)]);
        tx.send(r1).unwrap();
        tx.send(r2).unwrap();
        drop(tx);
        let queued = AtomicUsize::new(2);
        let t0 = Instant::now();
        match next_batch(&rx, &cfg, &queued) {
            BatchPull::Final(b) => {
                assert_eq!(b.requests.len(), 2, "both queued requests flushed");
                assert!(
                    t0.elapsed() < Duration::from_secs(5),
                    "disconnect must close the group immediately, not wait out max_wait"
                );
            }
            BatchPull::Batch(_) => panic!("disconnected channel must signal Final"),
            BatchPull::Shutdown => panic!("pending requests must not be stranded"),
        }
        assert_eq!(queued.load(Ordering::Acquire), 0);
        assert!(matches!(next_batch(&rx, &cfg, &queued), BatchPull::Shutdown));
    }

    #[test]
    fn property_split_preserves_every_query() {
        crate::util::proptest::check("batcher split lossless", 50, |rng| {
            let mut requests = Vec::new();
            let mut expected: Vec<Vec<u32>> = Vec::new();
            let mut counter = 0u32;
            for id in 0..rng.range(1, 8) {
                // Random mixed stream; updates get no answer slot.
                let on = rng.range(0, 10);
                let mut ops = Vec::with_capacity(on);
                let mut answers = Vec::new();
                for k in 0..on {
                    if rng.f64() < 0.3 {
                        ops.push(match rng.range(0, 2) {
                            0 => Op::Update { i: k as u32, v: 0.5 },
                            1 => Op::RangeAdd { l: k as u32, r: k as u32 + 4, v: 0.5 },
                            _ => Op::RangeAssign { l: k as u32, r: k as u32 + 4, v: 0.5 },
                        });
                    } else {
                        ops.push(Op::Query((k as u32, k as u32 + 1)));
                        counter += 1;
                        answers.push(counter);
                    }
                }
                let (r, _keep) = mixed(id as u64, ops);
                std::mem::forget(_keep);
                expected.push(answers);
                requests.push(r);
            }
            let fused = FusedBatch::from_requests(requests, Instant::now());
            // Segments must partition the op stream: alternating kinds,
            // never empty, counts adding up.
            let mut prev_is_query: Option<bool> = None;
            let (mut nq, mut nu) = (0usize, 0usize);
            for seg in &fused.segments {
                let is_query = matches!(seg, Segment::Queries(_));
                if prev_is_query == Some(is_query) {
                    return Err("adjacent segments of the same kind".into());
                }
                prev_is_query = Some(is_query);
                match seg {
                    Segment::Queries(qs) => {
                        if qs.is_empty() {
                            return Err("empty query segment".into());
                        }
                        nq += qs.len();
                    }
                    Segment::Updates(us) => {
                        if us.is_empty() {
                            return Err("empty update segment".into());
                        }
                        nu += us.len();
                    }
                }
            }
            if nq != fused.total_queries() || nu != fused.update_splits.iter().sum::<usize>() {
                return Err("segment counts disagree with splits".into());
            }
            // Overlap annotation invariants: parallel to segments; every
            // update segment except a leading one points at its direct
            // (query) predecessor, queries never point anywhere.
            if fused.overlap_with.len() != fused.segments.len() {
                return Err("overlap annotation length mismatch".into());
            }
            for (i, (seg, ov)) in fused.segments.iter().zip(&fused.overlap_with).enumerate() {
                let want = match seg {
                    Segment::Updates(_) if i > 0 => Some(i - 1),
                    _ => None,
                };
                if *ov != want {
                    return Err(format!("segment {i}: overlap {ov:?}, want {want:?}"));
                }
                if let Some(j) = *ov {
                    if !matches!(fused.segments[j], Segment::Queries(_)) {
                        return Err(format!("segment {i} overlaps non-query segment {j}"));
                    }
                }
            }
            let flat: Vec<u32> = expected.iter().flatten().copied().collect();
            if fused.split_answers(&flat) != expected {
                return Err("split mismatch".into());
            }
            Ok(())
        });
    }
}

//! Engine layer: every RMQ approach behind one interface, built once per
//! array ("the geometric model is ready to answer any number of RMQ
//! queries", §5.2 — the same build-once/query-many contract holds for all
//! engines).

use crate::model::rtcost::{RtCostModel, ShardWorkload};
use crate::rmq::exhaustive::Exhaustive;
use crate::rmq::hrmq::Hrmq;
use crate::rmq::lca::LcaRmq;
use crate::rmq::rtx::RtxRmq;
use crate::rmq::sharded::{ShardedOptions, ShardedRmq};
use crate::rmq::{Query, RmqSolver};
use crate::runtime::Runtime;
use crate::workload::RangeDist;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};

/// Engine identifiers (stable names used by the router, CLI and metrics).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EngineKind {
    Rtx,
    Sharded,
    Lca,
    Hrmq,
    Exhaustive,
    Xla,
}

impl EngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Rtx => "RTXRMQ",
            EngineKind::Sharded => "SHARDED",
            EngineKind::Lca => "LCA",
            EngineKind::Hrmq => "HRMQ",
            EngineKind::Exhaustive => "EXHAUSTIVE",
            EngineKind::Xla => "XLA",
        }
    }

    pub fn parse(s: &str) -> Option<EngineKind> {
        match s.to_ascii_uppercase().as_str() {
            "RTX" | "RTXRMQ" => Some(EngineKind::Rtx),
            "SHARDED" | "SHARD" => Some(EngineKind::Sharded),
            "LCA" => Some(EngineKind::Lca),
            "HRMQ" => Some(EngineKind::Hrmq),
            "EXHAUSTIVE" | "EX" => Some(EngineKind::Exhaustive),
            "XLA" => Some(EngineKind::Xla),
            _ => None,
        }
    }

    pub fn all() -> [EngineKind; 6] {
        [
            EngineKind::Rtx,
            EngineKind::Sharded,
            EngineKind::Lca,
            EngineKind::Hrmq,
            EngineKind::Exhaustive,
            EngineKind::Xla,
        ]
    }
}

/// A query engine bound to one array.
pub trait Engine: Send + Sync {
    fn kind(&self) -> EngineKind;
    /// Answer a batch. Must return one index per query, in order.
    fn solve(&self, queries: &[Query], workers: usize) -> Result<Vec<u32>>;
    /// Auxiliary structure bytes (Table 2).
    fn memory_bytes(&self) -> usize;
    /// Whether this engine can apply point updates in place (the
    /// mutable serving path routes update batches to such engines).
    fn supports_updates(&self) -> bool {
        false
    }
    /// Apply a batch of point updates. Only engines reporting
    /// [`supports_updates`](Self::supports_updates) implement this.
    fn update_batch(&self, _updates: &[(usize, f32)], _workers: usize) -> Result<()> {
        Err(anyhow!("engine {} is immutable", self.kind().name()))
    }
}

/// Blanket engine over any RmqSolver.
struct SolverEngine<S: RmqSolver> {
    kind: EngineKind,
    solver: S,
}

impl<S: RmqSolver> Engine for SolverEngine<S> {
    fn kind(&self) -> EngineKind {
        self.kind
    }
    fn solve(&self, queries: &[Query], workers: usize) -> Result<Vec<u32>> {
        Ok(self.solver.batch(queries, workers))
    }
    fn memory_bytes(&self) -> usize {
        self.solver.memory_bytes()
    }
}

/// The XLA engine: executes the AOT artifact through PJRT, chunking the
/// request into the artifact's static batch size (the L2/L1 layers of
/// the stack, with Python long gone).
pub struct XlaEngine {
    runtime: Arc<Runtime>,
    variant: String,
    chunk: usize,
    /// Input size (memory accounting).
    n: usize,
    /// Pre-padded array literal, built once per engine (§Perf L3.3).
    array: crate::runtime::PaddedArray,
}

impl XlaEngine {
    pub fn new(runtime: Arc<Runtime>, xs: &[f32]) -> Result<XlaEngine> {
        let v = runtime
            .select_rmq_variant(xs.len())
            .ok_or_else(|| anyhow!("no artifact variant fits n = {} (run make artifacts)", xs.len()))?;
        let (variant, chunk) = (v.name.clone(), v.q);
        let array = runtime.prepare_array(&variant, xs)?;
        Ok(XlaEngine { variant, chunk, n: xs.len(), array, runtime })
    }

    pub fn variant_name(&self) -> &str {
        &self.variant
    }
}

impl Engine for XlaEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Xla
    }

    fn solve(&self, queries: &[Query], _workers: usize) -> Result<Vec<u32>> {
        let mut out = Vec::with_capacity(queries.len());
        for chunk in queries.chunks(self.chunk) {
            let res = self.runtime.exec_rmq_prepadded(&self.array, chunk)?;
            out.extend(res.args.iter().map(|&a| a as u32));
        }
        Ok(out)
    }

    fn memory_bytes(&self) -> usize {
        // The compiled executable + padded input literal.
        self.n * 4
    }
}

/// The sharded engine is the set's only engine with a write path:
/// queries share the read lock, an update batch takes the write lock,
/// so readers never observe a half-applied batch (the lock *is* the
/// fence at the engine level; op-stream ordering is the server's job).
struct ShardedEngine {
    inner: RwLock<ShardedRmq>,
}

impl Engine for ShardedEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Sharded
    }

    fn solve(&self, queries: &[Query], workers: usize) -> Result<Vec<u32>> {
        Ok(self.inner.read().expect("sharded lock").batch(queries, workers))
    }

    fn memory_bytes(&self) -> usize {
        self.inner.read().expect("sharded lock").memory_bytes()
    }

    fn supports_updates(&self) -> bool {
        true
    }

    fn update_batch(&self, updates: &[(usize, f32)], workers: usize) -> Result<()> {
        self.inner.write().expect("sharded lock").update_batch_with(updates, workers);
        Ok(())
    }
}

/// How the sharded engine's block size is chosen (CLI `--shard-block`).
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum ShardBlock {
    /// The √n power-of-two default (`rmq::sharded::auto_block_size`).
    #[default]
    Sqrt,
    /// Explicit block size.
    Fixed(usize),
    /// `--shard-block auto`: minimise the modeled cost per op from
    /// [`RtCostModel`] — probe work at the expected range distribution
    /// plus amortised refit work at the expected update rate.
    Auto { dist: RangeDist, update_frac: f64 },
}

impl ShardBlock {
    /// Parse a `--shard-block` value: `auto`, an explicit size (scaled
    /// notation allowed), or `0` for the √n default.
    pub fn parse(s: &str, dist: RangeDist, update_frac: f64) -> Option<ShardBlock> {
        if s.eq_ignore_ascii_case("auto") {
            return Some(ShardBlock::Auto { dist, update_frac });
        }
        match crate::util::cli::parse_scaled(s)? as usize {
            0 => Some(ShardBlock::Sqrt),
            b => Some(ShardBlock::Fixed(b)),
        }
    }

    /// Resolve to a concrete `ShardedOptions::block_size` (0 = √n auto).
    pub fn resolve(&self, n: usize) -> usize {
        match *self {
            ShardBlock::Sqrt => 0,
            ShardBlock::Fixed(b) => b,
            ShardBlock::Auto { dist, update_frac } => RtCostModel::default().tune_shard_block(
                n,
                &ShardWorkload { mean_range: dist.mean_len(n), update_frac },
            ),
        }
    }
}

/// Per-set build knobs (CLI-facing).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineCfg {
    /// Block-size rule of the sharded engine.
    pub shard_block: ShardBlock,
}

/// All engines for one array. The XLA engine is optional (artifacts may
/// not cover very large n).
pub struct EngineSet {
    pub n: usize,
    engines: Vec<Box<dyn Engine>>,
    /// Set once any update batch has been applied. From then on only the
    /// mutable engine's view matches the served values — the static
    /// engines were built from the original array and are stale by
    /// definition (the router pins query segments accordingly).
    mutated: AtomicBool,
}

impl EngineSet {
    /// Build every available engine for the array with default knobs.
    /// `runtime` enables the XLA engine when an artifact variant fits.
    pub fn build(xs: &[f32], runtime: Option<Arc<Runtime>>) -> EngineSet {
        Self::build_with(xs, runtime, EngineCfg::default())
    }

    /// Build with explicit knobs (e.g. `--shard-block`).
    pub fn build_with(xs: &[f32], runtime: Option<Arc<Runtime>>, cfg: EngineCfg) -> EngineSet {
        let sharded = ShardedRmq::with_options(
            xs,
            ShardedOptions {
                block_size: cfg.shard_block.resolve(xs.len()),
                ..Default::default()
            },
        );
        let mut engines: Vec<Box<dyn Engine>> = vec![
            Box::new(SolverEngine { kind: EngineKind::Rtx, solver: RtxRmq::new_auto(xs) }),
            Box::new(ShardedEngine { inner: RwLock::new(sharded) }),
            Box::new(SolverEngine { kind: EngineKind::Lca, solver: LcaRmq::new(xs) }),
            Box::new(SolverEngine { kind: EngineKind::Hrmq, solver: Hrmq::new(xs) }),
            Box::new(SolverEngine { kind: EngineKind::Exhaustive, solver: Exhaustive::new(xs) }),
        ];
        if let Some(rt) = runtime {
            if let Ok(x) = XlaEngine::new(rt, xs) {
                engines.push(Box::new(x));
            }
        }
        EngineSet { n: xs.len(), engines, mutated: AtomicBool::new(false) }
    }

    pub fn get(&self, kind: EngineKind) -> Option<&dyn Engine> {
        self.engines.iter().find(|e| e.kind() == kind).map(|e| e.as_ref())
    }

    pub fn kinds(&self) -> Vec<EngineKind> {
        self.engines.iter().map(|e| e.kind()).collect()
    }

    /// Whether any update batch has been applied to this set.
    pub fn mutated(&self) -> bool {
        self.mutated.load(Ordering::Acquire)
    }

    /// Route an update batch to the first engine with a write path and
    /// mark the set mutated. Returns the engine that applied it.
    pub fn update_batch(&self, updates: &[(usize, f32)], workers: usize) -> Result<EngineKind> {
        let engine = self
            .engines
            .iter()
            .find(|e| e.supports_updates())
            .ok_or_else(|| anyhow!("no mutable engine built"))?;
        engine.update_batch(updates, workers)?;
        self.mutated.store(true, Ordering::Release);
        Ok(engine.kind())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rmq::sparse_table::oracle_batch;
    use crate::util::rng::Rng;
    use crate::workload::{gen_queries, RangeDist};

    #[test]
    fn all_solver_engines_agree_with_oracle() {
        let mut rng = Rng::new(60);
        let xs = rng.uniform_f32_vec(2000);
        let set = EngineSet::build(&xs, None);
        let queries = gen_queries(2000, 128, RangeDist::Medium, &mut rng);
        let want = oracle_batch(&xs, &queries);
        for kind in [
            EngineKind::Rtx,
            EngineKind::Sharded,
            EngineKind::Lca,
            EngineKind::Hrmq,
            EngineKind::Exhaustive,
        ] {
            let e = set.get(kind).expect("engine present");
            let got = e.solve(&queries, 2).unwrap();
            assert_eq!(got, want, "{}", kind.name());
        }
    }

    #[test]
    fn engine_kind_names_roundtrip() {
        for k in EngineKind::all() {
            assert_eq!(EngineKind::parse(k.name()), Some(k));
        }
        assert_eq!(EngineKind::parse("nope"), None);
    }

    #[test]
    fn xla_engine_absent_without_runtime() {
        let xs = Rng::new(61).uniform_f32_vec(64);
        let set = EngineSet::build(&xs, None);
        assert!(set.get(EngineKind::Xla).is_none());
        assert_eq!(set.kinds().len(), 5);
    }

    #[test]
    fn shard_block_knob_reaches_engine() {
        let xs = Rng::new(63).uniform_f32_vec(512);
        let set =
            EngineSet::build_with(&xs, None, EngineCfg { shard_block: ShardBlock::Fixed(32) });
        let e = set.get(EngineKind::Sharded).expect("sharded built");
        let queries = vec![(0u32, 511u32), (31, 32), (100, 100)];
        assert_eq!(e.solve(&queries, 2).unwrap(), oracle_batch(&xs, &queries));
        assert!(e.memory_bytes() > 0);
    }

    #[test]
    fn shard_block_parses_and_resolves() {
        let dist = RangeDist::Small;
        assert_eq!(ShardBlock::parse("64", dist, 0.0), Some(ShardBlock::Fixed(64)));
        assert_eq!(ShardBlock::parse("2^8", dist, 0.0), Some(ShardBlock::Fixed(256)));
        assert_eq!(ShardBlock::parse("0", dist, 0.0), Some(ShardBlock::Sqrt));
        assert_eq!(ShardBlock::parse("nope", dist, 0.0), None);
        assert_eq!(
            ShardBlock::parse("AUTO", dist, 0.25),
            Some(ShardBlock::Auto { dist, update_frac: 0.25 })
        );
        assert_eq!(ShardBlock::Sqrt.resolve(1 << 16), 0);
        assert_eq!(ShardBlock::Fixed(128).resolve(1 << 16), 128);
        let auto = ShardBlock::Auto { dist, update_frac: 0.1 }.resolve(1 << 16);
        assert!(auto.is_power_of_two() && (4..=1 << 12).contains(&auto), "auto = {auto}");
    }

    #[test]
    fn auto_shard_block_builds_and_answers() {
        let xs = Rng::new(65).uniform_f32_vec(2048);
        let set = EngineSet::build_with(
            &xs,
            None,
            EngineCfg {
                shard_block: ShardBlock::Auto { dist: RangeDist::Small, update_frac: 0.1 },
            },
        );
        let e = set.get(EngineKind::Sharded).expect("sharded built");
        let queries = vec![(0u32, 2047u32), (100, 140), (2047, 2047)];
        assert_eq!(e.solve(&queries, 2).unwrap(), oracle_batch(&xs, &queries));
    }

    #[test]
    fn update_batch_goes_to_the_sharded_engine_only() {
        let mut xs = Rng::new(64).uniform_f32_vec(512);
        let set =
            EngineSet::build_with(&xs, None, EngineCfg { shard_block: ShardBlock::Fixed(32) });
        assert!(!set.mutated());
        // Static engines refuse the write path.
        for kind in [EngineKind::Rtx, EngineKind::Lca, EngineKind::Hrmq, EngineKind::Exhaustive] {
            let e = set.get(kind).unwrap();
            assert!(!e.supports_updates());
            assert!(e.update_batch(&[(0, 0.5)], 1).is_err(), "{}", kind.name());
        }
        assert!(!set.mutated(), "refused updates must not mark the set mutated");
        // The set routes the batch to the sharded engine and flips the flag.
        let updates = vec![(3usize, -1.0f32), (31, -0.5), (32, -0.25), (511, -2.0)];
        let applied = set.update_batch(&updates, 2).unwrap();
        assert_eq!(applied, EngineKind::Sharded);
        assert!(set.mutated());
        for &(i, v) in &updates {
            xs[i] = v;
        }
        let queries = vec![(0u32, 511u32), (4, 40), (32, 511)];
        let got = set.get(EngineKind::Sharded).unwrap().solve(&queries, 2).unwrap();
        assert_eq!(got, oracle_batch(&xs, &queries));
    }

    #[test]
    fn memory_ordering_matches_table2() {
        // Table 2: HRMQ << LCA << RTXRMQ.
        let xs = Rng::new(62).uniform_f32_vec(1 << 14);
        let set = EngineSet::build(&xs, None);
        let mem = |k: EngineKind| set.get(k).unwrap().memory_bytes();
        assert!(mem(EngineKind::Hrmq) < mem(EngineKind::Lca));
        assert!(mem(EngineKind::Lca) < mem(EngineKind::Rtx));
        assert_eq!(mem(EngineKind::Exhaustive), 0);
    }
}

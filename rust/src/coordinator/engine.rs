//! Engine layer: every RMQ approach behind one interface, plus the
//! **epoch lifecycle** that keeps the set servable under mutation.
//!
//! The paper's contract is build-once/query-many ("the geometric model
//! is ready to answer any number of RMQ queries", §5.2). Mutable
//! serving breaks it: point updates land in the sharded engine in
//! place, and every *static* engine (RTX wide-BVH, LCA, HRMQ,
//! EXHAUSTIVE, XLA) silently keeps the array it was built from. Instead
//! of a sticky "mutated" flag that pins traffic to the shards forever,
//! engines now live in **epochs**:
//!
//! - [`EngineEpoch`] — one immutable generation: `version`, the engine
//!   set, and `built_from_seq`, the applied-update sequence number its
//!   static engines were built from. The epoch is *fresh* while that
//!   equals the mutable engine's live sequence; queries on a fresh
//!   epoch route freely (Fig. 12's crossover stays reachable), queries
//!   on a stale one are pinned to the always-current sharded engine.
//! - [`ShardedEngine`] — the single mutable engine, shared across
//!   epochs by `Arc`. Its update sequence number is bumped under the
//!   same write lock that applies the batch, so a read-locked
//!   [`snapshot`](ShardedEngine::snapshot) (values + seq) is consistent
//!   by construction.
//! - [`EpochState`] — the lifecycle manager. The serving thread feeds a
//!   [`WorkloadObserver`] and calls [`plan`](EpochState::plan) after
//!   each fused batch; once the decayed update rate drops below
//!   [`RtCostModel::rebuild_worthwhile`]'s threshold (or the
//!   workload-fed tuner drifts ≥ `reshard_drift` from the live block
//!   size under `--shard-block auto`), a [`BuildJob`] goes to the
//!   background builder ([`spawn_builder`]), which reconstructs from a
//!   snapshot and publishes the new epoch with an atomic `Arc` swap —
//!   in-flight query segments finish on the epoch they pinned, later
//!   segments route against the new one.

use super::metrics::Metrics;
use crate::model::rtcost::{RtCostModel, ShardWorkload};
use crate::rmq::exhaustive::Exhaustive;
use crate::rmq::hrmq::Hrmq;
use crate::rmq::lca::LcaRmq;
use crate::rmq::rtx::RtxRmq;
use crate::rmq::sharded::{PreparedBlockUpdate, RangeStats, ShardedOptions, ShardedRmq};
use crate::rmq::{Query, RmqSolver};
use crate::runtime::Runtime;
use crate::util::faults;
use crate::util::sync::{Mutex, RwLock};
use crate::workload::observer::WorkloadObserver;
use crate::workload::{RangeDist, UpdateOp};
use anyhow::{anyhow, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Engine identifiers (stable names used by the router, CLI and metrics).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EngineKind {
    Rtx,
    Sharded,
    Lca,
    Hrmq,
    Exhaustive,
    Xla,
}

impl EngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Rtx => "RTXRMQ",
            EngineKind::Sharded => "SHARDED",
            EngineKind::Lca => "LCA",
            EngineKind::Hrmq => "HRMQ",
            EngineKind::Exhaustive => "EXHAUSTIVE",
            EngineKind::Xla => "XLA",
        }
    }

    pub fn parse(s: &str) -> Option<EngineKind> {
        match s.to_ascii_uppercase().as_str() {
            "RTX" | "RTXRMQ" => Some(EngineKind::Rtx),
            "SHARDED" | "SHARD" => Some(EngineKind::Sharded),
            "LCA" => Some(EngineKind::Lca),
            "HRMQ" => Some(EngineKind::Hrmq),
            "EXHAUSTIVE" | "EX" => Some(EngineKind::Exhaustive),
            "XLA" => Some(EngineKind::Xla),
            _ => None,
        }
    }

    pub fn all() -> [EngineKind; 6] {
        [
            EngineKind::Rtx,
            EngineKind::Sharded,
            EngineKind::Lca,
            EngineKind::Hrmq,
            EngineKind::Exhaustive,
            EngineKind::Xla,
        ]
    }
}

/// A query engine bound to one array.
pub trait Engine: Send + Sync {
    fn kind(&self) -> EngineKind;
    /// Answer a batch. Must return one index per query, in order.
    fn solve(&self, queries: &[Query], workers: usize) -> Result<Vec<u32>>;
    /// Auxiliary structure bytes (Table 2).
    fn memory_bytes(&self) -> usize;
    /// Whether this engine can apply point updates in place (the
    /// mutable serving path routes update batches to such engines, and
    /// the router treats them as fresh in every epoch).
    fn supports_updates(&self) -> bool {
        false
    }
    /// Apply a batch of point updates. Only engines reporting
    /// [`supports_updates`](Self::supports_updates) implement this.
    fn update_batch(&self, _updates: &[(usize, f32)], _workers: usize) -> Result<()> {
        Err(anyhow!("engine {} is immutable", self.kind().name()))
    }
}

/// Blanket engine over any RmqSolver.
struct SolverEngine<S: RmqSolver> {
    kind: EngineKind,
    solver: S,
}

impl<S: RmqSolver> Engine for SolverEngine<S> {
    fn kind(&self) -> EngineKind {
        self.kind
    }
    fn solve(&self, queries: &[Query], workers: usize) -> Result<Vec<u32>> {
        Ok(self.solver.batch(queries, workers))
    }
    fn memory_bytes(&self) -> usize {
        self.solver.memory_bytes()
    }
}

/// The XLA engine: executes the AOT artifact through PJRT, chunking the
/// request into the artifact's static batch size (the L2/L1 layers of
/// the stack, with Python long gone).
pub struct XlaEngine {
    runtime: Arc<Runtime>,
    variant: String,
    chunk: usize,
    /// Input size (memory accounting).
    n: usize,
    /// Pre-padded array literal, built once per engine (§Perf L3.3).
    array: crate::runtime::PaddedArray,
}

impl XlaEngine {
    pub fn new(runtime: Arc<Runtime>, xs: &[f32]) -> Result<XlaEngine> {
        let v = runtime
            .select_rmq_variant(xs.len())
            .ok_or_else(|| anyhow!("no artifact variant fits n = {} (run make artifacts)", xs.len()))?;
        let (variant, chunk) = (v.name.clone(), v.q);
        let array = runtime.prepare_array(&variant, xs)?;
        Ok(XlaEngine { variant, chunk, n: xs.len(), array, runtime })
    }

    pub fn variant_name(&self) -> &str {
        &self.variant
    }
}

impl Engine for XlaEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Xla
    }

    fn solve(&self, queries: &[Query], _workers: usize) -> Result<Vec<u32>> {
        let mut out = Vec::with_capacity(queries.len());
        for chunk in queries.chunks(self.chunk) {
            let res = self.runtime.exec_rmq_prepadded(&self.array, chunk)?;
            out.extend(res.args.iter().map(|&a| a as u32));
        }
        Ok(out)
    }

    fn memory_bytes(&self) -> usize {
        // The compiled executable + padded input literal.
        self.n * 4
    }
}

/// The sharded solver plus its applied-update **sequence number**,
/// guarded by one lock: queries share the read lock, an update batch
/// takes the write lock and bumps the seq before releasing it, so
/// readers never observe a half-applied batch and a read-locked
/// (values, seq) snapshot is consistent by construction. `shape_gen`
/// counts structure swaps (re-shards): the seq tracks *value* history,
/// the shape generation tracks *decomposition* history — a staged
/// update commit is valid only while both stand.
struct VersionedSharded {
    rmq: ShardedRmq,
    seq: u64,
    shape_gen: u64,
}

/// The set's only mutable engine — always current, shared across epochs
/// by `Arc` (the lifecycle rebuilds *static* engines around it).
pub struct ShardedEngine {
    inner: RwLock<VersionedSharded>,
}

impl ShardedEngine {
    pub fn new(rmq: ShardedRmq) -> ShardedEngine {
        ShardedEngine { inner: RwLock::new(VersionedSharded { rmq, seq: 0, shape_gen: 0 }) }
    }

    /// Applied-update sequence number (one per update batch). This is
    /// the number the serving thread publishes to decide epoch
    /// freshness: an epoch with `built_from_seq == seq()` serves the
    /// exact values its static engines were built from.
    pub fn seq(&self) -> u64 {
        self.inner.read().seq
    }

    /// Live block size (the re-shard drift comparison's denominator).
    pub fn block_size(&self) -> usize {
        self.inner.read().rmq.block_size()
    }

    /// Consistent (values, applied-seq) snapshot — the rebuild source
    /// for background static-engine builds. Range tags need no special
    /// handling here: the lazy paths rewrite the value array eagerly
    /// (only the *structures* are lazy), so `values()` is always the
    /// served truth.
    pub fn snapshot(&self) -> (Vec<f32>, u64) {
        let g = self.inner.read();
        (g.rmq.values().to_vec(), g.seq)
    }

    /// Lifetime range-update counters (monotone across re-shards and
    /// recovery rebuilds — replacements adopt their predecessor's).
    pub fn range_stats(&self) -> RangeStats {
        self.inner.read().rmq.range_stats()
    }

    /// Online re-shard: build a replacement at `block_size` from a
    /// snapshot **outside** any lock (serving continues meanwhile),
    /// then swap it in iff no update batch landed in between — a moved
    /// seq means the replacement is stale, so it is dropped and the
    /// lifecycle retries once traffic is quiet again. Returns whether
    /// the swap happened.
    pub fn reshard(&self, block_size: usize) -> bool {
        let (xs, opts, expect) = {
            let g = self.inner.read();
            (g.rmq.values().to_vec(), g.rmq.options(), g.seq)
        };
        let fresh = ShardedRmq::reshard_from(&xs, opts, block_size);
        // Injected install failure: indistinguishable from a seq
        // conflict to the lifecycle (drop the replacement, back off).
        if faults::fire("reshard.install") {
            return false;
        }
        self.install(fresh, expect)
    }

    /// Swap in a replacement iff the seq still equals `expect_seq`.
    /// Bumps the shape generation, which invalidates any update batch
    /// staged against the old decomposition (its commit falls back to
    /// the direct path).
    pub(crate) fn install(&self, mut rmq: ShardedRmq, expect_seq: u64) -> bool {
        let mut g = self.inner.write();
        if g.seq != expect_seq {
            return false;
        }
        rmq.adopt_range_stats(g.rmq.range_stats());
        g.rmq = rmq;
        g.shape_gen += 1;
        true
    }

    /// Stage an update batch for the pipelined write path: snapshot the
    /// touched blocks and the (seq, shape) fingerprint under a *briefly
    /// held* read lock, then build the per-block replacement solvers
    /// with no lock held — so the expensive refit work runs concurrently
    /// with query segments reading the same engine.
    pub fn prepare_update_batch(
        &self,
        updates: &[(usize, f32)],
        workers: usize,
    ) -> PreparedUpdate {
        let ops: Vec<UpdateOp> =
            updates.iter().map(|&(i, v)| UpdateOp::Point { i, v }).collect();
        self.prepare_update_ops(&ops, workers)
    }

    /// Ops-aware staging: pure-point segments stage per-block value
    /// copies; a segment carrying a range op stages a pointer-sized tag
    /// spec (the lazy-tag application at commit is cheaper than the
    /// copy would be), fingerprint-guarded identically.
    pub fn prepare_update_ops(&self, ops: &[UpdateOp], workers: usize) -> PreparedUpdate {
        let t0 = Instant::now();
        let (spec, seq, shape_gen) = {
            let g = self.inner.read();
            (g.rmq.stage_update_ops(ops), g.seq, g.shape_gen)
        };
        let prep = spec.build(workers);
        PreparedUpdate { prep, seq, shape_gen, prep_ns: t0.elapsed().as_nanos() as u64 }
    }

    /// Commit a staged batch at its fence. The fast path installs the
    /// prepared blocks under the write lock iff no update batch and no
    /// re-shard landed since the stage (seq + shape fingerprint); a
    /// conflict voids the preparation and the batch is applied through
    /// the direct path instead — either way the values land exactly
    /// once and the seq bumps exactly once, so epoch staleness
    /// accounting is identical to [`update_batch`](Engine::update_batch).
    pub fn commit_prepared(&self, p: PreparedUpdate, workers: usize) -> CommitOutcome {
        // Injected commit conflict: drawn before the write lock so a
        // delay rule cannot stall readers. An `err` here voids the
        // preparation exactly like a real seq/shape conflict — the
        // direct path applies the same values, so answers are
        // unchanged (`panic` is rejected for this site at parse time).
        let forced_conflict = faults::fire("stage.commit");
        let mut g = self.inner.write();
        if !forced_conflict && g.seq == p.seq && g.shape_gen == p.shape_gen {
            match g.rmq.commit_prepared(p.prep) {
                Ok(()) => {
                    g.seq += 1;
                    return CommitOutcome::Installed;
                }
                Err(back) => {
                    // Fingerprint said clean but the decomposition
                    // disagrees — defensive: the direct path is always
                    // correct.
                    let ops = back.ops().to_vec();
                    apply_direct(&mut g, &ops, workers);
                    return CommitOutcome::FellBack;
                }
            }
        }
        let ops = p.prep.ops().to_vec();
        apply_direct(&mut g, &ops, workers);
        CommitOutcome::FellBack
    }

    /// Direct write path for an ops segment (point and range mutations
    /// in stream order), with the same panic backstop and seq accounting
    /// as the tuple [`update_batch`](Engine::update_batch).
    pub fn update_ops(&self, ops: &[UpdateOp], workers: usize) -> Result<()> {
        let mut g = self.inner.write();
        apply_direct(&mut g, ops, workers);
        Ok(())
    }
}

/// Apply an ops segment through the direct path with a panic backstop,
/// bumping the seq exactly once. The apply paths write each op's values
/// into the array *before* any structural refit, so if one unwinds
/// mid-refit (a bug — injected worker panics are already absorbed
/// inside `util::pool`) the pre-panic values array plus the segment is
/// still a correct source: re-apply every op elementwise and rebuild
/// the decomposition from scratch. The rebuild runs with `build_workers
/// = 1` — fully inline, it cannot reach any fault-injection site, so
/// recovery is deterministic. The replacement adopts the lifetime range
/// counters so the metrics stay monotone across the swap.
///
/// Point writes replay as idempotent assigns, but an interrupted range
/// `add` is not idempotent — so the segment's range-op union span is
/// snapshotted up front (O(span), the same order as the elementwise
/// writes the ranges do anyway) and recovery restores it before the
/// replay.
fn apply_direct(g: &mut VersionedSharded, ops: &[UpdateOp], workers: usize) {
    let mut span: Option<(usize, usize)> = None;
    for op in ops {
        if let UpdateOp::RangeAdd { l, r, .. } | UpdateOp::RangeAssign { l, r, .. } = *op {
            span = Some(match span {
                None => (l, r),
                Some((a, b)) => (a.min(l), b.max(r)),
            });
        }
    }
    let pre: Option<Vec<f32>> = span.map(|(a, b)| g.rmq.values()[a..=b].to_vec());
    if catch_unwind(AssertUnwindSafe(|| g.rmq.apply_update_ops(ops, workers))).is_err() {
        faults::note_caught();
        let mut vals = g.rmq.values().to_vec();
        if let (Some((a, _)), Some(pre)) = (span, &pre) {
            vals[a..a + pre.len()].copy_from_slice(pre);
        }
        for op in ops {
            op.apply_naive(&mut vals);
        }
        let mut opts = g.rmq.options();
        opts.build_workers = 1;
        let block_size = g.rmq.block_size();
        let stats = g.rmq.range_stats();
        g.rmq = ShardedRmq::reshard_from(&vals, opts, block_size);
        g.rmq.adopt_range_stats(stats);
    }
    g.seq += 1;
}

/// A staged update batch: per-block refit work computed against a
/// read-locked snapshot, plus the fingerprint that must still hold at
/// commit time.
pub struct PreparedUpdate {
    prep: PreparedBlockUpdate,
    seq: u64,
    shape_gen: u64,
    /// Wall-clock ns the preparation took — the work the pipeline hides
    /// behind the preceding query segment.
    pub prep_ns: u64,
}

impl PreparedUpdate {
    /// Number of update ops in the staged segment.
    pub fn len(&self) -> usize {
        self.prep.ops().len()
    }

    pub fn is_empty(&self) -> bool {
        self.prep.ops().is_empty()
    }
}

/// What [`ShardedEngine::commit_prepared`] did at the fence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommitOutcome {
    /// The prepared per-block work was installed as-is.
    Installed,
    /// A conflicting write or re-shard voided the preparation; the
    /// batch was applied through the direct path (same values, same
    /// seq accounting — only the refit work was re-done).
    FellBack,
}

impl Engine for ShardedEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Sharded
    }

    fn solve(&self, queries: &[Query], workers: usize) -> Result<Vec<u32>> {
        Ok(self.inner.read().rmq.batch(queries, workers))
    }

    fn memory_bytes(&self) -> usize {
        self.inner.read().rmq.memory_bytes()
    }

    fn supports_updates(&self) -> bool {
        true
    }

    fn update_batch(&self, updates: &[(usize, f32)], workers: usize) -> Result<()> {
        let ops: Vec<UpdateOp> =
            updates.iter().map(|&(i, v)| UpdateOp::Point { i, v }).collect();
        self.update_ops(&ops, workers)
    }
}

/// How the sharded engine's block size is chosen (CLI `--shard-block`).
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum ShardBlock {
    /// The √n power-of-two default (`rmq::sharded::auto_block_size`).
    #[default]
    Sqrt,
    /// Explicit block size.
    Fixed(usize),
    /// `--shard-block auto`: minimise the modeled cost per op from
    /// [`RtCostModel`]. The CLI `dist`/`update_frac` priors only seed
    /// the *initial* build; under the serving lifecycle the tuner is
    /// re-run against observed traffic and drifting engines re-shard in
    /// the background ([`EpochState::plan`]).
    Auto { dist: RangeDist, update_frac: f64 },
}

impl ShardBlock {
    /// Parse a `--shard-block` value: `auto`, an explicit size (scaled
    /// notation allowed), or `0` for the √n default.
    pub fn parse(s: &str, dist: RangeDist, update_frac: f64) -> Option<ShardBlock> {
        if s.eq_ignore_ascii_case("auto") {
            return Some(ShardBlock::Auto { dist, update_frac });
        }
        match crate::util::cli::parse_scaled(s)? as usize {
            0 => Some(ShardBlock::Sqrt),
            b => Some(ShardBlock::Fixed(b)),
        }
    }

    /// Resolve to a concrete `ShardedOptions::block_size` (0 = √n auto).
    pub fn resolve(&self, n: usize) -> usize {
        match *self {
            ShardBlock::Sqrt => 0,
            ShardBlock::Fixed(b) => b,
            ShardBlock::Auto { dist, update_frac } => RtCostModel::default().tune_shard_block(
                n,
                &ShardWorkload { mean_range: dist.mean_len(n), update_frac },
            ),
        }
    }
}

/// Per-set build knobs (CLI-facing).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineCfg {
    /// Block-size rule of the sharded engine.
    pub shard_block: ShardBlock,
    /// Ray-packet width for the RTX and sharded traversal drivers
    /// (`--packet-width`; 0 = the scalar path). Answers are
    /// bit-identical at every width — this is an A/B performance knob.
    pub packet_width: usize,
    /// Disable left-endpoint batch sorting (`--no-sort-queries`).
    /// Inverted so the zero `Default` keeps sorting on, matching the
    /// solver defaults.
    pub no_sort_queries: bool,
}

/// Build the static engines for an array (everything except the sharded
/// engine, which outlives epochs). `runtime` enables the XLA engine
/// when an artifact variant fits; `cfg` carries the traversal-driver
/// knobs (`--packet-width`, `--no-sort-queries`) into the RTX engine.
fn build_static_engines(
    xs: &[f32],
    runtime: Option<Arc<Runtime>>,
    cfg: EngineCfg,
) -> Vec<Arc<dyn Engine>> {
    let rtx = RtxRmq::new_auto_tuned(xs, cfg.packet_width, !cfg.no_sort_queries);
    let mut engines: Vec<Arc<dyn Engine>> = vec![
        Arc::new(SolverEngine { kind: EngineKind::Rtx, solver: rtx }),
        Arc::new(SolverEngine { kind: EngineKind::Lca, solver: LcaRmq::new(xs) }),
        Arc::new(SolverEngine { kind: EngineKind::Hrmq, solver: Hrmq::new(xs) }),
        Arc::new(SolverEngine { kind: EngineKind::Exhaustive, solver: Exhaustive::new(xs) }),
    ];
    if let Some(rt) = runtime {
        if let Ok(x) = XlaEngine::new(rt, xs) {
            engines.push(Arc::new(x));
        }
    }
    engines
}

fn build_sharded(xs: &[f32], cfg: EngineCfg) -> Arc<ShardedEngine> {
    Arc::new(ShardedEngine::new(ShardedRmq::with_options(
        xs,
        ShardedOptions {
            block_size: cfg.shard_block.resolve(xs.len()),
            packet_width: cfg.packet_width,
            sort_queries: !cfg.no_sort_queries,
            ..Default::default()
        },
    )))
}

/// All engines for one array — the one-shot (`solve`/`memory`) surface.
/// The serving path wraps the same engines in [`EngineEpoch`]s instead.
/// The XLA engine is optional (artifacts may not cover very large n).
pub struct EngineSet {
    pub n: usize,
    engines: Vec<Arc<dyn Engine>>,
    sharded: Arc<ShardedEngine>,
}

impl EngineSet {
    /// Build every available engine for the array with default knobs.
    pub fn build(xs: &[f32], runtime: Option<Arc<Runtime>>) -> EngineSet {
        Self::build_with(xs, runtime, EngineCfg::default())
    }

    /// Build with explicit knobs (e.g. `--shard-block`).
    pub fn build_with(xs: &[f32], runtime: Option<Arc<Runtime>>, cfg: EngineCfg) -> EngineSet {
        let sharded = build_sharded(xs, cfg);
        let mut engines = build_static_engines(xs, runtime, cfg);
        let sharded_dyn: Arc<dyn Engine> = sharded.clone();
        engines.insert(1, sharded_dyn);
        EngineSet { n: xs.len(), engines, sharded }
    }

    pub fn get(&self, kind: EngineKind) -> Option<&dyn Engine> {
        self.engines.iter().find(|e| e.kind() == kind).map(|e| e.as_ref())
    }

    pub fn kinds(&self) -> Vec<EngineKind> {
        self.engines.iter().map(|e| e.kind()).collect()
    }

    /// The typed mutable engine (the staged write path is
    /// sharded-specific and does not fit the object-safe [`Engine`]
    /// surface).
    pub fn sharded(&self) -> &ShardedEngine {
        &self.sharded
    }

    /// Staged write path, one-shot surface: see
    /// [`ShardedEngine::prepare_update_batch`].
    pub fn prepare_update_batch(
        &self,
        updates: &[(usize, f32)],
        workers: usize,
    ) -> PreparedUpdate {
        self.sharded.prepare_update_batch(updates, workers)
    }

    /// Staged write path, one-shot surface: see
    /// [`ShardedEngine::commit_prepared`].
    pub fn commit_prepared(&self, p: PreparedUpdate, workers: usize) -> CommitOutcome {
        self.sharded.commit_prepared(p, workers)
    }
}

// ------------------------------------------------- epoch lifecycle --

/// Whether the background lifecycle may rebuild stale static engines
/// and re-shard online (`serve --rebuild auto|off`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum RebuildMode {
    #[default]
    Auto,
    Off,
}

impl RebuildMode {
    pub fn parse(s: &str) -> Option<RebuildMode> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(RebuildMode::Auto),
            "off" => Some(RebuildMode::Off),
            _ => None,
        }
    }
}

/// Lifecycle knobs (CLI-facing).
#[derive(Clone, Copy, Debug)]
pub struct LifecycleCfg {
    pub rebuild: RebuildMode,
    /// Re-shard when the workload-fed tuner's block size drifts at
    /// least this factor from the live one (either direction). Applies
    /// only under `--shard-block auto` — an explicit pin stays pinned.
    pub reshard_drift: f64,
    /// Observer half-life in observed segments
    /// (`workload::observer::WorkloadObserver`).
    pub observer_half_life: f64,
}

impl Default for LifecycleCfg {
    fn default() -> Self {
        LifecycleCfg { rebuild: RebuildMode::Auto, reshard_drift: 2.0, observer_half_life: 8.0 }
    }
}

/// One immutable engine generation. Query segments pin the epoch (an
/// `Arc` clone) for their duration, so a background publish never pulls
/// engines out from under an in-flight segment.
pub struct EngineEpoch {
    pub version: u64,
    /// Applied-update sequence number the static engines were built
    /// from. The epoch is *fresh* while this equals the mutable
    /// engine's live seq ([`EpochState::is_fresh`]).
    pub built_from_seq: u64,
    pub n: usize,
    engines: Vec<Arc<dyn Engine>>,
    kinds: Vec<EngineKind>,
}

impl EngineEpoch {
    fn new(version: u64, built_from_seq: u64, n: usize, engines: Vec<Arc<dyn Engine>>) -> Self {
        let kinds = engines.iter().map(|e| e.kind()).collect();
        EngineEpoch { version, built_from_seq, n, engines, kinds }
    }

    pub fn get(&self, kind: EngineKind) -> Option<&dyn Engine> {
        self.engines.iter().find(|e| e.kind() == kind).map(|e| e.as_ref())
    }

    pub fn kinds(&self) -> &[EngineKind] {
        &self.kinds
    }
}

/// Background work the lifecycle can schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BuildJob {
    /// Rebuild every static engine from a snapshot and publish a fresh
    /// epoch.
    Statics,
    /// Re-shard the mutable engine to the given block size.
    Reshard(usize),
}

/// The lifecycle manager: current epoch, the shared mutable engine, the
/// traffic observer, and the trigger logic. Shared (`Arc`) between the
/// serving thread, the background builder and the coordinator handle.
pub struct EpochState {
    pub n: usize,
    current: RwLock<Arc<EngineEpoch>>,
    sharded: Arc<ShardedEngine>,
    runtime: Option<Arc<Runtime>>,
    engine_cfg: EngineCfg,
    pub cfg: LifecycleCfg,
    cost: RtCostModel,
    /// Decayed view of served traffic, fed per segment by the serving
    /// thread.
    pub observer: Mutex<WorkloadObserver>,
    version: AtomicU64,
    rebuilds: AtomicU64,
    reshards: AtomicU64,
    /// At most one background job in flight (claimed by
    /// [`plan`](Self::plan), released when the builder finishes).
    pending: AtomicBool,
    /// Re-shard backoff: a failed install (an update batch landed
    /// mid-build) skips this many `plan` calls before retrying,
    /// doubling per consecutive failure — a sustained update stream
    /// with persistent tuner drift must not livelock the builder on
    /// full rebuilds that can never install.
    reshard_cooldown: AtomicU64,
    reshard_failures: AtomicU64,
    /// Hysteresis: consecutive `plan` calls whose tuned block size sat
    /// at or beyond `reshard_drift`. A re-shard fires only on the 2nd —
    /// adjacent power-of-two tunings can park the drift ratio at
    /// exactly the threshold, and one borderline observation must not
    /// churn a full re-shard.
    reshard_streak: AtomicU64,
}

impl EpochState {
    /// Build the initial epoch (version 0, seq 0) and the manager.
    pub fn bootstrap(
        xs: &[f32],
        runtime: Option<Arc<Runtime>>,
        engine_cfg: EngineCfg,
        cfg: LifecycleCfg,
    ) -> Arc<EpochState> {
        let sharded = build_sharded(xs, engine_cfg);
        let mut engines = build_static_engines(xs, runtime.clone(), engine_cfg);
        let sharded_dyn: Arc<dyn Engine> = sharded.clone();
        engines.insert(1, sharded_dyn);
        let epoch = Arc::new(EngineEpoch::new(0, 0, xs.len(), engines));
        Arc::new(EpochState {
            n: xs.len(),
            current: RwLock::new(epoch),
            sharded,
            runtime,
            engine_cfg,
            cfg,
            cost: RtCostModel::default(),
            observer: Mutex::new(WorkloadObserver::new(cfg.observer_half_life)),
            version: AtomicU64::new(0),
            rebuilds: AtomicU64::new(0),
            reshards: AtomicU64::new(0),
            pending: AtomicBool::new(false),
            reshard_cooldown: AtomicU64::new(0),
            reshard_failures: AtomicU64::new(0),
            reshard_streak: AtomicU64::new(0),
        })
    }

    /// The current epoch (an `Arc` clone — callers pin it per segment).
    pub fn current(&self) -> Arc<EngineEpoch> {
        self.current.read().clone()
    }

    /// The published applied-update sequence number.
    pub fn applied_seq(&self) -> u64 {
        self.sharded.seq()
    }

    /// Whether an epoch's static engines match the served values.
    pub fn is_fresh(&self, epoch: &EngineEpoch) -> bool {
        epoch.built_from_seq == self.applied_seq()
    }

    pub fn epoch_version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    pub fn rebuilds(&self) -> u64 {
        self.rebuilds.load(Ordering::Acquire)
    }

    pub fn reshards(&self) -> u64 {
        self.reshards.load(Ordering::Acquire)
    }

    pub fn shard_block_live(&self) -> usize {
        self.sharded.block_size()
    }

    /// Route an update batch to the mutable engine (bumps the seq, so
    /// every epoch built before it immediately reads as stale).
    pub fn update_batch(&self, updates: &[(usize, f32)], workers: usize) -> Result<EngineKind> {
        self.sharded.update_batch(updates, workers)?;
        Ok(EngineKind::Sharded)
    }

    /// Route a fenced ops segment (point + range mutations, stream
    /// order) to the mutable engine — the range-aware twin of
    /// [`update_batch`](Self::update_batch).
    pub fn update_ops(&self, ops: &[UpdateOp], workers: usize) -> Result<EngineKind> {
        self.sharded.update_ops(ops, workers)?;
        Ok(EngineKind::Sharded)
    }

    /// Lifetime range-update counters of the mutable engine.
    pub fn range_stats(&self) -> RangeStats {
        self.sharded.range_stats()
    }

    /// Pipelined write path, stage half: run by the serving loop's
    /// staging lane while the *preceding* query segment executes (safe:
    /// the fence only constrains later queries, and staging never
    /// mutates the live structure).
    pub fn prepare_update(&self, ops: &[UpdateOp], workers: usize) -> PreparedUpdate {
        self.sharded.prepare_update_ops(ops, workers)
    }

    /// Pipelined write path, commit half: runs at the fence. Seq
    /// accounting is identical to [`update_batch`](Self::update_batch)
    /// for either outcome, so epoch staleness and the observer feed see
    /// exactly the sequential protocol.
    pub fn commit_prepared(
        &self,
        p: PreparedUpdate,
        workers: usize,
    ) -> (EngineKind, CommitOutcome) {
        (EngineKind::Sharded, self.sharded.commit_prepared(p, workers))
    }

    /// Trigger logic, run by the serving thread after each fused batch
    /// (cheap: one observer snapshot + O(log n) tuner sweep). Claims
    /// the single pending slot when work is due; the caller forwards
    /// the job to the builder thread.
    pub fn plan(&self) -> Option<BuildJob> {
        if self.cfg.rebuild == RebuildMode::Off {
            return None;
        }
        if self.pending.load(Ordering::Acquire) {
            return None;
        }
        let obs = self.observer.lock().snapshot();
        if obs.ops == 0 {
            return None;
        }
        // Static rebuild first: restoring routing freedom outranks a
        // block-size adjustment, and a stale epoch means recent
        // updates — exactly when a re-shard install would abort
        // anyway. Fires once the epoch is stale and the observed
        // update rate has dropped below the cost model's threshold.
        let epoch = self.current();
        if !self.is_fresh(&epoch)
            && self.cost.rebuild_worthwhile(self.n, self.shard_block_live(), &obs)
        {
            return self.claim(BuildJob::Statics);
        }
        // Online re-shard: only when the block rule is the auto-tuner,
        // and only once any post-abort cooldown has elapsed.
        if matches!(self.engine_cfg.shard_block, ShardBlock::Auto { .. }) {
            if self.reshard_cooldown.load(Ordering::Acquire) > 0 {
                self.reshard_cooldown.fetch_sub(1, Ordering::AcqRel);
                return None;
            }
            let live = self.shard_block_live().max(1);
            let tuned = self.cost.tune_shard_block_observed(self.n, &obs).max(1);
            let drift = (tuned as f64 / live as f64).max(live as f64 / tuned as f64);
            if drift >= self.cfg.reshard_drift {
                // Hysteresis: fire only on the 2nd consecutive drifted
                // plan — see `reshard_streak`.
                if self.reshard_streak.fetch_add(1, Ordering::AcqRel) >= 1 {
                    self.reshard_streak.store(0, Ordering::Release);
                    return self.claim(BuildJob::Reshard(tuned));
                }
            } else {
                self.reshard_streak.store(0, Ordering::Release);
            }
        }
        None
    }

    fn claim(&self, job: BuildJob) -> Option<BuildJob> {
        self.pending
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .ok()
            .map(|_| job)
    }

    /// Release the pending slot without running the job (send failure).
    pub fn clear_pending(&self) {
        self.pending.store(false, Ordering::Release);
    }

    /// Execute one job — the builder thread's body. Rebuild latency and
    /// counters land in `metrics`; the epoch publish is an `Arc` swap
    /// under a short write lock.
    pub fn run_job(&self, job: BuildJob, metrics: &Mutex<Metrics>) {
        match job {
            BuildJob::Statics => {
                // Injected build failure: unwinds before any state is
                // touched — the builder loop catches it, serving pins
                // the last good epoch, plan() reschedules.
                faults::fire("build.statics");
                let t0 = Instant::now();
                let (xs, seq) = self.sharded.snapshot();
                let mut engines = build_static_engines(&xs, self.runtime.clone(), self.engine_cfg);
                let sharded_dyn: Arc<dyn Engine> = self.sharded.clone();
                engines.insert(1, sharded_dyn);
                let version = self.version.fetch_add(1, Ordering::AcqRel) + 1;
                let epoch = Arc::new(EngineEpoch::new(version, seq, self.n, engines));
                *self.current.write() = epoch;
                // Metrics before the counter: the counter is the
                // "rebuild done" signal pollers watch, and they expect
                // the recorded metrics to be visible once it trips.
                metrics.lock().record_rebuild(version, t0.elapsed().as_nanos() as u64);
                self.rebuilds.fetch_add(1, Ordering::AcqRel);
            }
            BuildJob::Reshard(block_size) => {
                faults::fire("build.reshard");
                if self.sharded.reshard(block_size) {
                    // Publish a version bump so the swap is observable;
                    // the statics are untouched — the sharded engine is
                    // shared by Arc, so the current epoch already serves
                    // the new decomposition.
                    let version = self.version.fetch_add(1, Ordering::AcqRel) + 1;
                    let cur = self.current();
                    *self.current.write() = Arc::new(EngineEpoch::new(
                        version,
                        cur.built_from_seq,
                        self.n,
                        cur.engines.clone(),
                    ));
                    metrics.lock().record_reshard(version, self.sharded.block_size());
                    self.reshard_failures.store(0, Ordering::Release);
                    self.reshards.fetch_add(1, Ordering::AcqRel);
                } else {
                    // Aborted: an update batch landed mid-build. Back
                    // off exponentially (in plan() calls) before the
                    // next attempt so sustained updates with persistent
                    // drift cannot livelock the builder.
                    let failures = self.reshard_failures.fetch_add(1, Ordering::AcqRel);
                    self.reshard_cooldown.store(1u64 << failures.min(8), Ordering::Release);
                }
            }
        }
        self.pending.store(false, Ordering::Release);
    }
}

/// Spawn the background builder: a dedicated thread draining lifecycle
/// jobs (the builds themselves parallelise over `util::pool` inside the
/// engine constructors, e.g. the sharded per-block build). Dropping
/// every sender stops the thread after the queue drains.
///
/// The loop is panic-isolated: a job that unwinds (a build bug, or an
/// injected `build.statics`/`build.reshard` fault) is caught, counted
/// as a builder respawn, and the pending slot released so `plan()` can
/// reschedule — serving pins the last good epoch meanwhile. Consecutive
/// panics back off exponentially before the next job is taken, so a
/// deterministically-crashing build cannot spin the builder hot.
pub fn spawn_builder(
    state: Arc<EpochState>,
    metrics: Arc<Mutex<Metrics>>,
) -> (SyncSender<BuildJob>, JoinHandle<()>) {
    let (tx, rx) = sync_channel::<BuildJob>(2);
    let handle = std::thread::spawn(move || {
        let mut consecutive_panics = 0u32;
        while let Ok(job) = rx.recv() {
            match catch_unwind(AssertUnwindSafe(|| state.run_job(job, &metrics))) {
                Ok(()) => consecutive_panics = 0,
                Err(_) => {
                    faults::note_caught();
                    // run_job died before its trailing release.
                    state.clear_pending();
                    metrics.lock().record_builder_respawn();
                    std::thread::sleep(Duration::from_millis(
                        1u64 << consecutive_panics.min(6),
                    ));
                    consecutive_panics += 1;
                }
            }
        }
    });
    (tx, handle)
}

/// Spawn the **shared** builder pool used by the multi-tenant front-end
/// (`coordinator::tenants`): one thread draining `(tenant_idx, job)`
/// pairs for every tenant's lifecycle work. Rebuilds and reshards are
/// heavyweight — funneling them through one pool keeps N tenants from
/// saturating N cores with background builds while serving lanes starve.
///
/// Backoff is **per tenant**: a tenant whose builds deterministically
/// panic (a build bug, or an injected `build.statics` fault aimed at it)
/// sleeps its own exponential backoff before the next job is taken, and
/// its pending slot is released so `plan()` can reschedule — but a
/// healthy tenant's jobs reset only that tenant's counter, never the
/// crashing one's. Dropping the sender stops the thread after the queue
/// drains.
pub fn spawn_shared_builder(
    tenants: Vec<(Arc<EpochState>, Arc<Mutex<Metrics>>)>,
) -> (SyncSender<(usize, BuildJob)>, JoinHandle<()>) {
    let (tx, rx) = sync_channel::<(usize, BuildJob)>(2 * tenants.len().max(1));
    let handle = std::thread::spawn(move || {
        let mut consecutive_panics = vec![0u32; tenants.len()];
        while let Ok((idx, job)) = rx.recv() {
            let Some((state, metrics)) = tenants.get(idx) else {
                continue;
            };
            match catch_unwind(AssertUnwindSafe(|| state.run_job(job, metrics))) {
                Ok(()) => consecutive_panics[idx] = 0,
                Err(_) => {
                    faults::note_caught();
                    // run_job died before its trailing release.
                    state.clear_pending();
                    metrics.lock().record_builder_respawn();
                    std::thread::sleep(Duration::from_millis(
                        1u64 << consecutive_panics[idx].min(6),
                    ));
                    consecutive_panics[idx] += 1;
                }
            }
        }
    });
    (tx, handle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rmq::sparse_table::oracle_batch;
    use crate::util::rng::Rng;
    use crate::workload::{gen_queries, RangeDist};

    #[test]
    fn all_solver_engines_agree_with_oracle() {
        let mut rng = Rng::new(60);
        let xs = rng.uniform_f32_vec(2000);
        let set = EngineSet::build(&xs, None);
        let queries = gen_queries(2000, 128, RangeDist::Medium, &mut rng);
        let want = oracle_batch(&xs, &queries);
        for kind in [
            EngineKind::Rtx,
            EngineKind::Sharded,
            EngineKind::Lca,
            EngineKind::Hrmq,
            EngineKind::Exhaustive,
        ] {
            let e = set.get(kind).expect("engine present");
            let got = e.solve(&queries, 2).unwrap();
            assert_eq!(got, want, "{}", kind.name());
        }
    }

    #[test]
    fn engine_kind_names_roundtrip() {
        for k in EngineKind::all() {
            assert_eq!(EngineKind::parse(k.name()), Some(k));
        }
        assert_eq!(EngineKind::parse("nope"), None);
    }

    #[test]
    fn xla_engine_absent_without_runtime() {
        let xs = Rng::new(61).uniform_f32_vec(64);
        let set = EngineSet::build(&xs, None);
        assert!(set.get(EngineKind::Xla).is_none());
        assert_eq!(set.kinds().len(), 5);
    }

    #[test]
    fn shard_block_knob_reaches_engine() {
        let xs = Rng::new(63).uniform_f32_vec(512);
        let set =
            EngineSet::build_with(&xs, None, EngineCfg { shard_block: ShardBlock::Fixed(32), ..Default::default() });
        let e = set.get(EngineKind::Sharded).expect("sharded built");
        let queries = vec![(0u32, 511u32), (31, 32), (100, 100)];
        assert_eq!(e.solve(&queries, 2).unwrap(), oracle_batch(&xs, &queries));
        assert!(e.memory_bytes() > 0);
    }

    #[test]
    fn packet_knobs_reach_engines_and_stay_bit_identical() {
        // --packet-width / --no-sort-queries are pure A/B knobs: every
        // combination must answer exactly like the scalar default on
        // both traversal-driven engines.
        let mut rng = Rng::new(90);
        let xs = rng.uniform_f32_vec(3000);
        let queries = gen_queries(3000, 256, RangeDist::Small, &mut rng);
        let want = oracle_batch(&xs, &queries);
        for packet_width in [0usize, 8] {
            for no_sort_queries in [false, true] {
                let set = EngineSet::build_with(
                    &xs,
                    None,
                    EngineCfg {
                        shard_block: ShardBlock::Fixed(64),
                        packet_width,
                        no_sort_queries,
                    },
                );
                for kind in [EngineKind::Rtx, EngineKind::Sharded] {
                    let got = set.get(kind).unwrap().solve(&queries, 2).unwrap();
                    assert_eq!(
                        got,
                        want,
                        "{} packet_width={packet_width} no_sort={no_sort_queries}",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn shard_block_parses_and_resolves() {
        let dist = RangeDist::Small;
        assert_eq!(ShardBlock::parse("64", dist, 0.0), Some(ShardBlock::Fixed(64)));
        assert_eq!(ShardBlock::parse("2^8", dist, 0.0), Some(ShardBlock::Fixed(256)));
        assert_eq!(ShardBlock::parse("0", dist, 0.0), Some(ShardBlock::Sqrt));
        assert_eq!(ShardBlock::parse("nope", dist, 0.0), None);
        assert_eq!(
            ShardBlock::parse("AUTO", dist, 0.25),
            Some(ShardBlock::Auto { dist, update_frac: 0.25 })
        );
        assert_eq!(ShardBlock::Sqrt.resolve(1 << 16), 0);
        assert_eq!(ShardBlock::Fixed(128).resolve(1 << 16), 128);
        let auto = ShardBlock::Auto { dist, update_frac: 0.1 }.resolve(1 << 16);
        assert!(auto.is_power_of_two() && (4..=1 << 12).contains(&auto), "auto = {auto}");
    }

    #[test]
    fn auto_shard_block_builds_and_answers() {
        let xs = Rng::new(65).uniform_f32_vec(2048);
        let set = EngineSet::build_with(
            &xs,
            None,
            EngineCfg {
                shard_block: ShardBlock::Auto { dist: RangeDist::Small, update_frac: 0.1 },
                ..Default::default()
            },
        );
        let e = set.get(EngineKind::Sharded).expect("sharded built");
        let queries = vec![(0u32, 2047u32), (100, 140), (2047, 2047)];
        assert_eq!(e.solve(&queries, 2).unwrap(), oracle_batch(&xs, &queries));
    }

    #[test]
    fn updates_flow_through_the_epoch_state() {
        let mut xs = Rng::new(64).uniform_f32_vec(512);
        let state = EpochState::bootstrap(
            &xs,
            None,
            EngineCfg { shard_block: ShardBlock::Fixed(32), ..Default::default() },
            LifecycleCfg::default(),
        );
        let epoch = state.current();
        assert_eq!(epoch.version, 0);
        assert_eq!(epoch.built_from_seq, 0);
        assert!(state.is_fresh(&epoch));
        // Static engines refuse the write path.
        for kind in [EngineKind::Rtx, EngineKind::Lca, EngineKind::Hrmq, EngineKind::Exhaustive] {
            let e = epoch.get(kind).unwrap();
            assert!(!e.supports_updates());
            assert!(e.update_batch(&[(0, 0.5)], 1).is_err(), "{}", kind.name());
        }
        assert!(state.is_fresh(&epoch), "refused updates must not bump the seq");
        // An applied batch bumps the seq: the epoch reads as stale.
        let updates = vec![(3usize, -1.0f32), (31, -0.5), (32, -0.25), (511, -2.0)];
        assert_eq!(state.update_batch(&updates, 2).unwrap(), EngineKind::Sharded);
        assert_eq!(state.applied_seq(), 1);
        assert!(!state.is_fresh(&epoch));
        for &(i, v) in &updates {
            xs[i] = v;
        }
        let queries = vec![(0u32, 511u32), (4, 40), (32, 511)];
        let got = epoch.get(EngineKind::Sharded).unwrap().solve(&queries, 2).unwrap();
        assert_eq!(got, oracle_batch(&xs, &queries));
    }

    #[test]
    fn staged_commit_installs_when_nothing_conflicts() {
        let mut xs = Rng::new(80).uniform_f32_vec(1024);
        let state = EpochState::bootstrap(
            &xs,
            None,
            EngineCfg { shard_block: ShardBlock::Fixed(64), ..Default::default() },
            LifecycleCfg::default(),
        );
        let batch = vec![(5usize, -1.0f32), (63, -0.5), (64, -0.25), (900, -2.0)];
        let ops: Vec<UpdateOp> =
            batch.iter().map(|&(i, v)| UpdateOp::Point { i, v }).collect();
        let prep = state.prepare_update(&ops, 2);
        assert_eq!(prep.len(), 4);
        assert!(!prep.is_empty());
        assert!(prep.prep_ns > 0);
        // Staging mutates nothing: the epoch is still fresh.
        assert!(state.is_fresh(&state.current()));
        assert_eq!(state.applied_seq(), 0);
        let (kind, outcome) = state.commit_prepared(prep, 2);
        assert_eq!(kind, EngineKind::Sharded);
        assert_eq!(outcome, CommitOutcome::Installed);
        assert_eq!(state.applied_seq(), 1, "commit bumps the seq exactly once");
        assert!(!state.is_fresh(&state.current()), "staleness accounting as in direct apply");
        for &(i, v) in &batch {
            xs[i] = v;
        }
        let queries = vec![(0u32, 1023u32), (60, 70), (890, 910)];
        let got = state.current().get(EngineKind::Sharded).unwrap().solve(&queries, 2).unwrap();
        assert_eq!(got, oracle_batch(&xs, &queries));
    }

    #[test]
    fn range_ops_flow_and_stats_survive_reshard() {
        let mut xs = Rng::new(83).uniform_f32_vec(1024);
        let state = EpochState::bootstrap(
            &xs,
            None,
            EngineCfg { shard_block: ShardBlock::Fixed(64), ..Default::default() },
            LifecycleCfg::default(),
        );
        let ops = vec![
            UpdateOp::RangeAdd { l: 0, r: 1023, v: 0.5 },
            UpdateOp::Point { i: 7, v: -1.0 },
            UpdateOp::RangeAssign { l: 100, r: 300, v: 0.25 },
        ];
        state.update_ops(&ops, 2).unwrap();
        for op in &ops {
            op.apply_naive(&mut xs);
        }
        assert_eq!(state.applied_seq(), 1, "one seq bump per fenced segment");
        let stats = state.range_stats();
        assert_eq!(stats.range_updates, 2);
        assert!(stats.tag_hits >= 16, "full-coverage add takes the tag path: {stats:?}");
        // A range-carrying segment stages pointer-sized and commits as
        // tag application under the same fingerprint guard.
        let seg = vec![UpdateOp::RangeAdd { l: 10, r: 900, v: -0.125 }];
        let prep = state.prepare_update(&seg, 2);
        let (_, outcome) = state.commit_prepared(prep, 2);
        assert_eq!(outcome, CommitOutcome::Installed);
        for op in &seg {
            op.apply_naive(&mut xs);
        }
        let queries = vec![(0u32, 1023u32), (90, 310), (5, 9)];
        let got = state.current().get(EngineKind::Sharded).unwrap().solve(&queries, 2).unwrap();
        assert_eq!(got, oracle_batch(&xs, &queries));
        // A re-shard swaps the structure but keeps the lifetime
        // counters monotone (the replacement adopts them).
        let metrics = Mutex::new(Metrics::new());
        state.run_job(BuildJob::Reshard(16), &metrics);
        assert_eq!(state.shard_block_live(), 16);
        let after = state.range_stats();
        assert!(after.range_updates >= 3 && after.tag_hits >= stats.tag_hits, "{after:?}");
        let got = state.current().get(EngineKind::Sharded).unwrap().solve(&queries, 2).unwrap();
        assert_eq!(got, oracle_batch(&xs, &queries));
    }

    #[test]
    fn staged_commit_falls_back_on_conflicting_write() {
        // A different update batch lands between stage and commit: the
        // prepared work is void (it was built from pre-conflict values),
        // the commit must take the direct path, and the final state must
        // equal conflict-then-batch applied in order.
        let mut xs = Rng::new(81).uniform_f32_vec(512);
        let state = EpochState::bootstrap(
            &xs,
            None,
            EngineCfg { shard_block: ShardBlock::Fixed(32), ..Default::default() },
            LifecycleCfg::default(),
        );
        let batch = vec![(10usize, -1.0f32), (11, 0.9)];
        let ops: Vec<UpdateOp> =
            batch.iter().map(|&(i, v)| UpdateOp::Point { i, v }).collect();
        let prep = state.prepare_update(&ops, 2);
        // The conflict: overlaps block 0 (index 11) so the stale
        // prepared block would resurrect old values if installed.
        state.update_batch(&[(11, -3.0), (400, -2.0)], 2).unwrap();
        let (_, outcome) = state.commit_prepared(prep, 2);
        assert_eq!(outcome, CommitOutcome::FellBack);
        assert_eq!(state.applied_seq(), 2);
        for &(i, v) in &[(11usize, -3.0f32), (400, -2.0), (10, -1.0), (11, 0.9)] {
            xs[i] = v;
        }
        let queries = vec![(0u32, 511u32), (8, 16), (390, 410)];
        let got = state.current().get(EngineKind::Sharded).unwrap().solve(&queries, 2).unwrap();
        assert_eq!(got, oracle_batch(&xs, &queries), "fallback applies the batch in order");
    }

    #[test]
    fn staged_commit_falls_back_after_a_reshard() {
        // A re-shard between stage and commit changes the decomposition
        // but not the values (seq unmoved) — the shape generation must
        // catch it and route the commit through the direct path.
        let mut xs = Rng::new(82).uniform_f32_vec(2048);
        let state = EpochState::bootstrap(
            &xs,
            None,
            EngineCfg { shard_block: ShardBlock::Fixed(64), ..Default::default() },
            LifecycleCfg::default(),
        );
        let batch = vec![(100usize, -1.0f32), (2000, -0.5)];
        let ops: Vec<UpdateOp> =
            batch.iter().map(|&(i, v)| UpdateOp::Point { i, v }).collect();
        let prep = state.prepare_update(&ops, 2);
        let metrics = Mutex::new(Metrics::new());
        state.run_job(BuildJob::Reshard(16), &metrics);
        assert_eq!(state.shard_block_live(), 16);
        let (_, outcome) = state.commit_prepared(prep, 2);
        assert_eq!(outcome, CommitOutcome::FellBack);
        assert_eq!(state.applied_seq(), 1);
        for &(i, v) in &batch {
            xs[i] = v;
        }
        let queries = vec![(0u32, 2047u32), (90, 110), (1990, 2047)];
        let got = state.current().get(EngineKind::Sharded).unwrap().solve(&queries, 2).unwrap();
        assert_eq!(got, oracle_batch(&xs, &queries));
    }

    #[test]
    fn statics_rebuild_publishes_a_fresh_epoch() {
        let mut xs = Rng::new(66).uniform_f32_vec(1024);
        let state = EpochState::bootstrap(
            &xs,
            None,
            EngineCfg { shard_block: ShardBlock::Fixed(64), ..Default::default() },
            LifecycleCfg::default(),
        );
        let updates = vec![(100usize, -0.5f32), (900, -0.25)];
        state.update_batch(&updates, 2).unwrap();
        for &(i, v) in &updates {
            xs[i] = v;
        }
        let old = state.current();
        assert!(!state.is_fresh(&old));
        let metrics = Mutex::new(Metrics::new());
        state.run_job(BuildJob::Statics, &metrics);
        let fresh = state.current();
        assert_eq!(fresh.version, 1);
        assert_eq!(fresh.built_from_seq, 1);
        assert!(state.is_fresh(&fresh));
        assert!(!state.is_fresh(&old), "the old epoch stays stale");
        assert_eq!(state.rebuilds(), 1);
        assert_eq!(metrics.lock().rebuilds, 1);
        // The rebuilt statics serve the *updated* values.
        let queries = vec![(0u32, 1023u32), (50, 150), (850, 950)];
        let want = oracle_batch(&xs, &queries);
        for kind in [EngineKind::Rtx, EngineKind::Lca, EngineKind::Exhaustive] {
            let got = fresh.get(kind).unwrap().solve(&queries, 2).unwrap();
            assert_eq!(got, want, "{}", kind.name());
        }
        // The old epoch's statics still answer from the old array — the
        // in-flight-segment contract.
        let stale_got = old.get(EngineKind::Lca).unwrap().solve(&[(100, 100)], 1).unwrap();
        assert_eq!(stale_got, vec![100]);
    }

    #[test]
    fn reshard_swaps_and_aborts_on_seq_movement() {
        let xs = Rng::new(67).uniform_f32_vec(2048);
        let state = EpochState::bootstrap(
            &xs,
            None,
            EngineCfg { shard_block: ShardBlock::Fixed(64), ..Default::default() },
            LifecycleCfg::default(),
        );
        assert_eq!(state.shard_block_live(), 64);
        let metrics = Mutex::new(Metrics::new());
        state.run_job(BuildJob::Reshard(16), &metrics);
        assert_eq!(state.shard_block_live(), 16);
        assert_eq!(state.reshards(), 1);
        assert_eq!(state.epoch_version(), 1);
        let queries = vec![(0u32, 2047u32), (60, 70), (1000, 1100)];
        let got = state.current().get(EngineKind::Sharded).unwrap().solve(&queries, 2).unwrap();
        assert_eq!(got, oracle_batch(&xs, &queries));
        // A replacement built from a stale snapshot must not install.
        let replacement = ShardedRmq::with_options(
            &xs,
            ShardedOptions { block_size: 128, ..Default::default() },
        );
        state.update_batch(&[(0, -1.0)], 1).unwrap();
        assert!(!state.sharded.install(replacement, 0), "stale install must abort");
        assert_eq!(state.shard_block_live(), 16, "block size unchanged after abort");
    }

    #[test]
    fn plan_fires_statics_rebuild_only_after_quiet_period() {
        let n = 1usize << 14;
        let xs = Rng::new(68).uniform_f32_vec(n);
        let state = EpochState::bootstrap(
            &xs,
            None,
            EngineCfg { shard_block: ShardBlock::Fixed(128), ..Default::default() },
            LifecycleCfg { observer_half_life: 4.0, ..Default::default() },
        );
        let mut rng = Rng::new(69);
        let qs = gen_queries(n, 64, RangeDist::Small, &mut rng);
        // Fresh + no traffic: nothing to do.
        assert_eq!(state.plan(), None);
        // Stale but busy: the threshold holds the rebuild back.
        state.update_batch(&[(5, -0.5)], 1).unwrap();
        for _ in 0..4 {
            let mut o = state.observer.lock();
            o.observe_queries(&qs);
            o.observe_updates(64);
        }
        assert_eq!(state.plan(), None, "busy traffic must not trigger a rebuild");
        // Quiet period: decay until the threshold trips.
        let mut fired = None;
        for k in 0..500 {
            state.observer.lock().observe_queries(&qs);
            if let Some(job) = state.plan() {
                fired = Some((k, job));
                break;
            }
        }
        let (k, job) = fired.expect("quiet period must trigger a rebuild");
        assert_eq!(job, BuildJob::Statics);
        assert!(k > 0, "not on the first quiet segment (frac still high)");
        // The pending slot is claimed: no double-scheduling.
        assert_eq!(state.plan(), None);
        state.clear_pending();
        assert!(state.plan().is_some(), "cleared slot can re-claim");
    }

    #[test]
    fn plan_fires_reshard_on_observed_drift() {
        let n = 1usize << 14;
        let xs = Rng::new(70).uniform_f32_vec(n);
        let state = EpochState::bootstrap(
            &xs,
            None,
            EngineCfg {
                shard_block: ShardBlock::Auto { dist: RangeDist::Small, update_frac: 0.3 },
                ..Default::default()
            },
            LifecycleCfg { observer_half_life: 4.0, ..Default::default() },
        );
        let initial = state.shard_block_live();
        assert!(initial >= 4);
        // Offer pure large-range traffic: the observed-optimal block
        // size collapses far below the prior-tuned one.
        let mut rng = Rng::new(71);
        let large = gen_queries(n, 128, RangeDist::Large, &mut rng);
        let mut fired = None;
        for _ in 0..50 {
            state.observer.lock().observe_queries(&large);
            if let Some(job) = state.plan() {
                fired = Some(job);
                break;
            }
        }
        match fired.expect("distribution shift must trigger a re-shard") {
            BuildJob::Reshard(bs) => {
                let drift = (bs as f64 / initial as f64).max(initial as f64 / bs as f64);
                assert!(drift >= 2.0, "initial {initial} tuned {bs}");
                // Run it: the swap happens (no updates landed) and the
                // engine still answers correctly.
                let metrics = Mutex::new(Metrics::new());
                state.run_job(BuildJob::Reshard(bs), &metrics);
                assert_eq!(state.shard_block_live(), bs);
                assert_eq!(state.reshards(), 1);
                let queries = vec![(0u32, (n - 1) as u32), (77, 4000)];
                let got =
                    state.current().get(EngineKind::Sharded).unwrap().solve(&queries, 2).unwrap();
                assert_eq!(got, oracle_batch(&xs, &queries));
            }
            j => panic!("expected a re-shard, got {j:?}"),
        }
    }

    #[test]
    fn reshard_cooldown_gates_retries_after_aborted_installs() {
        let n = 1usize << 14;
        let xs = Rng::new(75).uniform_f32_vec(n);
        let state = EpochState::bootstrap(
            &xs,
            None,
            EngineCfg {
                shard_block: ShardBlock::Auto { dist: RangeDist::Small, update_frac: 0.3 },
                ..Default::default()
            },
            LifecycleCfg::default(),
        );
        // Offer drifted traffic, as in plan_fires_reshard_on_observed_drift.
        let mut rng = Rng::new(76);
        let large = gen_queries(n, 128, RangeDist::Large, &mut rng);
        state.observer.lock().observe_queries(&large);
        // Simulate two aborted installs' worth of backoff.
        state.reshard_failures.store(1, Ordering::Release);
        state.reshard_cooldown.store(2, Ordering::Release);
        assert_eq!(state.plan(), None, "cooldown tick 1 skips the re-shard");
        assert_eq!(state.plan(), None, "cooldown tick 2 skips the re-shard");
        assert_eq!(state.plan(), None, "first post-cooldown drifted plan only arms hysteresis");
        match state.plan() {
            Some(BuildJob::Reshard(_)) => {}
            j => panic!("cooldown elapsed: expected a re-shard, got {j:?}"),
        }
    }

    #[test]
    fn reshard_hysteresis_requires_two_consecutive_drifted_plans() {
        let n = 1usize << 14;
        let xs = Rng::new(77).uniform_f32_vec(n);
        let state = EpochState::bootstrap(
            &xs,
            None,
            EngineCfg {
                shard_block: ShardBlock::Auto { dist: RangeDist::Small, update_frac: 0.3 },
                ..Default::default()
            },
            LifecycleCfg::default(),
        );
        // Sustained drifted traffic, as in plan_fires_reshard_on_observed_drift.
        let mut rng = Rng::new(78);
        let large = gen_queries(n, 128, RangeDist::Large, &mut rng);
        state.observer.lock().observe_queries(&large);
        assert_eq!(state.plan(), None, "one drifted observation must not re-shard");
        assert!(
            matches!(state.plan(), Some(BuildJob::Reshard(_))),
            "the 2nd consecutive drifted plan fires"
        );
        // Firing resets the streak: the next pair behaves the same.
        state.clear_pending();
        assert_eq!(state.plan(), None, "streak restarts after a fire");
        assert!(matches!(state.plan(), Some(BuildJob::Reshard(_))));
    }

    #[test]
    fn rebuild_off_never_plans() {
        let n = 1usize << 12;
        let xs = Rng::new(72).uniform_f32_vec(n);
        let state = EpochState::bootstrap(
            &xs,
            None,
            EngineCfg::default(),
            LifecycleCfg { rebuild: RebuildMode::Off, ..Default::default() },
        );
        state.update_batch(&[(1, -1.0)], 1).unwrap();
        let mut rng = Rng::new(73);
        let qs = gen_queries(n, 64, RangeDist::Small, &mut rng);
        for _ in 0..100 {
            state.observer.lock().observe_queries(&qs);
            assert_eq!(state.plan(), None);
        }
    }

    #[test]
    fn builder_thread_drains_jobs_and_stops() {
        let xs = Rng::new(74).uniform_f32_vec(1024);
        let state = EpochState::bootstrap(
            &xs,
            None,
            EngineCfg { shard_block: ShardBlock::Fixed(64), ..Default::default() },
            LifecycleCfg::default(),
        );
        state.update_batch(&[(7, -0.5)], 1).unwrap();
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (tx, handle) = spawn_builder(state.clone(), metrics.clone());
        tx.send(BuildJob::Statics).unwrap();
        drop(tx);
        handle.join().unwrap();
        assert_eq!(state.rebuilds(), 1);
        assert!(state.is_fresh(&state.current()));
        assert_eq!(metrics.lock().epoch_version, 1);
    }

    #[test]
    fn memory_ordering_matches_table2() {
        // Table 2: HRMQ << LCA << RTXRMQ.
        let xs = Rng::new(62).uniform_f32_vec(1 << 14);
        let set = EngineSet::build(&xs, None);
        let mem = |k: EngineKind| set.get(k).unwrap().memory_bytes();
        assert!(mem(EngineKind::Hrmq) < mem(EngineKind::Lca));
        assert!(mem(EngineKind::Lca) < mem(EngineKind::Rtx));
        // Structure-free in Table 2 terms, but the solver owns the copy
        // it scans and resident accounting counts owned allocations.
        assert_eq!(mem(EngineKind::Exhaustive), xs.len() * 4);
    }
}

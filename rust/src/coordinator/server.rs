//! The serving loop: bounded request queue → dynamic batcher → router →
//! engine epoch → reply. One array ("model") per coordinator. Engines
//! live in **epochs** (`coordinator::engine`): query segments pin the
//! current epoch for their duration and route against its freshness,
//! update segments mutate the shared sharded engine and bump the
//! published applied-update sequence, and a background builder rebuilds
//! stale static engines / re-shards once the observed traffic says it
//! is worthwhile — so the Fig. 12 crossover routing comes back after a
//! burst of updates instead of being lost forever.

use super::batcher::{next_batch, BatcherCfg, Request, Response, Segment};
use super::engine::{spawn_builder, BuildJob, EngineCfg, EngineKind, EpochState, LifecycleCfg};
use super::metrics::Metrics;
use super::router::{Policy, Router};
use crate::rmq::Query;
use crate::runtime::Runtime;
use crate::workload::{validate_ops, Op};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorCfg {
    pub policy: Policy,
    pub batcher: BatcherCfg,
    /// Worker threads used by the engines for one fused batch.
    pub engine_workers: usize,
    /// Engine build knobs (e.g. the sharded engine's block size).
    pub engines: EngineCfg,
    /// Epoch-lifecycle knobs (`serve --rebuild`, `--reshard-drift`).
    pub lifecycle: LifecycleCfg,
}

impl Default for CoordinatorCfg {
    fn default() -> Self {
        CoordinatorCfg {
            policy: Policy::ModeledCost,
            batcher: BatcherCfg::default(),
            engine_workers: crate::util::pool::default_workers(),
            engines: EngineCfg::default(),
            lifecycle: LifecycleCfg::default(),
        }
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: Option<SyncSender<Request>>,
    worker: Option<JoinHandle<()>>,
    job_tx: Option<SyncSender<BuildJob>>,
    builder: Option<JoinHandle<()>>,
    pub metrics: Arc<Mutex<Metrics>>,
    /// Observable lifecycle state (epoch version, rebuild/re-shard
    /// counters, live block size).
    pub lifecycle: Arc<EpochState>,
    next_id: AtomicU64,
    n: usize,
}

impl Coordinator {
    /// Build the initial epoch for `xs`, start the background builder
    /// and the serving thread.
    pub fn start(xs: &[f32], runtime: Option<Arc<Runtime>>, cfg: CoordinatorCfg) -> Coordinator {
        let state = EpochState::bootstrap(xs, runtime, cfg.engines, cfg.lifecycle);
        let router = Router::new(cfg.policy);
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (job_tx, builder) = spawn_builder(state.clone(), metrics.clone());
        let (tx, rx) = sync_channel::<Request>(cfg.batcher.queue_cap);
        let m = metrics.clone();
        let st = state.clone();
        let jt = job_tx.clone();
        let n = xs.len();
        let batcher_cfg = cfg.batcher;
        let workers = cfg.engine_workers;
        let worker = std::thread::spawn(move || {
            while let Some(fused) = next_batch(&rx, &batcher_cfg) {
                let t0 = std::time::Instant::now();
                let mut answers: Vec<u32> = Vec::with_capacity(fused.total_queries());
                let mut query_engine: Option<&'static str> = None;
                let mut update_engine: Option<&'static str> = None;
                let mut updates_ok = true;
                // Published-epoch version (not the raw counter, which
                // briefly runs ahead mid-publish): keeps response epochs
                // monotone across update-only batches.
                let mut epoch_seen = st.current().version;
                // Segments execute strictly in stream order on this one
                // thread — that *is* the fence: an update segment is
                // visible to every later query segment and to none
                // earlier.
                for seg in &fused.segments {
                    match seg {
                        Segment::Queries(qs) => {
                            // Pin this segment to the epoch current at its
                            // start: the Arc keeps a mid-segment background
                            // swap from freeing engines under us; the next
                            // segment re-loads and routes freely against
                            // whatever epoch is current by then.
                            let epoch = st.current();
                            let fresh = st.is_fresh(&epoch);
                            let kind = router.route_epoch(n, qs, epoch.kinds(), fresh);
                            let engine = epoch.get(kind).expect("routed engine exists");
                            let ts = std::time::Instant::now();
                            let got = match engine.solve(qs, workers) {
                                Ok(a) => a,
                                Err(e) => {
                                    // Only the XLA engine can fail, and a
                                    // stale epoch never routes to it — so
                                    // the exhaustive fallback still sees
                                    // the array its epoch was built from.
                                    eprintln!("engine {} failed: {e}", kind.name());
                                    epoch
                                        .get(EngineKind::Exhaustive)
                                        .expect("exhaustive always built")
                                        .solve(qs, workers)
                                        .expect("exhaustive cannot fail")
                                }
                            };
                            let seg_ns = ts.elapsed().as_nanos() as u64;
                            m.lock().unwrap().record_batch(kind, qs.len() as u64, seg_ns);
                            st.observer.lock().unwrap().observe_queries(qs);
                            epoch_seen = epoch.version;
                            // Last segment wins: once an update fences the
                            // batch, later segments are the current truth.
                            query_engine = Some(kind.name());
                            answers.extend_from_slice(&got);
                        }
                        Segment::Updates(ups) => {
                            let ts = std::time::Instant::now();
                            match st.update_batch(ups, workers) {
                                Ok(kind) => {
                                    update_engine.get_or_insert(kind.name());
                                    m.lock().unwrap().record_update_batch(
                                        ups.len() as u64,
                                        ts.elapsed().as_nanos() as u64,
                                    );
                                }
                                // Admission validated the indices; this
                                // only fires when no mutable engine is
                                // built, which bootstrap precludes.
                                Err(e) => {
                                    eprintln!("update batch dropped: {e}");
                                    updates_ok = false;
                                }
                            }
                            st.observer.lock().unwrap().observe_updates(ups.len());
                        }
                    }
                }
                // Refresh the metrics' decayed-traffic view, then let the
                // lifecycle plan background work off it (rebuild once the
                // update rate is quiet, re-shard on tuner drift).
                {
                    let obs = st.observer.lock().unwrap().snapshot();
                    m.lock().unwrap().record_observed(
                        obs,
                        st.epoch_version(),
                        st.shard_block_live(),
                    );
                }
                if let Some(job) = st.plan() {
                    if jt.try_send(job).is_err() {
                        st.clear_pending();
                    }
                }
                let latency = t0.elapsed().as_nanos() as u64;
                let per_request = fused.split_answers(&answers);
                let engine_name = query_engine.or(update_engine).unwrap_or("NONE");
                for ((req, ans), &ups) in
                    fused.requests.iter().zip(per_request).zip(&fused.update_splits)
                {
                    // A dropped client is not an error. A dropped update
                    // segment must not be reported as applied.
                    let _ = req.reply.try_send(Response {
                        id: req.id,
                        answers: ans,
                        updates_applied: if updates_ok { ups } else { 0 },
                        engine: engine_name,
                        epoch: epoch_seen,
                        batch_latency_ns: latency,
                    });
                }
            }
        });
        Coordinator {
            tx: Some(tx),
            worker: Some(worker),
            job_tx: Some(job_tx),
            builder: Some(builder),
            metrics,
            lifecycle: state,
            next_id: AtomicU64::new(0),
            n,
        }
    }

    /// Validated blocking query: submit and wait for the answer.
    pub fn query(&self, queries: Vec<Query>) -> Result<Response> {
        self.submit_mixed(queries.into_iter().map(Op::Query).collect())
    }

    /// Validated blocking mixed request: queries and point updates
    /// execute in op order with fencing — an update is visible to every
    /// later query in the stream (and in any later request) and to no
    /// earlier one. Returns one answer per query op, in op order.
    pub fn submit_mixed(&self, ops: Vec<Op>) -> Result<Response> {
        validate_ops(self.n, &ops).map_err(|e| {
            self.metrics.lock().unwrap().record_rejected();
            anyhow!(e)
        })?;
        self.metrics.lock().unwrap().record_request();
        let (reply_tx, reply_rx) = sync_channel(1);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request { id, ops, reply: reply_tx };
        self.tx
            .as_ref()
            .expect("not shut down")
            .send(req)
            .map_err(|_| anyhow!("coordinator stopped"))?;
        reply_rx.recv().map_err(|_| anyhow!("coordinator dropped reply"))
    }

    /// Non-blocking submit; Err(queries) when the queue is full
    /// (backpressure surfaced to the caller).
    pub fn try_submit(
        &self,
        queries: Vec<Query>,
        reply: SyncSender<Response>,
    ) -> std::result::Result<u64, Vec<Query>> {
        let unwrap_queries = |ops: Vec<Op>| {
            ops.into_iter()
                .filter_map(|op| match op {
                    Op::Query(q) => Some(q),
                    Op::Update { .. } => None,
                })
                .collect()
        };
        if crate::rmq::validate_queries(self.n, &queries).is_err() {
            self.metrics.lock().unwrap().record_rejected();
            return Err(queries);
        }
        self.metrics.lock().unwrap().record_request();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request::queries(id, queries, reply);
        match self.tx.as_ref().expect("not shut down").try_send(req) {
            Ok(()) => Ok(id),
            Err(TrySendError::Full(r)) | Err(TrySendError::Disconnected(r)) => {
                Err(unwrap_queries(r.ops))
            }
        }
    }

    /// Graceful shutdown: drain the request queue, join the serving
    /// thread, then drain the lifecycle queue and join the builder.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        drop(self.job_tx.take());
        if let Some(b) = self.builder.take() {
            let _ = b.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::RebuildMode;
    use crate::rmq::sparse_table::oracle_batch;
    use crate::util::rng::Rng;
    use crate::workload::{gen_queries, RangeDist};

    fn coordinator(n: usize, policy: Policy) -> (Coordinator, Vec<f32>) {
        let xs = Rng::new(80).uniform_f32_vec(n);
        let c = Coordinator::start(
            &xs,
            None,
            CoordinatorCfg { policy, ..Default::default() },
        );
        (c, xs)
    }

    #[test]
    fn serves_correct_answers() {
        let (c, xs) = coordinator(4096, Policy::ModeledCost);
        let mut rng = Rng::new(81);
        for dist in RangeDist::all() {
            let qs = gen_queries(4096, 64, dist, &mut rng);
            let resp = c.query(qs.clone()).unwrap();
            assert_eq!(resp.answers, oracle_batch(&xs, &qs), "{dist:?}");
            assert_eq!(resp.epoch, 0, "no lifecycle events on a read-only run");
        }
        c.shutdown();
    }

    #[test]
    fn rejects_invalid_queries() {
        let (c, _) = coordinator(128, Policy::Heuristic);
        assert!(c.query(vec![(5, 4)]).is_err());
        assert!(c.query(vec![(0, 128)]).is_err());
        assert_eq!(c.metrics.lock().unwrap().rejected, 2);
        c.shutdown();
    }

    #[test]
    fn concurrent_clients_all_served() {
        let (c, xs) = coordinator(2048, Policy::ModeledCost);
        let c = Arc::new(c);
        let xs = Arc::new(xs);
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            let xs = xs.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                for _ in 0..10 {
                    let qs = gen_queries(2048, 16, RangeDist::Small, &mut rng);
                    let resp = c.query(qs.clone()).unwrap();
                    assert_eq!(resp.answers, oracle_batch(&xs, &qs));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = c.metrics.lock().unwrap();
        assert_eq!(m.requests, 40);
        assert_eq!(m.total_queries(), 40 * 16);
    }

    #[test]
    fn metrics_track_engines() {
        let (c, _) = coordinator(1 << 15, Policy::Heuristic);
        let mut rng = Rng::new(82);
        // Small ranges on a large-enough array land in the RTX regime,
        // which the router upgrades to the sharded engine when built.
        let qs = gen_queries(1 << 15, 32, RangeDist::Small, &mut rng);
        let resp = c.query(qs).unwrap();
        assert_eq!(resp.engine, "SHARDED");
        let m = c.metrics.lock().unwrap();
        assert!(m.engine(crate::coordinator::engine::EngineKind::Sharded).is_some());
        // The serving loop refreshes the decayed-traffic view.
        let obs = m.observed.expect("observed traffic recorded");
        assert_eq!(obs.ops, 32);
        assert!(m.shard_block > 0);
    }

    #[test]
    fn mixed_request_fences_updates_within_the_stream() {
        // All-equal array: the leftmost-tie answer moves exactly when an
        // update lands, so visibility mistakes are unmissable.
        let xs = vec![0.5f32; 256];
        let c = Coordinator::start(&xs, None, CoordinatorCfg::default());
        let ops = vec![
            Op::Query((0, 255)),
            Op::Update { i: 7, v: 0.1 },
            Op::Query((0, 255)),
            Op::Update { i: 3, v: 0.05 },
            Op::Query((0, 255)),
        ];
        let resp = c.submit_mixed(ops).unwrap();
        assert_eq!(resp.answers, vec![0, 7, 3], "each chunk sees exactly the prior updates");
        assert_eq!(resp.updates_applied, 2);
        let m = c.metrics.lock().unwrap();
        assert_eq!(m.update_batches, 2);
        assert_eq!(m.updates, 2);
        drop(m);
        c.shutdown();
    }

    #[test]
    fn stale_epoch_pins_later_plain_queries_to_sharded() {
        // With the lifecycle off, no background rebuild can refresh the
        // statics: after the first update every query — even a plain
        // read-only one — must route to the always-current shards.
        let mut xs = Rng::new(80).uniform_f32_vec(512);
        let c = Coordinator::start(
            &xs,
            None,
            CoordinatorCfg {
                policy: Policy::Heuristic,
                lifecycle: LifecycleCfg { rebuild: RebuildMode::Off, ..Default::default() },
                ..Default::default()
            },
        );
        // Small array: read-only requests route off the shards.
        let before = c.query(vec![(0, 511)]).unwrap();
        assert_ne!(before.engine, "SHARDED");
        // A mutating request bumps the seq; every later query sees the
        // new value and the shards.
        let upd = c
            .submit_mixed(vec![Op::Update { i: 300, v: -1.0 }, Op::Query((0, 511))])
            .unwrap();
        assert_eq!(upd.answers, vec![300]);
        assert_eq!(upd.engine, "SHARDED");
        xs[300] = -1.0;
        let after = c.query(vec![(0, 511), (0, 299)]).unwrap();
        assert_eq!(after.engine, "SHARDED");
        assert_eq!(after.answers, oracle_batch(&xs, &[(0, 511), (0, 299)]));
        assert_eq!(after.updates_applied, 0);
        assert_eq!(c.lifecycle.rebuilds(), 0, "--rebuild off never rebuilds");
        assert_eq!(c.lifecycle.epoch_version(), 0);
        c.shutdown();
    }

    #[test]
    fn rejects_invalid_update_ops() {
        let (c, _) = coordinator(128, Policy::Heuristic);
        assert!(c.submit_mixed(vec![Op::Update { i: 128, v: 0.0 }]).is_err());
        assert_eq!(c.metrics.lock().unwrap().rejected, 1);
        c.shutdown();
    }

    #[test]
    fn shutdown_drains() {
        let (c, _) = coordinator(256, Policy::Heuristic);
        let resp = c.query(vec![(0, 255)]).unwrap();
        assert_eq!(resp.answers.len(), 1);
        c.shutdown(); // must not hang (serving thread + builder thread)
    }
}

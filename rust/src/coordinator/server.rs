//! The serving loop: bounded request queue → dynamic batcher → router →
//! engine → reply. One array ("model") per coordinator, engines built
//! once at startup (the paper's build-once/query-many contract).

use super::batcher::{next_batch, BatcherCfg, Request, Response};
use super::engine::{EngineCfg, EngineKind, EngineSet};
use super::metrics::Metrics;
use super::router::{Policy, Router};
use crate::rmq::{validate_queries, Query};
use crate::runtime::Runtime;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorCfg {
    pub policy: Policy,
    pub batcher: BatcherCfg,
    /// Worker threads used by the engines for one fused batch.
    pub engine_workers: usize,
    /// Engine build knobs (e.g. the sharded engine's block size).
    pub engines: EngineCfg,
}

impl Default for CoordinatorCfg {
    fn default() -> Self {
        CoordinatorCfg {
            policy: Policy::ModeledCost,
            batcher: BatcherCfg::default(),
            engine_workers: crate::util::pool::default_workers(),
            engines: EngineCfg::default(),
        }
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: Option<SyncSender<Request>>,
    worker: Option<JoinHandle<()>>,
    pub metrics: Arc<Mutex<Metrics>>,
    next_id: AtomicU64,
    n: usize,
}

impl Coordinator {
    /// Build engines for `xs` and start the serving thread.
    pub fn start(xs: &[f32], runtime: Option<Arc<Runtime>>, cfg: CoordinatorCfg) -> Coordinator {
        let engines = Arc::new(EngineSet::build_with(xs, runtime, cfg.engines));
        let router = Router::new(cfg.policy);
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (tx, rx) = sync_channel::<Request>(cfg.batcher.queue_cap);
        let m = metrics.clone();
        let n = xs.len();
        let batcher_cfg = cfg.batcher;
        let workers = cfg.engine_workers;
        let worker = std::thread::spawn(move || {
            let available = engines.kinds();
            while let Some(fused) = next_batch(&rx, &batcher_cfg) {
                let kind = router.route(n, &fused.queries, &available);
                let engine = engines.get(kind).expect("routed engine exists");
                let t0 = std::time::Instant::now();
                let answers = match engine.solve(&fused.queries, workers) {
                    Ok(a) => a,
                    Err(e) => {
                        eprintln!("engine {} failed: {e}", kind.name());
                        // Fall back to the always-available exhaustive.
                        engines
                            .get(EngineKind::Exhaustive)
                            .expect("exhaustive always built")
                            .solve(&fused.queries, workers)
                            .expect("exhaustive cannot fail")
                    }
                };
                let latency = t0.elapsed().as_nanos() as u64;
                {
                    let mut mm = m.lock().unwrap();
                    mm.record_batch(kind, fused.queries.len() as u64, latency);
                }
                let per_request = fused.split_answers(&answers);
                for (req, ans) in fused.requests.iter().zip(per_request) {
                    // A dropped client is not an error.
                    let _ = req.reply.try_send(Response {
                        id: req.id,
                        answers: ans,
                        engine: kind.name(),
                        batch_latency_ns: latency,
                    });
                }
            }
        });
        Coordinator { tx: Some(tx), worker: Some(worker), metrics, next_id: AtomicU64::new(0), n }
    }

    /// Validated blocking query: submit and wait for the answer.
    pub fn query(&self, queries: Vec<Query>) -> Result<Response> {
        validate_queries(self.n, &queries).map_err(|e| {
            self.metrics.lock().unwrap().record_rejected();
            anyhow!(e)
        })?;
        self.metrics.lock().unwrap().record_request();
        let (reply_tx, reply_rx) = sync_channel(1);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request { id, queries, reply: reply_tx };
        self.tx
            .as_ref()
            .expect("not shut down")
            .send(req)
            .map_err(|_| anyhow!("coordinator stopped"))?;
        reply_rx.recv().map_err(|_| anyhow!("coordinator dropped reply"))
    }

    /// Non-blocking submit; Err(queries) when the queue is full
    /// (backpressure surfaced to the caller).
    pub fn try_submit(
        &self,
        queries: Vec<Query>,
        reply: SyncSender<Response>,
    ) -> std::result::Result<u64, Vec<Query>> {
        if validate_queries(self.n, &queries).is_err() {
            self.metrics.lock().unwrap().record_rejected();
            return Err(queries);
        }
        self.metrics.lock().unwrap().record_request();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        match self.tx.as_ref().expect("not shut down").try_send(Request { id, queries, reply }) {
            Ok(()) => Ok(id),
            Err(TrySendError::Full(r)) | Err(TrySendError::Disconnected(r)) => Err(r.queries),
        }
    }

    /// Graceful shutdown: drain the queue, then join the worker.
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rmq::sparse_table::oracle_batch;
    use crate::util::rng::Rng;
    use crate::workload::{gen_queries, RangeDist};

    fn coordinator(n: usize, policy: Policy) -> (Coordinator, Vec<f32>) {
        let xs = Rng::new(80).uniform_f32_vec(n);
        let c = Coordinator::start(
            &xs,
            None,
            CoordinatorCfg { policy, ..Default::default() },
        );
        (c, xs)
    }

    #[test]
    fn serves_correct_answers() {
        let (c, xs) = coordinator(4096, Policy::ModeledCost);
        let mut rng = Rng::new(81);
        for dist in RangeDist::all() {
            let qs = gen_queries(4096, 64, dist, &mut rng);
            let resp = c.query(qs.clone()).unwrap();
            assert_eq!(resp.answers, oracle_batch(&xs, &qs), "{dist:?}");
        }
        c.shutdown();
    }

    #[test]
    fn rejects_invalid_queries() {
        let (c, _) = coordinator(128, Policy::Heuristic);
        assert!(c.query(vec![(5, 4)]).is_err());
        assert!(c.query(vec![(0, 128)]).is_err());
        assert_eq!(c.metrics.lock().unwrap().rejected, 2);
        c.shutdown();
    }

    #[test]
    fn concurrent_clients_all_served() {
        let (c, xs) = coordinator(2048, Policy::ModeledCost);
        let c = Arc::new(c);
        let xs = Arc::new(xs);
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            let xs = xs.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                for _ in 0..10 {
                    let qs = gen_queries(2048, 16, RangeDist::Small, &mut rng);
                    let resp = c.query(qs.clone()).unwrap();
                    assert_eq!(resp.answers, oracle_batch(&xs, &qs));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = c.metrics.lock().unwrap();
        assert_eq!(m.requests, 40);
        assert_eq!(m.total_queries(), 40 * 16);
    }

    #[test]
    fn metrics_track_engines() {
        let (c, _) = coordinator(1 << 15, Policy::Heuristic);
        let mut rng = Rng::new(82);
        // Small ranges on a large-enough array land in the RTX regime,
        // which the router upgrades to the sharded engine when built.
        let qs = gen_queries(1 << 15, 32, RangeDist::Small, &mut rng);
        let resp = c.query(qs).unwrap();
        assert_eq!(resp.engine, "SHARDED");
        let m = c.metrics.lock().unwrap();
        assert!(m.engine(crate::coordinator::engine::EngineKind::Sharded).is_some());
    }

    #[test]
    fn shutdown_drains() {
        let (c, _) = coordinator(256, Policy::Heuristic);
        let resp = c.query(vec![(0, 255)]).unwrap();
        assert_eq!(resp.answers.len(), 1);
        c.shutdown(); // must not hang
    }
}

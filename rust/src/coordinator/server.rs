//! The serving loop: bounded request queue → dynamic batcher → router →
//! engine epoch → reply. One array ("model") per coordinator. Engines
//! live in **epochs** (`coordinator::engine`): query segments pin the
//! current epoch for their duration and route against its freshness,
//! update segments mutate the shared sharded engine and bump the
//! published applied-update sequence, and a background builder rebuilds
//! stale static engines / re-shards once the observed traffic says it
//! is worthwhile — so the Fig. 12 crossover routing comes back after a
//! burst of updates instead of being lost forever.
//!
//! Mixed streams execute on a **two-lane pipeline**: when a query
//! segment is directly followed by an update segment (the batcher's
//! `overlap_with` annotation), the update's refit work is *staged* on a
//! dedicated lane — per-block replacement solvers built against a
//! snapshot — while the serving lane still executes the query segment.
//! At the fence the staged work commits under the write lock (seq- and
//! shape-checked; conflicts fall back to the direct apply), so the
//! refit latency hides behind query execution instead of stalling the
//! stream. Results are bit-identical to the serial executor; the
//! `pipeline` metrics line reports how much latency was hidden.
//!
//! Every thread here is panic-isolated (see the "Failure model" note in
//! `rmq/mod.rs`): the staging lane catches its own panics and hands the
//! fence a fallback signal (ticketed, so an abandoned preparation can
//! never commit at a later fence), the builder catches and respawns its
//! job loop with backoff, and the serving loop itself backstops both
//! the batcher pull and segment execution — a lost batch rejects its
//! requests with [`ServeError::Failed`] instead of killing the thread.
//! Overload is shed at admission ([`ServeError::Overloaded`] past the
//! queue-depth watermark) and expiry at batch build time
//! ([`ServeError::DeadlineExceeded`]).

use super::batcher::{next_batch, BatchPull, BatcherCfg, Reply, Request, Response, Segment};
use super::engine::{
    spawn_builder, BuildJob, CommitOutcome, EngineCfg, EngineKind, EpochState, LifecycleCfg,
    PreparedUpdate,
};
use super::metrics::Metrics;
use super::router::{Policy, Router};
use crate::coordinator::batcher::ServeError;
use crate::rmq::Query;
use crate::runtime::Runtime;
use crate::util::faults;
use crate::util::sync::Mutex;
use crate::workload::{validate_ops, Op, UpdateOp};
use anyhow::{anyhow, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorCfg {
    pub policy: Policy,
    pub batcher: BatcherCfg,
    /// Worker threads used by the engines for one fused batch.
    pub engine_workers: usize,
    /// Engine build knobs (e.g. the sharded engine's block size).
    pub engines: EngineCfg,
    /// Epoch-lifecycle knobs (`serve --rebuild`, `--reshard-drift`).
    pub lifecycle: LifecycleCfg,
    /// Overlap update-segment preparation with the preceding query
    /// segment (`serve --no-pipeline` turns it off; answers are
    /// bit-identical either way).
    pub pipeline: bool,
}

impl Default for CoordinatorCfg {
    fn default() -> Self {
        CoordinatorCfg {
            policy: Policy::ModeledCost,
            batcher: BatcherCfg::default(),
            engine_workers: crate::util::pool::default_workers(),
            engines: EngineCfg::default(),
            lifecycle: LifecycleCfg::default(),
            pipeline: true,
        }
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: Option<SyncSender<Request>>,
    worker: Option<JoinHandle<()>>,
    stager: Option<JoinHandle<()>>,
    job_tx: Option<SyncSender<BuildJob>>,
    builder: Option<JoinHandle<()>>,
    pub metrics: Arc<Mutex<Metrics>>,
    /// Observable lifecycle state (epoch version, rebuild/re-shard
    /// counters, live block size).
    pub lifecycle: Arc<EpochState>,
    /// Live queue depth — requests submitted but not yet pulled by the
    /// batcher. Admission control sheds at `shed_watermark`.
    queued: Arc<AtomicUsize>,
    shed_watermark: usize,
    next_id: AtomicU64,
    n: usize,
}

impl Coordinator {
    /// Build the initial epoch for `xs`, start the background builder
    /// and the serving thread.
    pub fn start(xs: &[f32], runtime: Option<Arc<Runtime>>, cfg: CoordinatorCfg) -> Coordinator {
        let state = EpochState::bootstrap(xs, runtime, cfg.engines, cfg.lifecycle);
        let router = Router::new(cfg.policy);
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let queued = Arc::new(AtomicUsize::new(0));
        let (job_tx, builder) = spawn_builder(state.clone(), metrics.clone());
        let (tx, rx) = sync_channel::<Request>(cfg.batcher.queue_cap);
        // Staging lane: a dedicated worker that prepares an update
        // segment's refit work against a snapshot while the serving
        // thread still executes the *preceding* query segment. Rendezvous
        // channels of depth 1 — at most one preparation is ever in
        // flight, and the serving thread joins it at the fence. Both
        // directions carry a ticket: the fence accepts only the result
        // of the preparation it dispatched, so a preparation abandoned
        // by a panicked batch can never commit later. A `None` result
        // means the preparation itself died — the fence falls back to
        // the direct apply path.
        let (stage_tx, stage_rx) = sync_channel::<(u64, Vec<UpdateOp>)>(1);
        let (done_tx, done_rx) = sync_channel::<(u64, Option<PreparedUpdate>)>(1);
        let stage_state = state.clone();
        let stage_workers = cfg.engine_workers;
        let stager = std::thread::spawn(move || {
            while let Ok((ticket, ups)) = stage_rx.recv() {
                let prep = catch_unwind(AssertUnwindSafe(|| {
                    // Injected staging-lane failure (the stage.build
                    // site inside the spec build is caught here too).
                    faults::fire("stage.prepare");
                    stage_state.prepare_update(&ups, stage_workers)
                }))
                .map_err(|_| faults::note_caught())
                .ok();
                if done_tx.send((ticket, prep)).is_err() {
                    break;
                }
            }
        });
        let m = metrics.clone();
        let st = state.clone();
        let jt = job_tx.clone();
        let n = xs.len();
        let batcher_cfg = cfg.batcher;
        let workers = cfg.engine_workers;
        let pipeline = cfg.pipeline;
        let queued_w = queued.clone();
        let worker = std::thread::spawn(move || {
            // Monotone ticket for staged preparations (see above).
            let mut stage_ticket: u64 = 0;
            loop {
                // The pull is panic-isolated: an injected
                // batcher.handoff panic drops the pulled group whole —
                // its submitters see a closed reply channel, no op of
                // theirs has executed — and serving continues.
                let pull =
                    match catch_unwind(AssertUnwindSafe(|| next_batch(&rx, &batcher_cfg, &queued_w)))
                    {
                        Ok(p) => p,
                        Err(_) => {
                            faults::note_caught();
                            m.lock().record_degraded();
                            continue;
                        }
                    };
                let (fused, last) = match pull {
                    BatchPull::Batch(f) => (f, false),
                    BatchPull::Final(f) => (f, true),
                    BatchPull::Shutdown => break,
                };
                // Deadline shedding, queue-time stage: requests that
                // expired while waiting are rejected whole.
                for req in &fused.expired {
                    m.lock().record_expired();
                    let _ = req.reply.try_send(Err(ServeError::DeadlineExceeded));
                }
                let t0 = std::time::Instant::now();
                // Segment execution is backstopped too. Injected faults
                // are all absorbed *below* this point (pool retries,
                // stager fallback, commit conflicts), so under
                // injection this catch never fires — it exists so a
                // genuine executor bug degrades to Failed replies for
                // one batch instead of wedging the serving loop.
                let exec = catch_unwind(AssertUnwindSafe(|| {
                    let mut answers: Vec<u32> = Vec::with_capacity(fused.total_queries());
                    let mut query_engine: Option<&'static str> = None;
                    let mut update_engine: Option<&'static str> = None;
                    let mut updates_ok = true;
                    // Published-epoch version (not the raw counter, which
                    // briefly runs ahead mid-publish): keeps response epochs
                    // monotone across update-only batches.
                    let mut epoch_seen = st.current().version;
                    // In-flight staged preparation: (update segment index
                    // it commits at, its ticket, dispatch instant).
                    let mut staged: Option<(usize, u64, std::time::Instant)> = None;
                    // Segments execute (commit, for staged updates) strictly
                    // in stream order on this one thread — that *is* the
                    // fence: an update segment is visible to every later
                    // query segment and to none earlier. Staging only ever
                    // *reads*, so overlapping it with the preceding query
                    // segment cannot leak values across the fence.
                    for (si, seg) in fused.segments.iter().enumerate() {
                        match seg {
                            Segment::Queries(qs) => {
                                // Two-lane dispatch: if the next segment is an
                                // update fence, hand its preparation to the
                                // staging lane before running this query
                                // segment, per the batcher's annotation.
                                if pipeline {
                                    if let Some(Segment::Updates(ups)) = fused.segments.get(si + 1)
                                    {
                                        debug_assert_eq!(fused.overlap_with[si + 1], Some(si));
                                        stage_ticket += 1;
                                        if stage_tx.send((stage_ticket, ups.clone())).is_ok() {
                                            staged = Some((
                                                si + 1,
                                                stage_ticket,
                                                std::time::Instant::now(),
                                            ));
                                        }
                                    }
                                }
                                let (got, epoch_version, kind) =
                                    execute_query_segment(&st, &router, &m, qs, workers, n);
                                epoch_seen = epoch_version;
                                // Last segment wins: once an update fences the
                                // batch, later segments are the current truth.
                                query_engine = Some(kind.name());
                                answers.extend_from_slice(&got);
                            }
                            Segment::Updates(ups) => {
                                let ts = std::time::Instant::now();
                                let mut applied: Option<EngineKind> = None;
                                if let Some((at, ticket, dispatched)) = staged.take() {
                                    debug_assert_eq!(
                                        at, si,
                                        "staged work commits at its own fence"
                                    );
                                    // Join the staging lane and commit at the
                                    // fence. `hidden` is the slice of the
                                    // preparation that ran while this thread
                                    // was busy with the previous segment — the
                                    // latency the pipeline removed. The gap is
                                    // measured *before* the blocking recv: a
                                    // preparation that outlives the query
                                    // segment stalls the fence, and that stall
                                    // must not count as hidden.
                                    let gap = dispatched.elapsed().as_nanos() as u64;
                                    let mut prep_opt: Option<PreparedUpdate> = None;
                                    while let Ok((t, p)) = done_rx.recv() {
                                        if t == ticket {
                                            prep_opt = p;
                                            break;
                                        }
                                        // Stale result of a ticket abandoned
                                        // by a failed batch — discard.
                                    }
                                    if let Some(prep) = prep_opt {
                                        let hidden = prep.prep_ns.min(gap);
                                        let (kind, outcome) = st.commit_prepared(prep, workers);
                                        m.lock().record_staged_commit(
                                            outcome == CommitOutcome::Installed,
                                            hidden,
                                        );
                                        applied = Some(kind);
                                    } else {
                                        // The preparation died on the staging
                                        // lane: degrade to the direct path
                                        // below — same values, same fencing,
                                        // only the overlap is lost.
                                        m.lock().record_degraded();
                                    }
                                }
                                if applied.is_none() {
                                    match st.update_ops(ups, workers) {
                                        Ok(kind) => applied = Some(kind),
                                        // Admission validated the indices; this
                                        // only fires when no mutable engine is
                                        // built, which bootstrap precludes.
                                        Err(e) => {
                                            eprintln!("update batch dropped: {e}");
                                            updates_ok = false;
                                        }
                                    }
                                }
                                if let Some(kind) = applied {
                                    update_engine.get_or_insert(kind.name());
                                    m.lock().record_update_batch(
                                        ups.len() as u64,
                                        ts.elapsed().as_nanos() as u64,
                                    );
                                }
                                // Observer feed stays at the *commit* point,
                                // exactly as in the serial executor, so the
                                // lifecycle's staleness/seq accounting is
                                // unchanged by pipelining.
                                st.observer.lock().observe_updates(ups.len());
                            }
                        }
                    }
                    (answers, query_engine, update_engine, updates_ok, epoch_seen)
                }));
                let latency = t0.elapsed().as_nanos() as u64;
                match exec {
                    Ok((answers, query_engine, update_engine, updates_ok, epoch_seen)) => {
                        // Refresh the metrics' decayed-traffic view, then let
                        // the lifecycle plan background work off it (rebuild
                        // once the update rate is quiet, re-shard on tuner
                        // drift).
                        {
                            let obs = st.observer.lock().snapshot();
                            m.lock().record_observed(
                                obs,
                                st.epoch_version(),
                                st.shard_block_live(),
                            );
                            m.lock().record_faults(faults::stats());
                            m.lock().record_range_stats(st.range_stats());
                        }
                        if let Some(job) = st.plan() {
                            if jt.try_send(job).is_err() {
                                st.clear_pending();
                            }
                        }
                        let per_request = fused.split_answers(&answers);
                        let engine_name = query_engine.or(update_engine).unwrap_or("NONE");
                        for ((req, ans), &ups) in
                            fused.requests.iter().zip(per_request).zip(&fused.update_splits)
                        {
                            // A dropped client is not an error. A dropped
                            // update segment must not be reported as applied.
                            let _ = req.reply.try_send(Ok(Response {
                                id: req.id,
                                answers: ans,
                                updates_applied: if updates_ok { ups } else { 0 },
                                engine: engine_name,
                                epoch: epoch_seen,
                                batch_latency_ns: latency,
                            }));
                        }
                    }
                    Err(_) => {
                        // One batch lost to a caught executor panic: every
                        // request in it gets the typed rejection and serving
                        // moves on — the serving loop never wedges.
                        faults::note_caught();
                        {
                            let mut g = m.lock();
                            g.record_degraded();
                            g.record_faults(faults::stats());
                        }
                        for req in &fused.requests {
                            let _ = req.reply.try_send(Err(ServeError::Failed));
                        }
                    }
                }
                if last {
                    break;
                }
            }
        });
        Coordinator {
            tx: Some(tx),
            worker: Some(worker),
            stager: Some(stager),
            job_tx: Some(job_tx),
            builder: Some(builder),
            metrics,
            lifecycle: state,
            queued,
            shed_watermark: cfg.batcher.shed_watermark,
            next_id: AtomicU64::new(0),
            n,
        }
    }

    /// Validated blocking query: submit and wait for the answer.
    pub fn query(&self, queries: Vec<Query>) -> Result<Response> {
        self.submit_mixed(queries.into_iter().map(Op::Query).collect())
    }

    /// Validated blocking mixed request: queries, point updates and
    /// range `add`/`assign` tags execute in op order with fencing — a
    /// mutation is visible to every later query in the stream (and in
    /// any later request) and to no earlier one. Returns one answer per
    /// query op, in op order.
    pub fn submit_mixed(&self, ops: Vec<Op>) -> Result<Response> {
        self.submit_mixed_deadline(ops, None)
    }

    /// [`submit_mixed`](Self::submit_mixed) with overload semantics: the
    /// request is shed with [`ServeError::Overloaded`] when the queue
    /// depth is at the watermark, and dropped whole (no op executes)
    /// with [`ServeError::DeadlineExceeded`] if `deadline` elapses
    /// before it reaches an engine. Both come back as typed errors
    /// (`downcast_ref::<ServeError>()`).
    pub fn submit_mixed_deadline(
        &self,
        ops: Vec<Op>,
        deadline: Option<Duration>,
    ) -> Result<Response> {
        validate_ops(self.n, &ops).map_err(|e| {
            self.metrics.lock().record_rejected();
            anyhow!(e)
        })?;
        // Admission-control shed: reject fast instead of blocking on a
        // full queue.
        if self.queued.load(Ordering::Acquire) >= self.shed_watermark {
            self.metrics.lock().record_shed();
            return Err(anyhow::Error::new(ServeError::Overloaded));
        }
        let deadline = match deadline {
            Some(d) if d.is_zero() => {
                // Already expired at admission; don't bother the queue.
                self.metrics.lock().record_expired();
                return Err(anyhow::Error::new(ServeError::DeadlineExceeded));
            }
            d => d.map(|d| std::time::Instant::now() + d),
        };
        self.metrics.lock().record_request();
        let (reply_tx, reply_rx) = sync_channel(1);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request { id, ops, deadline, reply: reply_tx };
        // Increment *before* send: the batcher decrements after its
        // recv, and the gauge must never underflow.
        self.queued.fetch_add(1, Ordering::AcqRel);
        if self.tx.as_ref().expect("not shut down").send(req).is_err() {
            self.queued.fetch_sub(1, Ordering::AcqRel);
            return Err(anyhow!("coordinator stopped"));
        }
        match reply_rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(e)) => Err(anyhow::Error::new(e)),
            Err(_) => Err(anyhow!("coordinator dropped reply")),
        }
    }

    /// Non-blocking submit; Err(queries) when the queue is full
    /// (backpressure surfaced to the caller).
    pub fn try_submit(
        &self,
        queries: Vec<Query>,
        reply: SyncSender<Reply>,
    ) -> std::result::Result<u64, Vec<Query>> {
        let unwrap_queries = |ops: Vec<Op>| {
            ops.into_iter()
                .filter_map(|op| match op {
                    Op::Query(q) => Some(q),
                    _ => None,
                })
                .collect()
        };
        if crate::rmq::validate_queries(self.n, &queries).is_err() {
            self.metrics.lock().record_rejected();
            return Err(queries);
        }
        self.metrics.lock().record_request();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request::queries(id, queries, reply);
        self.queued.fetch_add(1, Ordering::AcqRel);
        match self.tx.as_ref().expect("not shut down").try_send(req) {
            Ok(()) => Ok(id),
            Err(TrySendError::Full(r)) | Err(TrySendError::Disconnected(r)) => {
                self.queued.fetch_sub(1, Ordering::AcqRel);
                Err(unwrap_queries(r.ops))
            }
        }
    }

    /// Fold the fault registry's live counters into the metrics. The
    /// serving loop does this after every batch; call it before reading
    /// metrics that must include recoveries which happened after the
    /// last batch (e.g. a builder respawn during a quiet tail, or at
    /// shutdown).
    pub fn sync_faults(&self) {
        self.metrics.lock().record_faults(faults::stats());
    }

    /// Graceful shutdown: drain the request queue, join the serving
    /// thread, then drain the lifecycle queue and join the builder.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        // The serving thread owned the staging lane's channels; its
        // exit hangs them up, so the stager drains and stops.
        if let Some(s) = self.stager.take() {
            let _ = s.join();
        }
        drop(self.job_tx.take());
        if let Some(b) = self.builder.take() {
            let _ = b.join();
        }
        self.sync_faults();
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Execute one query segment against an array's current epoch: pin the
/// epoch, route against its freshness, solve (falling back to the
/// exhaustive engine if the routed engine fails), record batch metrics,
/// and feed the workload observer. Returns the answers, the pinned
/// epoch's version, and the routed engine kind.
///
/// Shared by the single-array serving loop here and the multi-tenant
/// executor (`coordinator::tenants`), so both front-ends answer through
/// the identical routing/fallback/observation path.
pub(crate) fn execute_query_segment(
    st: &EpochState,
    router: &Router,
    m: &Mutex<Metrics>,
    qs: &[Query],
    workers: usize,
    n: usize,
) -> (Vec<u32>, u64, EngineKind) {
    // Pin this segment to the epoch current at its start: the Arc keeps
    // a mid-segment background swap from freeing engines under us; the
    // next segment re-loads and routes freely against whatever epoch is
    // current by then.
    let epoch = st.current();
    let fresh = st.is_fresh(&epoch);
    let kind = router.route_epoch(n, qs, epoch.kinds(), fresh);
    let engine = epoch.get(kind).expect("routed engine exists");
    let ts = std::time::Instant::now();
    let got = match engine.solve(qs, workers) {
        Ok(a) => a,
        Err(e) => {
            // Only the XLA engine can fail, and a stale epoch never
            // routes to it — so the exhaustive fallback still sees the
            // array its epoch was built from.
            eprintln!("engine {} failed: {e}", kind.name());
            epoch
                .get(EngineKind::Exhaustive)
                .expect("exhaustive always built")
                .solve(qs, workers)
                .expect("exhaustive cannot fail")
        }
    };
    let seg_ns = ts.elapsed().as_nanos() as u64;
    m.lock().record_batch(kind, qs.len() as u64, seg_ns);
    st.observer.lock().observe_queries(qs);
    (got, epoch.version, kind)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::RebuildMode;
    use crate::rmq::sparse_table::oracle_batch;
    use crate::util::rng::Rng;
    use crate::workload::{gen_queries, RangeDist};

    fn coordinator(n: usize, policy: Policy) -> (Coordinator, Vec<f32>) {
        let xs = Rng::new(80).uniform_f32_vec(n);
        let c = Coordinator::start(
            &xs,
            None,
            CoordinatorCfg { policy, ..Default::default() },
        );
        (c, xs)
    }

    #[test]
    fn serves_correct_answers() {
        let (c, xs) = coordinator(4096, Policy::ModeledCost);
        let mut rng = Rng::new(81);
        for dist in RangeDist::all() {
            let qs = gen_queries(4096, 64, dist, &mut rng);
            let resp = c.query(qs.clone()).unwrap();
            assert_eq!(resp.answers, oracle_batch(&xs, &qs), "{dist:?}");
            assert_eq!(resp.epoch, 0, "no lifecycle events on a read-only run");
        }
        c.shutdown();
    }

    #[test]
    fn rejects_invalid_queries() {
        let (c, _) = coordinator(128, Policy::Heuristic);
        assert!(c.query(vec![(5, 4)]).is_err());
        assert!(c.query(vec![(0, 128)]).is_err());
        assert_eq!(c.metrics.lock().rejected, 2);
        c.shutdown();
    }

    #[test]
    fn concurrent_clients_all_served() {
        let (c, xs) = coordinator(2048, Policy::ModeledCost);
        let c = Arc::new(c);
        let xs = Arc::new(xs);
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            let xs = xs.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                for _ in 0..10 {
                    let qs = gen_queries(2048, 16, RangeDist::Small, &mut rng);
                    let resp = c.query(qs.clone()).unwrap();
                    assert_eq!(resp.answers, oracle_batch(&xs, &qs));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = c.metrics.lock();
        assert_eq!(m.requests, 40);
        assert_eq!(m.total_queries(), 40 * 16);
    }

    #[test]
    fn metrics_track_engines() {
        let (c, _) = coordinator(1 << 15, Policy::Heuristic);
        let mut rng = Rng::new(82);
        // Small ranges on a large-enough array land in the RTX regime,
        // which the router upgrades to the sharded engine when built.
        let qs = gen_queries(1 << 15, 32, RangeDist::Small, &mut rng);
        let resp = c.query(qs).unwrap();
        assert_eq!(resp.engine, "SHARDED");
        let m = c.metrics.lock();
        assert!(m.engine(crate::coordinator::engine::EngineKind::Sharded).is_some());
        // The serving loop refreshes the decayed-traffic view.
        let obs = m.observed.expect("observed traffic recorded");
        assert_eq!(obs.ops, 32);
        assert!(m.shard_block > 0);
    }

    #[test]
    fn mixed_request_fences_updates_within_the_stream() {
        // All-equal array: the leftmost-tie answer moves exactly when an
        // update lands, so visibility mistakes are unmissable.
        let xs = vec![0.5f32; 256];
        let c = Coordinator::start(&xs, None, CoordinatorCfg::default());
        let ops = vec![
            Op::Query((0, 255)),
            Op::Update { i: 7, v: 0.1 },
            Op::Query((0, 255)),
            Op::Update { i: 3, v: 0.05 },
            Op::Query((0, 255)),
        ];
        let resp = c.submit_mixed(ops).unwrap();
        assert_eq!(resp.answers, vec![0, 7, 3], "each chunk sees exactly the prior updates");
        assert_eq!(resp.updates_applied, 2);
        let m = c.metrics.lock();
        assert_eq!(m.update_batches, 2);
        assert_eq!(m.updates, 2);
        drop(m);
        c.shutdown();
    }

    #[test]
    fn stale_epoch_pins_later_plain_queries_to_sharded() {
        // With the lifecycle off, no background rebuild can refresh the
        // statics: after the first update every query — even a plain
        // read-only one — must route to the always-current shards.
        let mut xs = Rng::new(80).uniform_f32_vec(512);
        let c = Coordinator::start(
            &xs,
            None,
            CoordinatorCfg {
                policy: Policy::Heuristic,
                lifecycle: LifecycleCfg { rebuild: RebuildMode::Off, ..Default::default() },
                ..Default::default()
            },
        );
        // Small array: read-only requests route off the shards.
        let before = c.query(vec![(0, 511)]).unwrap();
        assert_ne!(before.engine, "SHARDED");
        // A mutating request bumps the seq; every later query sees the
        // new value and the shards.
        let upd = c
            .submit_mixed(vec![Op::Update { i: 300, v: -1.0 }, Op::Query((0, 511))])
            .unwrap();
        assert_eq!(upd.answers, vec![300]);
        assert_eq!(upd.engine, "SHARDED");
        xs[300] = -1.0;
        let after = c.query(vec![(0, 511), (0, 299)]).unwrap();
        assert_eq!(after.engine, "SHARDED");
        assert_eq!(after.answers, oracle_batch(&xs, &[(0, 511), (0, 299)]));
        assert_eq!(after.updates_applied, 0);
        assert_eq!(c.lifecycle.rebuilds(), 0, "--rebuild off never rebuilds");
        assert_eq!(c.lifecycle.epoch_version(), 0);
        c.shutdown();
    }

    #[test]
    fn pipelined_executor_stages_update_segments_and_stays_exact() {
        // Fence-heavy stream: q|u|q|u|q segments per request, so every
        // update segment has a preceding query segment to overlap. The
        // answers must equal the sequential oracle and the metrics must
        // show staged commits with hidden preparation time.
        let n = 2048usize;
        let mut xs = Rng::new(90).uniform_f32_vec(n);
        let c = Coordinator::start(&xs, None, CoordinatorCfg::default());
        let mut rng = Rng::new(91);
        for _ in 0..8 {
            let mut ops = Vec::new();
            let mut want = Vec::new();
            for _ in 0..3 {
                let l = rng.range(0, n - 1);
                let r = rng.range(l, n - 1);
                ops.push(Op::Query((l as u32, r as u32)));
                want.push(crate::rmq::naive_rmq(&xs, l, r) as u32);
                let i = rng.range(0, n - 1);
                let v = rng.f32();
                ops.push(Op::Update { i: i as u32, v });
                xs[i] = v;
            }
            let resp = c.submit_mixed(ops).unwrap();
            assert_eq!(resp.answers, want);
            assert_eq!(resp.updates_applied, 3);
        }
        let m = c.metrics.lock();
        assert_eq!(m.update_batches, 24, "3 fences per request x 8 requests");
        assert_eq!(m.staged_batches, 24, "every fence had a preceding query segment");
        assert_eq!(
            m.staged_installed, 24,
            "single-writer stream: no conflicts, every prepared batch installs"
        );
        assert_eq!(m.staged_fallbacks, 0);
        assert!(m.overlap_ns_hidden_total > 0, "preparation overlapped query execution");
        assert!(m.to_string().contains("pipeline"), "{m}");
        drop(m);
        c.shutdown();
    }

    #[test]
    fn range_ops_fence_and_stage_like_point_updates() {
        // q|u|q|u|q stream where the mutation segments are range tags:
        // the staged lane must carry them (pointer-sized specs), the
        // fence must commit them in op order, and the metrics must show
        // both the staged commits and the lazy-tag counters.
        let n = 1024usize;
        let mut xs = Rng::new(92).uniform_f32_vec(n);
        let c = Coordinator::start(&xs, None, CoordinatorCfg::default());
        let ops = vec![
            Op::Query((0, (n - 1) as u32)),
            Op::RangeAdd { l: 0, r: (n - 1) as u32, v: 0.25 },
            Op::Query((0, (n - 1) as u32)),
            Op::RangeAssign { l: 100, r: 300, v: -1.0 },
            Op::Query((0, (n - 1) as u32)),
        ];
        let mut want = Vec::new();
        want.push(crate::rmq::naive_rmq(&xs, 0, n - 1) as u32);
        for x in xs.iter_mut() {
            *x += 0.25;
        }
        want.push(crate::rmq::naive_rmq(&xs, 0, n - 1) as u32);
        for x in xs[100..=300].iter_mut() {
            *x = -1.0;
        }
        want.push(crate::rmq::naive_rmq(&xs, 0, n - 1) as u32);
        let resp = c.submit_mixed(ops).unwrap();
        assert_eq!(resp.answers, want);
        assert_eq!(resp.updates_applied, 2);
        c.sync_faults();
        let m = c.metrics.lock();
        assert_eq!(m.update_batches, 2);
        assert_eq!(m.staged_batches, 2, "range fences stage like point fences");
        assert_eq!(m.range_updates, 2);
        assert!(m.tag_hits > 0, "covered blocks took the lazy-tag path");
        assert!(m.to_string().contains("ranges"), "{m}");
        drop(m);
        // Read-back through a fresh request: tags landed in served truth.
        let after = c.query(vec![(0, (n - 1) as u32)]).unwrap();
        assert_eq!(after.answers, vec![crate::rmq::naive_rmq(&xs, 0, n - 1) as u32]);
        c.shutdown();
    }

    #[test]
    fn leading_update_segments_take_the_direct_path() {
        // A request that *starts* with updates has nothing to hide the
        // first fence behind — the executor must fall through to the
        // direct apply and still fence correctly.
        let xs = vec![0.5f32; 128];
        let c = Coordinator::start(&xs, None, CoordinatorCfg::default());
        let resp = c
            .submit_mixed(vec![
                Op::Update { i: 100, v: 0.1 },
                Op::Query((0, 127)),
                Op::Update { i: 3, v: 0.05 },
                Op::Query((0, 127)),
            ])
            .unwrap();
        assert_eq!(resp.answers, vec![100, 3]);
        let m = c.metrics.lock();
        assert_eq!(m.update_batches, 2);
        assert_eq!(m.staged_batches, 1, "only the second fence had a query before it");
        drop(m);
        c.shutdown();
    }

    #[test]
    fn pipeline_off_never_stages() {
        let xs = vec![0.5f32; 256];
        let c = Coordinator::start(
            &xs,
            None,
            CoordinatorCfg { pipeline: false, ..Default::default() },
        );
        let resp = c
            .submit_mixed(vec![
                Op::Query((0, 255)),
                Op::Update { i: 9, v: 0.1 },
                Op::Query((0, 255)),
            ])
            .unwrap();
        assert_eq!(resp.answers, vec![0, 9], "serial executor: same fence semantics");
        let m = c.metrics.lock();
        assert_eq!(m.staged_batches, 0);
        assert_eq!(m.overlap_ns_hidden_total, 0);
        assert_eq!(m.update_batches, 1);
        drop(m);
        c.shutdown();
    }

    #[test]
    fn rejects_invalid_update_ops() {
        let (c, _) = coordinator(128, Policy::Heuristic);
        assert!(c.submit_mixed(vec![Op::Update { i: 128, v: 0.0 }]).is_err());
        assert_eq!(c.metrics.lock().rejected, 1);
        c.shutdown();
    }

    #[test]
    fn zero_watermark_sheds_with_typed_overloaded() {
        let xs = Rng::new(83).uniform_f32_vec(128);
        let c = Coordinator::start(
            &xs,
            None,
            CoordinatorCfg {
                batcher: BatcherCfg { shed_watermark: 0, ..Default::default() },
                ..Default::default()
            },
        );
        let err = c.query(vec![(0, 127)]).unwrap_err();
        assert_eq!(err.downcast_ref::<ServeError>(), Some(&ServeError::Overloaded));
        let m = c.metrics.lock();
        assert_eq!(m.shed, 1);
        assert_eq!(m.requests, 0, "a shed request never counts as admitted");
        drop(m);
        c.shutdown();
    }

    #[test]
    fn zero_deadline_is_rejected_before_any_op_executes() {
        let xs = vec![0.5f32; 128];
        let c = Coordinator::start(&xs, None, CoordinatorCfg::default());
        let err = c
            .submit_mixed_deadline(
                vec![Op::Update { i: 3, v: 0.1 }, Op::Query((0, 127))],
                Some(Duration::ZERO),
            )
            .unwrap_err();
        assert_eq!(err.downcast_ref::<ServeError>(), Some(&ServeError::DeadlineExceeded));
        assert_eq!(c.metrics.lock().deadline_expired, 1);
        // The rejected request's update must not have landed: on the
        // all-equal array the leftmost minimum is still index 0.
        let resp = c.query(vec![(0, 127)]).unwrap();
        assert_eq!(resp.answers, vec![0], "rejected update must not execute");
        assert_eq!(c.metrics.lock().update_batches, 0);
        c.shutdown();
    }

    #[test]
    fn generous_deadline_serves_normally() {
        let (c, xs) = coordinator(1024, Policy::ModeledCost);
        let mut rng = Rng::new(84);
        let qs = gen_queries(1024, 32, RangeDist::Medium, &mut rng);
        let resp =
            c.submit_mixed_deadline(
                qs.iter().copied().map(Op::Query).collect(),
                Some(Duration::from_secs(60)),
            )
            .unwrap();
        assert_eq!(resp.answers, oracle_batch(&xs, &qs));
        assert_eq!(c.metrics.lock().deadline_expired, 0);
        c.shutdown();
    }

    #[test]
    fn shutdown_drains() {
        let (c, _) = coordinator(256, Policy::Heuristic);
        let resp = c.query(vec![(0, 255)]).unwrap();
        assert_eq!(resp.answers.len(), 1);
        c.shutdown(); // must not hang (serving thread + builder thread)
    }
}

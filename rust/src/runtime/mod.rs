//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`) produced once by `python/compile/aot.py` and executes
//! them from the Rust hot path. **Python never runs here**: the HLO text
//! is parsed and compiled by the XLA CPU client at startup, and every
//! request is served from the cached executables.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// What a variant computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VariantKind {
    /// (xs, ls, rs) -> (mins, args) by brute force.
    Exhaustive,
    /// (xs, ls, rs) -> (mins, args) via the Algorithm-6 block graph.
    Block,
    /// (xs) -> (block mins, block args) preprocessing.
    BlockMin,
}

/// One AOT-compiled computation, as described by the manifest.
#[derive(Clone, Debug)]
pub struct Variant {
    pub name: String,
    pub kind: VariantKind,
    pub n: usize,
    pub q: usize,
    pub bs: usize,
    pub file: PathBuf,
}

/// Parse `manifest.json`.
pub fn parse_manifest(dir: &Path) -> Result<Vec<Variant>> {
    let text = std::fs::read_to_string(dir.join("manifest.json"))
        .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
    let root = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
    let format = root.get("format").and_then(|f| f.as_str()).unwrap_or("");
    if format != "hlo-text" {
        bail!("unsupported artifact format {format:?}");
    }
    let mut out = Vec::new();
    for v in root.get("variants").and_then(|v| v.as_arr()).unwrap_or(&[]) {
        let name = v.get("name").and_then(|s| s.as_str()).unwrap_or("").to_string();
        let kind = match v.get("kind").and_then(|s| s.as_str()) {
            Some("exhaustive") => VariantKind::Exhaustive,
            Some("block") => VariantKind::Block,
            Some("blockmin") => VariantKind::BlockMin,
            other => bail!("variant {name}: unknown kind {other:?}"),
        };
        let n = v.get("n").and_then(|x| x.as_usize()).context("variant n")?;
        let q = v.get("q").and_then(|x| x.as_usize()).unwrap_or(0);
        let bs = v.get("bs").and_then(|x| x.as_usize()).unwrap_or(0);
        let file = dir.join(v.get("file").and_then(|s| s.as_str()).context("variant file")?);
        out.push(Variant { name, kind, n, q, bs, file });
    }
    Ok(out)
}

/// A variant compiled onto the PJRT client, ready to execute.
pub struct Loaded {
    pub spec: Variant,
    exe: xla::PjRtLoadedExecutable,
}

/// The runtime: one PJRT CPU client + all compiled variants.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    loaded: Vec<Loaded>,
}

// SAFETY: the `xla` crate wraps PJRT handles in `Rc` + raw pointers,
// making them `!Send`/`!Sync` even though the underlying PJRT C API is
// documented thread-safe (and the TFRT CPU client serialises internally).
// `Runtime` only clones the `Rc`s during single-threaded `load()`; after
// that all access goes through `&self` (compile-once, execute-many), so
// sharing across the coordinator's threads is sound.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

/// A pre-padded input array bound to one artifact variant.
pub struct PaddedArray {
    literal: xla::Literal,
    variant: String,
}

// SAFETY: same argument as `Runtime` — the literal is created once and
// only read (by reference) afterwards; the coordinator serialises use.
unsafe impl Send for PaddedArray {}
unsafe impl Sync for PaddedArray {}

/// Result of a batched RMQ execution.
#[derive(Clone, Debug)]
pub struct RmqOutput {
    pub mins: Vec<f32>,
    pub args: Vec<i32>,
}

impl Runtime {
    /// Load every artifact in `dir`, compiling each HLO module once.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let variants = parse_manifest(dir)?;
        if variants.is_empty() {
            bail!("manifest has no variants");
        }
        let mut loaded = Vec::with_capacity(variants.len());
        for spec in variants {
            let proto = xla::HloModuleProto::from_text_file(&spec.file)
                .map_err(|e| anyhow!("parse {}: {e:?}", spec.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", spec.name))?;
            loaded.push(Loaded { spec, exe });
        }
        Ok(Runtime { client, loaded })
    }

    pub fn variants(&self) -> impl Iterator<Item = &Variant> {
        self.loaded.iter().map(|l| &l.spec)
    }

    /// Pick the smallest RMQ variant (exhaustive or block) whose static
    /// array size can hold `n` values.
    pub fn select_rmq_variant(&self, n: usize) -> Option<&Variant> {
        self.loaded
            .iter()
            .map(|l| &l.spec)
            .filter(|v| matches!(v.kind, VariantKind::Exhaustive | VariantKind::Block) && v.n >= n)
            .min_by_key(|v| v.n)
    }

    fn find(&self, name: &str) -> Result<&Loaded> {
        self.loaded
            .iter()
            .find(|l| l.spec.name == name)
            .ok_or_else(|| anyhow!("no artifact variant named {name}"))
    }

    /// Pre-pad an input array into a reusable device literal for the
    /// named variant (§Perf L3.3: the array literal is built once per
    /// (engine, array) epoch instead of once per chunk).
    pub fn prepare_array(&self, name: &str, xs: &[f32]) -> Result<PaddedArray> {
        let l = self.find(name)?;
        let v = &l.spec;
        if xs.len() > v.n {
            bail!("array of {} exceeds variant {} (n = {})", xs.len(), name, v.n);
        }
        // Pad the array with +inf: padded positions can never win a min,
        // and padded blocks' minima are +inf.
        let mut padded = xs.to_vec();
        padded.resize(v.n, f32::INFINITY);
        Ok(PaddedArray { literal: xla::Literal::vec1(&padded), variant: v.name.clone() })
    }

    /// Execute a batched RMQ on the named variant. `xs` is padded with
    /// +inf to the variant's static n; queries are padded with (0, 0)
    /// to its static q and the padding answers dropped.
    pub fn exec_rmq(&self, name: &str, xs: &[f32], queries: &[(u32, u32)]) -> Result<RmqOutput> {
        let arr = self.prepare_array(name, xs)?;
        self.exec_rmq_prepadded(&arr, queries)
    }

    /// Chunk execution against a pre-padded array literal.
    pub fn exec_rmq_prepadded(
        &self,
        arr: &PaddedArray,
        queries: &[(u32, u32)],
    ) -> Result<RmqOutput> {
        let name = arr.variant.as_str();
        let l = self.find(name)?;
        let v = &l.spec;
        if !matches!(v.kind, VariantKind::Exhaustive | VariantKind::Block) {
            bail!("variant {name} is not an rmq computation");
        }
        if queries.len() > v.q {
            bail!("batch of {} exceeds variant {} (q = {})", queries.len(), name, v.q);
        }
        let mut ls: Vec<i32> = queries.iter().map(|&(l, _)| l as i32).collect();
        let mut rs: Vec<i32> = queries.iter().map(|&(_, r)| r as i32).collect();
        ls.resize(v.q, 0);
        rs.resize(v.q, 0);

        let l_lit = xla::Literal::vec1(&ls);
        let r_lit = xla::Literal::vec1(&rs);
        let result = l
            .exe
            .execute::<&xla::Literal>(&[&arr.literal, &l_lit, &r_lit])
            .map_err(|e| anyhow!("execute {}: {e:?}", name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal {}: {e:?}", name))?;
        let parts = result.to_tuple().map_err(|e| anyhow!("tuple {}: {e:?}", name))?;
        if parts.len() != 2 {
            bail!("variant {name}: expected 2 outputs, got {}", parts.len());
        }
        let mut mins = parts[0].to_vec::<f32>().map_err(|e| anyhow!("mins {e:?}"))?;
        let mut args = parts[1].to_vec::<i32>().map_err(|e| anyhow!("args {e:?}"))?;
        mins.truncate(queries.len());
        args.truncate(queries.len());
        Ok(RmqOutput { mins, args })
    }

    /// Execute a block-minimums preprocessing variant.
    pub fn exec_blockmin(&self, name: &str, xs: &[f32]) -> Result<RmqOutput> {
        let l = self.find(name)?;
        let v = &l.spec;
        if v.kind != VariantKind::BlockMin {
            bail!("variant {name} is not a blockmin computation");
        }
        if xs.len() > v.n {
            bail!("array of {} exceeds variant {} (n = {})", xs.len(), name, v.n);
        }
        let mut padded = xs.to_vec();
        padded.resize(v.n, f32::INFINITY);
        let x_lit = xla::Literal::vec1(&padded);
        let result = l
            .exe
            .execute::<xla::Literal>(&[x_lit])
            .map_err(|e| anyhow!("execute {}: {e:?}", name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal {}: {e:?}", name))?;
        let parts = result.to_tuple().map_err(|e| anyhow!("tuple {}: {e:?}", name))?;
        let mins = parts[0].to_vec::<f32>().map_err(|e| anyhow!("mins {e:?}"))?;
        let args = parts[1].to_vec::<i32>().map_err(|e| anyhow!("args {e:?}"))?;
        Ok(RmqOutput { mins, args })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing_roundtrip() {
        let dir = std::env::temp_dir().join(format!("rtxrmq-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format":"hlo-text","variants":[
                {"name":"a","kind":"exhaustive","n":1024,"q":64,"block_q":64,"block_n":256,"file":"a.hlo.txt"},
                {"name":"b","kind":"block","n":4096,"q":64,"bs":64,"file":"b.hlo.txt"},
                {"name":"c","kind":"blockmin","n":4096,"bs":64,"file":"c.hlo.txt"}
            ]}"#,
        )
        .unwrap();
        let vs = parse_manifest(&dir).unwrap();
        assert_eq!(vs.len(), 3);
        assert_eq!(vs[0].kind, VariantKind::Exhaustive);
        assert_eq!(vs[1].bs, 64);
        assert_eq!(vs[2].kind, VariantKind::BlockMin);
        assert_eq!(vs[2].q, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_rejects_unknown_kind() {
        let dir = std::env::temp_dir().join(format!("rtxrmq-manifest-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format":"hlo-text","variants":[{"name":"x","kind":"wat","n":1,"file":"x"}]}"#,
        )
        .unwrap();
        assert!(parse_manifest(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! `rtxrmq` — launcher CLI for the RTXRMQ reproduction.
//!
//! Subcommands:
//!   solve        one-shot batch solve on a synthetic workload
//!   serve        start the coordinator and drive a synthetic client load
//!   bench-smoke  n × batch wall-clock grid over both BVH layouts -> BENCH_rmq.json
//!   memory       Table-2 style memory report for a given n
//!   artifacts    list the AOT artifact variants (PJRT manifest)
//!   info         architecture profiles used by the models

use rtxrmq::coordinator::batcher::BatcherCfg;
use rtxrmq::coordinator::engine::{
    EngineCfg, EngineKind, EngineSet, LifecycleCfg, RebuildMode, ShardBlock,
};
use rtxrmq::coordinator::router::Policy;
use rtxrmq::coordinator::server::{Coordinator, CoordinatorCfg};
use rtxrmq::rmq::naive_rmq;
use rtxrmq::runtime::Runtime;
use rtxrmq::util::cli::{Args, Help};
use rtxrmq::util::faults::{self, FaultPlan};
use rtxrmq::util::rng::Rng;
use rtxrmq::util::stats::fmt_mb;
use rtxrmq::workload::{gen_array, gen_mixed, gen_queries, Op, RangeDist};
use std::path::Path;
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("solve") => cmd_solve(&args),
        Some("serve") => cmd_serve(&args),
        Some("bench-smoke") => cmd_bench_smoke(&args),
        Some("bench-compare") => cmd_bench_compare(&args),
        Some("memory") => cmd_memory(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some("info") => cmd_info(),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "rtxrmq — reproduction of 'Accelerating Range Minimum Queries with Ray Tracing Cores'\n"
    );
    for h in [
        Help::new("solve", "solve one batch")
            .opt("n", "array size (default 2^16; accepts 2^k)")
            .opt("q", "queries in the batch (default 4096)")
            .opt("dist", "large|medium|small (default small)")
            .opt("engine", "RTXRMQ|SHARDED|LCA|HRMQ|EXHAUSTIVE|XLA (default: route by cost model)")
            .opt("shard-block", "block size or 'auto' = cost-model tuner (default √n)"),
        Help::new("serve", "run the coordinator under synthetic load")
            .opt("n", "array size (default 2^16)")
            .opt("requests", "number of requests (default 128)")
            .opt("batch", "ops per request (default 1024)")
            .opt("mixed", "serve a mixed query+update op stream (gen_mixed)")
            .opt("update-frac", "update fraction of the mixed stream (default 0.1)")
            .opt("dist", "range distribution of the mixed stream (default small)")
            .opt("shard-block", "block size or 'auto' = workload-fed tuner (default √n)")
            .opt("rebuild", "epoch lifecycle: auto = background rebuild/re-shard, off (default auto)")
            .opt("reshard-drift", "re-shard when the tuned block drifts this factor (default 2.0)")
            .opt("quiet-tail", "append this many pure-query requests (rebuild trigger window)")
            .opt("shift-dist", "switch the mixed stream to this distribution halfway through")
            .opt("expect-rebuild", "exit non-zero unless a background rebuild occurred")
            .opt("expect-reshard", "exit non-zero unless a background re-shard occurred")
            .opt("no-pipeline", "serial executor: apply update segments at the fence, no overlap")
            .opt("inject", "fault schedule site:kind:prob:count[,...] (chaos mode; see util::faults)")
            .opt("inject-seed", "RNG seed of the fault schedule — same seed, same faults (default 42)")
            .opt("deadline-ms", "per-request deadline; expired requests are dropped whole (0 = off)")
            .opt("shed-watermark", "queue depth past which admission sheds Overloaded (default 256)")
            .opt("no-xla", "disable the PJRT/XLA engine"),
        Help::new("bench-smoke", "wall-clock ns/query + build_ms/resident_bytes grid: binary/wide BVH + sharded engine")
            .opt("ns", "comma-separated array sizes (default 2^16,2^18,2^20)")
            .opt("batches", "comma-separated batch sizes (default 2^12,2^16)")
            .opt("seed", "workload seed")
            .opt("shard-block", "sharded column block size, or 'auto' (default √n)")
            .opt("dist", "expected range dist fed to the 'auto' tuner (default small)")
            .opt("update-frac", "also time updates: batch×frac points per grid cell (default 0)")
            .opt("summary-md", "append a markdown summary table to this file")
            .opt("out", "output JSON path (default BENCH_rmq.json)"),
        Help::new("bench-compare", "regression gate: fresh bench-smoke JSON vs baseline")
            .opt("baseline", "committed baseline JSON (required; ci/BENCH_baseline.json in CI)")
            .opt("current", "fresh bench JSON (default BENCH_rmq.json)")
            .opt("max-regress", "allowed relative regression per metric, incl. resident_bytes (default 0.25)")
            .opt("summary-md", "append the delta table to this markdown file"),
        Help::new("memory", "data-structure memory report").opt("n", "array size"),
        Help::new("artifacts", "list AOT artifacts").opt("dir", "artifacts dir"),
        Help::new("info", "print the GPU/CPU architecture profiles"),
    ] {
        println!("{}", h.render());
    }
    println!("benches: cargo bench --bench fig12_time_speedup (… fig10..fig17, table2, ablations)");
}

/// Parse `--shard-block` (`auto` | size | absent → √n default). The
/// `dist`/`update_frac` expectations parameterise the auto-tuner.
fn shard_block_arg(args: &Args, dist: RangeDist, update_frac: f64) -> ShardBlock {
    match args.opt("shard-block") {
        None => ShardBlock::Sqrt,
        Some(s) => ShardBlock::parse(s, dist, update_frac).unwrap_or_else(|| {
            eprintln!("invalid --shard-block {s} (expected a size or 'auto')");
            std::process::exit(2);
        }),
    }
}

fn cmd_solve(args: &Args) -> i32 {
    let n: usize = args.get_or("n", 1usize << 16).unwrap();
    let q: usize = args.get_or("q", 4096usize).unwrap();
    let dist = RangeDist::parse(&args.str_or("dist", "small")).unwrap_or(RangeDist::Small);
    let xs = gen_array(n, 7);
    let mut rng = Rng::new(8);
    let queries = gen_queries(n, q, dist, &mut rng);

    let runtime = Runtime::load(Path::new("artifacts")).ok().map(Arc::new);
    let shard_block = shard_block_arg(args, dist, 0.0);
    let engines = EngineSet::build_with(&xs, runtime, EngineCfg { shard_block });
    let kind = match args.opt("engine") {
        Some(name) => EngineKind::parse(name).unwrap_or_else(|| {
            eprintln!("unknown engine {name}");
            std::process::exit(2);
        }),
        None => {
            let router = rtxrmq::coordinator::router::Router::new(Policy::ModeledCost);
            router.route(n, &queries, &engines.kinds())
        }
    };
    let engine = engines.get(kind).expect("engine available");
    let t0 = std::time::Instant::now();
    let answers = engine.solve(&queries, rtxrmq::util::pool::default_workers()).unwrap();
    let dt = t0.elapsed();
    println!(
        "{} answered {} {}-range queries over n={} in {:.2?} ({:.0} ns/RMQ local)",
        kind.name(),
        answers.len(),
        dist.name(),
        n,
        dt,
        dt.as_nanos() as f64 / answers.len() as f64
    );
    for (i, &(l, r)) in queries.iter().take(3).enumerate() {
        println!("  RMQ({l},{r}) = {} (value {})", answers[i], xs[answers[i] as usize]);
    }
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let n: usize = args.get_or("n", 1usize << 16).unwrap();
    let requests: usize = args.get_or("requests", 128usize).unwrap();
    let batch: usize = args.get_or("batch", 1024usize).unwrap();
    let mixed = args.flag("mixed");
    let update_frac: f64 = args.get_or("update-frac", 0.1f64).unwrap();
    let dist = RangeDist::parse(&args.str_or("dist", "small")).unwrap_or(RangeDist::Small);
    let rebuild = RebuildMode::parse(&args.str_or("rebuild", "auto")).unwrap_or_else(|| {
        eprintln!("invalid --rebuild (expected auto|off)");
        std::process::exit(2);
    });
    let reshard_drift: f64 = args.get_or("reshard-drift", 2.0f64).unwrap();
    let quiet_tail: usize = args.get_or("quiet-tail", 0usize).unwrap();
    // Reshard-inducing distribution shift (nightly soak): the second
    // half of the run offers this distribution instead of --dist.
    let shift_dist = match args.opt("shift-dist") {
        None => None,
        Some(s) => match RangeDist::parse(s) {
            Some(d) => Some(d),
            None => {
                eprintln!("invalid --shift-dist {s} (expected large|medium|small)");
                std::process::exit(2);
            }
        },
    };
    // Chaos mode: arm the deterministic fault registry before any
    // serving thread starts. A bad spec is a usage error, not a crash.
    let inject_seed: u64 = args.get_or("inject-seed", 42u64).unwrap();
    if let Some(spec) = args.opt("inject") {
        match FaultPlan::parse(spec, inject_seed) {
            Ok(plan) => faults::arm(plan),
            Err(e) => {
                eprintln!("invalid --inject: {e}");
                std::process::exit(2);
            }
        }
    }
    let deadline_ms: u64 = args.get_or("deadline-ms", 0u64).unwrap();
    let deadline =
        if deadline_ms > 0 { Some(std::time::Duration::from_millis(deadline_ms)) } else { None };
    let shed_watermark: usize =
        args.get_or("shed-watermark", BatcherCfg::default().shed_watermark).unwrap();
    let xs = gen_array(n, 7);
    let runtime = if args.flag("no-xla") {
        None
    } else {
        Runtime::load(Path::new("artifacts")).ok().map(Arc::new)
    };
    let shard_block = shard_block_arg(args, dist, if mixed { update_frac } else { 0.0 });
    let c = Coordinator::start(
        &xs,
        runtime,
        CoordinatorCfg {
            batcher: BatcherCfg { shed_watermark, ..Default::default() },
            engines: EngineCfg { shard_block },
            lifecycle: LifecycleCfg { rebuild, reshard_drift, ..Default::default() },
            pipeline: !args.flag("no-pipeline"),
            ..Default::default()
        },
    );
    let mut rng = Rng::new(9);
    let t0 = std::time::Instant::now();
    // The rolling oracle tracks applied updates (mixed mode); a few
    // answers per request are spot-checked against it.
    let mut oracle = xs.clone();
    let mut rejected = 0usize;
    if mixed {
        let mut total_updates = 0usize;
        for r in 0..requests {
            let d = match shift_dist {
                Some(sd) if r >= requests / 2 => sd,
                _ => dist,
            };
            let ops = gen_mixed(n, batch, update_frac, d, &mut rng);
            // A rejected request — shed at admission, expired deadline,
            // or dropped whole by an injected hand-off fault — executed
            // none of its ops, so the rolling oracle skips it entirely.
            // Accepted requests must still match the oracle exactly,
            // whatever faults were injected underneath.
            let resp = match c.submit_mixed_deadline(ops.clone(), deadline) {
                Ok(resp) => resp,
                Err(_) => {
                    rejected += 1;
                    continue;
                }
            };
            total_updates += resp.updates_applied;
            let mut checked = 0;
            let mut k = 0;
            for op in &ops {
                match *op {
                    Op::Query((l, r)) => {
                        if checked < 4 {
                            let want = naive_rmq(&oracle, l as usize, r as usize) as u32;
                            assert_eq!(resp.answers[k], want, "({l},{r}) via {}", resp.engine);
                            checked += 1;
                        }
                        k += 1;
                    }
                    Op::Update { i, v } => oracle[i as usize] = v,
                }
            }
        }
        let wall = t0.elapsed();
        println!(
            "served {} of {requests} mixed requests x {batch} ops ({total_updates} updates, \
             {rejected} rejected) in {wall:.2?} ({:.0} ops/s, fenced, spot-checked)",
            requests - rejected,
            (requests * batch) as f64 / wall.as_secs_f64()
        );
    } else {
        for i in 0..requests {
            let dist = [RangeDist::Small, RangeDist::Medium, RangeDist::Large][i % 3];
            let qs = gen_queries(n, batch, dist, &mut rng);
            let ops = qs.into_iter().map(Op::Query).collect();
            if c.submit_mixed_deadline(ops, deadline).is_err() {
                rejected += 1;
            }
        }
        let wall = t0.elapsed();
        println!(
            "served {} of {requests} requests x {batch} queries ({rejected} rejected) \
             in {wall:.2?} ({:.0} queries/s)",
            requests - rejected,
            (requests * batch) as f64 / wall.as_secs_f64()
        );
    }
    if quiet_tail > 0 {
        // Quiet period: pure-query requests that let the observer's
        // decayed update rate fall below the rebuild threshold, so the
        // background builder can refresh the static engines. Under a
        // --shift-dist run the tail keeps the shifted distribution, so
        // the workload-fed tuner sees the drift it should re-shard for.
        let tail_dist = shift_dist.unwrap_or(dist);
        let mut tail_served = 0usize;
        for _ in 0..quiet_tail {
            let qs = gen_queries(n, batch, tail_dist, &mut rng);
            // An injected hand-off fault can still reject a tail
            // request whole; only accepted answers are oracle-checked.
            let resp = match c.query(qs.clone()) {
                Ok(resp) => resp,
                Err(_) => continue,
            };
            tail_served += 1;
            for (k, &(l, r)) in qs.iter().take(2).enumerate() {
                assert_eq!(
                    resp.answers[k],
                    naive_rmq(&oracle, l as usize, r as usize) as u32,
                    "({l},{r}) via {}",
                    resp.engine
                );
            }
        }
        println!("quiet tail: {tail_served} of {quiet_tail} pure-query requests served");
    }
    // The lifecycle claims happen on the serving thread; the builds may
    // still be in flight on the builder — give each expectation a grace
    // window to land before failing the run.
    let expect = |flag: &str, what: &str, count: &dyn Fn() -> u64| -> bool {
        if !args.flag(flag) {
            return true;
        }
        let t1 = std::time::Instant::now();
        while count() == 0 && t1.elapsed() < std::time::Duration::from_secs(5) {
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
        if count() == 0 {
            eprintln!("--{flag}: no background {what} occurred");
            return false;
        }
        true
    };
    let ok = expect("expect-rebuild", "rebuild", &|| c.metrics.lock().rebuilds)
        && expect("expect-reshard", "re-shard", &|| c.metrics.lock().reshards);
    // Fold recoveries that landed after the last batch (e.g. a builder
    // respawn during the grace window) into the printed snapshot.
    c.sync_faults();
    println!("{}", c.metrics.lock());
    c.shutdown();
    faults::disarm();
    if ok {
        0
    } else {
        1
    }
}

fn cmd_bench_smoke(args: &Args) -> i32 {
    use rtxrmq::bench_harness::smoke::{
        append_summary_md, run_smoke, speedups, summary_md, to_json, write_json, SmokeCfg,
    };
    let defaults = SmokeCfg::default();
    let update_frac: f64 = args.get_or("update-frac", defaults.update_frac).unwrap();
    let dist = RangeDist::parse(&args.str_or("dist", "small")).unwrap_or(RangeDist::Small);
    let cfg = SmokeCfg {
        ns: args.list_or("ns", &defaults.ns).unwrap(),
        batches: args.list_or("batches", &defaults.batches).unwrap(),
        workers: rtxrmq::util::pool::default_workers(),
        seed: args.get_or("seed", defaults.seed).unwrap(),
        shard_block: shard_block_arg(args, dist, update_frac),
        update_frac,
    };
    let out = args.str_or("out", "BENCH_rmq.json");
    let points = run_smoke(&cfg);
    let mut rows = Vec::new();
    for p in &points {
        rows.push(vec![
            p.layout.to_string(),
            p.n.to_string(),
            p.batch.to_string(),
            format!("{:.1}", p.ns_per_query),
            if p.upd_ns_per_op > 0.0 { format!("{:.1}", p.upd_ns_per_op) } else { "-".into() },
            format!("{:.2}", p.build_ms),
            fmt_mb(p.resident_bytes as u64),
            p.counters.nodes_visited.to_string(),
            p.counters.tri_tests.to_string(),
        ]);
    }
    rtxrmq::bench_harness::print_table(
        "RTXRMQ solver smoke grid (local wall clock)",
        &[
            "layout",
            "n",
            "batch",
            "ns/query",
            "ns/update",
            "build_ms",
            "resident",
            "nodes_visited",
            "tri_tests",
        ],
        &rows,
    );
    for (n, batch, label, binary_ns, ns, speedup) in speedups(&points) {
        println!(
            "n={n} batch={batch}: binary {binary_ns:.1} ns/q, {label} {ns:.1} ns/q -> {speedup:.2}x"
        );
    }
    if let Some(md_path) = args.opt("summary-md") {
        if let Err(e) = append_summary_md(std::path::Path::new(md_path), &summary_md(&cfg, &points))
        {
            eprintln!("failed to append summary to {md_path}: {e}");
        }
    }
    match write_json(std::path::Path::new(&out), &to_json(&cfg, &points)) {
        Ok(()) => {
            println!("wrote {out}");
            0
        }
        Err(e) => {
            eprintln!("failed to write {out}: {e}");
            1
        }
    }
}

fn cmd_bench_compare(args: &Args) -> i32 {
    use rtxrmq::bench_harness::compare::{compare, summary_md};
    use rtxrmq::bench_harness::smoke::append_summary_md;
    use rtxrmq::util::json::Json;
    let baseline_path = match args.opt("baseline") {
        Some(p) => p.to_string(),
        None => {
            eprintln!("bench-compare: --baseline is required");
            return 2;
        }
    };
    let current_path = args.str_or("current", "BENCH_rmq.json");
    let max_regress: f64 = args.get_or("max-regress", 0.25f64).unwrap();
    let load = |path: &str| -> Result<Json, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Json::parse(text.trim()).map_err(|e| format!("{path}: {e}"))
    };
    let (baseline, current) = match (load(&baseline_path), load(&current_path)) {
        (Ok(b), Ok(c)) => (b, c),
        (b, c) => {
            for r in [b.err(), c.err()].into_iter().flatten() {
                eprintln!("bench-compare: {r}");
            }
            return 2;
        }
    };
    let report = match compare(&baseline, &current, max_regress) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("bench-compare: {e}");
            return 2;
        }
    };
    let rows: Vec<Vec<String>> = report
        .rows
        .iter()
        .map(|r| {
            vec![
                r.layout.clone(),
                r.n.to_string(),
                r.batch.to_string(),
                r.metric.to_string(),
                format!("{:.1}", r.baseline),
                format!("{:.1}", r.current),
                format!("{:+.1}%", r.delta * 100.0),
                if r.regressed { "REGRESSED".into() } else { String::new() },
            ]
        })
        .collect();
    rtxrmq::bench_harness::print_table(
        &format!("bench-gate vs {baseline_path} (tolerance +{:.0}%)", max_regress * 100.0),
        &["solver", "n", "batch", "metric", "baseline", "current", "delta", ""],
        &rows,
    );
    for m in &report.missing {
        eprintln!("bench-compare: baseline point missing from current run: {m}");
    }
    if let Some(md_path) = args.opt("summary-md") {
        if let Err(e) = append_summary_md(std::path::Path::new(md_path), &summary_md(&report)) {
            eprintln!("failed to append summary to {md_path}: {e}");
        }
    }
    if report.bootstrap_baseline {
        println!(
            "baseline is the modeled bootstrap placeholder — gate reports only; commit a \
             measured BENCH_rmq.json (the CI bench artifact) over {baseline_path} to arm it"
        );
    }
    if report.failed() {
        eprintln!(
            "bench-compare: {} regression(s), {} missing point(s) beyond +{:.0}% tolerance",
            report.regressions().len(),
            report.missing.len(),
            max_regress * 100.0
        );
        1
    } else {
        println!("bench-gate: PASS ({} metrics compared)", report.rows.len());
        0
    }
}

fn cmd_memory(args: &Args) -> i32 {
    let n: usize = args.get_or("n", 1usize << 16).unwrap();
    let xs = gen_array(n, 7);
    let engines = EngineSet::build(&xs, None);
    println!("data-structure memory at n = {n} (input {}):", fmt_mb((n * 4) as u64));
    for kind in [
        EngineKind::Rtx,
        EngineKind::Sharded,
        EngineKind::Lca,
        EngineKind::Hrmq,
        EngineKind::Exhaustive,
    ] {
        let e = engines.get(kind).unwrap();
        println!("  {:<11} {}", kind.name(), fmt_mb(e.memory_bytes() as u64));
    }
    0
}

fn cmd_artifacts(args: &Args) -> i32 {
    let dir = args.str_or("dir", "artifacts");
    match Runtime::load(Path::new(&dir)) {
        Ok(rt) => {
            println!("PJRT artifacts in {dir}:");
            for v in rt.variants() {
                println!("  {:<28} kind={:?} n={} q={} bs={}", v.name, v.kind, v.n, v.q, v.bs);
            }
            0
        }
        Err(e) => {
            eprintln!("failed to load artifacts: {e}");
            1
        }
    }
}

fn cmd_info() -> i32 {
    println!("GPU architecture profiles (models' inputs):");
    for p in rtxrmq::rtcore::arch::generations()
        .into_iter()
        .chain(rtxrmq::rtcore::arch::lovelace_skus())
    {
        println!(
            "  {:<26} SMs={:<4} clock={:.2} GHz RTgen={:.0}x TDP={:.0} W L2={:.0} MiB",
            p.name, p.sm_count, p.clock_ghz, p.rt_gen_factor, p.tdp_w, p.l2_mib
        );
    }
    let cpu = rtxrmq::rtcore::arch::EPYC_9654_X2;
    println!("  {:<26} cores={} TDP={:.0} W", cpu.name, cpu.cores, cpu.tdp_w);
    0
}

//! `rtxrmq` — launcher CLI for the RTXRMQ reproduction.
//!
//! Subcommands:
//!   solve        one-shot batch solve on a synthetic workload
//!   serve        start the coordinator and drive a synthetic client load
//!   bench-smoke  n × batch wall-clock grid over both BVH layouts -> BENCH_rmq.json
//!   memory       Table-2 style memory report for a given n
//!   artifacts    list the AOT artifact variants (PJRT manifest)
//!   info         architecture profiles used by the models

use rtxrmq::coordinator::batcher::{BatcherCfg, Reply, Response, ServeError};
use rtxrmq::coordinator::engine::{
    EngineCfg, EngineKind, EngineSet, LifecycleCfg, RebuildMode, ShardBlock,
};
use rtxrmq::coordinator::router::Policy;
use rtxrmq::coordinator::server::{Coordinator, CoordinatorCfg};
use rtxrmq::coordinator::tenants::{MultiCfg, MultiCoordinator, TenantCfg, TenantSpec};
use rtxrmq::rmq::naive_rmq;
use rtxrmq::runtime::Runtime;
use rtxrmq::util::cli::{Args, Help};
use rtxrmq::util::faults::{self, FaultPlan};
use rtxrmq::util::json::Json;
use rtxrmq::util::manifest::{self, ManifestBuilder};
use rtxrmq::util::rng::Rng;
use rtxrmq::util::stats::fmt_mb;
use rtxrmq::workload::{gen_array, gen_mixed_ranged, gen_queries, Op, RangeDist};
use std::collections::VecDeque;
use std::path::Path;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("solve") => cmd_solve(&args),
        Some("serve") => cmd_serve(&args),
        Some("bench-smoke") => cmd_bench_smoke(&args),
        Some("bench-compare") => cmd_bench_compare(&args),
        Some("manifest-check") => cmd_manifest_check(&args),
        Some("memory") => cmd_memory(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some("info") => cmd_info(),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "rtxrmq — reproduction of 'Accelerating Range Minimum Queries with Ray Tracing Cores'\n"
    );
    for h in [
        Help::new("solve", "solve one batch")
            .opt("n", "array size (default 2^16; accepts 2^k)")
            .opt("q", "queries in the batch (default 4096)")
            .opt("dist", "large|medium|small (default small)")
            .opt("engine", "RTXRMQ|SHARDED|LCA|HRMQ|EXHAUSTIVE|XLA (default: route by cost model)")
            .opt("shard-block", "block size or 'auto' = cost-model tuner (default √n)")
            .opt("packet-width", "rays per traversal packet, 0 = scalar (default 0; A/B knob)")
            .opt("no-sort-queries", "skip the batch sort (disables packet grouping coherence)"),
        Help::new("serve", "run the coordinator under synthetic load")
            .opt("n", "array size (default 2^16)")
            .opt("requests", "number of requests (default 128)")
            .opt("batch", "ops per request (default 1024)")
            .opt("mixed", "serve a mixed query+update op stream (gen_mixed_ranged)")
            .opt("update-frac", "point-update fraction of the mixed stream (default 0.1)")
            .opt("range-frac", "range add/assign fraction of the mixed stream (default 0)")
            .opt("dist", "range distribution of the mixed stream (default small)")
            .opt("shard-block", "block size or 'auto' = workload-fed tuner (default √n)")
            .opt("rebuild", "epoch lifecycle: auto = background rebuild/re-shard, off (default auto)")
            .opt("reshard-drift", "re-shard when the tuned block drifts this factor (default 2.0)")
            .opt("quiet-tail", "append this many pure-query requests (rebuild trigger window)")
            .opt("shift-dist", "switch the mixed stream to this distribution halfway through")
            .opt("expect-rebuild", "exit non-zero unless a background rebuild occurred")
            .opt("expect-reshard", "exit non-zero unless a background re-shard occurred")
            .opt("no-pipeline", "serial executor: apply update segments at the fence, no overlap")
            .opt("inject", "fault schedule site:kind:prob:count[,...] (chaos mode; see util::faults)")
            .opt("inject-seed", "RNG seed of the fault schedule — same seed, same faults (default 42)")
            .opt("deadline-ms", "per-request deadline; expired requests are dropped whole (0 = off)")
            .opt("shed-watermark", "queue depth past which admission sheds Overloaded (default 256)")
            .opt("tenants", "multi-tenant mode: serve N default tenants t0..tN-1")
            .opt("tenant-specs", "multi-tenant mode: 'name,k=v,..;name2,..' — keys n dist uf rf shift weight watermark deadline-ms depth tail requests batch")
            .opt("global-watermark", "multi-tenant: aggregate queued-request shed cap (default 1024)")
            .opt("exec-workers", "multi-tenant: executor worker threads (default 2)")
            .opt("packet-width", "rays per traversal packet, 0 = scalar (default 0; A/B knob)")
            .opt("no-sort-queries", "skip the batch sort (disables packet grouping coherence)")
            .opt("manifest", "write a hashed run manifest (JSON) to this path; threads run= into metrics lines")
            .opt("no-xla", "disable the PJRT/XLA engine"),
        Help::new("bench-smoke", "wall-clock ns/query + build_ms/resident_bytes grid: binary/wide BVH + sharded engine")
            .opt("ns", "comma-separated array sizes (default 2^16,2^18,2^20)")
            .opt("batches", "comma-separated batch sizes (default 2^12,2^16)")
            .opt("seed", "workload seed")
            .opt("shard-block", "sharded column block size, or 'auto' (default √n)")
            .opt("dist", "expected range dist fed to the 'auto' tuner (default small)")
            .opt("update-frac", "also time updates: batch×frac points per grid cell (default 0)")
            .opt("range-frac", "also time lazy range updates: batch×frac range ops per sharded cell (default 0)")
            .opt("packet-width", "add a wide-pN/sharded-pN packet column pair (0 = off)")
            .opt("summary-md", "append a markdown summary table to this file")
            .opt("out", "output JSON path (default BENCH_rmq.json)")
            .opt("manifest", "write a hashed run manifest recording the bench JSON artifact"),
        Help::new("bench-compare", "regression gate: fresh bench-smoke JSON vs baseline")
            .opt("baseline", "committed baseline JSON (required; ci/BENCH_baseline.json in CI)")
            .opt("current", "fresh bench JSON (default BENCH_rmq.json)")
            .opt("max-regress", "allowed relative regression per metric, incl. resident_bytes (default 0.25)")
            .opt("summary-md", "append the delta table to this markdown file")
            .opt("manifest", "write a hashed run manifest recording both gate inputs"),
        Help::new("manifest-check", "re-hash and validate a run manifest (CI gate)")
            .opt("path", "manifest JSON to validate (required)"),
        Help::new("memory", "data-structure memory report").opt("n", "array size"),
        Help::new("artifacts", "list AOT artifacts").opt("dir", "artifacts dir"),
        Help::new("info", "print the GPU/CPU architecture profiles"),
    ] {
        println!("{}", h.render());
    }
    println!("benches: cargo bench --bench fig12_time_speedup (… fig10..fig17, table2, ablations)");
}

/// Parse `--shard-block` (`auto` | size | absent → √n default). The
/// `dist`/`update_frac` expectations parameterise the auto-tuner.
fn shard_block_arg(args: &Args, dist: RangeDist, update_frac: f64) -> ShardBlock {
    match args.opt("shard-block") {
        None => ShardBlock::Sqrt,
        Some(s) => ShardBlock::parse(s, dist, update_frac).unwrap_or_else(|| {
            eprintln!("invalid --shard-block {s} (expected a size or 'auto')");
            std::process::exit(2);
        }),
    }
}

fn cmd_solve(args: &Args) -> i32 {
    let n: usize = args.get_or("n", 1usize << 16).unwrap();
    let q: usize = args.get_or("q", 4096usize).unwrap();
    let dist = RangeDist::parse(&args.str_or("dist", "small")).unwrap_or(RangeDist::Small);
    let xs = gen_array(n, 7);
    let mut rng = Rng::new(8);
    let queries = gen_queries(n, q, dist, &mut rng);

    let runtime = Runtime::load(Path::new("artifacts")).ok().map(Arc::new);
    let shard_block = shard_block_arg(args, dist, 0.0);
    let packet_width: usize = args.get_or("packet-width", 0usize).unwrap();
    let no_sort_queries = args.flag("no-sort-queries");
    let engines = EngineSet::build_with(
        &xs,
        runtime,
        EngineCfg { shard_block, packet_width, no_sort_queries },
    );
    let kind = match args.opt("engine") {
        Some(name) => EngineKind::parse(name).unwrap_or_else(|| {
            eprintln!("unknown engine {name}");
            std::process::exit(2);
        }),
        None => {
            let router = rtxrmq::coordinator::router::Router::new(Policy::ModeledCost);
            router.route(n, &queries, &engines.kinds())
        }
    };
    let engine = engines.get(kind).expect("engine available");
    let t0 = std::time::Instant::now();
    let answers = engine.solve(&queries, rtxrmq::util::pool::default_workers()).unwrap();
    let dt = t0.elapsed();
    println!(
        "{} answered {} {}-range queries over n={} in {:.2?} ({:.0} ns/RMQ local)",
        kind.name(),
        answers.len(),
        dist.name(),
        n,
        dt,
        dt.as_nanos() as f64 / answers.len() as f64
    );
    for (i, &(l, r)) in queries.iter().take(3).enumerate() {
        println!("  RMQ({l},{r}) = {} (value {})", answers[i], xs[answers[i] as usize]);
    }
    0
}

fn cmd_serve(args: &Args) -> i32 {
    if args.opt("tenants").is_some() || args.opt("tenant-specs").is_some() {
        return cmd_serve_multi(args);
    }
    let n: usize = args.get_or("n", 1usize << 16).unwrap();
    let requests: usize = args.get_or("requests", 128usize).unwrap();
    let batch: usize = args.get_or("batch", 1024usize).unwrap();
    let mixed = args.flag("mixed");
    let update_frac: f64 = args.get_or("update-frac", 0.1f64).unwrap();
    let range_frac: f64 = args.get_or("range-frac", 0.0f64).unwrap();
    let dist = RangeDist::parse(&args.str_or("dist", "small")).unwrap_or(RangeDist::Small);
    let rebuild = RebuildMode::parse(&args.str_or("rebuild", "auto")).unwrap_or_else(|| {
        eprintln!("invalid --rebuild (expected auto|off)");
        std::process::exit(2);
    });
    let reshard_drift: f64 = args.get_or("reshard-drift", 2.0f64).unwrap();
    let quiet_tail: usize = args.get_or("quiet-tail", 0usize).unwrap();
    // Reshard-inducing distribution shift (nightly soak): the second
    // half of the run offers this distribution instead of --dist.
    let shift_dist = match args.opt("shift-dist") {
        None => None,
        Some(s) => match RangeDist::parse(s) {
            Some(d) => Some(d),
            None => {
                eprintln!("invalid --shift-dist {s} (expected large|medium|small)");
                std::process::exit(2);
            }
        },
    };
    // Chaos mode: arm the deterministic fault registry before any
    // serving thread starts. A bad spec is a usage error, not a crash.
    let inject_seed: u64 = args.get_or("inject-seed", 42u64).unwrap();
    if let Some(spec) = args.opt("inject") {
        match FaultPlan::parse(spec, inject_seed) {
            Ok(plan) => faults::arm(plan),
            Err(e) => {
                eprintln!("invalid --inject: {e}");
                std::process::exit(2);
            }
        }
    }
    let deadline_ms: u64 = args.get_or("deadline-ms", 0u64).unwrap();
    let deadline =
        if deadline_ms > 0 { Some(std::time::Duration::from_millis(deadline_ms)) } else { None };
    let shed_watermark: usize =
        args.get_or("shed-watermark", BatcherCfg::default().shed_watermark).unwrap();
    let manifest_path = args.opt("manifest").map(str::to_string);
    let run_id = manifest_path.as_ref().map(|_| manifest::gen_run_id());
    let xs = gen_array(n, 7);
    let runtime = if args.flag("no-xla") {
        None
    } else {
        Runtime::load(Path::new("artifacts")).ok().map(Arc::new)
    };
    let shard_block = shard_block_arg(args, dist, if mixed { update_frac } else { 0.0 });
    let packet_width: usize = args.get_or("packet-width", 0usize).unwrap();
    let no_sort_queries = args.flag("no-sort-queries");
    let c = Coordinator::start(
        &xs,
        runtime,
        CoordinatorCfg {
            batcher: BatcherCfg { shed_watermark, ..Default::default() },
            engines: EngineCfg { shard_block, packet_width, no_sort_queries },
            lifecycle: LifecycleCfg { rebuild, reshard_drift, ..Default::default() },
            pipeline: !args.flag("no-pipeline"),
            ..Default::default()
        },
    );
    if let Some(id) = &run_id {
        c.metrics.lock().set_labels(Some(id.clone()), None);
    }
    let mut rng = Rng::new(9);
    let t0 = std::time::Instant::now();
    // The rolling oracle tracks applied updates (mixed mode); a few
    // answers per request are spot-checked against it.
    let mut oracle = xs.clone();
    let mut rejected = 0usize;
    if mixed {
        let mut total_updates = 0usize;
        for r in 0..requests {
            let d = match shift_dist {
                Some(sd) if r >= requests / 2 => sd,
                _ => dist,
            };
            let ops = gen_mixed_ranged(n, batch, update_frac, range_frac, d, &mut rng);
            // A rejected request — shed at admission, expired deadline,
            // or dropped whole by an injected hand-off fault — executed
            // none of its ops, so the rolling oracle skips it entirely.
            // Accepted requests must still match the oracle exactly,
            // whatever faults were injected underneath.
            let resp = match c.submit_mixed_deadline(ops.clone(), deadline) {
                Ok(resp) => resp,
                Err(_) => {
                    rejected += 1;
                    continue;
                }
            };
            total_updates += resp.updates_applied;
            let mut checked = 0;
            let mut k = 0;
            for op in &ops {
                match *op {
                    Op::Query((l, r)) => {
                        if checked < 4 {
                            let want = naive_rmq(&oracle, l as usize, r as usize) as u32;
                            assert_eq!(resp.answers[k], want, "({l},{r}) via {}", resp.engine);
                            checked += 1;
                        }
                        k += 1;
                    }
                    Op::Update { i, v } => oracle[i as usize] = v,
                    Op::RangeAdd { l, r, v } => {
                        for x in oracle[l as usize..=r as usize].iter_mut() {
                            *x += v;
                        }
                    }
                    Op::RangeAssign { l, r, v } => {
                        for x in oracle[l as usize..=r as usize].iter_mut() {
                            *x = v;
                        }
                    }
                }
            }
        }
        let wall = t0.elapsed();
        println!(
            "served {} of {requests} mixed requests x {batch} ops ({total_updates} updates, \
             {rejected} rejected) in {wall:.2?} ({:.0} ops/s, fenced, spot-checked)",
            requests - rejected,
            (requests * batch) as f64 / wall.as_secs_f64()
        );
    } else {
        for i in 0..requests {
            let dist = [RangeDist::Small, RangeDist::Medium, RangeDist::Large][i % 3];
            let qs = gen_queries(n, batch, dist, &mut rng);
            let ops = qs.into_iter().map(Op::Query).collect();
            if c.submit_mixed_deadline(ops, deadline).is_err() {
                rejected += 1;
            }
        }
        let wall = t0.elapsed();
        println!(
            "served {} of {requests} requests x {batch} queries ({rejected} rejected) \
             in {wall:.2?} ({:.0} queries/s)",
            requests - rejected,
            (requests * batch) as f64 / wall.as_secs_f64()
        );
    }
    if quiet_tail > 0 {
        // Quiet period: pure-query requests that let the observer's
        // decayed update rate fall below the rebuild threshold, so the
        // background builder can refresh the static engines. Under a
        // --shift-dist run the tail keeps the shifted distribution, so
        // the workload-fed tuner sees the drift it should re-shard for.
        let tail_dist = shift_dist.unwrap_or(dist);
        let mut tail_served = 0usize;
        for _ in 0..quiet_tail {
            let qs = gen_queries(n, batch, tail_dist, &mut rng);
            // An injected hand-off fault can still reject a tail
            // request whole; only accepted answers are oracle-checked.
            let resp = match c.query(qs.clone()) {
                Ok(resp) => resp,
                Err(_) => continue,
            };
            tail_served += 1;
            for (k, &(l, r)) in qs.iter().take(2).enumerate() {
                assert_eq!(
                    resp.answers[k],
                    naive_rmq(&oracle, l as usize, r as usize) as u32,
                    "({l},{r}) via {}",
                    resp.engine
                );
            }
        }
        println!("quiet tail: {tail_served} of {quiet_tail} pure-query requests served");
    }
    // The lifecycle claims happen on the serving thread; the builds may
    // still be in flight on the builder — give each expectation a grace
    // window to land before failing the run.
    let expect = |flag: &str, what: &str, count: &dyn Fn() -> u64| -> bool {
        if !args.flag(flag) {
            return true;
        }
        let t1 = std::time::Instant::now();
        while count() == 0 && t1.elapsed() < std::time::Duration::from_secs(5) {
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
        if count() == 0 {
            eprintln!("--{flag}: no background {what} occurred");
            return false;
        }
        true
    };
    let ok = expect("expect-rebuild", "rebuild", &|| c.metrics.lock().rebuilds)
        && expect("expect-reshard", "re-shard", &|| c.metrics.lock().reshards);
    // Fold recoveries that landed after the last batch (e.g. a builder
    // respawn during the grace window) into the printed snapshot.
    c.sync_faults();
    println!("{}", c.metrics.lock());
    let mut summary = c.metrics.lock().summary_json();
    // The manifest records the A/B traversal knob so a packet run and
    // its scalar twin stay distinguishable after the fact.
    if let Json::Obj(m) = &mut summary {
        m.insert("packet_width".into(), Json::Num(packet_width as f64));
    }
    c.shutdown();
    faults::disarm();
    let code = if ok { 0 } else { 1 };
    finish_manifest(manifest_path.as_deref(), run_id.as_deref(), summary, &[], code)
}

/// Seal and write the run manifest when `--manifest` was given; no-op
/// otherwise. The recorded exit code is the run's own; a failed
/// artifact hash or manifest write turns a passing run into a failure —
/// the contract is machine-checkable or loudly absent, never silently
/// wrong.
fn finish_manifest(
    path: Option<&str>,
    run_id: Option<&str>,
    metrics: Json,
    artifacts: &[&str],
    code: i32,
) -> i32 {
    let (Some(path), Some(run_id)) = (path, run_id) else {
        return code;
    };
    let mut b = ManifestBuilder::new(run_id);
    let argv: Vec<String> = std::env::args().collect();
    b.command(&argv, code);
    b.metrics(metrics);
    for a in artifacts {
        if let Err(e) = b.artifact(Path::new(a)) {
            eprintln!("manifest: failed to hash artifact {a}: {e}");
            return if code == 0 { 1 } else { code };
        }
    }
    match b.write(Path::new(path)) {
        Ok(_) => {
            println!("wrote manifest {path} (run {run_id})");
            code
        }
        Err(e) => {
            eprintln!("failed to write manifest {path}: {e}");
            if code == 0 {
                1
            } else {
                code
            }
        }
    }
}

/// Per-tenant driver tally; the grep-stable `tenant-summary` line the
/// nightly soak asserts against is printed from these counters (the
/// *client's* view — admission rejections classified by type), while
/// the metrics block above it carries the server's view.
#[derive(Clone, Copy, Default)]
struct TenantOutcome {
    submitted: u64,
    served: u64,
    shed: u64,
    expired: u64,
    failed: u64,
    updates: u64,
}

impl TenantOutcome {
    fn note_err(&mut self, e: &anyhow::Error) {
        match e.downcast_ref::<ServeError>() {
            Some(ServeError::Overloaded) => self.shed += 1,
            Some(ServeError::DeadlineExceeded) => self.expired += 1,
            _ => self.failed += 1,
        }
    }
}

/// Spot-check an accepted response against the rolling oracle and apply
/// its updates. Replies are processed in submission order (per-tenant
/// FIFO holds across the multi-tenant executor), so the oracle is exact
/// for every accepted request no matter how tenants interleave.
fn check_response(
    name: &str,
    ops: &[Op],
    resp: &Response,
    oracle: &mut [f32],
    out: &mut TenantOutcome,
) {
    out.served += 1;
    out.updates += resp.updates_applied as u64;
    let mut checked = 0;
    let mut k = 0;
    for op in ops {
        match *op {
            Op::Query((l, r)) => {
                if checked < 4 {
                    let want = naive_rmq(oracle, l as usize, r as usize) as u32;
                    assert_eq!(
                        resp.answers[k], want,
                        "tenant {name}: ({l},{r}) via {}",
                        resp.engine
                    );
                    checked += 1;
                }
                k += 1;
            }
            Op::Update { i, v } => oracle[i as usize] = v,
            Op::RangeAdd { l, r, v } => {
                for x in oracle[l as usize..=r as usize].iter_mut() {
                    *x += v;
                }
            }
            Op::RangeAssign { l, r, v } => {
                for x in oracle[l as usize..=r as usize].iter_mut() {
                    *x = v;
                }
            }
        }
    }
}

/// One tenant's synthetic client: depth-K pipelined submission against
/// its own rolling oracle, then a quiet pure-query tail (the lifecycle
/// trigger window). A rejected request executed none of its ops, so the
/// oracle skips it; the injectable `tenant.exec` site panics *before*
/// any segment executes, so a Failed batch also leaves the oracle
/// exact.
fn drive_tenant(
    mc: &MultiCoordinator,
    spec: &TenantSpec,
    idx: usize,
    requests_default: usize,
    batch_default: usize,
) -> TenantOutcome {
    let name = spec.load.name.as_str();
    let n = spec.load.n;
    let requests = spec.requests.unwrap_or(requests_default);
    let batch = spec.batch.unwrap_or(batch_default);
    let mut rng = Rng::new(11 + idx as u64);
    let mut oracle = gen_array(n, 7 + idx as u64);
    let mut out = TenantOutcome::default();
    let mut inflight: VecDeque<(Vec<Op>, Receiver<Reply>)> = VecDeque::new();
    let mut drain_one = |inflight: &mut VecDeque<(Vec<Op>, Receiver<Reply>)>,
                         oracle: &mut Vec<f32>,
                         out: &mut TenantOutcome| {
        let Some((ops, rx)) = inflight.pop_front() else {
            return;
        };
        match rx.recv() {
            Ok(Ok(resp)) => check_response(name, &ops, &resp, oracle, out),
            Ok(Err(ServeError::Overloaded)) => out.shed += 1,
            Ok(Err(ServeError::DeadlineExceeded)) => out.expired += 1,
            Ok(Err(ServeError::Failed)) | Err(_) => out.failed += 1,
        }
    };
    for r in 0..requests {
        let progress = r as f64 / requests.max(1) as f64;
        let ops = spec.load.gen_request(batch, progress, &mut rng);
        out.submitted += 1;
        if spec.depth <= 1 {
            match mc.submit(name, ops.clone(), None) {
                Ok(resp) => check_response(name, &ops, &resp, &mut oracle, &mut out),
                Err(e) => out.note_err(&e),
            }
        } else {
            match mc.submit_async(name, ops.clone(), None) {
                Ok(rx) => {
                    inflight.push_back((ops, rx));
                    if inflight.len() >= spec.depth {
                        drain_one(&mut inflight, &mut oracle, &mut out);
                    }
                }
                Err(e) => out.note_err(&e),
            }
        }
    }
    while !inflight.is_empty() {
        drain_one(&mut inflight, &mut oracle, &mut out);
    }
    for _ in 0..spec.tail {
        let qs = gen_queries(n, batch, spec.load.dist_at(1.0), &mut rng);
        let ops: Vec<Op> = qs.into_iter().map(Op::Query).collect();
        out.submitted += 1;
        match mc.submit(name, ops.clone(), None) {
            Ok(resp) => check_response(name, &ops, &resp, &mut oracle, &mut out),
            Err(e) => out.note_err(&e),
        }
    }
    out
}

fn cmd_serve_multi(args: &Args) -> i32 {
    let specs: Vec<TenantSpec> = match args.opt("tenant-specs") {
        Some(s) => match TenantSpec::parse_list(s) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("invalid --tenant-specs: {e}");
                return 2;
            }
        },
        None => {
            let count: usize = args.get_or("tenants", 2usize).unwrap();
            if count == 0 {
                eprintln!("--tenants must be >= 1");
                return 2;
            }
            (0..count).map(|i| TenantSpec::default_named(&format!("t{i}"))).collect()
        }
    };
    let rebuild = RebuildMode::parse(&args.str_or("rebuild", "auto")).unwrap_or_else(|| {
        eprintln!("invalid --rebuild (expected auto|off)");
        std::process::exit(2);
    });
    let reshard_drift: f64 = args.get_or("reshard-drift", 2.0f64).unwrap();
    let inject_seed: u64 = args.get_or("inject-seed", 42u64).unwrap();
    if let Some(spec) = args.opt("inject") {
        match FaultPlan::parse(spec, inject_seed) {
            Ok(plan) => faults::arm(plan),
            Err(e) => {
                eprintln!("invalid --inject: {e}");
                return 2;
            }
        }
    }
    let requests_default: usize = args.get_or("requests", 96usize).unwrap();
    let batch_default: usize = args.get_or("batch", 1024usize).unwrap();
    let shed_watermark: usize =
        args.get_or("shed-watermark", BatcherCfg::default().shed_watermark).unwrap();
    let deadline_ms: u64 = args.get_or("deadline-ms", 0u64).unwrap();
    let global_watermark: usize = args.get_or("global-watermark", 1024usize).unwrap();
    let exec_workers: usize = args.get_or("exec-workers", 2usize).unwrap();
    let packet_width: usize = args.get_or("packet-width", 0usize).unwrap();
    let no_sort_queries = args.flag("no-sort-queries");
    let manifest_path = args.opt("manifest").map(str::to_string);
    let run_id = manifest_path.as_ref().map(|_| manifest::gen_run_id());
    let runtime = if args.flag("no-xla") {
        None
    } else {
        Runtime::load(Path::new("artifacts")).ok().map(Arc::new)
    };
    let arrays: Vec<(TenantCfg, Vec<f32>)> = specs
        .iter()
        .enumerate()
        .map(|(i, spec)| {
            let mut tc = TenantCfg::named(&spec.load.name);
            tc.engines = EngineCfg {
                shard_block: shard_block_arg(args, spec.load.dist, spec.load.update_frac),
                packet_width,
                no_sort_queries,
            };
            tc.lifecycle = LifecycleCfg { rebuild, reshard_drift, ..Default::default() };
            tc.weight = spec.weight;
            tc.shed_watermark = spec.watermark.unwrap_or(shed_watermark);
            let dms = spec.deadline_ms.unwrap_or(deadline_ms);
            tc.deadline = (dms > 0).then(|| Duration::from_millis(dms));
            (tc, gen_array(spec.load.n, 7 + i as u64))
        })
        .collect();
    let mc = MultiCoordinator::start(
        arrays,
        runtime,
        MultiCfg {
            exec_workers,
            engine_workers: rtxrmq::util::pool::default_workers(),
            global_watermark,
        },
    );
    if let Some(id) = &run_id {
        for spec in &specs {
            let m = mc.metrics(&spec.load.name).expect("registered");
            m.lock().set_labels(Some(id.clone()), Some(spec.load.name.clone()));
        }
    }
    let t0 = Instant::now();
    // One client thread per tenant; an oracle-mismatch assert panics
    // the thread, which the join below converts into a failed run.
    let outcomes: Vec<Option<TenantOutcome>> = std::thread::scope(|s| {
        let handles: Vec<_> = specs
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let mc = &mc;
                s.spawn(move || drive_tenant(mc, spec, i, requests_default, batch_default))
            })
            .collect();
        handles.into_iter().map(|h| h.join().ok()).collect()
    });
    let wall = t0.elapsed();
    let oracles_ok = outcomes.iter().all(Option::is_some);
    if !oracles_ok {
        eprintln!("serve: a tenant client failed its oracle check");
    }
    // Lifecycle expectations hold if *any* tenant did the work; builds
    // may still be in flight on the shared pool — grace-poll like the
    // single-array path.
    let expect = |flag: &str, what: &str, count: &dyn Fn() -> u64| -> bool {
        if !args.flag(flag) {
            return true;
        }
        let t1 = Instant::now();
        while count() == 0 && t1.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(50));
        }
        if count() == 0 {
            eprintln!("--{flag}: no background {what} occurred in any tenant");
            return false;
        }
        true
    };
    let sum_over = |f: &dyn Fn(&Arc<rtxrmq::coordinator::engine::EpochState>) -> u64| -> u64 {
        specs.iter().map(|s| f(&mc.lifecycle(&s.load.name).expect("registered"))).sum()
    };
    let ok = oracles_ok
        && expect("expect-rebuild", "rebuild", &|| sum_over(&|lc| lc.rebuilds()))
        && expect("expect-reshard", "re-shard", &|| sum_over(&|lc| lc.reshards()));
    mc.sync_faults();
    let mut total_submitted = 0u64;
    let mut total_served = 0u64;
    let mut metrics_doc = std::collections::BTreeMap::new();
    for (spec, out) in specs.iter().zip(&outcomes) {
        let name = spec.load.name.as_str();
        let out = out.unwrap_or_default();
        println!("{}", mc.metrics(name).expect("registered").lock());
        let lc = mc.lifecycle(name).expect("registered");
        println!(
            "tenant-summary name={name} submitted={} served={} shed={} expired={} failed={} \
             updates={} epoch={} rebuilds={} reshards={}",
            out.submitted,
            out.served,
            out.shed,
            out.expired,
            out.failed,
            out.updates,
            lc.epoch_version(),
            lc.rebuilds(),
            lc.reshards()
        );
        total_submitted += out.submitted;
        total_served += out.served;
        metrics_doc.insert(
            name.to_string(),
            mc.metrics(name).expect("registered").lock().summary_json(),
        );
    }
    println!(
        "served {total_served} of {total_submitted} requests across {} tenants in {wall:.2?}",
        specs.len()
    );
    mc.shutdown();
    faults::disarm();
    // Shared A/B knob alongside the per-tenant metric objects; tenant
    // names never collide with it (TenantSpec names are identifiers).
    metrics_doc.insert("packet_width".to_string(), Json::Num(packet_width as f64));
    let code = if ok { 0 } else { 1 };
    finish_manifest(
        manifest_path.as_deref(),
        run_id.as_deref(),
        Json::Obj(metrics_doc),
        &[],
        code,
    )
}

fn cmd_manifest_check(args: &Args) -> i32 {
    let path = match args.opt("path") {
        Some(p) => p.to_string(),
        None => {
            eprintln!("manifest-check: --path is required");
            return 2;
        }
    };
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("manifest-check: {path}: {e}");
            return 2;
        }
    };
    let doc = match Json::parse(text.trim()) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("manifest-check: {path}: parse error: {e}");
            return 2;
        }
    };
    // Artifact paths are resolved relative to the manifest's directory,
    // so a manifest checked from a CI artifact bundle still re-hashes
    // the files that travelled with it.
    let base = Path::new(&path).parent().map(|p| p.to_path_buf()).unwrap_or_default();
    match manifest::validate(&doc, &base) {
        Ok(()) => {
            let run = doc.get("run_id").and_then(|j| j.as_str()).unwrap_or("?");
            let arts = doc.get("artifacts").and_then(|j| j.as_arr()).map(|a| a.len()).unwrap_or(0);
            println!("manifest-check: PASS {path} (run {run}, {arts} artifact(s) re-hashed)");
            0
        }
        Err(errs) => {
            for e in &errs {
                eprintln!("manifest-check: {path}: {e}");
            }
            eprintln!("manifest-check: FAIL {path} ({} error(s))", errs.len());
            1
        }
    }
}

fn cmd_bench_smoke(args: &Args) -> i32 {
    use rtxrmq::bench_harness::smoke::{
        append_summary_md, run_smoke, speedups, summary_md, to_json, write_json, SmokeCfg,
    };
    let defaults = SmokeCfg::default();
    let update_frac: f64 = args.get_or("update-frac", defaults.update_frac).unwrap();
    let range_frac: f64 = args.get_or("range-frac", defaults.range_frac).unwrap();
    let dist = RangeDist::parse(&args.str_or("dist", "small")).unwrap_or(RangeDist::Small);
    let cfg = SmokeCfg {
        ns: args.list_or("ns", &defaults.ns).unwrap(),
        batches: args.list_or("batches", &defaults.batches).unwrap(),
        workers: rtxrmq::util::pool::default_workers(),
        seed: args.get_or("seed", defaults.seed).unwrap(),
        shard_block: shard_block_arg(args, dist, update_frac),
        update_frac,
        range_frac,
        packet_width: args.get_or("packet-width", defaults.packet_width).unwrap(),
    };
    let out = args.str_or("out", "BENCH_rmq.json");
    let points = run_smoke(&cfg);
    let mut rows = Vec::new();
    for p in &points {
        rows.push(vec![
            p.layout.to_string(),
            p.n.to_string(),
            p.batch.to_string(),
            format!("{:.1}", p.ns_per_query),
            if p.upd_ns_per_op > 0.0 { format!("{:.1}", p.upd_ns_per_op) } else { "-".into() },
            if p.range_ns_per_op > 0.0 { format!("{:.1}", p.range_ns_per_op) } else { "-".into() },
            format!("{:.2}", p.build_ms),
            fmt_mb(p.resident_bytes as u64),
            p.counters.nodes_visited.to_string(),
            format!("{:.1}", p.node_fetches_per_query()),
            p.counters.tri_tests.to_string(),
        ]);
    }
    rtxrmq::bench_harness::print_table(
        "RTXRMQ solver smoke grid (local wall clock)",
        &[
            "layout",
            "n",
            "batch",
            "ns/query",
            "ns/update",
            "ns/range",
            "build_ms",
            "resident",
            "nodes_visited",
            "fetches/q",
            "tri_tests",
        ],
        &rows,
    );
    for (n, batch, label, binary_ns, ns, speedup) in speedups(&points) {
        println!(
            "n={n} batch={batch}: binary {binary_ns:.1} ns/q, {label} {ns:.1} ns/q -> {speedup:.2}x"
        );
    }
    if let Some(md_path) = args.opt("summary-md") {
        if let Err(e) = append_summary_md(std::path::Path::new(md_path), &summary_md(&cfg, &points))
        {
            eprintln!("failed to append summary to {md_path}: {e}");
        }
    }
    let code = match write_json(std::path::Path::new(&out), &to_json(&cfg, &points)) {
        Ok(()) => {
            println!("wrote {out}");
            0
        }
        Err(e) => {
            eprintln!("failed to write {out}: {e}");
            1
        }
    };
    let manifest_path = args.opt("manifest");
    let run_id = manifest_path.map(|_| manifest::gen_run_id());
    // The bench JSON is the manifest's artifact: CI re-hashes it, so a
    // baseline swapped after the gate ran can no longer pass silently.
    let artifacts: &[&str] = if code == 0 { &[&out] } else { &[] };
    let mut metrics = std::collections::BTreeMap::new();
    metrics.insert("packet_width".to_string(), Json::Num(cfg.packet_width as f64));
    finish_manifest(manifest_path, run_id.as_deref(), Json::Obj(metrics), artifacts, code)
}

fn cmd_bench_compare(args: &Args) -> i32 {
    use rtxrmq::bench_harness::compare::{compare, summary_md};
    use rtxrmq::bench_harness::smoke::append_summary_md;
    use rtxrmq::util::json::Json;
    let baseline_path = match args.opt("baseline") {
        Some(p) => p.to_string(),
        None => {
            eprintln!("bench-compare: --baseline is required");
            return 2;
        }
    };
    let current_path = args.str_or("current", "BENCH_rmq.json");
    let max_regress: f64 = args.get_or("max-regress", 0.25f64).unwrap();
    let load = |path: &str| -> Result<Json, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Json::parse(text.trim()).map_err(|e| format!("{path}: {e}"))
    };
    let (baseline, current) = match (load(&baseline_path), load(&current_path)) {
        (Ok(b), Ok(c)) => (b, c),
        (b, c) => {
            for r in [b.err(), c.err()].into_iter().flatten() {
                eprintln!("bench-compare: {r}");
            }
            return 2;
        }
    };
    let report = match compare(&baseline, &current, max_regress) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("bench-compare: {e}");
            return 2;
        }
    };
    let rows: Vec<Vec<String>> = report
        .rows
        .iter()
        .map(|r| {
            vec![
                r.layout.clone(),
                r.n.to_string(),
                r.batch.to_string(),
                r.metric.to_string(),
                format!("{:.1}", r.baseline),
                format!("{:.1}", r.current),
                format!("{:+.1}%", r.delta * 100.0),
                if r.regressed { "REGRESSED".into() } else { String::new() },
            ]
        })
        .collect();
    rtxrmq::bench_harness::print_table(
        &format!("bench-gate vs {baseline_path} (tolerance +{:.0}%)", max_regress * 100.0),
        &["solver", "n", "batch", "metric", "baseline", "current", "delta", ""],
        &rows,
    );
    for m in &report.missing {
        eprintln!("bench-compare: baseline point missing from current run: {m}");
    }
    if let Some(md_path) = args.opt("summary-md") {
        if let Err(e) = append_summary_md(std::path::Path::new(md_path), &summary_md(&report)) {
            eprintln!("failed to append summary to {md_path}: {e}");
        }
    }
    // Provenance escalation: a modeled bootstrap baseline keeps the
    // gate report-only; the moment a measured baseline is committed the
    // gate arms itself — no workflow edit required.
    if report.bootstrap_baseline {
        println!(
            "bench-gate: provenance={} — REPORT-ONLY (baseline is the modeled bootstrap \
             placeholder; commit a measured BENCH_rmq.json over {baseline_path} to arm it)",
            report.baseline_provenance
        );
    } else {
        println!(
            "bench-gate: provenance={} — ENFORCING (>{:.0}% regressions fail the build)",
            report.baseline_provenance,
            max_regress * 100.0
        );
    }
    let code = if report.failed() {
        eprintln!(
            "bench-compare: {} regression(s), {} missing point(s) beyond +{:.0}% tolerance",
            report.regressions().len(),
            report.missing.len(),
            max_regress * 100.0
        );
        1
    } else {
        println!("bench-gate: PASS ({} metrics compared)", report.rows.len());
        0
    };
    let manifest_path = args.opt("manifest");
    let run_id = manifest_path.map(|_| manifest::gen_run_id());
    // Both gate inputs are recorded: the manifest pins exactly which
    // baseline and which fresh run produced this verdict.
    finish_manifest(
        manifest_path,
        run_id.as_deref(),
        Json::Obj(Default::default()),
        &[&baseline_path, &current_path],
        code,
    )
}

fn cmd_memory(args: &Args) -> i32 {
    let n: usize = args.get_or("n", 1usize << 16).unwrap();
    let xs = gen_array(n, 7);
    let engines = EngineSet::build(&xs, None);
    println!("data-structure memory at n = {n} (input {}):", fmt_mb((n * 4) as u64));
    for kind in [
        EngineKind::Rtx,
        EngineKind::Sharded,
        EngineKind::Lca,
        EngineKind::Hrmq,
        EngineKind::Exhaustive,
    ] {
        let e = engines.get(kind).unwrap();
        println!("  {:<11} {}", kind.name(), fmt_mb(e.memory_bytes() as u64));
    }
    0
}

fn cmd_artifacts(args: &Args) -> i32 {
    let dir = args.str_or("dir", "artifacts");
    match Runtime::load(Path::new(&dir)) {
        Ok(rt) => {
            println!("PJRT artifacts in {dir}:");
            for v in rt.variants() {
                println!("  {:<28} kind={:?} n={} q={} bs={}", v.name, v.kind, v.n, v.q, v.bs);
            }
            0
        }
        Err(e) => {
            eprintln!("failed to load artifacts: {e}");
            1
        }
    }
}

fn cmd_info() -> i32 {
    println!("GPU architecture profiles (models' inputs):");
    for p in rtxrmq::rtcore::arch::generations()
        .into_iter()
        .chain(rtxrmq::rtcore::arch::lovelace_skus())
    {
        println!(
            "  {:<26} SMs={:<4} clock={:.2} GHz RTgen={:.0}x TDP={:.0} W L2={:.0} MiB",
            p.name, p.sm_count, p.clock_ghz, p.rt_gen_factor, p.tdp_w, p.l2_mib
        );
    }
    let cpu = rtxrmq::rtcore::arch::EPYC_9654_X2;
    println!("  {:<26} cores={} TDP={:.0} W", cpu.name, cpu.cores, cpu.tdp_w);
    0
}

//! Work→time models for the four approaches.
//!
//! Calibration discipline (see `model` docs): each model has exactly one
//! scale constant, fixed against one Fig. 12 endpoint (n = 1e8, q = 2^26,
//! large (l,r) ranges: RTXRMQ ≈ 5 ns/RMQ, LCA ≈ 1 ns/RMQ, HRMQ ≈ 12.5
//! ns/RMQ on 192 cores, EXHAUSTIVE ~1e6 ns/RMQ). All n-, range-, batch-
//! and architecture-dependence comes from measured work, the cache model
//! and the public arch parameters.

use super::cache::CacheModel;
use crate::bvh::traverse::Counters;
use crate::rtcore::arch::{self, ArchProfile, CpuProfile};
use crate::workload::observer::ObservedWorkload;

/// Saturation of a parallel machine by batch size: throughput fraction
/// `batch / (batch + half_sat)`. Fig. 13's shapes: LCA/HRMQ/EXHAUSTIVE
/// saturate near 2^17–2^18 (half_sat ≈ 2^14); RTXRMQ keeps scaling past
/// 2^26 (half_sat ≈ 2^21, so even 2^26 is only ~97% saturated).
pub fn saturation(batch: u64, half_sat: f64) -> f64 {
    let b = batch.max(1) as f64;
    b / (b + half_sat)
}

// ------------------------------------------------------------ RTXRMQ --

/// RT-core model: converts BVH traversal counters into modeled time.
///
/// Counter semantics across acceleration layouts (see the "BVH layouts"
/// docs on `crate::bvh`): `nodes_visited` counts node pops in either
/// layout — a 4-wide pop replaces roughly three binary pops;
/// `aabb_tests` counts per-child box tests (2 per binary internal node,
/// exactly 4 per wide node). Weighing both terms (`c_node` for the
/// pop/dispatch cost, `c_aabb` for each box test) keeps modeled times
/// comparable between layouts: the wide layout trades more box tests
/// per pop for far fewer pops, which is exactly the trade RT hardware
/// makes.
///
/// Packet amortisation: `node_fetches` counts *memory* fetches of node
/// records — one per pop in scalar traversal (so it equals
/// `nodes_visited` there), but one per pop per **packet** in packetized
/// traversal, where P rays share each fetched node. The per-node charge
/// is split to mirror that: `c_node` prices the per-ray dispatch /
/// stack work that packets still pay once per member, `c_packet` the
/// node-record fetch they share. In scalar mode the two counters are
/// equal and the effective per-node weight is `c_node + c_packet`
/// (= 1.0 with defaults, so scalar modeled times are unchanged);
/// packetized counter sets with `node_fetches < nodes_visited` model
/// strictly cheaper, which is how the tuner sees the new cost shape.
#[derive(Clone, Copy, Debug)]
pub struct RtCostModel {
    /// Work units per BVH node visit / per-child AABB test / triangle
    /// test / ray launch.
    pub c_node: f64,
    pub c_aabb: f64,
    pub c_tri: f64,
    pub c_ray: f64,
    /// Work units per node-record *fetch* (`Counters::node_fetches`) —
    /// the part of the per-node cost a ray packet amortises across its
    /// members. Defaults keep `c_node + c_packet` equal to the old
    /// per-node unit weight, so every scalar-shaped counter set
    /// (`node_fetches == nodes_visited`) models exactly as before.
    pub c_packet: f64,
    /// ns per work unit *per query* on the reference GPU (RTX 6000 Ada),
    /// at full saturation. Single-point calibration against the Fig. 12
    /// endpoint (n = 1e8, q = 2^26, large ranges, ≈ 5 ns/RMQ): the
    /// measured block-matrix traversal there does ≈ 150 node pops, ≈ 300
    /// per-child box tests, ≈ 25 triangle tests and ≈ 3 rays per query,
    /// i.e. 150·c_node + 300·c_aabb + 25·c_tri + 3·c_ray ≈ 305 work
    /// units, and 5 ns = 305 · nsu / saturation(2^26, half_sat) gives
    /// nsu ≈ 0.0159.
    ///
    /// Recalibration procedure (repeat whenever a work term or weight
    /// changes): run `cargo bench --bench fig12_time_speedup`, read the
    /// measured work/query `W` at the reference point, and set
    /// `nsu = 5.0 × saturation(2^26, half_sat) / W`. The previous value
    /// (0.022) predated the `c_aabb` term — with box tests now counted
    /// the old constant overstated modeled GPU times by ~30%.
    pub ns_per_unit_ref: f64,
    /// Batch half-saturation (Fig. 13: RTXRMQ unsaturated at 2^26).
    pub half_sat: f64,
    /// Fixed per-launch overhead in ns (amortised over the batch).
    pub launch_overhead_ns: f64,
    /// Modeled work units per element for rebuilding the full static
    /// engine set from a snapshot (`rebuild_cost`). The builds are
    /// linear streaming passes (Cartesian tree + SV arrays for LCA, a
    /// SAH sweep over n triangles for RTXRMQ, succinct tables for HRMQ)
    /// that run on the *background* builder thread without stalling the
    /// serving loop, so the charge is the throughput they steal from
    /// query workers — a small per-element constant, not a latency.
    pub c_rebuild_per_elem: f64,
    /// Instancing discount on shard update-side work. With the
    /// instanced block backend (`rmq::sharded::ShardBackend::Instanced`,
    /// the default), a point update is a compressed leaf-table write
    /// plus a lane-min walk over shared shape nodes, and a *staged
    /// replacement block* is an O(B) quantize pass against the shared
    /// shape tree — not a tree build. Staging-lane cost is therefore
    /// charged as `c_inst ×` the refit-shaped work terms instead of
    /// full build work, closing the ROADMAP carry-over ("staging-lane
    /// cost is charged as build-not-refit until instancing lands").
    /// The factor scales **all** of
    /// [`shard_update_work`](Self::shard_update_work) uniformly, so
    /// pure-update block-size tuning argmins are unchanged (√n stays
    /// optimal); mixed workloads correctly lean further toward
    /// query-optimal blocks. ≈ 0.35: the quantize + min-maintenance
    /// pass touches ~1/3 the bytes of a bounds refit over 24-byte
    /// `WidePrim` leaves.
    pub c_inst: f64,
}

impl Default for RtCostModel {
    fn default() -> Self {
        RtCostModel {
            c_node: 0.55,
            c_aabb: 0.25,
            c_tri: 2.0,
            c_ray: 10.0,
            c_packet: 0.45,
            ns_per_unit_ref: 0.0159,
            half_sat: (1u64 << 21) as f64,
            launch_overhead_ns: 15_000.0,
            c_rebuild_per_elem: 0.01,
            c_inst: 0.35,
        }
    }
}

impl RtCostModel {
    /// Work units per query from measured counters. The per-node charge
    /// is split between pops (`c_node × nodes_visited`) and node-record
    /// fetches (`c_packet × node_fetches`): scalar traversal pays both
    /// per pop, packetized traversal shares the fetch half across the
    /// packet (see the struct docs).
    pub fn work_per_query(&self, c: &Counters, queries: u64) -> f64 {
        let w = c.nodes_visited as f64 * self.c_node
            + c.node_fetches as f64 * self.c_packet
            + c.aabb_tests as f64 * self.c_aabb
            + c.tri_tests as f64 * self.c_tri
            + c.rays as f64 * self.c_ray;
        w / queries.max(1) as f64
    }

    /// Modeled ns per query on `gpu` for a batch of `queries`.
    pub fn ns_per_query(&self, c: &Counters, queries: u64, gpu: &ArchProfile) -> f64 {
        let ref_gpu = arch::LOVELACE_RTX6000ADA;
        let scale = arch::rt_throughput(&ref_gpu) / arch::rt_throughput(gpu);
        let util = saturation(queries, self.half_sat);
        self.work_per_query(c, queries) * self.ns_per_unit_ref * scale / util
            + self.launch_overhead_ns / queries.max(1) as f64
    }

    /// Modeled work units for one small-range probe against a BVH over
    /// `k` elements: one ray descending ~log2 k wide nodes (4 per-child
    /// box tests each) down to a couple of candidate triangles. This is
    /// exactly the shape of a partial-block or summary probe of the
    /// sharded engine — small-range by construction.
    pub fn probe_work(&self, k: f64) -> f64 {
        let depth = k.max(2.0).log2().ceil() + 1.0;
        // A scalar probe fetches every node it pops, so it pays the full
        // per-node weight c_node + c_packet per level.
        self.c_ray + depth * (self.c_node + self.c_packet + 4.0 * self.c_aabb) + 2.0 * self.c_tri
    }

    /// Modeled work of a leaf-to-root **path refit** in a BVH over `k`
    /// elements: re-shape one triangle, then recompute ~log2 k node
    /// bounds (4 lanes of box mins each) up the ancestor chain. This is
    /// the `refit_prims` route single-update blocks and single-minimum
    /// summary changes take (`rmq::sharded`), as opposed to the full
    /// Θ(k) refit-and-rescan sweep.
    pub fn path_refit_work(&self, k: f64) -> f64 {
        let depth = k.max(2.0).log2().ceil() + 1.0;
        self.c_tri + depth * (self.c_node + self.c_packet + 4.0 * self.c_aabb)
    }

    /// Update-side work **per point** at block size `bs` when update
    /// segments carry `points` updates each. Distinguishes the batch
    /// shapes the write path special-cases:
    ///
    /// - `points == 0` (shape unknown): the conservative dense charge
    ///   `B + n/B` — a full block refit + rescan plus a full summary
    ///   refit per point, the pre-observation prior.
    /// - `points ≤ n/B` (sparse batch, mostly *single-update blocks*):
    ///   each touched block takes the path-refit route — Θ(log B)
    ///   instead of Θ(B), with the O(1) min maintenance skipping the
    ///   rescan.
    /// - larger batches: full per-block refits, amortised over the
    ///   points sharing each block.
    ///
    /// The summary term is the single-minimum point refit (Θ(log n/B))
    /// when at most one block is touched, the full Θ(n/B) sweep
    /// otherwise — both amortised over the batch.
    ///
    /// Every branch is scaled by the uniform instancing discount
    /// [`c_inst`](Self::c_inst): with the instanced default backend the
    /// dense charge is an O(B) value-table rewrite (not a tree build)
    /// and the sparse charge a leaf-table write + lane-min walk, so the
    /// staging lane's replacement-block work is priced as refit-shaped,
    /// not build-shaped.
    pub fn shard_update_work(&self, n: usize, bs: usize, points: f64) -> f64 {
        let b = (bs.max(1)) as f64;
        let nb = ((n.max(1)) as f64 / b).max(1.0);
        if points <= 0.0 {
            return self.c_inst * (b + nb);
        }
        let k = points.max(1.0);
        let touched = k.min(nb);
        let per_block = if k <= nb { self.path_refit_work(b) } else { b };
        let summary = if touched <= 1.0 { self.path_refit_work(nb) } else { nb };
        self.c_inst * (touched * per_block + summary) / k
    }

    /// Modeled work of one lazy range update (`add`/`assign`) over a
    /// span of `range_len` elements at block size `bs` ("Lazy range
    /// tags", `rmq/mod.rs`). Fully-covered blocks absorb the op as a
    /// per-block tag — an instanced `v_lo` shift or constant-block
    /// collapse, one bound write each, charged `c_aabb` — while the ≤2
    /// partial boundary blocks pay a full Θ(B) value refit. The summary
    /// refit is the single-minimum path route (Θ(log n/B)) when only
    /// boundary blocks can move, the full Θ(n/B) sweep once covered
    /// blocks shift too. Everything carries the same
    /// [`c_inst`](Self::c_inst) discount as the point write path: tags
    /// are leaf-table bound rewrites, never tree builds.
    pub fn range_update_work(&self, n: usize, bs: usize, range_len: f64) -> f64 {
        let b = (bs.max(1)) as f64;
        let nb = ((n.max(1)) as f64 / b).max(1.0);
        let m = range_len.max(1.0).min(n.max(1) as f64);
        let span = (1.0 + (m - 1.0) / b).min(nb);
        let boundary = span.min(2.0);
        let covered = (span - boundary).max(0.0);
        let summary =
            if covered > 0.0 { nb } else { self.path_refit_work(nb) };
        self.c_inst * (covered * self.c_aabb + boundary * b + summary)
    }

    /// Modeled work units per op of the two-level sharded engine at
    /// block size `bs` under workload `w` (array length `n`).
    ///
    /// Query side: a query of mean length `m` spans `s = 1 + (m−1)/B`
    /// blocks in expectation, costing `min(s, 2)` partial-block probes
    /// over `B`-element BVHs plus — once the span passes two blocks — a
    /// summary probe over the `n/B`-element block-minima BVH.
    ///
    /// Update side: [`shard_update_work`](Self::shard_update_work) with
    /// an unknown batch shape — the conservative `B + n/B` charge the
    /// CLI priors imply. The observed tuner
    /// ([`tune_shard_block_observed`](Self::tune_shard_block_observed))
    /// sharpens it with the measured mean update-segment size.
    pub fn shard_cost_per_op(&self, n: usize, bs: usize, w: &ShardWorkload) -> f64 {
        let query = self.shard_query_work(n, bs, w.mean_range);
        let update = self.shard_update_work(n, bs, 0.0);
        let u = w.update_frac.clamp(0.0, 1.0);
        (1.0 - u) * query + u * update
    }

    /// The query side of [`shard_cost_per_op`](Self::shard_cost_per_op):
    /// modeled work of one query of length `range` through the two-level
    /// decomposition at block size `bs`.
    pub fn shard_query_work(&self, n: usize, bs: usize, range: f64) -> f64 {
        let nf = (n.max(1)) as f64;
        let b = (bs.max(1)) as f64;
        let nb = (nf / b).max(1.0);
        let m = range.max(1.0).min(nf);
        let span = 1.0 + (m - 1.0) / b;
        let partial_probes = span.min(2.0);
        let summary_prob = (span - 2.0).clamp(0.0, 1.0);
        partial_probes * self.probe_work(b) + summary_prob * self.probe_work(nb)
    }

    /// Pick the power-of-two shard block size minimising
    /// [`shard_cost_per_op`](Self::shard_cost_per_op). Candidates cover
    /// the same `[4, 2^12]` clamp as the √n default
    /// (`crate::rmq::sharded::auto_block_size`) and therefore always
    /// include the default itself, so the tuned choice can never model
    /// worse than √n.
    pub fn tune_shard_block(&self, n: usize, w: &ShardWorkload) -> usize {
        let cap = n.max(1).next_power_of_two().clamp(4, 1 << 12);
        let mut best = (f64::INFINITY, 4usize);
        let mut b = 4usize;
        loop {
            let cost = self.shard_cost_per_op(n, b, w);
            if cost < best.0 {
                best = (cost, b);
            }
            if b >= cap {
                break;
            }
            b <<= 1;
        }
        best.1
    }

    /// `--shard-block auto`, fed by live traffic: minimise the expected
    /// cost per op over the *observed* decayed range-length histogram
    /// (`workload::observer`) plus the observed update fraction's
    /// amortised refit work — the CLI's `--dist`/`--update-frac` priors
    /// only seed the initial build; once traffic flows, this is the
    /// tuner the lifecycle manager compares against the live block
    /// size. Integrating the histogram (geometric bucket centres)
    /// rather than collapsing it to a mean matters because the probe
    /// cascade's cost is non-linear in the range length (the summary
    /// probe only appears once a query spans more than two blocks).
    /// Falls back to the scalar tuner while the histogram is empty.
    pub fn tune_shard_block_observed(&self, n: usize, w: &ObservedWorkload) -> usize {
        let mass: f64 = w.range_hist.iter().sum();
        if mass <= 0.0 {
            return self.tune_shard_block(
                n,
                &ShardWorkload { mean_range: w.mean_range, update_frac: w.update_frac },
            );
        }
        let u = w.update_frac.clamp(0.0, 1.0);
        let cap = n.max(1).next_power_of_two().clamp(4, 1 << 12);
        let mut best = (f64::INFINITY, 4usize);
        let mut bs = 4usize;
        loop {
            let mut query = 0.0;
            for (k, &wk) in w.range_hist.iter().enumerate() {
                if wk > 0.0 {
                    // Bucket k holds lengths in [2^k, 2^{k+1}); integrate
                    // at the geometric centre.
                    query += wk * self.shard_query_work(n, bs, (1u64 << k) as f64 * 1.5);
                }
            }
            query /= mass;
            // The observed mean update-segment size sharpens the update
            // term: sparse segments path-refit single-update blocks,
            // only dense ones pay the full B + n/B sweep.
            let update = self.shard_update_work(n, bs, w.mean_update_batch);
            let cost = (1.0 - u) * query + u * update;
            if cost < best.0 {
                best = (cost, bs);
            }
            if bs >= cap {
                break;
            }
            bs <<= 1;
        }
        best.1
    }

    /// One-time modeled cost of rebuilding the full static engine set
    /// from an `n`-element snapshot (see
    /// [`c_rebuild_per_elem`](Self::c_rebuild_per_elem)).
    pub fn rebuild_cost(&self, n: usize) -> f64 {
        self.c_rebuild_per_elem * n as f64
    }

    /// Should the lifecycle rebuild the stale static engines now?
    ///
    /// The rebuilt statics serve queries until the next update batch
    /// makes them stale again: with observed per-op update fraction
    /// `u`, that is an expected `(1 − u)/u` query ops (geometric). Each
    /// such query saves roughly the sharded probe cascade at the live
    /// block size minus LCA's ~12 dependent reads — the routing freedom
    /// the rebuild buys back. Worthwhile once the expected saving
    /// covers [`rebuild_cost`](Self::rebuild_cost); a (decayed-to-)zero
    /// update rate is always worthwhile, since the epoch then stays
    /// fresh indefinitely. This is the "update rate dropped below a
    /// cost-model threshold" trigger: solving for `u` gives the
    /// threshold `u* = g / (g + c·n)` with per-query gain `g`.
    pub fn rebuild_worthwhile(&self, n: usize, live_block: usize, w: &ObservedWorkload) -> bool {
        let u = w.update_frac.clamp(0.0, 1.0);
        if u <= f64::EPSILON {
            return true;
        }
        let gain = (self.shard_query_work(n, live_block.max(1), w.mean_range) - 12.0).max(0.0);
        if gain <= 0.0 {
            return false;
        }
        let expected_queries = (1.0 - u) / u;
        expected_queries * gain >= self.rebuild_cost(n)
    }
}

/// Expected serving workload for shard-block auto-tuning
/// (`--shard-block auto`): what the queries look like and how often the
/// array mutates.
#[derive(Clone, Copy, Debug)]
pub struct ShardWorkload {
    /// Expected mean query range length (e.g. `RangeDist::mean_len`).
    pub mean_range: f64,
    /// Fraction of ops that are point updates (0 = read-only serving).
    pub update_frac: f64,
}

// --------------------------------------------------------------- LCA --

/// Schieber–Vishkin batch-LCA on CUDA cores. The per-query op count is
/// constant (the algorithm is O(1) inline — counted from our own
/// implementation: ~12 dependent word reads); the n-dependence enters
/// through the cache model on the structure's working set (Fig. 12's
/// staircase, Fig. 13's L2 dip).
#[derive(Clone, Copy, Debug)]
pub struct LcaCostModel {
    pub accesses_per_query: f64,
    /// ns per access-latency-unit on the reference GPU. Calibration:
    /// n = 1e8 structures (≈2 GB) are VRAM-resident (lat 9) ⇒
    /// 12 × 9 = 108 units ≈ 1 ns/RMQ ⇒ 0.00926.
    pub ns_per_unit_ref: f64,
    pub half_sat: f64,
    pub launch_overhead_ns: f64,
}

impl Default for LcaCostModel {
    fn default() -> Self {
        LcaCostModel {
            accesses_per_query: 12.0,
            ns_per_unit_ref: 0.00926,
            half_sat: (1u64 << 14) as f64,
            launch_overhead_ns: 10_000.0,
        }
    }
}

impl LcaCostModel {
    /// Range-regime factor observed in Fig. 10's second heat map: at
    /// large n, small/medium-range LCA queries run *slower* than long
    /// ones (divergence/locality on the GPU). Anchored to Fig. 12's
    /// ratios: ≈1 for large/medium ranges, ≈2.3 for the small regime.
    pub fn range_factor(&self, mean_len: f64, n: usize) -> f64 {
        let nf = (n.max(2)) as f64;
        1.0 + 1.3 * (-(mean_len.max(1.0) / nf.powf(0.45))).exp()
    }

    pub fn ns_per_query(&self, structure_bytes: u64, queries: u64, gpu: &ArchProfile) -> f64 {
        let ref_gpu = arch::LOVELACE_RTX6000ADA;
        let cache = CacheModel::for_arch(gpu);
        let lat = cache.access_latency(structure_bytes);
        let scale = arch::cuda_throughput(&ref_gpu) / arch::cuda_throughput(gpu);
        let util = saturation(queries, self.half_sat);
        self.accesses_per_query * lat * self.ns_per_unit_ref * scale / util
            + self.launch_overhead_ns / queries.max(1) as f64
    }
}

// -------------------------------------------------------------- HRMQ --

/// Query-parallel succinct RMQ on the paper's 192-core EPYC host. The
/// per-query work is *measured* on this machine (single-thread wall
/// clock), then scaled to the paper host: divide by its core count
/// (queries are embarrassingly parallel, §6.1) and correct for the
/// working-set regime difference with the CPU cache model.
#[derive(Clone, Copy, Debug)]
pub struct HrmqCostModel {
    pub cpu: CpuProfile,
    /// Parallel efficiency of the OpenMP query loop (memory-bandwidth
    /// sharing keeps it below 1; one-point calibration against the
    /// 12.5 ns/RMQ endpoint gives ≈ 0.75).
    pub parallel_efficiency: f64,
}

impl Default for HrmqCostModel {
    fn default() -> Self {
        HrmqCostModel { cpu: arch::EPYC_9654_X2, parallel_efficiency: 0.75 }
    }
}

impl HrmqCostModel {
    /// Modeled ns/query on the paper host from a local single-thread
    /// measurement.
    pub fn ns_per_query(&self, measured_single_thread_ns: f64, batch: u64) -> f64 {
        let cores = self.cpu.cores as f64;
        // Small batches cannot use all cores.
        let used = cores.min(batch.max(1) as f64);
        measured_single_thread_ns / (used * self.parallel_efficiency)
    }
}

// --------------------------------------------------------- EXHAUSTIVE --

/// Brute-force CUDA kernel: one thread per query scanning its range.
/// Work = elements scanned (measured exactly); the batch time is bounded
/// by the *longest* range (a warp's thread occupies its SM until done),
/// but throughput-wise the mean dominates at large batches.
#[derive(Clone, Copy, Debug)]
pub struct CudaCostModel {
    /// ns per scanned element per query at L1-resident working sets on
    /// the reference GPU. Calibration: n = 1e8 large ranges (≈5e7
    /// elements/query, VRAM lat 9) at ~1e6 ns/RMQ ⇒ ≈ 0.002.
    pub ns_per_elem_ref: f64,
    pub half_sat: f64,
}

impl Default for CudaCostModel {
    fn default() -> Self {
        CudaCostModel { ns_per_elem_ref: 0.002, half_sat: (1u64 << 14) as f64 }
    }
}

impl CudaCostModel {
    pub fn ns_per_query(
        &self,
        scanned_per_query: f64,
        input_bytes: u64,
        queries: u64,
        gpu: &ArchProfile,
    ) -> f64 {
        let ref_gpu = arch::LOVELACE_RTX6000ADA;
        let cache = CacheModel::for_arch(gpu);
        let lat = cache.access_latency(input_bytes);
        let scale = arch::cuda_throughput(&ref_gpu) / arch::cuda_throughput(gpu);
        let util = saturation(queries, self.half_sat);
        (scanned_per_query * self.ns_per_elem_ref * lat * scale / util).max(0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtcore::arch::*;

    fn ref_counters(queries: u64) -> Counters {
        // Typical block-matrix large-range traversal at the calibration
        // point: ~150 node visits, ~25 tri tests, ~3 rays per query.
        // Scalar traversal fetches each popped node once, so
        // node_fetches == nodes_visited at the calibration point.
        Counters {
            nodes_visited: 150 * queries,
            node_fetches: 150 * queries,
            tri_tests: 25 * queries,
            rays: 3 * queries,
            aabb_tests: 300 * queries,
        }
    }

    #[test]
    fn rt_model_hits_calibration_point() {
        let m = RtCostModel::default();
        let q = 1u64 << 26;
        let ns = m.ns_per_query(&ref_counters(q), q, &LOVELACE_RTX6000ADA);
        // Paper: ≈ 5 ns/RMQ for large ranges on the RTX 6000 Ada.
        assert!((3.0..8.0).contains(&ns), "ns = {ns}");
    }

    #[test]
    fn packet_shaped_counters_model_cheaper_work() {
        // Packetized traversal shares node fetches across P rays:
        // node_fetches drops toward nodes_visited / P while every other
        // counter is identical (bit-identical results, same box/tri
        // tests). The model must price that strictly cheaper, and the
        // saving must grow with the amortisation factor.
        let m = RtCostModel::default();
        let q = 1u64 << 20;
        let scalar = ref_counters(q);
        let packet = |p: u64| Counters { node_fetches: 150 * q / p, ..scalar };
        let w_scalar = m.work_per_query(&scalar, q);
        let w_p4 = m.work_per_query(&packet(4), q);
        let w_p16 = m.work_per_query(&packet(16), q);
        assert!(w_p16 < w_p4 && w_p4 < w_scalar, "{w_p16} {w_p4} {w_scalar}");
        // The split keeps the scalar shape priced exactly as the old
        // unit c_node weight did: c_node + c_packet per node.
        assert!((m.c_node + m.c_packet - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rt_model_scales_with_architecture() {
        let m = RtCostModel::default();
        let q = 1u64 << 26;
        let c = ref_counters(q);
        let ada = m.ns_per_query(&c, q, &LOVELACE_RTX6000ADA);
        let ampere = m.ns_per_query(&c, q, &AMPERE_3090TI);
        let turing = m.ns_per_query(&c, q, &TURING_TITAN_RTX);
        // Newer generations strictly faster (Fig. 14's near-exponential
        // RT scaling).
        assert!(ada < ampere && ampere < turing, "{ada} {ampere} {turing}");
        // Generational ratio should be large (RT factor × SMs × clock).
        assert!(turing / ada > 4.0);
    }

    #[test]
    fn rt_model_batch_scaling_unsaturated_at_2_26() {
        let m = RtCostModel::default();
        let per = |q: u64| m.ns_per_query(&ref_counters(q), q, &LOVELACE_RTX6000ADA);
        // Fig. 13: still improving at the largest tested batch.
        assert!(per(1 << 26) < per(1 << 22));
        assert!(per(1 << 22) < per(1 << 18));
    }

    #[test]
    fn lca_model_staircase_and_calibration() {
        let m = LcaCostModel::default();
        let q = 1u64 << 26;
        // n = 1e8 ⇒ ~2 GB of SV arrays ⇒ ~1 ns.
        let big = m.ns_per_query(2_000_000_000, q, &LOVELACE_RTX6000ADA);
        assert!((0.5..2.0).contains(&big), "big = {big}");
        // Small structures are faster (staircase down).
        let small = m.ns_per_query(1 << 20, q, &LOVELACE_RTX6000ADA);
        assert!(small < big);
    }

    #[test]
    fn lca_saturates_early_unlike_rtx() {
        let lca = LcaCostModel::default();
        let s18 = lca.ns_per_query(1 << 30, 1 << 18, &LOVELACE_RTX6000ADA);
        let s26 = lca.ns_per_query(1 << 30, 1 << 26, &LOVELACE_RTX6000ADA);
        // Beyond 2^18 LCA gains almost nothing (< 10%).
        assert!((s18 - s26) / s18 < 0.10, "s18={s18} s26={s26}");
    }

    #[test]
    fn hrmq_model_calibration() {
        let m = HrmqCostModel::default();
        // Paper endpoint: ≈ 12.5 ns/RMQ on 192 cores ⇒ single-thread
        // ≈ 12.5 × 192 × 0.75 = 1800 ns.
        let ns = m.ns_per_query(1800.0, 1 << 26);
        assert!((10.0..16.0).contains(&ns), "ns = {ns}");
        // Tiny batches can't use the whole socket.
        assert!(m.ns_per_query(1800.0, 4) > m.ns_per_query(1800.0, 1 << 20));
    }

    #[test]
    fn exhaustive_model_orders_of_magnitude() {
        let m = CudaCostModel::default();
        let gpu = LOVELACE_RTX6000ADA;
        let q = 1u64 << 26;
        let large = m.ns_per_query(5e7, 400 << 20, q, &gpu);
        let small = m.ns_per_query(256.0, 400 << 20, q, &gpu);
        // Fig. 12: exhaustive is ~orders slower at large ranges but
        // competitive at small ones.
        assert!(large > 1e5, "large = {large}");
        assert!(small < 50.0, "small = {small}");
    }

    #[test]
    fn tuned_shard_block_never_models_worse_than_sqrt_default() {
        // Acceptance bound for `--shard-block auto`: on the benched grid
        // the tuned size must never model a higher cost than the √n
        // default picks (it is in the candidate set, so argmin ≤ it).
        let m = RtCostModel::default();
        for n in [1usize << 14, 1 << 16, 1 << 18, 1 << 20] {
            let sqrt_default = crate::rmq::sharded::auto_block_size(n);
            for mean_range in [4.0, 64.0, 1024.0, (n as f64) * 0.5] {
                for update_frac in [0.0, 0.05, 0.2, 0.5, 1.0] {
                    let w = ShardWorkload { mean_range, update_frac };
                    let tuned = m.tune_shard_block(n, &w);
                    assert!(tuned.is_power_of_two() && (4..=1 << 12).contains(&tuned));
                    assert!(
                        m.shard_cost_per_op(n, tuned, &w)
                            <= m.shard_cost_per_op(n, sqrt_default, &w),
                        "n={n} m={mean_range} u={update_frac}: tuned {tuned} \
                         models worse than default {sqrt_default}"
                    );
                }
            }
        }
    }

    #[test]
    fn pure_update_workloads_tune_to_sqrt() {
        // With only updates, cost = B + n/B — minimised at √n, which is
        // exactly the default block size for power-of-4 array lengths.
        let m = RtCostModel::default();
        for n in [1usize << 16, 1 << 18, 1 << 20] {
            let w = ShardWorkload { mean_range: 64.0, update_frac: 1.0 };
            assert_eq!(m.tune_shard_block(n, &w), crate::rmq::sharded::auto_block_size(n));
        }
    }

    #[test]
    fn query_heavy_small_ranges_tune_to_at_least_the_range() {
        // Blocks smaller than the mean range force 2 probes + a summary
        // probe on most queries; the tuner must grow the block past that.
        let m = RtCostModel::default();
        let w = ShardWorkload { mean_range: 256.0, update_frac: 0.0 };
        let tuned = m.tune_shard_block(1 << 20, &w);
        assert!(tuned >= 256, "tuned {tuned}");
    }

    fn observed(mean_range: f64, update_frac: f64, bucket: usize, mass: f64) -> ObservedWorkload {
        let mut hist = [0.0; crate::workload::observer::RANGE_BUCKETS];
        hist[bucket] = mass;
        ObservedWorkload {
            mean_range,
            mean_batch: 64.0,
            update_frac,
            range_hist: hist,
            ops: 100,
            ..Default::default()
        }
    }

    #[test]
    fn update_work_distinguishes_batch_shapes() {
        let m = RtCostModel::default();
        let (n, bs) = (1usize << 16, 256usize);
        let (b, nb) = (bs as f64, (n / bs) as f64);
        // Unknown shape: the conservative dense prior (instanced, so a
        // value-table rewrite — the c_inst discount applies everywhere).
        let prior = m.shard_update_work(n, bs, 0.0);
        assert_eq!(prior, m.c_inst * (b + nb));
        // A single-point batch takes both path-refit routes — orders of
        // magnitude below the dense charge.
        let single = m.shard_update_work(n, bs, 1.0);
        assert!(
            (single - m.c_inst * (m.path_refit_work(b) + m.path_refit_work(nb))).abs() < 1e-9,
            "single = {single}"
        );
        assert!(single < prior / 10.0, "single {single} vs dense {prior}");
        // Sparse multi-block batches: path refits per block, full
        // summary sweep amortised over the batch.
        let k = 8.0;
        let sparse = m.shard_update_work(n, bs, k);
        assert!(
            (sparse - m.c_inst * (k * m.path_refit_work(b) + nb) / k).abs() < 1e-9,
            "sparse = {sparse}"
        );
        // Denser-than-blocks batches: full block refits, amortised.
        let dense = m.shard_update_work(n, bs, 4.0 * nb);
        assert!(
            (dense - m.c_inst * (nb * b + nb) / (4.0 * nb)).abs() < 1e-9,
            "dense = {dense}"
        );
        // Per-point cost shrinks as batches amortise the shared work.
        assert!(sparse < m.shard_update_work(n, bs, 2.0) || k <= 2.0);
        assert!(dense < prior);
    }

    #[test]
    fn range_update_work_prices_tags_far_below_rebuilds() {
        let m = RtCostModel::default();
        let (n, bs) = (1usize << 16, 256usize);
        let (b, nb) = (bs as f64, (n / bs) as f64);
        // A full-array range: every interior block is one tag write, the
        // two boundary blocks pay the Θ(B) refit, the summary re-sweeps.
        let full = m.range_update_work(n, bs, n as f64);
        let covered = nb - 2.0;
        assert!(
            (full - m.c_inst * (covered * m.c_aabb + 2.0 * b + nb)).abs() < 1e-9,
            "full = {full}"
        );
        // The same span as point updates pays Θ(B) per *block* — the
        // lazy tag path must be far cheaper than rewriting every block.
        let as_points = nb * m.shard_update_work(n, bs, nb);
        assert!(full < as_points / 4.0, "tags {full} vs rewrites {as_points}");
        // A single-element range touches only boundary work and the
        // cheap single-minimum summary path — no covered or sweep terms.
        let tiny = m.range_update_work(n, bs, 1.0);
        assert!(
            (tiny - m.c_inst * (b + m.path_refit_work(nb))).abs() < 1e-9,
            "tiny = {tiny}"
        );
        assert!(tiny < full);
        // The c_inst discount scales the whole charge uniformly.
        let undisc = RtCostModel { c_inst: 1.0, ..Default::default() };
        let a = undisc.range_update_work(n, bs, 1e4);
        let d = m.range_update_work(n, bs, 1e4);
        assert!((d - m.c_inst * a).abs() < 1e-9);
    }

    #[test]
    fn instancing_discount_scales_update_work_uniformly() {
        // c_inst multiplies *every* shard_update_work branch by the same
        // factor — the property that keeps pure-update tuning argmins
        // where they were (√n) while pricing staged replacement blocks
        // as refit-shaped work rather than builds.
        let full = RtCostModel { c_inst: 1.0, ..Default::default() };
        let disc = RtCostModel::default();
        assert!(disc.c_inst > 0.0 && disc.c_inst < 1.0);
        let n = 1usize << 16;
        for bs in [4usize, 64, 256, 4096] {
            for points in [0.0, 1.0, 8.0, 1e3, 1e7] {
                let a = full.shard_update_work(n, bs, points);
                let b = disc.shard_update_work(n, bs, points);
                assert!((b - disc.c_inst * a).abs() < 1e-9, "bs={bs} points={points}");
            }
        }
        // Pure-update workloads still tune to the √n default.
        let w = ShardWorkload { mean_range: 64.0, update_frac: 1.0 };
        assert_eq!(disc.tune_shard_block(n, &w), full.tune_shard_block(n, &w));
    }

    #[test]
    fn observed_single_point_updates_relax_the_update_penalty() {
        // With point updates known to arrive one at a time, the update
        // term stops punishing large blocks (path refit is Θ(log B)),
        // so the tuner picks a block at least as large as the dense
        // prior would under the same mixed traffic.
        let m = RtCostModel::default();
        let n = 1usize << 18;
        let mut dense = observed(96.0, 0.4, 6, 10.0);
        let mut single = dense;
        dense.mean_update_batch = 0.0; // unknown -> dense prior
        single.mean_update_batch = 1.0;
        let tuned_dense = m.tune_shard_block_observed(n, &dense);
        let tuned_single = m.tune_shard_block_observed(n, &single);
        assert!(
            tuned_single >= tuned_dense,
            "single-point updates must not shrink the block: {tuned_single} < {tuned_dense}"
        );
        // And the modeled cost at the chosen block strictly improves.
        let cost =
            |w: &ObservedWorkload, bs| {
                0.6 * m.shard_query_work(n, bs, 96.0)
                    + 0.4 * m.shard_update_work(n, bs, w.mean_update_batch)
            };
        assert!(cost(&single, tuned_single) < cost(&dense, tuned_dense));
    }

    #[test]
    fn observed_tuner_matches_scalar_tuner_on_concentrated_mass() {
        // All histogram mass in one bucket ~ a scalar mean at the bucket
        // centre: both tuners must agree.
        let m = RtCostModel::default();
        for n in [1usize << 14, 1 << 18] {
            for (bucket, u) in [(4usize, 0.0), (8, 0.1), (12, 0.3)] {
                let centre = (1u64 << bucket) as f64 * 1.5;
                let via_hist = m.tune_shard_block_observed(n, &observed(centre, u, bucket, 10.0));
                let via_mean =
                    m.tune_shard_block(n, &ShardWorkload { mean_range: centre, update_frac: u });
                assert_eq!(via_hist, via_mean, "n={n} bucket={bucket} u={u}");
            }
        }
    }

    #[test]
    fn observed_tuner_falls_back_to_scalar_on_empty_histogram() {
        let m = RtCostModel::default();
        let w = ObservedWorkload { mean_range: 256.0, ..Default::default() };
        assert_eq!(
            m.tune_shard_block_observed(1 << 18, &w),
            m.tune_shard_block(1 << 18, &ShardWorkload { mean_range: 256.0, update_frac: 0.0 })
        );
    }

    #[test]
    fn observed_distribution_shift_drifts_the_tuned_block() {
        // The re-shard trigger's premise: a small-range read-heavy mix
        // and a large-range read-only mix must tune to block sizes at
        // least 2x apart (the default --reshard-drift threshold).
        let m = RtCostModel::default();
        let n = 1usize << 16;
        let small = m.tune_shard_block_observed(n, &observed(24.0, 0.2, 4, 10.0));
        let large = m.tune_shard_block_observed(n, &observed(32768.0, 0.0, 15, 10.0));
        let drift = (small as f64 / large as f64).max(large as f64 / small as f64);
        assert!(drift >= 2.0, "small {small} large {large}");
    }

    #[test]
    fn rebuild_worthwhile_is_a_threshold_in_the_update_rate() {
        let m = RtCostModel::default();
        let n = 1usize << 16;
        let bs = 256usize;
        // Zero update rate: always worthwhile.
        assert!(m.rebuild_worthwhile(n, bs, &observed(24.0, 0.0, 4, 10.0)));
        // Busy mixed traffic: not worthwhile.
        assert!(!m.rebuild_worthwhile(n, bs, &observed(24.0, 0.3, 4, 10.0)));
        // Monotone: sweeping u downward, once worthwhile it stays so.
        let mut flipped = false;
        for k in (0..=40).rev() {
            let u = k as f64 / 40.0;
            let w = m.rebuild_worthwhile(n, bs, &observed(24.0, u, 4, 10.0));
            if flipped && !w {
                panic!("non-monotone threshold at u={u}");
            }
            if w {
                flipped = true;
            }
        }
        assert!(flipped, "never worthwhile at any rate");
        // Bigger arrays cost more to rebuild -> stricter threshold.
        let u_mid = 0.02;
        assert!(m.rebuild_worthwhile(1 << 12, 64, &observed(24.0, u_mid, 4, 10.0)));
        assert!(!m.rebuild_worthwhile(1 << 24, 4096, &observed(24.0, u_mid, 4, 10.0)));
    }

    #[test]
    fn probe_and_shard_cost_are_finite_and_positive() {
        let m = RtCostModel::default();
        for k in [1.0, 2.0, 64.0, 4096.0, 1e7] {
            let w = m.probe_work(k);
            assert!(w.is_finite() && w > 0.0);
        }
        // Degenerate shapes must not divide by zero or go negative.
        let w = ShardWorkload { mean_range: 0.0, update_frac: 2.0 };
        assert!(m.shard_cost_per_op(1, 1, &w).is_finite());
    }

    #[test]
    fn saturation_shape() {
        assert!(saturation(1, 16384.0) < 0.001);
        assert!(saturation(1 << 18, 16384.0) > 0.9);
        assert!((saturation(u64::MAX >> 1, 16384.0) - 1.0).abs() < 1e-9);
    }
}

//! Work→time models for the four approaches.
//!
//! Calibration discipline (see `model` docs): each model has exactly one
//! scale constant, fixed against one Fig. 12 endpoint (n = 1e8, q = 2^26,
//! large (l,r) ranges: RTXRMQ ≈ 5 ns/RMQ, LCA ≈ 1 ns/RMQ, HRMQ ≈ 12.5
//! ns/RMQ on 192 cores, EXHAUSTIVE ~1e6 ns/RMQ). All n-, range-, batch-
//! and architecture-dependence comes from measured work, the cache model
//! and the public arch parameters.

use super::cache::CacheModel;
use crate::bvh::traverse::Counters;
use crate::rtcore::arch::{self, ArchProfile, CpuProfile};

/// Saturation of a parallel machine by batch size: throughput fraction
/// `batch / (batch + half_sat)`. Fig. 13's shapes: LCA/HRMQ/EXHAUSTIVE
/// saturate near 2^17–2^18 (half_sat ≈ 2^14); RTXRMQ keeps scaling past
/// 2^26 (half_sat ≈ 2^21, so even 2^26 is only ~97% saturated).
pub fn saturation(batch: u64, half_sat: f64) -> f64 {
    let b = batch.max(1) as f64;
    b / (b + half_sat)
}

// ------------------------------------------------------------ RTXRMQ --

/// RT-core model: converts BVH traversal counters into modeled time.
///
/// Counter semantics across acceleration layouts (see the "BVH layouts"
/// docs on `crate::bvh`): `nodes_visited` counts node pops in either
/// layout — a 4-wide pop replaces roughly three binary pops;
/// `aabb_tests` counts per-child box tests (2 per binary internal node,
/// exactly 4 per wide node). Weighing both terms (`c_node` for the
/// pop/dispatch cost, `c_aabb` for each box test) keeps modeled times
/// comparable between layouts: the wide layout trades more box tests
/// per pop for far fewer pops, which is exactly the trade RT hardware
/// makes.
#[derive(Clone, Copy, Debug)]
pub struct RtCostModel {
    /// Work units per BVH node visit / per-child AABB test / triangle
    /// test / ray launch.
    pub c_node: f64,
    pub c_aabb: f64,
    pub c_tri: f64,
    pub c_ray: f64,
    /// ns per work unit *per query* on the reference GPU (RTX 6000 Ada),
    /// at full saturation. Single-point calibration: at the Fig. 12
    /// reference the measured block-matrix traversal does ≈ 230 work
    /// units per query and the paper reports ≈ 5 ns/RMQ ⇒ 0.022 ns/unit.
    pub ns_per_unit_ref: f64,
    /// Batch half-saturation (Fig. 13: RTXRMQ unsaturated at 2^26).
    pub half_sat: f64,
    /// Fixed per-launch overhead in ns (amortised over the batch).
    pub launch_overhead_ns: f64,
}

impl Default for RtCostModel {
    fn default() -> Self {
        RtCostModel {
            c_node: 1.0,
            c_aabb: 0.25,
            c_tri: 2.0,
            c_ray: 10.0,
            ns_per_unit_ref: 0.022,
            half_sat: (1u64 << 21) as f64,
            launch_overhead_ns: 15_000.0,
        }
    }
}

impl RtCostModel {
    /// Work units per query from measured counters.
    pub fn work_per_query(&self, c: &Counters, queries: u64) -> f64 {
        let w = c.nodes_visited as f64 * self.c_node
            + c.aabb_tests as f64 * self.c_aabb
            + c.tri_tests as f64 * self.c_tri
            + c.rays as f64 * self.c_ray;
        w / queries.max(1) as f64
    }

    /// Modeled ns per query on `gpu` for a batch of `queries`.
    pub fn ns_per_query(&self, c: &Counters, queries: u64, gpu: &ArchProfile) -> f64 {
        let ref_gpu = arch::LOVELACE_RTX6000ADA;
        let scale = arch::rt_throughput(&ref_gpu) / arch::rt_throughput(gpu);
        let util = saturation(queries, self.half_sat);
        self.work_per_query(c, queries) * self.ns_per_unit_ref * scale / util
            + self.launch_overhead_ns / queries.max(1) as f64
    }
}

// --------------------------------------------------------------- LCA --

/// Schieber–Vishkin batch-LCA on CUDA cores. The per-query op count is
/// constant (the algorithm is O(1) inline — counted from our own
/// implementation: ~12 dependent word reads); the n-dependence enters
/// through the cache model on the structure's working set (Fig. 12's
/// staircase, Fig. 13's L2 dip).
#[derive(Clone, Copy, Debug)]
pub struct LcaCostModel {
    pub accesses_per_query: f64,
    /// ns per access-latency-unit on the reference GPU. Calibration:
    /// n = 1e8 structures (≈2 GB) are VRAM-resident (lat 9) ⇒
    /// 12 × 9 = 108 units ≈ 1 ns/RMQ ⇒ 0.00926.
    pub ns_per_unit_ref: f64,
    pub half_sat: f64,
    pub launch_overhead_ns: f64,
}

impl Default for LcaCostModel {
    fn default() -> Self {
        LcaCostModel {
            accesses_per_query: 12.0,
            ns_per_unit_ref: 0.00926,
            half_sat: (1u64 << 14) as f64,
            launch_overhead_ns: 10_000.0,
        }
    }
}

impl LcaCostModel {
    /// Range-regime factor observed in Fig. 10's second heat map: at
    /// large n, small/medium-range LCA queries run *slower* than long
    /// ones (divergence/locality on the GPU). Anchored to Fig. 12's
    /// ratios: ≈1 for large/medium ranges, ≈2.3 for the small regime.
    pub fn range_factor(&self, mean_len: f64, n: usize) -> f64 {
        let nf = (n.max(2)) as f64;
        1.0 + 1.3 * (-(mean_len.max(1.0) / nf.powf(0.45))).exp()
    }

    pub fn ns_per_query(&self, structure_bytes: u64, queries: u64, gpu: &ArchProfile) -> f64 {
        let ref_gpu = arch::LOVELACE_RTX6000ADA;
        let cache = CacheModel::for_arch(gpu);
        let lat = cache.access_latency(structure_bytes);
        let scale = arch::cuda_throughput(&ref_gpu) / arch::cuda_throughput(gpu);
        let util = saturation(queries, self.half_sat);
        self.accesses_per_query * lat * self.ns_per_unit_ref * scale / util
            + self.launch_overhead_ns / queries.max(1) as f64
    }
}

// -------------------------------------------------------------- HRMQ --

/// Query-parallel succinct RMQ on the paper's 192-core EPYC host. The
/// per-query work is *measured* on this machine (single-thread wall
/// clock), then scaled to the paper host: divide by its core count
/// (queries are embarrassingly parallel, §6.1) and correct for the
/// working-set regime difference with the CPU cache model.
#[derive(Clone, Copy, Debug)]
pub struct HrmqCostModel {
    pub cpu: CpuProfile,
    /// Parallel efficiency of the OpenMP query loop (memory-bandwidth
    /// sharing keeps it below 1; one-point calibration against the
    /// 12.5 ns/RMQ endpoint gives ≈ 0.75).
    pub parallel_efficiency: f64,
}

impl Default for HrmqCostModel {
    fn default() -> Self {
        HrmqCostModel { cpu: arch::EPYC_9654_X2, parallel_efficiency: 0.75 }
    }
}

impl HrmqCostModel {
    /// Modeled ns/query on the paper host from a local single-thread
    /// measurement.
    pub fn ns_per_query(&self, measured_single_thread_ns: f64, batch: u64) -> f64 {
        let cores = self.cpu.cores as f64;
        // Small batches cannot use all cores.
        let used = cores.min(batch.max(1) as f64);
        measured_single_thread_ns / (used * self.parallel_efficiency)
    }
}

// --------------------------------------------------------- EXHAUSTIVE --

/// Brute-force CUDA kernel: one thread per query scanning its range.
/// Work = elements scanned (measured exactly); the batch time is bounded
/// by the *longest* range (a warp's thread occupies its SM until done),
/// but throughput-wise the mean dominates at large batches.
#[derive(Clone, Copy, Debug)]
pub struct CudaCostModel {
    /// ns per scanned element per query at L1-resident working sets on
    /// the reference GPU. Calibration: n = 1e8 large ranges (≈5e7
    /// elements/query, VRAM lat 9) at ~1e6 ns/RMQ ⇒ ≈ 0.002.
    pub ns_per_elem_ref: f64,
    pub half_sat: f64,
}

impl Default for CudaCostModel {
    fn default() -> Self {
        CudaCostModel { ns_per_elem_ref: 0.002, half_sat: (1u64 << 14) as f64 }
    }
}

impl CudaCostModel {
    pub fn ns_per_query(
        &self,
        scanned_per_query: f64,
        input_bytes: u64,
        queries: u64,
        gpu: &ArchProfile,
    ) -> f64 {
        let ref_gpu = arch::LOVELACE_RTX6000ADA;
        let cache = CacheModel::for_arch(gpu);
        let lat = cache.access_latency(input_bytes);
        let scale = arch::cuda_throughput(&ref_gpu) / arch::cuda_throughput(gpu);
        let util = saturation(queries, self.half_sat);
        (scanned_per_query * self.ns_per_elem_ref * lat * scale / util).max(0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtcore::arch::*;

    fn ref_counters(queries: u64) -> Counters {
        // Typical block-matrix large-range traversal at the calibration
        // point: ~150 node visits, ~25 tri tests, ~3 rays per query.
        Counters {
            nodes_visited: 150 * queries,
            tri_tests: 25 * queries,
            rays: 3 * queries,
            aabb_tests: 300 * queries,
        }
    }

    #[test]
    fn rt_model_hits_calibration_point() {
        let m = RtCostModel::default();
        let q = 1u64 << 26;
        let ns = m.ns_per_query(&ref_counters(q), q, &LOVELACE_RTX6000ADA);
        // Paper: ≈ 5 ns/RMQ for large ranges on the RTX 6000 Ada.
        assert!((3.0..8.0).contains(&ns), "ns = {ns}");
    }

    #[test]
    fn rt_model_scales_with_architecture() {
        let m = RtCostModel::default();
        let q = 1u64 << 26;
        let c = ref_counters(q);
        let ada = m.ns_per_query(&c, q, &LOVELACE_RTX6000ADA);
        let ampere = m.ns_per_query(&c, q, &AMPERE_3090TI);
        let turing = m.ns_per_query(&c, q, &TURING_TITAN_RTX);
        // Newer generations strictly faster (Fig. 14's near-exponential
        // RT scaling).
        assert!(ada < ampere && ampere < turing, "{ada} {ampere} {turing}");
        // Generational ratio should be large (RT factor × SMs × clock).
        assert!(turing / ada > 4.0);
    }

    #[test]
    fn rt_model_batch_scaling_unsaturated_at_2_26() {
        let m = RtCostModel::default();
        let per = |q: u64| m.ns_per_query(&ref_counters(q), q, &LOVELACE_RTX6000ADA);
        // Fig. 13: still improving at the largest tested batch.
        assert!(per(1 << 26) < per(1 << 22));
        assert!(per(1 << 22) < per(1 << 18));
    }

    #[test]
    fn lca_model_staircase_and_calibration() {
        let m = LcaCostModel::default();
        let q = 1u64 << 26;
        // n = 1e8 ⇒ ~2 GB of SV arrays ⇒ ~1 ns.
        let big = m.ns_per_query(2_000_000_000, q, &LOVELACE_RTX6000ADA);
        assert!((0.5..2.0).contains(&big), "big = {big}");
        // Small structures are faster (staircase down).
        let small = m.ns_per_query(1 << 20, q, &LOVELACE_RTX6000ADA);
        assert!(small < big);
    }

    #[test]
    fn lca_saturates_early_unlike_rtx() {
        let lca = LcaCostModel::default();
        let s18 = lca.ns_per_query(1 << 30, 1 << 18, &LOVELACE_RTX6000ADA);
        let s26 = lca.ns_per_query(1 << 30, 1 << 26, &LOVELACE_RTX6000ADA);
        // Beyond 2^18 LCA gains almost nothing (< 10%).
        assert!((s18 - s26) / s18 < 0.10, "s18={s18} s26={s26}");
    }

    #[test]
    fn hrmq_model_calibration() {
        let m = HrmqCostModel::default();
        // Paper endpoint: ≈ 12.5 ns/RMQ on 192 cores ⇒ single-thread
        // ≈ 12.5 × 192 × 0.75 = 1800 ns.
        let ns = m.ns_per_query(1800.0, 1 << 26);
        assert!((10.0..16.0).contains(&ns), "ns = {ns}");
        // Tiny batches can't use the whole socket.
        assert!(m.ns_per_query(1800.0, 4) > m.ns_per_query(1800.0, 1 << 20));
    }

    #[test]
    fn exhaustive_model_orders_of_magnitude() {
        let m = CudaCostModel::default();
        let gpu = LOVELACE_RTX6000ADA;
        let q = 1u64 << 26;
        let large = m.ns_per_query(5e7, 400 << 20, q, &gpu);
        let small = m.ns_per_query(256.0, 400 << 20, q, &gpu);
        // Fig. 12: exhaustive is ~orders slower at large ranges but
        // competitive at small ones.
        assert!(large > 1e5, "large = {large}");
        assert!(small < 50.0, "small = {small}");
    }

    #[test]
    fn saturation_shape() {
        assert!(saturation(1, 16384.0) < 0.001);
        assert!(saturation(1 << 18, 16384.0) > 0.9);
        assert!((saturation(u64::MAX >> 1, 16384.0) - 1.0).abs() < 1e-9);
    }
}

//! Energy model (Figs. 16–17). The paper's measurements show *stable*
//! draw near a per-approach utilisation level for the whole run (§6.6):
//! RTXRMQ and EXHAUSTIVE at the 300 W TDP, LCA at 200–240 W, HRMQ at
//! ~600 W of the 720 W dual-EPYC budget. We model draw as
//! `idle + util·(tdp − idle)` and integrate over modeled runtime.

use crate::rtcore::arch::{ArchProfile, CpuProfile};
use crate::util::rng::Rng;

/// Per-approach utilisation levels (fraction of TDP above idle) taken
/// from the Fig. 16 time series.
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    pub util_rtx: f64,
    pub util_lca: f64,
    pub util_exhaustive: f64,
    pub util_hrmq_cpu: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            util_rtx: 1.0,        // reaches the 300 W TDP
            util_lca: 0.75,       // 200–240 W band
            util_exhaustive: 1.0, // reaches TDP
            util_hrmq_cpu: 0.80,  // ~600 W of 720 W
        }
    }
}

/// One sampled power trace (Fig. 16's series).
#[derive(Clone, Debug)]
pub struct PowerSeries {
    /// Sample timestamps in seconds.
    pub t_s: Vec<f64>,
    /// Instantaneous draw in watts.
    pub watts: Vec<f64>,
    /// Total energy in joules.
    pub energy_j: f64,
}

impl EnergyModel {
    /// Steady-state draw of a GPU approach.
    pub fn gpu_watts(&self, util: f64, gpu: &ArchProfile) -> f64 {
        gpu.idle_w + util * (gpu.tdp_w - gpu.idle_w)
    }

    /// Steady-state draw of the CPU approach.
    pub fn cpu_watts(&self, cpu: &CpuProfile) -> f64 {
        cpu.idle_w + self.util_hrmq_cpu * (cpu.tdp_w - cpu.idle_w)
    }

    /// Synthesize a power time series over `duration_s` with measurement
    /// jitter (~2%, as in the paper's flat traces), sampled at `hz`.
    pub fn series(&self, steady_w: f64, duration_s: f64, hz: f64, seed: u64) -> PowerSeries {
        let samples = ((duration_s * hz).ceil() as usize).max(2);
        let mut rng = Rng::new(seed);
        let mut t_s = Vec::with_capacity(samples);
        let mut watts = Vec::with_capacity(samples);
        for i in 0..samples {
            t_s.push(i as f64 / hz);
            let jitter = 1.0 + 0.02 * (rng.f64() * 2.0 - 1.0);
            watts.push(steady_w * jitter);
        }
        let energy_j = steady_w * duration_s;
        PowerSeries { t_s, watts, energy_j }
    }

    /// RMQs per joule (Fig. 17's metric) for a batch that took
    /// `total_ns` at `steady_w`.
    pub fn rmq_per_joule(&self, queries: u64, total_ns: f64, steady_w: f64) -> f64 {
        let energy = steady_w * (total_ns * 1e-9);
        if energy <= 0.0 {
            return 0.0;
        }
        queries as f64 / energy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtcore::arch::{EPYC_9654_X2, LOVELACE_RTX6000ADA};

    #[test]
    fn steady_levels_match_fig16() {
        let m = EnergyModel::default();
        let gpu = LOVELACE_RTX6000ADA;
        // RTXRMQ / EXHAUSTIVE at TDP.
        assert!((m.gpu_watts(m.util_rtx, &gpu) - 300.0).abs() < 1.0);
        // LCA in the 200–240 W band.
        let lca = m.gpu_watts(m.util_lca, &gpu);
        assert!((200.0..245.0).contains(&lca), "lca draw {lca}");
        // HRMQ ≈ 600 W.
        let hrmq = m.cpu_watts(&EPYC_9654_X2);
        assert!((550.0..650.0).contains(&hrmq), "hrmq draw {hrmq}");
    }

    #[test]
    fn series_is_flat_with_correct_energy() {
        let m = EnergyModel::default();
        let s = m.series(300.0, 10.0, 5.0, 42);
        assert!(s.t_s.len() >= 50);
        for &w in &s.watts {
            assert!((w - 300.0).abs() <= 300.0 * 0.021);
        }
        assert!((s.energy_j - 3000.0).abs() < 1e-9);
    }

    #[test]
    fn rmq_per_joule_favors_faster_runs() {
        let m = EnergyModel::default();
        // Same batch, same wattage, half the time => double the RMQ/J.
        let slow = m.rmq_per_joule(1 << 20, 2e9, 300.0);
        let fast = m.rmq_per_joule(1 << 20, 1e9, 300.0);
        assert!((fast / slow - 2.0).abs() < 1e-9);
        // LCA at lower wattage can beat RTXRMQ at equal speed (the
        // paper's large/medium-range outcome).
        let lca = m.rmq_per_joule(1 << 20, 1e9, 225.0);
        assert!(lca > fast);
    }
}

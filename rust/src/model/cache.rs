//! GPU memory-hierarchy model: converts a working-set size into a
//! relative access-latency multiplier. This is what produces the
//! staircase the paper observes for LCA in Fig. 12 ("constant time
//! switches to different levels at certain problem sizes due to the
//! effect of caches L1, L2 and VRAM") and LCA's Fig. 13 dip when its
//! structures stop fitting in the 96 MB L2.

use crate::rtcore::ArchProfile;

/// Relative latency multipliers per level (L1 = 1).
#[derive(Clone, Copy, Debug)]
pub struct CacheModel {
    pub l1_total_bytes: u64,
    pub l2_total_bytes: u64,
    pub lat_l1: f64,
    pub lat_l2: f64,
    pub lat_vram: f64,
}

impl CacheModel {
    /// Build from an architecture profile (128 KiB unified L1 per SM on
    /// Ampere/Ada-class parts).
    pub fn for_arch(p: &ArchProfile) -> CacheModel {
        CacheModel {
            l1_total_bytes: p.sm_count as u64 * 128 * 1024,
            l2_total_bytes: (p.l2_mib * 1024.0 * 1024.0) as u64,
            lat_l1: 1.0,
            lat_vram: 9.0,
            lat_l2: 3.0,
        }
    }

    /// Smooth-step latency for a random-access working set of the given
    /// size: fully below a level ⇒ that level's latency; across a
    /// boundary ⇒ capacity-weighted mix (fraction of hits still served by
    /// the smaller level).
    pub fn access_latency(&self, working_set: u64) -> f64 {
        let ws = working_set.max(1) as f64;
        let l1 = self.l1_total_bytes as f64;
        let l2 = self.l2_total_bytes as f64;
        if ws <= l1 {
            self.lat_l1
        } else if ws <= l2 {
            // hit fraction from L1 = l1/ws
            let f = l1 / ws;
            f * self.lat_l1 + (1.0 - f) * self.lat_l2
        } else {
            let f1 = l1 / ws;
            let f2 = (l2 - l1).max(0.0) / ws;
            f1 * self.lat_l1 + f2 * self.lat_l2 + (1.0 - f1 - f2) * self.lat_vram
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtcore::arch::LOVELACE_RTX6000ADA;

    #[test]
    fn monotone_in_working_set() {
        let m = CacheModel::for_arch(&LOVELACE_RTX6000ADA);
        let mut prev = 0.0;
        for ws in [1u64 << 10, 1 << 20, 1 << 24, 1 << 27, 1 << 30, 1 << 34] {
            let lat = m.access_latency(ws);
            assert!(lat >= prev, "latency must not decrease ({ws})");
            prev = lat;
        }
    }

    #[test]
    fn staircase_levels() {
        let m = CacheModel::for_arch(&LOVELACE_RTX6000ADA);
        // Tiny set: L1 speed.
        assert_eq!(m.access_latency(1 << 10), 1.0);
        // Around 1 GiB: essentially VRAM.
        assert!(m.access_latency(1 << 30) > 7.0);
        // Mid-size (50 MB): between L1 and VRAM.
        let mid = m.access_latency(50 << 20);
        assert!(mid > 1.0 && mid < 7.0, "mid = {mid}");
    }

    #[test]
    fn l2_capacity_from_profile() {
        let m = CacheModel::for_arch(&LOVELACE_RTX6000ADA);
        assert_eq!(m.l2_total_bytes, 96 * 1024 * 1024);
    }
}

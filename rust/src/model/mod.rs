//! Performance and energy models.
//!
//! Methodology (DESIGN.md §0): the simulator *executes* the paper's exact
//! workloads and **measures work** (BVH node visits, triangle tests,
//! memory touches, scanned elements). These models convert measured work
//! into modeled GPU/CPU time using public architecture parameters
//! (`rtcore::arch`) plus **one scale calibration per approach family**,
//! fixed once against a single reported endpoint of the paper (Fig. 12,
//! n = 1e8, large ranges: RTXRMQ ≈ 5 ns/RMQ, HRMQ ≈ 12.5 ns/RMQ, LCA ≈
//! 1 ns/RMQ). Everything else — crossovers, staircases, scaling ratios —
//! *emerges* from the measured work and the architecture parameters; it
//! is never fitted per-configuration.

pub mod cache;
pub mod energy;
pub mod rtcost;

pub use cache::CacheModel;
pub use energy::EnergyModel;
pub use rtcost::{CudaCostModel, HrmqCostModel, LcaCostModel, RtCostModel, ShardWorkload};

//! 4-wide structure-of-arrays BVH specialized for the paper's +X point
//! rays — the hot-path acceleration layout (`AccelLayout::Wide`).
//!
//! Rationale (paper §5.2 attributes RTXRMQ's cost to "bounding box
//! intersections between the ray and the internal nodes"): for a ray
//! `(θ, y, z) + t·(1, 0, 0)` an AABB slab test degenerates to two
//! interval checks on (y, z) plus an entry distance `xmin − θ`. A wide
//! node stores those per-lane quantities as small fixed arrays
//! (`ymin[4] / ymax[4] / zmin[4] / zmax[4] / xmin[4]`), so all four
//! child tests run as straight-line, auto-vectorizable compares with no
//! pointer chasing — the software analogue of how RT hardware amortizes
//! box tests across wide, shallow trees (RT-HDIST et al.).
//!
//! Leaves are compact [`WidePrim`] records (`x_plane, y_lo, y_hi, z_lo,
//! z_hi, prim` — 24 bytes, cache-linear) instead of full `Triangle`
//! dereferences through a permutation array.
//!
//! The binary layout ([`super::Bvh`]) remains the correctness oracle and
//! the cost-model reference; [`crate::bvh::build::collapse_to_wide`]
//! folds a built binary tree into this layout, so both builders (SAH and
//! LBVH) feed it. Hits are bit-identical between layouts (property-tested
//! in `tests/layout_equivalence.rs`), including leftmost tie-breaks and
//! the Algorithm-6 carried-hit sub-rays.

use super::traverse::{Counters, Hit};
use crate::geometry::{Ray, Triangle};

/// Sentinel for an unused child lane.
pub const INVALID_LANE: u32 = u32::MAX;

/// One 4-wide node. Per-lane arrays hold the child AABB projections the
/// +X specialization needs; `child[k]` is either an index into
/// [`WideBvh::nodes`] (when `count[k] == 0`) or the first index of a
/// contiguous run of `count[k]` records in [`WideBvh::prims`]
/// (when `count[k] > 0`). Unused lanes have `child[k] == INVALID_LANE`
/// and inverted bounds so every interval test fails.
#[derive(Clone, Copy, Debug)]
pub struct WideNode {
    pub ymin: [f32; 4],
    pub ymax: [f32; 4],
    pub zmin: [f32; 4],
    pub zmax: [f32; 4],
    /// Lower x bound of the lane — `xmin − origin.x` is the ray entry
    /// distance (clamped to 0). The +X specialization drops `xmax`: for
    /// valid query rays θ lies strictly below every value plane, so no
    /// subtree is ever entirely behind the origin; prims behind the
    /// origin are rejected per-record by the `t < 0` test.
    pub xmin: [f32; 4],
    pub child: [u32; 4],
    pub count: [u8; 4],
}

impl WideNode {
    pub fn empty() -> WideNode {
        WideNode {
            ymin: [f32::INFINITY; 4],
            ymax: [f32::NEG_INFINITY; 4],
            zmin: [f32::INFINITY; 4],
            zmax: [f32::NEG_INFINITY; 4],
            xmin: [f32::INFINITY; 4],
            child: [INVALID_LANE; 4],
            count: [0; 4],
        }
    }
}

/// Compact per-leaf primitive record: the value plane, the open (y, z)
/// footprint rectangle, and the primitive id to report. For every valid
/// query origin the footprint test is exactly
/// `y_lo < y < y_hi && z_lo < z < z_hi` (see the §Perf L3.1 note in
/// `bvh::traverse` — the hypotenuse never cuts a query space).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WidePrim {
    pub x_plane: f32,
    pub y_lo: f32,
    pub y_hi: f32,
    pub z_lo: f32,
    pub z_hi: f32,
    pub prim: u32,
}

impl WidePrim {
    /// Extract the record from a scene triangle (vertex layout per
    /// `geometry::flat` / `geometry::blocks`: v0 = right-angle corner
    /// (l, r), v1 = top, v2 = left).
    #[inline]
    pub fn from_triangle(tri: &Triangle) -> WidePrim {
        WidePrim {
            x_plane: tri.x_plane(),
            y_lo: tri.v2[1],
            y_hi: tri.v0[1],
            z_lo: tri.v0[2],
            z_hi: tri.v1[2],
            prim: tri.prim,
        }
    }
}

/// Topology links for point refits ([`WideBvh::refit_prims`]). Kept
/// outside [`WideBvh`] so only the dynamic-update path pays for them.
pub struct WideRefitLinks {
    /// `parent[i]` = node whose internal lane points at node `i`
    /// (`parent[0] == 0`: the root).
    pub parent: Vec<u32>,
    /// `node_of_slot[s]` = node whose leaf-lane run contains prims
    /// slot `s`.
    pub node_of_slot: Vec<u32>,
    /// `slot_of_prim[p]` = prims slot holding primitive id `p`.
    pub slot_of_prim: Vec<u32>,
}

impl WideRefitLinks {
    /// Heap bytes of the link tables. Once a solver builds them they
    /// stay resident for its lifetime, so resident-memory accounting
    /// must include them (they were the largest omission in the old
    /// node+prim-only tally).
    pub fn memory_bytes(&self) -> usize {
        (self.parent.len() + self.node_of_slot.len() + self.slot_of_prim.len()) * 4
    }
}

/// The wide acceleration structure.
pub struct WideBvh {
    pub nodes: Vec<WideNode>,
    pub prims: Vec<WidePrim>,
    /// Max leaf size inherited from the collapsed binary tree.
    pub leaf_size: usize,
}

/// Reusable wide-traversal stack (allocation-free hot loop — one per
/// worker). BVH4 depth is roughly half the binary depth, so the stack
/// stays small.
pub struct WideStack {
    stack: Vec<(u32, f32)>,
}

impl Default for WideStack {
    fn default() -> Self {
        Self::new()
    }
}

impl WideStack {
    pub fn new() -> WideStack {
        WideStack { stack: Vec::with_capacity(64) }
    }
}

/// Cast one +X ray through the wide BVH (closest hit, leftmost-min tie
/// break — identical semantics to `traverse::closest_hit`).
pub fn closest_hit_wide(
    wb: &WideBvh,
    ray: &Ray,
    ts: &mut WideStack,
    counters: &mut Counters,
) -> Option<Hit> {
    closest_hit_wide_from(wb, ray, ts, counters, None)
}

/// The payload-min variant (paper §5.3): seed the traversal with the
/// best hit of previous sub-rays of the same Algorithm-6 query. Matches
/// `traverse::closest_hit_from` hit-for-hit: a carried hit always wins
/// equal-t ties; new hits within one cast prefer the smallest prim id.
pub fn closest_hit_wide_from(
    wb: &WideBvh,
    ray: &Ray,
    ts: &mut WideStack,
    counters: &mut Counters,
    init_best: Option<Hit>,
) -> Option<Hit> {
    counters.rays += 1;
    let [ox, oy, oz] = ray.origin;
    let (mut best_t, mut best_prim, mut have) = match init_best {
        Some(h) => (h.t, h.prim, true),
        None => (f32::INFINITY, u32::MAX, false),
    };
    let mut carried = init_best.is_some();
    ts.stack.clear();
    ts.stack.push((0, 0.0));
    while let Some((ni, entry)) = ts.stack.pop() {
        // Prune: nothing under this node can beat the current hit
        // (strictly-greater keeps equal-t candidates alive for the
        // leftmost tie-break, as in the binary traversal).
        if have && entry > best_t {
            continue;
        }
        counters.nodes_visited += 1;
        counters.node_fetches += 1;
        let node = &wb.nodes[ni as usize];
        counters.aabb_tests += 4;

        // Evaluate all four lanes as straight-line interval compares and
        // insertion-sort the hits front-to-back (at most 4 entries).
        let mut lane_t = [0.0f32; 4];
        let mut lane_ref = [0u32; 4];
        let mut lane_cnt = [0u8; 4];
        let mut m = 0usize;
        for k in 0..4 {
            let child = node.child[k];
            if child == INVALID_LANE {
                continue;
            }
            let inside = oy >= node.ymin[k]
                && oy <= node.ymax[k]
                && oz >= node.zmin[k]
                && oz <= node.zmax[k];
            if !inside {
                continue;
            }
            let t = (node.xmin[k] - ox).max(0.0);
            if have && t > best_t {
                continue;
            }
            let mut i = m;
            while i > 0 && lane_t[i - 1] > t {
                lane_t[i] = lane_t[i - 1];
                lane_ref[i] = lane_ref[i - 1];
                lane_cnt[i] = lane_cnt[i - 1];
                i -= 1;
            }
            lane_t[i] = t;
            lane_ref[i] = child;
            lane_cnt[i] = node.count[k];
            m += 1;
        }

        // Nearest-first: scan leaf lanes inline (tightening the carried
        // bound before farther lanes are considered), defer internal
        // lanes to the stack in far-to-near order.
        let mut defer = [(0u32, 0.0f32); 4];
        let mut d = 0usize;
        for i in 0..m {
            let cnt = lane_cnt[i] as usize;
            if cnt == 0 {
                defer[d] = (lane_ref[i], lane_t[i]);
                d += 1;
                continue;
            }
            if have && lane_t[i] > best_t {
                continue;
            }
            let first = lane_ref[i] as usize;
            for p in &wb.prims[first..first + cnt] {
                counters.tri_tests += 1;
                let t = p.x_plane - ox;
                if t < 0.0 {
                    continue; // behind the origin (t_min = 0)
                }
                if have && (t > best_t || (t == best_t && (carried || p.prim >= best_prim))) {
                    continue;
                }
                if oy > p.y_lo && oy < p.y_hi && oz > p.z_lo && oz < p.z_hi {
                    best_t = t;
                    best_prim = p.prim;
                    have = true;
                    carried = false;
                }
            }
        }
        for i in (0..d).rev() {
            ts.stack.push(defer[i]);
        }
    }
    if have {
        Some(Hit { t: best_t, prim: best_prim })
    } else {
        None
    }
}

/// A bundle of up to `packet_width` +X query rays traversed together
/// (SIMD over queries, not just child lanes). SoA: per-ray origins plus
/// per-ray best-hit state, exactly the scalar traversal's registers.
/// See the "Packet traversal" design note in `bvh/mod.rs` for why the
/// result is bit-identical to casting each ray alone.
#[derive(Default)]
pub struct RayPacket {
    ox: Vec<f32>,
    oy: Vec<f32>,
    oz: Vec<f32>,
    best_t: Vec<f32>,
    best_prim: Vec<u32>,
    have: Vec<bool>,
    carried: Vec<bool>,
}

impl RayPacket {
    pub fn new() -> RayPacket {
        RayPacket::default()
    }

    pub fn clear(&mut self) {
        self.ox.clear();
        self.oy.clear();
        self.oz.clear();
        self.best_t.clear();
        self.best_prim.clear();
        self.have.clear();
        self.carried.clear();
    }

    /// Add one ray, optionally seeded with a carried hit from an earlier
    /// Algorithm-6 sub-ray of the *same query* (per-ray seeds, so a
    /// packet can mix queries at different phases of their decomposition).
    pub fn push(&mut self, ray: &Ray, init_best: Option<Hit>) {
        let [ox, oy, oz] = ray.origin;
        self.ox.push(ox);
        self.oy.push(oy);
        self.oz.push(oz);
        match init_best {
            Some(h) => {
                self.best_t.push(h.t);
                self.best_prim.push(h.prim);
                self.have.push(true);
                self.carried.push(true);
            }
            None => {
                self.best_t.push(f32::INFINITY);
                self.best_prim.push(u32::MAX);
                self.have.push(false);
                self.carried.push(false);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.ox.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ox.is_empty()
    }

    /// Final hit of ray `i` (call after [`closest_hit_packet`]).
    pub fn hit(&self, i: usize) -> Option<Hit> {
        if self.have[i] {
            Some(Hit { t: self.best_t[i], prim: self.best_prim[i] })
        } else {
            None
        }
    }

    /// The (y, z) interval envelope of every origin in the packet.
    fn envelope(&self) -> (f32, f32, f32, f32) {
        let mut ey_min = f32::INFINITY;
        let mut ey_max = f32::NEG_INFINITY;
        let mut ez_min = f32::INFINITY;
        let mut ez_max = f32::NEG_INFINITY;
        for i in 0..self.len() {
            ey_min = ey_min.min(self.oy[i]);
            ey_max = ey_max.max(self.oy[i]);
            ez_min = ez_min.min(self.oz[i]);
            ez_max = ez_max.max(self.oz[i]);
        }
        (ey_min, ey_max, ez_min, ez_max)
    }

    /// Loosest per-packet prune bound: the largest per-ray `best_t`
    /// (rays with no hit yet contribute +inf). A node whose entry
    /// exceeds this cannot improve any ray.
    fn tmax(&self) -> f32 {
        let mut tm = f32::NEG_INFINITY;
        for i in 0..self.len() {
            tm = tm.max(if self.have[i] { self.best_t[i] } else { f32::INFINITY });
        }
        tm
    }
}

/// Fraction of the root extent past which a packet's origin envelope is
/// considered divergent: the shared descent would visit roughly the
/// union of every ray's node set, so amortization is lost and the
/// per-ray path is cheaper. Results are identical either way — the
/// fallback is a pure cost decision.
pub const PACKET_DIVERGENCE_FRAC: f32 = 0.25;

/// Traverse the wide BVH once for a whole packet of +X rays, updating
/// each ray's best hit in place. Bit-identical to running
/// [`closest_hit_wide_from`] per ray (with its `init_best` seed):
/// every per-ray accept test below is the scalar rule verbatim, and all
/// scalar prunes are strict, so any traversal order with conservative
/// (envelope / packet-max) pruning converges to the same
/// lexicographic-min (t, prim) answer per ray.
///
/// Counters: `rays` counts packet members; `nodes_visited` counts node
/// pops *per ray serviced* (one shared pop visits the node on behalf of
/// every packet member, so the charge is the packet size — the
/// scalar-equivalent per-ray work); `node_fetches` counts one per pop
/// per *packet* — the amortized memory quantity, so
/// `nodes_visited / node_fetches` is the amortization factor and
/// `node_fetches == nodes_visited` is the scalar/fallback signature;
/// `aabb_tests` counts 4 envelope lane tests per pop plus one per-ray
/// containment test per surviving lane; `tri_tests` counts per-ray prim
/// tests as scalar.
pub fn closest_hit_packet(
    wb: &WideBvh,
    packet: &mut RayPacket,
    ts: &mut WideStack,
    counters: &mut Counters,
) {
    let p = packet.len();
    if p == 0 {
        return;
    }
    counters.rays += p as u64;
    let (ey_min, ey_max, ez_min, ez_max) = packet.envelope();

    // Divergence fallback: compare the envelope extent to the root's
    // lane-bounds union. A packet spread over a large fraction of the
    // scene shares almost no traversal, so descend per ray instead
    // (scalar counting; `rays` was already charged above).
    let root = &wb.nodes[0];
    let (mut ry_min, mut ry_max) = (f32::INFINITY, f32::NEG_INFINITY);
    let (mut rz_min, mut rz_max) = (f32::INFINITY, f32::NEG_INFINITY);
    for k in 0..4 {
        if root.child[k] == INVALID_LANE {
            continue;
        }
        ry_min = ry_min.min(root.ymin[k]);
        ry_max = ry_max.max(root.ymax[k]);
        rz_min = rz_min.min(root.zmin[k]);
        rz_max = rz_max.max(root.zmax[k]);
    }
    let root_extent = (ry_max - ry_min).max(0.0) + (rz_max - rz_min).max(0.0);
    let env_extent = (ey_max - ey_min) + (ez_max - ez_min);
    if p > 1 && env_extent > PACKET_DIVERGENCE_FRAC * root_extent {
        for i in 0..p {
            let ray = Ray::new([packet.ox[i], packet.oy[i], packet.oz[i]]);
            let init = if packet.carried[i] {
                Some(Hit { t: packet.best_t[i], prim: packet.best_prim[i] })
            } else {
                None
            };
            let mut solo = Counters::default();
            let hit = closest_hit_wide_from(wb, &ray, ts, &mut solo, init);
            // The per-ray cast re-counts its own ray; keep ours.
            solo.rays = 0;
            counters.add(&solo);
            match hit {
                Some(h) => {
                    packet.best_t[i] = h.t;
                    packet.best_prim[i] = h.prim;
                    packet.have[i] = true;
                    packet.carried[i] = false;
                }
                None => {
                    packet.have[i] = false;
                }
            }
        }
        return;
    }

    // All rays in one batch share the ray-origin plane θ, but take the
    // max defensively: entry computed from max_ox lower-bounds every
    // per-ray entry, keeping the packet prune conservative.
    let mut max_ox = f32::NEG_INFINITY;
    for i in 0..p {
        max_ox = max_ox.max(packet.ox[i]);
    }

    ts.stack.clear();
    ts.stack.push((0, 0.0));
    while let Some((ni, min_entry)) = ts.stack.pop() {
        // Packet prune: conservative analogue of the scalar strict
        // `entry > best_t` — skip only when *no* ray can improve.
        if min_entry > packet.tmax() {
            continue;
        }
        // One fetch serves the whole packet; the visit charge stays
        // per-ray so `nodes_visited / node_fetches` reads as the
        // amortization factor (see the fn docs).
        counters.nodes_visited += p as u64;
        counters.node_fetches += 1;
        let node = &wb.nodes[ni as usize];
        counters.aabb_tests += 4;

        let mut lane_t = [0.0f32; 4];
        let mut lane_k = [0usize; 4];
        let mut m = 0usize;
        for k in 0..4 {
            let child = node.child[k];
            if child == INVALID_LANE {
                continue;
            }
            // Envelope screen: if the packet's (y, z) envelope misses
            // the lane interval, every member origin misses it too.
            let overlap = ey_max >= node.ymin[k]
                && ey_min <= node.ymax[k]
                && ez_max >= node.zmin[k]
                && ez_min <= node.zmax[k];
            if !overlap {
                continue;
            }
            let t = (node.xmin[k] - max_ox).max(0.0);
            if t > packet.tmax() {
                continue;
            }
            let mut i = m;
            while i > 0 && lane_t[i - 1] > t {
                lane_t[i] = lane_t[i - 1];
                lane_k[i] = lane_k[i - 1];
                i -= 1;
            }
            lane_t[i] = t;
            lane_k[i] = k;
            m += 1;
        }

        // Nearest-first as in the scalar path: leaf lanes resolve per
        // ray inline (tightening the packet bound before farther lanes),
        // internal lanes defer to the shared stack far-to-near.
        let mut defer = [(0u32, 0.0f32); 4];
        let mut d = 0usize;
        for li in 0..m {
            let k = lane_k[li];
            let cnt = node.count[k] as usize;
            if cnt == 0 {
                defer[d] = (node.child[k], lane_t[li]);
                d += 1;
                continue;
            }
            let first = node.child[k] as usize;
            for i in 0..p {
                let (oy, oz) = (packet.oy[i], packet.oz[i]);
                counters.aabb_tests += 1;
                let inside = oy >= node.ymin[k]
                    && oy <= node.ymax[k]
                    && oz >= node.zmin[k]
                    && oz <= node.zmax[k];
                if !inside {
                    continue; // this ray deactivates for the lane
                }
                let t = (node.xmin[k] - packet.ox[i]).max(0.0);
                if packet.have[i] && t > packet.best_t[i] {
                    continue;
                }
                for pr in &wb.prims[first..first + cnt] {
                    counters.tri_tests += 1;
                    let t = pr.x_plane - packet.ox[i];
                    if t < 0.0 {
                        continue;
                    }
                    if packet.have[i]
                        && (t > packet.best_t[i]
                            || (t == packet.best_t[i]
                                && (packet.carried[i] || pr.prim >= packet.best_prim[i])))
                    {
                        continue;
                    }
                    if oy > pr.y_lo && oy < pr.y_hi && oz > pr.z_lo && oz < pr.z_hi {
                        packet.best_t[i] = t;
                        packet.best_prim[i] = pr.prim;
                        packet.have[i] = true;
                        packet.carried[i] = false;
                    }
                }
            }
        }
        for i in (0..d).rev() {
            ts.stack.push(defer[i]);
        }
    }
}

impl WideBvh {
    /// Refit after triangle positions changed (dynamic RMQ, §7.iii):
    /// re-extract every leaf record from its triangle, then recompute the
    /// per-lane bounds bottom-up. Valid because child nodes always follow
    /// their parent in `nodes` (collapse emits DFS preorder).
    pub fn refit(&mut self, tris: &[Triangle]) {
        for p in self.prims.iter_mut() {
            *p = WidePrim::from_triangle(&tris[p.prim as usize]);
        }
        for i in (0..self.nodes.len()).rev() {
            self.refit_lanes(i);
        }
    }

    /// Recompute all four lane bounds of node `i` from its current
    /// children (leaf runs read `prims`; internal lanes aggregate the
    /// child node's lanes). Shared by the full bottom-up sweep and the
    /// point-refit path walk.
    fn refit_lanes(&mut self, i: usize) {
        for k in 0..4 {
            let child = self.nodes[i].child[k];
            if child == INVALID_LANE {
                continue;
            }
            let cnt = self.nodes[i].count[k] as usize;
            let (mut ymin, mut ymax) = (f32::INFINITY, f32::NEG_INFINITY);
            let (mut zmin, mut zmax) = (f32::INFINITY, f32::NEG_INFINITY);
            let mut xmin = f32::INFINITY;
            if cnt > 0 {
                for p in &self.prims[child as usize..child as usize + cnt] {
                    ymin = ymin.min(p.y_lo);
                    ymax = ymax.max(p.y_hi);
                    zmin = zmin.min(p.z_lo);
                    zmax = zmax.max(p.z_hi);
                    xmin = xmin.min(p.x_plane);
                }
            } else {
                let c = self.nodes[child as usize];
                for j in 0..4 {
                    if c.child[j] == INVALID_LANE {
                        continue;
                    }
                    ymin = ymin.min(c.ymin[j]);
                    ymax = ymax.max(c.ymax[j]);
                    zmin = zmin.min(c.zmin[j]);
                    zmax = zmax.max(c.zmax[j]);
                    xmin = xmin.min(c.xmin[j]);
                }
            }
            let n = &mut self.nodes[i];
            n.ymin[k] = ymin;
            n.ymax[k] = ymax;
            n.zmin[k] = zmin;
            n.zmax[k] = zmax;
            n.xmin[k] = xmin;
        }
    }

    /// Topology links enabling point refits ([`WideBvh::refit_prims`]).
    /// Built once per structure; refits never change topology, so the
    /// links stay valid for the structure's lifetime.
    pub fn refit_links(&self) -> WideRefitLinks {
        let mut parent = vec![0u32; self.nodes.len()];
        let mut node_of_slot = vec![0u32; self.prims.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            for k in 0..4 {
                let c = n.child[k];
                if c == INVALID_LANE {
                    continue;
                }
                let cnt = n.count[k] as usize;
                if cnt > 0 {
                    for s in c as usize..c as usize + cnt {
                        node_of_slot[s] = i as u32;
                    }
                } else {
                    parent[c as usize] = i as u32;
                }
            }
        }
        // Prim ids are dense 0..prims.len() in both geometry modes, so a
        // plain inverse permutation maps triangle index -> prims slot.
        let mut slot_of_prim = vec![0u32; self.prims.len()];
        for (s, p) in self.prims.iter().enumerate() {
            slot_of_prim[p.prim as usize] = s as u32;
        }
        WideRefitLinks { parent, node_of_slot, slot_of_prim }
    }

    /// Point refit: re-extract only the given primitives' records and
    /// recompute the node lanes on their leaf-to-root paths — Θ(k·depth)
    /// against the full sweep's Θ(n). Same idempotent-path argument as
    /// [`crate::bvh::Bvh::refit_prims`]: equivalent to
    /// [`refit`](Self::refit) provided `prims` covers every changed
    /// triangle.
    pub fn refit_prims(&mut self, tris: &[Triangle], prims: &[u32], links: &WideRefitLinks) {
        for &p in prims {
            let slot = links.slot_of_prim[p as usize] as usize;
            self.prims[slot] = WidePrim::from_triangle(&tris[p as usize]);
            let mut i = links.node_of_slot[slot] as usize;
            loop {
                self.refit_lanes(i);
                if i == 0 {
                    break;
                }
                i = links.parent[i] as usize;
            }
        }
    }

    /// Heap bytes of the structure's own allocations (nodes + leaf
    /// records). [`WideRefitLinks`] are owned by whoever built them, so
    /// their bytes are reported by [`WideRefitLinks::memory_bytes`] and
    /// summed by the owning solver — see `RtxRmq::memory_bytes`.
    pub fn memory_bytes(&self) -> usize {
        self.nodes.len() * std::mem::size_of::<WideNode>()
            + self.prims.len() * std::mem::size_of::<WidePrim>()
    }

    /// Structural invariants (tests + debug builds): every triangle in
    /// exactly one leaf run, child lanes bound their contents, internal
    /// lanes point forward (refit relies on it), all nodes reachable.
    pub fn validate(&self, tris: &[Triangle]) -> Result<(), String> {
        if self.nodes.is_empty() {
            return Err("empty wide bvh".into());
        }
        if self.prims.len() != tris.len() {
            return Err(format!("{} prim records for {} triangles", self.prims.len(), tris.len()));
        }
        let mut seen = vec![false; tris.len()];
        let mut visited = 0usize;
        let mut stack = vec![0u32];
        while let Some(ni) = stack.pop() {
            visited += 1;
            let node = &self.nodes[ni as usize];
            for k in 0..4 {
                let child = node.child[k];
                if child == INVALID_LANE {
                    continue;
                }
                let cnt = node.count[k] as usize;
                if cnt > 0 {
                    if cnt > self.leaf_size.max(1) {
                        return Err(format!("leaf lane of {cnt} > leaf_size {}", self.leaf_size));
                    }
                    let first = child as usize;
                    if first + cnt > self.prims.len() {
                        return Err("leaf run out of range".into());
                    }
                    for p in &self.prims[first..first + cnt] {
                        let id = p.prim as usize;
                        if id >= tris.len() {
                            return Err(format!("prim id {id} out of range"));
                        }
                        if seen[id] {
                            return Err(format!("prim {id} in two leaves"));
                        }
                        seen[id] = true;
                        if *p != WidePrim::from_triangle(&tris[id]) {
                            return Err(format!("prim record {id} stale vs triangle"));
                        }
                        let eps = 1e-6f32;
                        if p.y_lo < node.ymin[k] - eps
                            || p.y_hi > node.ymax[k] + eps
                            || p.z_lo < node.zmin[k] - eps
                            || p.z_hi > node.zmax[k] + eps
                            || p.x_plane < node.xmin[k] - eps
                        {
                            return Err(format!("prim {id} escapes lane bounds"));
                        }
                    }
                } else {
                    if child as usize <= ni as usize || child as usize >= self.nodes.len() {
                        return Err("internal lane must point forward and in range".into());
                    }
                    stack.push(child);
                }
            }
        }
        if visited != self.nodes.len() {
            return Err(format!("unreachable wide nodes: {visited} of {}", self.nodes.len()));
        }
        if !seen.iter().all(|&s| s) {
            return Err("some prims not in any leaf".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bvh::build::{build, collapse_to_wide};
    use crate::bvh::traverse::{closest_hit, closest_hit_from, TraversalStack};
    use crate::bvh::Builder;
    use crate::geometry::flat::{build_scene, ray_for_query, ray_origin_x};
    use crate::rmq::naive_rmq;
    use crate::util::proptest::{check, gen};

    #[test]
    fn collapse_valid_structure_both_builders() {
        check("wide structural invariants", 40, |rng| {
            let xs = gen::f32_array(rng, 1..=600);
            let tris = build_scene(&xs);
            for builder in [Builder::BinnedSah, Builder::Lbvh] {
                let bvh = build(&tris, builder, 4);
                let wb = collapse_to_wide(&bvh, &tris);
                wb.validate(&tris)?;
            }
            Ok(())
        });
    }

    #[test]
    fn single_triangle_collapses() {
        let tris = build_scene(&[0.5]);
        let bvh = build(&tris, Builder::BinnedSah, 4);
        let wb = collapse_to_wide(&bvh, &tris);
        assert_eq!(wb.nodes.len(), 1);
        assert_eq!(wb.prims.len(), 1);
        wb.validate(&tris).unwrap();
        let ray = ray_for_query(0, 0, 1, ray_origin_x(&[0.5]));
        let mut c = Counters::default();
        let hit = closest_hit_wide(&wb, &ray, &mut WideStack::new(), &mut c).unwrap();
        assert_eq!(hit.prim, 0);
    }

    #[test]
    fn wide_hits_match_binary_and_oracle() {
        check("wide == binary == rmq (sah+lbvh)", 60, |rng| {
            let xs = gen::f32_array(rng, 1..=800);
            let n = xs.len();
            let tris = build_scene(&xs);
            let theta = ray_origin_x(&xs);
            for builder in [Builder::BinnedSah, Builder::Lbvh] {
                let bvh = build(&tris, builder, 4);
                let wb = collapse_to_wide(&bvh, &tris);
                let mut bs = TraversalStack::new();
                let mut ws = WideStack::new();
                let mut cb = Counters::default();
                let mut cw = Counters::default();
                for _ in 0..16 {
                    let (l, r) = gen::query(rng, n);
                    let ray = ray_for_query(l as u32, r as u32, n, theta);
                    let bh = closest_hit(&bvh, &tris, &ray, &mut bs, &mut cb)
                        .ok_or_else(|| format!("binary no hit for ({l},{r})"))?;
                    let wh = closest_hit_wide(&wb, &ray, &mut ws, &mut cw)
                        .ok_or_else(|| format!("wide no hit for ({l},{r})"))?;
                    if bh != wh {
                        return Err(format!("{builder:?} ({l},{r}): binary {bh:?} wide {wh:?}"));
                    }
                    let want = naive_rmq(&xs, l, r);
                    if wh.prim as usize != want {
                        return Err(format!("({l},{r}): wide {} want {want}", wh.prim));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn wide_ties_resolve_leftmost() {
        check("wide equal values leftmost", 60, |rng| {
            let xs = gen::dup_array(rng, 1..=400, 2);
            let n = xs.len();
            let tris = build_scene(&xs);
            let bvh = build(&tris, Builder::BinnedSah, 4);
            let wb = collapse_to_wide(&bvh, &tris);
            let theta = ray_origin_x(&xs);
            let mut ws = WideStack::new();
            let mut c = Counters::default();
            for _ in 0..16 {
                let (l, r) = gen::query(rng, n);
                let ray = ray_for_query(l as u32, r as u32, n, theta);
                let hit = closest_hit_wide(&wb, &ray, &mut ws, &mut c).unwrap();
                let want = naive_rmq(&xs, l, r);
                if hit.prim as usize != want {
                    return Err(format!("({l},{r}): got {} want {want}", hit.prim));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn carried_hits_match_binary() {
        // The Algorithm-6 payload-min path: seed both traversals with the
        // same prior hit and require identical final hits — including
        // carried hits surviving equal-t ties.
        check("wide carried-hit == binary", 40, |rng| {
            let xs = gen::dup_array(rng, 2..=300, 3);
            let n = xs.len();
            let tris = build_scene(&xs);
            let bvh = build(&tris, Builder::BinnedSah, 4);
            let wb = collapse_to_wide(&bvh, &tris);
            let theta = ray_origin_x(&xs);
            let mut bs = TraversalStack::new();
            let mut ws = WideStack::new();
            let mut cb = Counters::default();
            let mut cw = Counters::default();
            for _ in 0..12 {
                let (l1, r1) = gen::query(rng, n);
                let first = ray_for_query(l1 as u32, r1 as u32, n, theta);
                let seed_b = closest_hit(&bvh, &tris, &first, &mut bs, &mut cb);
                let seed_w = closest_hit_wide(&wb, &first, &mut ws, &mut cw);
                if seed_b != seed_w {
                    return Err(format!("seed mismatch: {seed_b:?} vs {seed_w:?}"));
                }
                let (l2, r2) = gen::query(rng, n);
                let second = ray_for_query(l2 as u32, r2 as u32, n, theta);
                let bh = closest_hit_from(&bvh, &tris, &second, &mut bs, &mut cb, seed_b);
                let wh = closest_hit_wide_from(&wb, &second, &mut ws, &mut cw, seed_w);
                if bh != wh {
                    return Err(format!(
                        "carried ({l1},{r1})→({l2},{r2}): binary {bh:?} wide {wh:?}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn refit_tracks_value_updates() {
        check("wide refit == rebuild answers", 30, |rng| {
            let mut xs = gen::f32_array(rng, 8..=256);
            let n = xs.len();
            let tris = build_scene(&xs);
            let bvh = build(&tris, Builder::BinnedSah, 4);
            let mut wb = collapse_to_wide(&bvh, &tris);
            // Point updates re-shape triangles; refit instead of rebuild.
            for _ in 0..4 {
                let i = rng.range(0, n - 1);
                xs[i] = rng.f32();
            }
            let tris = build_scene(&xs);
            wb.refit(&tris);
            wb.validate(&tris)?;
            let theta = ray_origin_x(&xs);
            let mut ws = WideStack::new();
            let mut c = Counters::default();
            for _ in 0..12 {
                let (l, r) = gen::query(rng, n);
                let ray = ray_for_query(l as u32, r as u32, n, theta);
                let hit = closest_hit_wide(&wb, &ray, &mut ws, &mut c).unwrap();
                let want = naive_rmq(&xs, l, r);
                if hit.prim as usize != want {
                    return Err(format!("after refit ({l},{r}): got {} want {want}", hit.prim));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn wide_visits_fewer_nodes_than_binary() {
        // The point of the layout: one wide pop replaces ~3 binary pops.
        let xs = crate::util::rng::Rng::new(13).uniform_f32_vec(4096);
        let tris = build_scene(&xs);
        let bvh = build(&tris, Builder::BinnedSah, 4);
        let wb = collapse_to_wide(&bvh, &tris);
        let theta = ray_origin_x(&xs);
        let mut cb = Counters::default();
        let mut cw = Counters::default();
        let mut bs = TraversalStack::new();
        let mut ws = WideStack::new();
        for i in 0..64u32 {
            let ray = ray_for_query(i * 8, i * 8 + 500, 4096, theta);
            closest_hit(&bvh, &tris, &ray, &mut bs, &mut cb).unwrap();
            closest_hit_wide(&wb, &ray, &mut ws, &mut cw).unwrap();
        }
        assert!(
            cw.nodes_visited * 3 < cb.nodes_visited * 2,
            "wide {} vs binary {} node visits",
            cw.nodes_visited,
            cb.nodes_visited
        );
    }

    #[test]
    fn packet_matches_scalar_per_ray() {
        // The tentpole equivalence: a packet of random rays — some seeded
        // with carried hits — finishes with the exact per-ray hits the
        // scalar traversal produces, for every packet width incl. 1 and
        // a non-power-of-two.
        check("packet == scalar per ray", 40, |rng| {
            let xs = gen::dup_array(rng, 2..=400, 2);
            let n = xs.len();
            let tris = build_scene(&xs);
            let bvh = build(&tris, Builder::BinnedSah, 4);
            let wb = collapse_to_wide(&bvh, &tris);
            let theta = ray_origin_x(&xs);
            let mut ws = WideStack::new();
            let mut cs = Counters::default();
            for &width in &[1usize, 4, 7, 8, 16] {
                let mut packet = RayPacket::new();
                let mut rays = Vec::new();
                let mut seeds = Vec::new();
                for _ in 0..width {
                    let (l, r) = gen::query(rng, n);
                    let ray = ray_for_query(l as u32, r as u32, n, theta);
                    // Half the rays carry a seed hit from another query.
                    let seed = if rng.range(0, 1) == 1 {
                        let (l2, r2) = gen::query(rng, n);
                        let prev = ray_for_query(l2 as u32, r2 as u32, n, theta);
                        closest_hit_wide(&wb, &prev, &mut ws, &mut cs)
                    } else {
                        None
                    };
                    packet.push(&ray, seed);
                    rays.push(ray);
                    seeds.push(seed);
                }
                let mut cp = Counters::default();
                closest_hit_packet(&wb, &mut packet, &mut ws, &mut cp);
                for i in 0..width {
                    let want = closest_hit_wide_from(&wb, &rays[i], &mut ws, &mut cs, seeds[i]);
                    if packet.hit(i) != want {
                        return Err(format!(
                            "width {width} ray {i}: packet {:?} scalar {want:?}",
                            packet.hit(i)
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn packet_node_fetches_decrease_with_width() {
        // The point of the packet path: coherent sorted queries fetch
        // strictly fewer nodes per query as the packet widens.
        let xs = crate::util::rng::Rng::new(21).uniform_f32_vec(4096);
        let tris = build_scene(&xs);
        let bvh = build(&tris, Builder::BinnedSah, 4);
        let wb = collapse_to_wide(&bvh, &tris);
        let theta = ray_origin_x(&xs);
        // Sorted small-range batch: the regime PR 1's chunk sort creates.
        let queries: Vec<(u32, u32)> = (0..256u32).map(|i| (i * 8, i * 8 + 48)).collect();
        let mut fetches = Vec::new();
        let mut hits_ref: Option<Vec<Option<Hit>>> = None;
        for &width in &[1usize, 4, 8, 16] {
            let mut c = Counters::default();
            let mut ws = WideStack::new();
            let mut packet = RayPacket::new();
            let mut hits = Vec::new();
            for chunk in queries.chunks(width) {
                packet.clear();
                for &(l, r) in chunk {
                    packet.push(&ray_for_query(l, r, 4096, theta), None);
                }
                closest_hit_packet(&wb, &mut packet, &mut ws, &mut c);
                for i in 0..chunk.len() {
                    hits.push(packet.hit(i));
                }
            }
            match &hits_ref {
                None => hits_ref = Some(hits),
                Some(prev) => assert_eq!(prev, &hits, "width {width} answers differ"),
            }
            fetches.push(c.node_fetches);
        }
        for w in 1..fetches.len() {
            assert!(
                fetches[w] < fetches[w - 1],
                "node fetches not strictly decreasing: {fetches:?}"
            );
        }
    }

    #[test]
    fn packet_divergence_falls_back_and_matches() {
        // Rays spread across the whole scene: the envelope blows past
        // PACKET_DIVERGENCE_FRAC of the root extent, the packet drops to
        // per-ray descents, and answers still match scalar exactly.
        let xs = crate::util::rng::Rng::new(22).uniform_f32_vec(2048);
        let tris = build_scene(&xs);
        let bvh = build(&tris, Builder::BinnedSah, 4);
        let wb = collapse_to_wide(&bvh, &tris);
        let theta = ray_origin_x(&xs);
        let n = xs.len();
        let queries: [(u32, u32); 4] =
            [(0, 10), (600, 1400), (2000, 2047), (5, (n as u32) - 5)];
        let mut packet = RayPacket::new();
        for &(l, r) in &queries {
            packet.push(&ray_for_query(l, r, n, theta), None);
        }
        let mut ws = WideStack::new();
        let mut cp = Counters::default();
        closest_hit_packet(&wb, &mut packet, &mut ws, &mut cp);
        // Fallback taken: per-ray counting means one fetch per pop, and
        // four solo descents pop more nodes than one shared descent of a
        // tight packet would — equal to nodes_visited is the signature.
        assert_eq!(cp.node_fetches, cp.nodes_visited, "expected scalar fallback counting");
        let mut cs = Counters::default();
        for (i, &(l, r)) in queries.iter().enumerate() {
            let ray = ray_for_query(l, r, n, theta);
            let want = closest_hit_wide(&wb, &ray, &mut ws, &mut cs);
            assert_eq!(packet.hit(i), want, "ray {i} diverged from scalar");
        }
    }

    #[test]
    fn memory_is_denser_than_binary_nodes() {
        let xs = crate::util::rng::Rng::new(14).uniform_f32_vec(1 << 12);
        let tris = build_scene(&xs);
        let bvh = build(&tris, Builder::BinnedSah, 4);
        let wb = collapse_to_wide(&bvh, &tris);
        // Wide node count must be well under the binary internal count.
        assert!(wb.nodes.len() * 2 < bvh.nodes.len());
        assert!(wb.memory_bytes() > 0);
    }
}

//! 4-wide structure-of-arrays BVH specialized for the paper's +X point
//! rays — the hot-path acceleration layout (`AccelLayout::Wide`).
//!
//! Rationale (paper §5.2 attributes RTXRMQ's cost to "bounding box
//! intersections between the ray and the internal nodes"): for a ray
//! `(θ, y, z) + t·(1, 0, 0)` an AABB slab test degenerates to two
//! interval checks on (y, z) plus an entry distance `xmin − θ`. A wide
//! node stores those per-lane quantities as small fixed arrays
//! (`ymin[4] / ymax[4] / zmin[4] / zmax[4] / xmin[4]`), so all four
//! child tests run as straight-line, auto-vectorizable compares with no
//! pointer chasing — the software analogue of how RT hardware amortizes
//! box tests across wide, shallow trees (RT-HDIST et al.).
//!
//! Leaves are compact [`WidePrim`] records (`x_plane, y_lo, y_hi, z_lo,
//! z_hi, prim` — 24 bytes, cache-linear) instead of full `Triangle`
//! dereferences through a permutation array.
//!
//! The binary layout ([`super::Bvh`]) remains the correctness oracle and
//! the cost-model reference; [`crate::bvh::build::collapse_to_wide`]
//! folds a built binary tree into this layout, so both builders (SAH and
//! LBVH) feed it. Hits are bit-identical between layouts (property-tested
//! in `tests/layout_equivalence.rs`), including leftmost tie-breaks and
//! the Algorithm-6 carried-hit sub-rays.

use super::traverse::{Counters, Hit};
use crate::geometry::{Ray, Triangle};

/// Sentinel for an unused child lane.
pub const INVALID_LANE: u32 = u32::MAX;

/// One 4-wide node. Per-lane arrays hold the child AABB projections the
/// +X specialization needs; `child[k]` is either an index into
/// [`WideBvh::nodes`] (when `count[k] == 0`) or the first index of a
/// contiguous run of `count[k]` records in [`WideBvh::prims`]
/// (when `count[k] > 0`). Unused lanes have `child[k] == INVALID_LANE`
/// and inverted bounds so every interval test fails.
#[derive(Clone, Copy, Debug)]
pub struct WideNode {
    pub ymin: [f32; 4],
    pub ymax: [f32; 4],
    pub zmin: [f32; 4],
    pub zmax: [f32; 4],
    /// Lower x bound of the lane — `xmin − origin.x` is the ray entry
    /// distance (clamped to 0). The +X specialization drops `xmax`: for
    /// valid query rays θ lies strictly below every value plane, so no
    /// subtree is ever entirely behind the origin; prims behind the
    /// origin are rejected per-record by the `t < 0` test.
    pub xmin: [f32; 4],
    pub child: [u32; 4],
    pub count: [u8; 4],
}

impl WideNode {
    pub fn empty() -> WideNode {
        WideNode {
            ymin: [f32::INFINITY; 4],
            ymax: [f32::NEG_INFINITY; 4],
            zmin: [f32::INFINITY; 4],
            zmax: [f32::NEG_INFINITY; 4],
            xmin: [f32::INFINITY; 4],
            child: [INVALID_LANE; 4],
            count: [0; 4],
        }
    }
}

/// Compact per-leaf primitive record: the value plane, the open (y, z)
/// footprint rectangle, and the primitive id to report. For every valid
/// query origin the footprint test is exactly
/// `y_lo < y < y_hi && z_lo < z < z_hi` (see the §Perf L3.1 note in
/// `bvh::traverse` — the hypotenuse never cuts a query space).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WidePrim {
    pub x_plane: f32,
    pub y_lo: f32,
    pub y_hi: f32,
    pub z_lo: f32,
    pub z_hi: f32,
    pub prim: u32,
}

impl WidePrim {
    /// Extract the record from a scene triangle (vertex layout per
    /// `geometry::flat` / `geometry::blocks`: v0 = right-angle corner
    /// (l, r), v1 = top, v2 = left).
    #[inline]
    pub fn from_triangle(tri: &Triangle) -> WidePrim {
        WidePrim {
            x_plane: tri.x_plane(),
            y_lo: tri.v2[1],
            y_hi: tri.v0[1],
            z_lo: tri.v0[2],
            z_hi: tri.v1[2],
            prim: tri.prim,
        }
    }
}

/// Topology links for point refits ([`WideBvh::refit_prims`]). Kept
/// outside [`WideBvh`] so only the dynamic-update path pays for them.
pub struct WideRefitLinks {
    /// `parent[i]` = node whose internal lane points at node `i`
    /// (`parent[0] == 0`: the root).
    pub parent: Vec<u32>,
    /// `node_of_slot[s]` = node whose leaf-lane run contains prims
    /// slot `s`.
    pub node_of_slot: Vec<u32>,
    /// `slot_of_prim[p]` = prims slot holding primitive id `p`.
    pub slot_of_prim: Vec<u32>,
}

impl WideRefitLinks {
    /// Heap bytes of the link tables. Once a solver builds them they
    /// stay resident for its lifetime, so resident-memory accounting
    /// must include them (they were the largest omission in the old
    /// node+prim-only tally).
    pub fn memory_bytes(&self) -> usize {
        (self.parent.len() + self.node_of_slot.len() + self.slot_of_prim.len()) * 4
    }
}

/// The wide acceleration structure.
pub struct WideBvh {
    pub nodes: Vec<WideNode>,
    pub prims: Vec<WidePrim>,
    /// Max leaf size inherited from the collapsed binary tree.
    pub leaf_size: usize,
}

/// Reusable wide-traversal stack (allocation-free hot loop — one per
/// worker). BVH4 depth is roughly half the binary depth, so the stack
/// stays small.
pub struct WideStack {
    stack: Vec<(u32, f32)>,
}

impl Default for WideStack {
    fn default() -> Self {
        Self::new()
    }
}

impl WideStack {
    pub fn new() -> WideStack {
        WideStack { stack: Vec::with_capacity(64) }
    }
}

/// Cast one +X ray through the wide BVH (closest hit, leftmost-min tie
/// break — identical semantics to `traverse::closest_hit`).
pub fn closest_hit_wide(
    wb: &WideBvh,
    ray: &Ray,
    ts: &mut WideStack,
    counters: &mut Counters,
) -> Option<Hit> {
    closest_hit_wide_from(wb, ray, ts, counters, None)
}

/// The payload-min variant (paper §5.3): seed the traversal with the
/// best hit of previous sub-rays of the same Algorithm-6 query. Matches
/// `traverse::closest_hit_from` hit-for-hit: a carried hit always wins
/// equal-t ties; new hits within one cast prefer the smallest prim id.
pub fn closest_hit_wide_from(
    wb: &WideBvh,
    ray: &Ray,
    ts: &mut WideStack,
    counters: &mut Counters,
    init_best: Option<Hit>,
) -> Option<Hit> {
    counters.rays += 1;
    let [ox, oy, oz] = ray.origin;
    let (mut best_t, mut best_prim, mut have) = match init_best {
        Some(h) => (h.t, h.prim, true),
        None => (f32::INFINITY, u32::MAX, false),
    };
    let mut carried = init_best.is_some();
    ts.stack.clear();
    ts.stack.push((0, 0.0));
    while let Some((ni, entry)) = ts.stack.pop() {
        // Prune: nothing under this node can beat the current hit
        // (strictly-greater keeps equal-t candidates alive for the
        // leftmost tie-break, as in the binary traversal).
        if have && entry > best_t {
            continue;
        }
        counters.nodes_visited += 1;
        let node = &wb.nodes[ni as usize];
        counters.aabb_tests += 4;

        // Evaluate all four lanes as straight-line interval compares and
        // insertion-sort the hits front-to-back (at most 4 entries).
        let mut lane_t = [0.0f32; 4];
        let mut lane_ref = [0u32; 4];
        let mut lane_cnt = [0u8; 4];
        let mut m = 0usize;
        for k in 0..4 {
            let child = node.child[k];
            if child == INVALID_LANE {
                continue;
            }
            let inside = oy >= node.ymin[k]
                && oy <= node.ymax[k]
                && oz >= node.zmin[k]
                && oz <= node.zmax[k];
            if !inside {
                continue;
            }
            let t = (node.xmin[k] - ox).max(0.0);
            if have && t > best_t {
                continue;
            }
            let mut i = m;
            while i > 0 && lane_t[i - 1] > t {
                lane_t[i] = lane_t[i - 1];
                lane_ref[i] = lane_ref[i - 1];
                lane_cnt[i] = lane_cnt[i - 1];
                i -= 1;
            }
            lane_t[i] = t;
            lane_ref[i] = child;
            lane_cnt[i] = node.count[k];
            m += 1;
        }

        // Nearest-first: scan leaf lanes inline (tightening the carried
        // bound before farther lanes are considered), defer internal
        // lanes to the stack in far-to-near order.
        let mut defer = [(0u32, 0.0f32); 4];
        let mut d = 0usize;
        for i in 0..m {
            let cnt = lane_cnt[i] as usize;
            if cnt == 0 {
                defer[d] = (lane_ref[i], lane_t[i]);
                d += 1;
                continue;
            }
            if have && lane_t[i] > best_t {
                continue;
            }
            let first = lane_ref[i] as usize;
            for p in &wb.prims[first..first + cnt] {
                counters.tri_tests += 1;
                let t = p.x_plane - ox;
                if t < 0.0 {
                    continue; // behind the origin (t_min = 0)
                }
                if have && (t > best_t || (t == best_t && (carried || p.prim >= best_prim))) {
                    continue;
                }
                if oy > p.y_lo && oy < p.y_hi && oz > p.z_lo && oz < p.z_hi {
                    best_t = t;
                    best_prim = p.prim;
                    have = true;
                    carried = false;
                }
            }
        }
        for i in (0..d).rev() {
            ts.stack.push(defer[i]);
        }
    }
    if have {
        Some(Hit { t: best_t, prim: best_prim })
    } else {
        None
    }
}

impl WideBvh {
    /// Refit after triangle positions changed (dynamic RMQ, §7.iii):
    /// re-extract every leaf record from its triangle, then recompute the
    /// per-lane bounds bottom-up. Valid because child nodes always follow
    /// their parent in `nodes` (collapse emits DFS preorder).
    pub fn refit(&mut self, tris: &[Triangle]) {
        for p in self.prims.iter_mut() {
            *p = WidePrim::from_triangle(&tris[p.prim as usize]);
        }
        for i in (0..self.nodes.len()).rev() {
            self.refit_lanes(i);
        }
    }

    /// Recompute all four lane bounds of node `i` from its current
    /// children (leaf runs read `prims`; internal lanes aggregate the
    /// child node's lanes). Shared by the full bottom-up sweep and the
    /// point-refit path walk.
    fn refit_lanes(&mut self, i: usize) {
        for k in 0..4 {
            let child = self.nodes[i].child[k];
            if child == INVALID_LANE {
                continue;
            }
            let cnt = self.nodes[i].count[k] as usize;
            let (mut ymin, mut ymax) = (f32::INFINITY, f32::NEG_INFINITY);
            let (mut zmin, mut zmax) = (f32::INFINITY, f32::NEG_INFINITY);
            let mut xmin = f32::INFINITY;
            if cnt > 0 {
                for p in &self.prims[child as usize..child as usize + cnt] {
                    ymin = ymin.min(p.y_lo);
                    ymax = ymax.max(p.y_hi);
                    zmin = zmin.min(p.z_lo);
                    zmax = zmax.max(p.z_hi);
                    xmin = xmin.min(p.x_plane);
                }
            } else {
                let c = self.nodes[child as usize];
                for j in 0..4 {
                    if c.child[j] == INVALID_LANE {
                        continue;
                    }
                    ymin = ymin.min(c.ymin[j]);
                    ymax = ymax.max(c.ymax[j]);
                    zmin = zmin.min(c.zmin[j]);
                    zmax = zmax.max(c.zmax[j]);
                    xmin = xmin.min(c.xmin[j]);
                }
            }
            let n = &mut self.nodes[i];
            n.ymin[k] = ymin;
            n.ymax[k] = ymax;
            n.zmin[k] = zmin;
            n.zmax[k] = zmax;
            n.xmin[k] = xmin;
        }
    }

    /// Topology links enabling point refits ([`WideBvh::refit_prims`]).
    /// Built once per structure; refits never change topology, so the
    /// links stay valid for the structure's lifetime.
    pub fn refit_links(&self) -> WideRefitLinks {
        let mut parent = vec![0u32; self.nodes.len()];
        let mut node_of_slot = vec![0u32; self.prims.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            for k in 0..4 {
                let c = n.child[k];
                if c == INVALID_LANE {
                    continue;
                }
                let cnt = n.count[k] as usize;
                if cnt > 0 {
                    for s in c as usize..c as usize + cnt {
                        node_of_slot[s] = i as u32;
                    }
                } else {
                    parent[c as usize] = i as u32;
                }
            }
        }
        // Prim ids are dense 0..prims.len() in both geometry modes, so a
        // plain inverse permutation maps triangle index -> prims slot.
        let mut slot_of_prim = vec![0u32; self.prims.len()];
        for (s, p) in self.prims.iter().enumerate() {
            slot_of_prim[p.prim as usize] = s as u32;
        }
        WideRefitLinks { parent, node_of_slot, slot_of_prim }
    }

    /// Point refit: re-extract only the given primitives' records and
    /// recompute the node lanes on their leaf-to-root paths — Θ(k·depth)
    /// against the full sweep's Θ(n). Same idempotent-path argument as
    /// [`crate::bvh::Bvh::refit_prims`]: equivalent to
    /// [`refit`](Self::refit) provided `prims` covers every changed
    /// triangle.
    pub fn refit_prims(&mut self, tris: &[Triangle], prims: &[u32], links: &WideRefitLinks) {
        for &p in prims {
            let slot = links.slot_of_prim[p as usize] as usize;
            self.prims[slot] = WidePrim::from_triangle(&tris[p as usize]);
            let mut i = links.node_of_slot[slot] as usize;
            loop {
                self.refit_lanes(i);
                if i == 0 {
                    break;
                }
                i = links.parent[i] as usize;
            }
        }
    }

    /// Heap bytes of the structure's own allocations (nodes + leaf
    /// records). [`WideRefitLinks`] are owned by whoever built them, so
    /// their bytes are reported by [`WideRefitLinks::memory_bytes`] and
    /// summed by the owning solver — see `RtxRmq::memory_bytes`.
    pub fn memory_bytes(&self) -> usize {
        self.nodes.len() * std::mem::size_of::<WideNode>()
            + self.prims.len() * std::mem::size_of::<WidePrim>()
    }

    /// Structural invariants (tests + debug builds): every triangle in
    /// exactly one leaf run, child lanes bound their contents, internal
    /// lanes point forward (refit relies on it), all nodes reachable.
    pub fn validate(&self, tris: &[Triangle]) -> Result<(), String> {
        if self.nodes.is_empty() {
            return Err("empty wide bvh".into());
        }
        if self.prims.len() != tris.len() {
            return Err(format!("{} prim records for {} triangles", self.prims.len(), tris.len()));
        }
        let mut seen = vec![false; tris.len()];
        let mut visited = 0usize;
        let mut stack = vec![0u32];
        while let Some(ni) = stack.pop() {
            visited += 1;
            let node = &self.nodes[ni as usize];
            for k in 0..4 {
                let child = node.child[k];
                if child == INVALID_LANE {
                    continue;
                }
                let cnt = node.count[k] as usize;
                if cnt > 0 {
                    if cnt > self.leaf_size.max(1) {
                        return Err(format!("leaf lane of {cnt} > leaf_size {}", self.leaf_size));
                    }
                    let first = child as usize;
                    if first + cnt > self.prims.len() {
                        return Err("leaf run out of range".into());
                    }
                    for p in &self.prims[first..first + cnt] {
                        let id = p.prim as usize;
                        if id >= tris.len() {
                            return Err(format!("prim id {id} out of range"));
                        }
                        if seen[id] {
                            return Err(format!("prim {id} in two leaves"));
                        }
                        seen[id] = true;
                        if *p != WidePrim::from_triangle(&tris[id]) {
                            return Err(format!("prim record {id} stale vs triangle"));
                        }
                        let eps = 1e-6f32;
                        if p.y_lo < node.ymin[k] - eps
                            || p.y_hi > node.ymax[k] + eps
                            || p.z_lo < node.zmin[k] - eps
                            || p.z_hi > node.zmax[k] + eps
                            || p.x_plane < node.xmin[k] - eps
                        {
                            return Err(format!("prim {id} escapes lane bounds"));
                        }
                    }
                } else {
                    if child as usize <= ni as usize || child as usize >= self.nodes.len() {
                        return Err("internal lane must point forward and in range".into());
                    }
                    stack.push(child);
                }
            }
        }
        if visited != self.nodes.len() {
            return Err(format!("unreachable wide nodes: {visited} of {}", self.nodes.len()));
        }
        if !seen.iter().all(|&s| s) {
            return Err("some prims not in any leaf".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bvh::build::{build, collapse_to_wide};
    use crate::bvh::traverse::{closest_hit, closest_hit_from, TraversalStack};
    use crate::bvh::Builder;
    use crate::geometry::flat::{build_scene, ray_for_query, ray_origin_x};
    use crate::rmq::naive_rmq;
    use crate::util::proptest::{check, gen};

    #[test]
    fn collapse_valid_structure_both_builders() {
        check("wide structural invariants", 40, |rng| {
            let xs = gen::f32_array(rng, 1..=600);
            let tris = build_scene(&xs);
            for builder in [Builder::BinnedSah, Builder::Lbvh] {
                let bvh = build(&tris, builder, 4);
                let wb = collapse_to_wide(&bvh, &tris);
                wb.validate(&tris)?;
            }
            Ok(())
        });
    }

    #[test]
    fn single_triangle_collapses() {
        let tris = build_scene(&[0.5]);
        let bvh = build(&tris, Builder::BinnedSah, 4);
        let wb = collapse_to_wide(&bvh, &tris);
        assert_eq!(wb.nodes.len(), 1);
        assert_eq!(wb.prims.len(), 1);
        wb.validate(&tris).unwrap();
        let ray = ray_for_query(0, 0, 1, ray_origin_x(&[0.5]));
        let mut c = Counters::default();
        let hit = closest_hit_wide(&wb, &ray, &mut WideStack::new(), &mut c).unwrap();
        assert_eq!(hit.prim, 0);
    }

    #[test]
    fn wide_hits_match_binary_and_oracle() {
        check("wide == binary == rmq (sah+lbvh)", 60, |rng| {
            let xs = gen::f32_array(rng, 1..=800);
            let n = xs.len();
            let tris = build_scene(&xs);
            let theta = ray_origin_x(&xs);
            for builder in [Builder::BinnedSah, Builder::Lbvh] {
                let bvh = build(&tris, builder, 4);
                let wb = collapse_to_wide(&bvh, &tris);
                let mut bs = TraversalStack::new();
                let mut ws = WideStack::new();
                let mut cb = Counters::default();
                let mut cw = Counters::default();
                for _ in 0..16 {
                    let (l, r) = gen::query(rng, n);
                    let ray = ray_for_query(l as u32, r as u32, n, theta);
                    let bh = closest_hit(&bvh, &tris, &ray, &mut bs, &mut cb)
                        .ok_or_else(|| format!("binary no hit for ({l},{r})"))?;
                    let wh = closest_hit_wide(&wb, &ray, &mut ws, &mut cw)
                        .ok_or_else(|| format!("wide no hit for ({l},{r})"))?;
                    if bh != wh {
                        return Err(format!("{builder:?} ({l},{r}): binary {bh:?} wide {wh:?}"));
                    }
                    let want = naive_rmq(&xs, l, r);
                    if wh.prim as usize != want {
                        return Err(format!("({l},{r}): wide {} want {want}", wh.prim));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn wide_ties_resolve_leftmost() {
        check("wide equal values leftmost", 60, |rng| {
            let xs = gen::dup_array(rng, 1..=400, 2);
            let n = xs.len();
            let tris = build_scene(&xs);
            let bvh = build(&tris, Builder::BinnedSah, 4);
            let wb = collapse_to_wide(&bvh, &tris);
            let theta = ray_origin_x(&xs);
            let mut ws = WideStack::new();
            let mut c = Counters::default();
            for _ in 0..16 {
                let (l, r) = gen::query(rng, n);
                let ray = ray_for_query(l as u32, r as u32, n, theta);
                let hit = closest_hit_wide(&wb, &ray, &mut ws, &mut c).unwrap();
                let want = naive_rmq(&xs, l, r);
                if hit.prim as usize != want {
                    return Err(format!("({l},{r}): got {} want {want}", hit.prim));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn carried_hits_match_binary() {
        // The Algorithm-6 payload-min path: seed both traversals with the
        // same prior hit and require identical final hits — including
        // carried hits surviving equal-t ties.
        check("wide carried-hit == binary", 40, |rng| {
            let xs = gen::dup_array(rng, 2..=300, 3);
            let n = xs.len();
            let tris = build_scene(&xs);
            let bvh = build(&tris, Builder::BinnedSah, 4);
            let wb = collapse_to_wide(&bvh, &tris);
            let theta = ray_origin_x(&xs);
            let mut bs = TraversalStack::new();
            let mut ws = WideStack::new();
            let mut cb = Counters::default();
            let mut cw = Counters::default();
            for _ in 0..12 {
                let (l1, r1) = gen::query(rng, n);
                let first = ray_for_query(l1 as u32, r1 as u32, n, theta);
                let seed_b = closest_hit(&bvh, &tris, &first, &mut bs, &mut cb);
                let seed_w = closest_hit_wide(&wb, &first, &mut ws, &mut cw);
                if seed_b != seed_w {
                    return Err(format!("seed mismatch: {seed_b:?} vs {seed_w:?}"));
                }
                let (l2, r2) = gen::query(rng, n);
                let second = ray_for_query(l2 as u32, r2 as u32, n, theta);
                let bh = closest_hit_from(&bvh, &tris, &second, &mut bs, &mut cb, seed_b);
                let wh = closest_hit_wide_from(&wb, &second, &mut ws, &mut cw, seed_w);
                if bh != wh {
                    return Err(format!(
                        "carried ({l1},{r1})→({l2},{r2}): binary {bh:?} wide {wh:?}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn refit_tracks_value_updates() {
        check("wide refit == rebuild answers", 30, |rng| {
            let mut xs = gen::f32_array(rng, 8..=256);
            let n = xs.len();
            let tris = build_scene(&xs);
            let bvh = build(&tris, Builder::BinnedSah, 4);
            let mut wb = collapse_to_wide(&bvh, &tris);
            // Point updates re-shape triangles; refit instead of rebuild.
            for _ in 0..4 {
                let i = rng.range(0, n - 1);
                xs[i] = rng.f32();
            }
            let tris = build_scene(&xs);
            wb.refit(&tris);
            wb.validate(&tris)?;
            let theta = ray_origin_x(&xs);
            let mut ws = WideStack::new();
            let mut c = Counters::default();
            for _ in 0..12 {
                let (l, r) = gen::query(rng, n);
                let ray = ray_for_query(l as u32, r as u32, n, theta);
                let hit = closest_hit_wide(&wb, &ray, &mut ws, &mut c).unwrap();
                let want = naive_rmq(&xs, l, r);
                if hit.prim as usize != want {
                    return Err(format!("after refit ({l},{r}): got {} want {want}", hit.prim));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn wide_visits_fewer_nodes_than_binary() {
        // The point of the layout: one wide pop replaces ~3 binary pops.
        let xs = crate::util::rng::Rng::new(13).uniform_f32_vec(4096);
        let tris = build_scene(&xs);
        let bvh = build(&tris, Builder::BinnedSah, 4);
        let wb = collapse_to_wide(&bvh, &tris);
        let theta = ray_origin_x(&xs);
        let mut cb = Counters::default();
        let mut cw = Counters::default();
        let mut bs = TraversalStack::new();
        let mut ws = WideStack::new();
        for i in 0..64u32 {
            let ray = ray_for_query(i * 8, i * 8 + 500, 4096, theta);
            closest_hit(&bvh, &tris, &ray, &mut bs, &mut cb).unwrap();
            closest_hit_wide(&wb, &ray, &mut ws, &mut cw).unwrap();
        }
        assert!(
            cw.nodes_visited * 3 < cb.nodes_visited * 2,
            "wide {} vs binary {} node visits",
            cw.nodes_visited,
            cb.nodes_visited
        );
    }

    #[test]
    fn memory_is_denser_than_binary_nodes() {
        let xs = crate::util::rng::Rng::new(14).uniform_f32_vec(1 << 12);
        let tris = build_scene(&xs);
        let bvh = build(&tris, Builder::BinnedSah, 4);
        let wb = collapse_to_wide(&bvh, &tris);
        // Wide node count must be well under the binary internal count.
        assert!(wb.nodes.len() * 2 < bvh.nodes.len());
        assert!(wb.memory_bytes() > 0);
    }
}

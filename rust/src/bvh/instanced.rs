//! Instanced block geometry: **one shared shape tree per unique block
//! length**, with per-block data reduced to an *instance* — a value
//! offset/scale plus a compressed `u16` leaf table — instead of a full
//! per-block BVH.
//!
//! The observation (ROADMAP "giant-array scale"; the AMR
//! point-containment paper and RT-HDIST do the same on real RT
//! hardware): every block of the sharded engine maps positions
//! `0..len` to the same triangle footprint — only the *values* differ,
//! and values enter traversal purely through ordering comparisons. So
//! the node structure depends only on the block length and can be
//! built once and shared by every same-length block:
//!
//! - [`ShapeTree`] — a balanced 4-ary positional interval tree over
//!   `[0, len)`, the instanced analogue of the wide SoA BVH
//!   (`bvh/wide.rs`): four child lanes per node, each covering a
//!   contiguous `u16` position range, children laid out in position
//!   order. Built by [`crate::bvh::build::build_shape_tree`], cached
//!   per length in a [`ShapeSet`].
//! - [`InstancedBlock`] — the per-block instance: `v_lo`/`scale`
//!   (dequantization transform), a `qval` table of one `u16` per
//!   element (the compressed leaf record — ~2 bytes vs the 24-byte
//!   [`super::wide::WidePrim`]), and per-node per-lane quantized
//!   minima (`node_qmin`) that play the role of the wide BVH's lane
//!   AABBs.
//!
//! # Why quantized traversal stays exact
//!
//! `qval[p]` is a **lower bound**: `dequant(qval[p]) = v_lo +
//! qval[p]·scale ≤ xs[p]` (floor quantization, with a rounding guard).
//! `node_qmin` is the min of `qval` over a subtree, so its dequantized
//! value lower-bounds every value in the subtree. Traversal descends a
//! lane only when that lower bound could *strictly* beat the current
//! best, and on reaching a leaf record it confirms against the exact
//! `f32` from the caller's value slice before accepting. Pruning on a
//! lower bound never discards a strictly-smaller candidate, and the
//! exact compare rejects quantization collisions — answers are
//! bit-identical to an exact solver.
//!
//! # Why leftmost ties survive quantization
//!
//! Lanes are visited strictly left-to-right in position order (children
//! pushed in reverse so the leftmost pops first), so every candidate
//! examined after the current best has a *larger* position. Both the
//! descend test (`lower bound < best`) and the accept test
//! (`exact value < best`) are strict, so a later equal value can never
//! replace an earlier one — the leftmost minimum wins by construction,
//! even when many records share a quantization bucket.
//!
//! # Updates without a rebuild
//!
//! A point update is a leaf-table write plus a leaf-to-root lane-min
//! walk ([`InstancedBlock::refit_point`], `O(leaf + 4·depth)`): the
//! shared shape is immutable, so there is no tree to rebuild. A value
//! below the instance's `v_lo` lowers `v_lo` in place — every stored
//! `qval` then dequantizes *lower*, which keeps the lower-bound
//! invariant (bounds get looser, never wrong) — and when the live
//! minimum later rises far above the floor, the refit re-derives the
//! transform so the 16-bit resolution isn't spent on dead headroom
//! below the array. Multi-point batches requantize the whole table
//! ([`InstancedBlock::rebuild_values`], `O(len)` — still no node
//! construction).
//!
//! # Range tags: updates without even a requantize
//!
//! Because a block's values enter traversal only through the affine
//! transform `v_lo + q·scale`, a range update that covers the *whole*
//! block never needs to touch `qval` or `node_qmin`:
//!
//! - `add v` ([`InstancedBlock::apply_add`]) shifts `v_lo` — every
//!   stored bound translates rigidly with the values (the paper's
//!   geometry picture: the block's triangles slide together). A short
//!   safety sweep then walks `v_lo` down by the few ulps that f32
//!   reassociation (`fl(v_lo + v) + q·scale` vs `fl(xs[p] + v)`) can
//!   overshoot, so the lower-bound invariant survives exactly.
//! - `assign v` ([`InstancedBlock::apply_assign`]) collapses the
//!   transform to the constant block `scale = 0, v_lo = v`: every
//!   record dequantizes to `v` and the tables are untouched — O(1).
//!
//! Neither path reconstructs a node or rewrites a leaf record; the
//! sharded engine counts these as `tag_hits`.

use super::traverse::Counters;
use std::sync::Arc;

/// Sentinel for "no child node" in a [`ShapeNode`] lane.
pub const NO_CHILD: u32 = u32::MAX;

/// Blocks longer than this cannot be instanced: positions are
/// block-relative `u16`s in the compressed leaf records.
pub const MAX_INSTANCED_LEN: usize = 1 << 16;

/// Elements per leaf lane of a shape tree (mirrors the wide BVH's
/// default leaf size; bounded by the `u8` lane count field).
pub const SHAPE_LEAF_SIZE: usize = 16;

/// One 4-wide node of a shape tree. Lane `k` covers the contiguous
/// position range `[pmin[k], pmax[k]]`; `count[k] > 0` marks a leaf
/// lane holding `count[k]` records (record index == position, so the
/// leaf table needs no indirection), `child[k] != NO_CHILD` an internal
/// lane, and neither an empty lane (short blocks).
#[derive(Clone, Copy, Debug)]
pub struct ShapeNode {
    pub pmin: [u16; 4],
    pub pmax: [u16; 4],
    pub child: [u32; 4],
    pub count: [u8; 4],
}

impl ShapeNode {
    pub fn empty() -> ShapeNode {
        ShapeNode { pmin: [0; 4], pmax: [0; 4], child: [NO_CHILD; 4], count: [0; 4] }
    }

    #[inline]
    pub fn lane_is_empty(&self, lane: usize) -> bool {
        self.count[lane] == 0 && self.child[lane] == NO_CHILD
    }
}

/// The shared, immutable shape for all blocks of one length: node
/// structure + the reverse links the instance refit walk needs. Built
/// once per unique length ([`ShapeSet`]) and shared by `Arc` — the
/// per-block cost is only the instance tables.
pub struct ShapeTree {
    /// Block length this shape serves (`1..=MAX_INSTANCED_LEN`).
    pub len: usize,
    pub leaf_size: usize,
    /// Node 0 is the root; children always follow their parent, so a
    /// reverse index sweep sees every child before its parent.
    pub nodes: Vec<ShapeNode>,
    /// Parent node index per node (`NO_CHILD` for the root).
    pub parent: Vec<u32>,
    /// Leaf node owning each position.
    pub node_of_pos: Vec<u32>,
    /// Lane within that node.
    pub lane_of_pos: Vec<u8>,
}

impl ShapeTree {
    pub fn memory_bytes(&self) -> usize {
        self.nodes.len() * std::mem::size_of::<ShapeNode>()
            + self.parent.len() * 4
            + self.node_of_pos.len() * 4
            + self.lane_of_pos.len()
    }

    /// Structural invariants: the leaf lanes partition `[0, len)` in
    /// strictly increasing position order (the property the leftmost
    /// tie-break rests on), parent/child links agree, and the
    /// per-position reverse links point at the owning leaf lane.
    pub fn validate(&self) -> Result<(), String> {
        if self.len == 0 || self.len > MAX_INSTANCED_LEN {
            return Err(format!("shape len {} out of range", self.len));
        }
        if self.nodes.is_empty() || self.parent.len() != self.nodes.len() {
            return Err("node/parent table mismatch".into());
        }
        if self.node_of_pos.len() != self.len || self.lane_of_pos.len() != self.len {
            return Err("reverse-link table length mismatch".into());
        }
        if self.parent[0] != NO_CHILD {
            return Err("root must have no parent".into());
        }
        // In-order DFS must emit positions 0..len exactly once, in order.
        let mut next_pos = 0usize;
        let mut stack = vec![0u32];
        let mut visited = vec![false; self.nodes.len()];
        while let Some(ni) = stack.pop() {
            let i = ni as usize;
            if visited[i] {
                return Err(format!("node {i} reachable twice"));
            }
            visited[i] = true;
            let nd = &self.nodes[i];
            // Push child lanes in reverse so lane 0's subtree completes
            // first; leaf lanes are consumed inline left-to-right.
            let mut pending: Vec<u32> = Vec::new();
            for lane in 0..4 {
                if nd.lane_is_empty(lane) {
                    continue;
                }
                let (lo, hi) = (nd.pmin[lane] as usize, nd.pmax[lane] as usize);
                if lo > hi || hi >= self.len {
                    return Err(format!("node {i} lane {lane}: bad range [{lo},{hi}]"));
                }
                if nd.count[lane] > 0 {
                    if nd.child[lane] != NO_CHILD {
                        return Err(format!("node {i} lane {lane}: both leaf and child"));
                    }
                    if hi - lo + 1 != nd.count[lane] as usize
                        || nd.count[lane] as usize > self.leaf_size
                    {
                        return Err(format!("node {i} lane {lane}: bad leaf count"));
                    }
                    if lo != next_pos {
                        return Err(format!(
                            "node {i} lane {lane}: out of order (have {next_pos}, lane at {lo})"
                        ));
                    }
                    for p in lo..=hi {
                        if self.node_of_pos[p] != ni || self.lane_of_pos[p] as usize != lane {
                            return Err(format!("position {p}: stale reverse link"));
                        }
                    }
                    next_pos = hi + 1;
                } else {
                    let ch = nd.child[lane] as usize;
                    if ch >= self.nodes.len() || ch <= i {
                        return Err(format!("node {i} lane {lane}: child {ch} out of order"));
                    }
                    if self.parent[ch] != ni {
                        return Err(format!("node {ch}: parent link disagrees"));
                    }
                    pending.push(nd.child[lane]);
                }
            }
            // The pending children are left-to-right; a plain stack
            // visits them in reverse — but each child's positions are
            // checked against `next_pos`, so order errors still surface
            // as long as we recurse leftmost-first. Reverse for that.
            for &c in pending.iter().rev() {
                stack.push(c);
            }
        }
        if next_pos != self.len {
            return Err(format!("leaf lanes cover {next_pos} of {} positions", self.len));
        }
        Ok(())
    }
}

/// Cache of shape trees keyed by block length. The sharded engine holds
/// one and pre-populates it (`ensure`) for every distinct block length
/// before its parallel build loops; lookups after that are read-only.
/// Clones share the underlying trees (`Arc`), so a staged update spec
/// can carry the set across threads for free.
#[derive(Clone, Default)]
pub struct ShapeSet {
    shapes: Vec<Arc<ShapeTree>>,
}

impl ShapeSet {
    /// Get-or-build the shape for `len`. Linear scan: a decomposition
    /// has at most three distinct lengths (block, tail, summary).
    pub fn ensure(&mut self, len: usize, leaf_size: usize) -> Arc<ShapeTree> {
        if let Some(s) = self.shapes.iter().find(|s| s.len == len) {
            return s.clone();
        }
        let s = Arc::new(super::build::build_shape_tree(len, leaf_size));
        self.shapes.push(s.clone());
        s
    }

    /// Lookup only — panics if [`ensure`](Self::ensure) did not run for
    /// this length (shape building must happen before the parallel
    /// block loops, which share the set immutably).
    pub fn get(&self, len: usize) -> &Arc<ShapeTree> {
        self.shapes
            .iter()
            .find(|s| s.len == len)
            .expect("ShapeSet::ensure must run for every block length before instancing")
    }

    pub fn num_shapes(&self) -> usize {
        self.shapes.len()
    }

    /// Bytes of all cached trees. Each tree is counted once no matter
    /// how many instances share it — the whole point of instancing.
    pub fn memory_bytes(&self) -> usize {
        self.shapes.iter().map(|s| s.memory_bytes()).sum()
    }
}

/// Floor-quantize `v` into the instance's bucket grid, guarding the
/// lower-bound invariant `dequant(q) ≤ v` against f32 rounding.
fn quantize(v: f32, v_lo: f32, scale: f32) -> u16 {
    if scale <= 0.0 {
        return 0;
    }
    let raw = ((v - v_lo) / scale).floor();
    let mut q = if raw <= 0.0 { 0u32 } else if raw >= 65535.0 { 65535 } else { raw as u32 };
    while q > 0 && v_lo + q as f32 * scale > v {
        q -= 1;
    }
    q as u16
}

/// One block's instance data over a shared [`ShapeTree`]: the value
/// transform, the compressed per-position leaf table, and the per-node
/// quantized lane minima. Exact `f32` values are *not* stored — the
/// probe resolves them from the caller's value slice on hit, so a block
/// costs ~2 bytes/element of leaf records plus ~0.6 bytes/element of
/// lane minima instead of a 24-byte prim + node structure.
pub struct InstancedBlock {
    shape: Arc<ShapeTree>,
    /// Dequantization offset. Only ever *lowered* by point refits, so
    /// stored quantized values stay lower bounds.
    v_lo: f32,
    /// Bucket width `(v_hi − v_lo) / 65535`; 0 for all-equal blocks
    /// (every record then dequantizes to `v_lo`, still a lower bound).
    scale: f32,
    /// Quantized lower bound per position (the compressed leaf record).
    qval: Vec<u16>,
    /// Per-node, per-lane min of `qval` over the lane's subtree.
    node_qmin: Vec<[u16; 4]>,
}

impl InstancedBlock {
    pub fn build(xs: &[f32], shape: Arc<ShapeTree>) -> InstancedBlock {
        assert_eq!(xs.len(), shape.len, "value slice must match the shape length");
        let mut b = InstancedBlock {
            qval: vec![0; xs.len()],
            node_qmin: vec![[u16::MAX; 4]; shape.nodes.len()],
            shape,
            v_lo: 0.0,
            scale: 0.0,
        };
        b.rebuild_values(xs);
        b
    }

    pub fn shape(&self) -> &Arc<ShapeTree> {
        &self.shape
    }

    #[inline]
    fn dequant(&self, q: u16) -> f32 {
        self.v_lo + q as f32 * self.scale
    }

    /// Requantize the whole instance from fresh values (multi-point
    /// update path / construction). `O(len)` table writes — the shared
    /// shape is untouched, so this is the instanced engine's "rebuild".
    pub fn rebuild_values(&mut self, xs: &[f32]) {
        assert_eq!(xs.len(), self.shape.len);
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in xs {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        self.v_lo = lo;
        self.scale = if hi > lo { (hi - lo) / 65535.0 } else { 0.0 };
        for (p, &v) in xs.iter().enumerate() {
            self.qval[p] = quantize(v, self.v_lo, self.scale);
        }
        // Children follow their parent in the node array, so a single
        // reverse sweep finalizes every child row before its parent
        // reads it.
        for i in (0..self.shape.nodes.len()).rev() {
            let mut qmin = [u16::MAX; 4];
            for lane in 0..4 {
                let nd = &self.shape.nodes[i];
                qmin[lane] = if nd.count[lane] > 0 {
                    (nd.pmin[lane]..=nd.pmax[lane])
                        .map(|p| self.qval[p as usize])
                        .min()
                        .unwrap()
                } else if nd.child[lane] != NO_CHILD {
                    let ch = nd.child[lane] as usize;
                    self.node_qmin[ch].iter().copied().min().unwrap()
                } else {
                    u16::MAX
                };
            }
            self.node_qmin[i] = qmin;
        }
    }

    /// Full-block `add` tag: shift `v_lo` with the values instead of
    /// requantizing. `xs` is the block's value slice *after* the add has
    /// been applied elementwise. `qval`/`node_qmin` are untouched — the
    /// whole bound structure translates rigidly — but f32 reassociation
    /// can leave `fl(v_lo + v) + q·scale` a few ulps above
    /// `fl(xs[p] + v)`, so a sweep walks `v_lo` down until every stored
    /// record is a lower bound again (reads only; no table writes).
    pub fn apply_add(&mut self, xs: &[f32], v: f32) {
        assert_eq!(xs.len(), self.shape.len);
        self.v_lo += v;
        for _ in 0..4 {
            let mut excess = 0.0f32;
            for (p, &x) in xs.iter().enumerate() {
                let d = self.dequant(self.qval[p]) - x;
                if d > excess {
                    excess = d;
                }
            }
            if excess <= 0.0 {
                return;
            }
            // Pad by a few ulps of the working magnitude so the
            // subtraction cannot round back to the old v_lo.
            self.v_lo -= excess + (self.v_lo.abs() + excess) * f32::EPSILON * 4.0;
        }
        // Pathological rounding (shouldn't happen with the pad, but a
        // wrong bound would corrupt answers): requantize exactly.
        self.rebuild_values(xs);
    }

    /// Full-block `assign` tag: collapse to the constant block
    /// `scale = 0, v_lo = v` — every record dequantizes to exactly `v`,
    /// and neither `qval` nor `node_qmin` is touched (their internal
    /// consistency is what [`validate`](Self::validate) checks, and a
    /// constant transform keeps every stored bound ≤ the live value).
    /// Truly O(1).
    pub fn apply_assign(&mut self, v: f32) {
        self.v_lo = v;
        self.scale = 0.0;
    }

    /// Point update: one leaf-table write plus a leaf-to-root lane-min
    /// walk — `O(leaf + 4·depth)`, no node construction. A value below
    /// the current `v_lo` lowers `v_lo` (all stored bounds shift down
    /// together — looser, never wrong); a value above the build-time
    /// `v_hi` clamps to the top bucket (still a lower bound).
    ///
    /// `xs` is the block's exact value slice with this write already
    /// applied. The fast path only ever *lowers* the floor, so after
    /// values rise back up new writes land deep in the top buckets with
    /// most of the 16-bit resolution wasted on empty space below the
    /// array; when this write's quantization error exceeds a quarter of
    /// the representable span (or the transform is degenerate for a
    /// differing value), the refit re-derives the transform from `xs`
    /// instead of quantizing against the stale grid.
    pub fn refit_point(&mut self, pos: usize, v: f32, xs: &[f32]) {
        assert!(pos < self.shape.len);
        debug_assert_eq!(xs.len(), self.shape.len);
        if self.scale <= 0.0 && v != self.v_lo {
            // All-equal build or an assign collapse: zero resolution to
            // quantize a differing value into.
            self.rebuild_values(xs);
            return;
        }
        if v < self.v_lo {
            self.v_lo = v;
        }
        let q = quantize(v, self.v_lo, self.scale);
        // Floor re-tightening: against a stale (over-lowered) floor the
        // new value lands in the top buckets with a quantization error
        // of many buckets — the screen bound goes useless-loose. When
        // the write's error exceeds a quarter of the representable
        // span, re-derive the transform from the exact values (O(len),
        // still no node construction) instead of quantizing against
        // the stale grid.
        if v - (self.v_lo + q as f32 * self.scale) > 16384.0 * self.scale {
            self.rebuild_values(xs);
            return;
        }
        self.qval[pos] = q;
        let mut node = self.shape.node_of_pos[pos] as usize;
        let lane = self.shape.lane_of_pos[pos] as usize;
        let nd = &self.shape.nodes[node];
        let mut m = u16::MAX;
        for p in nd.pmin[lane] as usize..=nd.pmax[lane] as usize {
            m = m.min(self.qval[p]);
        }
        self.node_qmin[node][lane] = m;
        loop {
            let p = self.shape.parent[node];
            if p == NO_CHILD {
                break;
            }
            let pi = p as usize;
            let lane_in_parent = self.shape.nodes[pi]
                .child
                .iter()
                .position(|&c| c as usize == node)
                .expect("parent links to child");
            let subtree_min = self.node_qmin[node].iter().copied().min().unwrap();
            if self.node_qmin[pi][lane_in_parent] == subtree_min {
                break; // unchanged here ⇒ unchanged above
            }
            self.node_qmin[pi][lane_in_parent] = subtree_min;
            node = pi;
        }
    }

    /// Leftmost argmin over local positions `[l, r]`. `xs` is the
    /// block's exact value slice (owned by the caller — the sharded
    /// engine's value array); quantized bounds prune, exact values
    /// decide. Counter semantics mirror the BVH probe: one ray per
    /// probe, a node visit per shape node expanded, a lane-interval
    /// test per non-empty lane, a "tri test" per leaf record scanned.
    pub fn probe(&self, xs: &[f32], l: usize, r: usize, c: &mut Counters) -> usize {
        debug_assert!(l <= r && r < self.shape.len);
        debug_assert_eq!(xs.len(), self.shape.len);
        c.rays += 1;
        let (lq, rq) = (l as u32, r as u32);
        let mut best = usize::MAX;
        let mut best_val = f32::INFINITY;
        // Work items: internal node (tag 0) or one leaf lane (tag 1).
        // Items are pushed in reverse lane order, so the stack pops
        // strictly left-to-right in position order — the invariant the
        // leftmost tie-break rides on.
        const LEAF: u32 = 1;
        let mut stack: Vec<u32> = Vec::with_capacity(32);
        stack.push(0);
        while let Some(item) = stack.pop() {
            let ni = (item >> 3) as usize;
            let nd = &self.shape.nodes[ni];
            if item & LEAF != 0 {
                let lane = ((item >> 1) & 0x3) as usize;
                let a = (nd.pmin[lane] as u32).max(lq) as usize;
                let b = (nd.pmax[lane] as u32).min(rq) as usize;
                for p in a..=b {
                    c.tri_tests += 1;
                    // Cheap quantized screen first; the exact value is
                    // read only for survivors. Both compares are strict,
                    // and p grows monotonically ⇒ leftmost ties hold.
                    if self.dequant(self.qval[p]) < best_val {
                        let v = xs[p];
                        if v < best_val {
                            best = p;
                            best_val = v;
                        }
                    }
                }
                continue;
            }
            c.nodes_visited += 1;
            c.node_fetches += 1;
            let qmin = &self.node_qmin[ni];
            // Re-check on pop: best_val may have improved since push.
            let node_min = qmin.iter().copied().min().unwrap();
            if self.dequant(node_min) >= best_val {
                continue;
            }
            for lane in (0..4).rev() {
                if nd.lane_is_empty(lane) {
                    continue;
                }
                c.aabb_tests += 1;
                if (nd.pmax[lane] as u32) < lq || (nd.pmin[lane] as u32) > rq {
                    continue;
                }
                if self.dequant(qmin[lane]) >= best_val {
                    continue; // can't strictly beat an earlier-position best
                }
                if nd.count[lane] > 0 {
                    stack.push(((ni as u32) << 3) | ((lane as u32) << 1) | LEAF);
                } else {
                    stack.push(nd.child[lane] << 3);
                }
            }
        }
        debug_assert!(best != usize::MAX, "query range always contains a record");
        best
    }

    /// Packet probe: resolve several `(l, r)` ranges over the *same*
    /// block in one shared descent, writing the leftmost argmin of
    /// range `i` to `out[i]`. Bit-identical to calling
    /// [`probe`](Self::probe) per range:
    ///
    /// - lanes screen on the packet's **position envelope**
    ///   `[min l, max r]` and on the quantized lane min vs the loosest
    ///   per-range best (`dequant` lower-bounds every value in the
    ///   subtree, so a skip can't lose any range's strict improvement);
    /// - surviving leaf lanes resolve **per range** with the scalar
    ///   rule verbatim (own `[l, r]` clamp, quantized screen, strict
    ///   exact compare) — and the shared stack still pops lanes in
    ///   strict position order, which is what leftmost ties ride on.
    ///
    /// Counter semantics mirror `bvh::wide::closest_hit_packet`:
    /// `rays` counts ranges, `nodes_visited` counts node expands *per
    /// range serviced* (the scalar-equivalent per-query work — one
    /// shared expand charges the packet size), `node_fetches` counts
    /// one per expand per *packet*, so `nodes_visited / node_fetches`
    /// is the amortization factor.
    pub fn probe_packet(
        &self,
        xs: &[f32],
        ranges: &[(usize, usize)],
        out: &mut [usize],
        c: &mut Counters,
    ) {
        debug_assert_eq!(ranges.len(), out.len());
        if ranges.is_empty() {
            return;
        }
        if ranges.len() == 1 {
            out[0] = self.probe(xs, ranges[0].0, ranges[0].1, c);
            return;
        }
        debug_assert_eq!(xs.len(), self.shape.len);
        let p = ranges.len();
        c.rays += p as u64;
        let mut env_l = u32::MAX;
        let mut env_r = 0u32;
        for &(l, r) in ranges {
            debug_assert!(l <= r && r < self.shape.len);
            env_l = env_l.min(l as u32);
            env_r = env_r.max(r as u32);
        }
        let mut best = vec![usize::MAX; p];
        let mut best_val = vec![f32::INFINITY; p];
        // Loosest per-packet bound; recomputed on demand (p ≤ 16).
        let packet_best = |best_val: &[f32]| -> f32 {
            let mut m = f32::NEG_INFINITY;
            for &v in best_val {
                m = m.max(v);
            }
            m
        };
        const LEAF: u32 = 1;
        let mut stack: Vec<u32> = Vec::with_capacity(32);
        stack.push(0);
        while let Some(item) = stack.pop() {
            let ni = (item >> 3) as usize;
            let nd = &self.shape.nodes[ni];
            if item & LEAF != 0 {
                let lane = ((item >> 1) & 0x3) as usize;
                for i in 0..p {
                    let (l, r) = ranges[i];
                    c.aabb_tests += 1;
                    let a = (nd.pmin[lane] as usize).max(l);
                    let b = (nd.pmax[lane] as usize).min(r);
                    if a > b {
                        continue; // this range deactivates for the lane
                    }
                    for pos in a..=b {
                        c.tri_tests += 1;
                        if self.dequant(self.qval[pos]) < best_val[i] {
                            let v = xs[pos];
                            if v < best_val[i] {
                                best[i] = pos;
                                best_val[i] = v;
                            }
                        }
                    }
                }
                continue;
            }
            c.nodes_visited += p as u64;
            c.node_fetches += 1;
            let qmin = &self.node_qmin[ni];
            // Re-check on pop against the loosest best: skipping is safe
            // only when *no* range could still strictly improve.
            let node_min = qmin.iter().copied().min().unwrap();
            if self.dequant(node_min) >= packet_best(&best_val) {
                continue;
            }
            for lane in (0..4).rev() {
                if nd.lane_is_empty(lane) {
                    continue;
                }
                c.aabb_tests += 1;
                // Position-envelope screen: outside [env_l, env_r] no
                // range intersects the lane.
                if (nd.pmax[lane] as u32) < env_l || (nd.pmin[lane] as u32) > env_r {
                    continue;
                }
                if self.dequant(qmin[lane]) >= packet_best(&best_val) {
                    continue;
                }
                if nd.count[lane] > 0 {
                    stack.push(((ni as u32) << 3) | ((lane as u32) << 1) | LEAF);
                } else {
                    stack.push(nd.child[lane] << 3);
                }
            }
        }
        for i in 0..p {
            debug_assert!(best[i] != usize::MAX, "query range always contains a record");
            out[i] = best[i];
        }
    }

    /// Instance bytes (leaf table + lane minima). The shared shape is
    /// *not* included — count it once per [`ShapeSet`], not per block.
    pub fn memory_bytes(&self) -> usize {
        self.qval.len() * 2 + self.node_qmin.len() * std::mem::size_of::<[u16; 4]>()
    }

    /// Invariants against the exact values: every stored record is a
    /// lower bound, and every lane min matches a recomputation.
    pub fn validate(&self, xs: &[f32]) -> Result<(), String> {
        if xs.len() != self.shape.len || self.qval.len() != self.shape.len {
            return Err("instance/shape length mismatch".into());
        }
        self.shape.validate()?;
        for (p, &v) in xs.iter().enumerate() {
            if self.dequant(self.qval[p]) > v {
                return Err(format!(
                    "position {p}: dequant({}) = {} exceeds value {v}",
                    self.qval[p],
                    self.dequant(self.qval[p])
                ));
            }
        }
        for (i, nd) in self.shape.nodes.iter().enumerate() {
            for lane in 0..4 {
                let want = if nd.count[lane] > 0 {
                    (nd.pmin[lane]..=nd.pmax[lane])
                        .map(|p| self.qval[p as usize])
                        .min()
                        .unwrap()
                } else if nd.child[lane] != NO_CHILD {
                    self.node_qmin[nd.child[lane] as usize].iter().copied().min().unwrap()
                } else {
                    u16::MAX
                };
                if self.node_qmin[i][lane] != want {
                    return Err(format!("node {i} lane {lane}: stale qmin"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bvh::build::build_shape_tree;
    use crate::util::rng::Rng;

    fn naive(xs: &[f32], l: usize, r: usize) -> usize {
        let mut best = l;
        for k in l + 1..=r {
            if xs[k] < xs[best] {
                best = k;
            }
        }
        best
    }

    #[test]
    fn shape_trees_validate_across_lengths() {
        for len in [1, 2, 3, 4, 5, 15, 16, 17, 63, 64, 65, 100, 255, 1000, 4096, 65536] {
            let t = build_shape_tree(len, SHAPE_LEAF_SIZE);
            t.validate().unwrap_or_else(|e| panic!("len {len}: {e}"));
            assert!(t.memory_bytes() > 0);
        }
        // Tiny leaf sizes force deep trees; the structure must still hold.
        for len in [7, 31, 64, 129] {
            build_shape_tree(len, 1).validate().unwrap();
            build_shape_tree(len, 2).validate().unwrap();
        }
    }

    #[test]
    fn shape_set_dedups_by_length() {
        let mut set = ShapeSet::default();
        let a = set.ensure(64, SHAPE_LEAF_SIZE);
        let b = set.ensure(64, SHAPE_LEAF_SIZE);
        let c = set.ensure(63, SHAPE_LEAF_SIZE);
        assert!(Arc::ptr_eq(&a, &b), "same length shares one tree");
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(set.num_shapes(), 2);
        assert_eq!(set.memory_bytes(), a.memory_bytes() + c.memory_bytes());
        assert!(Arc::ptr_eq(set.get(64), &a));
    }

    #[test]
    fn probe_matches_naive_exhaustively() {
        let mut rng = Rng::new(41);
        let mut set = ShapeSet::default();
        for &len in &[1usize, 2, 5, 16, 17, 48, 97, 130] {
            let shape = set.ensure(len, SHAPE_LEAF_SIZE);
            for round in 0..4 {
                // Tie-heavy quantized values stress bucket collisions.
                let xs: Vec<f32> =
                    (0..len).map(|_| (rng.f32() * 6.0).floor() / 2.0).collect();
                let inst = InstancedBlock::build(&xs, shape.clone());
                inst.validate(&xs).unwrap();
                let mut c = Counters::default();
                for l in 0..len {
                    for r in l..len {
                        let got = inst.probe(&xs, l, r, &mut c);
                        let want = naive(&xs, l, r);
                        assert_eq!(got, want, "len={len} round={round} ({l},{r}) xs={xs:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn probe_is_exact_at_bucket_boundaries() {
        // Values straddling a single quantization bucket: the quantized
        // screen cannot tell them apart, so only the exact compare keeps
        // the answer right — this pins the resolve-on-hit step.
        let mut set = ShapeSet::default();
        let shape = set.ensure(8, 4);
        let lo = 0.0f32;
        let hi = 655.35f32; // scale = 0.01
        let eps = 0.001f32; // well inside one bucket
        let xs = vec![hi, lo + eps, lo, lo + eps, hi, lo, lo + 2.0 * eps, hi];
        let inst = InstancedBlock::build(&xs, shape.clone());
        inst.validate(&xs).unwrap();
        let mut c = Counters::default();
        // Exact minimum is at 2 (and tied at 5): leftmost must win even
        // though positions 1..=3 and 5..=6 share dequantized bounds.
        assert_eq!(inst.probe(&xs, 0, 7, &mut c), 2);
        assert_eq!(inst.probe(&xs, 3, 7, &mut c), 5);
        assert_eq!(inst.probe(&xs, 1, 3, &mut c), 2);
        assert_eq!(inst.probe(&xs, 3, 3, &mut c), 3);
        assert_eq!(inst.probe(&xs, 5, 6, &mut c), 5);
        // All-equal block (scale = 0): every bound collapses to v_lo.
        let flat = vec![1.5f32; 8];
        let inst = InstancedBlock::build(&flat, shape);
        inst.validate(&flat).unwrap();
        for l in 0..8 {
            for r in l..8 {
                assert_eq!(inst.probe(&flat, l, r, &mut c), l, "leftmost of all-equal");
            }
        }
    }

    #[test]
    fn probe_packet_matches_scalar_probe() {
        // Packet probes must equal per-range scalar probes bit-for-bit —
        // tie-heavy values stress the leftmost invariant through the
        // shared descent, and widths cover 1/non-pow2/8/16.
        let mut rng = Rng::new(53);
        let mut set = ShapeSet::default();
        for &len in &[5usize, 16, 48, 130, 700] {
            let shape = set.ensure(len, SHAPE_LEAF_SIZE);
            let xs: Vec<f32> = (0..len).map(|_| (rng.f32() * 8.0).floor() / 4.0).collect();
            let inst = InstancedBlock::build(&xs, shape.clone());
            for &width in &[1usize, 4, 7, 8, 16] {
                let mut ranges = Vec::new();
                for _ in 0..width {
                    let l = rng.range(0, len - 1);
                    let r = rng.range(l, len - 1);
                    ranges.push((l, r));
                }
                let mut out = vec![0usize; width];
                let mut cp = Counters::default();
                inst.probe_packet(&xs, &ranges, &mut out, &mut cp);
                let mut cs = Counters::default();
                for (i, &(l, r)) in ranges.iter().enumerate() {
                    let want = inst.probe(&xs, l, r, &mut cs);
                    assert_eq!(out[i], want, "len={len} width={width} range ({l},{r})");
                    assert_eq!(want, naive(&xs, l, r));
                }
            }
        }
    }

    #[test]
    fn probe_packet_amortizes_node_fetches() {
        // Coherent consecutive ranges over one block: a shared descent
        // must fetch strictly fewer nodes than per-range probes.
        let mut rng = Rng::new(59);
        let mut set = ShapeSet::default();
        let len = 2048;
        let shape = set.ensure(len, SHAPE_LEAF_SIZE);
        let xs: Vec<f32> = (0..len).map(|_| rng.f32()).collect();
        let inst = InstancedBlock::build(&xs, shape);
        let ranges: Vec<(usize, usize)> = (0..8).map(|i| (i * 16, i * 16 + 100)).collect();
        let mut out = vec![0usize; ranges.len()];
        let mut cp = Counters::default();
        inst.probe_packet(&xs, &ranges, &mut out, &mut cp);
        let mut cs = Counters::default();
        for &(l, r) in &ranges {
            inst.probe(&xs, l, r, &mut cs);
        }
        assert!(
            cp.node_fetches < cs.node_fetches,
            "packet {} vs scalar {} node fetches",
            cp.node_fetches,
            cs.node_fetches
        );
    }

    #[test]
    fn refit_point_matches_fresh_rebuild() {
        let mut rng = Rng::new(43);
        let mut set = ShapeSet::default();
        for &len in &[3usize, 16, 33, 100] {
            let shape = set.ensure(len, SHAPE_LEAF_SIZE);
            let mut xs: Vec<f32> = (0..len).map(|_| rng.f32()).collect();
            let mut inst = InstancedBlock::build(&xs, shape.clone());
            for _ in 0..40 {
                let pos = rng.range(0, len - 1);
                // Raises, drops (including below the current v_lo) and ties.
                let v = match rng.range(0, 3) {
                    0 => rng.f32() * 2.0 - 0.5,
                    1 => -rng.f32(),
                    2 => xs[rng.range(0, len - 1)],
                    _ => xs[pos] + 0.25,
                };
                xs[pos] = v;
                inst.refit_point(pos, v, &xs);
                inst.validate(&xs).unwrap();
                let fresh = InstancedBlock::build(&xs, shape.clone());
                let mut c = Counters::default();
                for _ in 0..16 {
                    let l = rng.range(0, len - 1);
                    let r = rng.range(l, len - 1);
                    let want = naive(&xs, l, r);
                    assert_eq!(inst.probe(&xs, l, r, &mut c), want, "refit ({l},{r})");
                    assert_eq!(fresh.probe(&xs, l, r, &mut c), want, "rebuild ({l},{r})");
                }
            }
        }
    }

    #[test]
    fn rebuild_values_handles_batches_and_degenerate_blocks() {
        let mut rng = Rng::new(47);
        let mut set = ShapeSet::default();
        let shape = set.ensure(40, SHAPE_LEAF_SIZE);
        let mut xs: Vec<f32> = (0..40).map(|_| rng.f32()).collect();
        let mut inst = InstancedBlock::build(&xs, shape.clone());
        let mut c = Counters::default();
        for round in 0..20 {
            for _ in 0..rng.range(1, 8) {
                let i = rng.range(0, 39);
                xs[i] = if round % 3 == 0 { 0.25 } else { rng.f32() * 10.0 - 5.0 };
            }
            inst.rebuild_values(&xs);
            inst.validate(&xs).unwrap();
            for l in 0..40 {
                for r in l..40 {
                    assert_eq!(inst.probe(&xs, l, r, &mut c), naive(&xs, l, r));
                }
            }
        }
        // Degenerate: collapse to all-equal via a batch, then diverge again.
        xs.iter_mut().for_each(|v| *v = 7.0);
        inst.rebuild_values(&xs);
        inst.validate(&xs).unwrap();
        assert_eq!(inst.probe(&xs, 0, 39, &mut c), 0);
        assert_eq!(inst.memory_bytes(), 40 * 2 + inst.node_qmin.len() * 8);
    }

    #[test]
    fn add_tag_shifts_bounds_without_touching_tables() {
        let mut rng = Rng::new(61);
        let mut set = ShapeSet::default();
        for &len in &[1usize, 7, 16, 48, 130] {
            let shape = set.ensure(len, SHAPE_LEAF_SIZE);
            // Tie-heavy values so bucket collisions ride through shifts.
            let mut xs: Vec<f32> = (0..len).map(|_| (rng.f32() * 6.0).floor() / 2.0).collect();
            let mut inst = InstancedBlock::build(&xs, shape.clone());
            let qval_before = inst.qval.clone();
            let qmin_before = inst.node_qmin.clone();
            let mut c = Counters::default();
            for &v in &[0.5f32, -1.25, 1e-3, -0.37, 2.0] {
                for x in xs.iter_mut() {
                    *x += v; // the oracle's elementwise f32 add
                }
                inst.apply_add(&xs, v);
                inst.validate(&xs).unwrap();
                for l in 0..len {
                    for r in l..len {
                        assert_eq!(
                            inst.probe(&xs, l, r, &mut c),
                            naive(&xs, l, r),
                            "len={len} v={v} ({l},{r})"
                        );
                    }
                }
            }
            // The whole point of the tag: the tables were never written.
            assert_eq!(inst.qval, qval_before, "len={len}: qval rewritten by add tag");
            assert_eq!(inst.node_qmin, qmin_before, "len={len}: node_qmin rewritten");
        }
    }

    #[test]
    fn assign_tag_collapses_to_a_constant_block() {
        let mut rng = Rng::new(67);
        let mut set = ShapeSet::default();
        let len = 48;
        let shape = set.ensure(len, SHAPE_LEAF_SIZE);
        let mut xs: Vec<f32> = (0..len).map(|_| rng.f32()).collect();
        let mut inst = InstancedBlock::build(&xs, shape.clone());
        let qval_before = inst.qval.clone();
        xs.iter_mut().for_each(|x| *x = -2.5);
        inst.apply_assign(-2.5);
        inst.validate(&xs).unwrap();
        assert_eq!(inst.scale, 0.0);
        assert_eq!(inst.qval, qval_before, "assign tag must not rewrite the leaf table");
        let mut c = Counters::default();
        for l in 0..len {
            for r in l..len {
                assert_eq!(inst.probe(&xs, l, r, &mut c), l, "leftmost of all-equal");
            }
        }
        // assign-then-add composition: the constant block shifts rigidly.
        xs.iter_mut().for_each(|x| *x += 0.75);
        inst.apply_add(&xs, 0.75);
        inst.validate(&xs).unwrap();
        assert_eq!(inst.probe(&xs, 0, len - 1, &mut c), 0);
        // A later point refit on the degenerate transform re-derives it.
        xs[10] = -9.0;
        inst.refit_point(10, -9.0, &xs);
        inst.validate(&xs).unwrap();
        assert!(inst.scale > 0.0, "refit re-derived the transform");
        for l in 0..len {
            for r in l..len {
                assert_eq!(inst.probe(&xs, l, r, &mut c), naive(&xs, l, r));
            }
        }
    }

    #[test]
    fn refit_retightens_a_stale_floor() {
        // Regression: lower an element far below the block, raise it
        // back, repeat. The old refit only ever lowered v_lo, so after
        // a few cycles every live value quantized into the top slice of
        // the bucket grid and resolution was effectively lost. The
        // refit must re-derive the floor once the dead headroom
        // dominates.
        let mut rng = Rng::new(71);
        let mut set = ShapeSet::default();
        let len = 64;
        let shape = set.ensure(len, SHAPE_LEAF_SIZE);
        let mut xs: Vec<f32> = (0..len).map(|_| rng.f32()).collect();
        let mut inst = InstancedBlock::build(&xs, shape.clone());
        let mut c = Counters::default();
        for cycle in 0..6 {
            let dip = -100.0 * (cycle + 1) as f32;
            xs[3] = dip;
            inst.refit_point(3, dip, &xs);
            inst.validate(&xs).unwrap();
            let raised = rng.f32();
            xs[3] = raised;
            inst.refit_point(3, raised, &xs);
            inst.validate(&xs).unwrap();
            for _ in 0..16 {
                let l = rng.range(0, len - 1);
                let r = rng.range(l, len - 1);
                assert_eq!(inst.probe(&xs, l, r, &mut c), naive(&xs, l, r));
            }
        }
        // After the last raise the floor must sit near the live values
        // again, not at cycle 6's -600: with the re-tighten, at least
        // three quarters of the span covers the live range.
        let live_min = xs.iter().cloned().fold(f32::INFINITY, f32::min);
        let span = 65535.0 * inst.scale;
        assert!(
            live_min - inst.v_lo <= span * 0.25 + f32::EPSILON,
            "stale floor: v_lo={} live_min={live_min} span={span}",
            inst.v_lo
        );
    }

    #[test]
    fn quantize_guards_the_lower_bound() {
        // Awkward scales where floor + f32 rounding can overshoot.
        for &(lo, hi) in
            &[(0.0f32, 1.0f32), (-3.7, 11.3), (1e-6, 2e-6), (0.1, 0.1000001), (-1e6, 1e6)]
        {
            let scale = if hi > lo { (hi - lo) / 65535.0 } else { 0.0 };
            for k in 0..=100 {
                let v = lo + (hi - lo) * k as f32 / 100.0;
                let q = quantize(v, lo, scale);
                assert!(lo + q as f32 * scale <= v, "lo={lo} hi={hi} v={v} q={q}");
            }
            // Above the representable range: clamps to the top bucket.
            let q = quantize(hi + (hi - lo).abs() + 1.0, lo, scale);
            assert!(lo + q as f32 * scale <= hi + (hi - lo).abs() + 1.0);
        }
        assert_eq!(quantize(5.0, 5.0, 0.0), 0, "degenerate scale");
    }
}

//! Closest-hit traversal for the paper's +X query rays, with the work
//! counters the RT cost model consumes (node visits ↔ the "bounding box
//! intersections between the ray and the internal nodes" the paper blames
//! for the flat layout's O(n log n) behaviour, §5.2).

use super::Bvh;
use crate::geometry::{point_in_footprint, Ray, Triangle};

/// Work performed by one or more ray casts. These are the *measured*
/// quantities converted to modeled GPU time by `crate::model::rtcost`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counters {
    /// BVH nodes popped and examined.
    pub nodes_visited: u64,
    /// Child AABB slab tests.
    pub aabb_tests: u64,
    /// Ray–triangle tests executed.
    pub tri_tests: u64,
    /// Rays launched.
    pub rays: u64,
    /// Node memory fetches. In scalar traversal this equals
    /// `nodes_visited` (one fetch per pop, one ray per pop); in packet
    /// traversal (`bvh::wide::closest_hit_packet`,
    /// `bvh::instanced::probe_packet`) a node popped once serves every
    /// ray in the packet, so `node_fetches` counts one per pop per
    /// *packet* while `nodes_visited` charges the pop per ray serviced —
    /// `nodes_visited / node_fetches` is the amortization factor
    /// bench-smoke reports, and equality is the scalar/fallback
    /// signature.
    pub node_fetches: u64,
}

impl Counters {
    pub fn add(&mut self, o: &Counters) {
        self.nodes_visited += o.nodes_visited;
        self.aabb_tests += o.aabb_tests;
        self.tri_tests += o.tri_tests;
        self.rays += o.rays;
        self.node_fetches += o.node_fetches;
    }
}

/// A closest hit: distance along +X and the primitive id.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Hit {
    pub t: f32,
    pub prim: u32,
}

/// Reusable traversal stack (allocation-free hot loop — one per worker).
pub struct TraversalStack {
    stack: Vec<(u32, f32)>,
}

impl Default for TraversalStack {
    fn default() -> Self {
        Self::new()
    }
}

impl TraversalStack {
    pub fn new() -> TraversalStack {
        TraversalStack { stack: Vec::with_capacity(96) }
    }
}

/// Cast one +X ray and return its closest hit (ties broken towards the
/// smallest prim id — the leftmost array element, matching the paper's
/// leftmost-minimum convention).
pub fn closest_hit(
    bvh: &Bvh,
    tris: &[Triangle],
    ray: &Ray,
    ts: &mut TraversalStack,
    counters: &mut Counters,
) -> Option<Hit> {
    closest_hit_from(bvh, tris, ray, ts, counters, None)
}

/// The paper's payload-min variant (§5.3): seed the traversal with the
/// best hit of *previous* rays of the same Algorithm-6 query, so a
/// later sub-ray prunes every subtree whose entry distance already
/// exceeds the carried minimum. t-values are globally comparable (t =
/// value − Θ for every cell).
pub fn closest_hit_from(
    bvh: &Bvh,
    tris: &[Triangle],
    ray: &Ray,
    ts: &mut TraversalStack,
    counters: &mut Counters,
    init_best: Option<Hit>,
) -> Option<Hit> {
    counters.rays += 1;
    let origin = ray.origin;
    let mut best: Option<Hit> = init_best;
    // Whether `best` came from a *previous* sub-ray. Prim-id tie-breaks
    // are only meaningful within one geometry region (one cell's prims
    // are index-ordered; block-min prims are block-ordered); across
    // sub-rays the earlier ray covers strictly smaller array indices, so
    // a carried hit always wins an equal-t tie.
    let mut carried = init_best.is_some();
    ts.stack.clear();
    counters.aabb_tests += 1;
    if let Some(t) = bvh.nodes[0].aabb.entry_posx(origin) {
        ts.stack.push((0, t));
    }
    while let Some((ni, entry)) = ts.stack.pop() {
        if let Some(b) = best {
            // Prune: nothing in this subtree can beat the current hit.
            // Strictly-greater prune keeps equal-t candidates alive for
            // the leftmost tie-break.
            if entry > b.t {
                continue;
            }
        }
        counters.nodes_visited += 1;
        counters.node_fetches += 1;
        let node = &bvh.nodes[ni as usize];
        if node.is_leaf() {
            for k in node.first..node.first + node.count {
                let prim = bvh.prim_order[k as usize];
                let tri = &tris[prim as usize];
                counters.tri_tests += 1;
                let t = tri.x_plane() - origin[0];
                if t < 0.0 {
                    continue; // behind the origin (t_min = 0)
                }
                if let Some(b) = best {
                    if t > b.t || (t == b.t && (carried || tri.prim >= b.prim)) {
                        continue;
                    }
                }
                // Perf fast path (§Perf L3.1): for every valid ray origin
                // (a cell's query space) the triangle footprint is exactly
                // the open rectangle y < l_i ∧ z > r_i clipped to the
                // triangle's own extent (the extent terms only exclude
                // rays from *other* cells, which the 3-unit cell pitch
                // keeps ≥ 1 unit away; the hypotenuse never cuts a query
                // space — geometry::tests prove both). The full half-plane
                // test remains the debug-mode oracle.
                let hit = origin[1] < tri.v0[1]
                    && origin[2] > tri.v0[2]
                    && origin[1] > tri.v2[1]
                    && origin[2] < tri.v1[2];
                debug_assert_eq!(hit, point_in_footprint(origin[1], origin[2], tri));
                if hit {
                    best = Some(Hit { t, prim: tri.prim });
                    carried = false;
                }
            }
        } else {
            counters.aabb_tests += 2;
            let lt = bvh.nodes[node.left as usize].aabb.entry_posx(origin);
            let rt = bvh.nodes[node.right as usize].aabb.entry_posx(origin);
            // Push the farther child first so the nearer is traversed
            // next (front-to-back order enables early pruning).
            match (lt, rt) {
                (Some(a), Some(b)) => {
                    if a <= b {
                        ts.stack.push((node.right, b));
                        ts.stack.push((node.left, a));
                    } else {
                        ts.stack.push((node.left, a));
                        ts.stack.push((node.right, b));
                    }
                }
                (Some(a), None) => ts.stack.push((node.left, a)),
                (None, Some(b)) => ts.stack.push((node.right, b)),
                (None, None) => {}
            }
        }
    }
    best
}

/// Cast a batch of rays sequentially with a shared stack; returns hits
/// and accumulates counters. (Parallel batching lives in `rtcore`.)
pub fn cast_batch(
    bvh: &Bvh,
    tris: &[Triangle],
    rays: &[Ray],
    counters: &mut Counters,
) -> Vec<Option<Hit>> {
    let mut ts = TraversalStack::new();
    rays.iter().map(|r| closest_hit(bvh, tris, r, &mut ts, counters)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bvh::{build::build, Builder};
    use crate::geometry::flat::{build_scene, ray_for_query, ray_origin_x};
    use crate::rmq::naive_rmq;
    use crate::util::proptest::{check, gen};

    #[test]
    fn global_min_simple_case() {
        // §5.1: computing the minimum of [5,3,1,9,6,2] = RMQ(0, n-1).
        let xs = [5.0, 3.0, 1.0, 9.0, 6.0, 2.0];
        let tris = build_scene(&xs);
        let bvh = build(&tris, Builder::BinnedSah, 2);
        let ray = ray_for_query(0, 5, 6, ray_origin_x(&xs));
        let mut c = Counters::default();
        let hit =
            closest_hit(&bvh, &tris, &ray, &mut TraversalStack::new(), &mut c).expect("must hit");
        assert_eq!(hit.prim, 2);
        assert_eq!(c.rays, 1);
        assert!(c.nodes_visited > 0 && c.tri_tests > 0);
    }

    #[test]
    fn figure5_query() {
        // Figure 5: RMQ(3,5) on [5,3,1,9,6,2] = index 5 (value 2).
        let xs = [5.0, 3.0, 1.0, 9.0, 6.0, 2.0];
        let tris = build_scene(&xs);
        let bvh = build(&tris, Builder::BinnedSah, 2);
        let ray = ray_for_query(3, 5, 6, ray_origin_x(&xs));
        let mut c = Counters::default();
        let hit = closest_hit(&bvh, &tris, &ray, &mut TraversalStack::new(), &mut c).unwrap();
        assert_eq!(hit.prim, 5);
    }

    #[test]
    fn both_builders_match_oracle() {
        check("closest hit == rmq (sah+lbvh)", 60, |rng| {
            let xs = gen::f32_array(rng, 1..=800);
            let n = xs.len();
            let tris = build_scene(&xs);
            let theta = ray_origin_x(&xs);
            for builder in [Builder::BinnedSah, Builder::Lbvh] {
                let bvh = build(&tris, builder, 4);
                let mut ts = TraversalStack::new();
                let mut c = Counters::default();
                for _ in 0..16 {
                    let (l, r) = gen::query(rng, n);
                    let ray = ray_for_query(l as u32, r as u32, n, theta);
                    let hit = closest_hit(&bvh, &tris, &ray, &mut ts, &mut c)
                        .ok_or_else(|| format!("no hit for ({l},{r})"))?;
                    let want = naive_rmq(&xs, l, r);
                    if hit.prim as usize != want {
                        return Err(format!(
                            "{builder:?} ({l},{r}): got {} want {want}",
                            hit.prim
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ties_resolve_leftmost() {
        check("equal values leftmost", 60, |rng| {
            let xs = gen::dup_array(rng, 1..=400, 2);
            let n = xs.len();
            let tris = build_scene(&xs);
            let bvh = build(&tris, Builder::BinnedSah, 4);
            let theta = ray_origin_x(&xs);
            let mut ts = TraversalStack::new();
            let mut c = Counters::default();
            for _ in 0..16 {
                let (l, r) = gen::query(rng, n);
                let ray = ray_for_query(l as u32, r as u32, n, theta);
                let hit = closest_hit(&bvh, &tris, &ray, &mut ts, &mut c).unwrap();
                let want = naive_rmq(&xs, l, r);
                if hit.prim as usize != want {
                    return Err(format!("({l},{r}): got {} want {want}", hit.prim));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn counters_accumulate_over_batch() {
        let xs = crate::util::rng::Rng::new(9).uniform_f32_vec(256);
        let tris = build_scene(&xs);
        let bvh = build(&tris, Builder::BinnedSah, 4);
        let theta = ray_origin_x(&xs);
        let rays: Vec<Ray> =
            (0..32).map(|i| ray_for_query(i, 128 + i, 256, theta)).collect();
        let mut c = Counters::default();
        let hits = cast_batch(&bvh, &tris, &rays, &mut c);
        assert_eq!(hits.len(), 32);
        assert!(hits.iter().all(|h| h.is_some()));
        assert_eq!(c.rays, 32);
        assert!(c.nodes_visited >= 32);
    }

    #[test]
    fn refit_preserves_correctness_after_value_update() {
        // Dynamic RMQ (paper §7.iii): change values, refit, re-query.
        let mut xs = crate::util::rng::Rng::new(11).uniform_f32_vec(128);
        let mut tris = build_scene(&xs);
        let mut bvh = build(&tris, Builder::BinnedSah, 4);
        // Update some values (keep within [0,1) so theta = min-1 works).
        xs[7] = 0.001;
        xs[100] = 0.002;
        tris = build_scene(&xs);
        bvh.refit(&tris);
        bvh.validate(&tris).unwrap();
        let theta = ray_origin_x(&xs);
        let mut ts = TraversalStack::new();
        let mut c = Counters::default();
        for (l, r) in [(0u32, 127u32), (5, 20), (90, 110), (7, 7)] {
            let ray = ray_for_query(l, r, 128, theta);
            let hit = closest_hit(&bvh, &tris, &ray, &mut ts, &mut c).unwrap();
            assert_eq!(hit.prim as usize, naive_rmq(&xs, l as usize, r as usize), "({l},{r})");
        }
    }

    #[test]
    fn sah_visits_fewer_nodes_than_worst_case() {
        // Sanity: for a small-range query, front-to-back pruning should
        // visit far fewer nodes than the tree has.
        let xs = crate::util::rng::Rng::new(13).uniform_f32_vec(4096);
        let tris = build_scene(&xs);
        let bvh = build(&tris, Builder::BinnedSah, 4);
        let theta = ray_origin_x(&xs);
        let mut c = Counters::default();
        let ray = ray_for_query(100, 116, 4096, theta); // small range
        closest_hit(&bvh, &tris, &ray, &mut TraversalStack::new(), &mut c).unwrap();
        assert!(
            (c.nodes_visited as usize) < bvh.nodes.len() / 4,
            "visited {} of {} nodes",
            c.nodes_visited,
            bvh.nodes.len()
        );
    }
}

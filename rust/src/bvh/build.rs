//! BVH construction: binned SAH (quality reference) and Morton-order
//! LBVH (the GPU-builder analogue; OptiX's fast build path is in this
//! family). Both produce the same flat [`Node`] layout, so traversal and
//! the cost model are builder-agnostic — the Fig-ablation bench compares
//! their traversal work on identical workloads.
//!
//! [`collapse_to_wide`] then folds either binary tree into the 4-wide
//! SoA hot-path layout ([`crate::bvh::wide::WideBvh`]).

use super::instanced::{ShapeNode, ShapeTree, MAX_INSTANCED_LEN, NO_CHILD};
use super::wide::{WideBvh, WideNode, WidePrim};
use super::{Aabb, Builder, Bvh, Node};
use crate::geometry::Triangle;
use crate::util::bits::morton3_canonical;

/// Number of SAH bins per axis.
const SAH_BINS: usize = 16;

/// Build a BVH with the requested builder and leaf size.
pub fn build(tris: &[Triangle], builder: Builder, leaf_size: usize) -> Bvh {
    assert!(!tris.is_empty(), "no triangles");
    let leaf_size = leaf_size.max(1);
    match builder {
        Builder::BinnedSah => build_sah(tris, leaf_size),
        Builder::Lbvh => build_lbvh(tris, leaf_size),
    }
}

/// Per-primitive build info.
struct PrimInfo {
    aabb: Aabb,
    centroid: [f32; 3],
}

fn prim_infos(tris: &[Triangle]) -> Vec<PrimInfo> {
    tris.iter()
        .map(|t| {
            let aabb = Aabb::from_triangle(t);
            PrimInfo { aabb, centroid: aabb.centroid() }
        })
        .collect()
}

fn range_bounds(info: &[PrimInfo], order: &[u32]) -> (Aabb, Aabb) {
    let mut bounds = Aabb::EMPTY;
    let mut cbounds = Aabb::EMPTY;
    for &p in order {
        bounds = bounds.union(&info[p as usize].aabb);
        cbounds.grow_point(info[p as usize].centroid);
    }
    (bounds, cbounds)
}

// ---------------------------------------------------------------- SAH --

fn build_sah(tris: &[Triangle], leaf_size: usize) -> Bvh {
    let info = prim_infos(tris);
    let mut order: Vec<u32> = (0..tris.len() as u32).collect();
    let mut nodes: Vec<Node> = Vec::with_capacity(2 * tris.len());
    nodes.push(Node { aabb: Aabb::EMPTY, left: 0, right: 0, first: 0, count: 0 });
    // Explicit stack of (node index, range) to avoid recursion depth
    // limits on adversarial scenes.
    let mut stack = vec![(0usize, 0usize, tris.len())];
    while let Some((ni, start, end)) = stack.pop() {
        let (bounds, cbounds) = range_bounds(&info, &order[start..end]);
        nodes[ni].aabb = bounds;
        let len = end - start;
        if len <= leaf_size {
            nodes[ni].first = start as u32;
            nodes[ni].count = len as u32;
            continue;
        }
        // Choose the widest centroid axis.
        let ext = [
            cbounds.hi[0] - cbounds.lo[0],
            cbounds.hi[1] - cbounds.lo[1],
            cbounds.hi[2] - cbounds.lo[2],
        ];
        let axis = if ext[0] >= ext[1] && ext[0] >= ext[2] {
            0
        } else if ext[1] >= ext[2] {
            1
        } else {
            2
        };
        let mut mid = start + len / 2; // fallback: median split
        if ext[axis] > 1e-12 {
            // Binned SAH along `axis`.
            let k = SAH_BINS as f32 * (1.0 - 1e-6) / ext[axis];
            let mut bin_bounds = [Aabb::EMPTY; SAH_BINS];
            let mut bin_count = [0usize; SAH_BINS];
            for &p in &order[start..end] {
                let b = (k * (info[p as usize].centroid[axis] - cbounds.lo[axis])) as usize;
                let b = b.min(SAH_BINS - 1);
                bin_bounds[b] = bin_bounds[b].union(&info[p as usize].aabb);
                bin_count[b] += 1;
            }
            // Sweep to find the cheapest split.
            let mut right_acc = [Aabb::EMPTY; SAH_BINS];
            let mut acc = Aabb::EMPTY;
            for b in (1..SAH_BINS).rev() {
                acc = acc.union(&bin_bounds[b]);
                right_acc[b] = acc;
            }
            let mut left_bb = Aabb::EMPTY;
            let mut left_n = 0usize;
            let mut best_cost = f32::INFINITY;
            let mut best_bin = 0usize;
            for b in 0..SAH_BINS - 1 {
                left_bb = left_bb.union(&bin_bounds[b]);
                left_n += bin_count[b];
                let right_n = len - left_n;
                if left_n == 0 || right_n == 0 {
                    continue;
                }
                let cost = left_bb.surface_area() * left_n as f32
                    + right_acc[b + 1].surface_area() * right_n as f32;
                if cost < best_cost {
                    best_cost = cost;
                    best_bin = b;
                }
            }
            if best_cost.is_finite() {
                // Partition by bin.
                let split_val = |p: u32| {
                    let b = (k * (info[p as usize].centroid[axis] - cbounds.lo[axis])) as usize;
                    b.min(SAH_BINS - 1) <= best_bin
                };
                mid = partition(&mut order[start..end], split_val) + start;
                if mid == start || mid == end {
                    mid = start + len / 2;
                    order[start..end].sort_unstable_by(|&a, &b| {
                        info[a as usize].centroid[axis]
                            .partial_cmp(&info[b as usize].centroid[axis])
                            .unwrap()
                    });
                }
            } else {
                order[start..end].sort_unstable_by(|&a, &b| {
                    info[a as usize].centroid[axis]
                        .partial_cmp(&info[b as usize].centroid[axis])
                        .unwrap()
                });
            }
        }
        let li = nodes.len();
        nodes.push(Node { aabb: Aabb::EMPTY, left: 0, right: 0, first: 0, count: 0 });
        let ri = nodes.len();
        nodes.push(Node { aabb: Aabb::EMPTY, left: 0, right: 0, first: 0, count: 0 });
        nodes[ni].left = li as u32;
        nodes[ni].right = ri as u32;
        // Push right first so left is processed next (locality).
        stack.push((ri, mid, end));
        stack.push((li, start, mid));
    }
    Bvh { nodes, prim_order: order, builder: Builder::BinnedSah, leaf_size }
}

// ----------------------------------------------------- BVH2 → BVH4 --

/// Expand a binary node into up to 4 subtree roots for one wide node:
/// start from the node's two children and repeatedly replace the
/// largest-surface-area internal candidate with its two children until
/// four slots are filled or only leaves remain. A leaf root collapses to
/// a single-lane node.
fn expand_children(bvh: &Bvh, ni: u32) -> ([u32; 4], usize) {
    let node = bvh.nodes[ni as usize];
    if node.is_leaf() {
        return ([ni, 0, 0, 0], 1);
    }
    let mut targets = [node.left, node.right, 0, 0];
    let mut len = 2usize;
    while len < 4 {
        let mut pick: Option<usize> = None;
        let mut best_area = f32::NEG_INFINITY;
        for (i, &t) in targets.iter().enumerate().take(len) {
            let n = &bvh.nodes[t as usize];
            if !n.is_leaf() {
                let a = n.aabb.surface_area();
                if a > best_area {
                    best_area = a;
                    pick = Some(i);
                }
            }
        }
        match pick {
            None => break,
            Some(i) => {
                let n = bvh.nodes[targets[i] as usize];
                targets[i] = n.left;
                targets[len] = n.right;
                len += 1;
            }
        }
    }
    (targets, len)
}

/// Collapse a built binary BVH into the 4-wide SoA layout
/// ([`crate::bvh::AccelLayout::Wide`]): every wide node covers up to four
/// binary subtrees, with per-lane (y, z) intervals and `xmin` laid out
/// for straight-line +X interval tests, and leaf lanes pointing at
/// contiguous runs of compact [`WidePrim`] records. Children are emitted
/// in DFS preorder so lane indices always point forward (refit relies on
/// this). Works for both builders; the traversal result is hit-identical
/// to the binary tree's.
pub fn collapse_to_wide(bvh: &Bvh, tris: &[Triangle]) -> WideBvh {
    assert!(!bvh.nodes.is_empty(), "empty bvh");
    assert!(bvh.leaf_size <= u8::MAX as usize, "wide layout packs leaf counts in u8");
    let mut nodes: Vec<WideNode> = Vec::with_capacity(bvh.nodes.len() / 2 + 1);
    let mut prims: Vec<WidePrim> = Vec::with_capacity(bvh.prim_order.len());
    nodes.push(WideNode::empty());
    let (targets, tlen) = expand_children(bvh, 0);
    let mut work: Vec<(usize, [u32; 4], usize)> = vec![(0, targets, tlen)];
    while let Some((wi, targets, tlen)) = work.pop() {
        for (k, &target) in targets.iter().enumerate().take(tlen) {
            let b = bvh.nodes[target as usize];
            {
                let n = &mut nodes[wi];
                n.ymin[k] = b.aabb.lo[1];
                n.ymax[k] = b.aabb.hi[1];
                n.zmin[k] = b.aabb.lo[2];
                n.zmax[k] = b.aabb.hi[2];
                n.xmin[k] = b.aabb.lo[0];
            }
            if b.is_leaf() {
                let first = prims.len() as u32;
                for j in b.first..b.first + b.count {
                    let ti = bvh.prim_order[j as usize] as usize;
                    let tri = &tris[ti];
                    // Refit resolves records back through `prim`, which
                    // both geometry modes keep equal to the triangle's
                    // index in the scene array.
                    debug_assert_eq!(tri.prim as usize, ti);
                    prims.push(WidePrim::from_triangle(tri));
                }
                nodes[wi].child[k] = first;
                nodes[wi].count[k] = b.count as u8;
            } else {
                let ci = nodes.len();
                nodes.push(WideNode::empty());
                nodes[wi].child[k] = ci as u32;
                let (ct, cl) = expand_children(bvh, target);
                work.push((ci, ct, cl));
            }
        }
    }
    debug_assert_eq!(prims.len(), bvh.prim_order.len());
    WideBvh { nodes, prims, leaf_size: bvh.leaf_size }
}

// ------------------------------------------------------- shape trees --

/// Build the shared shape for all blocks of length `len`
/// ([`crate::bvh::instanced`]): a balanced 4-ary positional interval
/// tree over `[0, len)`. Unlike the geometric builders above there is
/// nothing to optimize — the "scene" is the integer line, every block
/// of this length maps to the same footprint — so the split is a plain
/// even 4-way chunking, recursed until a chunk fits one leaf lane.
/// Children are emitted in position order directly after their parent
/// (DFS preorder, forward child pointers), which gives the instance
/// refit its one-reverse-sweep property and the probe its
/// left-to-right lane order.
pub fn build_shape_tree(len: usize, leaf_size: usize) -> ShapeTree {
    assert!(len >= 1, "empty shape");
    assert!(len <= MAX_INSTANCED_LEN, "instanced positions are u16 (len {len} > 2^16)");
    let leaf_size = leaf_size.clamp(1, u8::MAX as usize);
    let mut nodes: Vec<ShapeNode> = Vec::new();
    let mut parent: Vec<u32> = Vec::new();
    let mut node_of_pos: Vec<u32> = vec![0; len];
    let mut lane_of_pos: Vec<u8> = vec![0; len];
    // Recursion depth is log4(len/leaf) ≤ 8 for len ≤ 2^16 — safe.
    #[allow(clippy::too_many_arguments)]
    fn grow(
        lo: usize,
        hi: usize, // exclusive
        par: u32,
        leaf_size: usize,
        nodes: &mut Vec<ShapeNode>,
        parent: &mut Vec<u32>,
        node_of_pos: &mut [u32],
        lane_of_pos: &mut [u8],
    ) -> u32 {
        let ni = nodes.len() as u32;
        nodes.push(ShapeNode::empty());
        parent.push(par);
        let span = hi - lo;
        let (base, rem) = (span / 4, span % 4);
        let mut at = lo;
        for lane in 0..4 {
            let size = base + usize::from(lane < rem);
            if size == 0 {
                continue; // empty lane (span < 4)
            }
            let (clo, chi) = (at, at + size);
            at = chi;
            let n = &mut nodes[ni as usize];
            n.pmin[lane] = clo as u16;
            n.pmax[lane] = (chi - 1) as u16;
            if size <= leaf_size {
                n.count[lane] = size as u8;
                for p in clo..chi {
                    node_of_pos[p] = ni;
                    lane_of_pos[p] = lane as u8;
                }
            } else {
                let child =
                    grow(clo, chi, ni, leaf_size, nodes, parent, node_of_pos, lane_of_pos);
                nodes[ni as usize].child[lane] = child;
            }
        }
        ni
    }
    if len <= leaf_size {
        // Single node, one leaf lane covering the whole block.
        nodes.push(ShapeNode::empty());
        parent.push(NO_CHILD);
        let n = &mut nodes[0];
        n.pmin[0] = 0;
        n.pmax[0] = (len - 1) as u16;
        n.count[0] = len as u8;
        // node_of_pos/lane_of_pos are already all zeros.
    } else {
        grow(0, len, NO_CHILD, leaf_size, &mut nodes, &mut parent, &mut node_of_pos, &mut lane_of_pos);
    }
    ShapeTree { len, leaf_size, nodes, parent, node_of_pos, lane_of_pos }
}

/// In-place stable-ish partition; returns count of elements satisfying
/// the predicate (placed first).
fn partition(xs: &mut [u32], pred: impl Fn(u32) -> bool) -> usize {
    let mut i = 0;
    for j in 0..xs.len() {
        if pred(xs[j]) {
            xs.swap(i, j);
            i += 1;
        }
    }
    i
}

// --------------------------------------------------------------- LBVH --

fn build_lbvh(tris: &[Triangle], leaf_size: usize) -> Bvh {
    let info = prim_infos(tris);
    // Scene centroid bounds for Morton quantization.
    let mut cbounds = Aabb::EMPTY;
    for pi in &info {
        cbounds.grow_point(pi.centroid);
    }
    let scale = |v: f32, lo: f32, hi: f32| -> u32 {
        if hi <= lo {
            return 0;
        }
        let t = ((v - lo) / (hi - lo)).clamp(0.0, 1.0);
        (t * ((1 << 21) - 1) as f32) as u32
    };
    let mut keyed: Vec<(u64, u32)> = info
        .iter()
        .enumerate()
        .map(|(i, pi)| {
            let m = morton3_canonical(
                scale(pi.centroid[0], cbounds.lo[0], cbounds.hi[0]),
                scale(pi.centroid[1], cbounds.lo[1], cbounds.hi[1]),
                scale(pi.centroid[2], cbounds.lo[2], cbounds.hi[2]),
            );
            (m, i as u32)
        })
        .collect();
    keyed.sort_unstable();
    let codes: Vec<u64> = keyed.iter().map(|&(m, _)| m).collect();
    let order: Vec<u32> = keyed.iter().map(|&(_, i)| i).collect();

    let mut nodes: Vec<Node> = Vec::with_capacity(2 * tris.len());
    nodes.push(Node { aabb: Aabb::EMPTY, left: 0, right: 0, first: 0, count: 0 });
    let mut stack = vec![(0usize, 0usize, tris.len())];
    while let Some((ni, start, end)) = stack.pop() {
        let mut bb = Aabb::EMPTY;
        for &p in &order[start..end] {
            bb = bb.union(&info[p as usize].aabb);
        }
        nodes[ni].aabb = bb;
        let len = end - start;
        if len <= leaf_size {
            nodes[ni].first = start as u32;
            nodes[ni].count = len as u32;
            continue;
        }
        // Split where the highest differing Morton bit flips (Karras);
        // falls back to the median when all codes are equal.
        let first = codes[start];
        let last = codes[end - 1];
        let mid = if first == last {
            start + len / 2
        } else {
            let msb = 63 - (first ^ last).leading_zeros();
            let mask = !0u64 << msb;
            // Binary search for the first index whose masked code differs
            // from `first`'s.
            let target = first & mask;
            let mut lo = start;
            let mut hi = end;
            while lo < hi {
                let m = (lo + hi) / 2;
                if codes[m] & mask == target {
                    lo = m + 1;
                } else {
                    hi = m;
                }
            }
            lo.clamp(start + 1, end - 1)
        };
        let li = nodes.len();
        nodes.push(Node { aabb: Aabb::EMPTY, left: 0, right: 0, first: 0, count: 0 });
        let ri = nodes.len();
        nodes.push(Node { aabb: Aabb::EMPTY, left: 0, right: 0, first: 0, count: 0 });
        nodes[ni].left = li as u32;
        nodes[ni].right = ri as u32;
        stack.push((ri, mid, end));
        stack.push((li, start, mid));
    }
    Bvh { nodes, prim_order: order, builder: Builder::Lbvh, leaf_size }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::flat::build_scene;
    use crate::util::proptest::{check, gen};

    fn scenes(rng: &mut crate::util::rng::Rng) -> Vec<Triangle> {
        let xs = gen::f32_array(rng, 1..=600);
        build_scene(&xs)
    }

    #[test]
    fn sah_valid_structure() {
        check("sah structural invariants", 40, |rng| {
            let tris = scenes(rng);
            let bvh = build(&tris, Builder::BinnedSah, 4);
            bvh.validate(&tris)
        });
    }

    #[test]
    fn lbvh_valid_structure() {
        check("lbvh structural invariants", 40, |rng| {
            let tris = scenes(rng);
            let bvh = build(&tris, Builder::Lbvh, 4);
            bvh.validate(&tris)
        });
    }

    #[test]
    fn single_triangle() {
        let tris = build_scene(&[0.5]);
        for b in [Builder::BinnedSah, Builder::Lbvh] {
            let bvh = build(&tris, b, 4);
            assert_eq!(bvh.nodes.len(), 1);
            assert!(bvh.nodes[0].is_leaf());
            bvh.validate(&tris).unwrap();
        }
    }

    #[test]
    fn identical_positions_dont_loop() {
        // Constant array: all triangles in the same plane with nested
        // footprints; centroid extents degenerate on x.
        let xs = vec![0.5f32; 257];
        let tris = build_scene(&xs);
        for b in [Builder::BinnedSah, Builder::Lbvh] {
            let bvh = build(&tris, b, 2);
            bvh.validate(&tris).unwrap();
        }
    }

    #[test]
    fn leaf_size_respected() {
        let mut rng = crate::util::rng::Rng::new(44);
        let xs = rng.uniform_f32_vec(1000);
        let tris = build_scene(&xs);
        for ls in [1usize, 2, 8] {
            let bvh = build(&tris, Builder::BinnedSah, ls);
            for n in &bvh.nodes {
                if n.is_leaf() {
                    assert!(n.count as usize <= ls.max(1), "leaf of {} > {}", n.count, ls);
                }
            }
        }
    }

    #[test]
    fn sah_reasonable_depth() {
        // Uniform random values should produce a tree of depth O(log n),
        // not a degenerate list.
        let mut rng = crate::util::rng::Rng::new(45);
        let xs = rng.uniform_f32_vec(4096);
        let tris = build_scene(&xs);
        let bvh = build(&tris, Builder::BinnedSah, 4);
        // depth via DFS
        let mut max_depth = 0usize;
        let mut stack = vec![(0u32, 1usize)];
        while let Some((ni, d)) = stack.pop() {
            max_depth = max_depth.max(d);
            let n = &bvh.nodes[ni as usize];
            if !n.is_leaf() {
                stack.push((n.left, d + 1));
                stack.push((n.right, d + 1));
            }
        }
        assert!(max_depth <= 64, "depth {max_depth} too deep for n=4096");
    }
}

//! Bounding Volume Hierarchy substrate — the software stand-in for the
//! RT cores' hardware BVH (paper §3). Provides binned-SAH and Morton/LBVH
//! builders (GPUs build LBVH-like trees; SAH is the quality reference),
//! closest-hit traversal for the paper's +X query rays with **work
//! counters** (node visits / triangle tests — the quantities the cost
//! model converts to RT-core time), and refit for the dynamic-RMQ
//! future-work feature (§7.iii).
//!
//! # BVH layouts
//!
//! Two acceleration layouts sit behind [`AccelLayout`]:
//!
//! - **Binary (AoS)** — the [`Node`] array built directly by
//!   [`build::build`]: one AABB plus child/leaf indices per node,
//!   children tested one at a time by [`traverse::closest_hit`]. This is
//!   the correctness oracle and the layout the cost model was calibrated
//!   on; refit ([`Bvh::refit`]) supports dynamic RMQ.
//! - **Wide (4-wide SoA)** — [`wide::WideBvh`], produced by collapsing a
//!   built binary tree ([`build::collapse_to_wide`]). Each node holds
//!   four child lanes as per-component arrays
//!   (`ymin[4]/ymax[4]/zmin[4]/zmax[4]/xmin[4]` + packed child/leaf
//!   metadata), exploiting the **+X specialization**: every query ray
//!   travels along (1, 0, 0) from below the scene, so a box test is two
//!   interval checks on (y, z) plus the entry distance `xmin − θ`, and
//!   `xmax` can be dropped entirely. Leaves are compact
//!   [`wide::WidePrim`] records scanned cache-linearly. Hits (prim id
//!   and t, including leftmost tie-breaks and Algorithm-6 carried-hit
//!   sub-rays) are identical between layouts; only the work *counters*
//!   differ.
//!
//! **Counter semantics across layouts** (consumed by
//! `crate::model::rtcost`): `nodes_visited` counts node pops in either
//! layout — a wide pop replaces roughly three binary pops; `aabb_tests`
//! counts per-child box tests — 1 for the binary root test plus 2 per
//! binary internal node, exactly 4 per wide node (all lanes are tested
//! branchlessly, empty lanes included, as wide hardware would);
//! `tri_tests` and `rays` mean the same thing in both layouts. The cost
//! model weighs both `nodes_visited` and `aabb_tests`, which is what
//! makes modeled times comparable across layouts.
//!
//! # Packet traversal (SIMD-over-queries)
//!
//! [`wide::closest_hit_packet`] carries P sorted queries down the wide
//! tree together — one descent per *packet* instead of one per ray,
//! mirroring how RT hardware amortizes node fetches across a warp of
//! coherent rays. Three rules make it exact:
//!
//! - **Envelope pruning.** A child lane is descended iff its (y, z)
//!   slabs intersect the packet's *interval envelope* (the union of the
//!   member origins) and its conservative entry `xmin − max(ox)` can
//!   still beat some active ray's best t. The envelope test is a
//!   superset of every member's scalar lane test, so no lane a member
//!   ray would visit is ever skipped — pruning stays conservative
//!   per ray.
//! - **Per-ray resolution.** Leaves are resolved with the scalar accept
//!   rule verbatim (reject `t < 0`, strict footprint, strict
//!   `(t, prim)` lexicographic improvement, carried-hit tie ownership),
//!   and lanes are pushed in the same reversed order, so pops stay
//!   left-to-right. Since every scalar prune is strict, the scalar
//!   result is the global lexicographic minimum over footprint-passing
//!   prims — any conservative traversal order with the same accept rule
//!   lands on the same hit, bit for bit. The same argument covers
//!   [`instanced::InstancedBlock::probe_packet`], whose quantized lane
//!   screen is conservative for the packet's position envelope while
//!   exact values decide each range.
//! - **Divergence fallback.** When the packet's envelope exceeds
//!   [`wide::PACKET_DIVERGENCE_FRAC`] of the root's extent, the shared
//!   descent would visit nearly the union of the members' node sets and
//!   amortize nothing; the packet drops to per-ray scalar traversal.
//!   Either path returns identical hits — the knob trades work, never
//!   answers.
//!
//! Packet counters split the per-node cost: `nodes_visited` charges a
//! shared pop once per ray serviced while `node_fetches` counts the
//! single node-record fetch, so `nodes_visited / node_fetches` reads
//! directly as the amortization factor (and equality is the
//! scalar/fallback signature). `RtCostModel::c_packet` prices the
//! fetch-shaped share of the per-node cost.

pub mod build;
pub mod instanced;
pub mod traverse;
pub mod wide;

use crate::geometry::Triangle;

/// Which acceleration-structure layout the query path traverses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AccelLayout {
    /// Binary AoS tree (correctness oracle / cost-model reference).
    Binary,
    /// 4-wide SoA tree specialized for +X point rays (hot-path default).
    #[default]
    Wide,
}

impl AccelLayout {
    pub fn name(&self) -> &'static str {
        match self {
            AccelLayout::Binary => "binary",
            AccelLayout::Wide => "wide",
        }
    }

    pub fn all() -> [AccelLayout; 2] {
        [AccelLayout::Binary, AccelLayout::Wide]
    }

    pub fn parse(s: &str) -> Option<AccelLayout> {
        match s.to_ascii_lowercase().as_str() {
            "binary" | "bvh2" => Some(AccelLayout::Binary),
            "wide" | "bvh4" | "soa" => Some(AccelLayout::Wide),
            _ => None,
        }
    }
}

/// Axis-aligned bounding box.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Aabb {
    pub lo: [f32; 3],
    pub hi: [f32; 3],
}

impl Aabb {
    pub const EMPTY: Aabb =
        Aabb { lo: [f32::INFINITY; 3], hi: [f32::NEG_INFINITY; 3] };

    pub fn from_triangle(t: &Triangle) -> Aabb {
        let (lo, hi) = t.bounds();
        Aabb { lo, hi }
    }

    pub fn union(&self, o: &Aabb) -> Aabb {
        let mut r = *self;
        for a in 0..3 {
            r.lo[a] = r.lo[a].min(o.lo[a]);
            r.hi[a] = r.hi[a].max(o.hi[a]);
        }
        r
    }

    pub fn grow_point(&mut self, p: [f32; 3]) {
        for a in 0..3 {
            self.lo[a] = self.lo[a].min(p[a]);
            self.hi[a] = self.hi[a].max(p[a]);
        }
    }

    pub fn centroid(&self) -> [f32; 3] {
        [
            0.5 * (self.lo[0] + self.hi[0]),
            0.5 * (self.lo[1] + self.hi[1]),
            0.5 * (self.lo[2] + self.hi[2]),
        ]
    }

    pub fn surface_area(&self) -> f32 {
        if self.lo[0] > self.hi[0] {
            return 0.0;
        }
        let d = [self.hi[0] - self.lo[0], self.hi[1] - self.lo[1], self.hi[2] - self.lo[2]];
        2.0 * (d[0] * d[1] + d[1] * d[2] + d[2] * d[0])
    }

    /// Slab test specialised to the paper's +X rays: the ray
    /// `(ox, oy, oz) + t·(1,0,0)` intersects iff the (y, z) point is
    /// inside the box's (y, z) extent and the box is not entirely behind
    /// the origin. Returns the entry distance (≥ 0) if hit.
    #[inline]
    pub fn entry_posx(&self, origin: [f32; 3]) -> Option<f32> {
        let (_, oy, oz) = (origin[0], origin[1], origin[2]);
        if oy < self.lo[1] || oy > self.hi[1] || oz < self.lo[2] || oz > self.hi[2] {
            return None;
        }
        if self.hi[0] < origin[0] {
            return None;
        }
        Some((self.lo[0] - origin[0]).max(0.0))
    }
}

/// Flat BVH node. A node is a leaf iff `count > 0`; then
/// `prim_order[first .. first+count]` lists its triangle indices.
/// Internal nodes store child node indices in `left`/`right`
/// (children always have larger indices than the parent — refit relies
/// on this).
#[derive(Clone, Copy, Debug)]
pub struct Node {
    pub aabb: Aabb,
    pub left: u32,
    pub right: u32,
    pub first: u32,
    pub count: u32,
}

impl Node {
    #[inline]
    pub fn is_leaf(&self) -> bool {
        self.count > 0
    }
}

/// Which construction algorithm built a BVH (ablation: SAH vs LBVH,
/// DESIGN.md §7).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Builder {
    /// Top-down binned surface-area-heuristic (quality reference).
    BinnedSah,
    /// Morton-order linear BVH (what GPU builders approximate).
    Lbvh,
}

/// Topology links for point refits ([`Bvh::refit_prims`]): parent index
/// per node plus the owning leaf per primitive. Kept outside [`Bvh`] so
/// only the dynamic-update path pays for them.
pub struct RefitLinks {
    /// `parent[i]` = parent node of `i` (`parent[0] == 0`: the root).
    pub parent: Vec<u32>,
    /// `leaf_of_prim[p]` = leaf node whose range contains primitive `p`.
    pub leaf_of_prim: Vec<u32>,
}

impl RefitLinks {
    /// Heap bytes of the link tables — once built, they are resident
    /// alongside the structure they serve, so memory accounting must
    /// include them.
    pub fn memory_bytes(&self) -> usize {
        self.parent.len() * 4 + self.leaf_of_prim.len() * 4
    }
}

/// The acceleration structure.
pub struct Bvh {
    pub nodes: Vec<Node>,
    /// Permutation: leaf ranges index into this, giving triangle ids.
    pub prim_order: Vec<u32>,
    pub builder: Builder,
    /// Max leaf size used at build time.
    pub leaf_size: usize,
}

impl Bvh {
    /// Refit: recompute all node bounds bottom-up after triangle
    /// positions changed (dynamic RMQ, paper §7.iii). Topology is kept;
    /// valid because children always follow parents in `nodes`.
    pub fn refit(&mut self, tris: &[Triangle]) {
        for i in (0..self.nodes.len()).rev() {
            let node = self.nodes[i];
            let aabb = if node.is_leaf() {
                let mut bb = Aabb::EMPTY;
                for k in node.first..node.first + node.count {
                    bb = bb.union(&Aabb::from_triangle(&tris[self.prim_order[k as usize] as usize]));
                }
                bb
            } else {
                self.nodes[node.left as usize].aabb.union(&self.nodes[node.right as usize].aabb)
            };
            self.nodes[i].aabb = aabb;
        }
    }

    /// Topology links enabling point refits ([`Bvh::refit_prims`]).
    /// Built once per structure — refits never change topology, so the
    /// links stay valid for the structure's lifetime.
    pub fn refit_links(&self) -> RefitLinks {
        let mut parent = vec![0u32; self.nodes.len()];
        let mut leaf_of_prim = vec![0u32; self.prim_order.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            if n.is_leaf() {
                for k in n.first..n.first + n.count {
                    leaf_of_prim[self.prim_order[k as usize] as usize] = i as u32;
                }
            } else {
                parent[n.left as usize] = i as u32;
                parent[n.right as usize] = i as u32;
            }
        }
        RefitLinks { parent, leaf_of_prim }
    }

    /// Point refit: recompute only the leaf-to-root bound paths of the
    /// given primitives after their triangles changed — Θ(k·depth)
    /// against the full sweep's Θ(n). Each path walks bottom-up, so an
    /// ancestor shared by several paths is recomputed once per path;
    /// the recomputation is idempotent and its *last* evaluation sees
    /// every child subtree already final, so the result is identical to
    /// [`refit`](Self::refit) provided `prims` covers every changed
    /// triangle.
    pub fn refit_prims(&mut self, tris: &[Triangle], prims: &[u32], links: &RefitLinks) {
        for &p in prims {
            let mut i = links.leaf_of_prim[p as usize] as usize;
            loop {
                let node = self.nodes[i];
                let aabb = if node.is_leaf() {
                    let mut bb = Aabb::EMPTY;
                    for k in node.first..node.first + node.count {
                        bb = bb
                            .union(&Aabb::from_triangle(&tris[self.prim_order[k as usize] as usize]));
                    }
                    bb
                } else {
                    self.nodes[node.left as usize].aabb.union(&self.nodes[node.right as usize].aabb)
                };
                self.nodes[i].aabb = aabb;
                if i == 0 {
                    break;
                }
                i = links.parent[i] as usize;
            }
        }
    }

    /// Heap bytes of the acceleration structure itself (Table 2's
    /// "default" form: our actual node array + permutation).
    pub fn memory_bytes(&self) -> usize {
        self.nodes.len() * std::mem::size_of::<Node>() + self.prim_order.len() * 4
    }

    /// Modeled OptiX-style sizes for Table 2: the device BVH stores
    /// float3 vertices (36 B/tri) plus ~64 B per node in its default
    /// (uncompacted) form; compaction packs nodes to ~32 B. These are
    /// estimates of the *external* format — our in-memory size is
    /// `memory_bytes`.
    pub fn optix_size_estimate(&self, tri_count: usize) -> (usize, usize) {
        let verts = tri_count * 36;
        let default = verts + self.nodes.len() * 64 + self.prim_order.len() * 4;
        let compacted = verts + self.nodes.len() * 32 + self.prim_order.len() * 4;
        (default, compacted)
    }

    /// Structural invariants (tests + debug builds).
    pub fn validate(&self, tris: &[Triangle]) -> Result<(), String> {
        if self.nodes.is_empty() {
            return Err("empty bvh".into());
        }
        let mut seen = vec![false; self.prim_order.len()];
        let mut stack = vec![0u32];
        let mut visited = 0usize;
        while let Some(ni) = stack.pop() {
            visited += 1;
            let n = &self.nodes[ni as usize];
            if n.is_leaf() {
                for k in n.first..n.first + n.count {
                    let p = self.prim_order[k as usize] as usize;
                    if seen[p] {
                        return Err(format!("prim {p} in two leaves"));
                    }
                    seen[p] = true;
                    // leaf bounds must contain the triangle
                    let tb = Aabb::from_triangle(&tris[p]);
                    for a in 0..3 {
                        if tb.lo[a] < n.aabb.lo[a] - 1e-6 || tb.hi[a] > n.aabb.hi[a] + 1e-6 {
                            return Err(format!("prim {p} escapes leaf bounds on axis {a}"));
                        }
                    }
                }
            } else {
                if n.left as usize <= ni as usize || n.right as usize <= ni as usize {
                    return Err("child index not greater than parent".into());
                }
                for &c in &[n.left, n.right] {
                    let cb = &self.nodes[c as usize].aabb;
                    for a in 0..3 {
                        if cb.lo[a] < n.aabb.lo[a] - 1e-6 || cb.hi[a] > n.aabb.hi[a] + 1e-6 {
                            return Err(format!("child {c} escapes parent bounds"));
                        }
                    }
                }
                stack.push(n.left);
                stack.push(n.right);
            }
        }
        if visited != self.nodes.len() {
            return Err(format!("unreachable nodes: visited {visited} of {}", self.nodes.len()));
        }
        if !seen.iter().all(|&s| s) {
            return Err("some prims not in any leaf".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aabb_union_and_area() {
        let a = Aabb { lo: [0.0; 3], hi: [1.0; 3] };
        let b = Aabb { lo: [2.0, 0.0, 0.0], hi: [3.0, 1.0, 1.0] };
        let u = a.union(&b);
        assert_eq!(u.lo, [0.0; 3]);
        assert_eq!(u.hi, [3.0, 1.0, 1.0]);
        assert_eq!(a.surface_area(), 6.0);
        assert_eq!(Aabb::EMPTY.surface_area(), 0.0);
    }

    #[test]
    fn posx_entry() {
        let b = Aabb { lo: [2.0, 0.0, 0.0], hi: [3.0, 1.0, 1.0] };
        assert_eq!(b.entry_posx([0.0, 0.5, 0.5]), Some(2.0));
        // origin inside the box in x: entry clamps to 0
        assert_eq!(b.entry_posx([2.5, 0.5, 0.5]), Some(0.0));
        // behind
        assert_eq!(b.entry_posx([4.0, 0.5, 0.5]), None);
        // outside yz slab
        assert_eq!(b.entry_posx([0.0, 2.0, 0.5]), None);
        assert_eq!(b.entry_posx([0.0, 0.5, -0.1]), None);
    }
}

//! Workload generation — the paper's input and query distributions (§6,
//! §6.4).
//!
//! Inputs: uniformly random f32 values in [0, 1). Queries: the start is
//! uniform; the range *length* follows one of three distributions:
//!
//! - **Large**: uniform in [1, n] (mean span ≈ n/2).
//! - **Medium**: LogNormal(µ = ln n^0.6, σ = 0.3) — mean ≈ 2^15 at n = 2^26.
//! - **Small**: LogNormal(µ = ln n^0.3, σ = 0.3) — mean ≈ 2^8 at n = 2^26.

pub mod observer;

use crate::rmq::Query;
use crate::util::rng::Rng;

/// The paper's three (l, r) range regimes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RangeDist {
    Large,
    Medium,
    Small,
}

impl RangeDist {
    pub fn name(&self) -> &'static str {
        match self {
            RangeDist::Large => "large",
            RangeDist::Medium => "medium",
            RangeDist::Small => "small",
        }
    }

    pub fn all() -> [RangeDist; 3] {
        [RangeDist::Large, RangeDist::Medium, RangeDist::Small]
    }

    pub fn parse(s: &str) -> Option<RangeDist> {
        match s.to_ascii_lowercase().as_str() {
            "large" | "l" => Some(RangeDist::Large),
            "medium" | "m" => Some(RangeDist::Medium),
            "small" | "s" => Some(RangeDist::Small),
            _ => None,
        }
    }

    /// Draw one range length for an array of size n.
    pub fn sample_len(&self, n: usize, rng: &mut Rng) -> usize {
        let nf = n as f64;
        let len = match self {
            RangeDist::Large => rng.range_u64(1, n as u64) as f64,
            RangeDist::Medium => rng.lognormal(nf.powf(0.6).ln(), 0.3),
            RangeDist::Small => rng.lognormal(nf.powf(0.3).ln(), 0.3),
        };
        (len as usize).clamp(1, n)
    }

    /// Expected mean length (used by the router's classifier tests).
    pub fn mean_len(&self, n: usize) -> f64 {
        let nf = n as f64;
        match self {
            RangeDist::Large => nf / 2.0,
            // LogNormal mean = exp(µ + σ²/2)
            RangeDist::Medium => (nf.powf(0.6).ln() + 0.045).exp(),
            RangeDist::Small => (nf.powf(0.3).ln() + 0.045).exp(),
        }
    }
}

/// The paper's input arrays: uniform f32 in [0, 1).
pub fn gen_array(n: usize, seed: u64) -> Vec<f32> {
    Rng::new(seed).uniform_f32_vec(n)
}

/// Place one query of the given length uniformly in `[0, n)`.
///
/// `len` is clamped to `[1, n]` first, so the boundary cases are exact
/// rather than accidental: `len == n` pins `l = 0, r = n - 1` (the old
/// expression `rng.range(0, n - len.min(n))` relied on the degenerate
/// inclusive range `[0, 0]` and silently re-clamped `r`), and `n == 1`
/// always yields `(0, 0)`.
pub fn place_query(n: usize, len: usize, rng: &mut Rng) -> Query {
    debug_assert!(n > 0, "empty array");
    let len = len.clamp(1, n);
    // Uniform over the n - len + 1 valid left endpoints.
    let l = rng.range(0, n - len);
    (l as u32, (l + len - 1) as u32)
}

/// A batch of queries under a range distribution.
pub fn gen_queries(n: usize, count: usize, dist: RangeDist, rng: &mut Rng) -> Vec<Query> {
    (0..count)
        .map(|_| {
            let len = dist.sample_len(n, rng);
            place_query(n, len, rng)
        })
        .collect()
}

/// A batch of point updates: uniform index, fresh uniform value in
/// [0, 1) (the paper's input distribution) — the mutable-array workload
/// the sharded engine's `update_batch` consumes.
pub fn gen_updates(n: usize, count: usize, rng: &mut Rng) -> Vec<(usize, f32)> {
    (0..count).map(|_| (rng.range(0, n - 1), rng.f32())).collect()
}

/// One operation of a mutable-array workload.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Op {
    Query(Query),
    Update { i: u32, v: f32 },
    /// `xs[i] += v` for every i in `[l, r]` (inclusive), applied in f32
    /// exactly as a naive elementwise loop would — the oracle contract.
    RangeAdd { l: u32, r: u32, v: f32 },
    /// `xs[i] = v` for every i in `[l, r]` (inclusive).
    RangeAssign { l: u32, r: u32, v: f32 },
}

impl Op {
    pub fn is_query(&self) -> bool {
        matches!(self, Op::Query(_))
    }

    /// Any mutating op — point writes and both range shapes.
    pub fn is_update(&self) -> bool {
        !self.is_query()
    }
}

/// A mutating op in executor form: indices widened to `usize`, queries
/// stripped. This is the payload of an update segment — the batcher
/// fences runs of these between query segments, and
/// `ShardedRmq::apply_update_ops` consumes them in stream order
/// (f32 adds do not reassociate, so order is part of the contract).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum UpdateOp {
    Point { i: usize, v: f32 },
    RangeAdd { l: usize, r: usize, v: f32 },
    RangeAssign { l: usize, r: usize, v: f32 },
}

impl UpdateOp {
    /// Apply this op to a plain values array — the naive oracle the
    /// differential suites compare every backend against.
    pub fn apply_naive(&self, xs: &mut [f32]) {
        match *self {
            UpdateOp::Point { i, v } => xs[i] = v,
            UpdateOp::RangeAdd { l, r, v } => {
                for x in &mut xs[l..=r] {
                    *x += v;
                }
            }
            UpdateOp::RangeAssign { l, r, v } => {
                for x in &mut xs[l..=r] {
                    *x = v;
                }
            }
        }
    }
}

/// Validate a mixed op stream against the array length — the
/// coordinator's admission check for the mutable serving path (the
/// query-only counterpart is [`crate::rmq::validate_queries`]).
pub fn validate_ops(n: usize, ops: &[Op]) -> Result<(), String> {
    for (k, op) in ops.iter().enumerate() {
        match *op {
            Op::Query((l, r)) => {
                if l > r || (r as usize) >= n {
                    return Err(format!("op {k}: query ({l},{r}) invalid for n={n}"));
                }
            }
            Op::Update { i, v } => {
                if (i as usize) >= n {
                    return Err(format!("op {k}: update index {i} out of range for n={n}"));
                }
                // NaN/inf would silently corrupt every later `<`
                // comparison (min tables, tie-breaks) — reject at
                // admission like an out-of-range index.
                if !v.is_finite() {
                    return Err(format!("op {k}: update value {v} is not finite"));
                }
            }
            Op::RangeAdd { l, r, v } | Op::RangeAssign { l, r, v } => {
                if l > r || (r as usize) >= n {
                    return Err(format!("op {k}: range update ({l},{r}) invalid for n={n}"));
                }
                if !v.is_finite() {
                    return Err(format!("op {k}: range update value {v} is not finite"));
                }
            }
        }
    }
    Ok(())
}

/// Mixed query/update stream: each op is an update with probability
/// `update_frac`, otherwise a query drawn from `dist`. This is the
/// serving shape of the ROADMAP's mutable-array scenarios (paper §7.iii:
/// "input arrays that change their values over time").
pub fn gen_mixed(
    n: usize,
    count: usize,
    update_frac: f64,
    dist: RangeDist,
    rng: &mut Rng,
) -> Vec<Op> {
    gen_mixed_ranged(n, count, update_frac, 0.0, dist, rng)
}

/// [`gen_mixed`] with a range-update share: each op is a range update
/// with probability `range_frac` (alternating `add`/`assign`, endpoints
/// drawn from `dist` like a query's), a point update with probability
/// `update_frac`, otherwise a query. `add` deltas are centered on zero
/// so long streams don't drift the array out of [0, 1).
pub fn gen_mixed_ranged(
    n: usize,
    count: usize,
    update_frac: f64,
    range_frac: f64,
    dist: RangeDist,
    rng: &mut Rng,
) -> Vec<Op> {
    let mut add_next = true;
    (0..count)
        .map(|_| {
            let x = rng.f64();
            if x < range_frac {
                let len = dist.sample_len(n, rng);
                let (l, r) = place_query(n, len, rng);
                add_next = !add_next;
                if add_next {
                    Op::RangeAssign { l, r, v: rng.f32() }
                } else {
                    Op::RangeAdd { l, r, v: rng.f32() - 0.5 }
                }
            } else if x < range_frac + update_frac {
                Op::Update { i: rng.range(0, n - 1) as u32, v: rng.f32() }
            } else {
                let len = dist.sample_len(n, rng);
                Op::Query(place_query(n, len, rng))
            }
        })
        .collect()
}

/// Per-tenant load shape for the multi-tenant serving front-end
/// (`coordinator::tenants`): a named array with its own size, range
/// distribution, and update mix, optionally shifting to a second
/// distribution mid-run (the drift that trips the reshard lifecycle).
#[derive(Clone, Debug)]
pub struct TenantLoad {
    pub name: String,
    pub n: usize,
    pub dist: RangeDist,
    pub update_frac: f64,
    /// Share of ops that are range updates (`add`/`assign` over [l,r]).
    pub range_frac: f64,
    /// When set, requests generated past 50% progress draw from this
    /// distribution instead of `dist` — a mid-soak traffic shift.
    pub shift: Option<RangeDist>,
}

impl TenantLoad {
    /// The distribution in effect at `progress` ∈ [0, 1].
    pub fn dist_at(&self, progress: f64) -> RangeDist {
        match self.shift {
            Some(d) if progress >= 0.5 => d,
            _ => self.dist,
        }
    }

    /// One request's op stream at the given run progress. Each tenant
    /// owns its own `Rng` stream, so interleaving tenants never
    /// perturbs any single tenant's sequence — the property the
    /// isolation differential tests lean on.
    pub fn gen_request(&self, ops: usize, progress: f64, rng: &mut Rng) -> Vec<Op> {
        gen_mixed_ranged(
            self.n,
            ops,
            self.update_frac,
            self.range_frac,
            self.dist_at(progress),
            rng,
        )
    }
}

/// Mean range length of a batch (the router's classification feature).
pub fn mean_range_len(queries: &[Query]) -> f64 {
    if queries.is_empty() {
        return 0.0;
    }
    queries.iter().map(|&(l, r)| (r - l + 1) as f64).sum::<f64>() / queries.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queries_are_valid() {
        let mut rng = Rng::new(1);
        for dist in RangeDist::all() {
            for n in [1usize, 2, 100, 1 << 16] {
                let qs = gen_queries(n, 200, dist, &mut rng);
                assert!(crate::rmq::validate_queries(n, &qs).is_ok(), "{dist:?} n={n}");
            }
        }
    }

    #[test]
    fn distribution_means_are_ordered() {
        let mut rng = Rng::new(2);
        let n = 1 << 20;
        let mean = |d: RangeDist, rng: &mut Rng| {
            let qs = gen_queries(n, 4000, d, rng);
            mean_range_len(&qs)
        };
        let large = mean(RangeDist::Large, &mut rng);
        let medium = mean(RangeDist::Medium, &mut rng);
        let small = mean(RangeDist::Small, &mut rng);
        assert!(large > medium && medium > small, "{large} {medium} {small}");
        // Paper reference points: at n = 2^26 medium ~ 2^15, small ~ 2^8.
        // At n = 2^20: medium ~ n^0.6 = 2^12, small ~ n^0.3 = 2^6.
        assert!((10.0..15.0).contains(&medium.log2()), "medium 2^{}", medium.log2());
        assert!((4.5..8.0).contains(&small.log2()), "small 2^{}", small.log2());
        assert!(large > n as f64 / 3.0);
    }

    #[test]
    fn paper_reference_medium_at_2_26() {
        // §6.4: "for n = 2^26 the mean sits at ~2^15".
        let m = RangeDist::Medium.mean_len(1 << 26);
        assert!((14.0..16.5).contains(&m.log2()), "2^{}", m.log2());
        let s = RangeDist::Small.mean_len(1 << 26);
        assert!((7.0..9.0).contains(&s.log2()), "2^{}", s.log2());
    }

    #[test]
    fn place_query_pins_boundaries() {
        let mut rng = Rng::new(5);
        // len == n: the only valid placement is the full range.
        for n in [1usize, 2, 7, 100] {
            for _ in 0..20 {
                assert_eq!(place_query(n, n, &mut rng), (0, n as u32 - 1));
            }
        }
        // Oversized lengths clamp to the full range, zero clamps to 1.
        assert_eq!(place_query(10, usize::MAX, &mut rng), (0, 9));
        let (l, r) = place_query(10, 0, &mut rng);
        assert_eq!(l, r);
        // n == 1 always yields (0, 0) whatever the requested length.
        for len in [0usize, 1, 2, 1000] {
            assert_eq!(place_query(1, len, &mut rng), (0, 0));
        }
        // len == 1 covers every position, including both endpoints.
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            let (l, r) = place_query(8, 1, &mut rng);
            assert_eq!(l, r);
            lo_seen |= l == 0;
            hi_seen |= l == 7;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn degenerate_n_queries_are_valid() {
        // Regression for the old `rng.range(0, n - len.min(n))` boundary
        // expression: n = 1 and full-length draws must stay in range for
        // every distribution (Large samples len = n with probability
        // 1/n, so small n hits it fast).
        let mut rng = Rng::new(6);
        for dist in RangeDist::all() {
            for n in [1usize, 2, 3] {
                let qs = gen_queries(n, 500, dist, &mut rng);
                assert!(crate::rmq::validate_queries(n, &qs).is_ok(), "{dist:?} n={n}");
                if n == 1 {
                    assert!(qs.iter().all(|&q| q == (0, 0)));
                }
            }
        }
    }

    #[test]
    fn updates_are_in_range_and_uniformish() {
        let mut rng = Rng::new(10);
        let ups = gen_updates(64, 2000, &mut rng);
        assert_eq!(ups.len(), 2000);
        let mut seen = [false; 64];
        for &(i, v) in &ups {
            assert!(i < 64);
            assert!((0.0..1.0).contains(&v));
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s), "all indices hit");
    }

    #[test]
    fn mixed_stream_respects_fraction_and_validity() {
        let mut rng = Rng::new(11);
        let n = 1000;
        let ops = gen_mixed(n, 4000, 0.25, RangeDist::Small, &mut rng);
        let updates = ops.iter().filter(|o| matches!(o, Op::Update { .. })).count();
        let frac = updates as f64 / ops.len() as f64;
        assert!((0.2..0.3).contains(&frac), "update fraction {frac}");
        for op in &ops {
            match *op {
                Op::Query((l, r)) => assert!(l <= r && (r as usize) < n),
                Op::Update { i, v } => {
                    assert!((i as usize) < n && (0.0..1.0).contains(&v))
                }
                Op::RangeAdd { .. } | Op::RangeAssign { .. } => {
                    panic!("gen_mixed must not emit range ops")
                }
            }
        }
        // Pure-query and pure-update endpoints.
        assert!(gen_mixed(n, 50, 0.0, RangeDist::Large, &mut rng)
            .iter()
            .all(|o| matches!(o, Op::Query(_))));
        assert!(gen_mixed(n, 50, 1.0, RangeDist::Large, &mut rng)
            .iter()
            .all(|o| matches!(o, Op::Update { .. })));
    }

    #[test]
    fn ranged_stream_respects_fractions_and_validity() {
        let mut rng = Rng::new(31);
        let n = 1000;
        let ops = gen_mixed_ranged(n, 4000, 0.2, 0.1, RangeDist::Small, &mut rng);
        assert!(validate_ops(n, &ops).is_ok());
        let ranges =
            ops.iter().filter(|o| matches!(o, Op::RangeAdd { .. } | Op::RangeAssign { .. }));
        let frac = ranges.count() as f64 / ops.len() as f64;
        assert!((0.07..0.13).contains(&frac), "range fraction {frac}");
        // Both range shapes appear (the generator alternates them).
        assert!(ops.iter().any(|o| matches!(o, Op::RangeAdd { .. })));
        assert!(ops.iter().any(|o| matches!(o, Op::RangeAssign { .. })));
        // Point updates still show up at their own fraction.
        let upd = ops.iter().filter(|o| matches!(o, Op::Update { .. })).count() as f64
            / ops.len() as f64;
        assert!((0.16..0.24).contains(&upd), "point-update fraction {upd}");
        // range_frac = 0 reduces to the old generator exactly.
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        assert_eq!(
            gen_mixed(n, 200, 0.3, RangeDist::Medium, &mut a),
            gen_mixed_ranged(n, 200, 0.3, 0.0, RangeDist::Medium, &mut b),
        );
        // is_update covers every mutating shape.
        assert!(Op::RangeAdd { l: 0, r: 3, v: 0.5 }.is_update());
        assert!(Op::RangeAssign { l: 0, r: 3, v: 0.5 }.is_update());
        assert!(!Op::RangeAdd { l: 0, r: 3, v: 0.5 }.is_query());
    }

    #[test]
    fn validate_ops_checks_range_updates() {
        assert!(validate_ops(8, &[Op::RangeAdd { l: 0, r: 7, v: 0.25 }]).is_ok());
        assert!(validate_ops(8, &[Op::RangeAssign { l: 3, r: 3, v: -1.0 }]).is_ok());
        assert!(validate_ops(8, &[Op::RangeAdd { l: 5, r: 4, v: 0.1 }]).is_err());
        assert!(validate_ops(8, &[Op::RangeAssign { l: 0, r: 8, v: 0.1 }]).is_err());
        assert!(validate_ops(8, &[Op::RangeAdd { l: 0, r: 1, v: f32::NAN }]).is_err());
        assert!(validate_ops(8, &[Op::RangeAssign { l: 0, r: 1, v: f32::INFINITY }]).is_err());
    }

    #[test]
    fn update_op_naive_application_matches_loops() {
        let mut xs = vec![0.5f32, 0.25, 0.75, 0.125, 0.625];
        UpdateOp::Point { i: 2, v: 0.1 }.apply_naive(&mut xs);
        assert_eq!(xs[2], 0.1);
        xs[2] = 0.0625;
        UpdateOp::RangeAdd { l: 1, r: 3, v: 0.25 }.apply_naive(&mut xs);
        assert_eq!(xs, vec![0.5, 0.5, 0.3125, 0.375, 0.625]);
        UpdateOp::RangeAssign { l: 0, r: 4, v: -1.0 }.apply_naive(&mut xs);
        assert!(xs.iter().all(|&x| x == -1.0));
        // Single-element range: touches exactly one slot.
        UpdateOp::RangeAdd { l: 2, r: 2, v: 0.5 }.apply_naive(&mut xs);
        assert_eq!(xs, vec![-1.0, -1.0, -0.5, -1.0, -1.0]);
    }

    #[test]
    fn validate_ops_accepts_and_rejects() {
        assert!(validate_ops(8, &[Op::Query((0, 7)), Op::Update { i: 7, v: 0.5 }]).is_ok());
        assert!(validate_ops(8, &[Op::Query((5, 4))]).is_err());
        assert!(validate_ops(8, &[Op::Query((0, 8))]).is_err());
        assert!(validate_ops(8, &[Op::Update { i: 8, v: 0.5 }]).is_err());
        assert!(validate_ops(8, &[Op::Update { i: 0, v: f32::NAN }]).is_err());
        assert!(validate_ops(8, &[Op::Update { i: 0, v: f32::INFINITY }]).is_err());
        assert!(validate_ops(8, &[]).is_ok());
        assert!(Op::Query((0, 1)).is_query() && !Op::Query((0, 1)).is_update());
        assert!(Op::Update { i: 0, v: 0.0 }.is_update());
    }

    #[test]
    fn parse_names() {
        assert_eq!(RangeDist::parse("small"), Some(RangeDist::Small));
        assert_eq!(RangeDist::parse("M"), Some(RangeDist::Medium));
        assert_eq!(RangeDist::parse("huge"), None);
    }

    #[test]
    fn array_is_deterministic_unit_interval() {
        let a = gen_array(1000, 7);
        let b = gen_array(1000, 7);
        assert_eq!(a, b);
        assert!(a.iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn tenant_load_shifts_distribution_at_half_progress() {
        let t = TenantLoad {
            name: "shifty".into(),
            n: 1 << 16,
            dist: RangeDist::Small,
            update_frac: 0.0,
            range_frac: 0.0,
            shift: Some(RangeDist::Large),
        };
        assert_eq!(t.dist_at(0.0), RangeDist::Small);
        assert_eq!(t.dist_at(0.49), RangeDist::Small);
        assert_eq!(t.dist_at(0.5), RangeDist::Large);
        assert_eq!(t.dist_at(1.0), RangeDist::Large);
        // No shift configured: the base distribution holds throughout.
        let steady = TenantLoad { shift: None, ..t.clone() };
        assert_eq!(steady.dist_at(0.9), RangeDist::Small);
        // The generated streams actually move: mean range length after
        // the shift lands near the Large mean, far above Small's.
        let mut rng = Rng::new(23);
        let early: Vec<Query> = t
            .gen_request(512, 0.0, &mut rng)
            .iter()
            .filter_map(|o| if let Op::Query(q) = o { Some(*q) } else { None })
            .collect();
        let late: Vec<Query> = t
            .gen_request(512, 0.75, &mut rng)
            .iter()
            .filter_map(|o| if let Op::Query(q) = o { Some(*q) } else { None })
            .collect();
        assert!(mean_range_len(&late) > 16.0 * mean_range_len(&early));
    }

    #[test]
    fn tenant_streams_are_independent_per_rng() {
        let t = TenantLoad {
            name: "t0".into(),
            n: 4096,
            dist: RangeDist::Medium,
            update_frac: 0.2,
            range_frac: 0.1,
            shift: None,
        };
        // Same seed, same progress → same stream, regardless of what
        // any other tenant's rng did in between.
        let a = t.gen_request(64, 0.0, &mut Rng::new(5));
        let mut other = Rng::new(99);
        let _ = t.gen_request(64, 0.0, &mut other);
        let b = t.gen_request(64, 0.0, &mut Rng::new(5));
        assert_eq!(a, b);
        assert!(validate_ops(4096, &a).is_ok());
    }
}

//! Workload observer: decayed statistics of the *served* traffic.
//!
//! The serving thread feeds every executed segment into one observer —
//! query segments contribute their batch size and per-query range
//! lengths, update segments their point count. All statistics decay
//! exponentially per observation (EWMA with a configurable half-life in
//! segments), so the snapshot tracks what the traffic looks like *now*:
//! a quiet period drives the decayed update fraction toward zero, which
//! is exactly the signal the engine lifecycle waits for before
//! rebuilding static engines (`coordinator::engine`), and a shift in
//! the range-length histogram is what re-triggers the shard-block tuner
//! (`RtCostModel::tune_shard_block_observed`) — observed traffic
//! replacing the CLI's `--dist`/`--update-frac` priors.

use crate::rmq::Query;

/// Log₂ buckets of the decayed range-length histogram (lengths are
/// `u32`-indexed, so 33 buckets cover every possible range).
pub const RANGE_BUCKETS: usize = 33;

/// One decayed snapshot of the observed workload.
#[derive(Clone, Copy, Debug)]
pub struct ObservedWorkload {
    /// Decayed mean query range length (0 until a query is seen).
    pub mean_range: f64,
    /// Decayed mean query-segment size (0 until a query is seen).
    pub mean_batch: f64,
    /// Decayed mean update-segment size in points (0 until an update is
    /// seen). Feeds the cost model's update term: batches near 1 point
    /// take the single-update path-refit route, larger ones amortise
    /// full block refits (`RtCostModel::shard_update_work`).
    pub mean_update_batch: f64,
    /// Decayed fraction of ops that are point updates.
    pub update_frac: f64,
    /// Decayed range-length mass per log₂ bucket: `range_hist[k]` holds
    /// queries with length in `[2^k, 2^{k+1})`.
    pub range_hist: [f64; RANGE_BUCKETS],
    /// Total (undecayed) ops ever observed — 0 means "no traffic yet",
    /// and consumers skip tuning decisions entirely.
    pub ops: u64,
}

impl Default for ObservedWorkload {
    fn default() -> Self {
        ObservedWorkload {
            mean_range: 0.0,
            mean_batch: 0.0,
            mean_update_batch: 0.0,
            update_frac: 0.0,
            range_hist: [0.0; RANGE_BUCKETS],
            ops: 0,
        }
    }
}

/// Maintains the decayed counters. One per coordinator, fed from the
/// serving thread (cheap: O(batch) adds per segment, no allocation).
pub struct WorkloadObserver {
    /// Per-observation decay factor, `0.5^(1/half_life)`.
    alpha: f64,
    /// Decayed op counters: query ops, update ops, summed range length.
    dq: f64,
    du: f64,
    dlen: f64,
    /// Decayed query-segment size mass and segment count.
    dbatch: f64,
    dsegs: f64,
    /// Decayed update-segment count (`du` is the decayed point mass).
    dusegs: f64,
    hist: [f64; RANGE_BUCKETS],
    ops: u64,
}

impl WorkloadObserver {
    /// `half_life`: observations (segments) after which old traffic
    /// carries half its weight.
    pub fn new(half_life: f64) -> WorkloadObserver {
        WorkloadObserver {
            alpha: 0.5f64.powf(1.0 / half_life.max(1.0)),
            dq: 0.0,
            du: 0.0,
            dlen: 0.0,
            dbatch: 0.0,
            dsegs: 0.0,
            dusegs: 0.0,
            hist: [0.0; RANGE_BUCKETS],
            ops: 0,
        }
    }

    fn decay(&mut self) {
        self.dq *= self.alpha;
        self.du *= self.alpha;
        self.dlen *= self.alpha;
        self.dbatch *= self.alpha;
        self.dsegs *= self.alpha;
        self.dusegs *= self.alpha;
        for h in self.hist.iter_mut() {
            *h *= self.alpha;
        }
    }

    /// Feed one executed query segment.
    pub fn observe_queries(&mut self, queries: &[Query]) {
        if queries.is_empty() {
            return;
        }
        self.decay();
        for &(l, r) in queries {
            let len = (r - l + 1) as u64;
            self.dlen += len as f64;
            self.hist[(len.ilog2() as usize).min(RANGE_BUCKETS - 1)] += 1.0;
        }
        self.dq += queries.len() as f64;
        self.dbatch += queries.len() as f64;
        self.dsegs += 1.0;
        self.ops += queries.len() as u64;
    }

    /// Feed one executed update segment.
    pub fn observe_updates(&mut self, count: usize) {
        if count == 0 {
            return;
        }
        self.decay();
        self.du += count as f64;
        self.dusegs += 1.0;
        self.ops += count as u64;
    }

    pub fn snapshot(&self) -> ObservedWorkload {
        let mass = self.dq + self.du;
        ObservedWorkload {
            mean_range: if self.dq > 0.0 { self.dlen / self.dq } else { 0.0 },
            mean_batch: if self.dsegs > 0.0 { self.dbatch / self.dsegs } else { 0.0 },
            mean_update_batch: if self.dusegs > 0.0 { self.du / self.dusegs } else { 0.0 },
            update_frac: if mass > 0.0 { self.du / mass } else { 0.0 },
            range_hist: self.hist,
            ops: self.ops,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_observer_snapshots_zero() {
        let o = WorkloadObserver::new(8.0);
        let s = o.snapshot();
        assert_eq!(s.ops, 0);
        assert_eq!(s.mean_range, 0.0);
        assert_eq!(s.update_frac, 0.0);
        assert!(s.range_hist.iter().all(|&h| h == 0.0));
    }

    #[test]
    fn means_and_fraction_track_traffic() {
        let mut o = WorkloadObserver::new(8.0);
        o.observe_queries(&[(0, 15), (10, 25)]); // lengths 16, 16
        o.observe_updates(2);
        let s = o.snapshot();
        assert_eq!(s.ops, 4);
        assert!((s.mean_range - 16.0).abs() < 1e-9);
        assert!((s.mean_batch - 2.0).abs() < 1e-9);
        // 2 updates vs 2 (slightly decayed) queries: frac a bit over 0.5.
        assert!((0.45..0.6).contains(&s.update_frac), "{}", s.update_frac);
        // Length-16 queries land in bucket 4.
        assert!(s.range_hist[4] > 0.0);
        assert_eq!(s.range_hist[5], 0.0);
    }

    #[test]
    fn mean_update_batch_tracks_segment_sizes() {
        let mut o = WorkloadObserver::new(8.0);
        assert_eq!(o.snapshot().mean_update_batch, 0.0, "no updates yet");
        o.observe_updates(1);
        assert!((o.snapshot().mean_update_batch - 1.0).abs() < 1e-9);
        // Two segments of 1 and 7 points: decayed mean lands between.
        o.observe_updates(7);
        let m = o.snapshot().mean_update_batch;
        assert!((1.0..=7.0).contains(&m), "{m}");
        // A run of large segments pulls the decayed mean up toward 32.
        for _ in 0..40 {
            o.observe_updates(32);
        }
        assert!(o.snapshot().mean_update_batch > 28.0);
    }

    #[test]
    fn quiet_period_decays_update_fraction_to_zero() {
        let mut o = WorkloadObserver::new(4.0);
        for _ in 0..10 {
            o.observe_queries(&[(0, 7); 8]);
            o.observe_updates(8);
        }
        let busy = o.snapshot().update_frac;
        assert!(busy > 0.3, "busy frac {busy}");
        for _ in 0..40 {
            o.observe_queries(&[(0, 7); 8]);
        }
        let quiet = o.snapshot().update_frac;
        assert!(quiet < 0.01, "quiet frac {quiet}");
        // Half-life math: 40 quiet segments at half-life 4 is 10 halvings.
        assert!(quiet < busy / 500.0, "busy {busy} quiet {quiet}");
    }

    #[test]
    fn histogram_mass_follows_distribution_shift() {
        let mut o = WorkloadObserver::new(4.0);
        for _ in 0..20 {
            o.observe_queries(&[(0, 15); 16]); // length 16: bucket 4
        }
        let small = o.snapshot();
        let small_peak = small.range_hist[4];
        assert!(small_peak > 0.0);
        for _ in 0..40 {
            o.observe_queries(&[(0, 4095); 16]); // length 4096: bucket 12
        }
        let shifted = o.snapshot();
        assert!(shifted.range_hist[12] > shifted.range_hist[4] * 100.0);
        assert!(shifted.mean_range > 4000.0, "{}", shifted.mean_range);
    }

    #[test]
    fn degenerate_lengths_bucket_safely() {
        let mut o = WorkloadObserver::new(8.0);
        o.observe_queries(&[(5, 5), (0, u32::MAX - 1)]);
        let s = o.snapshot();
        assert!(s.range_hist[0] > 0.0); // length 1 -> bucket 0
        assert!(s.range_hist[31] > 0.0); // length 2^32 - 1 -> bucket 31
        assert_eq!(s.ops, 2);
    }
}

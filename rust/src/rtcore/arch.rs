//! GPU architecture profiles for the cost/energy models and the scaling
//! experiments (Figs. 14–15). Every number here is public: the paper's
//! Table 1 (RTX 6000 Ada), the NVIDIA Turing/Ada whitepapers it cites for
//! the per-generation RT throughput factors (§3: Turing ≈ 10× over
//! software, Ada ≈ 4× over Turing ⇒ ~40× total; Ampere sits at ~2× over
//! Turing per NVIDIA's Ampere material), and published SM counts/TDPs for
//! the Lovelace SKUs of Fig. 15.

/// Static description of one GPU (or CPU) used by the models.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ArchProfile {
    pub name: &'static str,
    /// Streaming multiprocessors (= RT cores; one per SM on RTX parts).
    pub sm_count: u32,
    /// Boost clock in GHz.
    pub clock_ghz: f64,
    /// Relative per-RT-core ray-tracing throughput, Turing = 1.0
    /// (generation factor from the whitepapers).
    pub rt_gen_factor: f64,
    /// Thermal design power in watts.
    pub tdp_w: f64,
    /// Idle/base power draw in watts (models' floor).
    pub idle_w: f64,
    /// Memory bandwidth GB/s.
    pub mem_bw_gbs: f64,
    /// L2 cache in MiB (drives the LCA staircase of Fig. 12).
    pub l2_mib: f64,
    /// CUDA cores (for the non-RT approaches' compute model).
    pub cuda_cores: u32,
}

/// TITAN RTX — Turing, 2018 (Fig. 14).
pub const TURING_TITAN_RTX: ArchProfile = ArchProfile {
    name: "TITAN RTX (Turing)",
    sm_count: 72,
    clock_ghz: 1.77,
    rt_gen_factor: 1.0,
    tdp_w: 280.0,
    idle_w: 15.0,
    mem_bw_gbs: 672.0,
    l2_mib: 6.0,
    cuda_cores: 4608,
};

/// RTX 3090 Ti — Ampere, 2022 (Fig. 14).
pub const AMPERE_3090TI: ArchProfile = ArchProfile {
    name: "RTX 3090 Ti (Ampere)",
    sm_count: 84,
    clock_ghz: 1.86,
    rt_gen_factor: 2.0,
    tdp_w: 450.0,
    idle_w: 20.0,
    mem_bw_gbs: 1008.0,
    l2_mib: 6.0,
    cuda_cores: 10752,
};

/// RTX 6000 Ada — Lovelace, 2022 (paper Table 1; the main test GPU).
pub const LOVELACE_RTX6000ADA: ArchProfile = ArchProfile {
    name: "RTX 6000 Ada (Lovelace)",
    sm_count: 142,
    clock_ghz: 2.5,
    rt_gen_factor: 4.0,
    tdp_w: 300.0,
    idle_w: 20.0,
    mem_bw_gbs: 960.0,
    l2_mib: 96.0,
    cuda_cores: 18176,
};

/// RTX 4070 Ti / 4080 / 4090 — the Fig. 15 SM-scaling set.
pub const ADA_4070TI: ArchProfile = ArchProfile {
    name: "RTX 4070 Ti",
    sm_count: 60,
    clock_ghz: 2.61,
    rt_gen_factor: 4.0,
    tdp_w: 285.0,
    idle_w: 12.0,
    mem_bw_gbs: 504.0,
    l2_mib: 48.0,
    cuda_cores: 7680,
};

pub const ADA_4080: ArchProfile = ArchProfile {
    name: "RTX 4080",
    sm_count: 76,
    clock_ghz: 2.51,
    rt_gen_factor: 4.0,
    tdp_w: 320.0,
    idle_w: 13.0,
    mem_bw_gbs: 717.0,
    l2_mib: 64.0,
    cuda_cores: 9728,
};

pub const ADA_4090: ArchProfile = ArchProfile {
    name: "RTX 4090",
    sm_count: 128,
    clock_ghz: 2.52,
    rt_gen_factor: 4.0,
    tdp_w: 450.0,
    idle_w: 15.0,
    mem_bw_gbs: 1008.0,
    l2_mib: 72.0,
    cuda_cores: 16384,
};

/// Hypothetical next generation, continuing the observed trend (Fig. 14's
/// "projected" series): Ada-level SMs grown ~20%, RT factor doubled again.
pub const NEXT_GEN_PROJECTED: ArchProfile = ArchProfile {
    name: "Next-gen (projected)",
    sm_count: 170,
    clock_ghz: 2.7,
    rt_gen_factor: 8.0,
    tdp_w: 350.0,
    idle_w: 20.0,
    mem_bw_gbs: 1400.0,
    l2_mib: 128.0,
    cuda_cores: 21760,
};

/// The paper's CPU host: 2× AMD EPYC 9654 (192 cores, §6.2 Table 1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CpuProfile {
    pub name: &'static str,
    pub cores: u32,
    pub clock_ghz: f64,
    pub tdp_w: f64,
    pub idle_w: f64,
}

pub const EPYC_9654_X2: CpuProfile = CpuProfile {
    name: "2x AMD EPYC 9654 (192 cores)",
    cores: 192,
    clock_ghz: 2.4,
    tdp_w: 720.0,
    idle_w: 120.0,
};

/// Architectures of the Fig. 14 generational sweep, oldest first.
pub fn generations() -> [ArchProfile; 4] {
    [TURING_TITAN_RTX, AMPERE_3090TI, LOVELACE_RTX6000ADA, NEXT_GEN_PROJECTED]
}

/// SKUs of the Fig. 15 SM sweep (all Lovelace), ascending SM count.
pub fn lovelace_skus() -> [ArchProfile; 4] {
    [ADA_4070TI, ADA_4080, ADA_4090, LOVELACE_RTX6000ADA]
}

/// Effective RT throughput proxy: RT cores × clock × generation factor.
/// Used by the cost model as the denominator for traversal work.
pub fn rt_throughput(p: &ArchProfile) -> f64 {
    p.sm_count as f64 * p.clock_ghz * p.rt_gen_factor
}

/// Effective CUDA compute proxy (for LCA / exhaustive models).
pub fn cuda_throughput(p: &ArchProfile) -> f64 {
    p.cuda_cores as f64 * p.clock_ghz
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values() {
        // Match the paper's Table 1 for the main GPU.
        let p = LOVELACE_RTX6000ADA;
        assert_eq!(p.sm_count, 142);
        assert_eq!(p.tdp_w, 300.0);
        assert_eq!(p.mem_bw_gbs, 960.0);
        assert_eq!(p.cuda_cores, 18176);
        assert_eq!(p.l2_mib, 96.0);
    }

    #[test]
    fn rt_throughput_grows_across_generations() {
        let gens = generations();
        for w in gens.windows(2) {
            assert!(
                rt_throughput(&w[0]) < rt_throughput(&w[1]),
                "{} !< {}",
                w[0].name,
                w[1].name
            );
        }
    }

    #[test]
    fn lovelace_skus_ordered_by_sms() {
        let skus = lovelace_skus();
        for w in skus.windows(2) {
            assert!(w[0].sm_count < w[1].sm_count);
        }
        assert_eq!(skus[0].sm_count, 60);
        assert_eq!(skus[3].sm_count, 142);
    }

    #[test]
    fn cpu_profile_matches_paper() {
        assert_eq!(EPYC_9654_X2.cores, 192);
        assert_eq!(EPYC_9654_X2.tdp_w, 720.0);
    }
}

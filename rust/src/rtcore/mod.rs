//! RT-core execution simulator.
//!
//! The paper runs ray batches on real RT cores (Turing/Ampere/Lovelace);
//! this environment has none, so per DESIGN.md §0 we *execute* the exact
//! same geometry/ray workload on the software BVH and *measure the work*
//! (node visits, AABB tests, triangle tests). [`ArchProfile`] carries the
//! public per-architecture parameters (SM count, clock, per-generation RT
//! throughput factors from the Turing/Ada whitepapers the paper cites in
//! §3) that `crate::model` uses to convert measured work into modeled
//! GPU time for Figs. 12–17.

pub mod arch;

use crate::bvh::traverse::{closest_hit, Counters, Hit, TraversalStack};
use crate::bvh::Bvh;
use crate::geometry::{Ray, Triangle};
use crate::util::pool;

pub use arch::ArchProfile;

/// Result of launching a ray batch on the simulator.
pub struct LaunchResult {
    pub hits: Vec<Option<Hit>>,
    pub counters: Counters,
    /// Wall-clock of the software simulation (not GPU time — see
    /// `crate::model` for modeled RT-core time).
    pub sim_wall_ns: u64,
}

/// A scene ready for ray launches: triangles + BVH.
pub struct Scene {
    pub tris: Vec<Triangle>,
    pub bvh: Bvh,
}

impl Scene {
    pub fn new(tris: Vec<Triangle>, builder: crate::bvh::Builder, leaf_size: usize) -> Scene {
        let bvh = crate::bvh::build::build(&tris, builder, leaf_size);
        Scene { tris, bvh }
    }

    /// Acceleration-structure memory (our in-memory form).
    pub fn memory_bytes(&self) -> usize {
        self.bvh.memory_bytes() + self.tris.len() * std::mem::size_of::<Triangle>()
    }
}

/// Launch a grid of rays (the OptiX `optixLaunch` analogue). Rays are
/// distributed over `workers` threads, mirroring the paper's statement
/// that "many rays (queries) can be processed in parallel for the same
/// geometry built once" (§5.2). Counters are summed across workers.
pub fn launch(scene: &Scene, rays: &[Ray], workers: usize) -> LaunchResult {
    let t0 = std::time::Instant::now();
    let nrays = rays.len();
    let mut hits: Vec<Option<Hit>> = vec![None; nrays];
    let worker_counters: Vec<std::sync::Mutex<Counters>> =
        (0..workers.max(1)).map(|_| std::sync::Mutex::new(Counters::default())).collect();
    let counter_idx = std::sync::atomic::AtomicUsize::new(0);
    pool::for_each_chunk_mut(&mut hits, workers, |off, slice| {
        let my = counter_idx.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut ts = TraversalStack::new();
        let mut c = Counters::default();
        for (k, out) in slice.iter_mut().enumerate() {
            *out = closest_hit(&scene.bvh, &scene.tris, &rays[off + k], &mut ts, &mut c);
        }
        worker_counters[my % worker_counters.len()].lock().unwrap().add(&c);
    });
    let mut counters = Counters::default();
    for m in &worker_counters {
        counters.add(&m.lock().unwrap());
    }
    LaunchResult { hits, counters, sim_wall_ns: t0.elapsed().as_nanos() as u64 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bvh::Builder;
    use crate::geometry::flat::{build_scene, ray_for_query, ray_origin_x};
    use crate::rmq::naive_rmq;

    #[test]
    fn launch_matches_sequential() {
        let mut rng = crate::util::rng::Rng::new(31);
        let xs = rng.uniform_f32_vec(512);
        let scene = Scene::new(build_scene(&xs), Builder::BinnedSah, 4);
        let theta = ray_origin_x(&xs);
        let rays: Vec<Ray> = (0..200)
            .map(|_| {
                let l = rng.range(0, 511);
                let r = rng.range(l, 511);
                ray_for_query(l as u32, r as u32, 512, theta)
            })
            .collect();
        let par = launch(&scene, &rays, 4);
        let seq = launch(&scene, &rays, 1);
        assert_eq!(par.hits, seq.hits);
        // Counters are identical regardless of partitioning (pure work).
        assert_eq!(par.counters, seq.counters);
        assert_eq!(par.counters.rays, 200);
    }

    #[test]
    fn launch_answers_are_rmq() {
        let mut rng = crate::util::rng::Rng::new(32);
        let xs = rng.uniform_f32_vec(300);
        let scene = Scene::new(build_scene(&xs), Builder::Lbvh, 4);
        let theta = ray_origin_x(&xs);
        let queries: Vec<(usize, usize)> = (0..64)
            .map(|_| {
                let l = rng.range(0, 299);
                (l, rng.range(l, 299))
            })
            .collect();
        let rays: Vec<Ray> = queries
            .iter()
            .map(|&(l, r)| ray_for_query(l as u32, r as u32, 300, theta))
            .collect();
        let res = launch(&scene, &rays, 2);
        for (q, hit) in queries.iter().zip(&res.hits) {
            let h = hit.expect("hit");
            assert_eq!(h.prim as usize, naive_rmq(&xs, q.0, q.1));
        }
    }

    #[test]
    fn scene_memory_accounts_tris_and_nodes() {
        let xs = crate::util::rng::Rng::new(33).uniform_f32_vec(128);
        let scene = Scene::new(build_scene(&xs), Builder::BinnedSah, 4);
        assert!(scene.memory_bytes() > 128 * std::mem::size_of::<Triangle>());
    }
}

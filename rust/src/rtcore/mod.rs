//! RT-core execution simulator.
//!
//! The paper runs ray batches on real RT cores (Turing/Ampere/Lovelace);
//! this environment has none, so per DESIGN.md §0 we *execute* the exact
//! same geometry/ray workload on the software BVH and *measure the work*
//! (node visits, AABB tests, triangle tests). [`ArchProfile`] carries the
//! public per-architecture parameters (SM count, clock, per-generation RT
//! throughput factors from the Turing/Ada whitepapers the paper cites in
//! §3) that `crate::model` uses to convert measured work into modeled
//! GPU time for Figs. 12–17.
//!
//! A [`Scene`] carries the binary BVH (always built — it is the
//! correctness oracle and the collapse source) and, for
//! [`AccelLayout::Wide`], the 4-wide SoA structure the hot path
//! traverses (see the layout docs on [`crate::bvh`]). [`launch`]
//! distributes rays over a worker pool with **per-worker counters
//! returned from the pool and summed by the caller** — no mutex or
//! atomic traffic inside the ray loop.

pub mod arch;

use crate::bvh::build::collapse_to_wide;
use crate::bvh::traverse::{closest_hit, Counters, Hit, TraversalStack};
use crate::bvh::wide::{closest_hit_wide, WideBvh, WideStack};
use crate::bvh::{AccelLayout, Bvh};
use crate::geometry::{Ray, Triangle};
use crate::util::pool;

pub use arch::ArchProfile;

/// Result of launching a ray batch on the simulator.
pub struct LaunchResult {
    pub hits: Vec<Option<Hit>>,
    pub counters: Counters,
    /// Wall-clock of the software simulation (not GPU time — see
    /// `crate::model` for modeled RT-core time).
    pub sim_wall_ns: u64,
}

/// Topology links for [`Scene::refit_prims`] (one per built layout).
pub struct SceneRefitLinks {
    bin: crate::bvh::RefitLinks,
    wide: Option<crate::bvh::wide::WideRefitLinks>,
}

impl SceneRefitLinks {
    /// Heap bytes of the link tables across every built layout. The
    /// fields are private, so owners (e.g. `RtxRmq`) report link
    /// residency through this method.
    pub fn memory_bytes(&self) -> usize {
        self.bin.memory_bytes() + self.wide.as_ref().map_or(0, |w| w.memory_bytes())
    }
}

/// A scene ready for ray launches: triangles + acceleration structures.
pub struct Scene {
    pub tris: Vec<Triangle>,
    /// Binary layout — always present (oracle + collapse source).
    pub bvh: Bvh,
    /// Wide layout — present iff built with [`AccelLayout::Wide`].
    pub wide: Option<WideBvh>,
}

impl Scene {
    /// Build with the default (wide) layout.
    pub fn new(tris: Vec<Triangle>, builder: crate::bvh::Builder, leaf_size: usize) -> Scene {
        Scene::with_layout(tris, builder, leaf_size, AccelLayout::default())
    }

    /// Build with an explicit acceleration layout.
    pub fn with_layout(
        tris: Vec<Triangle>,
        builder: crate::bvh::Builder,
        leaf_size: usize,
        layout: AccelLayout,
    ) -> Scene {
        let bvh = crate::bvh::build::build(&tris, builder, leaf_size);
        let wide = match layout {
            AccelLayout::Wide => Some(collapse_to_wide(&bvh, &tris)),
            AccelLayout::Binary => None,
        };
        Scene { tris, bvh, wide }
    }

    /// Which layout ray casts traverse.
    pub fn layout(&self) -> AccelLayout {
        if self.wide.is_some() {
            AccelLayout::Wide
        } else {
            AccelLayout::Binary
        }
    }

    /// Refit all built layouts after triangle updates (dynamic RMQ).
    pub fn refit(&mut self) {
        self.bvh.refit(&self.tris);
        if let Some(w) = &mut self.wide {
            w.refit(&self.tris);
        }
    }

    /// Topology links for [`Scene::refit_prims`], covering every built
    /// layout. Build once; topology never changes across refits.
    pub fn refit_links(&self) -> SceneRefitLinks {
        SceneRefitLinks {
            bin: self.bvh.refit_links(),
            wide: self.wide.as_ref().map(|w| w.refit_links()),
        }
    }

    /// Point refit of both layouts: recompute only the leaf-to-root
    /// bound paths of the listed primitives (Θ(k·depth) vs the full
    /// sweep's Θ(n)) — see [`Bvh::refit_prims`]. `prims` must cover
    /// every triangle changed since the last refit.
    pub fn refit_prims(&mut self, prims: &[u32], links: &SceneRefitLinks) {
        self.bvh.refit_prims(&self.tris, prims, &links.bin);
        if let Some(w) = &mut self.wide {
            w.refit_prims(&self.tris, prims, links.wide.as_ref().expect("links from this scene"));
        }
    }

    /// Acceleration-structure memory (our in-memory form, all layouts).
    /// With `AccelLayout::Wide` this deliberately counts the binary tree
    /// too: it is retained as the correctness oracle, the refit/collapse
    /// source, and the Table-2 OptiX-size reference — a device-only
    /// deployment would ship just the wide structure, whose share is
    /// `wide.memory_bytes()`.
    pub fn memory_bytes(&self) -> usize {
        self.bvh.memory_bytes()
            + self.wide.as_ref().map_or(0, |w| w.memory_bytes())
            + self.tris.len() * std::mem::size_of::<Triangle>()
    }
}

/// Launch a grid of rays (the OptiX `optixLaunch` analogue). Rays are
/// distributed over `workers` threads, mirroring the paper's statement
/// that "many rays (queries) can be processed in parallel for the same
/// geometry built once" (§5.2). Each worker accumulates its own
/// [`Counters`] and returns them from the pool; the caller sums — the
/// hot loop takes no locks.
pub fn launch(scene: &Scene, rays: &[Ray], workers: usize) -> LaunchResult {
    let t0 = std::time::Instant::now();
    let mut hits: Vec<Option<Hit>> = vec![None; rays.len()];
    let per_worker: Vec<Counters> = pool::map_chunks_mut(&mut hits, workers, |off, slice| {
        let mut c = Counters::default();
        match &scene.wide {
            Some(wb) => {
                let mut ts = WideStack::new();
                for (k, out) in slice.iter_mut().enumerate() {
                    *out = closest_hit_wide(wb, &rays[off + k], &mut ts, &mut c);
                }
            }
            None => {
                let mut ts = TraversalStack::new();
                for (k, out) in slice.iter_mut().enumerate() {
                    *out = closest_hit(&scene.bvh, &scene.tris, &rays[off + k], &mut ts, &mut c);
                }
            }
        }
        c
    });
    let mut counters = Counters::default();
    for c in &per_worker {
        counters.add(c);
    }
    LaunchResult { hits, counters, sim_wall_ns: t0.elapsed().as_nanos() as u64 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bvh::Builder;
    use crate::geometry::flat::{build_scene, ray_for_query, ray_origin_x};
    use crate::rmq::naive_rmq;

    #[test]
    fn launch_matches_sequential() {
        let mut rng = crate::util::rng::Rng::new(31);
        let xs = rng.uniform_f32_vec(512);
        let scene = Scene::new(build_scene(&xs), Builder::BinnedSah, 4);
        let theta = ray_origin_x(&xs);
        let rays: Vec<Ray> = (0..200)
            .map(|_| {
                let l = rng.range(0, 511);
                let r = rng.range(l, 511);
                ray_for_query(l as u32, r as u32, 512, theta)
            })
            .collect();
        let par = launch(&scene, &rays, 4);
        let seq = launch(&scene, &rays, 1);
        assert_eq!(par.hits, seq.hits);
        // Counters are identical regardless of partitioning (pure work).
        assert_eq!(par.counters, seq.counters);
        assert_eq!(par.counters.rays, 200);
    }

    #[test]
    fn layouts_produce_identical_hits() {
        let mut rng = crate::util::rng::Rng::new(35);
        let xs = rng.uniform_f32_vec(700);
        let theta = ray_origin_x(&xs);
        let rays: Vec<Ray> = (0..300)
            .map(|_| {
                let l = rng.range(0, 699);
                let r = rng.range(l, 699);
                ray_for_query(l as u32, r as u32, 700, theta)
            })
            .collect();
        let wide =
            Scene::with_layout(build_scene(&xs), Builder::BinnedSah, 4, AccelLayout::Wide);
        let binary =
            Scene::with_layout(build_scene(&xs), Builder::BinnedSah, 4, AccelLayout::Binary);
        assert_eq!(wide.layout(), AccelLayout::Wide);
        assert_eq!(binary.layout(), AccelLayout::Binary);
        let hw = launch(&wide, &rays, 3);
        let hb = launch(&binary, &rays, 3);
        assert_eq!(hw.hits, hb.hits);
        // Same rays, different per-layout work accounting.
        assert_eq!(hw.counters.rays, hb.counters.rays);
    }

    #[test]
    fn launch_answers_are_rmq() {
        let mut rng = crate::util::rng::Rng::new(32);
        let xs = rng.uniform_f32_vec(300);
        let scene = Scene::new(build_scene(&xs), Builder::Lbvh, 4);
        let theta = ray_origin_x(&xs);
        let queries: Vec<(usize, usize)> = (0..64)
            .map(|_| {
                let l = rng.range(0, 299);
                (l, rng.range(l, 299))
            })
            .collect();
        let rays: Vec<Ray> = queries
            .iter()
            .map(|&(l, r)| ray_for_query(l as u32, r as u32, 300, theta))
            .collect();
        let res = launch(&scene, &rays, 2);
        for (q, hit) in queries.iter().zip(&res.hits) {
            let h = hit.expect("hit");
            assert_eq!(h.prim as usize, naive_rmq(&xs, q.0, q.1));
        }
    }

    #[test]
    fn scene_memory_accounts_tris_and_nodes() {
        let xs = crate::util::rng::Rng::new(33).uniform_f32_vec(128);
        let scene = Scene::new(build_scene(&xs), Builder::BinnedSah, 4);
        assert!(scene.memory_bytes() > 128 * std::mem::size_of::<Triangle>());
        // The wide structure is included in the accounting.
        let binary =
            Scene::with_layout(build_scene(&xs), Builder::BinnedSah, 4, AccelLayout::Binary);
        assert!(scene.memory_bytes() > binary.memory_bytes());
    }

    #[test]
    fn refit_links_memory_counts_every_table() {
        // The sum must cover every owned allocation: both binary link
        // tables and all three wide link tables, 4 bytes per entry.
        let xs = crate::util::rng::Rng::new(36).uniform_f32_vec(256);
        let scene = Scene::new(build_scene(&xs), Builder::BinnedSah, 4);
        let links = scene.refit_links();
        let bin = scene.bvh.refit_links();
        let wide = scene.wide.as_ref().unwrap().refit_links();
        let expect = (bin.parent.len() + bin.leaf_of_prim.len()) * 4
            + (wide.parent.len() + wide.node_of_slot.len() + wide.slot_of_prim.len()) * 4;
        assert_eq!(links.memory_bytes(), expect);
        assert!(links.memory_bytes() > 0);

        let binary =
            Scene::with_layout(build_scene(&xs), Builder::BinnedSah, 4, AccelLayout::Binary);
        let blinks = binary.refit_links();
        assert_eq!(blinks.memory_bytes(), binary.bvh.refit_links().memory_bytes());
    }

    #[test]
    fn scene_refit_updates_both_layouts() {
        let mut xs = crate::util::rng::Rng::new(34).uniform_f32_vec(256);
        let mut scene = Scene::new(build_scene(&xs), Builder::BinnedSah, 4);
        xs[17] = -0.5; // strictly below every uniform [0,1) value
        scene.tris = build_scene(&xs);
        scene.refit();
        scene.bvh.validate(&scene.tris).unwrap();
        scene.wide.as_ref().unwrap().validate(&scene.tris).unwrap();
        let ray = ray_for_query(0, 255, 256, ray_origin_x(&xs));
        let res = launch(&scene, &[ray], 1);
        assert_eq!(res.hits[0].unwrap().prim, 17);
    }

    #[test]
    fn point_refit_matches_full_refit_on_both_layouts() {
        // A path refit of exactly the changed prims must leave the
        // structures hit-identical to a full bottom-up sweep.
        let mut rng = crate::util::rng::Rng::new(35);
        let mut xs = rng.uniform_f32_vec(400);
        let mut point = Scene::new(build_scene(&xs), Builder::BinnedSah, 4);
        let mut full = Scene::new(build_scene(&xs), Builder::BinnedSah, 4);
        let links = point.refit_links();
        let theta = ray_origin_x(&xs);
        for round in 0..10 {
            let touched: Vec<u32> = (0..3).map(|_| rng.range(0, 399) as u32).collect();
            for &i in &touched {
                // Values stay in [0, 1) so theta remains valid.
                xs[i as usize] = rng.f32();
            }
            let tris = build_scene(&xs);
            for &i in &touched {
                point.tris[i as usize] = tris[i as usize];
                full.tris[i as usize] = tris[i as usize];
            }
            point.refit_prims(&touched, &links);
            full.refit();
            point.bvh.validate(&point.tris).unwrap();
            point.wide.as_ref().unwrap().validate(&point.tris).unwrap();
            let rays: Vec<Ray> = (0..64)
                .map(|_| {
                    let l = rng.range(0, 399);
                    let r = rng.range(l, 399);
                    ray_for_query(l as u32, r as u32, 400, theta)
                })
                .collect();
            let hp = launch(&point, &rays, 2);
            let hf = launch(&full, &rays, 2);
            assert_eq!(hp.hits, hf.hits, "round {round}");
        }
    }
}

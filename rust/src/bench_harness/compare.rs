//! Bench-regression gate: compare a fresh `bench-smoke` JSON against a
//! committed baseline (`rtxrmq bench-compare --baseline …`).
//!
//! Points are matched by (layout, n, batch); for each matched point the
//! gate checks `ns_per_query`, — when both sides measured the write
//! path — `upd_ns_per_op` and `range_ns_per_op`, and — when both
//! sides recorded it —
//! `resident_bytes` (memory regressions gate exactly like time
//! regressions: the instanced backend's ≥4× footprint win must not
//! erode silently). Any relative regression above the tolerance
//! (default 25%, the CI knob) fails. `build_ms` is carried in the JSON
//! but not gated: build wall time is too noisy on shared CI runners. A
//! baseline point missing from the current run is coverage loss and
//! also fails. New points in the current run are reported but never
//! gate.
//!
//! A baseline whose `provenance` field says `modeled-bootstrap` (the
//! committed placeholder seeded before any toolchain host ran the
//! bench) reports its deltas but never fails the gate: the first real
//! trajectory point — the CI artifact of a toolchain run — should be
//! committed over it, at which point the gate arms itself.

use crate::util::json::Json;
use std::fmt::Write as _;

/// Marker value of the baseline's `provenance` field for the committed
/// pre-toolchain placeholder.
pub const BOOTSTRAP_PROVENANCE: &str = "modeled-bootstrap";

/// One gated metric of one matched grid point.
#[derive(Clone, Debug)]
pub struct CompareRow {
    pub layout: String,
    pub n: u64,
    pub batch: u64,
    /// "ns/query", "ns/update", "ns/range-update" or "resident_bytes".
    pub metric: &'static str,
    pub baseline: f64,
    pub current: f64,
    /// `current / baseline − 1` (positive = slower than baseline).
    pub delta: f64,
    /// Above tolerance?
    pub regressed: bool,
}

/// Full gate outcome.
#[derive(Clone, Debug, Default)]
pub struct CompareReport {
    pub rows: Vec<CompareRow>,
    /// Baseline points with no counterpart in the current run.
    pub missing: Vec<String>,
    /// Current-run points with no counterpart in the baseline (informational).
    pub unmatched: Vec<String>,
    /// The baseline is the committed pre-toolchain placeholder.
    pub bootstrap_baseline: bool,
    /// The baseline's literal `provenance` field (`"measured"` when
    /// absent: a committed bench artifact predating the field is a real
    /// measurement, and defaulting the other way would let a mislabeled
    /// baseline silently disarm the gate).
    pub baseline_provenance: String,
    pub tolerance: f64,
}

impl CompareReport {
    pub fn regressions(&self) -> Vec<&CompareRow> {
        self.rows.iter().filter(|r| r.regressed).collect()
    }

    /// Provenance escalation: the gate enforces exactly when the
    /// baseline is *not* the modeled bootstrap placeholder — committing
    /// a measured baseline arms it with no workflow change.
    pub fn gate_enforcing(&self) -> bool {
        !self.bootstrap_baseline
    }

    /// Should the CI step fail? Regressions (or lost coverage) against
    /// a *real* baseline gate; a bootstrap baseline only reports.
    pub fn failed(&self) -> bool {
        self.gate_enforcing() && (!self.regressions().is_empty() || !self.missing.is_empty())
    }
}

type PointRow = (String, u64, u64, f64, f64, f64, f64);

fn points_of(doc: &Json) -> Result<Vec<PointRow>, String> {
    let arr = doc
        .get("points")
        .and_then(|p| p.as_arr())
        .ok_or_else(|| "no 'points' array in bench JSON".to_string())?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, p) in arr.iter().enumerate() {
        let layout = p
            .get("layout")
            .and_then(|l| l.as_str())
            .ok_or_else(|| format!("point {i}: missing layout"))?;
        let n = p
            .get("n")
            .and_then(|v| v.as_u64())
            .ok_or_else(|| format!("point {i}: missing n"))?;
        let batch = p
            .get("batch")
            .and_then(|v| v.as_u64())
            .ok_or_else(|| format!("point {i}: missing batch"))?;
        let ns = p
            .get("ns_per_query")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("point {i}: missing ns_per_query"))?;
        let upd = p.get("upd_ns_per_op").and_then(|v| v.as_f64()).unwrap_or(0.0);
        // Baselines committed before the memory column existed read as
        // 0.0 and fall through the both-sides-measured guard below.
        let resident = p.get("resident_bytes").and_then(|v| v.as_f64()).unwrap_or(0.0);
        // Likewise for the range-tag column: only --range-frac runs
        // measure it, and only on the sharded solver.
        let range = p.get("range_ns_per_op").and_then(|v| v.as_f64()).unwrap_or(0.0);
        out.push((layout.to_string(), n, batch, ns, upd, resident, range));
    }
    Ok(out)
}

/// Compare two bench-smoke JSON documents. `tolerance` is the allowed
/// relative slowdown per metric (0.25 = +25%).
pub fn compare(baseline: &Json, current: &Json, tolerance: f64) -> Result<CompareReport, String> {
    for (doc, name) in [(baseline, "baseline"), (current, "current")] {
        if doc.get("bench").and_then(|b| b.as_str()) != Some("rmq_smoke") {
            return Err(format!("{name}: not a bench-smoke JSON ('bench' != \"rmq_smoke\")"));
        }
    }
    let baseline_provenance = baseline
        .get("provenance")
        .and_then(|p| p.as_str())
        .unwrap_or("measured")
        .to_string();
    let bootstrap_baseline = baseline_provenance == BOOTSTRAP_PROVENANCE;
    let base = points_of(baseline)?;
    let cur = points_of(current)?;
    let mut report =
        CompareReport { bootstrap_baseline, baseline_provenance, tolerance, ..Default::default() };
    for (layout, n, batch, base_ns, base_upd, base_resident, base_range) in &base {
        let Some(&(_, _, _, cur_ns, cur_upd, cur_resident, cur_range)) =
            cur.iter().find(|(l, cn, cb, ..)| l == layout && cn == n && cb == batch)
        else {
            report.missing.push(format!("{layout} n={n} batch={batch}"));
            continue;
        };
        let mut push = |metric: &'static str, b: f64, c: f64| {
            if b <= 0.0 || c <= 0.0 {
                // The write path is only measured with --update-frac,
                // the range-tag path only with --range-frac (and only
                // on the sharded solver), and resident_bytes only
                // exists in post-instancing runs; a side that didn't
                // measure a metric cannot gate it.
                return;
            }
            let delta = c / b - 1.0;
            report.rows.push(CompareRow {
                layout: layout.clone(),
                n: *n,
                batch: *batch,
                metric,
                baseline: b,
                current: c,
                delta,
                regressed: delta > tolerance,
            });
        };
        push("ns/query", *base_ns, cur_ns);
        push("ns/update", *base_upd, cur_upd);
        push("resident_bytes", *base_resident, cur_resident);
        push("ns/range-update", *base_range, cur_range);
    }
    for (layout, n, batch, ..) in &cur {
        if !base.iter().any(|(l, bn, bb, ..)| l == layout && bn == n && bb == batch) {
            report.unmatched.push(format!("{layout} n={n} batch={batch}"));
        }
    }
    if report.rows.is_empty() && report.missing.is_empty() {
        return Err("no comparable points between baseline and current".to_string());
    }
    Ok(report)
}

/// Render the delta table as GitHub-flavoured markdown (the `bench-gate`
/// CI step appends this to `$GITHUB_STEP_SUMMARY`).
pub fn summary_md(report: &CompareReport) -> String {
    let mut s = String::from("## rtxrmq bench-gate\n\n");
    if report.bootstrap_baseline {
        let _ = writeln!(
            s,
            "baseline is the committed `{BOOTSTRAP_PROVENANCE}` placeholder — deltas are \
             informational until a measured BENCH_rmq.json is committed over it\n"
        );
    }
    let _ = writeln!(
        s,
        "tolerance: +{:.0}% | baseline provenance: `{}` ({}) | verdict: **{}**\n",
        report.tolerance * 100.0,
        report.baseline_provenance,
        if report.gate_enforcing() { "enforcing" } else { "report-only" },
        if report.failed() { "FAIL" } else { "PASS" }
    );
    s.push_str("| solver | n | batch | metric | baseline | current | delta | |\n");
    s.push_str("|---|---:|---:|---|---:|---:|---:|---|\n");
    for r in &report.rows {
        let _ = writeln!(
            s,
            "| {} | {} | {} | {} | {:.1} | {:.1} | {:+.1}% | {} |",
            r.layout,
            r.n,
            r.batch,
            r.metric,
            r.baseline,
            r.current,
            r.delta * 100.0,
            if r.regressed { "REGRESSED" } else { "" }
        );
    }
    for m in &report.missing {
        let _ = writeln!(s, "\nmissing from current run: {m} (coverage loss)");
    }
    for u in &report.unmatched {
        let _ = writeln!(s, "\nnew point (not in baseline): {u}");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::obj;

    fn smoke_doc(points: Vec<(&str, u64, u64, f64, f64)>, provenance: Option<&str>) -> Json {
        let rows: Vec<Json> = points
            .into_iter()
            .map(|(layout, n, batch, ns, upd)| {
                obj(vec![
                    ("layout", Json::from(layout)),
                    ("n", Json::from(n)),
                    ("batch", Json::from(batch)),
                    ("ns_per_query", Json::from(ns)),
                    ("upd_ns_per_op", Json::from(upd)),
                ])
            })
            .collect();
        let mut fields = vec![("bench", Json::from("rmq_smoke")), ("points", Json::Arr(rows))];
        if let Some(p) = provenance {
            fields.push(("provenance", Json::from(p)));
        }
        obj(fields)
    }

    #[test]
    fn identical_runs_pass_within_tolerance() {
        let base = smoke_doc(vec![("wide", 65536, 4096, 400.0, 90.0)], None);
        let cur = smoke_doc(vec![("wide", 65536, 4096, 440.0, 99.0)], None);
        let report = compare(&base, &cur, 0.25).unwrap();
        assert_eq!(report.rows.len(), 2, "query + update metrics");
        assert!(report.regressions().is_empty());
        assert!(!report.failed());
        let md = summary_md(&report);
        assert!(md.contains("PASS") && md.contains("+10.0%"), "{md}");
    }

    #[test]
    fn injected_regression_fails_the_gate() {
        let base = smoke_doc(
            vec![("binary", 65536, 4096, 900.0, 0.0), ("wide", 65536, 4096, 400.0, 90.0)],
            None,
        );
        // Wide column 40% slower on queries: one regressed row.
        let cur = smoke_doc(
            vec![("binary", 65536, 4096, 910.0, 0.0), ("wide", 65536, 4096, 560.0, 92.0)],
            None,
        );
        let report = compare(&base, &cur, 0.25).unwrap();
        assert!(report.failed());
        let reg = report.regressions();
        assert_eq!(reg.len(), 1);
        assert_eq!((reg[0].layout.as_str(), reg[0].metric), ("wide", "ns/query"));
        assert!(summary_md(&report).contains("REGRESSED"));
    }

    #[test]
    fn update_regression_gates_only_when_both_sides_measured() {
        let base = smoke_doc(vec![("sharded", 65536, 4096, 300.0, 50.0)], None);
        // ns/update 2x worse -> fail …
        let slow = smoke_doc(vec![("sharded", 65536, 4096, 300.0, 100.0)], None);
        assert!(compare(&base, &slow, 0.25).unwrap().failed());
        // … but a current run without the write path cannot gate it.
        let unmeasured = smoke_doc(vec![("sharded", 65536, 4096, 300.0, 0.0)], None);
        let report = compare(&base, &unmeasured, 0.25).unwrap();
        assert_eq!(report.rows.len(), 1);
        assert!(!report.failed());
    }

    #[test]
    fn range_regression_gates_only_when_both_sides_measured() {
        let with_range = |range: f64| {
            let rows = vec![obj(vec![
                ("layout", Json::from("sharded")),
                ("n", Json::from(65536u64)),
                ("batch", Json::from(4096u64)),
                ("ns_per_query", Json::from(300.0)),
                ("upd_ns_per_op", Json::from(0.0)),
                ("range_ns_per_op", Json::from(range)),
            ])];
            obj(vec![("bench", Json::from("rmq_smoke")), ("points", Json::Arr(rows))])
        };
        let base = with_range(800.0);
        // 2x slower tags: the instanced O(1) cover path eroded.
        let slow = with_range(1600.0);
        let report = compare(&base, &slow, 0.25).unwrap();
        assert!(report.failed());
        let reg = report.regressions();
        assert_eq!(reg.len(), 1);
        assert_eq!(reg[0].metric, "ns/range-update");
        assert!(summary_md(&report).contains("ns/range-update"));
        // Within tolerance passes.
        assert!(!compare(&base, &with_range(900.0), 0.25).unwrap().failed());
        // A baseline without --range-frac (or predating the column)
        // cannot gate it: the both-sides-measured guard.
        let old = smoke_doc(vec![("sharded", 65536, 4096, 300.0, 0.0)], None);
        let report = compare(&old, &slow, 0.25).unwrap();
        assert_eq!(report.rows.len(), 1, "ns/query only: {:?}", report.rows);
        assert!(!report.failed());
        // Nor can a current run that skipped the range path.
        assert!(!compare(&base, &with_range(0.0), 0.25).unwrap().failed());
    }

    #[test]
    fn missing_coverage_fails_new_points_do_not() {
        let base = smoke_doc(
            vec![("binary", 65536, 4096, 900.0, 0.0), ("wide", 65536, 4096, 400.0, 0.0)],
            None,
        );
        let cur = smoke_doc(
            vec![("binary", 65536, 4096, 900.0, 0.0), ("sharded", 65536, 4096, 250.0, 0.0)],
            None,
        );
        let report = compare(&base, &cur, 0.25).unwrap();
        assert!(report.failed(), "baseline wide column vanished");
        assert_eq!(report.missing, vec!["wide n=65536 batch=4096"]);
        assert_eq!(report.unmatched, vec!["sharded n=65536 batch=4096"]);
    }

    #[test]
    fn bootstrap_baseline_reports_but_never_fails() {
        let base =
            smoke_doc(vec![("wide", 65536, 4096, 400.0, 0.0)], Some(BOOTSTRAP_PROVENANCE));
        let cur = smoke_doc(vec![("wide", 65536, 4096, 4000.0, 0.0)], None);
        let report = compare(&base, &cur, 0.25).unwrap();
        assert!(report.bootstrap_baseline);
        assert!(!report.gate_enforcing());
        assert_eq!(report.baseline_provenance, BOOTSTRAP_PROVENANCE);
        assert_eq!(report.regressions().len(), 1, "the delta is still reported");
        assert!(!report.failed(), "placeholder baselines do not gate");
        assert!(summary_md(&report).contains("modeled-bootstrap"));
    }

    #[test]
    fn measured_provenance_arms_the_gate() {
        // Explicitly-measured baseline: same regression now fails.
        let base = smoke_doc(vec![("wide", 65536, 4096, 400.0, 0.0)], Some("measured"));
        let cur = smoke_doc(vec![("wide", 65536, 4096, 4000.0, 0.0)], None);
        let report = compare(&base, &cur, 0.25).unwrap();
        assert!(!report.bootstrap_baseline);
        assert!(report.gate_enforcing());
        assert_eq!(report.baseline_provenance, "measured");
        assert!(report.failed(), "a measured baseline enforces");
        // A baseline predating the provenance field enforces too — the
        // conservative default keeps mislabeling from disarming the
        // gate.
        let legacy = smoke_doc(vec![("wide", 65536, 4096, 400.0, 0.0)], None);
        let report = compare(&legacy, &cur, 0.25).unwrap();
        assert!(report.gate_enforcing());
        assert_eq!(report.baseline_provenance, "measured");
        assert!(report.failed());
    }

    #[test]
    fn memory_regression_fails_the_gate() {
        let with_mem = |resident: f64| {
            let rows = vec![obj(vec![
                ("layout", Json::from("sharded")),
                ("n", Json::from(65536u64)),
                ("batch", Json::from(4096u64)),
                ("ns_per_query", Json::from(300.0)),
                ("upd_ns_per_op", Json::from(0.0)),
                ("build_ms", Json::from(12.0)),
                ("resident_bytes", Json::from(resident)),
            ])];
            obj(vec![("bench", Json::from("rmq_smoke")), ("points", Json::Arr(rows))])
        };
        let base = with_mem(400_000.0);
        // 50% more resident bytes: the instanced footprint win eroded.
        let bloated = with_mem(600_000.0);
        let report = compare(&base, &bloated, 0.25).unwrap();
        assert!(report.failed());
        let reg = report.regressions();
        assert_eq!(reg.len(), 1);
        assert_eq!(reg[0].metric, "resident_bytes");
        assert!(summary_md(&report).contains("resident_bytes"));
        // Within tolerance passes.
        assert!(!compare(&base, &with_mem(440_000.0), 0.25).unwrap().failed());
        // A pre-instancing baseline without the column reports nothing
        // for it and cannot gate it (the both-sides-measured guard).
        let old = smoke_doc(vec![("sharded", 65536, 4096, 300.0, 0.0)], None);
        let report = compare(&old, &bloated, 0.25).unwrap();
        assert_eq!(report.rows.len(), 1, "ns/query only: {:?}", report.rows);
        assert!(!report.failed());
    }

    #[test]
    fn rejects_malformed_documents() {
        let good = smoke_doc(vec![("wide", 1024, 128, 100.0, 0.0)], None);
        let not_smoke = obj(vec![("bench", Json::from("other"))]);
        assert!(compare(&not_smoke, &good, 0.25).is_err());
        assert!(compare(&good, &not_smoke, 0.25).is_err());
        let disjoint = smoke_doc(vec![("wide", 2048, 128, 100.0, 0.0)], None);
        let report = compare(&good, &disjoint, 0.25).unwrap();
        assert!(report.failed(), "fully disjoint grids are coverage loss");
    }
}

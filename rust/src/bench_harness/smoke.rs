//! Smoke-mode perf grid: wall-clock ns/query plus traversal counters for
//! **both acceleration layouts and the sharded engine** over a small
//! n × batch grid, written to `BENCH_rmq.json` so successive PRs have a
//! perf trajectory to compare against (the acceptance point is n = 2^20,
//! batch = 2^16, uniform queries).
//!
//! Unlike the figure benches (which model GPU time), this mode records
//! the *local* wall clock of the software traversal — exactly the
//! quantity the wide-SoA layout and the blocked decomposition are meant
//! to improve — and cross-checks that every solver column returns
//! identical answers on every grid point.
//!
//! With `--update-frac > 0` every grid point also times the write path
//! (`upd_ns_per_op`): a batch of `batch × frac` point updates applied to
//! each solver (triangle re-shape + refit), then rolled back off the
//! clock so the read measurements stay comparable.

use crate::bvh::traverse::Counters;
use crate::bvh::AccelLayout;
use crate::coordinator::engine::ShardBlock;
use crate::geometry::precision::{best_block_size, OptixLimits};
use crate::rmq::rtx::{RtxMode, RtxOptions, RtxRmq};
use crate::rmq::sharded::{ShardedOptions, ShardedRmq};
use crate::rmq::{Query, RmqSolver};
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;
use crate::workload::{gen_array, gen_updates, UpdateOp};
use std::path::Path;

/// Stable column labels for the grid's solver axis.
pub const LABEL_BINARY: &str = "binary";
pub const LABEL_WIDE: &str = "wide";
pub const LABEL_SHARDED: &str = "sharded";

/// Grid configuration.
#[derive(Clone, Debug)]
pub struct SmokeCfg {
    pub ns: Vec<usize>,
    pub batches: Vec<usize>,
    pub workers: usize,
    pub seed: u64,
    /// Sharded column's block-size rule (`--shard-block`).
    pub shard_block: ShardBlock,
    /// Updates per grid point as a fraction of the batch size; 0
    /// disables the write-path column.
    pub update_frac: f64,
    /// Lazy range updates (`add`/`assign`, alternating) per grid point
    /// as a fraction of the batch size; 0 disables the range column.
    /// Measured on the sharded column only — the monolithic BVHs have
    /// no range-update API to compare against.
    pub range_frac: f64,
    /// Ray-packet width for the A/B column pair (`--packet-width`): when
    /// > 0 the grid grows `wide-pN` and `sharded-pN` columns running the
    /// packetized traversal drivers next to their scalar twins, so one
    /// report shows the on/off `node_fetches_per_query` amortization
    /// directly. 0 keeps the scalar-only grid.
    pub packet_width: usize,
}

impl Default for SmokeCfg {
    fn default() -> Self {
        SmokeCfg {
            ns: vec![1 << 16, 1 << 18, 1 << 20],
            batches: vec![1 << 12, 1 << 16],
            workers: crate::util::pool::default_workers(),
            seed: 0xBE9C,
            shard_block: ShardBlock::Sqrt,
            update_frac: 0.0,
            range_frac: 0.0,
            packet_width: 0,
        }
    }
}

/// One measured grid point. `layout` is the solver column: the two
/// monolithic BVH layouts plus the two-level sharded engine.
#[derive(Clone, Debug)]
pub struct SmokePoint {
    pub layout: &'static str,
    pub n: usize,
    pub batch: usize,
    pub ns_per_query: f64,
    /// Wall-clock ns per applied point update (0 when not measured).
    pub upd_ns_per_op: f64,
    /// Wall-clock ns per applied lazy range update (0 when not
    /// measured; sharded column only — see [`SmokeCfg::range_frac`]).
    pub range_ns_per_op: f64,
    /// Wall-clock ms to build this solver over the n-element array
    /// (shared by every batch row of the same (n, solver) pair).
    pub build_ms: f64,
    /// `RmqSolver::memory_bytes` of the freshly built solver — the
    /// resident-memory column the instanced backend is meant to shrink
    /// (ISSUE 7's ≥4× acceptance gate reads this).
    pub resident_bytes: usize,
    /// Ray-packet width this column ran with (0 = scalar traversal).
    pub packet_width: usize,
    pub counters: Counters,
}

impl SmokePoint {
    /// Node-record fetches per query — the packet-amortization figure
    /// (equals `nodes_visited / batch` on scalar columns).
    pub fn node_fetches_per_query(&self) -> f64 {
        self.counters.node_fetches as f64 / self.batch.max(1) as f64
    }
}

/// Uniform queries: l uniform over [0, n), r uniform over [l, n).
fn uniform_queries(n: usize, count: usize, rng: &mut Rng) -> Vec<Query> {
    (0..count)
        .map(|_| {
            let l = rng.range(0, n - 1);
            let r = rng.range(l, n - 1);
            (l as u32, r as u32)
        })
        .collect()
}

/// Run the grid. Panics if any two solver columns ever disagree on an
/// answer (a smoke result over wrong answers would be meaningless).
pub fn run_smoke(cfg: &SmokeCfg) -> Vec<SmokePoint> {
    let mut points = Vec::new();
    // Column labels for the packet A/B pair carry the width (e.g.
    // "wide-p8"), so bench-compare treats each width as its own column
    // and the CI pin (`--packet-width 8`) stays label-stable run to run.
    let packet_labels: Option<(&'static str, &'static str)> = (cfg.packet_width > 0).then(|| {
        (
            &*Box::leak(format!("{LABEL_WIDE}-p{}", cfg.packet_width).into_boxed_str()),
            &*Box::leak(format!("{LABEL_SHARDED}-p{}", cfg.packet_width).into_boxed_str()),
        )
    });
    for &n in &cfg.ns {
        let xs = gen_array(n, cfg.seed);
        let mode = if n > (1 << 16) {
            match best_block_size(n, &OptixLimits::default()) {
                Some(bs) => RtxMode::Blocks { block_size: bs },
                None => RtxMode::Flat,
            }
        } else {
            RtxMode::Flat
        };
        let t0 = std::time::Instant::now();
        let mut sharded = ShardedRmq::with_options(
            &xs,
            ShardedOptions { block_size: cfg.shard_block.resolve(n), ..Default::default() },
        );
        let sharded_build = (t0.elapsed().as_secs_f64() * 1e3, sharded.memory_bytes());
        let mut rtx: Vec<(AccelLayout, RtxRmq, f64, usize)> = AccelLayout::all()
            .into_iter()
            .map(|layout| {
                let opts = RtxOptions { mode, layout, ..Default::default() };
                let t0 = std::time::Instant::now();
                let solver = RtxRmq::with_options(&xs, opts);
                let ms = t0.elapsed().as_secs_f64() * 1e3;
                let bytes = solver.memory_bytes();
                (layout, solver, ms, bytes)
            })
            .collect();
        // The packet A/B twins: identical geometry, packetized driver.
        let packet_solvers = packet_labels.map(|_| {
            let t0 = std::time::Instant::now();
            let wide = RtxRmq::with_options(
                &xs,
                RtxOptions {
                    mode,
                    layout: AccelLayout::Wide,
                    packet_width: cfg.packet_width,
                    ..Default::default()
                },
            );
            let wide_build = (t0.elapsed().as_secs_f64() * 1e3, wide.memory_bytes());
            let t0 = std::time::Instant::now();
            let shard = ShardedRmq::with_options(
                &xs,
                ShardedOptions {
                    block_size: cfg.shard_block.resolve(n),
                    packet_width: cfg.packet_width,
                    ..Default::default()
                },
            );
            let shard_build = (t0.elapsed().as_secs_f64() * 1e3, shard.memory_bytes());
            (wide, wide_build, shard, shard_build)
        });
        for &batch in &cfg.batches {
            let mut rng = Rng::new(cfg.seed ^ (n as u64) ^ ((batch as u64) << 32));
            let queries = uniform_queries(n, batch, &mut rng);
            let mut reference: Option<Vec<u32>> = None;
            let mut measure =
                |label: &'static str,
                 run: &dyn Fn(&[Query], usize) -> (Vec<u32>, Counters),
                 build_ms: f64,
                 resident_bytes: usize,
                 packet_width: usize,
                 points: &mut Vec<SmokePoint>| {
                    // Warm the structures (page-in, branch predictors)
                    // off the clock, then time one full batch.
                    let warm = queries.len().min(256);
                    std::hint::black_box(run(&queries[..warm], cfg.workers));
                    let t0 = std::time::Instant::now();
                    let (answers, counters) = run(&queries, cfg.workers);
                    let wall_ns = t0.elapsed().as_nanos() as f64;
                    match &reference {
                        None => reference = Some(answers),
                        Some(want) => assert_eq!(
                            want, &answers,
                            "{label} disagrees at n={n} batch={batch}"
                        ),
                    }
                    points.push(SmokePoint {
                        layout: label,
                        n,
                        batch,
                        ns_per_query: wall_ns / batch as f64,
                        upd_ns_per_op: 0.0,
                        range_ns_per_op: 0.0,
                        build_ms,
                        resident_bytes,
                        packet_width,
                        counters,
                    });
                };
            for (layout, solver, build_ms, bytes) in &rtx {
                let label = match layout {
                    AccelLayout::Binary => LABEL_BINARY,
                    AccelLayout::Wide => LABEL_WIDE,
                };
                measure(
                    label,
                    &|q, w| solver.batch_counted(q, w),
                    *build_ms,
                    *bytes,
                    0,
                    &mut points,
                );
            }
            measure(
                LABEL_SHARDED,
                &|q, w| sharded.batch_counted(q, w),
                sharded_build.0,
                sharded_build.1,
                0,
                &mut points,
            );
            // The packet pair rides after the scalar columns, so the
            // cross-column answer check also pins packet == scalar
            // bit-for-bit on every grid point.
            if let (Some((wide_l, shard_l)), Some((wide, wide_b, shard, shard_b))) =
                (packet_labels, packet_solvers.as_ref())
            {
                measure(
                    wide_l,
                    &|q, w| wide.batch_counted(q, w),
                    wide_b.0,
                    wide_b.1,
                    cfg.packet_width,
                    &mut points,
                );
                measure(
                    shard_l,
                    &|q, w| shard.batch_counted(q, w),
                    shard_b.0,
                    shard_b.1,
                    cfg.packet_width,
                    &mut points,
                );
            }

            // Write path: time one update batch per solver, then roll the
            // values back off the clock so later grid points (and the
            // cross-column answer check) still see the original array.
            if cfg.update_frac > 0.0 {
                let count = ((batch as f64 * cfg.update_frac) as usize).max(1);
                let updates = gen_updates(n, count, &mut rng);
                let rollback: Vec<(usize, f32)> =
                    updates.iter().map(|&(i, _)| (i, xs[i])).collect();
                // The grid point pushed one row per RTX layout, the
                // sharded row, then the read-only packet pair (when
                // enabled), in that order — mirror it structurally.
                let packet_rows = if packet_labels.is_some() { 2 } else { 0 };
                let base = points.len() - (rtx.len() + 1 + packet_rows);
                for (slot, (_, solver, ..)) in rtx.iter_mut().enumerate() {
                    let t0 = std::time::Instant::now();
                    solver.update_values(&updates);
                    points[base + slot].upd_ns_per_op =
                        t0.elapsed().as_nanos() as f64 / count as f64;
                    solver.update_values(&rollback);
                }
                let t0 = std::time::Instant::now();
                sharded.update_batch_with(&updates, cfg.workers);
                points[base + rtx.len()].upd_ns_per_op =
                    t0.elapsed().as_nanos() as f64 / count as f64;
                sharded.update_batch_with(&rollback, cfg.workers);
            }

            // Range-tag path: time a batch of lazy add/assign range ops
            // on the sharded column (the monolithic BVHs have no range
            // API), then restore the union span's pre-image off the
            // clock — later grid points and the cross-column agreement
            // check still see the original array.
            if cfg.range_frac > 0.0 {
                let count = ((batch as f64 * cfg.range_frac) as usize).max(1);
                let ops: Vec<UpdateOp> = (0..count)
                    .map(|k| {
                        let l = rng.range(0, n - 1);
                        let r = rng.range(l, n - 1);
                        if k % 2 == 0 {
                            UpdateOp::RangeAdd { l, r, v: rng.f32() - 0.5 }
                        } else {
                            UpdateOp::RangeAssign { l, r, v: rng.f32() }
                        }
                    })
                    .collect();
                let (mut lo, mut hi) = (n - 1, 0usize);
                for op in &ops {
                    if let UpdateOp::RangeAdd { l, r, .. }
                    | UpdateOp::RangeAssign { l, r, .. } = *op
                    {
                        lo = lo.min(l);
                        hi = hi.max(r);
                    }
                }
                let pre: Vec<(usize, f32)> = (lo..=hi).map(|i| (i, xs[i])).collect();
                let packet_rows = if packet_labels.is_some() { 2 } else { 0 };
                let base = points.len() - (rtx.len() + 1 + packet_rows);
                let t0 = std::time::Instant::now();
                sharded.apply_update_ops(&ops, cfg.workers);
                points[base + rtx.len()].range_ns_per_op =
                    t0.elapsed().as_nanos() as f64 / count as f64;
                sharded.update_batch_with(&pre, cfg.workers);
            }
        }
    }
    points
}

/// Speedup summary rows vs the binary baseline: one row per
/// (n, batch, non-binary label).
///
/// A grid point without a binary baseline (a partial grid — e.g. a
/// filtered rerun, or a future column measured at sizes the binary
/// layout can't build) is **skipped with a log line**, never reported
/// as a bogus ratio: a missing or unmeasured (≤ 0 ns) baseline used to
/// divide through regardless, producing `inf`/`NaN` speedups downstream.
pub fn speedups(points: &[SmokePoint]) -> Vec<(usize, usize, &'static str, f64, f64, f64)> {
    let mut out = Vec::new();
    for p in points.iter().filter(|p| p.layout != LABEL_BINARY) {
        let baseline = points
            .iter()
            .find(|b| b.layout == LABEL_BINARY && b.n == p.n && b.batch == p.batch);
        let Some(b) = baseline else {
            eprintln!(
                "bench-smoke: no binary baseline for {} n={} batch={} — skipping speedup row",
                p.layout, p.n, p.batch
            );
            continue;
        };
        if b.ns_per_query <= 0.0 || p.ns_per_query <= 0.0 {
            eprintln!(
                "bench-smoke: unmeasured ns/query for {} n={} batch={} — skipping speedup row",
                p.layout, p.n, p.batch
            );
            continue;
        }
        out.push((
            p.n,
            p.batch,
            p.layout,
            b.ns_per_query,
            p.ns_per_query,
            b.ns_per_query / p.ns_per_query,
        ));
    }
    out
}

/// Serialize the grid (per-point counters + speedup summary) to JSON.
pub fn to_json(cfg: &SmokeCfg, points: &[SmokePoint]) -> Json {
    let point_rows: Vec<Json> = points
        .iter()
        .map(|p| {
            obj(vec![
                ("engine", Json::from("RTXRMQ")),
                ("layout", Json::from(p.layout)),
                ("n", Json::from(p.n)),
                ("batch", Json::from(p.batch)),
                ("ns_per_query", Json::from(p.ns_per_query)),
                ("upd_ns_per_op", Json::from(p.upd_ns_per_op)),
                ("range_ns_per_op", Json::from(p.range_ns_per_op)),
                ("build_ms", Json::from(p.build_ms)),
                ("resident_bytes", Json::from(p.resident_bytes)),
                ("packet_width", Json::from(p.packet_width)),
                ("nodes_visited", Json::from(p.counters.nodes_visited)),
                ("node_fetches", Json::from(p.counters.node_fetches)),
                ("node_fetches_per_query", Json::from(p.node_fetches_per_query())),
                ("aabb_tests", Json::from(p.counters.aabb_tests)),
                ("tri_tests", Json::from(p.counters.tri_tests)),
                ("rays", Json::from(p.counters.rays)),
            ])
        })
        .collect();
    let speedup_rows: Vec<Json> = speedups(points)
        .into_iter()
        .map(|(n, batch, label, binary_ns, ns, speedup)| {
            obj(vec![
                ("n", Json::from(n)),
                ("batch", Json::from(batch)),
                ("layout", Json::from(label)),
                ("binary_ns_per_query", Json::from(binary_ns)),
                ("ns_per_query", Json::from(ns)),
                ("speedup_vs_binary", Json::from(speedup)),
            ])
        })
        .collect();
    obj(vec![
        ("bench", Json::from("rmq_smoke")),
        ("engine", Json::from("RTXRMQ")),
        ("seed", Json::from(cfg.seed)),
        ("workers", Json::from(cfg.workers)),
        ("update_frac", Json::from(cfg.update_frac)),
        ("range_frac", Json::from(cfg.range_frac)),
        ("packet_width", Json::from(cfg.packet_width)),
        ("points", Json::Arr(point_rows)),
        ("speedups", Json::Arr(speedup_rows)),
    ])
}

/// Render the grid as a GitHub-flavoured markdown table (the bench CI
/// job appends this to `$GITHUB_STEP_SUMMARY`).
pub fn summary_md(cfg: &SmokeCfg, points: &[SmokePoint]) -> String {
    let mut s = String::from("## rtxrmq bench-smoke\n\n");
    s.push_str(&format!(
        "seed `{:#x}`, {} workers, update fraction {}\n\n",
        cfg.seed, cfg.workers, cfg.update_frac
    ));
    s.push_str("| solver | n | batch | ns/query | ns/update | ns/range | fetches/query | build ms | resident MiB | speedup vs binary |\n");
    s.push_str("|---|---:|---:|---:|---:|---:|---:|---:|---:|---:|\n");
    let sp = speedups(points);
    for p in points {
        let speedup = if p.layout == LABEL_BINARY {
            "1.00x".to_string()
        } else {
            sp.iter()
                .find(|&&(n, b, label, ..)| n == p.n && b == p.batch && label == p.layout)
                .map_or("-".to_string(), |&(.., s)| format!("{s:.2}x"))
        };
        let upd = if p.upd_ns_per_op > 0.0 {
            format!("{:.1}", p.upd_ns_per_op)
        } else {
            "-".to_string()
        };
        let range = if p.range_ns_per_op > 0.0 {
            format!("{:.1}", p.range_ns_per_op)
        } else {
            "-".to_string()
        };
        s.push_str(&format!(
            "| {} | {} | {} | {:.1} | {} | {} | {:.1} | {:.2} | {:.2} | {} |\n",
            p.layout,
            p.n,
            p.batch,
            p.ns_per_query,
            upd,
            range,
            p.node_fetches_per_query(),
            p.build_ms,
            p.resident_bytes as f64 / (1 << 20) as f64,
            speedup
        ));
    }
    s
}

/// Append markdown to a summary file (creating it if needed) — the
/// `$GITHUB_STEP_SUMMARY` contract is append-only.
pub fn append_summary_md(path: &Path, md: &str) -> std::io::Result<()> {
    use std::io::Write as _;
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    f.write_all(md.as_bytes())
}

/// Write the JSON report (creating parent directories).
pub fn write_json(path: &Path, json: &Json) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, json.to_string_compact() + "\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_grid_runs_and_serializes() {
        let cfg = SmokeCfg {
            ns: vec![512],
            batches: vec![128],
            workers: 2,
            seed: 7,
            shard_block: ShardBlock::Fixed(32),
            update_frac: 0.0,
            range_frac: 0.0,
            packet_width: 0,
        };
        let points = run_smoke(&cfg);
        // Three solver columns × one n × one batch.
        assert_eq!(points.len(), 3);
        for label in [LABEL_BINARY, LABEL_WIDE, LABEL_SHARDED] {
            assert!(points.iter().any(|p| p.layout == label), "{label} column missing");
        }
        assert!(points.iter().all(|p| p.ns_per_query > 0.0));
        assert!(points.iter().all(|p| p.upd_ns_per_op == 0.0), "no write path measured");
        assert!(points.iter().all(|p| p.build_ms > 0.0), "build wall time recorded");
        assert!(points.iter().all(|p| p.resident_bytes > 0), "resident bytes recorded");
        // The default sharded backend is instanced: its resident bytes
        // must come in below the monolithic per-element BVH layouts.
        let bytes = |label: &str| {
            points.iter().find(|p| p.layout == label).unwrap().resident_bytes
        };
        assert!(
            bytes(LABEL_SHARDED) < bytes(LABEL_WIDE),
            "instanced sharded {} !< wide {}",
            bytes(LABEL_SHARDED),
            bytes(LABEL_WIDE)
        );
        assert!(points.iter().all(|p| p.counters.rays >= 128));
        let sp = speedups(&points);
        assert_eq!(sp.len(), 2); // wide + sharded vs binary
        let json = to_json(&cfg, &points);
        let dir = std::env::temp_dir().join(format!("rtxrmq-smoke-{}", std::process::id()));
        let path = dir.join("BENCH_rmq.json");
        write_json(&path, &json).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let back = Json::parse(text.trim()).unwrap();
        assert_eq!(back.get("bench").and_then(|b| b.as_str()), Some("rmq_smoke"));
        let pts = back.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(pts.len(), 3);
        assert!(pts
            .iter()
            .any(|p| p.get("layout").and_then(|l| l.as_str()) == Some(LABEL_SHARDED)));
        for p in pts {
            assert!(p.get("ns_per_query").and_then(|v| v.as_f64()).unwrap() > 0.0);
            assert!(p.get("upd_ns_per_op").and_then(|v| v.as_f64()).is_some());
            assert!(p.get("build_ms").and_then(|v| v.as_f64()).unwrap() > 0.0);
            assert!(p.get("resident_bytes").and_then(|v| v.as_u64()).unwrap() > 0);
            assert!(p.get("nodes_visited").and_then(|v| v.as_u64()).is_some());
            assert!(p.get("node_fetches").and_then(|v| v.as_u64()).is_some());
            assert!(p.get("node_fetches_per_query").and_then(|v| v.as_f64()).unwrap() > 0.0);
            assert_eq!(p.get("packet_width").and_then(|v| v.as_u64()), Some(0));
            assert!(p.get("aabb_tests").and_then(|v| v.as_u64()).is_some());
            assert!(p.get("tri_tests").and_then(|v| v.as_u64()).is_some());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn update_frac_measures_write_path_without_skewing_reads() {
        let cfg = SmokeCfg {
            ns: vec![512],
            batches: vec![128, 128],
            workers: 2,
            seed: 9,
            shard_block: ShardBlock::Fixed(32),
            update_frac: 0.25,
            range_frac: 0.0,
            packet_width: 0,
        };
        // Two identical batch sizes: the rollback must restore the array
        // so both grid points agree with each other (run_smoke asserts
        // cross-column agreement internally on each one).
        let points = run_smoke(&cfg);
        assert_eq!(points.len(), 6);
        assert!(
            points.iter().all(|p| p.upd_ns_per_op > 0.0),
            "every column measures the write path"
        );
        let md = summary_md(&cfg, &points);
        assert!(md.contains("ns/update") && md.contains("sharded"));
        let dir = std::env::temp_dir().join(format!("rtxrmq-summary-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("summary.md");
        append_summary_md(&path, &md).unwrap();
        append_summary_md(&path, &md).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.matches("## rtxrmq bench-smoke").count(), 2, "append, not truncate");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn range_frac_measures_the_tag_path_on_the_sharded_column_only() {
        let cfg = SmokeCfg {
            ns: vec![512],
            batches: vec![128, 128],
            workers: 2,
            seed: 13,
            shard_block: ShardBlock::Fixed(32),
            update_frac: 0.0,
            range_frac: 0.1,
            packet_width: 0,
        };
        // Two identical batch sizes: the pre-image rollback must restore
        // the array so the second grid point's cross-column agreement
        // check (inside run_smoke) still passes after range tags landed.
        let points = run_smoke(&cfg);
        assert_eq!(points.len(), 6);
        for p in &points {
            if p.layout == LABEL_SHARDED {
                assert!(p.range_ns_per_op > 0.0, "sharded column measures ranges");
            } else {
                assert_eq!(p.range_ns_per_op, 0.0, "{} has no range API", p.layout);
            }
        }
        let json = to_json(&cfg, &points);
        assert_eq!(json.get("range_frac").and_then(|v| v.as_f64()), Some(0.1));
        let rows = json.get("points").and_then(|p| p.as_arr()).unwrap();
        assert!(rows.iter().any(|r| {
            r.get("layout").and_then(|l| l.as_str()) == Some(LABEL_SHARDED)
                && r.get("range_ns_per_op").and_then(|v| v.as_f64()).unwrap() > 0.0
        }));
        let md = summary_md(&cfg, &points);
        assert!(md.contains("ns/range"), "{md}");
    }

    #[test]
    fn speedups_skip_points_without_a_binary_baseline() {
        let mk = |layout, n, batch, ns| SmokePoint {
            layout,
            n,
            batch,
            ns_per_query: ns,
            upd_ns_per_op: 0.0,
            range_ns_per_op: 0.0,
            build_ms: 1.0,
            resident_bytes: 64,
            packet_width: 0,
            counters: Counters::default(),
        };
        let points = vec![
            mk(LABEL_BINARY, 1024, 64, 900.0),
            mk(LABEL_WIDE, 1024, 64, 300.0),
            // Partial grid: no binary row at n = 4096 — both non-binary
            // rows must be skipped with a log, not become inf/NaN.
            mk(LABEL_WIDE, 4096, 64, 500.0),
            mk(LABEL_SHARDED, 4096, 64, 250.0),
            // Baseline present but unmeasured (0 ns): also skipped.
            mk(LABEL_BINARY, 2048, 64, 0.0),
            mk(LABEL_SHARDED, 2048, 64, 100.0),
        ];
        let sp = speedups(&points);
        assert_eq!(sp.len(), 1, "only the fully covered point survives: {sp:?}");
        let (n, batch, label, base_ns, ns, speedup) = sp[0];
        assert_eq!((n, batch, label), (1024, 64, LABEL_WIDE));
        assert!((speedup - 3.0).abs() < 1e-9, "{base_ns}/{ns} = {speedup}");
        assert!(sp.iter().all(|&(.., s)| s.is_finite()));
        // The markdown table renders skipped points with a "-" cell.
        let cfg = SmokeCfg::default();
        let md = summary_md(&cfg, &points);
        assert!(md.contains("| - |"), "{md}");
    }

    #[test]
    fn packet_column_pair_reports_decreasing_node_fetches() {
        // The acceptance curve: with left-endpoint sorting on, node
        // fetches per query strictly decrease as the packet width
        // grows. The sharded column's probes are small-range by
        // construction (per-block local ranges), so its packet path
        // amortizes on any query mix; same seed ⇒ same queries, so the
        // three runs are directly comparable.
        let mk_cfg = |packet_width: usize| SmokeCfg {
            ns: vec![1024],
            batches: vec![256],
            workers: 2,
            seed: 11,
            shard_block: ShardBlock::Fixed(32),
            update_frac: 0.0,
            range_frac: 0.0,
            packet_width,
        };
        let scalar = run_smoke(&mk_cfg(0));
        assert_eq!(scalar.len(), 3, "no packet columns when the knob is off");
        let p4 = run_smoke(&mk_cfg(4));
        let p8 = run_smoke(&mk_cfg(8));
        assert_eq!(p4.len(), 5, "scalar columns plus the wide/sharded packet pair");
        assert!(p4.iter().any(|p| p.layout == "wide-p4" && p.packet_width == 4));
        assert!(p8.iter().any(|p| p.layout == "sharded-p8" && p.packet_width == 8));
        let fetches = |points: &[SmokePoint], label: &str| {
            points.iter().find(|p| p.layout == label).unwrap().node_fetches_per_query()
        };
        let base = fetches(&scalar, LABEL_SHARDED);
        let f4 = fetches(&p4, "sharded-p4");
        let f8 = fetches(&p8, "sharded-p8");
        assert!(
            f8 < f4 && f4 < base,
            "fetches/query must strictly decrease with width: {base} > {f4} > {f8}"
        );
        // The scalar twin columns are untouched by the knob, and the
        // packet columns never fetch more than they visit.
        assert_eq!(fetches(&p8, LABEL_SHARDED), base);
        for p in p8.iter().filter(|p| p.packet_width > 0) {
            assert!(p.counters.node_fetches <= p.counters.nodes_visited, "{}", p.layout);
        }
        // The JSON report carries the amortization column per row.
        let json = to_json(&mk_cfg(8), &p8);
        assert_eq!(json.get("packet_width").and_then(|v| v.as_u64()), Some(8));
        let rows = json.get("points").and_then(|p| p.as_arr()).unwrap();
        assert!(rows.iter().any(|r| {
            r.get("layout").and_then(|l| l.as_str()) == Some("sharded-p8")
                && r.get("node_fetches_per_query").and_then(|v| v.as_f64()).unwrap() > 0.0
        }));
        // And the markdown table shows the fetch column for eyeballs.
        let md = summary_md(&mk_cfg(8), &p8);
        assert!(md.contains("fetches/query") && md.contains("sharded-p8"), "{md}");
    }

    #[test]
    fn uniform_queries_are_valid() {
        let mut rng = Rng::new(3);
        let qs = uniform_queries(1000, 500, &mut rng);
        assert!(crate::rmq::validate_queries(1000, &qs).is_ok());
    }
}

//! Approach runners: build each solver once per array, execute query
//! samples, and convert measured work to modeled time (see module docs).

use crate::bvh::traverse::Counters;
use crate::model::{CudaCostModel, EnergyModel, HrmqCostModel, LcaCostModel, RtCostModel};
use crate::rmq::hrmq::Hrmq;
use crate::rmq::lca::LcaRmq;
use crate::rmq::rtx::{RtxMode, RtxOptions, RtxRmq};
use crate::rmq::{Query, RmqSolver};
use crate::rtcore::arch::{ArchProfile, LOVELACE_RTX6000ADA};
use crate::workload::mean_range_len;

/// All solvers over one array, with the paper's models attached.
pub struct Suite {
    pub xs: Vec<f32>,
    pub n: usize,
    pub rtx: RtxRmq,
    pub lca: LcaRmq,
    pub hrmq: Hrmq,
    pub rt_model: RtCostModel,
    pub lca_model: LcaCostModel,
    pub hrmq_model: HrmqCostModel,
    pub cuda_model: CudaCostModel,
    pub energy: EnergyModel,
}

/// Modeled ns/RMQ for the four approaches at one measurement point.
#[derive(Clone, Copy, Debug)]
pub struct PointResult {
    pub rtx_ns: f64,
    pub lca_ns: f64,
    pub hrmq_ns: f64,
    pub exhaustive_ns: f64,
    /// Measured RTX traversal work units per query (for Fig. 11 etc.).
    pub rtx_work: f64,
}

impl Suite {
    pub fn build(n: usize, seed: u64) -> Suite {
        let xs = crate::workload::gen_array(n, seed);
        Suite::from_values(xs)
    }

    pub fn from_values(xs: Vec<f32>) -> Suite {
        let n = xs.len();
        Suite {
            rtx: RtxRmq::new_auto(&xs),
            lca: LcaRmq::new(&xs),
            hrmq: Hrmq::new(&xs),
            rt_model: RtCostModel::default(),
            lca_model: LcaCostModel::default(),
            hrmq_model: HrmqCostModel::default(),
            cuda_model: CudaCostModel::default(),
            energy: EnergyModel::default(),
            n,
            xs,
        }
    }

    /// Build with an explicit RTX block size (Fig. 11's configuration
    /// axis). Returns None when the configuration violates Eq. 2 /
    /// OptiX limits — exactly the filtered cells of the paper's cube.
    pub fn build_with_block_size(n: usize, seed: u64, bs: usize) -> Option<Suite> {
        use crate::geometry::precision::{config_valid, OptixLimits};
        config_valid(n, bs, &OptixLimits::default()).ok()?;
        let xs = crate::workload::gen_array(n, seed);
        let rtx = RtxRmq::with_options(
            &xs,
            RtxOptions { mode: RtxMode::Blocks { block_size: bs }, ..Default::default() },
        );
        let mut s = Suite::from_values(xs);
        s.rtx = rtx;
        Some(s)
    }

    /// Measured RTX work/query on a query sample.
    pub fn rtx_counters(&self, queries: &[Query], workers: usize) -> Counters {
        self.rtx.batch_counted(queries, workers).1
    }

    /// Modeled ns/RMQ for RTXRMQ at the given batch size on `gpu`.
    pub fn rtx_modeled_ns(&self, queries: &[Query], batch: u64, gpu: &ArchProfile, workers: usize) -> (f64, f64) {
        let c = self.rtx_counters(queries, workers);
        let work = self.rt_model.work_per_query(&c, queries.len() as u64);
        // Scale the sample's counters to the modeled batch (per-query
        // work is batch-independent).
        let scale = batch as f64 / queries.len() as f64;
        let scaled = Counters {
            nodes_visited: (c.nodes_visited as f64 * scale) as u64,
            node_fetches: (c.node_fetches as f64 * scale) as u64,
            aabb_tests: (c.aabb_tests as f64 * scale) as u64,
            tri_tests: (c.tri_tests as f64 * scale) as u64,
            rays: (c.rays as f64 * scale) as u64,
        };
        (self.rt_model.ns_per_query(&scaled, batch, gpu), work)
    }

    /// Modeled ns/RMQ for LCA (O(1) measured work; cache + range factor).
    pub fn lca_modeled_ns(&self, queries: &[Query], batch: u64, gpu: &ArchProfile) -> f64 {
        let mean = mean_range_len(queries);
        let base = self.lca_model.ns_per_query(self.lca.memory_bytes() as u64, batch, gpu);
        base * self.lca_model.range_factor(mean, self.n)
    }

    /// HRMQ: measure local single-thread wall clock on the sample, model
    /// the paper's 192-core host.
    pub fn hrmq_modeled_ns(&self, queries: &[Query], batch: u64) -> f64 {
        let t0 = std::time::Instant::now();
        let answers = self.hrmq.batch(queries, 1);
        let per_query = t0.elapsed().as_nanos() as f64 / queries.len() as f64;
        std::hint::black_box(answers);
        self.hrmq_model.ns_per_query(per_query, batch)
    }

    /// EXHAUSTIVE: work = elements scanned per query (measured exactly
    /// from the ranges).
    pub fn exhaustive_modeled_ns(&self, queries: &[Query], batch: u64, gpu: &ArchProfile) -> f64 {
        let scanned = mean_range_len(queries);
        self.cuda_model.ns_per_query(scanned, (self.n as u64) * 4, batch, gpu)
    }

    /// Full point measurement on the reference GPU.
    pub fn measure_point(&self, queries: &[Query], batch: u64, workers: usize) -> PointResult {
        self.measure_point_on(queries, batch, &LOVELACE_RTX6000ADA, workers)
    }

    pub fn measure_point_on(
        &self,
        queries: &[Query],
        batch: u64,
        gpu: &ArchProfile,
        workers: usize,
    ) -> PointResult {
        let (rtx_ns, rtx_work) = self.rtx_modeled_ns(queries, batch, gpu, workers);
        PointResult {
            rtx_ns,
            rtx_work,
            lca_ns: self.lca_modeled_ns(queries, batch, gpu),
            hrmq_ns: self.hrmq_modeled_ns(queries, batch),
            exhaustive_ns: self.exhaustive_modeled_ns(queries, batch, gpu),
        }
    }

    /// Correctness guard used by every bench: all solvers must agree on
    /// the sample (a bench over wrong answers is meaningless).
    pub fn verify(&self, queries: &[Query], workers: usize) {
        let a = self.rtx.batch(queries, workers);
        let b = self.lca.batch(queries, workers);
        let c = self.hrmq.batch(queries, workers);
        assert_eq!(a, b, "RTX vs LCA disagree");
        assert_eq!(a, c, "RTX vs HRMQ disagree");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workload::{gen_queries, RangeDist};

    #[test]
    fn suite_point_measurement_is_sane() {
        let suite = Suite::build(1 << 12, 42);
        let mut rng = Rng::new(43);
        let qs = gen_queries(1 << 12, 256, RangeDist::Small, &mut rng);
        suite.verify(&qs, 2);
        let p = suite.measure_point(&qs, 1 << 26, 2);
        assert!(p.rtx_ns > 0.0 && p.lca_ns > 0.0 && p.hrmq_ns > 0.0 && p.exhaustive_ns > 0.0);
        assert!(p.rtx_work > 1.0, "traversal must do some work");
    }

    #[test]
    fn fig12_shape_holds_at_modeled_batch() {
        // The paper's scale-robust qualitative results at saturated
        // batches (block-matrix mode, n > 2^16): RTXRMQ favors small
        // ranges over large ones (Fig 10), LCA wins large ranges
        // (Fig 12), EXHAUSTIVE's cost tracks range length. The
        // HRMQ-relative speedups are checked at paper scale by the fig12
        // driver's extrapolation (they depend on absolute wall-clock,
        // which debug/release builds shift at CI sizes).
        let n = (1 << 16) + 4096;
        let suite = Suite::build(n, 44);
        let mut rng = Rng::new(45);
        let batch = 1u64 << 26;
        let small = gen_queries(n, 1024, RangeDist::Small, &mut rng);
        let large = gen_queries(n, 1024, RangeDist::Large, &mut rng);
        let ps = suite.measure_point(&small, batch, 2);
        let pl = suite.measure_point(&large, batch, 2);
        assert!(ps.rtx_ns < pl.rtx_ns, "RTX favors small ranges: {ps:?} vs {pl:?}");
        assert!(pl.lca_ns < pl.rtx_ns, "LCA must win large ranges: {pl:?}");
        assert!(ps.exhaustive_ns < pl.exhaustive_ns, "exhaustive loves small ranges");
        assert!(ps.hrmq_ns > 0.0 && pl.hrmq_ns > 0.0);
    }

    #[test]
    fn invalid_block_size_is_filtered() {
        assert!(Suite::build_with_block_size(1 << 20, 1, 1 << 19).is_none());
        assert!(Suite::build_with_block_size(1 << 12, 1, 64).is_some());
    }
}

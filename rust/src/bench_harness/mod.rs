//! Shared machinery for the per-figure bench drivers (`rust/benches/`).
//!
//! Measurement protocol ("measured work, modeled batch"): each bench
//! executes a *sample* of real queries through the real data structures,
//! measures the per-query work (BVH counters, wall-clock, scanned
//! elements), then converts that work to modeled GPU/CPU time **at the
//! paper's batch size** via `crate::model`. The paper's batches (2^26
//! queries at n up to 1e8) do not fit a 1-core CI budget; the per-query
//! work is batch-independent, so sampling is exact for everything except
//! the saturation term, which the models carry explicitly (Fig. 13).

pub mod compare;
pub mod runner;
pub mod smoke;

use crate::util::cli::Args;
use std::path::PathBuf;

/// Configuration shared by all bench drivers.
#[derive(Clone, Debug)]
pub struct BenchCfg {
    pub seed: u64,
    /// Queries sampled per measurement point.
    pub sample_queries: usize,
    /// Batch size the models are evaluated at (paper: 2^26).
    pub model_batch: u64,
    /// Largest n in default sweeps.
    pub max_n: usize,
    /// Full paper-scale sweep (slow).
    pub paper_scale: bool,
    /// Where CSVs are written.
    pub out_dir: PathBuf,
    pub workers: usize,
}

impl BenchCfg {
    /// Parse from process args (works both under `cargo bench` and when
    /// invoked directly). Honors `--quick`, `--paper-scale`, `--n`,
    /// `--samples`, `--seed`, `--out-dir`.
    pub fn from_env() -> BenchCfg {
        // cargo bench passes a `--bench` flag; ignore unknown tokens.
        let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
        let quick = args.flag("quick") || std::env::var("RTXRMQ_BENCH_QUICK").is_ok();
        let paper_scale = args.flag("paper-scale");
        let max_n_default = if quick {
            1 << 14
        } else if paper_scale {
            1 << 24
        } else {
            1 << 18
        };
        BenchCfg {
            seed: args.get_or("seed", 0xBE9C_u64).unwrap_or(0xBE9C),
            sample_queries: args
                .get_or("samples", if quick { 512usize } else { 2048 })
                .unwrap_or(2048),
            model_batch: args.get_or("model-batch", 1u64 << 26).unwrap_or(1 << 26),
            max_n: args.get_or("n", max_n_default).unwrap_or(max_n_default),
            paper_scale,
            out_dir: PathBuf::from(args.str_or("out-dir", "results")),
            workers: crate::util::pool::default_workers(),
        }
    }

    /// The n sweep for Fig. 10/12-style experiments: powers of two from
    /// 2^10 up to `max_n`.
    pub fn n_sweep(&self) -> Vec<usize> {
        let mut out = Vec::new();
        let mut n = 1usize << 10;
        while n <= self.max_n {
            out.push(n);
            n <<= 2; // every other power of two keeps CI fast
        }
        if *out.last().unwrap_or(&0) != self.max_n {
            out.push(self.max_n);
        }
        out
    }
}

/// Print a paper-style table header + rows to stdout.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{c:>width$}", width = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn n_sweep_is_bounded_and_sorted() {
        let cfg = BenchCfg {
            seed: 1,
            sample_queries: 16,
            model_batch: 1 << 20,
            max_n: 1 << 16,
            paper_scale: false,
            out_dir: PathBuf::from("/tmp"),
            workers: 1,
        };
        let sweep = cfg.n_sweep();
        assert_eq!(*sweep.first().unwrap(), 1 << 10);
        assert_eq!(*sweep.last().unwrap(), 1 << 16);
        assert!(sweep.windows(2).all(|w| w[0] < w[1]));
    }
}

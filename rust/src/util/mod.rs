//! Substrate utilities built from scratch for the offline environment
//! (no rand / clap / rayon / serde / criterion / proptest — see DESIGN.md
//! §0): PRNG + distributions, CLI parsing, scoped thread pool, statistics,
//! JSON/CSV, bit utilities, timing, a mini property-test harness, and
//! the hashed run-manifest contract (SHA-256 + builder/validator).

pub mod bits;
pub mod cli;
pub mod csv;
pub mod faults;
pub mod json;
pub mod manifest;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod sha256;
pub mod stats;
pub mod sync;
pub mod timer;

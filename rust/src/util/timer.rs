//! Wall-clock timing helpers and the adaptive measurement loop used by the
//! bench harness (our stand-in for `criterion`, which is unavailable
//! offline). Measurements follow the paper's protocol (§6.4): realizations
//! × repeats, reporting the mean.

use super::stats::OnlineStats;
use std::time::Instant;

/// Time a closure once, returning (result, elapsed ns).
pub fn time_once<R>(f: impl FnOnce() -> R) -> (R, u64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_nanos() as u64)
}

/// Measurement configuration.
#[derive(Clone, Copy, Debug)]
pub struct MeasureCfg {
    /// Warmup iterations (not recorded).
    pub warmup: u32,
    /// Minimum recorded iterations.
    pub min_iters: u32,
    /// Maximum recorded iterations.
    pub max_iters: u32,
    /// Stop early once the relative standard error of the mean drops
    /// below this (and `min_iters` reached).
    pub target_rse: f64,
    /// Hard wall-clock budget in ns for the whole measurement.
    pub budget_ns: u64,
}

impl Default for MeasureCfg {
    fn default() -> Self {
        MeasureCfg {
            warmup: 1,
            min_iters: 3,
            max_iters: 100,
            target_rse: 0.02,
            budget_ns: 2_000_000_000,
        }
    }
}

impl MeasureCfg {
    /// Fast configuration for CI / smoke runs.
    pub fn quick() -> Self {
        MeasureCfg { warmup: 0, min_iters: 1, max_iters: 3, target_rse: 1.0, budget_ns: 500_000_000 }
    }
}

/// Result of an adaptive measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub stats: OnlineStats,
}

impl Measurement {
    pub fn mean_ns(&self) -> f64 {
        self.stats.mean()
    }
    pub fn iters(&self) -> u64 {
        self.stats.count()
    }
}

/// Adaptively measure `f` (mean ns per call) under the given config.
pub fn measure(cfg: &MeasureCfg, mut f: impl FnMut()) -> Measurement {
    for _ in 0..cfg.warmup {
        f();
    }
    let mut stats = OnlineStats::new();
    let start = Instant::now();
    loop {
        let t0 = Instant::now();
        f();
        stats.push(t0.elapsed().as_nanos() as f64);
        let done_min = stats.count() >= cfg.min_iters as u64;
        let converged = done_min && stats.rel_stderr() <= cfg.target_rse;
        let out_of_budget = start.elapsed().as_nanos() as u64 >= cfg.budget_ns;
        let maxed = stats.count() >= cfg.max_iters as u64;
        if converged || maxed || (done_min && out_of_budget) {
            return Measurement { stats };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_once_returns_result() {
        let (v, ns) = time_once(|| 21 * 2);
        assert_eq!(v, 42);
        // elapsed is non-negative by type; just ensure it's sane (< 1s)
        assert!(ns < 1_000_000_000);
    }

    #[test]
    fn measure_respects_min_and_max() {
        let cfg = MeasureCfg { warmup: 0, min_iters: 5, max_iters: 7, target_rse: 0.0, budget_ns: u64::MAX };
        let m = measure(&cfg, || {
            std::hint::black_box(1 + 1);
        });
        assert!(m.iters() >= 5 && m.iters() <= 7, "iters={}", m.iters());
    }

    #[test]
    fn measure_converges_on_stable_work() {
        let cfg = MeasureCfg { warmup: 1, min_iters: 3, max_iters: 1000, target_rse: 0.5, budget_ns: u64::MAX };
        let m = measure(&cfg, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            std::hint::black_box(acc);
        });
        assert!(m.iters() < 1000, "should converge before max, got {}", m.iters());
        assert!(m.mean_ns() > 0.0);
    }
}

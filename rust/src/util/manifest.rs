//! Versioned, hashed **run manifests**: the machine-checkable record a
//! CLI run leaves behind (`serve`/`bench-smoke`/`bench-compare`
//! `--manifest PATH`, re-validated by `rtxrmq manifest-check`).
//!
//! A manifest captures what a soak or bench run *was* — the command and
//! its exit code, a metrics snapshot, and every artifact it produced
//! with its `sha256` and byte size — so CI claims stop being grep'd log
//! tails and become versioned documents any host can re-verify:
//!
//! - `schema_version` — semver; validators accept any `1.x.y`.
//! - `run_id` — random hex token, also threaded into the `Metrics`
//!   display header (`run=<id> ...`) so log lines correlate with the
//!   manifest that summarizes them.
//! - `commands[]` — `{argv, exit_code, duration_ms}` per command.
//! - `artifacts[]` / `logs[]` — `{path, sha256, bytes}`; the validator
//!   re-reads each file and re-hashes it, so a swapped or truncated
//!   artifact fails the check.
//! - `metrics` — free-form snapshot object (per-tenant summaries for
//!   multi-tenant soaks, gate mode for `bench-compare`).
//! - `manifest_sha256` — SHA-256 of the **canonical JSON** of the
//!   whole document with this field removed. `Json::Obj` is backed by
//!   a `BTreeMap` and [`Json::to_string_compact`] prints sorted keys
//!   with `,`/`:` separators, so the compact form *is* the canonical
//!   form — same convention as `json.dumps(sort_keys=True,
//!   separators=(',', ':'))`.

use crate::util::json::{obj, Json};
use crate::util::sha256::sha256_hex;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Current manifest schema. Validators require the same major.
pub const SCHEMA_VERSION: &str = "1.0.0";

/// Random-enough run token: time + pid through a splitmix64 finalizer.
/// Collision resistance only needs to cover "runs a human might ever
/// compare", not adversaries.
pub fn gen_run_id() -> String {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let mut state = nanos ^ ((std::process::id() as u64) << 32) ^ 0x9e37_79b9_7f4a_7c15;
    format!("{:016x}", crate::util::rng::splitmix64(&mut state))
}

/// Accumulates one run's record; [`finish`](Self::finish) seals it with
/// the canonical-JSON hash.
pub struct ManifestBuilder {
    run_id: String,
    started: Instant,
    timestamp_s: u64,
    commands: Vec<Json>,
    logs: Vec<Json>,
    artifacts: Vec<Json>,
    metrics: Json,
}

impl ManifestBuilder {
    pub fn new(run_id: &str) -> ManifestBuilder {
        let timestamp_s = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        ManifestBuilder {
            run_id: run_id.to_string(),
            started: Instant::now(),
            timestamp_s,
            commands: Vec::new(),
            logs: Vec::new(),
            artifacts: Vec::new(),
            metrics: Json::Obj(BTreeMap::new()),
        }
    }

    pub fn run_id(&self) -> &str {
        &self.run_id
    }

    /// Record the invoking command. Call once per command the manifest
    /// covers (the CLI records its own argv + computed exit code).
    pub fn command(&mut self, argv: &[String], exit_code: i32) {
        let duration_ms = self.started.elapsed().as_millis() as u64;
        self.commands.push(obj(vec![
            ("argv", Json::Arr(argv.iter().map(|a| Json::Str(a.clone())).collect())),
            ("exit_code", Json::Num(exit_code as f64)),
            ("duration_ms", Json::Num(duration_ms as f64)),
        ]));
    }

    pub fn metrics(&mut self, metrics: Json) {
        self.metrics = metrics;
    }

    /// Hash a produced file into `artifacts[]`. Missing files are an
    /// error: a manifest must not silently claim artifacts.
    pub fn artifact(&mut self, path: &Path) -> std::io::Result<()> {
        self.artifacts.push(file_record(path)?);
        Ok(())
    }

    /// Hash a log file into `logs[]` (same record shape as artifacts).
    pub fn log(&mut self, path: &Path) -> std::io::Result<()> {
        self.logs.push(file_record(path)?);
        Ok(())
    }

    /// Seal: compute `manifest_sha256` over the canonical JSON of the
    /// document without that field, then embed it.
    pub fn finish(self) -> Json {
        let mut doc = BTreeMap::new();
        doc.insert("schema_version".into(), Json::Str(SCHEMA_VERSION.into()));
        doc.insert("run_id".into(), Json::Str(self.run_id));
        doc.insert("timestamp".into(), Json::Num(self.timestamp_s as f64));
        doc.insert(
            "env".into(),
            obj(vec![
                ("os", Json::Str(std::env::consts::OS.into())),
                ("arch", Json::Str(std::env::consts::ARCH.into())),
            ]),
        );
        doc.insert("commands".into(), Json::Arr(self.commands));
        doc.insert("logs".into(), Json::Arr(self.logs));
        doc.insert("artifacts".into(), Json::Arr(self.artifacts));
        doc.insert("metrics".into(), self.metrics);
        let hash = canonical_sha256(&Json::Obj(doc.clone()));
        doc.insert("manifest_sha256".into(), Json::Str(hash));
        Json::Obj(doc)
    }

    /// Seal and write (compact JSON + trailing newline, parents
    /// created). Returns the sealed document for further inspection.
    pub fn write(self, path: &Path) -> std::io::Result<Json> {
        let doc = self.finish();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, format!("{}\n", doc.to_string_compact()))?;
        Ok(doc)
    }
}

fn file_record(path: &Path) -> std::io::Result<Json> {
    let bytes = std::fs::read(path)?;
    Ok(obj(vec![
        ("path", Json::Str(path.to_string_lossy().into_owned())),
        ("sha256", Json::Str(sha256_hex(&bytes))),
        ("bytes", Json::Num(bytes.len() as f64)),
    ]))
}

/// Canonical hash of a manifest document: serialize compact (sorted
/// keys, `,`/`:` separators) with `manifest_sha256` removed.
pub fn canonical_sha256(doc: &Json) -> String {
    let canon = match doc {
        Json::Obj(map) => {
            let mut m = map.clone();
            m.remove("manifest_sha256");
            Json::Obj(m)
        }
        other => other.clone(),
    };
    sha256_hex(canon.to_string_compact().as_bytes())
}

/// Validate a parsed manifest: schema shape, semver major, and — the
/// part that gives CI teeth — re-read and re-hash every referenced
/// file against `base` (the manifest's own directory). Returns every
/// problem found, not just the first.
pub fn validate(doc: &Json, base: &Path) -> Result<(), Vec<String>> {
    let mut errs = Vec::new();
    let require_str = |key: &str, errs: &mut Vec<String>| -> Option<String> {
        match doc.get(key).and_then(|v| v.as_str()) {
            Some(s) if !s.is_empty() => Some(s.to_string()),
            _ => {
                errs.push(format!("missing or empty required field '{key}'"));
                None
            }
        }
    };
    if let Some(v) = require_str("schema_version", &mut errs) {
        match v.split('.').next().and_then(|m| m.parse::<u64>().ok()) {
            Some(1) => {}
            Some(major) => errs.push(format!("unsupported schema major {major} (want 1.x.y)")),
            None => errs.push(format!("schema_version '{v}' is not semver")),
        }
    }
    require_str("run_id", &mut errs);
    if doc.get("timestamp").and_then(|v| v.as_u64()).is_none() {
        errs.push("missing numeric field 'timestamp'".into());
    }
    for key in ["os", "arch"] {
        if doc.get("env").and_then(|e| e.get(key)).and_then(|v| v.as_str()).is_none() {
            errs.push(format!("missing env.{key}"));
        }
    }
    match doc.get("commands").and_then(|v| v.as_arr()) {
        None => errs.push("missing array field 'commands'".into()),
        Some(cmds) => {
            if cmds.is_empty() {
                errs.push("commands[] must record at least one command".into());
            }
            for (i, c) in cmds.iter().enumerate() {
                if c.get("argv").and_then(|v| v.as_arr()).map(|a| a.is_empty()).unwrap_or(true) {
                    errs.push(format!("commands[{i}]: missing non-empty argv"));
                }
                for key in ["exit_code", "duration_ms"] {
                    if c.get(key).and_then(|v| v.as_f64()).is_none() {
                        errs.push(format!("commands[{i}]: missing numeric {key}"));
                    }
                }
            }
        }
    }
    if doc.get("metrics").is_none() {
        errs.push("missing field 'metrics'".into());
    }
    for section in ["artifacts", "logs"] {
        match doc.get(section).and_then(|v| v.as_arr()) {
            None => errs.push(format!("missing array field '{section}'")),
            Some(files) => {
                for (i, f) in files.iter().enumerate() {
                    validate_file_record(section, i, f, base, &mut errs);
                }
            }
        }
    }
    match doc.get("manifest_sha256").and_then(|v| v.as_str()) {
        None => errs.push("missing field 'manifest_sha256'".into()),
        Some(claimed) => {
            let actual = canonical_sha256(doc);
            if claimed != actual {
                errs.push(format!(
                    "manifest_sha256 mismatch: manifest says {claimed}, canonical body hashes \
                     to {actual}"
                ));
            }
        }
    }
    if errs.is_empty() {
        Ok(())
    } else {
        Err(errs)
    }
}

fn validate_file_record(section: &str, i: usize, f: &Json, base: &Path, errs: &mut Vec<String>) {
    let at = format!("{section}[{i}]");
    let (path, sha, bytes) = match (
        f.get("path").and_then(|v| v.as_str()),
        f.get("sha256").and_then(|v| v.as_str()),
        f.get("bytes").and_then(|v| v.as_u64()),
    ) {
        (Some(p), Some(s), Some(b)) => (p, s, b),
        _ => {
            errs.push(format!("{at}: needs path, sha256 and bytes"));
            return;
        }
    };
    // Relative paths resolve against the manifest's own directory
    // first (a CI artifact bundle travels as one tree), falling back to
    // the working directory (a manifest written to `manifests/` while
    // its artifacts stayed in the repo root).
    let full: PathBuf = if Path::new(path).is_absolute() {
        PathBuf::from(path)
    } else {
        let joined = base.join(path);
        if !joined.exists() && Path::new(path).exists() {
            PathBuf::from(path)
        } else {
            joined
        }
    };
    match std::fs::read(&full) {
        Err(e) => errs.push(format!("{at}: cannot read {}: {e}", full.display())),
        Ok(data) => {
            if data.len() as u64 != bytes {
                errs.push(format!(
                    "{at}: {path} is {} bytes, manifest says {bytes}",
                    data.len()
                ));
            }
            let actual = sha256_hex(&data);
            if actual != sha {
                errs.push(format!("{at}: {path} hashes to {actual}, manifest says {sha}"));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("rtxrmq_manifest_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn build_one(dir: &Path) -> (PathBuf, Json) {
        let artifact = dir.join("bench.json");
        std::fs::write(&artifact, b"{\"bench\":\"rmq_smoke\"}\n").unwrap();
        let mut mb = ManifestBuilder::new("cafe0123deadbeef");
        mb.command(&["rtxrmq".into(), "bench-smoke".into()], 0);
        mb.metrics(obj(vec![("points", Json::Num(12.0))]));
        mb.artifact(&artifact).unwrap();
        let path = dir.join("manifest.json");
        let doc = mb.write(&path).unwrap();
        (path, doc)
    }

    #[test]
    fn roundtrip_validates() {
        let dir = tmp_dir("roundtrip");
        let (path, doc) = build_one(&dir);
        // From the sealed document in memory…
        validate(&doc, &dir).unwrap();
        // …and re-parsed from disk (what manifest-check does).
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(text.trim()).unwrap();
        validate(&parsed, &dir).unwrap();
        assert_eq!(parsed.get("schema_version").unwrap().as_str(), Some(SCHEMA_VERSION));
        assert_eq!(parsed.get("run_id").unwrap().as_str(), Some("cafe0123deadbeef"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tampered_artifact_fails_the_hash_check() {
        let dir = tmp_dir("tamper");
        let (path, _) = build_one(&dir);
        std::fs::write(dir.join("bench.json"), b"{\"bench\":\"swapped\"}\n").unwrap();
        let parsed = Json::parse(std::fs::read_to_string(&path).unwrap().trim()).unwrap();
        let errs = validate(&parsed, &dir).unwrap_err();
        assert!(errs.iter().any(|e| e.contains("hashes to")), "{errs:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn edited_body_fails_the_manifest_hash() {
        let dir = tmp_dir("editbody");
        let (_, doc) = build_one(&dir);
        let mut map = match doc {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        map.insert("run_id".into(), Json::Str("0000000000000000".into()));
        let errs = validate(&Json::Obj(map), &dir).unwrap_err();
        assert!(errs.iter().any(|e| e.contains("manifest_sha256 mismatch")), "{errs:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_fields_are_each_reported() {
        let doc = obj(vec![("schema_version", Json::Str("2.0.0".into()))]);
        let errs = validate(&doc, Path::new(".")).unwrap_err();
        assert!(errs.iter().any(|e| e.contains("unsupported schema major 2")), "{errs:?}");
        assert!(errs.iter().any(|e| e.contains("run_id")), "{errs:?}");
        assert!(errs.iter().any(|e| e.contains("commands")), "{errs:?}");
        assert!(errs.iter().any(|e| e.contains("manifest_sha256")), "{errs:?}");
    }

    #[test]
    fn canonical_hash_ignores_embedded_hash_only() {
        let dir = tmp_dir("canon");
        let (_, doc) = build_one(&dir);
        let h1 = canonical_sha256(&doc);
        // Stripping the hash field does not change the canonical hash…
        let stripped = match &doc {
            Json::Obj(m) => {
                let mut m = m.clone();
                m.remove("manifest_sha256");
                Json::Obj(m)
            }
            _ => unreachable!(),
        };
        assert_eq!(h1, canonical_sha256(&stripped));
        // …but touching any other field does.
        let touched = match &doc {
            Json::Obj(m) => {
                let mut m = m.clone();
                m.insert("timestamp".into(), Json::Num(0.0));
                Json::Obj(m)
            }
            _ => unreachable!(),
        };
        assert_ne!(h1, canonical_sha256(&touched));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn relative_artifact_falls_back_to_cwd() {
        // The CLI records artifact paths as given on the command line
        // (often CWD-relative) while `--manifest manifests/run.json`
        // puts the manifest in a subdirectory; the validator must find
        // the artifact via the working directory when the
        // manifest-directory join misses.
        let rel = PathBuf::from(format!("target/manifest_cwd_fallback_{}", std::process::id()));
        std::fs::create_dir_all(&rel).unwrap();
        let artifact = rel.join("bench.json");
        std::fs::write(&artifact, b"{\"bench\":\"rmq_smoke\"}\n").unwrap();
        let mut mb = ManifestBuilder::new("cafe0123deadbeef");
        mb.command(&["rtxrmq".into(), "bench-smoke".into()], 0);
        mb.artifact(&artifact).unwrap();
        let doc = mb.finish();
        let missing_base = std::env::temp_dir().join("rtxrmq_no_such_base_dir");
        validate(&doc, &missing_base).unwrap();
        std::fs::remove_dir_all(&rel).ok();
    }

    #[test]
    fn run_ids_are_hex_and_distinct() {
        let a = gen_run_id();
        let b = gen_run_id();
        assert_eq!(a.len(), 16);
        assert!(a.chars().all(|c| c.is_ascii_hexdigit()));
        assert_ne!(a, b, "two draws share a token only on a splitmix collision");
    }
}

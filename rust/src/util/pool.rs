//! Scoped fork-join parallelism built on `std::thread::scope` (the
//! offline environment has no `rayon`; std scoped threads cover the
//! fork-join pattern without any dependency). Batch engines use
//! [`par_map_chunks`] / [`for_each_chunk_mut`] / [`map_chunks_mut`] to
//! parallelise over query batches the way the paper parallelises HRMQ
//! with OpenMP (§6.1).
//!
//! [`map_chunks_mut`] additionally returns one value per worker chunk —
//! the hot-path engines use it to hand back per-worker `Counters` that
//! the caller sums, instead of funnelling every worker through a shared
//! `Mutex` (§Perf: no lock traffic inside the query loop).

/// Number of workers to use: `RTXRMQ_THREADS` env override, else the
/// machine's available parallelism.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("RTXRMQ_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `len` items into at most `workers` contiguous chunk ranges of
/// near-equal size.
pub fn chunk_ranges(len: usize, workers: usize) -> Vec<std::ops::Range<usize>> {
    if len == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, len);
    let base = len / workers;
    let extra = len % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for w in 0..workers {
        let size = base + usize::from(w < extra);
        out.push(start..start + size);
        start += size;
    }
    out
}

/// Apply `f` to each index chunk of `out` in parallel, giving each worker
/// a disjoint `&mut [T]` slice plus the global offset of its chunk, and
/// collect each worker's return value (in chunk order).
///
/// With one worker (this CI host) it degenerates to a plain loop with no
/// thread spawn, so wall-clock baselines remain clean.
pub fn map_chunks_mut<T, R, F>(out: &mut [T], workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut [T]) -> R + Sync + Send,
{
    let ranges = chunk_ranges(out.len(), workers);
    if ranges.is_empty() {
        return Vec::new();
    }
    if ranges.len() == 1 {
        return vec![f(0, out)];
    }
    // Carve disjoint mutable slices.
    let mut slices: Vec<(usize, &mut [T])> = Vec::with_capacity(ranges.len());
    let mut rest = out;
    let mut offset = 0;
    for r in &ranges {
        let (head, tail) = rest.split_at_mut(r.len());
        slices.push((offset, head));
        offset += r.len();
        rest = tail;
    }
    let f = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> =
            slices.into_iter().map(|(off, slice)| s.spawn(move || f(off, slice))).collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    })
}

/// Apply `f` to each index chunk of `out` in parallel (no return values).
pub fn for_each_chunk_mut<T: Send, F>(out: &mut [T], workers: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync + Send,
{
    map_chunks_mut(out, workers, |off, slice| f(off, slice));
}

/// Parallel map over chunks: each worker maps its chunk of `items` with
/// `f(global_index, &item)`; results are returned in input order.
pub fn par_map_chunks<T: Sync, R: Send + Default + Clone, F>(
    items: &[T],
    workers: usize,
    f: F,
) -> Vec<R>
where
    F: Fn(usize, &T) -> R + Sync + Send,
{
    let mut out = vec![R::default(); items.len()];
    for_each_chunk_mut(&mut out, workers, |off, slice| {
        for (k, o) in slice.iter_mut().enumerate() {
            *o = f(off + k, &items[off + k]);
        }
    });
    out
}

/// Run `workers` copies of a worker function that pull whole pre-computed
/// chunk ranges; used when per-worker state (e.g. a traversal stack) must
/// be reused across items.
pub fn run_chunked<F>(len: usize, workers: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync + Send,
{
    let ranges = chunk_ranges(len, workers);
    if ranges.len() <= 1 {
        if let Some(r) = ranges.into_iter().next() {
            f(r);
        }
        return;
    }
    let f = &f;
    std::thread::scope(|s| {
        for r in ranges {
            s.spawn(move || f(r));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_exactly() {
        for len in [0usize, 1, 7, 100, 101] {
            for w in [1usize, 2, 3, 8, 200] {
                let ranges = chunk_ranges(len, w);
                let total: usize = ranges.iter().map(|r| r.len()).sum();
                assert_eq!(total, len, "len={len} w={w}");
                let mut expect = 0;
                for r in &ranges {
                    assert_eq!(r.start, expect);
                    assert!(!r.is_empty());
                    expect = r.end;
                }
            }
        }
    }

    #[test]
    fn for_each_chunk_mut_writes_all() {
        let mut v = vec![0usize; 1000];
        for_each_chunk_mut(&mut v, 4, |off, slice| {
            for (k, x) in slice.iter_mut().enumerate() {
                *x = off + k;
            }
        });
        assert!(v.iter().enumerate().all(|(i, &x)| x == i));
    }

    #[test]
    fn map_chunks_collects_one_result_per_chunk() {
        let mut v = vec![1u64; 100];
        let sums = map_chunks_mut(&mut v, 4, |_, slice| slice.iter().sum::<u64>());
        assert_eq!(sums.len(), 4);
        assert_eq!(sums.iter().sum::<u64>(), 100);
        // Empty input: no chunks, no results.
        let mut empty: Vec<u64> = Vec::new();
        let r = map_chunks_mut(&mut empty, 4, |_, slice| slice.len());
        assert!(r.is_empty());
        // Single worker runs inline and still returns its result.
        let mut one = vec![0u8; 16];
        let r = map_chunks_mut(&mut one, 1, |_, slice| slice.len());
        assert_eq!(r, vec![16]);
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<u64> = (0..257).collect();
        let out = par_map_chunks(&items, 3, |i, &x| x * 2 + i as u64);
        for (i, &o) in out.iter().enumerate() {
            assert_eq!(o, items[i] * 2 + i as u64);
        }
    }

    #[test]
    fn run_chunked_visits_every_index_once() {
        let counter = AtomicUsize::new(0);
        run_chunked(1003, 5, |r| {
            counter.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1003);
    }

    #[test]
    fn single_worker_runs_inline() {
        let mut v = vec![0u8; 16];
        for_each_chunk_mut(&mut v, 1, |_, s| s.fill(7));
        assert!(v.iter().all(|&x| x == 7));
    }
}

//! Scoped fork-join parallelism built on `std::thread::scope` (the
//! offline environment has no `rayon`; std scoped threads cover the
//! fork-join pattern without any dependency). Batch engines use
//! [`par_map_chunks`] / [`for_each_chunk_mut`] / [`map_chunks_mut`] to
//! parallelise over query batches the way the paper parallelises HRMQ
//! with OpenMP (§6.1).
//!
//! [`map_chunks_mut`] additionally returns one value per worker chunk —
//! the hot-path engines use it to hand back per-worker `Counters` that
//! the caller sums, instead of funnelling every worker through a shared
//! `Mutex` (§Perf: no lock traffic inside the query loop).
//!
//! §Robustness: spawned workers are panic-isolated. A worker that
//! unwinds (a bug, or an injected `pool.worker` fault) is caught at the
//! join, counted via [`faults::note_caught`], and its chunk re-run
//! inline on the calling thread — the chunk closures are pure functions
//! of their disjoint slice, so an inline retry produces exactly the
//! result the dead worker would have. The inline paths (single chunk,
//! and the retry itself) never poll the fault registry, so a retry
//! cannot re-draw the fault that killed the worker.

use crate::util::faults;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};

/// Number of workers to use: `RTXRMQ_THREADS` env override, else the
/// machine's available parallelism.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("RTXRMQ_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `len` items into at most `workers` contiguous chunk ranges of
/// near-equal size.
pub fn chunk_ranges(len: usize, workers: usize) -> Vec<std::ops::Range<usize>> {
    if len == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, len);
    let base = len / workers;
    let extra = len % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for w in 0..workers {
        let size = base + usize::from(w < extra);
        out.push(start..start + size);
        start += size;
    }
    out
}

/// Apply `f` to each index chunk of `out` in parallel, giving each worker
/// a disjoint `&mut [T]` slice plus the global offset of its chunk, and
/// collect each worker's return value (in chunk order).
///
/// With one worker (this CI host) it degenerates to a plain loop with no
/// thread spawn, so wall-clock baselines remain clean.
pub fn map_chunks_mut<T, R, F>(out: &mut [T], workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut [T]) -> R + Sync + Send,
{
    let ranges = chunk_ranges(out.len(), workers);
    if ranges.is_empty() {
        return Vec::new();
    }
    if ranges.len() == 1 {
        return vec![f(0, out)];
    }
    // Carve disjoint mutable slices.
    let mut slices: Vec<(usize, &mut [T])> = Vec::with_capacity(ranges.len());
    let mut rest = out;
    let mut offset = 0;
    for r in &ranges {
        let (head, tail) = rest.split_at_mut(r.len());
        slices.push((offset, head));
        offset += r.len();
        rest = tail;
    }
    let f = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = slices
            .into_iter()
            .map(|(off, slice)| {
                s.spawn(move || {
                    let r = catch_unwind(AssertUnwindSafe(|| {
                        faults::fire("pool.worker");
                        f(off, &mut *slice)
                    }));
                    (off, slice, r.ok())
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                let (off, slice, r) = h.join().expect("worker thread infrastructure failed");
                r.unwrap_or_else(|| {
                    faults::note_caught();
                    f(off, slice)
                })
            })
            .collect()
    })
}

/// Apply `f` to each index chunk of `out` in parallel (no return values).
pub fn for_each_chunk_mut<T: Send, F>(out: &mut [T], workers: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync + Send,
{
    map_chunks_mut(out, workers, |off, slice| f(off, slice));
}

/// Parallel map over chunks: each worker maps its chunk of `items` with
/// `f(global_index, &item)`; results are returned in input order.
pub fn par_map_chunks<T: Sync, R: Send + Default + Clone, F>(
    items: &[T],
    workers: usize,
    f: F,
) -> Vec<R>
where
    F: Fn(usize, &T) -> R + Sync + Send,
{
    let mut out = vec![R::default(); items.len()];
    for_each_chunk_mut(&mut out, workers, |off, slice| {
        for (k, o) in slice.iter_mut().enumerate() {
            *o = f(off + k, &items[off + k]);
        }
    });
    out
}

/// Exclusive-ownership token for work-stealing over many logical
/// queues: workers race [`try_claim`](Self::try_claim), the winner
/// drains that queue, and the [`ClaimGuard`] hands it back on drop —
/// panic included, so a dying worker can never orphan a queue. The
/// multi-tenant executor (`coordinator/tenants.rs`) uses one `Claim`
/// per tenant to let any idle worker steal any ready tenant while
/// still guaranteeing at most one worker executes a given tenant's
/// stream at a time (the per-tenant fence is strict stream order).
#[derive(Debug, Default)]
pub struct Claim(AtomicBool);

impl Claim {
    pub const fn new() -> Claim {
        Claim(AtomicBool::new(false))
    }

    /// Race for ownership; the winner gets a releasing guard.
    pub fn try_claim(&self) -> Option<ClaimGuard<'_>> {
        self.0
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .ok()
            .map(|_| ClaimGuard(self))
    }

    pub fn is_claimed(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// RAII release of a [`Claim`].
pub struct ClaimGuard<'a>(&'a Claim);

impl Drop for ClaimGuard<'_> {
    fn drop(&mut self) {
        self.0 .0.store(false, Ordering::Release);
    }
}

/// Run `workers` copies of a worker function that pull whole pre-computed
/// chunk ranges; used when per-worker state (e.g. a traversal stack) must
/// be reused across items.
pub fn run_chunked<F>(len: usize, workers: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync + Send,
{
    let ranges = chunk_ranges(len, workers);
    if ranges.len() <= 1 {
        if let Some(r) = ranges.into_iter().next() {
            f(r);
        }
        return;
    }
    let f = &f;
    // Ranges whose worker panicked; re-run inline after the scope (the
    // closures are idempotent over their disjoint ranges).
    let failed: std::sync::Mutex<Vec<std::ops::Range<usize>>> = std::sync::Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for r in ranges {
            let failed = &failed;
            s.spawn(move || {
                let attempt = catch_unwind(AssertUnwindSafe(|| {
                    faults::fire("pool.worker");
                    f(r.clone())
                }));
                if attempt.is_err() {
                    failed.lock().unwrap_or_else(|p| p.into_inner()).push(r);
                }
            });
        }
    });
    for r in failed.into_inner().unwrap_or_else(|p| p.into_inner()) {
        faults::note_caught();
        f(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_exactly() {
        for len in [0usize, 1, 7, 100, 101] {
            for w in [1usize, 2, 3, 8, 200] {
                let ranges = chunk_ranges(len, w);
                let total: usize = ranges.iter().map(|r| r.len()).sum();
                assert_eq!(total, len, "len={len} w={w}");
                let mut expect = 0;
                for r in &ranges {
                    assert_eq!(r.start, expect);
                    assert!(!r.is_empty());
                    expect = r.end;
                }
            }
        }
    }

    #[test]
    fn for_each_chunk_mut_writes_all() {
        let mut v = vec![0usize; 1000];
        for_each_chunk_mut(&mut v, 4, |off, slice| {
            for (k, x) in slice.iter_mut().enumerate() {
                *x = off + k;
            }
        });
        assert!(v.iter().enumerate().all(|(i, &x)| x == i));
    }

    #[test]
    fn map_chunks_collects_one_result_per_chunk() {
        let mut v = vec![1u64; 100];
        let sums = map_chunks_mut(&mut v, 4, |_, slice| slice.iter().sum::<u64>());
        assert_eq!(sums.len(), 4);
        assert_eq!(sums.iter().sum::<u64>(), 100);
        // Empty input: no chunks, no results.
        let mut empty: Vec<u64> = Vec::new();
        let r = map_chunks_mut(&mut empty, 4, |_, slice| slice.len());
        assert!(r.is_empty());
        // Single worker runs inline and still returns its result.
        let mut one = vec![0u8; 16];
        let r = map_chunks_mut(&mut one, 1, |_, slice| slice.len());
        assert_eq!(r, vec![16]);
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<u64> = (0..257).collect();
        let out = par_map_chunks(&items, 3, |i, &x| x * 2 + i as u64);
        for (i, &o) in out.iter().enumerate() {
            assert_eq!(o, items[i] * 2 + i as u64);
        }
    }

    #[test]
    fn run_chunked_visits_every_index_once() {
        let counter = AtomicUsize::new(0);
        run_chunked(1003, 5, |r| {
            counter.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1003);
    }

    #[test]
    fn single_worker_runs_inline() {
        let mut v = vec![0u8; 16];
        for_each_chunk_mut(&mut v, 1, |_, s| s.fill(7));
        assert!(v.iter().all(|&x| x == 7));
    }

    #[test]
    fn map_chunks_retries_panicked_worker_inline() {
        // First invocation touching offset 0 dies mid-write; the join
        // catches it and the inline retry recomputes the exact chunk.
        let boom = std::sync::atomic::AtomicBool::new(true);
        let mut v = vec![0usize; 1000];
        let sums = map_chunks_mut(&mut v, 4, |off, slice| {
            for (k, x) in slice.iter_mut().enumerate() {
                *x = off + k;
            }
            if off == 0 && boom.swap(false, Ordering::SeqCst) {
                panic!("worker dies after writing");
            }
            slice.iter().sum::<usize>()
        });
        assert!(v.iter().enumerate().all(|(i, &x)| x == i));
        assert_eq!(sums.len(), 4);
        assert_eq!(sums.iter().sum::<usize>(), (0..1000).sum::<usize>());
    }

    #[test]
    fn claim_is_exclusive_and_releases_on_drop() {
        let c = Claim::new();
        assert!(!c.is_claimed());
        let g = c.try_claim().expect("first claim wins");
        assert!(c.is_claimed());
        assert!(c.try_claim().is_none(), "held claim rejects the race");
        drop(g);
        assert!(!c.is_claimed());
        assert!(c.try_claim().is_some(), "released claim is takeable again");
    }

    #[test]
    fn claim_releases_across_a_panic() {
        let c = Claim::new();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = c.try_claim().unwrap();
            panic!("worker dies holding the claim");
        }));
        assert!(r.is_err());
        assert!(!c.is_claimed(), "guard drop ran during unwind");
    }

    #[test]
    fn run_chunked_retries_panicked_range() {
        let boom = std::sync::atomic::AtomicBool::new(true);
        let visited = std::sync::Mutex::new(vec![0u32; 1003]);
        run_chunked(1003, 5, |r| {
            if boom.swap(false, Ordering::SeqCst) {
                panic!("worker dies before touching its range");
            }
            let mut v = visited.lock().unwrap_or_else(|p| p.into_inner());
            for i in r {
                v[i] = 1; // idempotent: retry rewrites the same slots
            }
        });
        let v = visited.into_inner().unwrap_or_else(|p| p.into_inner());
        assert!(v.iter().all(|&x| x == 1), "every index visited despite one dead worker");
    }
}

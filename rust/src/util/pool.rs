//! Scoped fork-join parallelism built on `crossbeam_utils::thread::scope`
//! (the offline environment has no `rayon`). Batch engines use
//! [`par_map_chunks`] / [`for_each_chunk_mut`] to parallelise over query
//! batches the way the paper parallelises HRMQ with OpenMP (§6.1).

use crossbeam_utils::thread;

/// Number of workers to use: `RTXRMQ_THREADS` env override, else the
/// machine's available parallelism.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("RTXRMQ_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `len` items into at most `workers` contiguous chunk ranges of
/// near-equal size.
pub fn chunk_ranges(len: usize, workers: usize) -> Vec<std::ops::Range<usize>> {
    if len == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, len);
    let base = len / workers;
    let extra = len % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for w in 0..workers {
        let size = base + usize::from(w < extra);
        out.push(start..start + size);
        start += size;
    }
    out
}

/// Apply `f` to each index chunk of `out` in parallel, giving each worker a
/// disjoint `&mut [T]` slice plus the global offset of its chunk.
///
/// With one worker (this CI host) it degenerates to a plain loop with no
/// thread spawn, so wall-clock baselines remain clean.
pub fn for_each_chunk_mut<T: Send, F>(out: &mut [T], workers: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync + Send,
{
    let ranges = chunk_ranges(out.len(), workers);
    if ranges.len() <= 1 {
        if !out.is_empty() {
            f(0, out);
        }
        return;
    }
    // Carve disjoint mutable slices.
    let mut slices: Vec<(usize, &mut [T])> = Vec::with_capacity(ranges.len());
    let mut rest = out;
    let mut offset = 0;
    for r in &ranges {
        let (head, tail) = rest.split_at_mut(r.len());
        slices.push((offset, head));
        offset += r.len();
        rest = tail;
    }
    let f = &f;
    thread::scope(|s| {
        for (off, slice) in slices {
            s.spawn(move |_| f(off, slice));
        }
    })
    .expect("worker panicked");
}

/// Parallel map over chunks: each worker maps its chunk of `items` with
/// `f(global_index, &item)`; results are returned in input order.
pub fn par_map_chunks<T: Sync, R: Send + Default + Clone, F>(
    items: &[T],
    workers: usize,
    f: F,
) -> Vec<R>
where
    F: Fn(usize, &T) -> R + Sync + Send,
{
    let mut out = vec![R::default(); items.len()];
    for_each_chunk_mut(&mut out, workers, |off, slice| {
        for (k, o) in slice.iter_mut().enumerate() {
            *o = f(off + k, &items[off + k]);
        }
    });
    out
}

/// Run `workers` copies of a worker function that pull whole pre-computed
/// chunk ranges; used when per-worker state (e.g. a traversal stack) must
/// be reused across items.
pub fn run_chunked<F>(len: usize, workers: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync + Send,
{
    let ranges = chunk_ranges(len, workers);
    if ranges.len() <= 1 {
        if let Some(r) = ranges.into_iter().next() {
            f(r);
        }
        return;
    }
    let f = &f;
    thread::scope(|s| {
        for r in ranges {
            s.spawn(move |_| f(r));
        }
    })
    .expect("worker panicked");
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_exactly() {
        for len in [0usize, 1, 7, 100, 101] {
            for w in [1usize, 2, 3, 8, 200] {
                let ranges = chunk_ranges(len, w);
                let total: usize = ranges.iter().map(|r| r.len()).sum();
                assert_eq!(total, len, "len={len} w={w}");
                let mut expect = 0;
                for r in &ranges {
                    assert_eq!(r.start, expect);
                    assert!(!r.is_empty());
                    expect = r.end;
                }
            }
        }
    }

    #[test]
    fn for_each_chunk_mut_writes_all() {
        let mut v = vec![0usize; 1000];
        for_each_chunk_mut(&mut v, 4, |off, slice| {
            for (k, x) in slice.iter_mut().enumerate() {
                *x = off + k;
            }
        });
        assert!(v.iter().enumerate().all(|(i, &x)| x == i));
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<u64> = (0..257).collect();
        let out = par_map_chunks(&items, 3, |i, &x| x * 2 + i as u64);
        for (i, &o) in out.iter().enumerate() {
            assert_eq!(o, items[i] * 2 + i as u64);
        }
    }

    #[test]
    fn run_chunked_visits_every_index_once() {
        let counter = AtomicUsize::new(0);
        run_chunked(1003, 5, |r| {
            counter.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1003);
    }

    #[test]
    fn single_worker_runs_inline() {
        let mut v = vec![0u8; 16];
        for_each_chunk_mut(&mut v, 1, |_, s| s.fill(7));
        assert!(v.iter().all(|&x| x == 7));
    }
}

//! Bit-level utilities shared by the succinct RMQ structures (HRMQ's
//! balanced-parentheses excess blocks, ±1 RMQ lookup tables) and the
//! Morton-code LBVH builder.

/// Plain bit vector with O(1) access and rank support (one absolute count
/// per 64-bit word — simple, cache-friendly, 1.5n bits total with counts).
#[derive(Clone, Debug)]
pub struct BitVec {
    words: Vec<u64>,
    len: usize,
    /// rank1 up to the start of each word (built by `build_rank`).
    rank: Vec<u32>,
}

impl BitVec {
    pub fn with_len(len: usize) -> BitVec {
        BitVec { words: vec![0; len.div_ceil(64)], len, rank: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let (w, b) = (i / 64, i % 64);
        if v {
            self.words[w] |= 1u64 << b;
        } else {
            self.words[w] &= !(1u64 << b);
        }
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Build the rank directory; must be called before [`rank1`].
    pub fn build_rank(&mut self) {
        let mut acc = 0u32;
        self.rank = Vec::with_capacity(self.words.len() + 1);
        for &w in &self.words {
            self.rank.push(acc);
            acc += w.count_ones();
        }
        self.rank.push(acc);
    }

    /// Number of 1-bits in `[0, i)`.
    #[inline]
    pub fn rank1(&self, i: usize) -> usize {
        debug_assert!(i <= self.len);
        debug_assert!(!self.rank.is_empty(), "build_rank not called");
        let (w, b) = (i / 64, i % 64);
        let partial = if b == 0 { 0 } else { (self.words[w] & ((1u64 << b) - 1)).count_ones() };
        self.rank[w] as usize + partial as usize
    }

    /// Number of 0-bits in `[0, i)`.
    #[inline]
    pub fn rank0(&self, i: usize) -> usize {
        i - self.rank1(i)
    }

    /// Heap size of the structure in bytes (Table 2 accounting).
    pub fn memory_bytes(&self) -> usize {
        self.words.len() * 8 + self.rank.len() * 4
    }
}

/// Select the position of the `k`-th (0-based) set bit within a word.
/// Portable broadword implementation.
#[inline]
pub fn select_in_word(mut word: u64, mut k: u32) -> u32 {
    // Clear the k lowest set bits, then count trailing zeros.
    for _ in 0..k {
        word &= word - 1;
    }
    debug_assert!(word != 0, "select out of range");
    k = word.trailing_zeros();
    k
}

/// Canonical bit-spread: insert two zero bits between each of the low 21
/// bits of `v`. Used by the Morton-code LBVH builder, mirroring GPU BVH
/// construction (Karras-style).
#[inline]
pub fn part1by2_canonical(v: u32) -> u64 {
    let mut x = (v as u64) & 0x1F_FFFF;
    x = (x | (x << 32)) & 0x001F_0000_0000_FFFF;
    x = (x | (x << 16)) & 0x001F_0000_FF00_00FF;
    x = (x | (x << 8)) & 0x100F_00F0_0F00_F00F;
    x = (x | (x << 4)) & 0x10C3_0C30_C30C_30C3;
    x = (x | (x << 2)) & 0x1249_2492_4924_9249;
    x
}

/// Morton code via the canonical spread.
#[inline]
pub fn morton3_canonical(x: u32, y: u32, z: u32) -> u64 {
    part1by2_canonical(x) | (part1by2_canonical(y) << 1) | (part1by2_canonical(z) << 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitvec_set_get() {
        let mut b = BitVec::with_len(130);
        b.set(0, true);
        b.set(64, true);
        b.set(129, true);
        assert!(b.get(0) && b.get(64) && b.get(129));
        assert!(!b.get(1) && !b.get(63) && !b.get(128));
        b.set(64, false);
        assert!(!b.get(64));
    }

    #[test]
    fn rank_matches_naive() {
        let mut b = BitVec::with_len(1000);
        let mut naive = vec![false; 1000];
        let mut state = 12345u64;
        for i in 0..1000 {
            let v = super::super::rng::splitmix64(&mut state) & 1 == 1;
            b.set(i, v);
            naive[i] = v;
        }
        b.build_rank();
        let mut acc = 0;
        for i in 0..=1000 {
            assert_eq!(b.rank1(i), acc, "at {i}");
            assert_eq!(b.rank0(i), i - acc);
            if i < 1000 && naive[i] {
                acc += 1;
            }
        }
    }

    #[test]
    fn select_in_word_matches_scan() {
        let w: u64 = 0b1011_0110_0100;
        let set_positions: Vec<u32> =
            (0..64).filter(|&i| (w >> i) & 1 == 1).collect();
        for (k, &pos) in set_positions.iter().enumerate() {
            assert_eq!(select_in_word(w, k as u32), pos);
        }
    }

    #[test]
    fn morton_interleaves() {
        // x=0b1, y=0b0, z=0b0 -> bit0
        assert_eq!(morton3_canonical(1, 0, 0), 0b001);
        assert_eq!(morton3_canonical(0, 1, 0), 0b010);
        assert_eq!(morton3_canonical(0, 0, 1), 0b100);
        // x=0b11 -> bits 0 and 3
        assert_eq!(morton3_canonical(3, 0, 0), 0b1001);
        // Full 21-bit round trip: de-interleave by scanning.
        let (x, y, z) = (0x155555, 0xAAAA, 0x1F0F3);
        let m = morton3_canonical(x, y, z);
        let (mut dx, mut dy, mut dz) = (0u32, 0u32, 0u32);
        for i in 0..21 {
            dx |= (((m >> (3 * i)) & 1) as u32) << i;
            dy |= (((m >> (3 * i + 1)) & 1) as u32) << i;
            dz |= (((m >> (3 * i + 2)) & 1) as u32) << i;
        }
        assert_eq!((dx, dy, dz), (x, y, z));
    }

    #[test]
    fn morton_orders_nearby_points_together() {
        // Points close in 3D should mostly be close in Morton order:
        // specifically the code is monotone along each axis.
        assert!(morton3_canonical(1, 1, 1) < morton3_canonical(2, 2, 2));
        assert!(morton3_canonical(0, 0, 0) < morton3_canonical(1, 0, 0));
    }

    #[test]
    fn bitvec_memory_accounting() {
        let mut b = BitVec::with_len(1 << 16);
        b.build_rank();
        // 1024 words * 8B + 1025 rank entries * 4B
        assert_eq!(b.memory_bytes(), 1024 * 8 + 1025 * 4);
    }
}

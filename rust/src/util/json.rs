//! Minimal JSON reader/writer (no `serde` offline). Used for
//! `artifacts/manifest.json` (runtime) and experiment metadata output.
//!
//! Supports the full JSON grammar except exotic number forms; numbers are
//! stored as `f64` (adequate for manifest shapes up to 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder for objects.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, text: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{text}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if matches!(c, b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self.b.get(self.pos).ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.b.get(self.pos).ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let start = self.pos - 1;
                        let end = (start + len).min(self.b.len());
                        let s = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"name":"rmq_exhaustive","n":1024,"q":256,"pallas":true,"dims":[1024,256],"note":null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(1024));
        assert_eq!(v.get("name").unwrap().as_str(), Some("rmq_exhaustive"));
        assert_eq!(v.get("pallas").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("dims").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("note"), Some(&Json::Null));
        let back = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn nested_and_whitespace() {
        let v = Json::parse(" { \"a\" : [ 1 , { \"b\" : [] } ] } ").unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].get("b").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA"));
        let round = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(round, v);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}x").is_err());
        assert!(Json::parse("nope").is_err());
    }

    #[test]
    fn builder() {
        let j = obj(vec![("k", Json::from(3usize)), ("s", Json::from("x"))]);
        assert_eq!(j.to_string_compact(), r#"{"k":3,"s":"x"}"#);
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"αβγ µs\"").unwrap();
        assert_eq!(v.as_str(), Some("αβγ µs"));
    }
}

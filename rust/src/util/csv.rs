//! Tiny CSV writer used by the bench harness to emit the per-figure data
//! series (one CSV per paper figure/table, see DESIGN.md §4).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// CSV writer with a fixed header; panics if a row has the wrong arity
/// (bench drivers are internal callers, so this is a programmer error).
pub struct CsvWriter {
    out: Box<dyn Write>,
    columns: usize,
}

impl CsvWriter {
    /// Write to a file, creating parent directories.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<CsvWriter> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let f = BufWriter::new(File::create(path)?);
        Self::from_writer(Box::new(f), header)
    }

    /// Write to an arbitrary sink (tests use a Vec<u8>).
    pub fn from_writer(mut out: Box<dyn Write>, header: &[&str]) -> std::io::Result<CsvWriter> {
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, columns: header.len() })
    }

    /// Write one row of already-formatted fields.
    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        assert_eq!(fields.len(), self.columns, "csv row arity mismatch");
        let escaped: Vec<String> = fields.iter().map(|f| escape(f)).collect();
        writeln!(self.out, "{}", escaped.join(","))
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

fn escape(field: &str) -> String {
    if field.contains([',', '"', '\n']) {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

/// Format a float for CSV (trim noise, keep precision for plotting).
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1e6 || x.abs() < 1e-3 {
        format!("{x:.6e}")
    } else {
        format!("{x:.6}")
    }
}

/// Macro-free convenience: build a row from heterogeneous displayables.
#[macro_export]
macro_rules! csv_row {
    ($w:expr, $($v:expr),+ $(,)?) => {
        $w.row(&[$(format!("{}", $v)),+])
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    /// A Write sink backed by shared memory so the test can inspect output.
    #[derive(Clone)]
    struct Sink(Arc<Mutex<Vec<u8>>>);
    impl Write for Sink {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn header_and_rows() {
        let buf = Arc::new(Mutex::new(Vec::new()));
        let mut w =
            CsvWriter::from_writer(Box::new(Sink(buf.clone())), &["n", "ns_per_rmq"]).unwrap();
        csv_row!(w, 1024, fnum(5.25)).unwrap();
        csv_row!(w, 2048, fnum(6.5)).unwrap();
        w.flush().unwrap();
        let s = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        assert_eq!(s, "n,ns_per_rmq\n1024,5.250000\n2048,6.500000\n");
    }

    #[test]
    fn escaping() {
        assert_eq!(escape("plain"), "plain");
        assert_eq!(escape("a,b"), "\"a,b\"");
        assert_eq!(escape("q\"q"), "\"q\"\"q\"");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let buf = Arc::new(Mutex::new(Vec::new()));
        let mut w = CsvWriter::from_writer(Box::new(Sink(buf)), &["a", "b"]).unwrap();
        w.row(&["only-one".into()]).unwrap();
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(5.25), "5.250000");
        assert!(fnum(1e9).contains('e'));
        assert!(fnum(1e-6).contains('e'));
    }
}
